#!/usr/bin/env python3
"""Render the paper-figure panels from results/ CSVs.

Usage:
  python python/analysis/plot_curves.py results/fig3        # one figure dir
  python python/analysis/plot_curves.py --all results/      # every figure

Produces, per figure directory:
  <dir>/curves.png       learning curves vs wall-clock (Fig N top panel)
  <dir>/runtime.png      total-runtime bars (middle panel)
  <dir>/ce.png           AIP cross-entropy bars (bottom panel)
Falls back to ASCII rendering when matplotlib is unavailable.
"""

import argparse
import csv
import os
import sys
from collections import defaultdict


def read_curve(path):
    with open(path) as f:
        rows = list(csv.DictReader(f))
    return (
        [float(r["wall_clock_s"]) for r in rows],
        [float(r["eval_mean"]) for r in rows],
    )


def read_summary(path):
    with open(path) as f:
        return list(csv.DictReader(f))


def condition_of(fname):
    # '<condition>_seed<k>.csv'
    stem = os.path.basename(fname)[: -len(".csv")]
    return stem.rsplit("_seed", 1)[0]


def gather(figdir):
    curves = defaultdict(list)
    for f in sorted(os.listdir(figdir)):
        if f.endswith(".csv") and "_seed" in f and not f.startswith("histogram"):
            curves[condition_of(f)].append(read_curve(os.path.join(figdir, f)))
    summary_path = os.path.join(figdir, "summary.csv")
    summary = read_summary(summary_path) if os.path.exists(summary_path) else []
    return curves, summary


def ascii_plot(curves, width=72, height=18):
    pts = [(x, y) for runs in curves.values() for xs, ys in runs for x, y in zip(xs, ys)]
    if not pts:
        return
    xmax = max(x for x, _ in pts) or 1.0
    ymin = min(y for _, y in pts)
    ymax = max(y for _, y in pts) or 1.0
    span = (ymax - ymin) or 1.0
    grid = [[" "] * width for _ in range(height)]
    marks = "ox+*#@"
    for ci, (cond, runs) in enumerate(sorted(curves.items())):
        m = marks[ci % len(marks)]
        for xs, ys in runs:
            for x, y in zip(xs, ys):
                cx = min(width - 1, int(x / xmax * (width - 1)))
                cy = min(height - 1, int((ymax - y) / span * (height - 1)))
                grid[cy][cx] = m
    print(f"  y in [{ymin:.4f}, {ymax:.4f}], x in [0, {xmax:.1f}s]")
    for row in grid:
        print("  |" + "".join(row))
    print("  +" + "-" * width)
    for ci, cond in enumerate(sorted(curves)):
        print(f"   {marks[ci % len(marks)]} = {cond}")


def render(figdir):
    curves, summary = gather(figdir)
    if not curves and not summary:
        print(f"{figdir}: nothing to plot")
        return
    print(f"\n=== {figdir} ===")
    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt

        fig, ax = plt.subplots(figsize=(7, 4))
        for cond, runs in sorted(curves.items()):
            for i, (xs, ys) in enumerate(runs):
                ax.plot(xs, ys, label=cond if i == 0 else None, alpha=0.8)
        ax.set_xlabel("wall-clock time (s, incl. AIP prep)")
        ax.set_ylabel("GS evaluation reward")
        ax.legend()
        fig.tight_layout()
        fig.savefig(os.path.join(figdir, "curves.png"), dpi=120)
        print(f"wrote {figdir}/curves.png")

        if summary:
            conds = sorted({r["condition"] for r in summary})
            totals = [
                sum(float(r["total_secs"]) for r in summary if r["condition"] == c)
                / max(1, sum(1 for r in summary if r["condition"] == c))
                for c in conds
            ]
            ces = [
                sum(float(r["aip_ce"]) for r in summary if r["condition"] == c)
                / max(1, sum(1 for r in summary if r["condition"] == c))
                for c in conds
            ]
            for vals, name, ylabel in [
                (totals, "runtime.png", "total seconds"),
                (ces, "ce.png", "held-out cross-entropy"),
            ]:
                fig, ax = plt.subplots(figsize=(6, 3))
                ax.bar(range(len(conds)), vals)
                ax.set_xticks(range(len(conds)))
                ax.set_xticklabels(conds, rotation=20, ha="right", fontsize=7)
                ax.set_ylabel(ylabel)
                fig.tight_layout()
                fig.savefig(os.path.join(figdir, name), dpi=120)
                print(f"wrote {figdir}/{name}")
    except ImportError:
        print("(matplotlib unavailable — ASCII rendering)")
        ascii_plot(curves)
        for r in summary:
            print("  ", r)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="figure results dir, or results/ with --all")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    if args.all:
        for d in sorted(os.listdir(args.path)):
            full = os.path.join(args.path, d)
            if os.path.isdir(full):
                render(full)
    else:
        render(args.path)


if __name__ == "__main__":
    sys.exit(main())
