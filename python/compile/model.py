"""Layer 2: JAX model definitions — policy networks, influence predictors,
the PPO update and the AIP trainers — plus parameter specs shared with the
AOT emitter (``aot.py``) and the Rust runtime (via the manifest).

Conventions
-----------
* All functions take **flat positional tensor arguments** in the exact
  order declared by the specs here; ``aot.py`` lowers them positionally and
  writes the same order into the manifest, so the Rust runtime can bind
  parameters by name without any pytree logic.
* Forward (request-path) functions run the Pallas kernels (Layer 1).
  Update functions differentiate through the identical pure-jnp math from
  ``kernels/ref.py`` (interpret-mode ``pallas_call`` has no VJP rule); the
  kernel-vs-ref pytest suite pins the two implementations together.
* Scalars (learning rate, clip, Adam step counter, ...) are shape-``(1,)``
  f32 tensors to keep the Rust literal story uniform.
"""

import numpy as np
import jax
import jax.numpy as jnp

from .kernels.gru import fused_gru_cell
from .kernels.linear import fused_linear
from .kernels.ref import gru_cell_ref, linear_ref

# ---------------------------------------------------------------------------
# Domain geometry (MUST match the Rust simulators; the manifest carries these
# so the runtime validates at load time).
# ---------------------------------------------------------------------------

TRAFFIC_OBS = 42  # 4 lanes x 10 cells + phase one-hot
TRAFFIC_ACT = 2
TRAFFIC_DSET = 40
TRAFFIC_ALSH = 43
TRAFFIC_U = 4

WH_OBS = 37  # 25 position bitmap + 12 item bits
WH_ACT = 5
WH_DSET = 24
WH_ALSH = 49
WH_U = 12
WH_STACK = 8  # frame stack of the memory agent (paper App F)

POLICY_HID = 64
AIP_FNN_HID = 64
GRU_HID = 64

ROLLOUT_B = 16  # vectorized envs per training simulator
ROLLOUT_T = 128  # steps per rollout
PPO_ROLLOUT_N = ROLLOUT_B * ROLLOUT_T  # full-batch size of the fused update
PPO_EPOCHS = 4
PPO_MINIBATCH = 256
AIP_BATCH = 256
GRU_SEQ_B = 16
GRU_SEQ_T = 32  # BPTT length >= agent memory (Theorem 1)

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


# ---------------------------------------------------------------------------
# Parameter specs + initialization
# ---------------------------------------------------------------------------

def policy_spec(obs_dim, act_dim, hid=POLICY_HID):
    return [
        ("w1", (obs_dim, hid)),
        ("b1", (hid,)),
        ("w2", (hid, hid)),
        ("b2", (hid,)),
        ("w_pi", (hid, act_dim)),
        ("b_pi", (act_dim,)),
        ("w_v", (hid, 1)),
        ("b_v", (1,)),
    ]


def aip_fnn_spec(d_dim, u_dim, hid=AIP_FNN_HID):
    return [
        ("w1", (d_dim, hid)),
        ("b1", (hid,)),
        ("w2", (hid, u_dim)),
        ("b2", (u_dim,)),
    ]


def aip_gru_spec(d_dim, u_dim, hid=GRU_HID):
    return [
        ("w_x", (d_dim, 3 * hid)),
        ("w_h", (hid, 3 * hid)),
        ("b_g", (3 * hid,)),
        ("w_o", (hid, u_dim)),
        ("b_o", (u_dim,)),
    ]


def init_params(spec, seed, head_names=("w_pi", "w_v")):
    """Glorot-normal init (small-scale policy heads), deterministic."""
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in spec:
        if len(shape) == 1:
            out.append(np.zeros(shape, dtype=np.float32))
        else:
            fan_in, fan_out = shape[0], shape[1]
            scale = np.sqrt(2.0 / (fan_in + fan_out))
            if name in head_names:
                scale *= 0.1  # near-uniform initial policy / small values
            out.append(rng.normal(0.0, scale, size=shape).astype(np.float32))
    return out


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _lin(use_pallas):
    return fused_linear if use_pallas else linear_ref


def policy_fwd(params, obs, use_pallas=True):
    """-> (logits [B, A], value [B])."""
    w1, b1, w2, b2, w_pi, b_pi, w_v, b_v = params
    lin = _lin(use_pallas)
    h = lin(obs, w1, b1, "tanh")
    h = lin(h, w2, b2, "tanh")
    logits = lin(h, w_pi, b_pi, "none")
    value = lin(h, w_v, b_v, "none")[:, 0]
    return logits, value


def aip_fnn_fwd(params, d, use_pallas=True):
    """-> per-source Bernoulli probabilities [B, U]."""
    w1, b1, w2, b2 = params
    lin = _lin(use_pallas)
    h = lin(d, w1, b1, "tanh")
    return lin(h, w2, b2, "sigmoid")


def aip_fnn_logits(params, d):
    """jnp-only logits path (for the numerically-stable BCE trainer)."""
    w1, b1, w2, b2 = params
    h = linear_ref(d, w1, b1, "tanh")
    return linear_ref(h, w2, b2, "none")


def aip_gru_step(params, h, d, use_pallas=True):
    """One recurrent AIP step: -> (probs [B, U], h' [B, H])."""
    w_x, w_h, b_g, w_o, b_o = params
    cell = fused_gru_cell if use_pallas else gru_cell_ref
    lin = _lin(use_pallas)
    h_new = cell(d, h, w_x, w_h, b_g)
    probs = lin(h_new, w_o, b_o, "sigmoid")
    return probs, h_new


def aip_gru_logits_scan(params, seqs):
    """Unrolled (lax.scan) logits over a [B, T, D] batch -> [B, T, U]."""
    w_x, w_h, b_g, w_o, b_o = params
    bsz = seqs.shape[0]
    hid = w_h.shape[0]

    def step(h, x_t):
        h_new = gru_cell_ref(x_t, h, w_x, w_h, b_g)
        logits_t = linear_ref(h_new, w_o, b_o, "none")
        return h_new, logits_t

    h0 = jnp.zeros((bsz, hid), dtype=jnp.float32)
    _, logits = jax.lax.scan(step, h0, jnp.swapaxes(seqs, 0, 1))
    return jnp.swapaxes(logits, 0, 1)


# ---------------------------------------------------------------------------
# Optimization building blocks
# ---------------------------------------------------------------------------

def bce_with_logits(logits, targets):
    """Numerically-stable mean binary cross-entropy (paper Eq. 3)."""
    return jnp.mean(
        jnp.maximum(logits, 0.0)
        - logits * targets
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def clip_global_norm(grads, max_norm):
    gn = jnp.sqrt(sum(jnp.sum(g * g) for g in grads) + 1e-12)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-8))
    return [g * scale for g in grads], gn


def adam_step(params, grads, m, v, t, lr):
    """One Adam update. ``t`` and ``lr`` are shape-(1,) tensors.

    Returns (new_params, new_m, new_v, new_t).
    """
    t_new = t + 1.0
    bc1 = 1.0 - jnp.power(ADAM_B1, t_new[0])
    bc2 = 1.0 - jnp.power(ADAM_B2, t_new[0])
    new_params, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        m2 = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        v2 = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        new_params.append(p - lr[0] * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
        new_m.append(m2)
        new_v.append(v2)
    return new_params, new_m, new_v, t_new


# ---------------------------------------------------------------------------
# Training steps (compiled into *_update artifacts)
# ---------------------------------------------------------------------------

def ppo_update(params, m, v, t, lr, clip, vf_coef, ent_coef, max_gn,
               obs, actions, advantages, returns, old_logp):
    """Clipped-surrogate PPO minibatch update (Schulman et al. 2017).

    All of ``params/m/v`` are lists; scalars are shape-(1,); ``actions`` is
    int32 [M]. Returns (new_params, new_m, new_v, new_t, stats[6]) where
    stats = [total_loss, pg_loss, v_loss, entropy, approx_kl, grad_norm]
    (grad_norm is the pre-clip global gradient norm).
    """

    def loss_fn(ps):
        logits, value = policy_fwd(ps, obs, use_pallas=False)
        logp_all = jax.nn.log_softmax(logits)
        logp = jnp.take_along_axis(logp_all, actions[:, None], axis=1)[:, 0]
        ratio = jnp.exp(logp - old_logp)
        s1 = ratio * advantages
        s2 = jnp.clip(ratio, 1.0 - clip[0], 1.0 + clip[0]) * advantages
        pg_loss = -jnp.mean(jnp.minimum(s1, s2))
        v_loss = jnp.mean((value - returns) ** 2)
        probs = jnp.exp(logp_all)
        entropy = jnp.mean(-jnp.sum(probs * logp_all, axis=1))
        total = pg_loss + vf_coef[0] * v_loss - ent_coef[0] * entropy
        approx_kl = jnp.mean(old_logp - logp)
        return total, (pg_loss, v_loss, entropy, approx_kl)

    (total, (pg, vl, ent, kl)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        list(params)
    )
    grads, gn = clip_global_norm(grads, max_gn[0])
    new_params, new_m, new_v, new_t = adam_step(list(params), grads, list(m), list(v), t, lr)
    # Pre-clip global grad norm rides along as stats[5]: the Rust health
    # guard's spike detector reads it (runtime/guard.rs), and both
    # backends must agree on the stats ABI.
    stats = jnp.stack([total, pg, vl, ent, kl, gn])
    return new_params, new_m, new_v, new_t, stats


def ppo_update_fused(params, m, v, t, lr, clip, vf_coef, ent_coef, max_gn,
                     perm, obs, actions, advantages, returns, old_logp,
                     minibatch=None):
    """A whole PPO update phase (epochs × minibatches) in ONE compiled
    call — the L3 perf-pass optimization (EXPERIMENTS.md §Perf): the naive
    path pays per-call parameter round-trips 32× per iteration; this pays
    them once.

    ``perm``: int32 [E, N] — per-epoch shuffled indices supplied by the
    Rust trainer (keeping all RNG on the Rust side). ``obs`` etc. are the
    full rollout batch [N, ...]. Scans over epochs and minibatch chunks.
    Returns (new_params, new_m, new_v, new_t, stats[6]) with stats averaged
    over all minibatch updates.
    """
    mb = minibatch or PPO_MINIBATCH
    n = obs.shape[0]
    assert n % mb == 0
    p_len = len(params)

    def mb_body(carry, idx):
        ps, ms, vs, ts = carry
        mb_obs = jnp.take(obs, idx, axis=0)
        mb_act = jnp.take(actions, idx, axis=0)
        mb_adv = jnp.take(advantages, idx, axis=0)
        mb_ret = jnp.take(returns, idx, axis=0)
        mb_lp = jnp.take(old_logp, idx, axis=0)
        nps, nms, nvs, nts, stats = ppo_update(
            list(ps), list(ms), list(vs), ts, lr, clip, vf_coef, ent_coef,
            max_gn, mb_obs, mb_act, mb_adv, mb_ret, mb_lp
        )
        return (tuple(nps), tuple(nms), tuple(nvs), nts), stats

    def epoch_body(carry, perm_e):
        chunks = perm_e.reshape(n // mb, mb)
        return jax.lax.scan(mb_body, carry, chunks)

    carry = (tuple(params), tuple(m), tuple(v), t)
    carry, stats = jax.lax.scan(epoch_body, carry, perm)
    ps, ms, vs, ts = carry
    mean_stats = jnp.mean(stats.reshape(-1, 6), axis=0)
    assert len(ps) == p_len
    return list(ps), list(ms), list(vs), ts, mean_stats


def aip_fnn_update(params, m, v, t, lr, d, targets):
    """One Adam step on the FNN influence predictor (BCE, Eq. 3)."""

    def loss_fn(ps):
        return bce_with_logits(aip_fnn_logits(ps, d), targets)

    loss, grads = jax.value_and_grad(loss_fn)(list(params))
    new_params, new_m, new_v, new_t = adam_step(list(params), grads, list(m), list(v), t, lr)
    return new_params, new_m, new_v, new_t, jnp.stack([loss])


def aip_gru_update(params, m, v, t, lr, seqs, targets):
    """One Adam step on the GRU influence predictor (BPTT over T steps)."""

    def loss_fn(ps):
        return bce_with_logits(aip_gru_logits_scan(ps, seqs), targets)

    loss, grads = jax.value_and_grad(loss_fn)(list(params))
    new_params, new_m, new_v, new_t = adam_step(list(params), grads, list(m), list(v), t, lr)
    return new_params, new_m, new_v, new_t, jnp.stack([loss])
