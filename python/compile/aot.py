"""AOT emitter: lowers every Layer-2 entry point to **HLO text** and writes
the artifact manifest + initial-parameter blobs consumed by the Rust
runtime (``rust/src/runtime``).

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs (under --out, default ../artifacts):
  manifest.txt            models (param tensors) + artifacts (call ABI)
  <artifact>.hlo.txt      one per entry point
  <model>.params.bin      f32-LE tensor concatenation in manifest order

Run via ``make artifacts`` (idempotent; only reruns when sources change).
"""

import argparse
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Model registry
# ---------------------------------------------------------------------------

def _with_adam(spec):
    """Base params + Adam slots (m.*, v.*, adam_t) in manifest order."""
    full = list(spec)
    full += [(f"m.{n}", s) for n, s in spec]
    full += [(f"v.{n}", s) for n, s in spec]
    full += [("adam_t", (1,))]
    return full


def _init_with_adam(spec, seed):
    base = M.init_params(spec, seed)
    zeros = [np.zeros(s, dtype=np.float32) for _, s in spec]
    return base + zeros + [z.copy() for z in zeros] + [np.zeros((1,), np.float32)]


MODELS = {
    # name: (base spec, init seed)
    "policy_traffic": (M.policy_spec(M.TRAFFIC_OBS, M.TRAFFIC_ACT), 10),
    "policy_warehouse": (M.policy_spec(M.WH_OBS * M.WH_STACK, M.WH_ACT), 11),
    "policy_warehouse_nm": (M.policy_spec(M.WH_OBS, M.WH_ACT), 12),
    "aip_traffic": (M.aip_fnn_spec(M.TRAFFIC_DSET, M.TRAFFIC_U), 20),
    "aip_traffic_full": (M.aip_fnn_spec(M.TRAFFIC_ALSH, M.TRAFFIC_U), 21),
    "aip_warehouse": (M.aip_gru_spec(M.WH_DSET, M.WH_U), 22),
    "aip_warehouse_nm": (M.aip_fnn_spec(M.WH_DSET, M.WH_U), 23),
}

GEOMETRY = {
    "traffic_obs": M.TRAFFIC_OBS,
    "traffic_act": M.TRAFFIC_ACT,
    "traffic_dset": M.TRAFFIC_DSET,
    "traffic_alsh": M.TRAFFIC_ALSH,
    "traffic_u": M.TRAFFIC_U,
    "wh_obs": M.WH_OBS,
    "wh_act": M.WH_ACT,
    "wh_dset": M.WH_DSET,
    "wh_alsh": M.WH_ALSH,
    "wh_u": M.WH_U,
    "wh_stack": M.WH_STACK,
    "rollout_b": M.ROLLOUT_B,
    "rollout_t": M.ROLLOUT_T,
    "ppo_rollout_n": M.PPO_ROLLOUT_N,
    "ppo_epochs": M.PPO_EPOCHS,
    "ppo_minibatch": M.PPO_MINIBATCH,
    "aip_batch": M.AIP_BATCH,
    "gru_seq_b": M.GRU_SEQ_B,
    "gru_seq_t": M.GRU_SEQ_T,
    "gru_hid": M.GRU_HID,
}


# ---------------------------------------------------------------------------
# Artifact builders. Each returns (fn, data_inputs, outputs) where
# data_inputs/outputs are [(name, dtype_str, shape)] and fn takes
# (base params..., [adam m..., v..., t, scalars...,] data...) positionally.
# ---------------------------------------------------------------------------

def policy_fwd_artifact(model, batch):
    spec, _ = MODELS[model]
    p = len(spec)
    obs_dim = spec[0][1][0]
    act_dim = spec[4][1][1]  # w_pi

    def fn(*args):
        logits, value = M.policy_fwd(args[:p], args[p], use_pallas=True)
        return (logits, value)

    data_in = [("obs", "f32", (batch, obs_dim))]
    outs = [("logits", "f32", (batch, act_dim)), ("value", "f32", (batch,))]
    return fn, data_in, outs


def policy_update_artifact(model, mb):
    spec, _ = MODELS[model]
    p = len(spec)
    obs_dim = spec[0][1][0]

    def fn(*args):
        params = args[:p]
        m = args[p : 2 * p]
        v = args[2 * p : 3 * p]
        t = args[3 * p]
        lr, clip, vf, ent, mgn = args[3 * p + 1 : 3 * p + 6]
        obs, actions, adv, ret, old_logp = args[3 * p + 6 :]
        np_, nm, nv, nt, stats = M.ppo_update(
            params, m, v, t, lr, clip, vf, ent, mgn, obs, actions, adv, ret, old_logp
        )
        return (*np_, *nm, *nv, nt, stats)

    data_in = [
        ("lr", "f32", (1,)),
        ("clip", "f32", (1,)),
        ("vf_coef", "f32", (1,)),
        ("ent_coef", "f32", (1,)),
        ("max_grad_norm", "f32", (1,)),
        ("obs", "f32", (mb, obs_dim)),
        ("actions", "i32", (mb,)),
        ("advantages", "f32", (mb,)),
        ("returns", "f32", (mb,)),
        ("old_logp", "f32", (mb,)),
    ]
    outs = [("stats", "f32", (6,))]
    return fn, data_in, outs


def policy_update_fused_artifact(model, n, epochs, mb):
    spec, _ = MODELS[model]
    p = len(spec)
    obs_dim = spec[0][1][0]

    def fn(*args):
        params = args[:p]
        m = args[p : 2 * p]
        v = args[2 * p : 3 * p]
        t = args[3 * p]
        lr, clip, vf, ent, mgn = args[3 * p + 1 : 3 * p + 6]
        perm, obs, actions, adv, ret, old_logp = args[3 * p + 6 :]
        np_, nm, nv, nt, stats = M.ppo_update_fused(
            params, m, v, t, lr, clip, vf, ent, mgn,
            perm, obs, actions, adv, ret, old_logp, minibatch=mb,
        )
        return (*np_, *nm, *nv, nt, stats)

    data_in = [
        ("lr", "f32", (1,)),
        ("clip", "f32", (1,)),
        ("vf_coef", "f32", (1,)),
        ("ent_coef", "f32", (1,)),
        ("max_grad_norm", "f32", (1,)),
        ("perm", "i32", (epochs, n)),
        ("obs", "f32", (n, obs_dim)),
        ("actions", "i32", (n,)),
        ("advantages", "f32", (n,)),
        ("returns", "f32", (n,)),
        ("old_logp", "f32", (n,)),
    ]
    outs = [("stats", "f32", (6,))]
    return fn, data_in, outs


def aip_fnn_fwd_artifact(model, batch):
    spec, _ = MODELS[model]
    p = len(spec)
    d_dim = spec[0][1][0]
    u_dim = spec[2][1][1]

    def fn(*args):
        return (M.aip_fnn_fwd(args[:p], args[p], use_pallas=True),)

    return fn, [("d", "f32", (batch, d_dim))], [("probs", "f32", (batch, u_dim))]


def aip_fnn_update_artifact(model, mb):
    spec, _ = MODELS[model]
    p = len(spec)
    d_dim = spec[0][1][0]
    u_dim = spec[2][1][1]

    def fn(*args):
        params = args[:p]
        m = args[p : 2 * p]
        v = args[2 * p : 3 * p]
        t = args[3 * p]
        lr = args[3 * p + 1]
        d, targets = args[3 * p + 2 :]
        np_, nm, nv, nt, loss = M.aip_fnn_update(params, m, v, t, lr, d, targets)
        return (*np_, *nm, *nv, nt, loss)

    data_in = [
        ("lr", "f32", (1,)),
        ("d", "f32", (mb, d_dim)),
        ("targets", "f32", (mb, u_dim)),
    ]
    return fn, data_in, [("loss", "f32", (1,))]


def aip_gru_step_artifact(model, batch):
    spec, _ = MODELS[model]
    p = len(spec)
    d_dim = spec[0][1][0]
    hid = spec[1][1][0]
    u_dim = spec[3][1][1]

    def fn(*args):
        probs, h_new = M.aip_gru_step(args[:p], args[p], args[p + 1], use_pallas=True)
        return (probs, h_new)

    data_in = [("h", "f32", (batch, hid)), ("d", "f32", (batch, d_dim))]
    outs = [("probs", "f32", (batch, u_dim)), ("h_new", "f32", (batch, hid))]
    return fn, data_in, outs


def aip_gru_update_artifact(model, b, t_len):
    spec, _ = MODELS[model]
    p = len(spec)
    d_dim = spec[0][1][0]
    u_dim = spec[3][1][1]

    def fn(*args):
        params = args[:p]
        m = args[p : 2 * p]
        v = args[2 * p : 3 * p]
        t = args[3 * p]
        lr = args[3 * p + 1]
        seqs, targets = args[3 * p + 2 :]
        np_, nm, nv, nt, loss = M.aip_gru_update(params, m, v, t, lr, seqs, targets)
        return (*np_, *nm, *nv, nt, loss)

    data_in = [
        ("lr", "f32", (1,)),
        ("seqs", "f32", (b, t_len, d_dim)),
        ("targets", "f32", (b, t_len, u_dim)),
    ]
    return fn, data_in, [("loss", "f32", (1,))]


def artifact_registry():
    arts = {}

    def add(name, model, kind, builder):
        arts[name] = dict(name=name, model=model, kind=kind, builder=builder)

    for pol in ("policy_traffic", "policy_warehouse", "policy_warehouse_nm"):
        add(f"{pol}_fwd_b{M.ROLLOUT_B}", pol, "fwd",
            lambda m=pol: policy_fwd_artifact(m, M.ROLLOUT_B))
        add(f"{pol}_fwd_b1", pol, "fwd", lambda m=pol: policy_fwd_artifact(m, 1))
        add(f"{pol}_update", pol, "train",
            lambda m=pol: policy_update_artifact(m, M.PPO_MINIBATCH))
        add(f"{pol}_update_fused", pol, "train",
            lambda m=pol: policy_update_fused_artifact(
                m, M.PPO_ROLLOUT_N, M.PPO_EPOCHS, M.PPO_MINIBATCH))

    for fnn in ("aip_traffic", "aip_traffic_full", "aip_warehouse_nm"):
        add(f"{fnn}_fwd_b{M.ROLLOUT_B}", fnn, "fwd",
            lambda m=fnn: aip_fnn_fwd_artifact(m, M.ROLLOUT_B))
        add(f"{fnn}_fwd_b1", fnn, "fwd", lambda m=fnn: aip_fnn_fwd_artifact(m, 1))
        add(f"{fnn}_update", fnn, "train",
            lambda m=fnn: aip_fnn_update_artifact(m, M.AIP_BATCH))

    add(f"aip_warehouse_step_b{M.ROLLOUT_B}", "aip_warehouse", "fwd",
        lambda: aip_gru_step_artifact("aip_warehouse", M.ROLLOUT_B))
    add("aip_warehouse_step_b1", "aip_warehouse", "fwd",
        lambda: aip_gru_step_artifact("aip_warehouse", 1))
    add("aip_warehouse_update", "aip_warehouse", "train",
        lambda: aip_gru_update_artifact("aip_warehouse", M.GRU_SEQ_B, M.GRU_SEQ_T))

    return arts


# ---------------------------------------------------------------------------
# Lowering + manifest emission
# ---------------------------------------------------------------------------

def _sds(dtype, shape):
    return jax.ShapeDtypeStruct(shape, I32 if dtype == "i32" else F32)


def lower_artifact(art):
    """Returns (hlo_text, param_inputs, data_inputs, param_outputs, data_outputs)."""
    spec, _seed = MODELS[art["model"]]
    fn, data_in, data_out = art["builder"]()
    p = len(spec)

    param_in = [n for n, _ in spec]
    param_out = []
    arg_specs = [_sds("f32", s) for _, s in spec]
    if art["kind"] == "train":
        param_in += [f"m.{n}" for n, _ in spec]
        param_in += [f"v.{n}" for n, _ in spec]
        param_in += ["adam_t"]
        param_out = list(param_in)  # updates write everything back
        arg_specs += [_sds("f32", s) for _, s in spec]  # m
        arg_specs += [_sds("f32", s) for _, s in spec]  # v
        arg_specs += [_sds("f32", (1,))]  # adam_t
        assert len(arg_specs) == 3 * p + 1
    arg_specs += [_sds(dt, sh) for _, dt, sh in data_in]

    lowered = jax.jit(fn).lower(*arg_specs)
    return to_hlo_text(lowered), param_in, data_in, param_out, data_out


def emit(out_dir, only=None):
    os.makedirs(out_dir, exist_ok=True)
    arts = artifact_registry()
    manifest = ["version 1", ""]

    manifest.append("geometry")
    for k, v in GEOMETRY.items():
        manifest.append(f"{k} {v}")
    manifest.append("endgeometry")
    manifest.append("")

    # Models + parameter blobs.
    for mname, (spec, seed) in MODELS.items():
        full = _with_adam(spec)
        manifest.append(f"model {mname}")
        for n, s in full:
            dims = " ".join(str(d) for d in s)
            manifest.append(f"param {n} f32 {dims}")
        manifest.append("endmodel")
        manifest.append("")
        arrays = _init_with_adam(spec, seed)
        blob = np.concatenate([a.astype("<f4").ravel() for a in arrays])
        blob.tofile(os.path.join(out_dir, f"{mname}.params.bin"))

    # Artifacts.
    for name, art in arts.items():
        if only and only not in name:
            continue
        hlo, param_in, data_in, param_out, data_out = lower_artifact(art)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(hlo)
        manifest.append(f"artifact {name}")
        manifest.append(f"model {art['model']}")
        manifest.append(f"hlo {name}.hlo.txt")
        for n in param_in:
            manifest.append(f"input param {n}")
        for n, dt, sh in data_in:
            dims = " ".join(str(d) for d in sh)
            manifest.append(f"input data {n} {dt} {dims}")
        for n in param_out:
            manifest.append(f"output param {n}")
        for n, dt, sh in data_out:
            dims = " ".join(str(d) for d in sh)
            manifest.append(f"output data {n} {dt} {dims}")
        manifest.append("endartifact")
        manifest.append("")
        print(f"lowered {name} ({len(hlo)} chars)")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote manifest with {len(arts)} artifacts to {out_dir}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    args = ap.parse_args()
    emit(args.out, args.only)


if __name__ == "__main__":
    sys.exit(main())
