"""Pure-jnp reference oracles for the Pallas kernels (Layer 1 correctness
anchors — every kernel must match these to float tolerance, checked by
pytest + hypothesis in ``python/tests/test_kernel.py``).

The *training* artifacts (PPO update, AIP trainers) also use these
implementations directly: interpret-mode ``pallas_call`` has no VJP rule,
so the backward pass is taken through the identical jnp math instead (see
DESIGN.md §Hardware-Adaptation). The kernel-vs-ref tests are what make
"identical" a checked property rather than a hope.
"""

import jax.numpy as jnp


def linear_ref(x, w, b, activation="none"):
    """y = act(x @ w + b). activation in {none, relu, tanh, sigmoid}."""
    y = x @ w + b
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "tanh":
        return jnp.tanh(y)
    if activation == "sigmoid":
        return jnp.reciprocal(1.0 + jnp.exp(-y))
    if activation == "none":
        return y
    raise ValueError(f"unknown activation {activation}")


def gru_cell_ref(x, h, w_x, w_h, b):
    """Standard GRU cell with fused gate weights.

    x: [B, D], h: [B, H]
    w_x: [D, 3H] (z | r | n blocks), w_h: [H, 3H], b: [3H]
    returns h': [B, H]
    """
    hidden = h.shape[-1]
    gx = x @ w_x + b  # [B, 3H]
    gh = h @ w_h  # [B, 3H]
    xz, xr, xn = gx[:, :hidden], gx[:, hidden : 2 * hidden], gx[:, 2 * hidden :]
    hz, hr, hn = gh[:, :hidden], gh[:, hidden : 2 * hidden], gh[:, 2 * hidden :]
    z = jnp.reciprocal(1.0 + jnp.exp(-(xz + hz)))
    r = jnp.reciprocal(1.0 + jnp.exp(-(xr + hr)))
    n = jnp.tanh(xn + r * hn)
    return (1.0 - z) * n + z * h
