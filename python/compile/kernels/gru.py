"""Layer 1: fused GRU-cell Pallas kernel — the compute hot-spot of the
warehouse influence predictor, which runs on the IALS simulation hot path
(one call per simulator step).

Fusion strategy (DESIGN.md §Hardware-Adaptation): the three gates are
computed from two MXU-shaped matmuls ``x @ W_x`` ([B,D]x[D,3H]) and
``h @ W_h`` ([B,H]x[H,3H]) executed in one kernel invocation, with all gate
nonlinearities and the convex-combination update applied in-register before
a single store of h'. A naive cell issues 6 matmuls and 5+ elementwise
kernels; the fused cell is 2 matmuls + 1 store.

VMEM footprint per block (f32): block_b*(D+H) inputs + (D+H)*3H weights +
3H bias + block_b*3H workspace + block_b*H output. For the paper config
(B=16, D=24, H=32): ~21 KB — a single-block schedule fits trivially in the
~16 MB VMEM budget, so grid=(1,) is the optimal schedule and the kernel is
launch-latency-bound, which is exactly why fusing it matters.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gru_kernel(x_ref, h_ref, wx_ref, wh_ref, b_ref, o_ref):
    x = x_ref[...]
    h = h_ref[...]
    wx = wx_ref[...]
    wh = wh_ref[...]
    b = b_ref[...]
    hidden = h.shape[-1]
    gx = jnp.dot(x, wx, preferred_element_type=jnp.float32) + b[None, :]
    gh = jnp.dot(h, wh, preferred_element_type=jnp.float32)
    xz = gx[:, :hidden]
    xr = gx[:, hidden : 2 * hidden]
    xn = gx[:, 2 * hidden :]
    hz = gh[:, :hidden]
    hr = gh[:, hidden : 2 * hidden]
    hn = gh[:, 2 * hidden :]
    z = jnp.reciprocal(1.0 + jnp.exp(-(xz + hz)))
    r = jnp.reciprocal(1.0 + jnp.exp(-(xr + hr)))
    n = jnp.tanh(xn + r * hn)
    o_ref[...] = (1.0 - z) * n + z * h


def fused_gru_cell(x, h, w_x, w_h, b, block_b=None):
    """One GRU step: returns h' of shape [B, H].

    x: [B, D], h: [B, H], w_x: [D, 3H], w_h: [H, 3H], b: [3H].
    """
    bsz, d = x.shape
    _, hidden = h.shape
    assert w_x.shape == (d, 3 * hidden), (w_x.shape, d, hidden)
    assert w_h.shape == (hidden, 3 * hidden)
    assert b.shape == (3 * hidden,)
    if block_b is None or block_b >= bsz:
        block_b = bsz
    assert bsz % block_b == 0
    grid = (bsz // block_b,)
    return pl.pallas_call(
        _gru_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, hidden), lambda i: (i, 0)),
            pl.BlockSpec((d, 3 * hidden), lambda i: (0, 0)),
            pl.BlockSpec((hidden, 3 * hidden), lambda i: (0, 0)),
            pl.BlockSpec((3 * hidden,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, hidden), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, hidden), jnp.float32),
        interpret=True,
    )(x, h, w_x, w_h, b)
