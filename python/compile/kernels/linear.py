"""Layer 1: fused linear(+activation) Pallas kernel.

The MLP policy / FNN influence-predictor forwards are chains of
``act(x @ W + b)``; fusing the bias-add and activation into the matmul
kernel keeps the intermediate in VMEM and stores exactly once — the TPU
analogue of a fused CUDA epilogue (DESIGN.md §Hardware-Adaptation).

Block schedule: grid over the batch dimension in tiles of ``block_b`` rows;
the full weight tile lives in VMEM (our layer widths are tiny relative to
the ~16 MB VMEM budget — see EXPERIMENTS.md §Perf for the footprint math).
``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; real-TPU behaviour is estimated analytically.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, activation):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "tanh":
        y = jnp.tanh(y)
    elif activation == "sigmoid":
        y = jnp.reciprocal(1.0 + jnp.exp(-y))
    o_ref[...] = y


def fused_linear(x, w, b, activation="none", block_b=None):
    """act(x @ w + b) as a single Pallas kernel.

    x: [B, D], w: [D, N], b: [N] -> [B, N]
    """
    assert x.ndim == 2 and w.ndim == 2 and b.ndim == 1, (x.shape, w.shape, b.shape)
    bsz, d = x.shape
    d2, n = w.shape
    assert d == d2 and b.shape[0] == n
    if block_b is None or block_b >= bsz:
        block_b = bsz
    assert bsz % block_b == 0, "batch must divide by the block size"
    grid = (bsz // block_b,)
    kernel = functools.partial(_linear_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((d, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, n), jnp.float32),
        interpret=True,
    )(x, w, b)
