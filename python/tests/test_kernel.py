"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes; assert_allclose against ref — this is THE
correctness signal that lets the training artifacts (which differentiate
the jnp math) stand in for the kernels' backward pass.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.gru import fused_gru_cell
from compile.kernels.linear import fused_linear
from compile.kernels.ref import gru_cell_ref, linear_ref

RTOL = 1e-5
ATOL = 1e-5


def _rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 32),
    d=st.integers(1, 48),
    n=st.integers(1, 48),
    act=st.sampled_from(["none", "relu", "tanh", "sigmoid"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref(b, d, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, bias = _rand(rng, b, d), _rand(rng, d, n), _rand(rng, n)
    got = np.asarray(fused_linear(x, w, bias, act))
    want = np.asarray(linear_ref(x, w, bias, act))
    assert got.shape == (b, n)
    assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 24),
    d=st.integers(1, 40),
    h=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_gru_cell_matches_ref(b, d, h, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, b, d)
    hid = _rand(rng, b, h)
    wx, wh, bias = _rand(rng, d, 3 * h), _rand(rng, h, 3 * h), _rand(rng, 3 * h)
    got = np.asarray(fused_gru_cell(x, hid, wx, wh, bias))
    want = np.asarray(gru_cell_ref(x, hid, wx, wh, bias))
    assert got.shape == (b, h)
    assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("block_b", [1, 2, 4, 8])
def test_fused_linear_blocked_grid(block_b):
    """Batch-tiled schedules must agree with the single-block kernel."""
    rng = np.random.default_rng(0)
    x, w, bias = _rand(rng, 8, 16), _rand(rng, 16, 12), _rand(rng, 12)
    got = np.asarray(fused_linear(x, w, bias, "relu", block_b=block_b))
    want = np.asarray(linear_ref(x, w, bias, "relu"))
    assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("block_b", [1, 2, 4])
def test_fused_gru_blocked_grid(block_b):
    rng = np.random.default_rng(1)
    x = _rand(rng, 4, 24)
    h = _rand(rng, 4, 32)
    wx, wh, bias = _rand(rng, 24, 96), _rand(rng, 32, 96), _rand(rng, 96)
    got = np.asarray(fused_gru_cell(x, h, wx, wh, bias, block_b=block_b))
    want = np.asarray(gru_cell_ref(x, h, wx, wh, bias))
    assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_gru_gates_behave():
    """Degenerate weights: zero weights -> h' = (1-z)*tanh(0)+z*h with
    z = sigmoid(0) = 0.5 -> h' = h/2 exactly."""
    b, d, h = 3, 5, 7
    x = np.ones((b, d), np.float32)
    hid = np.full((b, h), 2.0, np.float32)
    wx = np.zeros((d, 3 * h), np.float32)
    wh = np.zeros((h, 3 * h), np.float32)
    bias = np.zeros(3 * h, np.float32)
    got = np.asarray(fused_gru_cell(x, hid, wx, wh, bias))
    assert_allclose(got, np.full((b, h), 1.0), rtol=1e-6, atol=1e-6)


def test_linear_identity():
    x = np.eye(4, dtype=np.float32)
    w = np.eye(4, dtype=np.float32)
    b = np.zeros(4, np.float32)
    assert_allclose(np.asarray(fused_linear(x, w, b)), x, rtol=0, atol=0)
