"""Layer-2 correctness: policy/AIP forwards, PPO + AIP updates."""

import numpy as np
import jax.numpy as jnp
import pytest
from numpy.testing import assert_allclose

from compile import model as M


def _params(spec, seed=0):
    return [jnp.asarray(a) for a in M.init_params(spec, seed)]


def _zeros_like(params):
    return [jnp.zeros_like(p) for p in params]


class TestPolicy:
    def test_fwd_shapes(self):
        spec = M.policy_spec(M.TRAFFIC_OBS, M.TRAFFIC_ACT)
        params = _params(spec)
        obs = jnp.zeros((16, M.TRAFFIC_OBS))
        logits, value = M.policy_fwd(params, obs, use_pallas=True)
        assert logits.shape == (16, 2)
        assert value.shape == (16,)

    def test_pallas_and_ref_paths_agree(self):
        spec = M.policy_spec(M.WH_OBS, M.WH_ACT)
        params = _params(spec, 3)
        rng = np.random.default_rng(0)
        obs = jnp.asarray(rng.standard_normal((8, M.WH_OBS)).astype(np.float32))
        lp, vp = M.policy_fwd(params, obs, use_pallas=True)
        lr_, vr = M.policy_fwd(params, obs, use_pallas=False)
        assert_allclose(np.asarray(lp), np.asarray(lr_), rtol=1e-5, atol=1e-5)
        assert_allclose(np.asarray(vp), np.asarray(vr), rtol=1e-5, atol=1e-5)

    def test_initial_policy_near_uniform(self):
        spec = M.policy_spec(M.TRAFFIC_OBS, M.TRAFFIC_ACT)
        params = _params(spec, 1)
        rng = np.random.default_rng(1)
        obs = jnp.asarray(rng.standard_normal((64, M.TRAFFIC_OBS)).astype(np.float32))
        logits, _ = M.policy_fwd(params, obs, use_pallas=False)
        probs = np.asarray(jnp.exp(logits) / jnp.sum(jnp.exp(logits), 1, keepdims=True))
        assert np.all(np.abs(probs - 0.5) < 0.25)


class TestPpoUpdate:
    def _setup(self, mb=32):
        spec = M.policy_spec(10, 3)
        params = _params(spec, 2)
        m, v = _zeros_like(params), _zeros_like(params)
        t = jnp.zeros((1,))
        rng = np.random.default_rng(7)
        obs = jnp.asarray(rng.standard_normal((mb, 10)).astype(np.float32))
        actions = jnp.asarray(rng.integers(0, 3, mb).astype(np.int32))
        adv = jnp.asarray(rng.standard_normal(mb).astype(np.float32))
        ret = jnp.asarray(rng.standard_normal(mb).astype(np.float32))
        logits, _ = M.policy_fwd(params, obs, use_pallas=False)
        logp_all = np.asarray(jnp.log(jnp.exp(logits) / jnp.sum(jnp.exp(logits), 1, keepdims=True)))
        old_logp = jnp.asarray(logp_all[np.arange(mb), np.asarray(actions)])
        scal = lambda x: jnp.asarray([x], dtype=jnp.float32)
        return params, m, v, t, (scal(3e-4), scal(0.2), scal(0.5), scal(0.01), scal(0.5)), (
            obs, actions, adv, ret, old_logp)

    def test_update_changes_params_and_reports_stats(self):
        params, m, v, t, hyp, data = self._setup()
        np_, nm, nv, nt, stats = M.ppo_update(params, m, v, t, *hyp, *data)
        assert nt[0] == 1.0
        assert stats.shape == (6,)
        # stats[5] is the pre-clip global grad norm — finite and positive
        # on a real update (the Rust health guard's spike-detector input).
        assert float(stats[5]) > 0.0
        changed = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in zip(np_, params))
        assert changed > 0.0
        # Entropy of a near-uniform 3-way policy ~ ln 3.
        assert 0.5 < float(stats[3]) <= np.log(3) + 1e-3

    def test_zero_advantage_keeps_kl_tiny(self):
        params, m, v, t, hyp, data = self._setup()
        obs, actions, _, ret, old_logp = data
        zadv = jnp.zeros_like(old_logp)
        np_, *_ = M.ppo_update(params, m, v, t, *hyp, obs, actions, zadv, ret, old_logp)
        logits0, _ = M.policy_fwd(params, obs, use_pallas=False)
        logits1, _ = M.policy_fwd(np_, obs, use_pallas=False)
        # Value/entropy terms still move the trunk, but the policy head
        # shouldn't jump far in one step.
        assert float(jnp.mean(jnp.abs(logits1 - logits0))) < 0.1

    def test_repeated_updates_reduce_value_loss(self):
        params, m, v, t, hyp, data = self._setup(mb=64)
        obs, actions, adv, ret, old_logp = data
        lr = jnp.asarray([1e-2], jnp.float32)
        hyp = (lr, *hyp[1:])
        first = None
        for _ in range(60):
            params, m, v, t, stats = M.ppo_update(
                list(params), list(m), list(v), t, *hyp, obs, actions, adv, ret, old_logp
            )
            if first is None:
                first = float(stats[2])
        last = float(stats[2])
        assert last < first * 0.5, f"value loss should drop: {first} -> {last}"


class TestAipFnn:
    def test_update_learns_identity_mapping(self):
        # Target: u = first U bits of d. The FNN must drive BCE well below
        # the ~0.69 chance level.
        spec = M.aip_fnn_spec(M.WH_DSET, M.WH_U)
        params = _params(spec, 4)
        m, v = _zeros_like(params), _zeros_like(params)
        t = jnp.zeros((1,))
        rng = np.random.default_rng(9)
        lr = jnp.asarray([1e-2], jnp.float32)
        losses = []
        for _ in range(120):
            d = rng.integers(0, 2, (M.AIP_BATCH, M.WH_DSET)).astype(np.float32)
            targets = d[:, : M.WH_U].copy()
            params, m, v, t, loss = M.aip_fnn_update(
                list(params), list(m), list(v), t, lr, jnp.asarray(d), jnp.asarray(targets)
            )
            losses.append(float(loss[0]))
        assert losses[0] > 0.5
        assert losses[-1] < 0.1, f"final loss {losses[-1]}"

    def test_fwd_probs_in_unit_interval(self):
        spec = M.aip_fnn_spec(M.TRAFFIC_DSET, M.TRAFFIC_U)
        params = _params(spec, 5)
        rng = np.random.default_rng(2)
        d = jnp.asarray(rng.standard_normal((16, M.TRAFFIC_DSET)).astype(np.float32))
        probs = np.asarray(M.aip_fnn_fwd(params, d, use_pallas=True))
        assert probs.shape == (16, M.TRAFFIC_U)
        assert np.all(probs >= 0) and np.all(probs <= 1)


class TestAipGru:
    def test_step_shapes_and_paths_agree(self):
        spec = M.aip_gru_spec(M.WH_DSET, M.WH_U)
        params = _params(spec, 6)
        rng = np.random.default_rng(3)
        h = jnp.asarray(rng.standard_normal((8, M.GRU_HID)).astype(np.float32))
        d = jnp.asarray(rng.standard_normal((8, M.WH_DSET)).astype(np.float32))
        p1, h1 = M.aip_gru_step(params, h, d, use_pallas=True)
        p2, h2 = M.aip_gru_step(params, h, d, use_pallas=False)
        assert p1.shape == (8, M.WH_U) and h1.shape == (8, M.GRU_HID)
        assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-5, atol=1e-5)
        assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-5, atol=1e-5)

    def test_scan_matches_manual_unroll(self):
        spec = M.aip_gru_spec(6, 4)
        params = _params(spec, 7)
        rng = np.random.default_rng(4)
        seqs = jnp.asarray(rng.standard_normal((3, 5, 6)).astype(np.float32))
        logits = np.asarray(M.aip_gru_logits_scan(params, seqs))
        # manual
        h = jnp.zeros((3, M.GRU_HID))
        outs = []
        for t_ in range(5):
            _, h = M.aip_gru_step(params, h, seqs[:, t_, :], use_pallas=False)
            w_o, b_o = params[3], params[4]
            outs.append(np.asarray(h @ w_o + b_o))
        manual = np.stack(outs, axis=1)
        assert_allclose(logits, manual, rtol=1e-4, atol=1e-4)

    def test_gru_learns_temporal_rule(self):
        """u_t = d_{t-2}[0]: requires 2 steps of memory — a feedforward
        model cannot beat chance, the GRU must."""
        spec = M.aip_gru_spec(1, 1)
        params = _params(spec, 8)
        m, v = _zeros_like(params), _zeros_like(params)
        t = jnp.zeros((1,))
        lr = jnp.asarray([1e-2], jnp.float32)
        rng = np.random.default_rng(11)
        last = None
        for _ in range(150):
            d = rng.integers(0, 2, (M.GRU_SEQ_B, M.GRU_SEQ_T, 1)).astype(np.float32)
            targets = np.zeros_like(d)
            targets[:, 2:, 0] = d[:, :-2, 0]
            params, m, v, t, loss = M.aip_gru_update(
                list(params), list(m), list(v), t, lr, jnp.asarray(d), jnp.asarray(targets)
            )
            last = float(loss[0])
        assert last < 0.25, f"GRU should learn the 2-step delay rule, loss={last}"


class TestAdam:
    def test_bias_correction_first_step(self):
        p = [jnp.ones((2,))]
        g = [jnp.full((2,), 0.5)]
        m = [jnp.zeros((2,))]
        v = [jnp.zeros((2,))]
        t = jnp.zeros((1,))
        lr = jnp.asarray([0.1], jnp.float32)
        new_p, _, _, nt = M.adam_step(p, g, m, v, t, lr)
        # First Adam step moves by ~lr * sign(g) regardless of magnitude.
        assert_allclose(np.asarray(new_p[0]), np.asarray(p[0]) - 0.1, rtol=1e-3)
        assert nt[0] == 1.0

    def test_clip_global_norm(self):
        g = [jnp.full((3,), 10.0)]
        clipped, gn = M.clip_global_norm(g, jnp.asarray(1.0))
        assert float(gn) == pytest.approx(np.sqrt(300.0), rel=1e-4)
        norm = float(jnp.sqrt(jnp.sum(clipped[0] ** 2)))
        assert norm == pytest.approx(1.0, rel=1e-3)
        # under the threshold: untouched
        g2 = [jnp.full((3,), 0.01)]
        same, _ = M.clip_global_norm(g2, jnp.asarray(1.0))
        assert_allclose(np.asarray(same[0]), np.asarray(g2[0]), rtol=1e-5)
