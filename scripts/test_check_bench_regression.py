#!/usr/bin/env python3
"""Self-test for the CI bench-regression guard (check_bench_regression.py).

The guard gates merges, so its own behavior is pinned here: a real
regression fails the run, baseline-less cells are skipped *and listed*,
malformed JSON is rejected with a readable error, and within-threshold
noise passes. Run directly (`python3 scripts/test_check_bench_regression.py`)
or via unittest discovery; CI runs it as a cheap step before the guard.
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

SCRIPT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "check_bench_regression.py")


def cell(op, workers, rate):
    return {"op": op, "num_workers": workers, "rows_per_sec": rate, "backend": "native"}


def serve_cell(clients, rate, mode="close"):
    """A bench_serve.json cell: keyed by clients/window/mode, metered by
    requests_per_sec, with latency metrics the guard must ignore."""
    return {
        "op": "serve_act",
        "clients": clients,
        "batch_window_ms": 2,
        "mode": mode,
        "requests_per_sec": rate,
        "p50_ms": 1.0,
        "p99_ms": 5.0,
        "backend": "native",
    }


class GuardHarness(unittest.TestCase):
    def setUp(self):
        self.tmp = tempfile.TemporaryDirectory()
        self.baseline = os.path.join(self.tmp.name, "baseline")
        self.fresh = os.path.join(self.tmp.name, "fresh")
        os.makedirs(self.baseline)
        os.makedirs(self.fresh)

    def tearDown(self):
        self.tmp.cleanup()

    def write(self, dirname, name, payload):
        path = os.path.join(dirname, name)
        with open(path, "w") as f:
            if isinstance(payload, str):
                f.write(payload)
            else:
                json.dump(payload, f)
        return path

    def run_guard(self, max_regression=0.25):
        proc = subprocess.run(
            [
                sys.executable,
                SCRIPT,
                "--baseline",
                self.baseline,
                "--fresh",
                self.fresh,
                "--max-regression",
                str(max_regression),
            ],
            capture_output=True,
            text=True,
        )
        return proc.returncode, proc.stdout + proc.stderr


class TestRegressionDetection(GuardHarness):
    def test_regression_beyond_threshold_fails(self):
        self.write(self.baseline, "b.json", [cell("ppo", 4, 100.0)])
        self.write(self.fresh, "b.json", [cell("ppo", 4, 70.0)])
        rc, out = self.run_guard()
        self.assertEqual(rc, 1, out)
        self.assertIn("[FAIL]", out)
        self.assertIn("regressed", out)

    def test_within_threshold_passes(self):
        self.write(self.baseline, "b.json", [cell("ppo", 4, 100.0)])
        self.write(self.fresh, "b.json", [cell("ppo", 4, 80.0)])
        rc, out = self.run_guard()
        self.assertEqual(rc, 0, out)
        self.assertIn("[ok]", out)
        self.assertIn("no throughput regressions", out)

    def test_improvement_passes(self):
        self.write(self.baseline, "b.json", [cell("ppo", 4, 100.0)])
        self.write(self.fresh, "b.json", [cell("ppo", 4, 250.0)])
        rc, out = self.run_guard()
        self.assertEqual(rc, 0, out)

    def test_requests_per_sec_regression_fails(self):
        # The serving bench meters requests_per_sec; a drop beyond the
        # threshold must fail even though the cells also carry latency
        # floats (which are metrics, not identity, and must not unmatch
        # the cells).
        self.write(self.baseline, "serve.json", [serve_cell(4, 1000.0)])
        self.write(self.fresh, "serve.json", [serve_cell(4, 600.0)])
        rc, out = self.run_guard()
        self.assertEqual(rc, 1, out)
        self.assertIn("[FAIL]", out)
        self.assertIn("serve_act", out)

    def test_requests_per_sec_within_threshold_passes(self):
        self.write(self.baseline, "serve.json", [serve_cell(4, 1000.0)])
        self.write(self.fresh, "serve.json", [serve_cell(4, 900.0)])
        rc, out = self.run_guard()
        self.assertEqual(rc, 0, out)
        self.assertIn("[ok]", out)

    def test_keepalive_requests_per_sec_regression_fails(self):
        # The keep-alive sweep cells are distinct identities from the
        # close cells (the `mode` field), and their floor is enforced too.
        self.write(
            self.baseline,
            "serve.json",
            [serve_cell(16, 1000.0, mode="close"), serve_cell(16, 1500.0, mode="keepalive")],
        )
        self.write(
            self.fresh,
            "serve.json",
            [serve_cell(16, 1000.0, mode="close"), serve_cell(16, 700.0, mode="keepalive")],
        )
        rc, out = self.run_guard()
        self.assertEqual(rc, 1, out)
        self.assertIn("[FAIL]", out)
        self.assertIn("keepalive", out)

    def test_note_annotation_does_not_unmatch_cells(self):
        # Hand-set floor cells carry a loud `_note`; the bench emits the
        # same cell without it. Underscore keys are not identity, so the
        # pair must still match (and the note must stay out of log lines).
        base = serve_cell(16, 1000.0, mode="keepalive")
        base["_note"] = "hand-set conservative floor, not a measurement"
        self.write(self.baseline, "serve.json", [base])
        self.write(self.fresh, "serve.json", [serve_cell(16, 1200.0, mode="keepalive")])
        rc, out = self.run_guard()
        self.assertEqual(rc, 0, out)
        self.assertIn("[ok]", out)
        self.assertNotIn("[new]", out)
        self.assertNotIn("_note", out)


class TestBaselineLessCells(GuardHarness):
    def test_new_cell_in_known_file_is_skipped_and_listed(self):
        self.write(self.baseline, "b.json", [cell("ppo", 4, 100.0)])
        self.write(self.fresh, "b.json", [cell("ppo", 4, 100.0), cell("ppo", 8, 50.0)])
        rc, out = self.run_guard()
        self.assertEqual(rc, 0, out)
        self.assertIn("[new]", out)
        self.assertIn("no baseline (skipped)", out)

    def test_whole_fresh_file_without_baseline_is_listed(self):
        self.write(self.fresh, "brand_new.json", [cell("rollout", 2, 10.0)])
        rc, out = self.run_guard()
        self.assertEqual(rc, 0, out)
        self.assertIn("brand_new.json: no committed baseline", out)
        self.assertIn("no baseline (skipped)", out)

    def test_baseline_cell_missing_from_fresh_is_skipped(self):
        self.write(self.baseline, "b.json", [cell("ppo", 4, 100.0), cell("ppo", 8, 90.0)])
        self.write(self.fresh, "b.json", [cell("ppo", 4, 100.0)])
        rc, out = self.run_guard()
        self.assertEqual(rc, 0, out)
        self.assertIn("[skip]", out)

    def test_missing_baseline_dir_guards_nothing(self):
        self.write(self.fresh, "b.json", [cell("ppo", 4, 100.0)])
        os.rmdir(self.baseline)
        rc, out = self.run_guard()
        self.assertEqual(rc, 0, out)
        self.assertIn("nothing to guard", out)


class TestMalformedInput(GuardHarness):
    def test_truncated_json_is_rejected_with_error(self):
        self.write(self.baseline, "b.json", [cell("ppo", 4, 100.0)])
        self.write(self.fresh, "b.json", '[{"op": "ppo", "rows_per_sec": ')
        rc, out = self.run_guard()
        self.assertEqual(rc, 2, out)
        self.assertIn("[error]", out)
        self.assertNotIn("Traceback", out)

    def test_non_array_payload_is_rejected(self):
        self.write(self.baseline, "b.json", [cell("ppo", 4, 100.0)])
        self.write(self.fresh, "b.json", {"op": "ppo"})
        rc, out = self.run_guard()
        self.assertEqual(rc, 2, out)
        self.assertIn("expected a JSON array", out)

    def test_malformed_fresh_only_file_is_rejected(self):
        self.write(self.fresh, "extra.json", "not json at all")
        rc, out = self.run_guard()
        self.assertEqual(rc, 2, out)
        self.assertIn("[error]", out)

    def test_regression_still_reported_alongside_malformed_file(self):
        self.write(self.baseline, "a.json", [cell("ppo", 4, 100.0)])
        self.write(self.fresh, "a.json", [cell("ppo", 4, 10.0)])
        self.write(self.fresh, "broken.json", "{")
        rc, out = self.run_guard()
        # Malformed input takes precedence (rc 2) but the regression is
        # still visible in the log.
        self.assertEqual(rc, 2, out)
        self.assertIn("[FAIL]", out)


if __name__ == "__main__":
    unittest.main(verbosity=2)
