#!/usr/bin/env python3
"""CI bench-regression guard.

Compares freshly produced bench JSONs (rust/results/*.json) against the
committed baselines (results/*.json at the repo root) and fails the job
when any cell's throughput regresses by more than the threshold.

Matching: cells are keyed by every non-metric field (op, model, domain,
batch, minibatch, num_workers, nn_workers, pipeline, backend, ...); the
throughput metric is whichever of `rows_per_sec` / `steps_per_sec` the
cell carries. Cells present only in the fresh run (new benches, new sweep
points) or only in the baseline (retired cells) are skipped — the guard
never blocks adding coverage, only losing speed. Every skipped cell is
printed, so brand-new sweep points are visible in the CI log (and can be
promoted to committed baselines from the bench-results artifact).

Usage:
  python3 scripts/check_bench_regression.py \
      --baseline results --fresh rust/results [--max-regression 0.25]
"""

import argparse
import json
import os
import sys

THROUGHPUT_KEYS = ("rows_per_sec", "steps_per_sec", "requests_per_sec")


def ident(cell):
    """The cell's identity fields (metrics, including derived floats like
    speedup ratios, excluded) — the single source of truth for matching
    (`cell_key`) and for log lines. Keys starting with `_` are human
    annotations (e.g. the `_note` marking hand-set floor cells) and never
    part of identity: an annotated baseline must still match the fresh
    cell the bench emits without it."""
    return {
        k: v
        for k, v in cell.items()
        if not k.startswith("_")
        and (not isinstance(v, float) or k in ("batch", "minibatch", "num_workers", "nn_workers"))
    }


def cell_key(cell):
    return tuple(sorted(ident(cell).items()))


def throughput(cell):
    for k in THROUGHPUT_KEYS:
        if k in cell:
            return float(cell[k])
    return None


def load_cells(path):
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, list):
        raise ValueError(f"{path}: expected a JSON array of cells")
    return {cell_key(c): c for c in data if isinstance(c, dict)}


def try_load_cells(path, errors):
    """Load a cell file, recording (instead of raising) malformed input —
    a truncated or hand-mangled JSON must fail the guard with a readable
    message, not a traceback."""
    try:
        return load_cells(path)
    except (OSError, ValueError) as e:
        print(f"[error] {path}: {e}")
        errors.append(path)
        return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True, help="directory of committed baseline JSONs")
    ap.add_argument("--fresh", required=True, help="directory of freshly produced JSONs")
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.25,
        help="fail when fresh < baseline * (1 - this) in any matched cell",
    )
    args = ap.parse_args()

    if not os.path.isdir(args.baseline):
        print(f"no baseline directory {args.baseline}; nothing to guard")
        return 0

    regressions = []
    errors = []
    compared = skipped = 0
    baseline_files = [n for n in sorted(os.listdir(args.baseline)) if n.endswith(".json")]
    for name in baseline_files:
        fresh_path = os.path.join(args.fresh, name)
        if not os.path.exists(fresh_path):
            print(f"[skip] {name}: no fresh run")
            continue
        base = try_load_cells(os.path.join(args.baseline, name), errors)
        fresh = try_load_cells(fresh_path, errors)
        if base is None or fresh is None:
            continue
        for key, bcell in base.items():
            b = throughput(bcell)
            fcell = fresh.get(key)
            f = throughput(fcell) if fcell else None
            if b is None or f is None or b <= 0:
                skipped += 1
                print(f"[skip] {name} {ident(bcell)}: baseline cell not matched/metric-less")
                continue
            compared += 1
            floor = b * (1.0 - args.max_regression)
            if f < floor:
                regressions.append((name, ident(bcell), b, f))
                print(f"[FAIL] {name} {ident(bcell)}: {f:.1f} < {floor:.1f} (baseline {b:.1f})")
            else:
                print(f"[ok]   {name} {ident(bcell)}: {f:.1f} vs baseline {b:.1f}")
        for key in sorted(fresh.keys() - base.keys()):
            skipped += 1
            print(f"[new]  {name} {ident(fresh[key])}: no baseline (skipped)")

    # Fresh result files with no committed baseline at all (new benches):
    # list every cell so the sweep is visible in the CI log and can be
    # promoted to a baseline from the bench-results artifact.
    if os.path.isdir(args.fresh):
        for name in sorted(os.listdir(args.fresh)):
            if not name.endswith(".json") or name in baseline_files:
                continue
            fresh = try_load_cells(os.path.join(args.fresh, name), errors)
            if fresh is None:
                continue
            print(f"[new]  {name}: no committed baseline — {len(fresh)} cell(s) skipped")
            for key in sorted(fresh.keys()):
                skipped += 1
                print(f"[new]  {name} {ident(fresh[key])}: no baseline (skipped)")

    print(f"\ncompared {compared} cells, skipped {skipped} (no baseline / no metric)")
    if errors:
        print(f"{len(errors)} malformed result file(s); refusing to certify this run")
        return 2
    if regressions:
        print(f"{len(regressions)} cell(s) regressed more than "
              f"{args.max_regression:.0%} vs committed baselines")
        return 1
    print("no throughput regressions beyond threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
