//! Host-side stub of the `xla` PJRT binding.
//!
//! The offline build environment does not ship the native XLA/PJRT runtime,
//! so this crate provides the exact API surface `ials::runtime` consumes:
//! literals, host buffers, HLO text loading and executable handles. Every
//! host-side operation (literal packing/unpacking, shape checks, file IO) is
//! fully implemented; only `execute`/`execute_b` — the calls that would hand
//! an HLO program to a real PJRT device — return a clear error.
//!
//! Swapping in a real backend means replacing this path dependency in
//! `rust/Cargo.toml` with the actual `xla` crate; no call-site changes are
//! required, which is the point of keeping the stub API-identical.

use std::fmt;

/// Error type for all stub operations. Implements `std::error::Error` so the
/// caller's `anyhow` context machinery applies unchanged.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types used by the artifacts (f32 data/params, i32 action inputs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn byte_width(self) -> usize {
        4
    }
}

/// Sealed mapping from Rust scalar types to [`ElementType`].
pub trait NativeType: Copy + 'static {
    const ELEMENT_TYPE: ElementType;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
}

/// A host-resident tensor (or tuple of tensors) value.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = dims.iter().product();
        if data.len() != numel * ty.byte_width() {
            return Err(Error::new(format!(
                "literal of shape {dims:?} needs {} bytes, got {}",
                numel * ty.byte_width(),
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), bytes: data.to_vec(), tuple: None })
    }

    /// Build a tuple literal (what executables return).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { ty: ElementType::F32, dims: Vec::new(), bytes: Vec::new(), tuple: Some(parts) }
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn element_count(&self) -> usize {
        if self.tuple.is_some() {
            0
        } else {
            self.dims.iter().product()
        }
    }

    /// Unpack a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        self.tuple.ok_or_else(|| Error::new("literal is not a tuple"))
    }

    /// Copy the raw payload into a typed slice (lengths must match).
    pub fn copy_raw_to<T: NativeType>(&self, dst: &mut [T]) -> Result<()> {
        if T::ELEMENT_TYPE != self.ty {
            return Err(Error::new("copy_raw_to: element type mismatch"));
        }
        if dst.len() != self.element_count() {
            return Err(Error::new(format!(
                "copy_raw_to: literal has {} elements, destination {}",
                self.element_count(),
                dst.len()
            )));
        }
        // SAFETY: `dst` is a plain scalar slice of exactly `bytes.len()`
        // bytes (checked above; both supported scalars are 4 bytes wide).
        let dst_bytes = unsafe {
            std::slice::from_raw_parts_mut(
                dst.as_mut_ptr() as *mut u8,
                dst.len() * self.ty.byte_width(),
            )
        };
        dst_bytes.copy_from_slice(&self.bytes);
        Ok(())
    }

    pub fn to_vec<T: NativeType + Default>(&self) -> Result<Vec<T>> {
        let mut out = vec![T::default(); self.element_count()];
        self.copy_raw_to(&mut out)?;
        Ok(out)
    }
}

/// Parsed HLO module text (the stub keeps the raw text only).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading HLO text {path}: {e}")))?;
        if text.trim().is_empty() {
            return Err(Error::new(format!("HLO text {path} is empty")));
        }
        Ok(HloModuleProto { text })
    }
}

/// A computation handle built from an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _module_len: usize,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _module_len: proto.text.len() }
    }
}

/// A device-resident buffer (host memory in the stub).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// Borrowing device→host transfer. Clones the full payload — kept for
    /// API parity with the real binding, but the runtime's output path
    /// uses [`PjRtBuffer::into_literal`] instead, which moves the payload
    /// and keeps `Runtime::call_into` single-copy end to end.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }

    /// Consuming device→host transfer: moves the payload out of the
    /// (host-memory) "device" buffer without copying the bytes.
    pub fn into_literal(self) -> Result<Literal> {
        Ok(self.literal)
    }
}

/// A compiled executable handle. The stub cannot run HLO — execution
/// surfaces a descriptive error instead.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable {
    _computation: XlaComputation,
}

const EXEC_UNAVAILABLE: &str = "the bundled `xla` stub cannot execute HLO programs; \
     link the real xla/PJRT crate in rust/Cargo.toml to run compiled artifacts";

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(EXEC_UNAVAILABLE))
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(EXEC_UNAVAILABLE))
    }
}

/// The PJRT client handle.
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { _computation: computation.clone() })
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        // SAFETY: `data` is a plain scalar slice; reinterpreting as bytes of
        // the same length is valid for the 4-byte scalars supported here.
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
        };
        let literal =
            Literal::create_from_shape_and_untyped_data(T::ELEMENT_TYPE, dims, bytes)?;
        Ok(PjRtBuffer { literal })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let xs = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.element_count(), 3);
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 4]).is_err()
        );
    }

    #[test]
    fn execution_reports_stub() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto { text: "HloModule x".into() });
        let exe = client.compile(&comp).unwrap();
        let err = exe.execute::<Literal>(&[]).unwrap_err();
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn tuple_unpack() {
        let a = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0u8; 4])
            .unwrap();
        let t = Literal::tuple(vec![a.clone(), a]);
        assert_eq!(t.to_tuple().unwrap().len(), 2);
    }

    #[test]
    fn into_literal_moves_payload() {
        let client = PjRtClient::cpu().unwrap();
        let xs = [1.5f32, -2.0];
        let buf = client.buffer_from_host_buffer(&xs, &[2], None).unwrap();
        let lit = buf.into_literal().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs);
    }
}
