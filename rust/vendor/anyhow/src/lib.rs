//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! The build environment for this repository is fully offline (no crates.io
//! registry), so the crate is vendored as a path dependency. Only the surface
//! the workspace actually uses is provided:
//!
//! * [`Error`] — an error value carrying a context chain.
//! * [`Result<T>`] — `Result<T, Error>` with a defaulted error type.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — construction macros.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//!
//! Formatting matches `anyhow` where tests depend on it: `{}` prints the
//! outermost message, `{:#}` prints the whole chain joined by `": "`.

use std::fmt;

/// `Result<T, anyhow::Error>` with a defaulted error parameter, so the
/// two-parameter form `Result<T, E>` keeps working.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error with a chain of human-readable messages, outermost context
/// first. Deliberately does **not** implement `std::error::Error` (exactly
/// like the real `anyhow::Error`) so the blanket `From<E: std::error::Error>`
/// impl below does not overlap with the reflexive `From<T> for T`.
pub struct Error {
    /// `chain[0]` is the outermost context, the last entry the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause messages, outermost first.
    pub fn chain_messages(&self) -> &[String] {
        &self.chain
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain, outermost first, joined by ": ".
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for (i, cause) in rest.iter().enumerate() {
                        write!(f, "\n    {i}: {cause}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

/// Convert any standard error into an [`Error`], capturing its source chain.
/// This is the impl that makes `?` work on `io::Error`, parse errors, etc.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: file missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<i64> {
            let v: i64 = "12x".parse()?;
            Ok(v)
        }
        assert!(inner().is_err());
    }

    #[test]
    fn option_context() {
        let x: Option<u8> = None;
        let e = x.context("nothing here").unwrap_err();
        assert_eq!(e.to_string(), "nothing here");
    }

    #[test]
    fn macros_build_errors() {
        fn f(flag: bool) -> Result<u8> {
            ensure!(flag, "flag was {flag}");
            bail!("always fails after ensure")
        }
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(f(true).unwrap_err().to_string(), "always fails after ensure");
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
    }
}
