//! Experiment metrics: learning-curve points (the paper plots reward vs
//! *wall-clock time*), per-condition summaries, and CSV writers.

use crate::rl::PpoStats;
use crate::util::csv::CsvWriter;
use crate::util::{StateReader, StateWriter};
use crate::Result;
use std::path::Path;

/// One point of a learning curve (paper Figs 3/5/6/10–12 top panels).
#[derive(Debug, Clone, Copy)]
pub struct CurvePoint {
    /// Training wall-clock seconds (AIP preparation time included as an
    /// offset, evaluation time excluded — the paper's protocol).
    pub wall_clock_s: f64,
    pub env_steps: usize,
    pub eval_mean: f64,
    pub eval_std: f64,
    pub stats: PpoStats,
}

/// Result of training one condition with one seed.
#[derive(Debug, Clone)]
pub struct ConditionResult {
    pub condition: String,
    pub seed: u64,
    pub curve: Vec<CurvePoint>,
    /// AIP preparation (dataset collection + offline training) seconds.
    pub prep_secs: f64,
    /// PPO training seconds (excluding evaluations).
    pub train_secs: f64,
    /// Held-out cross-entropy of the influence predictor (paper's bottom
    /// bar charts); NaN for the GS condition.
    pub aip_ce: f64,
    pub final_eval: f64,
}

impl ConditionResult {
    pub fn total_secs(&self) -> f64 {
        self.prep_secs + self.train_secs
    }
}

/// Serialize a learning curve exactly (every float byte for byte) — the
/// one binary curve format, shared by training checkpoints
/// (`coordinator::trainer`) and distributed shard results
/// (`coordinator::distributed`).
pub fn write_curve_state(curve: &[CurvePoint], w: &mut StateWriter) {
    w.usize(curve.len());
    for p in curve {
        w.f64(p.wall_clock_s);
        w.usize(p.env_steps);
        w.f64(p.eval_mean);
        w.f64(p.eval_std);
        w.f32(p.stats.total_loss);
        w.f32(p.stats.pg_loss);
        w.f32(p.stats.v_loss);
        w.f32(p.stats.entropy);
        w.f32(p.stats.approx_kl);
        w.f32(p.stats.grad_norm);
        w.f32(p.stats.rollout_reward);
        w.usize(p.stats.episodes);
    }
}

/// Inverse of [`write_curve_state`].
pub fn read_curve_state(r: &mut StateReader<'_>) -> Result<Vec<CurvePoint>> {
    let n = r.usize()?;
    let mut curve = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        curve.push(CurvePoint {
            wall_clock_s: r.f64()?,
            env_steps: r.usize()?,
            eval_mean: r.f64()?,
            eval_std: r.f64()?,
            stats: PpoStats {
                total_loss: r.f32()?,
                pg_loss: r.f32()?,
                v_loss: r.f32()?,
                entropy: r.f32()?,
                approx_kl: r.f32()?,
                grad_norm: r.f32()?,
                rollout_reward: r.f32()?,
                episodes: r.usize()?,
            },
        });
    }
    Ok(curve)
}

/// Write a curve CSV: one row per evaluation point.
pub fn write_curve(path: impl AsRef<Path>, curve: &[CurvePoint]) -> Result<()> {
    let mut w = CsvWriter::create(
        path,
        &[
            "wall_clock_s",
            "env_steps",
            "eval_mean",
            "eval_std",
            "rollout_reward",
            "entropy",
            "approx_kl",
            "v_loss",
            "grad_norm",
        ],
    )?;
    for p in curve {
        w.row(&[
            p.wall_clock_s,
            p.env_steps as f64,
            p.eval_mean,
            p.eval_std,
            p.stats.rollout_reward as f64,
            p.stats.entropy as f64,
            p.stats.approx_kl as f64,
            p.stats.v_loss as f64,
            p.stats.grad_norm as f64,
        ])?;
    }
    w.flush()?;
    Ok(())
}

/// Append-style summary writer for a whole figure run.
pub struct SummaryWriter {
    w: CsvWriter,
}

impl SummaryWriter {
    pub fn create(path: impl AsRef<Path>) -> Result<SummaryWriter> {
        Ok(SummaryWriter {
            w: CsvWriter::create(
                path,
                &[
                    "condition",
                    "seed",
                    "prep_secs",
                    "train_secs",
                    "total_secs",
                    "aip_ce",
                    "final_eval",
                ],
            )?,
        })
    }

    pub fn add(&mut self, r: &ConditionResult) -> Result<()> {
        self.w.row_str(&[
            r.condition.clone(),
            r.seed.to_string(),
            format!("{:.3}", r.prep_secs),
            format!("{:.3}", r.train_secs),
            format!("{:.3}", r.total_secs()),
            format!("{:.4}", r.aip_ce),
            format!("{:.4}", r.final_eval),
        ])?;
        self.w.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_roundtrip() {
        let dir = std::env::temp_dir().join("ials_metrics_test");
        let path = dir.join("curve.csv");
        let curve = vec![CurvePoint {
            wall_clock_s: 1.5,
            env_steps: 2048,
            eval_mean: 0.7,
            eval_std: 0.1,
            stats: PpoStats::default(),
        }];
        write_curve(&path, &curve).unwrap();
        let (header, rows) = crate::util::csv::read_numeric(&path).unwrap();
        assert_eq!(header[0], "wall_clock_s");
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0][1], 2048.0);
        std::fs::remove_dir_all(dir).ok();
    }
}
