//! TOML-subset parser.
//!
//! Grammar supported (sufficient for the experiment configs under
//! `configs/`):
//!
//! ```toml
//! # comment
//! [section]
//! key = "string"
//! n = 42
//! x = 3.14
//! flag = true
//! xs = [1, 2, 3]
//! names = ["a", "b"]
//! ```
//!
//! Keys before the first `[section]` live in the implicit root table `""`.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

/// A scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_int(&self) -> Result<i64> {
        match self {
            Value::Int(v) => Ok(*v),
            other => bail!("expected integer, got {other:?}"),
        }
    }

    pub fn as_float(&self) -> Result<f64> {
        match self {
            Value::Float(v) => Ok(*v),
            Value::Int(v) => Ok(*v as f64),
            other => bail!("expected float, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(v) => Ok(*v),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_array(&self) -> Result<&[Value]> {
        match self {
            Value::Array(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }
}

/// Parsed document: section name → key → value.
#[derive(Debug, Clone, Default)]
pub struct Document {
    pub tables: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Document {
    pub fn table(&self, name: &str) -> Option<&BTreeMap<String, Value>> {
        self.tables.get(name)
    }

    pub fn get(&self, table: &str, key: &str) -> Option<&Value> {
        self.tables.get(table).and_then(|t| t.get(key))
    }

    // --- typed getters with defaults, used by the schema layer ---

    pub fn str_or(&self, table: &str, key: &str, default: &str) -> Result<String> {
        match self.get(table, key) {
            Some(v) => Ok(v.as_str().with_context(|| format!("[{table}].{key}"))?.to_string()),
            None => Ok(default.to_string()),
        }
    }

    pub fn int_or(&self, table: &str, key: &str, default: i64) -> Result<i64> {
        match self.get(table, key) {
            Some(v) => v.as_int().with_context(|| format!("[{table}].{key}")),
            None => Ok(default),
        }
    }

    pub fn float_or(&self, table: &str, key: &str, default: f64) -> Result<f64> {
        match self.get(table, key) {
            Some(v) => v.as_float().with_context(|| format!("[{table}].{key}")),
            None => Ok(default),
        }
    }

    pub fn bool_or(&self, table: &str, key: &str, default: bool) -> Result<bool> {
        match self.get(table, key) {
            Some(v) => v.as_bool().with_context(|| format!("[{table}].{key}")),
            None => Ok(default),
        }
    }

    pub fn require(&self, table: &str, key: &str) -> Result<&Value> {
        self.get(table, key)
            .ok_or_else(|| anyhow!("missing required key [{table}].{key}"))
    }
}

/// Parse a TOML-subset document from text.
pub fn parse(text: &str) -> Result<Document> {
    let mut doc = Document::default();
    let mut current = String::new();
    doc.tables.entry(current.clone()).or_default();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                bail!("line {}: malformed table header: {raw}", lineno + 1);
            }
            let name = line[1..line.len() - 1].trim();
            if name.is_empty() || !name.chars().all(valid_key_char) {
                bail!("line {}: invalid table name '{name}'", lineno + 1);
            }
            current = name.to_string();
            doc.tables.entry(current.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| anyhow!("line {}: expected 'key = value': {raw}", lineno + 1))?;
        let key = line[..eq].trim();
        if key.is_empty() || !key.chars().all(valid_key_char) {
            bail!("line {}: invalid key '{key}'", lineno + 1);
        }
        let value = parse_value(line[eq + 1..].trim())
            .with_context(|| format!("line {}: value for key '{key}'", lineno + 1))?;
        let table = doc.tables.get_mut(&current).unwrap();
        if table.insert(key.to_string(), value).is_some() {
            bail!("line {}: duplicate key '{key}' in [{current}]", lineno + 1);
        }
    }
    Ok(doc)
}

fn valid_key_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.'
}

/// Remove a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    let s = s.trim();
    if s.is_empty() {
        bail!("empty value");
    }
    if s.starts_with('"') {
        if s.len() < 2 || !s.ends_with('"') {
            bail!("unterminated string: {s}");
        }
        let inner = &s[1..s.len() - 1];
        if inner.contains('"') {
            bail!("embedded quotes not supported: {s}");
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            bail!("unterminated array: {s}");
        }
        let inner = s[1..s.len() - 1].trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: Result<Vec<Value>> =
            split_top_level(inner)?.iter().map(|p| parse_value(p)).collect();
        return Ok(Value::Array(items?));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value: {s}")
}

/// Split an array body on commas (nested arrays are not supported — the
/// configs never need them; strings may contain commas).
fn split_top_level(s: &str) -> Result<Vec<String>> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth = depth.checked_sub(1).ok_or_else(|| anyhow!("unbalanced ]"))?;
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                parts.push(cur.trim().to_string());
                cur.clear();
            }
            _ => cur.push(c),
        }
    }
    if in_str {
        bail!("unterminated string in array");
    }
    if !cur.trim().is_empty() {
        parts.push(cur.trim().to_string());
    }
    Ok(parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_document() {
        let doc = parse(
            r#"
            # experiment config
            top = 1
            [experiment]
            name = "fig3"   # trailing comment
            steps = 40000
            lr = 3.0e-4
            eval = true
            seeds = [1, 2, 3]
            tags = ["a", "b"]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "top").unwrap().as_int().unwrap(), 1);
        assert_eq!(doc.get("experiment", "name").unwrap().as_str().unwrap(), "fig3");
        assert_eq!(doc.get("experiment", "steps").unwrap().as_int().unwrap(), 40000);
        assert!((doc.get("experiment", "lr").unwrap().as_float().unwrap() - 3e-4).abs() < 1e-12);
        assert!(doc.get("experiment", "eval").unwrap().as_bool().unwrap());
        assert_eq!(
            doc.get("experiment", "seeds").unwrap().as_array().unwrap().len(),
            3
        );
    }

    #[test]
    fn int_coerces_to_float() {
        let doc = parse("x = 2").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_float().unwrap(), 2.0);
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = parse(r##"s = "a#b""##).unwrap();
        assert_eq!(doc.get("", "s").unwrap().as_str().unwrap(), "a#b");
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(parse("[unclosed").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("x = ").is_err());
        assert!(parse(r#"x = "open"#).is_err());
        assert!(parse("x = [1, 2").is_err());
    }

    #[test]
    fn empty_array_ok() {
        let doc = parse("xs = []").unwrap();
        assert!(doc.get("", "xs").unwrap().as_array().unwrap().is_empty());
    }

    #[test]
    fn defaults_api() {
        let doc = parse("[a]\nx = 5").unwrap();
        assert_eq!(doc.int_or("a", "x", 0).unwrap(), 5);
        assert_eq!(doc.int_or("a", "y", 7).unwrap(), 7);
        assert_eq!(doc.str_or("b", "z", "d").unwrap(), "d");
        assert!(doc.require("a", "missing").is_err());
    }

    #[test]
    fn type_errors_reported() {
        let doc = parse("[a]\nx = 5").unwrap();
        assert!(doc.get("a", "x").unwrap().as_str().is_err());
        assert!(doc.get("a", "x").unwrap().as_bool().is_err());
    }
}
