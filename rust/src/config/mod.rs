//! Configuration system: a hand-rolled TOML-subset parser ([`toml`]) plus
//! the typed experiment schema ([`schema`]).
//!
//! The offline crate set has neither `serde` nor `toml` (DESIGN.md §6), so
//! the parser is built here. The supported subset covers everything the
//! experiment configs need: `[tables]`, dotted keys are *not* needed,
//! strings, integers, floats, booleans, arrays of scalars and `#` comments.

pub mod schema;
pub mod toml;

pub use schema::{
    AipKind, BackendKind, DomainKind, ExperimentConfig, HealthConfig, PpoConfig, RuntimeConfig,
    ServeConfig, SimulatorKind, TrafficConfig, WarehouseConfig,
};
pub use toml::{parse as parse_toml, Document, Value};
