//! Typed experiment configuration: defaults, parsing from the TOML-subset
//! [`super::toml::Document`], and validation.
//!
//! A config fully determines an experiment run: the domain (traffic /
//! warehouse), which simulator trains the agent (GS / IALS / untrained-IALS
//! / F-IALS — the paper's four conditions), PPO hyperparameters, AIP
//! dataset/training settings, and seeds.

use super::toml::Document;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Which benchmark domain (paper §5.2 / §5.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DomainKind {
    Traffic,
    Warehouse,
}

impl DomainKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "traffic" => Ok(DomainKind::Traffic),
            "warehouse" => Ok(DomainKind::Warehouse),
            other => bail!("unknown domain '{other}' (want traffic|warehouse)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DomainKind::Traffic => "traffic",
            DomainKind::Warehouse => "warehouse",
        }
    }
}

/// Which simulator the agent trains on (paper §5.1 conditions + Appendix E).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimulatorKind {
    /// Global simulator — the slow, exact baseline.
    Gs,
    /// Influence-augmented local simulator with a trained neural AIP.
    Ials,
    /// IALS whose AIP keeps its random initialization (untrained-IALS).
    UntrainedIals,
    /// IALS with a fixed marginal P(u) (F-IALS, Appendix E).
    FixedIals,
}

impl SimulatorKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "gs" => Ok(SimulatorKind::Gs),
            "ials" => Ok(SimulatorKind::Ials),
            "untrained-ials" => Ok(SimulatorKind::UntrainedIals),
            "f-ials" => Ok(SimulatorKind::FixedIals),
            other => bail!("unknown simulator '{other}' (want gs|ials|untrained-ials|f-ials)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SimulatorKind::Gs => "gs",
            SimulatorKind::Ials => "ials",
            SimulatorKind::UntrainedIals => "untrained-ials",
            SimulatorKind::FixedIals => "f-ials",
        }
    }
}

/// AIP flavor (influence predictor implementations in `influence/`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AipKind {
    Neural,
    Untrained,
    Fixed,
}

impl AipKind {
    pub fn name(&self) -> &'static str {
        match self {
            AipKind::Neural => "neural",
            AipKind::Untrained => "untrained",
            AipKind::Fixed => "fixed",
        }
    }
}

/// Which execution engine runs the NN artifacts (`runtime::Backend`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Native when no artifacts directory exists, PJRT otherwise.
    Auto,
    /// Hand-rolled CPU kernels against a synthesized in-memory manifest —
    /// trains end-to-end with no `make artifacts` step.
    Native,
    /// AOT-compiled artifacts through the PJRT client (requires
    /// `artifacts/` and a real `xla` binding).
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(BackendKind::Auto),
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => bail!("unknown backend '{other}' (want auto|native|pjrt)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Auto => "auto",
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Runtime / execution-engine settings.
#[derive(Debug, Clone)]
pub struct RuntimeConfig {
    pub backend: BackendKind,
    /// Worker threads for the native engine's data-parallel NN work
    /// (batched forwards + PPO/AIP training) — same semantics as `[ppo]
    /// num_workers`: `1` = serial execution (the default), `0` = one worker
    /// per available core, `n > 1` = that many workers from the run's
    /// shared compute pool. At a fixed seed, results are bitwise identical
    /// across `nn_workers` values and machines: batch rows partition over a
    /// fixed slice grid and per-slice gradient partials reduce in fixed
    /// slice order, so the knob only changes wall-clock. (Ignored by the
    /// PJRT backend, which owns its own threading.)
    pub nn_workers: usize,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig { backend: BackendKind::Auto, nn_workers: 1 }
    }
}

/// Cross-process distributed-training settings (`coordinator::distributed`):
/// how many worker processes `repro train --distributed` supervises and how
/// the supervisor reacts to crashed or hung workers.
#[derive(Debug, Clone)]
pub struct DistributedConfig {
    /// Worker processes the K learners are partitioned across (contiguous
    /// shards; clamped to K when larger). `repro train --distributed N`
    /// overrides this.
    pub workers: usize,
    /// A worker whose heartbeat file shows no progress for this many
    /// seconds is declared hung, killed and restarted. Must exceed the
    /// slowest single phase of a worker (AIP preparation or one PPO
    /// iteration) — heartbeats are progress reports, not a timer thread.
    pub heartbeat_timeout_secs: f64,
    /// Restarts the supervisor grants each worker before marking its
    /// learner shard failed and finishing without it.
    pub max_restarts: usize,
    /// Base delay before a restart; doubles per consecutive restart of the
    /// same worker (bounded exponential backoff).
    pub backoff_ms: u64,
}

impl Default for DistributedConfig {
    fn default() -> Self {
        DistributedConfig {
            workers: 2,
            heartbeat_timeout_secs: 120.0,
            max_restarts: 2,
            backoff_ms: 500,
        }
    }
}

/// Training health-guard settings (`runtime::guard`): cheap read-only
/// invariant checks after every PPO update classify each learner as
/// healthy, anomalous or diverged; a diverged learner is rolled back to
/// its newest valid checkpoint, and quarantined once the rollback budget
/// is spent. Checks never touch RNG streams or training floats, so a
/// guard-on clean run is bitwise identical to a guard-off one.
#[derive(Debug, Clone)]
pub struct HealthConfig {
    /// Master switch for the per-iteration checks (AIP preparation is
    /// always checked: a non-finite offline loss dooms the run regardless).
    pub enabled: bool,
    /// Rolling window of recent grad norms the spike detector compares
    /// against (per learner, reset on rollback).
    pub window: usize,
    /// A finite grad norm above `spike_factor x` the rolling-window mean
    /// is an anomaly (the window must be full first).
    pub spike_factor: f64,
    /// Consecutive anomalous iterations before a learner counts as
    /// diverged (non-finite values diverge immediately).
    pub max_anomalies: usize,
    /// Rollbacks granted per learner before it is quarantined; must be
    /// >= 1 (use `enabled = false` to turn the guard off instead).
    pub max_rollbacks: usize,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            enabled: true,
            window: 8,
            spike_factor: 10.0,
            max_anomalies: 3,
            max_rollbacks: 2,
        }
    }
}

/// Policy-inference server settings (`serve`): how `repro serve` batches,
/// bounds and times out requests. All knobs are robustness levers — the
/// server's correctness (batched forwards bitwise identical to serial
/// ones, atomic hot-reload) does not depend on any of them.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// TCP port to listen on (loopback). `repro serve --port P` overrides;
    /// `0` binds an ephemeral port (printed at startup) for tests/CI.
    pub port: usize,
    /// How long the micro-batcher holds the first request of a batch while
    /// coalescing concurrent ones into a single batched policy forward.
    /// `0` disables coalescing (every request is a batch of one).
    pub batch_window_ms: u64,
    /// Largest batch one forward executes; a full batch dispatches
    /// immediately, before the window elapses.
    pub max_batch: usize,
    /// Bound of the request queue between connection workers and the
    /// engine thread. A full queue sheds new requests with
    /// `503 + Retry-After` instead of letting latency grow without bound.
    pub queue_capacity: usize,
    /// Connection-handler threads (each parses HTTP, submits to the
    /// engine, and writes the response for one connection at a time).
    pub workers: usize,
    /// Socket read timeout: a client that stalls mid-request (slow loris)
    /// is answered `408` and disconnected after this long.
    pub read_timeout_ms: u64,
    /// Socket write timeout: a client that stops reading its response is
    /// disconnected after this long.
    pub write_timeout_ms: u64,
    /// Per-request deadline, admission to response: requests that cannot
    /// be served in time are answered `504` (and shed engine-side if the
    /// deadline expires while queued).
    pub request_timeout_ms: u64,
    /// Largest request body accepted; larger ones are answered `413`
    /// before any allocation of the claimed size.
    pub max_body_bytes: usize,
    /// Requests served on one keep-alive connection before the server
    /// closes it (resource hygiene — no connection is immortal).
    pub max_requests_per_conn: usize,
    /// How long a keep-alive connection may sit idle between requests
    /// before the server closes it silently.
    pub idle_timeout_ms: u64,
    /// Checkpoint run directories to host (`repro serve` with no
    /// `--checkpoint-dir` flags serves these; each becomes a
    /// `/v1/runs/<basename>/…` namespace). Empty by default: the CLI
    /// flag is the usual way in.
    pub runs: Vec<String>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            port: 8080,
            batch_window_ms: 2,
            max_batch: 64,
            queue_capacity: 256,
            workers: 4,
            read_timeout_ms: 5_000,
            write_timeout_ms: 5_000,
            request_timeout_ms: 10_000,
            max_body_bytes: 1 << 20,
            max_requests_per_conn: 1_000,
            idle_timeout_ms: 5_000,
            runs: Vec::new(),
        }
    }
}

/// Traffic domain parameters (§5.2). The GS is a `grid x grid` network of
/// signalized intersections; the LS is the single agent intersection.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    /// Grid side (paper: 5 → 25 intersections).
    pub grid: usize,
    /// Cells per lane segment between intersections.
    pub lane_len: usize,
    /// Probability a car enters each boundary lane per step (paper App E: 0.1).
    pub inflow_prob: f32,
    /// Which intersection the agent controls: 1 (center) or 2 (off-center),
    /// matching the two highlighted intersections of Fig 2.
    pub agent_intersection: usize,
    /// Minimum green phase duration (steps) before a switch is allowed.
    pub min_green: usize,
    /// Gap-out horizon for the actuated baseline controller.
    pub actuated_max_green: usize,
    /// Episode length in steps.
    pub episode_len: usize,
    /// Probability a car goes straight at an intersection (rest split
    /// equally between left/right turns).
    pub p_straight: f32,
    /// Simulator ticks per control decision (SUMO-style: the microscopic
    /// simulation runs several 1-second ticks between traffic-light
    /// decisions). Both GS and LS use the same value.
    pub substeps: usize,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig {
            grid: 5,
            lane_len: 10,
            inflow_prob: 0.1,
            agent_intersection: 1,
            min_green: 3,
            actuated_max_green: 20,
            episode_len: 200,
            p_straight: 0.7,
            substeps: 3,
        }
    }
}

/// Warehouse domain parameters (§5.3).
#[derive(Debug, Clone)]
pub struct WarehouseConfig {
    /// Robots per side (paper: 6 → 36 robots).
    pub robots_per_side: usize,
    /// Region side length (paper: 5).
    pub region: usize,
    /// Per-shelf-cell item spawn probability (paper: 0.02).
    pub item_prob: f32,
    /// Episode length in steps.
    pub episode_len: usize,
    /// §5.4 variant: items vanish after exactly this many steps (0 = off).
    pub fixed_item_lifetime: usize,
    /// Observation frame-stack for the memory agent (paper App F: 8).
    pub frame_stack: usize,
}

impl Default for WarehouseConfig {
    fn default() -> Self {
        WarehouseConfig {
            robots_per_side: 6,
            region: 5,
            item_prob: 0.02,
            episode_len: 200,
            fixed_item_lifetime: 0,
            frame_stack: 1,
        }
    }
}

/// PPO hyperparameters (Schulman et al. 2017). Batch geometry must match
/// the AOT-compiled artifacts (validated against the manifest at load).
#[derive(Debug, Clone)]
pub struct PpoConfig {
    pub num_envs: usize,
    pub rollout_len: usize,
    pub epochs: usize,
    pub minibatch: usize,
    pub gamma: f32,
    pub lam: f32,
    pub clip: f32,
    pub lr: f32,
    pub vf_coef: f32,
    pub ent_coef: f32,
    pub max_grad_norm: f32,
    /// Total environment steps of training.
    pub total_steps: usize,
    /// Worker threads for sharded env stepping and dataset collection
    /// (`core::shard`, `collect`): `1` = inline serial execution (the
    /// default), `0` = one worker per available core, `n > 1` = that many
    /// persistent shard workers. At a fixed seed, results are bitwise
    /// identical across worker counts and machines — the knob only changes
    /// wall-clock. (Per-env RNG streams + fixed collection chunking make
    /// this hold; seeds are therefore *not* bit-compatible with runs from
    /// before the sharded executor existed.)
    pub num_workers: usize,
}

impl Default for PpoConfig {
    fn default() -> Self {
        PpoConfig {
            num_envs: 16,
            rollout_len: 128,
            epochs: 4,
            minibatch: 256,
            gamma: 0.99,
            lam: 0.95,
            clip: 0.2,
            lr: 3e-4,
            vf_coef: 0.5,
            ent_coef: 0.01,
            max_grad_norm: 0.5,
            total_steps: 40_000,
            num_workers: 1,
        }
    }
}

/// AIP dataset + offline-training settings (paper §4, Algorithm 1).
#[derive(Debug, Clone)]
pub struct AipConfig {
    pub kind: AipKind,
    /// Number of (d-set, u) samples collected from the GS.
    pub dataset_size: usize,
    /// Held-out GS samples for the reported AIP cross-entropy (never on
    /// the training clock; 4000 reproduces the paper harness).
    pub eval_size: usize,
    /// Offline training epochs over the dataset.
    pub train_epochs: usize,
    pub batch: usize,
    pub lr: f32,
    /// Sequence length for BPTT (GRU AIPs). Theorem 1: should be >= the
    /// agent's memory (frame_stack).
    pub seq_len: usize,
    /// F-IALS: fixed marginal probability; if < 0, estimate the marginal
    /// from the dataset (warehouse variant of Appendix E).
    pub fixed_p: f32,
    /// Feed the full ALSH features (confounders included) instead of the
    /// d-set — the Appendix B ablation.
    pub use_full_alsh: bool,
}

impl Default for AipConfig {
    fn default() -> Self {
        AipConfig {
            kind: AipKind::Neural,
            dataset_size: 50_000,
            eval_size: 4000,
            train_epochs: 4,
            batch: 256,
            lr: 1e-3,
            seq_len: 8,
            fixed_p: 0.1,
            use_full_alsh: false,
        }
    }
}

/// Top-level experiment config.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub domain: DomainKind,
    pub simulator: SimulatorKind,
    /// Concurrent learners per run (`coordinator::multi`): `1` (the
    /// default) is the historical single-learner experiment, bit for bit;
    /// `K > 1` trains K independent policies round-robin over the one
    /// shared compute pool, against one shared AIP dataset — learner `j`
    /// is seeded by `runtime::learner_seed(seed, j)`, so results are
    /// bitwise reproducible for any `num_learners × num_workers ×
    /// nn_workers`.
    pub num_learners: usize,
    /// Seeds to run (results are averaged; paper uses 5).
    pub seeds: Vec<u64>,
    /// Evaluate on the GS every this many training steps (paper §5.1:
    /// training interleaved with periodic GS evaluations).
    pub eval_every: usize,
    pub eval_episodes: usize,
    pub results_dir: String,
    pub artifacts_dir: String,
    /// Write a crash-safe training checkpoint every this many per-learner
    /// env steps (`runtime::checkpoint`); `0` (the default) disables
    /// checkpointing. Saves land on iteration boundaries, so the effective
    /// cadence is rounded up to `num_envs * rollout_len`.
    pub checkpoint_every: usize,
    /// Directory for checkpoint files; each (condition, seed) run uses its
    /// own subdirectory so concurrent runs never collide.
    pub checkpoint_dir: String,
    /// How many checkpoint files to keep per run directory (older ones are
    /// pruned after each successful save). The retention window is also the
    /// corruption-fallback depth of `load_latest`; must be >= 1.
    pub checkpoint_retain: usize,
    pub traffic: TrafficConfig,
    pub warehouse: WarehouseConfig,
    pub ppo: PpoConfig,
    pub aip: AipConfig,
    pub runtime: RuntimeConfig,
    pub distributed: DistributedConfig,
    pub health: HealthConfig,
    pub serve: ServeConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            domain: DomainKind::Traffic,
            simulator: SimulatorKind::Ials,
            num_learners: 1,
            seeds: vec![1],
            eval_every: 4096,
            eval_episodes: 4,
            results_dir: "results".into(),
            artifacts_dir: "artifacts".into(),
            checkpoint_every: 0,
            checkpoint_dir: "checkpoints".into(),
            checkpoint_retain: 3,
            traffic: TrafficConfig::default(),
            warehouse: WarehouseConfig::default(),
            ppo: PpoConfig::default(),
            aip: AipConfig::default(),
            runtime: RuntimeConfig::default(),
            distributed: DistributedConfig::default(),
            health: HealthConfig::default(),
            serve: ServeConfig::default(),
        }
    }
}

impl ExperimentConfig {
    /// Parse from TOML text; unknown keys are rejected to catch typos.
    pub fn from_toml(text: &str) -> Result<ExperimentConfig> {
        let doc = super::toml::parse(text)?;
        Self::from_doc(&doc)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        Self::from_toml(&text)
    }

    pub fn from_doc(doc: &Document) -> Result<ExperimentConfig> {
        check_known_keys(doc)?;
        let mut cfg = ExperimentConfig::default();

        cfg.name = doc.str_or("experiment", "name", &cfg.name)?;
        cfg.domain = DomainKind::parse(&doc.str_or("experiment", "domain", "traffic")?)?;
        cfg.simulator = SimulatorKind::parse(&doc.str_or("experiment", "simulator", "ials")?)?;
        cfg.num_learners =
            doc.int_or("experiment", "num_learners", cfg.num_learners as i64)? as usize;
        if let Some(v) = doc.get("experiment", "seeds") {
            cfg.seeds = v
                .as_array()?
                .iter()
                .map(|x| Ok(x.as_int()? as u64))
                .collect::<Result<Vec<_>>>()?;
        }
        cfg.eval_every = doc.int_or("experiment", "eval_every", cfg.eval_every as i64)? as usize;
        cfg.eval_episodes =
            doc.int_or("experiment", "eval_episodes", cfg.eval_episodes as i64)? as usize;
        cfg.results_dir = doc.str_or("experiment", "results_dir", &cfg.results_dir)?;
        cfg.artifacts_dir = doc.str_or("experiment", "artifacts_dir", &cfg.artifacts_dir)?;
        cfg.checkpoint_every =
            doc.int_or("experiment", "checkpoint_every", cfg.checkpoint_every as i64)? as usize;
        cfg.checkpoint_dir = doc.str_or("experiment", "checkpoint_dir", &cfg.checkpoint_dir)?;
        cfg.checkpoint_retain =
            doc.int_or("experiment", "checkpoint_retain", cfg.checkpoint_retain as i64)? as usize;

        let t = &mut cfg.traffic;
        t.grid = doc.int_or("traffic", "grid", t.grid as i64)? as usize;
        t.lane_len = doc.int_or("traffic", "lane_len", t.lane_len as i64)? as usize;
        t.inflow_prob = doc.float_or("traffic", "inflow_prob", t.inflow_prob as f64)? as f32;
        t.agent_intersection =
            doc.int_or("traffic", "agent_intersection", t.agent_intersection as i64)? as usize;
        t.min_green = doc.int_or("traffic", "min_green", t.min_green as i64)? as usize;
        t.actuated_max_green =
            doc.int_or("traffic", "actuated_max_green", t.actuated_max_green as i64)? as usize;
        t.episode_len = doc.int_or("traffic", "episode_len", t.episode_len as i64)? as usize;
        t.p_straight = doc.float_or("traffic", "p_straight", t.p_straight as f64)? as f32;
        t.substeps = doc.int_or("traffic", "substeps", t.substeps as i64)? as usize;

        let w = &mut cfg.warehouse;
        w.robots_per_side =
            doc.int_or("warehouse", "robots_per_side", w.robots_per_side as i64)? as usize;
        w.region = doc.int_or("warehouse", "region", w.region as i64)? as usize;
        w.item_prob = doc.float_or("warehouse", "item_prob", w.item_prob as f64)? as f32;
        w.episode_len = doc.int_or("warehouse", "episode_len", w.episode_len as i64)? as usize;
        w.fixed_item_lifetime =
            doc.int_or("warehouse", "fixed_item_lifetime", w.fixed_item_lifetime as i64)? as usize;
        w.frame_stack = doc.int_or("warehouse", "frame_stack", w.frame_stack as i64)? as usize;

        let p = &mut cfg.ppo;
        p.num_envs = doc.int_or("ppo", "num_envs", p.num_envs as i64)? as usize;
        p.rollout_len = doc.int_or("ppo", "rollout_len", p.rollout_len as i64)? as usize;
        p.epochs = doc.int_or("ppo", "epochs", p.epochs as i64)? as usize;
        p.minibatch = doc.int_or("ppo", "minibatch", p.minibatch as i64)? as usize;
        p.gamma = doc.float_or("ppo", "gamma", p.gamma as f64)? as f32;
        p.lam = doc.float_or("ppo", "lam", p.lam as f64)? as f32;
        p.clip = doc.float_or("ppo", "clip", p.clip as f64)? as f32;
        p.lr = doc.float_or("ppo", "lr", p.lr as f64)? as f32;
        p.vf_coef = doc.float_or("ppo", "vf_coef", p.vf_coef as f64)? as f32;
        p.ent_coef = doc.float_or("ppo", "ent_coef", p.ent_coef as f64)? as f32;
        p.max_grad_norm = doc.float_or("ppo", "max_grad_norm", p.max_grad_norm as f64)? as f32;
        p.total_steps = doc.int_or("ppo", "total_steps", p.total_steps as i64)? as usize;
        p.num_workers = doc.int_or("ppo", "num_workers", p.num_workers as i64)? as usize;

        let a = &mut cfg.aip;
        a.kind = match doc.str_or("aip", "kind", "neural")?.as_str() {
            "neural" => AipKind::Neural,
            "untrained" => AipKind::Untrained,
            "fixed" => AipKind::Fixed,
            other => bail!("unknown aip kind '{other}'"),
        };
        a.dataset_size = doc.int_or("aip", "dataset_size", a.dataset_size as i64)? as usize;
        a.eval_size = doc.int_or("aip", "eval_size", a.eval_size as i64)? as usize;
        a.train_epochs = doc.int_or("aip", "train_epochs", a.train_epochs as i64)? as usize;
        a.batch = doc.int_or("aip", "batch", a.batch as i64)? as usize;
        a.lr = doc.float_or("aip", "lr", a.lr as f64)? as f32;
        a.seq_len = doc.int_or("aip", "seq_len", a.seq_len as i64)? as usize;
        a.fixed_p = doc.float_or("aip", "fixed_p", a.fixed_p as f64)? as f32;
        a.use_full_alsh = doc.bool_or("aip", "use_full_alsh", a.use_full_alsh)?;

        cfg.runtime.backend = BackendKind::parse(&doc.str_or("runtime", "backend", "auto")?)?;
        cfg.runtime.nn_workers =
            doc.int_or("runtime", "nn_workers", cfg.runtime.nn_workers as i64)? as usize;

        let d = &mut cfg.distributed;
        d.workers = doc.int_or("distributed", "workers", d.workers as i64)? as usize;
        d.heartbeat_timeout_secs =
            doc.float_or("distributed", "heartbeat_timeout_secs", d.heartbeat_timeout_secs)?;
        d.max_restarts = doc.int_or("distributed", "max_restarts", d.max_restarts as i64)? as usize;
        d.backoff_ms = doc.int_or("distributed", "backoff_ms", d.backoff_ms as i64)? as u64;

        let h = &mut cfg.health;
        h.enabled = doc.bool_or("health", "enabled", h.enabled)?;
        h.window = doc.int_or("health", "window", h.window as i64)? as usize;
        h.spike_factor = doc.float_or("health", "spike_factor", h.spike_factor)?;
        h.max_anomalies = doc.int_or("health", "max_anomalies", h.max_anomalies as i64)? as usize;
        h.max_rollbacks = doc.int_or("health", "max_rollbacks", h.max_rollbacks as i64)? as usize;

        let s = &mut cfg.serve;
        s.port = doc.int_or("serve", "port", s.port as i64)? as usize;
        s.batch_window_ms =
            doc.int_or("serve", "batch_window_ms", s.batch_window_ms as i64)? as u64;
        s.max_batch = doc.int_or("serve", "max_batch", s.max_batch as i64)? as usize;
        s.queue_capacity = doc.int_or("serve", "queue_capacity", s.queue_capacity as i64)? as usize;
        s.workers = doc.int_or("serve", "workers", s.workers as i64)? as usize;
        s.read_timeout_ms =
            doc.int_or("serve", "read_timeout_ms", s.read_timeout_ms as i64)? as u64;
        s.write_timeout_ms =
            doc.int_or("serve", "write_timeout_ms", s.write_timeout_ms as i64)? as u64;
        s.request_timeout_ms =
            doc.int_or("serve", "request_timeout_ms", s.request_timeout_ms as i64)? as u64;
        s.max_body_bytes = doc.int_or("serve", "max_body_bytes", s.max_body_bytes as i64)? as usize;
        s.max_requests_per_conn =
            doc.int_or("serve", "max_requests_per_conn", s.max_requests_per_conn as i64)? as usize;
        s.idle_timeout_ms =
            doc.int_or("serve", "idle_timeout_ms", s.idle_timeout_ms as i64)? as u64;
        if let Some(v) = doc.get("serve", "runs") {
            s.runs = v
                .as_array()?
                .iter()
                .map(|x| Ok(x.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?;
        }

        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity checks that fail fast rather than mid-run.
    pub fn validate(&self) -> Result<()> {
        let p = &self.ppo;
        anyhow::ensure!(p.num_envs > 0, "num_envs must be positive");
        anyhow::ensure!(p.rollout_len > 0, "rollout_len must be positive");
        let batch = p.num_envs * p.rollout_len;
        anyhow::ensure!(
            batch % p.minibatch == 0,
            "rollout batch {} not divisible by minibatch {}",
            batch,
            p.minibatch
        );
        anyhow::ensure!((0.0..=1.0).contains(&p.gamma), "gamma out of range");
        anyhow::ensure!((0.0..=1.0).contains(&p.lam), "lambda out of range");
        // Worker knobs parse through i64 → usize, so a negative value wraps
        // to a huge count; bound both so a typo fails here instead of
        // trying to spawn 2^64 pool threads.
        anyhow::ensure!(
            p.num_workers <= 1024,
            "num_workers must be in 0..=1024 (got {})",
            p.num_workers
        );
        anyhow::ensure!(
            self.runtime.nn_workers <= 1024,
            "nn_workers must be in 0..=1024 (got {})",
            self.runtime.nn_workers
        );
        let t = &self.traffic;
        anyhow::ensure!(t.grid >= 3, "traffic grid must be >= 3 (needs interior)");
        anyhow::ensure!(t.lane_len >= 4, "lane_len must be >= 4");
        anyhow::ensure!((0.0..=1.0).contains(&t.inflow_prob), "inflow_prob out of range");
        anyhow::ensure!(
            t.agent_intersection == 1 || t.agent_intersection == 2,
            "agent_intersection must be 1 or 2"
        );
        anyhow::ensure!(t.substeps >= 1, "substeps must be >= 1");
        let w = &self.warehouse;
        anyhow::ensure!(w.region == 5, "warehouse region must be 5 (paper layout)");
        anyhow::ensure!(w.robots_per_side >= 2, "need at least 2x2 robots");
        anyhow::ensure!((0.0..=1.0).contains(&w.item_prob), "item_prob out of range");
        anyhow::ensure!(w.frame_stack >= 1, "frame_stack must be >= 1");
        anyhow::ensure!(self.aip.seq_len >= 1, "aip seq_len must be >= 1");
        anyhow::ensure!(self.aip.eval_size >= 1, "aip eval_size must be >= 1");
        anyhow::ensure!(!self.seeds.is_empty(), "need at least one seed");
        // Like the worker knobs, a negative value wraps through `as usize`
        // — bound it so a typo fails here, not while allocating K runs'
        // worth of envs and stores.
        anyhow::ensure!(
            (1..=64).contains(&self.num_learners),
            "num_learners must be in 1..=64 (got {})",
            self.num_learners
        );
        // retain = 0 would delete the checkpoint that was just written.
        anyhow::ensure!(
            self.checkpoint_retain >= 1,
            "checkpoint_retain must be >= 1 (got {})",
            self.checkpoint_retain
        );
        let d = &self.distributed;
        anyhow::ensure!(
            (1..=64).contains(&d.workers),
            "distributed workers must be in 1..=64 (got {})",
            d.workers
        );
        anyhow::ensure!(
            d.heartbeat_timeout_secs.is_finite() && d.heartbeat_timeout_secs > 0.0,
            "heartbeat_timeout_secs must be a positive finite number (got {})",
            d.heartbeat_timeout_secs
        );
        anyhow::ensure!(
            d.max_restarts <= 100,
            "max_restarts must be in 0..=100 (got {})",
            d.max_restarts
        );
        anyhow::ensure!(
            d.backoff_ms <= 600_000,
            "backoff_ms must be in 0..=600000 (got {})",
            d.backoff_ms
        );
        let h = &self.health;
        anyhow::ensure!(
            (1..=1024).contains(&h.window),
            "[health] window must be in 1..=1024 (got {})",
            h.window
        );
        anyhow::ensure!(
            h.spike_factor.is_finite() && h.spike_factor > 1.0,
            "[health] spike_factor must be a finite number > 1 (got {})",
            h.spike_factor
        );
        anyhow::ensure!(
            (1..=1024).contains(&h.max_anomalies),
            "[health] max_anomalies must be in 1..=1024 (got {})",
            h.max_anomalies
        );
        // max_rollbacks = 0 would quarantine a learner on its first
        // divergence without ever attempting the recovery the guard exists
        // for — almost certainly a misconfiguration, so it is rejected in
        // favor of the explicit off switch.
        anyhow::ensure!(
            (1..=100).contains(&h.max_rollbacks),
            "[health] max_rollbacks must be in 1..=100 (got {}); to disable the guard set \
             [health] enabled = false instead",
            h.max_rollbacks
        );
        let s = &self.serve;
        anyhow::ensure!(s.port <= 65_535, "[serve] port must be in 0..=65535 (got {})", s.port);
        anyhow::ensure!(
            s.batch_window_ms <= 1_000,
            "[serve] batch_window_ms must be in 0..=1000 (got {})",
            s.batch_window_ms
        );
        anyhow::ensure!(
            (1..=4096).contains(&s.max_batch),
            "[serve] max_batch must be in 1..=4096 (got {})",
            s.max_batch
        );
        anyhow::ensure!(
            (1..=65_536).contains(&s.queue_capacity),
            "[serve] queue_capacity must be in 1..=65536 (got {})",
            s.queue_capacity
        );
        anyhow::ensure!(
            (1..=256).contains(&s.workers),
            "[serve] workers must be in 1..=256 (got {})",
            s.workers
        );
        for (what, ms) in [
            ("read_timeout_ms", s.read_timeout_ms),
            ("write_timeout_ms", s.write_timeout_ms),
            ("request_timeout_ms", s.request_timeout_ms),
        ] {
            anyhow::ensure!(
                (1..=600_000).contains(&ms),
                "[serve] {what} must be in 1..=600000 (got {ms})"
            );
        }
        anyhow::ensure!(
            (1..=(1 << 30)).contains(&s.max_body_bytes),
            "[serve] max_body_bytes must be in 1..=2^30 (got {})",
            s.max_body_bytes
        );
        anyhow::ensure!(
            (1..=1_000_000).contains(&s.max_requests_per_conn),
            "[serve] max_requests_per_conn must be in 1..=1000000 (got {})",
            s.max_requests_per_conn
        );
        anyhow::ensure!(
            (1..=600_000).contains(&s.idle_timeout_ms),
            "[serve] idle_timeout_ms must be in 1..=600000 (got {})",
            s.idle_timeout_ms
        );
        for dir in &s.runs {
            anyhow::ensure!(!dir.is_empty(), "[serve] runs entries must be non-empty paths");
        }
        Ok(())
    }

    /// Cross-field checks for a distributed (`--distributed`) run, beyond
    /// [`ExperimentConfig::validate`]: the worker restart protocol resumes
    /// from checkpoints, and shards cannot be empty. Errors name both
    /// offending keys so the fix is obvious from the message alone.
    pub fn validate_distributed(&self, workers: usize) -> Result<()> {
        anyhow::ensure!(
            self.checkpoint_every > 0,
            "--distributed requires checkpointing: [experiment] checkpoint_every = 0 while \
             [distributed] workers = {workers}; workers restart from their shard's newest \
             checkpoint, so set [experiment] checkpoint_every > 0 (or pass --checkpoint-every)"
        );
        anyhow::ensure!(
            workers <= self.num_learners,
            "[distributed] workers = {workers} exceeds [experiment] num_learners = {}; every \
             worker needs at least one learner — lower workers or raise num_learners",
            self.num_learners
        );
        Ok(())
    }

    /// Render the *effective* config back to TOML, every known key spelled
    /// out. `from_toml(cfg.to_toml_string())` reconstructs `cfg` exactly:
    /// floats print via Rust's shortest-roundtrip `Display` (whole values
    /// print as integers, which `float_or` coerces back), so the f32 knobs
    /// survive the f64 parse bit for bit. The distributed coordinator ships
    /// the coordinator's config to workers through this.
    pub fn to_toml_string(&self) -> String {
        fn s(v: &str) -> String {
            // Our minimal TOML parser rejects embedded quotes; catch them at
            // write time so a bad value fails in the coordinator, not when a
            // worker re-parses the shipped file.
            assert!(
                !v.contains('"') && !v.contains('\n'),
                "config string {v:?} cannot be serialized"
            );
            format!("\"{v}\"")
        }
        let mut o = String::new();
        let e = |o: &mut String, k: &str, v: String| {
            o.push_str(k);
            o.push_str(" = ");
            o.push_str(&v);
            o.push('\n');
        };
        o.push_str("[experiment]\n");
        e(&mut o, "name", s(&self.name));
        e(&mut o, "domain", s(self.domain.name()));
        e(&mut o, "simulator", s(self.simulator.name()));
        e(&mut o, "num_learners", self.num_learners.to_string());
        let seeds: Vec<String> = self.seeds.iter().map(|x| x.to_string()).collect();
        e(&mut o, "seeds", format!("[{}]", seeds.join(", ")));
        e(&mut o, "eval_every", self.eval_every.to_string());
        e(&mut o, "eval_episodes", self.eval_episodes.to_string());
        e(&mut o, "results_dir", s(&self.results_dir));
        e(&mut o, "artifacts_dir", s(&self.artifacts_dir));
        e(&mut o, "checkpoint_every", self.checkpoint_every.to_string());
        e(&mut o, "checkpoint_dir", s(&self.checkpoint_dir));
        e(&mut o, "checkpoint_retain", self.checkpoint_retain.to_string());
        let t = &self.traffic;
        o.push_str("\n[traffic]\n");
        e(&mut o, "grid", t.grid.to_string());
        e(&mut o, "lane_len", t.lane_len.to_string());
        e(&mut o, "inflow_prob", t.inflow_prob.to_string());
        e(&mut o, "agent_intersection", t.agent_intersection.to_string());
        e(&mut o, "min_green", t.min_green.to_string());
        e(&mut o, "actuated_max_green", t.actuated_max_green.to_string());
        e(&mut o, "episode_len", t.episode_len.to_string());
        e(&mut o, "p_straight", t.p_straight.to_string());
        e(&mut o, "substeps", t.substeps.to_string());
        let w = &self.warehouse;
        o.push_str("\n[warehouse]\n");
        e(&mut o, "robots_per_side", w.robots_per_side.to_string());
        e(&mut o, "region", w.region.to_string());
        e(&mut o, "item_prob", w.item_prob.to_string());
        e(&mut o, "episode_len", w.episode_len.to_string());
        e(&mut o, "fixed_item_lifetime", w.fixed_item_lifetime.to_string());
        e(&mut o, "frame_stack", w.frame_stack.to_string());
        let p = &self.ppo;
        o.push_str("\n[ppo]\n");
        e(&mut o, "num_envs", p.num_envs.to_string());
        e(&mut o, "rollout_len", p.rollout_len.to_string());
        e(&mut o, "epochs", p.epochs.to_string());
        e(&mut o, "minibatch", p.minibatch.to_string());
        e(&mut o, "gamma", p.gamma.to_string());
        e(&mut o, "lam", p.lam.to_string());
        e(&mut o, "clip", p.clip.to_string());
        e(&mut o, "lr", p.lr.to_string());
        e(&mut o, "vf_coef", p.vf_coef.to_string());
        e(&mut o, "ent_coef", p.ent_coef.to_string());
        e(&mut o, "max_grad_norm", p.max_grad_norm.to_string());
        e(&mut o, "total_steps", p.total_steps.to_string());
        e(&mut o, "num_workers", p.num_workers.to_string());
        let a = &self.aip;
        o.push_str("\n[aip]\n");
        e(&mut o, "kind", s(a.kind.name()));
        e(&mut o, "dataset_size", a.dataset_size.to_string());
        e(&mut o, "eval_size", a.eval_size.to_string());
        e(&mut o, "train_epochs", a.train_epochs.to_string());
        e(&mut o, "batch", a.batch.to_string());
        e(&mut o, "lr", a.lr.to_string());
        e(&mut o, "seq_len", a.seq_len.to_string());
        e(&mut o, "fixed_p", a.fixed_p.to_string());
        e(&mut o, "use_full_alsh", a.use_full_alsh.to_string());
        o.push_str("\n[runtime]\n");
        e(&mut o, "backend", s(self.runtime.backend.name()));
        e(&mut o, "nn_workers", self.runtime.nn_workers.to_string());
        let d = &self.distributed;
        o.push_str("\n[distributed]\n");
        e(&mut o, "workers", d.workers.to_string());
        e(&mut o, "heartbeat_timeout_secs", d.heartbeat_timeout_secs.to_string());
        e(&mut o, "max_restarts", d.max_restarts.to_string());
        e(&mut o, "backoff_ms", d.backoff_ms.to_string());
        let h = &self.health;
        o.push_str("\n[health]\n");
        e(&mut o, "enabled", h.enabled.to_string());
        e(&mut o, "window", h.window.to_string());
        e(&mut o, "spike_factor", h.spike_factor.to_string());
        e(&mut o, "max_anomalies", h.max_anomalies.to_string());
        e(&mut o, "max_rollbacks", h.max_rollbacks.to_string());
        let v = &self.serve;
        o.push_str("\n[serve]\n");
        e(&mut o, "port", v.port.to_string());
        e(&mut o, "batch_window_ms", v.batch_window_ms.to_string());
        e(&mut o, "max_batch", v.max_batch.to_string());
        e(&mut o, "queue_capacity", v.queue_capacity.to_string());
        e(&mut o, "workers", v.workers.to_string());
        e(&mut o, "read_timeout_ms", v.read_timeout_ms.to_string());
        e(&mut o, "write_timeout_ms", v.write_timeout_ms.to_string());
        e(&mut o, "request_timeout_ms", v.request_timeout_ms.to_string());
        e(&mut o, "max_body_bytes", v.max_body_bytes.to_string());
        e(&mut o, "max_requests_per_conn", v.max_requests_per_conn.to_string());
        e(&mut o, "idle_timeout_ms", v.idle_timeout_ms.to_string());
        let runs: Vec<String> = v.runs.iter().map(|r| s(r)).collect();
        e(&mut o, "runs", format!("[{}]", runs.join(", ")));
        o
    }
}

const KNOWN_TABLES: &[&str] = &[
    "",
    "experiment",
    "traffic",
    "warehouse",
    "ppo",
    "aip",
    "runtime",
    "distributed",
    "health",
    "serve",
];

const KNOWN_KEYS: &[(&str, &str)] = &[
    ("experiment", "name"),
    ("experiment", "domain"),
    ("experiment", "simulator"),
    ("experiment", "num_learners"),
    ("experiment", "seeds"),
    ("experiment", "eval_every"),
    ("experiment", "eval_episodes"),
    ("experiment", "results_dir"),
    ("experiment", "artifacts_dir"),
    ("experiment", "checkpoint_every"),
    ("experiment", "checkpoint_dir"),
    ("experiment", "checkpoint_retain"),
    ("traffic", "grid"),
    ("traffic", "lane_len"),
    ("traffic", "inflow_prob"),
    ("traffic", "agent_intersection"),
    ("traffic", "min_green"),
    ("traffic", "actuated_max_green"),
    ("traffic", "episode_len"),
    ("traffic", "p_straight"),
    ("traffic", "substeps"),
    ("warehouse", "robots_per_side"),
    ("warehouse", "region"),
    ("warehouse", "item_prob"),
    ("warehouse", "episode_len"),
    ("warehouse", "fixed_item_lifetime"),
    ("warehouse", "frame_stack"),
    ("ppo", "num_envs"),
    ("ppo", "rollout_len"),
    ("ppo", "epochs"),
    ("ppo", "minibatch"),
    ("ppo", "gamma"),
    ("ppo", "lam"),
    ("ppo", "clip"),
    ("ppo", "lr"),
    ("ppo", "vf_coef"),
    ("ppo", "ent_coef"),
    ("ppo", "max_grad_norm"),
    ("ppo", "total_steps"),
    ("ppo", "num_workers"),
    ("aip", "kind"),
    ("aip", "dataset_size"),
    ("aip", "eval_size"),
    ("aip", "train_epochs"),
    ("aip", "batch"),
    ("aip", "lr"),
    ("aip", "seq_len"),
    ("aip", "fixed_p"),
    ("aip", "use_full_alsh"),
    ("runtime", "backend"),
    ("runtime", "nn_workers"),
    ("distributed", "workers"),
    ("distributed", "heartbeat_timeout_secs"),
    ("distributed", "max_restarts"),
    ("distributed", "backoff_ms"),
    ("health", "enabled"),
    ("health", "window"),
    ("health", "spike_factor"),
    ("health", "max_anomalies"),
    ("health", "max_rollbacks"),
    ("serve", "port"),
    ("serve", "batch_window_ms"),
    ("serve", "max_batch"),
    ("serve", "queue_capacity"),
    ("serve", "workers"),
    ("serve", "read_timeout_ms"),
    ("serve", "write_timeout_ms"),
    ("serve", "request_timeout_ms"),
    ("serve", "max_body_bytes"),
    ("serve", "max_requests_per_conn"),
    ("serve", "idle_timeout_ms"),
    ("serve", "runs"),
];

fn check_known_keys(doc: &Document) -> Result<()> {
    for (table, keys) in &doc.tables {
        if !KNOWN_TABLES.contains(&table.as_str()) {
            bail!("unknown config table [{table}]");
        }
        for key in keys.keys() {
            if table.is_empty() {
                bail!("top-level key '{key}' not allowed; use a [table]");
            }
            if !KNOWN_KEYS.contains(&(table.as_str(), key.as_str())) {
                bail!("unknown config key [{table}].{key}");
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_full_config() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            [experiment]
            name = "fig5"
            domain = "warehouse"
            simulator = "gs"
            seeds = [1, 2, 3, 4, 5]
            eval_every = 2048

            [warehouse]
            item_prob = 0.02
            frame_stack = 8

            [ppo]
            total_steps = 100000
            lr = 2.5e-4

            [aip]
            kind = "fixed"
            fixed_p = -1.0
            "#,
        )
        .unwrap();
        assert_eq!(cfg.name, "fig5");
        assert_eq!(cfg.domain, DomainKind::Warehouse);
        assert_eq!(cfg.simulator, SimulatorKind::Gs);
        assert_eq!(cfg.seeds.len(), 5);
        assert_eq!(cfg.warehouse.frame_stack, 8);
        assert_eq!(cfg.ppo.total_steps, 100_000);
        assert_eq!(cfg.aip.kind, AipKind::Fixed);
        assert!(cfg.aip.fixed_p < 0.0);
    }

    #[test]
    fn num_learners_knob_parses_defaults_and_bounds() {
        assert_eq!(ExperimentConfig::default().num_learners, 1, "single learner by default");
        let cfg = ExperimentConfig::from_toml("[experiment]\nnum_learners = 4").unwrap();
        assert_eq!(cfg.num_learners, 4);
        // 0 learners is meaningless; negative wraps through `as usize`.
        assert!(ExperimentConfig::from_toml("[experiment]\nnum_learners = 0").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\nnum_learners = -1").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\nnum_learners = 65").is_err());
    }

    #[test]
    fn num_workers_knob_parses_and_defaults() {
        let cfg = ExperimentConfig::from_toml("[ppo]\nnum_workers = 4").unwrap();
        assert_eq!(cfg.ppo.num_workers, 4);
        assert_eq!(ExperimentConfig::default().ppo.num_workers, 1, "serial by default");
        // 0 = auto (resolved to the core count at env construction).
        let auto = ExperimentConfig::from_toml("[ppo]\nnum_workers = 0").unwrap();
        assert_eq!(auto.ppo.num_workers, 0);
    }

    #[test]
    fn nn_workers_knob_parses_and_defaults() {
        assert_eq!(ExperimentConfig::default().runtime.nn_workers, 1, "serial by default");
        let cfg = ExperimentConfig::from_toml("[runtime]\nnn_workers = 4").unwrap();
        assert_eq!(cfg.runtime.nn_workers, 4);
        // 0 = auto (one NN worker per core, resolved via WorkerPlan).
        let auto = ExperimentConfig::from_toml("[runtime]\nnn_workers = 0").unwrap();
        assert_eq!(auto.runtime.nn_workers, 0);
        // Negative values would wrap through `as usize`; validation stops
        // them before anything tries to size a pool.
        assert!(ExperimentConfig::from_toml("[runtime]\nnn_workers = -1").is_err());
        assert!(ExperimentConfig::from_toml("[ppo]\nnum_workers = -2").is_err());
    }

    #[test]
    fn backend_knob_parses_and_defaults_to_auto() {
        assert_eq!(ExperimentConfig::default().runtime.backend, BackendKind::Auto);
        let cfg = ExperimentConfig::from_toml("[runtime]\nbackend = \"native\"").unwrap();
        assert_eq!(cfg.runtime.backend, BackendKind::Native);
        let cfg = ExperimentConfig::from_toml("[runtime]\nbackend = \"pjrt\"").unwrap();
        assert_eq!(cfg.runtime.backend, BackendKind::Pjrt);
        assert!(ExperimentConfig::from_toml("[runtime]\nbackend = \"tpu\"").is_err());
        assert!(ExperimentConfig::from_toml("[runtime]\nengine = \"native\"").is_err());
    }

    #[test]
    fn checkpoint_knobs_parse_and_default_off() {
        let d = ExperimentConfig::default();
        assert_eq!(d.checkpoint_every, 0, "checkpointing off by default");
        assert_eq!(d.checkpoint_dir, "checkpoints");
        let cfg = ExperimentConfig::from_toml(
            "[experiment]\ncheckpoint_every = 8192\ncheckpoint_dir = \"/tmp/ck\"",
        )
        .unwrap();
        assert_eq!(cfg.checkpoint_every, 8192);
        assert_eq!(cfg.checkpoint_dir, "/tmp/ck");
    }

    #[test]
    fn checkpoint_retain_parses_and_rejects_zero() {
        assert_eq!(ExperimentConfig::default().checkpoint_retain, 3, "historical default");
        let cfg = ExperimentConfig::from_toml("[experiment]\ncheckpoint_retain = 5").unwrap();
        assert_eq!(cfg.checkpoint_retain, 5);
        // retain = 0 would delete every checkpoint right after writing it;
        // negative wraps through `as usize`.
        assert!(ExperimentConfig::from_toml("[experiment]\ncheckpoint_retain = 0").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\ncheckpoint_retain = -1").is_err());
    }

    #[test]
    fn distributed_knobs_parse_and_bound() {
        let d = ExperimentConfig::default().distributed;
        assert_eq!(d.workers, 2);
        assert_eq!(d.max_restarts, 2);
        let cfg = ExperimentConfig::from_toml(
            "[distributed]\nworkers = 4\nheartbeat_timeout_secs = 30.5\nmax_restarts = 0\n\
             backoff_ms = 100",
        )
        .unwrap();
        assert_eq!(cfg.distributed.workers, 4);
        assert_eq!(cfg.distributed.heartbeat_timeout_secs, 30.5);
        assert_eq!(cfg.distributed.max_restarts, 0, "0 = never restart, fail the shard");
        assert_eq!(cfg.distributed.backoff_ms, 100);
        // Whole-number timeouts are the common spelling.
        let cfg =
            ExperimentConfig::from_toml("[distributed]\nheartbeat_timeout_secs = 60").unwrap();
        assert_eq!(cfg.distributed.heartbeat_timeout_secs, 60.0);
        assert!(ExperimentConfig::from_toml("[distributed]\nworkers = 0").is_err());
        assert!(ExperimentConfig::from_toml("[distributed]\nworkers = 65").is_err());
        assert!(ExperimentConfig::from_toml("[distributed]\nheartbeat_timeout_secs = 0").is_err());
        assert!(ExperimentConfig::from_toml("[distributed]\nmax_restarts = -1").is_err());
        assert!(ExperimentConfig::from_toml("[distributed]\nbackoff_ms = 600001").is_err());
    }

    #[test]
    fn health_knobs_parse_and_bound() {
        let h = ExperimentConfig::default().health;
        assert!(h.enabled, "guard on by default (checks are read-only)");
        assert_eq!(h.window, 8);
        assert_eq!(h.max_rollbacks, 2);
        let cfg = ExperimentConfig::from_toml(
            "[health]\nenabled = false\nwindow = 4\nspike_factor = 25.5\nmax_anomalies = 1\n\
             max_rollbacks = 7",
        )
        .unwrap();
        assert!(!cfg.health.enabled);
        assert_eq!(cfg.health.window, 4);
        assert_eq!(cfg.health.spike_factor, 25.5);
        assert_eq!(cfg.health.max_anomalies, 1);
        assert_eq!(cfg.health.max_rollbacks, 7);
        assert!(ExperimentConfig::from_toml("[health]\nwindow = 0").is_err());
        assert!(ExperimentConfig::from_toml("[health]\nspike_factor = 1.0").is_err());
        assert!(ExperimentConfig::from_toml("[health]\nmax_anomalies = 0").is_err());
    }

    #[test]
    fn health_max_rollbacks_zero_rejected_naming_the_off_switch() {
        let err =
            ExperimentConfig::from_toml("[health]\nmax_rollbacks = 0").unwrap_err().to_string();
        assert!(err.contains("[health] max_rollbacks"), "{err}");
        assert!(err.contains("enabled = false"), "error must point at the off switch: {err}");
        assert!(ExperimentConfig::from_toml("[health]\nmax_rollbacks = -1").is_err());
    }

    #[test]
    fn distributed_cross_field_validation_names_both_keys() {
        // Distributed without checkpointing: the restart protocol has
        // nothing to resume from.
        let cfg = ExperimentConfig::from_toml("[experiment]\nnum_learners = 4").unwrap();
        assert_eq!(cfg.checkpoint_every, 0);
        let err = cfg.validate_distributed(2).unwrap_err().to_string();
        assert!(err.contains("checkpoint_every"), "{err}");
        assert!(err.contains("[distributed] workers"), "{err}");
        // More workers than learners: some shard would be empty.
        let cfg = ExperimentConfig::from_toml(
            "[experiment]\nnum_learners = 2\ncheckpoint_every = 2048",
        )
        .unwrap();
        let err = cfg.validate_distributed(3).unwrap_err().to_string();
        assert!(err.contains("[distributed] workers = 3"), "{err}");
        assert!(err.contains("num_learners = 2"), "{err}");
        // The valid shape passes.
        cfg.validate_distributed(2).unwrap();
        cfg.validate_distributed(1).unwrap();
    }

    #[test]
    fn toml_round_trip_is_exact() {
        // The distributed coordinator ships its effective config to workers
        // via to_toml_string; every field must survive the round trip so
        // coordinator and worker build bitwise-identical runs. Use awkward
        // values: non-representable decimals, scientific-notation floats,
        // whole floats (printed as ints), multiple seeds.
        let mut cfg = ExperimentConfig::from_toml(
            r#"
            [experiment]
            name = "fig5"
            domain = "warehouse"
            simulator = "f-ials"
            num_learners = 3
            seeds = [7, 11]
            checkpoint_every = 4096
            checkpoint_retain = 5

            [warehouse]
            item_prob = 0.02

            [ppo]
            lr = 2.5e-4
            gamma = 1.0

            [aip]
            kind = "fixed"
            fixed_p = 0.1

            [distributed]
            workers = 3
            heartbeat_timeout_secs = 45.25

            [health]
            enabled = false
            spike_factor = 12.5
            max_rollbacks = 4
            "#,
        )
        .unwrap();
        cfg.runtime.backend = BackendKind::Native;
        let text = cfg.to_toml_string();
        let back = ExperimentConfig::from_toml(&text).unwrap();
        assert_eq!(format!("{cfg:?}"), format!("{back:?}"), "round trip drifted:\n{text}");
        // And the defaults round-trip too.
        let d = ExperimentConfig::default();
        let back = ExperimentConfig::from_toml(&d.to_toml_string()).unwrap();
        assert_eq!(format!("{d:?}"), format!("{back:?}"));
    }

    #[test]
    fn unknown_key_rejected() {
        let err = ExperimentConfig::from_toml("[ppo]\nlearning_rate = 0.1").unwrap_err();
        assert!(err.to_string().contains("unknown config key"));
    }

    #[test]
    fn unknown_table_rejected() {
        assert!(ExperimentConfig::from_toml("[nope]\nx = 1").is_err());
    }

    #[test]
    fn bad_minibatch_rejected() {
        let err = ExperimentConfig::from_toml("[ppo]\nminibatch = 1000").unwrap_err();
        assert!(err.to_string().contains("not divisible"));
    }

    #[test]
    fn bad_enum_rejected() {
        assert!(ExperimentConfig::from_toml("[experiment]\ndomain = \"atari\"").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\nsimulator = \"magic\"").is_err());
        assert!(ExperimentConfig::from_toml("[aip]\nkind = \"oracle\"").is_err());
    }
}
