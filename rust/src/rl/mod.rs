//! The RL stack: PPO (Schulman et al. 2017) orchestrated from Rust, with
//! all neural computation in AOT-compiled XLA artifacts.
//!
//! Split of responsibilities:
//! * [`gae`] — generalized advantage estimation (pure Rust, O(T·B)).
//! * [`rollout`] — on-policy experience storage in flat, minibatch-ready
//!   layout.
//! * [`policy`] — handle around the policy model's artifacts (batched
//!   forward, single forward, minibatch update).
//! * [`ppo`] — the trainer: collect → GAE → epochs of minibatch updates.

pub mod gae;
pub mod policy;
pub mod ppo;
pub mod rollout;

pub use gae::compute_gae;
pub use policy::Policy;
pub use ppo::{PpoStats, PpoTrainer};
pub use rollout::RolloutBuffer;
