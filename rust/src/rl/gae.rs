//! Generalized advantage estimation (Schulman et al. 2016).
//!
//! Time-major layout: index `t * B + i` for step `t`, environment `i`.
//! `dones[t*B+i]` marks that env `i`'s episode ended *at* step `t` (the
//! value bootstrap across that boundary is cut).

/// Compute advantages and returns in place.
///
/// * `rewards`, `dones`: `[T*B]`
/// * `values`: `[T*B]` — V(s_t) under the rollout policy
/// * `bootstrap`: `[B]` — V(s_T) of the observation after the last step
/// * outputs `advantages`, `returns_`: `[T*B]`
#[allow(clippy::too_many_arguments)]
pub fn compute_gae(
    rewards: &[f32],
    dones: &[bool],
    values: &[f32],
    bootstrap: &[f32],
    gamma: f32,
    lam: f32,
    advantages: &mut [f32],
    returns_: &mut [f32],
) {
    let b = bootstrap.len();
    assert!(b > 0);
    let t_len = rewards.len() / b;
    assert_eq!(rewards.len(), t_len * b);
    assert_eq!(dones.len(), t_len * b);
    assert_eq!(values.len(), t_len * b);
    assert_eq!(advantages.len(), t_len * b);
    assert_eq!(returns_.len(), t_len * b);

    for i in 0..b {
        let mut gae = 0.0f32;
        for t in (0..t_len).rev() {
            let idx = t * b + i;
            let not_done = if dones[idx] { 0.0 } else { 1.0 };
            let next_value = if t + 1 < t_len { values[(t + 1) * b + i] } else { bootstrap[i] };
            let delta = rewards[idx] + gamma * next_value * not_done - values[idx];
            gae = delta + gamma * lam * not_done * gae;
            advantages[idx] = gae;
            returns_[idx] = gae + values[idx];
        }
    }
}

/// Normalize advantages to zero mean / unit std (standard PPO practice).
pub fn normalize(advantages: &mut [f32]) {
    let n = advantages.len() as f32;
    let mean = advantages.iter().sum::<f32>() / n;
    let var = advantages.iter().map(|a| (a - mean).powi(2)).sum::<f32>() / n;
    let std = var.sqrt().max(1e-8);
    for a in advantages.iter_mut() {
        *a = (*a - mean) / std;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_step_single_env() {
        // adv = r + gamma*V' - V
        let mut adv = [0.0f32];
        let mut ret = [0.0f32];
        compute_gae(&[1.0], &[false], &[0.5], &[2.0], 0.9, 0.95, &mut adv, &mut ret);
        let delta = 1.0 + 0.9 * 2.0 - 0.5;
        assert!((adv[0] - delta).abs() < 1e-6);
        assert!((ret[0] - (delta + 0.5)).abs() < 1e-6);
    }

    #[test]
    fn done_cuts_bootstrap() {
        let mut adv = [0.0f32];
        let mut ret = [0.0f32];
        compute_gae(&[1.0], &[true], &[0.5], &[100.0], 0.9, 0.95, &mut adv, &mut ret);
        assert!((adv[0] - (1.0 - 0.5)).abs() < 1e-6, "bootstrap must be ignored at done");
    }

    #[test]
    fn matches_manual_two_steps() {
        // T=2, B=1, no dones.
        let (g, l) = (0.99f32, 0.95f32);
        let rewards = [1.0f32, 2.0];
        let values = [0.3f32, 0.6];
        let boot = [0.9f32];
        let mut adv = [0.0f32; 2];
        let mut ret = [0.0f32; 2];
        compute_gae(&rewards, &[false, false], &values, &boot, g, l, &mut adv, &mut ret);
        let d1 = 2.0 + g * 0.9 - 0.6;
        let d0 = 1.0 + g * 0.6 - 0.3;
        assert!((adv[1] - d1).abs() < 1e-6);
        assert!((adv[0] - (d0 + g * l * d1)).abs() < 1e-5);
    }

    #[test]
    fn gamma_zero_is_td_error() {
        let rewards = [1.0f32, 0.5, 2.0];
        let values = [0.2f32, 0.4, 0.1];
        let mut adv = [0.0f32; 3];
        let mut ret = [0.0f32; 3];
        compute_gae(
            &rewards,
            &[false; 3],
            &values,
            &[0.0],
            0.0,
            0.95,
            &mut adv,
            &mut ret,
        );
        for t in 0..3 {
            assert!((adv[t] - (rewards[t] - values[t])).abs() < 1e-6);
        }
    }

    #[test]
    fn multi_env_layout_independent_streams() {
        // Two envs with identical data must produce identical advantages.
        let b = 2;
        let t_len = 4;
        let mut rewards = vec![0.0f32; t_len * b];
        let mut values = vec![0.0f32; t_len * b];
        let mut dones = vec![false; t_len * b];
        for t in 0..t_len {
            for i in 0..b {
                rewards[t * b + i] = (t as f32) * 0.5;
                values[t * b + i] = 0.1 * t as f32;
            }
        }
        dones[1 * b] = true; // env0 episode ends at t=1
        dones[1 * b + 1] = true;
        let mut adv = vec![0.0f32; t_len * b];
        let mut ret = vec![0.0f32; t_len * b];
        compute_gae(&rewards, &dones, &values, &[0.7, 0.7], 0.99, 0.95, &mut adv, &mut ret);
        for t in 0..t_len {
            assert_eq!(adv[t * b], adv[t * b + 1], "env streams must be independent");
        }
    }

    #[test]
    fn normalize_zero_mean_unit_std() {
        let mut xs = vec![1.0f32, 2.0, 3.0, 4.0, 5.0];
        normalize(&mut xs);
        let mean: f32 = xs.iter().sum::<f32>() / 5.0;
        let var: f32 = xs.iter().map(|x| x * x).sum::<f32>() / 5.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-5);
    }
}
