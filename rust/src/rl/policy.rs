//! Handle around one policy model's compiled artifacts: batched forward
//! (rollout), single forward (evaluation) and the PPO minibatch update.

use crate::config::PpoConfig;
use crate::nn::ParamStore;
use crate::runtime::{DataArg, Runtime};
use crate::util::stats::log_prob_from_logits;
use crate::util::Pcg32;
use crate::Result;
use anyhow::Context;
use std::rc::Rc;

pub struct Policy {
    rt: Rc<Runtime>,
    pub store: ParamStore,
    pub model: String,
    fwd_b: String,
    fwd_1: String,
    update: String,
    update_fused: Option<String>,
    pub batch: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
    pub minibatch: usize,
    /// (epochs, N) geometry of the fused update artifact, if present.
    pub fused_geom: Option<(usize, usize)>,
    /// Reusable batch-1 scratch for the GS evaluation path
    /// ([`Policy::forward1`] — no allocation per evaluation step).
    eval_logits: Vec<f32>,
    eval_value: [f32; 1],
}

impl Policy {
    pub fn new(rt: Rc<Runtime>, model: &str, batch: usize) -> Result<Policy> {
        let store = rt.load_store(model)?;
        let fwd_b = format!("{model}_fwd_b{batch}");
        let fwd_1 = format!("{model}_fwd_b1");
        let update = format!("{model}_update");
        let art = rt
            .manifest
            .artifact(&fwd_b)
            .with_context(|| format!("no forward artifact for {model} at batch {batch}"))?;
        let obs = art.data_inputs().find(|t| t.name == "obs").context("obs input")?;
        let obs_dim = *obs.shape.last().unwrap();
        let logits = art.data_outputs().find(|t| t.name == "logits").context("logits")?;
        let act_dim = *logits.shape.last().unwrap();
        let upd = rt.manifest.artifact(&update)?;
        let mb_obs = upd.data_inputs().find(|t| t.name == "obs").context("update obs")?;
        let minibatch = mb_obs.shape[0];
        // Prefer the fused whole-phase update when the artifact exists
        // (one PJRT call per PPO iteration instead of epochs×minibatches).
        let fused_name = format!("{model}_update_fused");
        let (update_fused, fused_geom) = match rt.manifest.artifact(&fused_name) {
            Ok(art) => {
                let perm = art
                    .data_inputs()
                    .find(|t| t.name == "perm")
                    .context("fused update perm input")?;
                (Some(fused_name), Some((perm.shape[0], perm.shape[1])))
            }
            Err(_) => (None, None),
        };
        Ok(Policy {
            rt,
            store,
            model: model.to_string(),
            fwd_b,
            fwd_1,
            update,
            update_fused,
            batch,
            obs_dim,
            act_dim,
            minibatch,
            fused_geom,
            eval_logits: vec![0.0; act_dim],
            eval_value: [0.0],
        })
    }

    /// Fresh per-seed initialization (keeps the artifact, re-rolls weights).
    pub fn reinit(&mut self, seed: u64) -> Result<()> {
        let spec = self.rt.manifest.model(&self.model)?.clone();
        self.store.reinit(&spec, seed);
        Ok(())
    }

    /// Batched forward: `obs` is `[batch * obs_dim]`. Returns
    /// (logits `[batch * act_dim]`, values `[batch]`). Allocating wrapper
    /// around [`Policy::forward_into`].
    pub fn forward(&mut self, obs: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut logits = vec![0.0; self.batch * self.act_dim];
        let mut values = vec![0.0; self.batch];
        self.forward_into(obs, &mut logits, &mut values)?;
        Ok((logits, values))
    }

    /// Batched forward writing into caller-provided scratch (the rollout
    /// hot path — no allocation per step). `logits` is
    /// `[batch * act_dim]`, `values` is `[batch]`. One call per step; on
    /// the native backend with `[runtime] nn_workers > 1` the rows of this
    /// call partition over the shared compute pool (each worker writes its
    /// disjoint output band, so results are bitwise identical to serial —
    /// the same row-independence that lets the fused IALS step run the
    /// *AIP* forward inside the sim shards' dispatch; the policy forward
    /// stays coordinator-batched because action sampling consumes one RNG
    /// stream in env order).
    pub fn forward_into(
        &mut self,
        obs: &[f32],
        logits: &mut [f32],
        values: &mut [f32],
    ) -> Result<()> {
        self.rt.call_into(
            &self.fwd_b,
            &mut self.store,
            &[DataArg::F32(obs)],
            &mut [logits, values],
        )
    }

    /// Single-observation forward (GS evaluation path). Returns the logits
    /// as a borrow of the reusable eval scratch plus the value estimate —
    /// like the batched path, no allocation per call. Bitwise identical to
    /// row `i` of [`Policy::forward_into`] on the same observation (rows
    /// are independent in the native forward kernels), so eval metrics can
    /// never drift from the training pipeline — pinned by
    /// `rust/tests/eval_parity.rs`.
    pub fn forward1(&mut self, obs: &[f32]) -> Result<(&[f32], f32)> {
        let Policy { rt, store, fwd_1, eval_logits, eval_value, .. } = self;
        rt.call_into(
            fwd_1,
            store,
            &[DataArg::F32(obs)],
            &mut [eval_logits.as_mut_slice(), eval_value.as_mut_slice()],
        )?;
        Ok((self.eval_logits.as_slice(), self.eval_value[0]))
    }

    /// Sample actions (and log-probs) from batched logits.
    pub fn sample_actions(
        &self,
        logits: &[f32],
        rng: &mut Pcg32,
        actions: &mut [usize],
        log_probs: &mut [f32],
    ) {
        let a = self.act_dim;
        for i in 0..actions.len() {
            let row = &logits[i * a..(i + 1) * a];
            let act = rng.categorical_from_logits(row);
            actions[i] = act;
            log_probs[i] = log_prob_from_logits(row, act);
        }
    }

    /// One PPO minibatch update; returns stats
    /// `[total, pg_loss, v_loss, entropy, approx_kl, grad_norm]` (the
    /// last is the pre-clip global gradient norm).
    #[allow(clippy::too_many_arguments)]
    pub fn update_minibatch(
        &mut self,
        cfg: &PpoConfig,
        obs: &[f32],
        actions: &[i32],
        advantages: &[f32],
        returns_: &[f32],
        old_logp: &[f32],
    ) -> Result<[f32; 6]> {
        let lr = [cfg.lr];
        let clip = [cfg.clip];
        let vf = [cfg.vf_coef];
        let ent = [cfg.ent_coef];
        let mgn = [cfg.max_grad_norm];
        let mut stats = [0.0f32; 6];
        self.rt.call_into(
            &self.update,
            &mut self.store,
            &[
                DataArg::F32(&lr),
                DataArg::F32(&clip),
                DataArg::F32(&vf),
                DataArg::F32(&ent),
                DataArg::F32(&mgn),
                DataArg::F32(obs),
                DataArg::I32(actions),
                DataArg::F32(advantages),
                DataArg::F32(returns_),
                DataArg::F32(old_logp),
            ],
            &mut [stats.as_mut_slice()],
        )?;
        Ok(stats)
    }

    /// The fused whole-phase PPO update: all epochs and minibatches in one
    /// compiled call. `perm` is `[epochs * n]` int32 shuffled indices.
    /// Returns averaged stats. Errors if the fused artifact is absent.
    #[allow(clippy::too_many_arguments)]
    pub fn update_fused(
        &mut self,
        cfg: &PpoConfig,
        perm: &[i32],
        obs: &[f32],
        actions: &[i32],
        advantages: &[f32],
        returns_: &[f32],
        old_logp: &[f32],
    ) -> Result<[f32; 6]> {
        // Borrow (don't clone) the artifact name: this is the steady-state
        // training path and must stay allocation-free.
        let Policy { rt, store, update_fused, model, .. } = self;
        let name = update_fused
            .as_deref()
            .ok_or_else(|| anyhow::anyhow!("no fused update artifact for {model}"))?;
        let lr = [cfg.lr];
        let clip = [cfg.clip];
        let vf = [cfg.vf_coef];
        let ent = [cfg.ent_coef];
        let mgn = [cfg.max_grad_norm];
        let mut stats = [0.0f32; 6];
        rt.call_into(
            name,
            store,
            &[
                DataArg::F32(&lr),
                DataArg::F32(&clip),
                DataArg::F32(&vf),
                DataArg::F32(&ent),
                DataArg::F32(&mgn),
                DataArg::I32(perm),
                DataArg::F32(obs),
                DataArg::I32(actions),
                DataArg::F32(advantages),
                DataArg::F32(returns_),
                DataArg::F32(old_logp),
            ],
            &mut [stats.as_mut_slice()],
        )?;
        Ok(stats)
    }
}
