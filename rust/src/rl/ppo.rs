//! The PPO trainer: collect a rollout from any [`VecEnv`], run GAE, then
//! several epochs of shuffled minibatch updates through the compiled
//! `*_update` artifact.

use super::gae::{compute_gae, normalize};
use super::policy::Policy;
use super::rollout::RolloutBuffer;
use crate::config::PpoConfig;
use crate::core::VecEnv;
use crate::util::{Pcg32, StateReader, StateWriter};
use crate::Result;

/// Aggregated statistics of one `train_iteration`.
#[derive(Debug, Clone, Copy, Default)]
pub struct PpoStats {
    pub total_loss: f32,
    pub pg_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub approx_kl: f32,
    /// Mean pre-clip global gradient norm across the iteration's
    /// minibatch updates — the health guard's spike-detector input.
    pub grad_norm: f32,
    /// Mean per-step environment reward in the collected rollout.
    pub rollout_reward: f32,
    pub episodes: usize,
}

pub struct PpoTrainer {
    pub cfg: PpoConfig,
    pub buffer: RolloutBuffer,
    rng: Pcg32,
    // reusable minibatch scratch
    mb_obs: Vec<f32>,
    mb_act: Vec<i32>,
    mb_adv: Vec<f32>,
    mb_ret: Vec<f32>,
    mb_lp: Vec<f32>,
    order: Vec<usize>,
    /// Reusable `[epochs * n]` shuffled-index buffer for the fused
    /// whole-phase update (no per-iteration allocation).
    perm: Vec<i32>,
    actions_scratch: Vec<usize>,
    obs_scratch: Vec<f32>,
    // forward-pass scratch (sized on first collect, when act_dim is known)
    logits_scratch: Vec<f32>,
    values_scratch: Vec<f32>,
}

impl PpoTrainer {
    pub fn new(cfg: &PpoConfig, obs_dim: usize, seed: u64) -> PpoTrainer {
        let buffer = RolloutBuffer::new(cfg.rollout_len, cfg.num_envs, obs_dim);
        let mb = cfg.minibatch;
        PpoTrainer {
            cfg: cfg.clone(),
            buffer,
            rng: Pcg32::new(seed, 4242),
            mb_obs: vec![0.0; mb * obs_dim],
            mb_act: vec![0; mb],
            mb_adv: vec![0.0; mb],
            mb_ret: vec![0.0; mb],
            mb_lp: vec![0.0; mb],
            order: (0..cfg.rollout_len * cfg.num_envs).collect(),
            perm: Vec::with_capacity(cfg.epochs * cfg.rollout_len * cfg.num_envs),
            actions_scratch: vec![0; cfg.num_envs],
            obs_scratch: vec![0.0; cfg.num_envs * obs_dim],
            logits_scratch: Vec::new(),
            values_scratch: vec![0.0; cfg.num_envs],
        }
    }

    /// Collect one rollout (T steps of B envs) into the buffer. For a
    /// sharded env (`core::shard`), observation and env stepping fan out
    /// over the worker pool — a fused IALS additionally runs its AIP
    /// forward inside the step dispatch itself (`ials::IalsVecEnv`). The
    /// policy forward stays one batched call issued from this thread
    /// (its rows fan out over the same pool with `[runtime] nn_workers >
    /// 1`): `sample_actions` consumes a single RNG stream in env order,
    /// so splitting the forward across shards would change the action
    /// stream — the one part of the step that is serial by semantics, not
    /// by engine limitation. All buffers (rollout storage and forward
    /// scratch) are reused across steps and iterations: no allocation on
    /// this path.
    pub fn collect(&mut self, env: &mut dyn VecEnv, policy: &mut Policy) -> Result<()> {
        let b = self.cfg.num_envs;
        debug_assert_eq!(env.num_envs(), b);
        debug_assert_eq!(env.obs_dim(), self.buffer.obs_dim);
        if self.logits_scratch.len() != b * policy.act_dim {
            self.logits_scratch.resize(b * policy.act_dim, 0.0);
        }
        for t in 0..self.cfg.rollout_len {
            env.observe_all(self.buffer.obs_at_mut(t));
            let obs_slab = {
                let w = b * self.buffer.obs_dim;
                &self.buffer.obs[t * w..(t + 1) * w]
            };
            policy.forward_into(obs_slab, &mut self.logits_scratch, &mut self.values_scratch)?;
            policy.sample_actions(
                &self.logits_scratch,
                &mut self.rng,
                &mut self.actions_scratch,
                &mut self.buffer.log_probs[t * b..(t + 1) * b],
            );
            for i in 0..b {
                self.buffer.actions[t * b + i] = self.actions_scratch[i] as i32;
                self.buffer.values[t * b + i] = self.values_scratch[i];
            }
            env.step_all(
                &self.actions_scratch,
                &mut self.buffer.rewards[t * b..(t + 1) * b],
                &mut self.buffer.dones[t * b..(t + 1) * b],
            );
        }
        // Bootstrap values for the observation after the last step.
        env.observe_all(&mut self.obs_scratch);
        policy.forward_into(&self.obs_scratch, &mut self.logits_scratch, &mut self.values_scratch)?;
        self.buffer.bootstrap.copy_from_slice(&self.values_scratch);
        Ok(())
    }

    /// GAE + the update phase. Uses the fused whole-phase artifact when the
    /// geometry matches (one backend call per iteration — see PERF.md);
    /// otherwise falls back to the per-minibatch loop. On the native
    /// backend the update itself is data-parallel over `nn_workers` with
    /// bitwise-deterministic ordered gradient reduction.
    pub fn update(&mut self, policy: &mut Policy) -> Result<PpoStats> {
        let cfg = &self.cfg;
        compute_gae(
            &self.buffer.rewards,
            &self.buffer.dones,
            &self.buffer.values,
            &self.buffer.bootstrap,
            cfg.gamma,
            cfg.lam,
            &mut self.buffer.advantages,
            &mut self.buffer.returns_,
        );
        normalize(&mut self.buffer.advantages);

        let n = self.buffer.total();
        if policy.fused_geom == Some((cfg.epochs, n)) && cfg.minibatch == policy.minibatch {
            // Fused path: shuffle per epoch on the Rust side, one call
            // (reusing the preallocated perm buffer — steady-state
            // zero-allocation, like the rest of the update phase).
            self.perm.clear();
            for _ in 0..cfg.epochs {
                self.rng.shuffle(&mut self.order);
                self.perm.extend(self.order.iter().map(|&k| k as i32));
            }
            let stats = policy.update_fused(
                cfg,
                &self.perm,
                &self.buffer.obs,
                &self.buffer.actions,
                &self.buffer.advantages,
                &self.buffer.returns_,
                &self.buffer.log_probs,
            )?;
            let (rollout_reward, episodes) = self.buffer.reward_stats();
            return Ok(PpoStats {
                total_loss: stats[0],
                pg_loss: stats[1],
                v_loss: stats[2],
                entropy: stats[3],
                approx_kl: stats[4],
                grad_norm: stats[5],
                rollout_reward,
                episodes,
            });
        }

        let mut agg = [0.0f64; 6];
        let mut updates = 0usize;
        for _ in 0..cfg.epochs {
            self.rng.shuffle(&mut self.order);
            for chunk in self.order.chunks_exact(cfg.minibatch) {
                self.buffer.gather(
                    chunk,
                    &mut self.mb_obs,
                    &mut self.mb_act,
                    &mut self.mb_adv,
                    &mut self.mb_ret,
                    &mut self.mb_lp,
                );
                let stats = policy.update_minibatch(
                    cfg,
                    &self.mb_obs,
                    &self.mb_act,
                    &self.mb_adv,
                    &self.mb_ret,
                    &self.mb_lp,
                )?;
                for (a, s) in agg.iter_mut().zip(stats) {
                    *a += s as f64;
                }
                updates += 1;
            }
        }
        let n = updates.max(1) as f64;
        let (rollout_reward, episodes) = self.buffer.reward_stats();
        Ok(PpoStats {
            total_loss: (agg[0] / n) as f32,
            pg_loss: (agg[1] / n) as f32,
            v_loss: (agg[2] / n) as f32,
            entropy: (agg[3] / n) as f32,
            approx_kl: (agg[4] / n) as f32,
            grad_norm: (agg[5] / n) as f32,
            rollout_reward,
            episodes,
        })
    }

    /// One full PPO iteration: collect + update.
    pub fn train_iteration(
        &mut self,
        env: &mut dyn VecEnv,
        policy: &mut Policy,
    ) -> Result<PpoStats> {
        self.collect(env, policy)?;
        self.update(policy)
    }

    /// Environment steps consumed per iteration.
    pub fn steps_per_iteration(&self) -> usize {
        self.cfg.num_envs * self.cfg.rollout_len
    }

    /// Serialize the trainer's mutable cross-iteration state for
    /// checkpointing: the action/shuffle RNG and the persistent `order`
    /// permutation (shuffled in place each epoch, so its current
    /// arrangement feeds the next iteration's shuffles). Rollout and
    /// minibatch buffers are refilled from scratch every iteration.
    pub fn save_state(&self, out: &mut StateWriter) {
        let (s, inc) = self.rng.state();
        out.u64(s);
        out.u64(inc);
        out.u64s(&self.order.iter().map(|&k| k as u64).collect::<Vec<u64>>());
    }

    /// Restore state written by [`PpoTrainer::save_state`].
    pub fn load_state(&mut self, r: &mut StateReader) -> Result<()> {
        let (s, inc) = (r.u64()?, r.u64()?);
        let order = r.u64s()?;
        anyhow::ensure!(
            order.len() == self.order.len(),
            "trainer snapshot has {} order entries, expected {}",
            order.len(),
            self.order.len()
        );
        let n = self.order.len();
        let mut seen = vec![false; n];
        for (dst, &k) in self.order.iter_mut().zip(&order) {
            let k = usize::try_from(k).ok().filter(|&k| k < n);
            let k = k.ok_or_else(|| anyhow::anyhow!("corrupt state: order entry out of range"))?;
            anyhow::ensure!(!seen[k], "corrupt state: order is not a permutation");
            seen[k] = true;
            *dst = k;
        }
        self.rng = Pcg32::from_state(s, inc);
        Ok(())
    }
}
