//! On-policy rollout storage, time-major (`t * B + i`), pre-allocated once
//! and reused across iterations (no allocation on the collection path).

/// Fixed-geometry rollout buffer for `T` steps of `B` environments.
pub struct RolloutBuffer {
    pub t_len: usize,
    pub b: usize,
    pub obs_dim: usize,
    /// `[T * B * obs_dim]`
    pub obs: Vec<f32>,
    /// `[T * B]`
    pub actions: Vec<i32>,
    pub log_probs: Vec<f32>,
    pub rewards: Vec<f32>,
    pub dones: Vec<bool>,
    pub values: Vec<f32>,
    /// `[B]` — V(s_T) after the last collected step.
    pub bootstrap: Vec<f32>,
    /// `[T * B]`, filled by the GAE pass.
    pub advantages: Vec<f32>,
    pub returns_: Vec<f32>,
}

impl RolloutBuffer {
    pub fn new(t_len: usize, b: usize, obs_dim: usize) -> RolloutBuffer {
        let n = t_len * b;
        RolloutBuffer {
            t_len,
            b,
            obs_dim,
            obs: vec![0.0; n * obs_dim],
            actions: vec![0; n],
            log_probs: vec![0.0; n],
            rewards: vec![0.0; n],
            dones: vec![false; n],
            values: vec![0.0; n],
            bootstrap: vec![0.0; b],
            advantages: vec![0.0; n],
            returns_: vec![0.0; n],
        }
    }

    pub fn total(&self) -> usize {
        self.t_len * self.b
    }

    /// Slice of the observation batch at step `t` (`[B * obs_dim]`).
    ///
    /// A sharded env (`core::shard`) writes each shard's observations
    /// directly into its disjoint sub-slice of this slab — the rollout
    /// buffer is the final destination, with no intermediate copies.
    pub fn obs_at_mut(&mut self, t: usize) -> &mut [f32] {
        let w = self.b * self.obs_dim;
        &mut self.obs[t * w..(t + 1) * w]
    }

    /// Immutable view of the observation slab at step `t`.
    pub fn obs_at(&self, t: usize) -> &[f32] {
        let w = self.b * self.obs_dim;
        &self.obs[t * w..(t + 1) * w]
    }

    /// Gather a minibatch (by flat transition indices) into the provided
    /// scratch buffers.
    pub fn gather(
        &self,
        idx: &[usize],
        obs_out: &mut [f32],
        act_out: &mut [i32],
        adv_out: &mut [f32],
        ret_out: &mut [f32],
        logp_out: &mut [f32],
    ) {
        let d = self.obs_dim;
        for (row, &k) in idx.iter().enumerate() {
            obs_out[row * d..(row + 1) * d].copy_from_slice(&self.obs[k * d..(k + 1) * d]);
            act_out[row] = self.actions[k];
            adv_out[row] = self.advantages[k];
            ret_out[row] = self.returns_[k];
            logp_out[row] = self.log_probs[k];
        }
    }

    /// Mean episodic statistics of this rollout: (mean reward per step,
    /// episodes completed).
    pub fn reward_stats(&self) -> (f32, usize) {
        let mean = self.rewards.iter().sum::<f32>() / self.total().max(1) as f32;
        let episodes = self.dones.iter().filter(|&&d| d).count();
        (mean, episodes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_and_gather() {
        let mut buf = RolloutBuffer::new(3, 2, 4);
        assert_eq!(buf.total(), 6);
        // Fill obs with recognizable values.
        for k in 0..6 {
            for j in 0..4 {
                buf.obs[k * 4 + j] = (k * 10 + j) as f32;
            }
            buf.actions[k] = k as i32;
            buf.advantages[k] = k as f32;
            buf.returns_[k] = -(k as f32);
            buf.log_probs[k] = 0.1 * k as f32;
        }
        let idx = [4usize, 1];
        let mut obs = vec![0.0; 2 * 4];
        let mut act = vec![0; 2];
        let mut adv = vec![0.0; 2];
        let mut ret = vec![0.0; 2];
        let mut lp = vec![0.0; 2];
        buf.gather(&idx, &mut obs, &mut act, &mut adv, &mut ret, &mut lp);
        assert_eq!(&obs[0..4], &[40.0, 41.0, 42.0, 43.0]);
        assert_eq!(act, vec![4, 1]);
        assert_eq!(adv, vec![4.0, 1.0]);
        assert_eq!(ret, vec![-4.0, -1.0]);
        assert_eq!(lp[1], 0.1);
    }

    #[test]
    fn obs_at_mut_addresses_step_slab() {
        let mut buf = RolloutBuffer::new(2, 3, 2);
        buf.obs_at_mut(1).fill(7.0);
        assert_eq!(buf.obs[0], 0.0);
        assert_eq!(buf.obs[6], 7.0);
        assert_eq!(buf.obs[11], 7.0);
    }

    #[test]
    fn reward_stats() {
        let mut buf = RolloutBuffer::new(2, 2, 1);
        buf.rewards = vec![1.0, 0.0, 1.0, 0.0];
        buf.dones = vec![false, true, true, false];
        let (mean, eps) = buf.reward_stats();
        assert_eq!(mean, 0.5);
        assert_eq!(eps, 2);
    }
}
