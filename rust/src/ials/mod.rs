//! The influence-augmented local simulator (Algorithm 2): a local
//! simulator driven by an influence predictor, packaged as a drop-in
//! [`VecEnv`] so the PPO trainer cannot tell it apart from the GS.
//!
//! Per step and per environment (Algorithm 2, lines 5–11):
//! 1. read the current d-set `d_t` from the LS,
//! 2. query the AIP for `P(u_t | d_t, history)` — **one batched PJRT call
//!    for all B environments** (the L3 perf lever, DESIGN.md §7),
//! 3. sample the binary realization `u_t`,
//! 4. step the LS with `(a_t, u_t)`.

use crate::core::{LocalEnv, VecEnv};
use crate::influence::InfluencePredictor;
use crate::util::Pcg32;

pub struct IalsVecEnv<L: LocalEnv> {
    envs: Vec<L>,
    predictor: Box<dyn InfluencePredictor>,
    rng: Pcg32,
    episode_counter: Vec<u64>,
    base_seed: u64,
    // scratch (no allocation on the step path)
    dsets: Vec<f32>,
    probs: Vec<f32>,
    u_bools: Vec<bool>,
}

impl<L: LocalEnv> IalsVecEnv<L> {
    pub fn new(envs: Vec<L>, predictor: Box<dyn InfluencePredictor>) -> Self {
        assert!(!envs.is_empty());
        let b = envs.len();
        assert_eq!(predictor.batch(), b, "predictor batch must equal env count");
        assert_eq!(predictor.dset_dim(), envs[0].dset_dim(), "d-set dims must agree");
        assert_eq!(
            predictor.num_sources(),
            envs[0].num_influence_sources(),
            "influence dims must agree"
        );
        let dd = envs[0].dset_dim();
        let ud = envs[0].num_influence_sources();
        IalsVecEnv {
            envs,
            predictor,
            rng: Pcg32::seeded(0),
            episode_counter: vec![0; b],
            base_seed: 0,
            dsets: vec![0.0; b * dd],
            probs: vec![0.0; b * ud],
            u_bools: vec![false; ud],
        }
    }

    pub fn predictor(&self) -> &dyn InfluencePredictor {
        self.predictor.as_ref()
    }

    /// Direct access to the wrapped local simulators (diagnostics, e.g.
    /// the Fig 6 item-lifetime histograms).
    pub fn envs_mut(&mut self) -> &mut [L] {
        &mut self.envs
    }

    fn seed_for(&self, env_idx: usize) -> u64 {
        self.base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(env_idx as u64)
            .wrapping_add(self.episode_counter[env_idx].wrapping_mul(0xD1B54A32D192ED03))
    }
}

impl<L: LocalEnv> VecEnv for IalsVecEnv<L> {
    fn num_envs(&self) -> usize {
        self.envs.len()
    }

    fn obs_dim(&self) -> usize {
        self.envs[0].obs_dim()
    }

    fn num_actions(&self) -> usize {
        self.envs[0].num_actions()
    }

    fn reset_all(&mut self, seed: u64) {
        self.base_seed = seed;
        self.rng = Pcg32::new(seed, 1312);
        self.predictor.reset_all();
        for i in 0..self.envs.len() {
            self.episode_counter[i] = 0;
            let s = self.seed_for(i);
            self.envs[i].reset(s);
        }
    }

    fn observe_all(&self, out: &mut [f32]) {
        let d = self.obs_dim();
        for (i, env) in self.envs.iter().enumerate() {
            env.observe(&mut out[i * d..(i + 1) * d]);
        }
    }

    fn step_all(&mut self, actions: &[usize], rewards: &mut [f32], dones: &mut [bool]) {
        let b = self.envs.len();
        let dd = self.predictor.dset_dim();
        let ud = self.predictor.num_sources();
        debug_assert_eq!(actions.len(), b);

        // 1. d_t for every env.
        for (i, env) in self.envs.iter().enumerate() {
            env.dset(&mut self.dsets[i * dd..(i + 1) * dd]);
        }
        // 2. One batched AIP call.
        self.predictor
            .predict(&self.dsets, &mut self.probs)
            .expect("influence predictor failed");
        // 3+4. Sample u_t and step each LS.
        for i in 0..b {
            for k in 0..ud {
                self.u_bools[k] = self.rng.bernoulli(self.probs[i * ud + k]);
            }
            let step = self.envs[i].step_with_influence(actions[i], &self.u_bools);
            rewards[i] = step.reward;
            dones[i] = step.done;
            if step.done {
                self.episode_counter[i] += 1;
                let s = self.seed_for(i);
                self.envs[i].reset(s);
                self.predictor.reset_state(i);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrafficConfig;
    use crate::influence::{FixedMarginalAip, ReplayPredictor};
    use crate::sim::traffic::TrafficLocalEnv;

    fn make(b: usize, p: f32) -> IalsVecEnv<TrafficLocalEnv> {
        let cfg = TrafficConfig::default();
        let envs: Vec<TrafficLocalEnv> = (0..b).map(|_| TrafficLocalEnv::new(&cfg)).collect();
        let aip = FixedMarginalAip::constant(b, 40, 4, p);
        IalsVecEnv::new(envs, Box::new(aip))
    }

    #[test]
    fn steps_and_shapes() {
        let mut v = make(4, 0.1);
        v.reset_all(1);
        assert_eq!(v.num_envs(), 4);
        assert_eq!(v.obs_dim(), 42);
        let mut obs = vec![0.0; 4 * 42];
        let mut rewards = [0.0f32; 4];
        let mut dones = [false; 4];
        for _ in 0..50 {
            v.step_all(&[0, 1, 0, 1], &mut rewards, &mut dones);
        }
        v.observe_all(&mut obs);
        assert!(obs.iter().all(|&x| x.is_finite()));
    }

    #[test]
    fn influence_rate_controls_traffic_density() {
        let density = |p: f32| {
            let mut v = make(2, p);
            v.reset_all(7);
            let mut rewards = [0.0f32; 2];
            let mut dones = [false; 2];
            let mut obs = vec![0.0; 2 * 42];
            let mut occ = 0.0f64;
            for _ in 0..300 {
                v.step_all(&[0, 0], &mut rewards, &mut dones);
                v.observe_all(&mut obs);
                occ += obs[..40].iter().sum::<f32>() as f64;
            }
            occ
        };
        let low = density(0.05);
        let high = density(0.5);
        assert!(
            high > low * 1.5,
            "higher influence rate must mean more cars: {low} vs {high}"
        );
    }

    #[test]
    fn auto_reset_keeps_running() {
        let mut v = make(1, 0.1);
        v.reset_all(3);
        let mut rewards = [0.0f32; 1];
        let mut dones = [false; 1];
        let mut done_count = 0;
        for _ in 0..450 {
            v.step_all(&[0], &mut rewards, &mut dones);
            if dones[0] {
                done_count += 1;
            }
        }
        assert_eq!(done_count, 2, "two 200-step episodes complete in 450 steps");
    }

    #[test]
    #[should_panic(expected = "batch")]
    fn batch_mismatch_rejected() {
        let cfg = TrafficConfig::default();
        let envs = vec![TrafficLocalEnv::new(&cfg)];
        let p = ReplayPredictor { batch: 2, dset_dim: 40, rows: vec![vec![0.0; 4]], cursor: 0 };
        let _ = IalsVecEnv::new(envs, Box::new(p));
    }
}
