//! The influence-augmented local simulator (Algorithm 2): a local
//! simulator driven by an influence predictor, packaged as a drop-in
//! [`VecEnv`] so the PPO trainer cannot tell it apart from the GS.
//!
//! Per step and per environment (Algorithm 2, lines 5–11):
//! 1. read the current d-set `d_t` from the LS,
//! 2. query the AIP for `P(u_t | d_t, history)`,
//! 3. sample the binary realization `u_t`,
//! 4. step the LS with `(a_t, u_t)`.
//!
//! ## The fused step pipeline
//!
//! When the predictor supports shard execution (the native engine's
//! `Sync` forward views — see `runtime::native`), all four phases run in
//! **one pool dispatch**: each [`IalsShard`] gathers its own d-set band,
//! runs the AIP forward over its own rows with its own
//! [`EngineScratch`], samples `u_t`, and steps its local simulators —
//! no barrier between the phases and no coordinator round-trip per step.
//! Because every forward kernel computes rows independently, banding the
//! AIP forward by shard is bitwise identical to the coordinator-batched
//! call, so the fused pipeline produces exactly the bits of the sandwich
//! below (`rust/tests/integration_parallel.rs` locks this in).
//!
//! ## The sandwich fallback
//!
//! Predictors that cannot cross threads (the PJRT backend's runtime is
//! `Rc`/`RefCell`-based) keep the historical parallel/serial sandwich:
//! parallel d-set gather → one coordinator-issued batched AIP call →
//! parallel influence sampling + LS stepping. [`IalsVecEnv::set_fused`]
//! can force this path for A/B benchmarking (`bench_rollout`) and parity
//! tests.
//!
//! Either way, every environment owns its RNG stream and is seeded from
//! its **global** index, so results are bitwise identical to serial
//! execution at the same seed, for any worker count.

use crate::core::shard::{SendSliceMut, SendSliceRef, ShardExec};
use crate::core::{shard_ranges, LocalEnv, VecEnv};
use crate::influence::{InfluencePredictor, ShardPredict};
use crate::runtime::native::{EngineScratch, FnnView, GruView};
use crate::util::{Pcg32, StateReader, StateWriter};

/// One shard of local simulators covering the global env indices
/// `[start, start + envs.len())`, with per-env influence-sampling RNG
/// streams, episode counters and its own NN forward scratch (the fused
/// step path runs the AIP on this shard's rows, on this shard's worker).
pub struct IalsShard<L: LocalEnv> {
    envs: Vec<L>,
    rngs: Vec<Pcg32>,
    episode_counter: Vec<u64>,
    start: usize,
    base_seed: u64,
    /// Per-step scratch for one env's sampled influence realization.
    u_bools: Vec<bool>,
    /// Per-shard forward scratch for the fused AIP band (empty when the
    /// predictor needs none).
    scratch: EngineScratch,
    /// `reset_all` must run before the first step — the placeholder RNG
    /// streams would otherwise give every env an identical influence
    /// stream (see `step_with_probs`).
    is_reset: bool,
}

impl<L: LocalEnv> IalsShard<L> {
    fn new(envs: Vec<L>, start: usize, num_sources: usize, scratch: EngineScratch) -> IalsShard<L> {
        let n = envs.len();
        IalsShard {
            envs,
            rngs: (0..n).map(|_| Pcg32::seeded(0)).collect(),
            episode_counter: vec![0; n],
            start,
            base_seed: 0,
            u_bools: vec![false; num_sources],
            scratch,
            is_reset: false,
        }
    }

    fn seed_for(&self, local_idx: usize) -> u64 {
        // Distinct per (base_seed, global env index, episode) — the same
        // formula for any sharding, which is what makes sharded == serial.
        self.base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((self.start + local_idx) as u64)
            .wrapping_add(self.episode_counter[local_idx].wrapping_mul(0xD1B54A32D192ED03))
    }

    fn reset_all(&mut self, seed: u64) {
        self.base_seed = seed;
        self.is_reset = true;
        for i in 0..self.envs.len() {
            self.episode_counter[i] = 0;
            let s = self.seed_for(i);
            self.envs[i].reset(s);
            // Influence-sampling stream: one per global env index, persists
            // across episode boundaries (like the env's own RNG does not).
            self.rngs[i] = Pcg32::new(seed, 1312 + (self.start + i) as u64);
        }
    }

    fn observe_into(&self, d: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.envs.len() * d);
        for (i, env) in self.envs.iter().enumerate() {
            env.observe(&mut out[i * d..(i + 1) * d]);
        }
    }

    fn dset_into(&self, dd: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.envs.len() * dd);
        for (i, env) in self.envs.iter().enumerate() {
            env.dset(&mut out[i * dd..(i + 1) * dd]);
        }
    }

    /// Sample `u_t` per env from the batched probabilities and step the LS
    /// (Algorithm 2 lines 8–11), auto-resetting finished episodes. The
    /// caller resets predictor state for envs flagged in `dones` (the
    /// fused dispatch does it in-band, the sandwich on the coordinator).
    fn step_with_probs(
        &mut self,
        actions: &[usize],
        probs: &[f32],
        ud: usize,
        rewards: &mut [f32],
        dones: &mut [bool],
    ) {
        // Stepping before `reset_all` would sample every env from the same
        // placeholder `Pcg32::seeded(0)` stream — identical influence
        // realizations across the whole batch, silently. Hard error in
        // every build (one bool compare per shard per step).
        assert!(
            self.is_reset,
            "IalsVecEnv stepped before reset_all: per-env influence streams are unseeded"
        );
        let n = self.envs.len();
        debug_assert_eq!(actions.len(), n);
        debug_assert_eq!(probs.len(), n * ud);
        for i in 0..n {
            for k in 0..ud {
                self.u_bools[k] = self.rngs[i].bernoulli(probs[i * ud + k]);
            }
            let step = self.envs[i].step_with_influence(actions[i], &self.u_bools);
            rewards[i] = step.reward;
            dones[i] = step.done;
            if step.done {
                self.episode_counter[i] += 1;
                let s = self.seed_for(i);
                self.envs[i].reset(s);
            }
        }
    }
}

impl<L: LocalEnv> IalsShard<L> {
    /// Serialize this shard's mutable state: seeding bookkeeping, per-env
    /// influence streams and the wrapped local simulators. `u_bools` and
    /// the forward scratch are per-step scratch and excluded.
    fn save_state(&self, out: &mut StateWriter) -> crate::Result<()> {
        out.u64(self.base_seed);
        out.bool(self.is_reset);
        out.u64s(&self.episode_counter);
        for rng in &self.rngs {
            let (s, inc) = rng.state();
            out.u64(s);
            out.u64(inc);
        }
        for env in &self.envs {
            env.save_state(out)?;
        }
        Ok(())
    }

    /// Restore state written by [`IalsShard::save_state`].
    fn load_state(&mut self, r: &mut StateReader) -> crate::Result<()> {
        self.base_seed = r.u64()?;
        self.is_reset = r.bool()?;
        let counters = r.u64s()?;
        anyhow::ensure!(
            counters.len() == self.envs.len(),
            "shard snapshot has {} episode counters, shard has {} envs",
            counters.len(),
            self.envs.len()
        );
        self.episode_counter = counters;
        for rng in &mut self.rngs {
            let (s, inc) = (r.u64()?, r.u64()?);
            *rng = Pcg32::from_state(s, inc);
        }
        for env in &mut self.envs {
            env.load_state(r)?;
        }
        Ok(())
    }
}

/// `Sync` form of [`ShardPredict`] for the fused dispatch: the GRU state
/// double-buffer crosses threads as raw handles whose disjoint per-shard
/// bands make the aliasing sound (same contract as the env-major buffers).
enum FusedPlan<'p> {
    Marginals(&'p [f32]),
    Fnn(FnnView<'p>),
    Gru { view: GruView<'p>, h: SendSliceRef<f32>, h_next: SendSliceMut<f32> },
}

impl<'p> FusedPlan<'p> {
    fn new(plan: ShardPredict<'p>) -> FusedPlan<'p> {
        match plan {
            ShardPredict::Marginals(m) => FusedPlan::Marginals(m),
            ShardPredict::Fnn(v) => FusedPlan::Fnn(v),
            ShardPredict::Gru { view, h, h_next } => FusedPlan::Gru {
                view,
                h: SendSliceRef::new(h),
                h_next: SendSliceMut::new(h_next),
            },
        }
    }

    /// AIP forward for the band covering global env rows `[s, s + n)`.
    fn predict_band(
        &self,
        s: usize,
        n: usize,
        d: &[f32],
        probs: &mut [f32],
        scratch: &mut EngineScratch,
    ) {
        match self {
            FusedPlan::Marginals(m) => {
                if !m.is_empty() {
                    for row in probs.chunks_exact_mut(m.len()) {
                        row.copy_from_slice(m);
                    }
                }
            }
            FusedPlan::Fnn(view) => view.forward_rows(n, d, probs, scratch),
            FusedPlan::Gru { view, h, h_next } => {
                let hid = view.hid;
                // SAFETY: this shard's disjoint state band; the dispatch
                // blocks until every band is done, and the double-buffer
                // swap (`end_step`) happens only afterwards.
                let (hb, hnb) =
                    unsafe { (h.range(s * hid, n * hid), h_next.range(s * hid, n * hid)) };
                view.step_rows(n, hb, d, probs, hnb, scratch);
            }
        }
    }

    /// Clear recurrent state for finished episodes. The rows written this
    /// step become the live state after `end_step`'s swap, so zeroing them
    /// here is exactly the sandwich's post-step `reset_state(i)`.
    fn reset_done_rows(&self, s: usize, n: usize, dones: &[bool]) {
        if let FusedPlan::Gru { view, h_next, .. } = self {
            let hid = view.hid;
            // SAFETY: same disjoint band as `predict_band` above.
            let hnb = unsafe { h_next.range(s * hid, n * hid) };
            for (i, &done) in dones.iter().enumerate().take(n) {
                if done {
                    hnb[i * hid..(i + 1) * hid].fill(0.0);
                }
            }
        }
    }
}

pub struct IalsVecEnv<L: LocalEnv + Send + 'static> {
    exec: ShardExec<IalsShard<L>>,
    predictor: Box<dyn InfluencePredictor>,
    num_envs: usize,
    obs_dim: usize,
    num_actions: usize,
    dset_dim: usize,
    num_sources: usize,
    /// Fused single-dispatch stepping (on by default when the predictor
    /// supports shard execution; see module docs).
    fused: bool,
    // coordinator scratch (no allocation on the step path)
    dsets: Vec<f32>,
    probs: Vec<f32>,
}

impl<L: LocalEnv + Send + 'static> IalsVecEnv<L> {
    /// Serial IALS (a single shard stepped inline) — the historical
    /// behaviour and the reference for the determinism guarantee.
    pub fn new(envs: Vec<L>, predictor: Box<dyn InfluencePredictor>) -> Self {
        Self::with_workers(envs, predictor, 1)
    }

    /// Shard the `B` environments over `num_workers` persistent worker
    /// threads (clamped to `B`; `1` keeps everything inline). Output is
    /// bitwise identical to [`IalsVecEnv::new`] at the same seed.
    pub fn with_workers(
        envs: Vec<L>,
        predictor: Box<dyn InfluencePredictor>,
        num_workers: usize,
    ) -> Self {
        assert!(!envs.is_empty());
        let b = envs.len();
        assert_eq!(predictor.batch(), b, "predictor batch must equal env count");
        assert_eq!(predictor.dset_dim(), envs[0].dset_dim(), "d-set dims must agree");
        assert_eq!(
            predictor.num_sources(),
            envs[0].num_influence_sources(),
            "influence dims must agree"
        );
        let obs_dim = envs[0].obs_dim();
        let num_actions = envs[0].num_actions();
        let dd = envs[0].dset_dim();
        let ud = envs[0].num_influence_sources();

        let w = num_workers.max(1).min(b);
        // Per-row forward scratch the fused path needs on each shard
        // (allocated once here — the step path stays allocation-free).
        // Predictors that can never shard-execute (PJRT) get none.
        let fused = predictor.supports_shard_exec();
        let (sr_a, sr_b) = if fused { predictor.shard_scratch_rows() } else { (0, 0) };
        let mut envs = envs;
        let mut shards = Vec::with_capacity(w);
        // Split off shards back-to-front so each keeps its contiguous range.
        for &(s, e) in shard_ranges(b, w).iter().rev() {
            let tail = envs.split_off(s);
            debug_assert_eq!(tail.len(), e - s);
            let n = e - s;
            shards.push(IalsShard::new(tail, s, ud, EngineScratch::new(n * sr_a, n * sr_b)));
        }
        shards.reverse();

        IalsVecEnv {
            exec: ShardExec::new(shards, w > 1),
            predictor,
            num_envs: b,
            obs_dim,
            num_actions,
            dset_dim: dd,
            num_sources: ud,
            fused,
            dsets: vec![0.0; b * dd],
            probs: vec![0.0; b * ud],
        }
    }

    pub fn predictor(&self) -> &dyn InfluencePredictor {
        self.predictor.as_ref()
    }

    pub fn num_shards(&self) -> usize {
        self.exec.num_shards()
    }

    /// Toggle the fused single-dispatch step. It is on by default whenever
    /// the predictor supports shard execution; turning it off forces the
    /// gather → batched-predict → step sandwich (for A/B benchmarking and
    /// the fused-vs-sandwich parity tests — both pipelines are bitwise
    /// identical at the same seed). Requesting `true` on a predictor that
    /// cannot shard-execute is a no-op.
    pub fn set_fused(&mut self, fused: bool) {
        self.fused = fused && self.predictor.supports_shard_exec();
    }

    /// Whether `step_all` runs the fused single-dispatch pipeline.
    pub fn is_fused(&self) -> bool {
        self.fused
    }

    /// Direct access to the wrapped local simulators (diagnostics, e.g.
    /// the Fig 6 item-lifetime histograms). Serial mode only — pooled
    /// shards live on their worker threads.
    pub fn envs_mut(&mut self) -> &mut [L] {
        let shards = self
            .exec
            .serial_shards_mut()
            .expect("envs_mut requires a serial IalsVecEnv (num_workers = 1)");
        debug_assert_eq!(shards.len(), 1, "serial executor holds exactly one shard");
        &mut shards[0].envs
    }
}

impl<L: LocalEnv + Send + 'static> VecEnv for IalsVecEnv<L> {
    fn num_envs(&self) -> usize {
        self.num_envs
    }

    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn num_actions(&self) -> usize {
        self.num_actions
    }

    fn reset_all(&mut self, seed: u64) {
        self.predictor.reset_all();
        self.exec.run_mut(move |_, shard| shard.reset_all(seed));
    }

    fn observe_all(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.num_envs * self.obs_dim);
        let d = self.obs_dim;
        let out = SendSliceMut::new(out);
        self.exec.run_ref(move |_, shard| {
            // SAFETY: disjoint per-shard ranges; run_ref blocks until done.
            let dst = unsafe { out.range(shard.start * d, shard.envs.len() * d) };
            shard.observe_into(d, dst);
        });
    }

    fn step_all(&mut self, actions: &[usize], rewards: &mut [f32], dones: &mut [bool]) {
        let b = self.num_envs;
        let dd = self.dset_dim;
        let ud = self.num_sources;
        debug_assert_eq!(actions.len(), b);

        if self.fused {
            // Fused pipeline: gather → AIP forward on own rows → influence
            // sampling → LS step, all inside ONE dispatch. Bitwise
            // identical to the sandwich below — forward kernels compute
            // rows independently, so banding by shard instead of by NN
            // slice cannot change any bit.
            let IalsVecEnv { exec, predictor, dsets, probs, .. } = self;
            if let Some(plan) = predictor.begin_step() {
                let plan = FusedPlan::new(plan);
                let dsets = SendSliceMut::new(dsets);
                let probs = SendSliceMut::new(probs);
                let actions = SendSliceRef::new(actions);
                let rewards = SendSliceMut::new(rewards);
                let dones = SendSliceMut::new(dones);
                exec.run_mut(move |_, shard| {
                    let (s, n) = (shard.start, shard.envs.len());
                    // SAFETY: every range below is this shard's disjoint
                    // band of the shared env-major buffers (d-set rows,
                    // prob rows, actions/rewards/dones, GRU state rows);
                    // run_mut blocks until every shard has completed.
                    let (db, pb) =
                        unsafe { (dsets.range(s * dd, n * dd), probs.range(s * ud, n * ud)) };
                    shard.dset_into(dd, db);
                    plan.predict_band(s, n, db, pb, &mut shard.scratch);
                    let (a, r, dn) = unsafe {
                        (actions.range(s, n), rewards.range(s, n), dones.range(s, n))
                    };
                    shard.step_with_probs(a, pb, ud, r, dn);
                    plan.reset_done_rows(s, n, dn);
                });
                predictor.end_step();
                return;
            }
        }

        // Sandwich fallback: parallel gather → one batched AIP call on the
        // coordinator → parallel sampling + stepping.
        // 1. d_t for every env (parallel, direct into the shared buffer).
        {
            let dsets = SendSliceMut::new(&mut self.dsets);
            self.exec.run_ref(move |_, shard| {
                // SAFETY: disjoint per-shard ranges; run_ref blocks until done.
                let dst = unsafe { dsets.range(shard.start * dd, shard.envs.len() * dd) };
                shard.dset_into(dd, dst);
            });
        }
        // 2. One batched AIP call on the coordinator thread.
        self.predictor.predict(&self.dsets, &mut self.probs).expect("influence predictor failed");
        // 3+4. Sample u_t and step each LS (parallel).
        {
            let actions = SendSliceRef::new(actions);
            let probs = SendSliceRef::new(&self.probs);
            let rewards = SendSliceMut::new(rewards);
            let dones = SendSliceMut::new(dones);
            self.exec.run_mut(move |_, shard| {
                let (s, n) = (shard.start, shard.envs.len());
                // SAFETY: disjoint per-shard ranges; run_mut blocks until done.
                let (a, p, r, dn) = unsafe {
                    (
                        actions.range(s, n),
                        probs.range(s * ud, n * ud),
                        rewards.range(s, n),
                        dones.range(s, n),
                    )
                };
                shard.step_with_probs(a, p, ud, r, dn);
            });
        }
        // Episode boundaries: clear the predictor's recurrent state rows on
        // the coordinator (same effect and order as the serial loop — the
        // state is not consulted again until the next batched predict).
        for (i, &done) in dones.iter().enumerate().take(b) {
            if done {
                self.predictor.reset_state(i);
            }
        }
    }

    fn save_state(&self, out: &mut StateWriter) -> crate::Result<()> {
        // Predictor step state (recurrent hidden rows / replay cursor)
        // first, then each shard's blob length-prefixed in shard order —
        // independent of the worker count, like everything else here.
        let mut pred = Vec::new();
        self.predictor.save_state(&mut pred);
        out.bytes(&pred);
        let mut slots: Vec<crate::Result<Vec<u8>>> =
            (0..self.exec.num_shards()).map(|_| Ok(Vec::new())).collect();
        let slots_ptr = SendSliceMut::new(&mut slots);
        self.exec.run_ref(move |i, shard| {
            // SAFETY: slot i is written only by task i; run_ref barriers.
            let slot = unsafe { slots_ptr.range(i, 1) };
            let mut w = StateWriter::new();
            slot[0] = shard.save_state(&mut w).map(|()| w.into_bytes());
        });
        out.usize(slots.len());
        for slot in slots {
            out.bytes(&slot?);
        }
        Ok(())
    }

    fn load_state(&mut self, r: &mut StateReader) -> crate::Result<()> {
        let pred = r.bytes()?;
        self.predictor.load_state(pred)?;
        let n = r.usize()?;
        anyhow::ensure!(
            n == self.exec.num_shards(),
            "IALS snapshot has {n} shards, executor has {}",
            self.exec.num_shards()
        );
        let blobs: Vec<&[u8]> =
            (0..n).map(|_| r.bytes()).collect::<crate::Result<Vec<_>>>()?;
        let mut results: Vec<crate::Result<()>> = (0..n).map(|_| Ok(())).collect();
        let blobs_ptr = SendSliceRef::new(&blobs);
        let results_ptr = SendSliceMut::new(&mut results);
        self.exec.run_mut(move |i, shard| {
            // SAFETY: disjoint per-task slots; run_mut barriers.
            let (blob, slot) = unsafe { (&blobs_ptr.range(i, 1)[0], results_ptr.range(i, 1)) };
            let mut sr = StateReader::new(blob);
            slot[0] = shard.load_state(&mut sr).and_then(|()| sr.expect_end());
        });
        results.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrafficConfig;
    use crate::influence::{FixedMarginalAip, ReplayPredictor};
    use crate::sim::traffic::TrafficLocalEnv;

    fn make(b: usize, p: f32) -> IalsVecEnv<TrafficLocalEnv> {
        make_workers(b, p, 1)
    }

    fn make_workers(b: usize, p: f32, w: usize) -> IalsVecEnv<TrafficLocalEnv> {
        let cfg = TrafficConfig::default();
        let envs: Vec<TrafficLocalEnv> = (0..b).map(|_| TrafficLocalEnv::new(&cfg)).collect();
        let aip = FixedMarginalAip::constant(b, 40, 4, p);
        IalsVecEnv::with_workers(envs, Box::new(aip), w)
    }

    #[test]
    fn steps_and_shapes() {
        let mut v = make(4, 0.1);
        v.reset_all(1);
        assert_eq!(v.num_envs(), 4);
        assert_eq!(v.obs_dim(), 42);
        let mut obs = vec![0.0; 4 * 42];
        let mut rewards = [0.0f32; 4];
        let mut dones = [false; 4];
        for _ in 0..50 {
            v.step_all(&[0, 1, 0, 1], &mut rewards, &mut dones);
        }
        v.observe_all(&mut obs);
        assert!(obs.iter().all(|&x| x.is_finite()));
    }

    #[test]
    fn influence_rate_controls_traffic_density() {
        let density = |p: f32| {
            let mut v = make(2, p);
            v.reset_all(7);
            let mut rewards = [0.0f32; 2];
            let mut dones = [false; 2];
            let mut obs = vec![0.0; 2 * 42];
            let mut occ = 0.0f64;
            for _ in 0..300 {
                v.step_all(&[0, 0], &mut rewards, &mut dones);
                v.observe_all(&mut obs);
                occ += obs[..40].iter().sum::<f32>() as f64;
            }
            occ
        };
        let low = density(0.05);
        let high = density(0.5);
        assert!(high > low * 1.5, "higher influence rate must mean more cars: {low} vs {high}");
    }

    #[test]
    fn auto_reset_keeps_running() {
        let mut v = make(1, 0.1);
        v.reset_all(3);
        let mut rewards = [0.0f32; 1];
        let mut dones = [false; 1];
        let mut done_count = 0;
        for _ in 0..450 {
            v.step_all(&[0], &mut rewards, &mut dones);
            if dones[0] {
                done_count += 1;
            }
        }
        assert_eq!(done_count, 2, "two 200-step episodes complete in 450 steps");
    }

    #[test]
    fn sharded_matches_serial_bitwise() {
        let b = 6;
        let mut serial = make_workers(b, 0.3, 1);
        let mut sharded = make_workers(b, 0.3, 4);
        assert_eq!(sharded.num_shards(), 4);
        serial.reset_all(11);
        sharded.reset_all(11);
        let mut obs_a = vec![0.0f32; b * 42];
        let mut obs_b = vec![0.0f32; b * 42];
        let (mut ra, mut rb) = (vec![0.0f32; b], vec![0.0f32; b]);
        let (mut da, mut db) = (vec![false; b], vec![false; b]);
        for t in 0..50 {
            let actions: Vec<usize> = (0..b).map(|i| (t + i) % 2).collect();
            serial.step_all(&actions, &mut ra, &mut da);
            sharded.step_all(&actions, &mut rb, &mut db);
            assert_eq!(ra, rb, "rewards diverged at step {t}");
            assert_eq!(da, db, "dones diverged at step {t}");
            serial.observe_all(&mut obs_a);
            sharded.observe_all(&mut obs_b);
            assert_eq!(obs_a, obs_b, "observations diverged at step {t}");
        }
    }

    #[test]
    fn pipeline_toggle_is_honored() {
        // Fused-vs-sandwich bitwise parity itself is pinned (with sweeps
        // and neural AIPs) in tests/integration_parallel.rs; here just the
        // toggle semantics.
        let mut v = make_workers(4, 0.3, 2);
        assert!(v.is_fused(), "fixed-marginal AIP defaults to fused");
        v.set_fused(false);
        assert!(!v.is_fused());
        v.set_fused(true);
        assert!(v.is_fused());
    }

    #[test]
    #[should_panic(expected = "before reset_all")]
    fn stepping_before_reset_is_a_hard_error() {
        // Un-reset shards hold placeholder RNGs — every env would sample
        // the identical influence stream. Serial env so the panic surfaces
        // directly instead of through the pool's worker-panicked wrapper.
        let mut v = make(2, 0.1);
        let mut rewards = [0.0f32; 2];
        let mut dones = [false; 2];
        v.step_all(&[0, 0], &mut rewards, &mut dones);
    }

    #[test]
    #[should_panic(expected = "batch")]
    fn batch_mismatch_rejected() {
        let cfg = TrafficConfig::default();
        let envs = vec![TrafficLocalEnv::new(&cfg)];
        let p = ReplayPredictor { batch: 2, dset_dim: 40, rows: vec![vec![0.0; 4]], cursor: 0 };
        let _ = IalsVecEnv::new(envs, Box::new(p));
    }
}
