//! The influence-augmented local simulator (Algorithm 2): a local
//! simulator driven by an influence predictor, packaged as a drop-in
//! [`VecEnv`] so the PPO trainer cannot tell it apart from the GS.
//!
//! Per step and per environment (Algorithm 2, lines 5–11):
//! 1. read the current d-set `d_t` from the LS,
//! 2. query the AIP for `P(u_t | d_t, history)` — **one batched PJRT call
//!    for all B environments** (the L3 perf lever, DESIGN.md §7),
//! 3. sample the binary realization `u_t`,
//! 4. step the LS with `(a_t, u_t)`.
//!
//! ## Parallel execution
//!
//! The step splits into a parallel/serial sandwich (see `core::shard`):
//! d-set gathering (1) and LS stepping (3+4) are pure Rust and run on the
//! shard workers, each writing its contiguous slice of the shared env-major
//! buffers; the AIP forward (2) stays a single batched call on the
//! coordinator thread (the `Runtime` is `Rc`/`RefCell`-based and must not
//! cross threads). Every environment owns its RNG stream and is seeded from
//! its **global** index, so results are bitwise identical to serial
//! execution at the same seed, for any worker count.

use crate::core::shard::{SendSliceMut, SendSliceRef, ShardExec};
use crate::core::{shard_ranges, LocalEnv, VecEnv};
use crate::influence::InfluencePredictor;
use crate::util::Pcg32;

/// One shard of local simulators covering the global env indices
/// `[start, start + envs.len())`, with per-env influence-sampling RNG
/// streams and episode counters.
pub struct IalsShard<L: LocalEnv> {
    envs: Vec<L>,
    rngs: Vec<Pcg32>,
    episode_counter: Vec<u64>,
    start: usize,
    base_seed: u64,
    /// Per-step scratch for one env's sampled influence realization.
    u_bools: Vec<bool>,
}

impl<L: LocalEnv> IalsShard<L> {
    fn new(envs: Vec<L>, start: usize, num_sources: usize) -> IalsShard<L> {
        let n = envs.len();
        IalsShard {
            envs,
            rngs: (0..n).map(|_| Pcg32::seeded(0)).collect(),
            episode_counter: vec![0; n],
            start,
            base_seed: 0,
            u_bools: vec![false; num_sources],
        }
    }

    fn seed_for(&self, local_idx: usize) -> u64 {
        // Distinct per (base_seed, global env index, episode) — the same
        // formula for any sharding, which is what makes sharded == serial.
        self.base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((self.start + local_idx) as u64)
            .wrapping_add(self.episode_counter[local_idx].wrapping_mul(0xD1B54A32D192ED03))
    }

    fn reset_all(&mut self, seed: u64) {
        self.base_seed = seed;
        for i in 0..self.envs.len() {
            self.episode_counter[i] = 0;
            let s = self.seed_for(i);
            self.envs[i].reset(s);
            // Influence-sampling stream: one per global env index, persists
            // across episode boundaries (like the env's own RNG does not).
            self.rngs[i] = Pcg32::new(seed, 1312 + (self.start + i) as u64);
        }
    }

    fn observe_into(&self, d: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.envs.len() * d);
        for (i, env) in self.envs.iter().enumerate() {
            env.observe(&mut out[i * d..(i + 1) * d]);
        }
    }

    fn dset_into(&self, dd: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.envs.len() * dd);
        for (i, env) in self.envs.iter().enumerate() {
            env.dset(&mut out[i * dd..(i + 1) * dd]);
        }
    }

    /// Sample `u_t` per env from the batched probabilities and step the LS
    /// (Algorithm 2 lines 8–11), auto-resetting finished episodes. The
    /// coordinator later resets predictor state for envs flagged in `dones`.
    fn step_with_probs(
        &mut self,
        actions: &[usize],
        probs: &[f32],
        ud: usize,
        rewards: &mut [f32],
        dones: &mut [bool],
    ) {
        let n = self.envs.len();
        debug_assert_eq!(actions.len(), n);
        debug_assert_eq!(probs.len(), n * ud);
        for i in 0..n {
            for k in 0..ud {
                self.u_bools[k] = self.rngs[i].bernoulli(probs[i * ud + k]);
            }
            let step = self.envs[i].step_with_influence(actions[i], &self.u_bools);
            rewards[i] = step.reward;
            dones[i] = step.done;
            if step.done {
                self.episode_counter[i] += 1;
                let s = self.seed_for(i);
                self.envs[i].reset(s);
            }
        }
    }
}

pub struct IalsVecEnv<L: LocalEnv + Send + 'static> {
    exec: ShardExec<IalsShard<L>>,
    predictor: Box<dyn InfluencePredictor>,
    num_envs: usize,
    obs_dim: usize,
    num_actions: usize,
    dset_dim: usize,
    num_sources: usize,
    // coordinator scratch (no allocation on the step path)
    dsets: Vec<f32>,
    probs: Vec<f32>,
}

impl<L: LocalEnv + Send + 'static> IalsVecEnv<L> {
    /// Serial IALS (a single shard stepped inline) — the historical
    /// behaviour and the reference for the determinism guarantee.
    pub fn new(envs: Vec<L>, predictor: Box<dyn InfluencePredictor>) -> Self {
        Self::with_workers(envs, predictor, 1)
    }

    /// Shard the `B` environments over `num_workers` persistent worker
    /// threads (clamped to `B`; `1` keeps everything inline). Output is
    /// bitwise identical to [`IalsVecEnv::new`] at the same seed.
    pub fn with_workers(
        envs: Vec<L>,
        predictor: Box<dyn InfluencePredictor>,
        num_workers: usize,
    ) -> Self {
        assert!(!envs.is_empty());
        let b = envs.len();
        assert_eq!(predictor.batch(), b, "predictor batch must equal env count");
        assert_eq!(predictor.dset_dim(), envs[0].dset_dim(), "d-set dims must agree");
        assert_eq!(
            predictor.num_sources(),
            envs[0].num_influence_sources(),
            "influence dims must agree"
        );
        let obs_dim = envs[0].obs_dim();
        let num_actions = envs[0].num_actions();
        let dd = envs[0].dset_dim();
        let ud = envs[0].num_influence_sources();

        let w = num_workers.max(1).min(b);
        let mut envs = envs;
        let mut shards = Vec::with_capacity(w);
        // Split off shards back-to-front so each keeps its contiguous range.
        for &(s, e) in shard_ranges(b, w).iter().rev() {
            let tail = envs.split_off(s);
            debug_assert_eq!(tail.len(), e - s);
            shards.push(IalsShard::new(tail, s, ud));
        }
        shards.reverse();

        IalsVecEnv {
            exec: ShardExec::new(shards, w > 1),
            predictor,
            num_envs: b,
            obs_dim,
            num_actions,
            dset_dim: dd,
            num_sources: ud,
            dsets: vec![0.0; b * dd],
            probs: vec![0.0; b * ud],
        }
    }

    pub fn predictor(&self) -> &dyn InfluencePredictor {
        self.predictor.as_ref()
    }

    pub fn num_shards(&self) -> usize {
        self.exec.num_shards()
    }

    /// Direct access to the wrapped local simulators (diagnostics, e.g.
    /// the Fig 6 item-lifetime histograms). Serial mode only — pooled
    /// shards live on their worker threads.
    pub fn envs_mut(&mut self) -> &mut [L] {
        let shards = self
            .exec
            .serial_shards_mut()
            .expect("envs_mut requires a serial IalsVecEnv (num_workers = 1)");
        debug_assert_eq!(shards.len(), 1, "serial executor holds exactly one shard");
        &mut shards[0].envs
    }
}

impl<L: LocalEnv + Send + 'static> VecEnv for IalsVecEnv<L> {
    fn num_envs(&self) -> usize {
        self.num_envs
    }

    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn num_actions(&self) -> usize {
        self.num_actions
    }

    fn reset_all(&mut self, seed: u64) {
        self.predictor.reset_all();
        self.exec.run_mut(move |_, shard| shard.reset_all(seed));
    }

    fn observe_all(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.num_envs * self.obs_dim);
        let d = self.obs_dim;
        let out = SendSliceMut::new(out);
        self.exec.run_ref(move |_, shard| {
            // SAFETY: disjoint per-shard ranges; run_ref blocks until done.
            let dst = unsafe { out.range(shard.start * d, shard.envs.len() * d) };
            shard.observe_into(d, dst);
        });
    }

    fn step_all(&mut self, actions: &[usize], rewards: &mut [f32], dones: &mut [bool]) {
        let b = self.num_envs;
        let dd = self.dset_dim;
        let ud = self.num_sources;
        debug_assert_eq!(actions.len(), b);

        // 1. d_t for every env (parallel, direct into the shared buffer).
        {
            let dsets = SendSliceMut::new(&mut self.dsets);
            self.exec.run_ref(move |_, shard| {
                // SAFETY: disjoint per-shard ranges; run_ref blocks until done.
                let dst = unsafe { dsets.range(shard.start * dd, shard.envs.len() * dd) };
                shard.dset_into(dd, dst);
            });
        }
        // 2. One batched AIP call on the coordinator thread.
        self.predictor
            .predict(&self.dsets, &mut self.probs)
            .expect("influence predictor failed");
        // 3+4. Sample u_t and step each LS (parallel).
        {
            let actions = SendSliceRef::new(actions);
            let probs = SendSliceRef::new(&self.probs);
            let rewards = SendSliceMut::new(rewards);
            let dones = SendSliceMut::new(dones);
            self.exec.run_mut(move |_, shard| {
                let (s, n) = (shard.start, shard.envs.len());
                // SAFETY: disjoint per-shard ranges; run_mut blocks until done.
                let (a, p, r, dn) = unsafe {
                    (
                        actions.range(s, n),
                        probs.range(s * ud, n * ud),
                        rewards.range(s, n),
                        dones.range(s, n),
                    )
                };
                shard.step_with_probs(a, p, ud, r, dn);
            });
        }
        // Episode boundaries: clear the predictor's recurrent state rows on
        // the coordinator (same effect and order as the serial loop — the
        // state is not consulted again until the next batched predict).
        for (i, &done) in dones.iter().enumerate().take(b) {
            if done {
                self.predictor.reset_state(i);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrafficConfig;
    use crate::influence::{FixedMarginalAip, ReplayPredictor};
    use crate::sim::traffic::TrafficLocalEnv;

    fn make(b: usize, p: f32) -> IalsVecEnv<TrafficLocalEnv> {
        make_workers(b, p, 1)
    }

    fn make_workers(b: usize, p: f32, w: usize) -> IalsVecEnv<TrafficLocalEnv> {
        let cfg = TrafficConfig::default();
        let envs: Vec<TrafficLocalEnv> = (0..b).map(|_| TrafficLocalEnv::new(&cfg)).collect();
        let aip = FixedMarginalAip::constant(b, 40, 4, p);
        IalsVecEnv::with_workers(envs, Box::new(aip), w)
    }

    #[test]
    fn steps_and_shapes() {
        let mut v = make(4, 0.1);
        v.reset_all(1);
        assert_eq!(v.num_envs(), 4);
        assert_eq!(v.obs_dim(), 42);
        let mut obs = vec![0.0; 4 * 42];
        let mut rewards = [0.0f32; 4];
        let mut dones = [false; 4];
        for _ in 0..50 {
            v.step_all(&[0, 1, 0, 1], &mut rewards, &mut dones);
        }
        v.observe_all(&mut obs);
        assert!(obs.iter().all(|&x| x.is_finite()));
    }

    #[test]
    fn influence_rate_controls_traffic_density() {
        let density = |p: f32| {
            let mut v = make(2, p);
            v.reset_all(7);
            let mut rewards = [0.0f32; 2];
            let mut dones = [false; 2];
            let mut obs = vec![0.0; 2 * 42];
            let mut occ = 0.0f64;
            for _ in 0..300 {
                v.step_all(&[0, 0], &mut rewards, &mut dones);
                v.observe_all(&mut obs);
                occ += obs[..40].iter().sum::<f32>() as f64;
            }
            occ
        };
        let low = density(0.05);
        let high = density(0.5);
        assert!(
            high > low * 1.5,
            "higher influence rate must mean more cars: {low} vs {high}"
        );
    }

    #[test]
    fn auto_reset_keeps_running() {
        let mut v = make(1, 0.1);
        v.reset_all(3);
        let mut rewards = [0.0f32; 1];
        let mut dones = [false; 1];
        let mut done_count = 0;
        for _ in 0..450 {
            v.step_all(&[0], &mut rewards, &mut dones);
            if dones[0] {
                done_count += 1;
            }
        }
        assert_eq!(done_count, 2, "two 200-step episodes complete in 450 steps");
    }

    #[test]
    fn sharded_matches_serial_bitwise() {
        let b = 6;
        let mut serial = make_workers(b, 0.3, 1);
        let mut sharded = make_workers(b, 0.3, 4);
        assert_eq!(sharded.num_shards(), 4);
        serial.reset_all(11);
        sharded.reset_all(11);
        let mut obs_a = vec![0.0f32; b * 42];
        let mut obs_b = vec![0.0f32; b * 42];
        let (mut ra, mut rb) = (vec![0.0f32; b], vec![0.0f32; b]);
        let (mut da, mut db) = (vec![false; b], vec![false; b]);
        for t in 0..50 {
            let actions: Vec<usize> = (0..b).map(|i| (t + i) % 2).collect();
            serial.step_all(&actions, &mut ra, &mut da);
            sharded.step_all(&actions, &mut rb, &mut db);
            assert_eq!(ra, rb, "rewards diverged at step {t}");
            assert_eq!(da, db, "dones diverged at step {t}");
            serial.observe_all(&mut obs_a);
            sharded.observe_all(&mut obs_b);
            assert_eq!(obs_a, obs_b, "observations diverged at step {t}");
        }
    }

    #[test]
    #[should_panic(expected = "batch")]
    fn batch_mismatch_rejected() {
        let cfg = TrafficConfig::default();
        let envs = vec![TrafficLocalEnv::new(&cfg)];
        let p = ReplayPredictor { batch: 2, dset_dim: 40, rows: vec![vec![0.0; 4]], cursor: 0 };
        let _ = IalsVecEnv::new(envs, Box::new(p));
    }
}
