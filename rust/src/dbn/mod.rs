//! Dynamic-Bayesian-network tooling: d-separation tests and minimal
//! d-separating-set search (paper §4.2, Definitions 4–5; Acid & De Campos
//! 1996; Tian, Paz & Pearl 1998).
//!
//! The IALS construction requires a d-set `d_t ⊆ l_t` such that
//! `u_t ⟂ l_t \ d_t | d_t`. The two benchmark domains specify their d-sets
//! by hand (as the paper does); this module provides the machinery to
//! *verify* those choices against each domain's DBN, and a greedy
//! minimization pass that strips redundant variables.

use anyhow::{bail, Result};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// A directed acyclic graph over named nodes.
#[derive(Debug, Clone, Default)]
pub struct Dag {
    names: Vec<String>,
    index: BTreeMap<String, usize>,
    parents: Vec<Vec<usize>>,
    children: Vec<Vec<usize>>,
}

impl Dag {
    pub fn new() -> Dag {
        Dag::default()
    }

    /// Add (or get) a node by name.
    pub fn node(&mut self, name: &str) -> usize {
        if let Some(&i) = self.index.get(name) {
            return i;
        }
        let i = self.names.len();
        self.names.push(name.to_string());
        self.index.insert(name.to_string(), i);
        self.parents.push(Vec::new());
        self.children.push(Vec::new());
        i
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    pub fn name(&self, i: usize) -> &str {
        &self.names[i]
    }

    pub fn lookup(&self, name: &str) -> Result<usize> {
        match self.index.get(name) {
            Some(&i) => Ok(i),
            None => bail!("unknown DBN node '{name}'"),
        }
    }

    /// Add a directed edge `from -> to` (idempotent). Panics on self-loops.
    pub fn edge(&mut self, from: &str, to: &str) {
        let f = self.node(from);
        let t = self.node(to);
        assert_ne!(f, t, "self loop on {from}");
        if !self.children[f].contains(&t) {
            self.children[f].push(t);
            self.parents[t].push(f);
        }
    }

    pub fn parents_of(&self, i: usize) -> &[usize] {
        &self.parents[i]
    }

    pub fn children_of(&self, i: usize) -> &[usize] {
        &self.children[i]
    }

    /// Check acyclicity (Kahn's algorithm).
    pub fn is_acyclic(&self) -> bool {
        let mut indeg: Vec<usize> = self.parents.iter().map(|p| p.len()).collect();
        let mut queue: VecDeque<usize> = (0..self.len()).filter(|&i| indeg[i] == 0).collect();
        let mut seen = 0;
        while let Some(n) = queue.pop_front() {
            seen += 1;
            for &c in &self.children[n] {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    queue.push_back(c);
                }
            }
        }
        seen == self.len()
    }

    /// Ancestors of a set (including the set itself).
    fn ancestral_closure(&self, set: &BTreeSet<usize>) -> BTreeSet<usize> {
        let mut out = set.clone();
        let mut stack: Vec<usize> = set.iter().cloned().collect();
        while let Some(n) = stack.pop() {
            for &p in &self.parents[n] {
                if out.insert(p) {
                    stack.push(p);
                }
            }
        }
        out
    }

    /// Test d-separation: is every node in `xs` d-separated from every node
    /// in `ys` given `zs`? Implemented via the moralized-ancestral-graph
    /// criterion (Lauritzen): X ⟂ Y | Z in the DAG iff X and Y are
    /// separated by Z in the moral graph of the ancestral graph of X∪Y∪Z.
    pub fn d_separated(&self, xs: &[usize], ys: &[usize], zs: &[usize]) -> bool {
        let x: BTreeSet<usize> = xs.iter().cloned().collect();
        let y: BTreeSet<usize> = ys.iter().cloned().collect();
        let z: BTreeSet<usize> = zs.iter().cloned().collect();
        assert!(x.is_disjoint(&z) && y.is_disjoint(&z), "conditioning set overlaps query");
        if !x.is_disjoint(&y) {
            return false;
        }

        let mut all = x.clone();
        all.extend(&y);
        all.extend(&z);
        let anc = self.ancestral_closure(&all);

        // Build the moral graph restricted to `anc`: undirected adjacency.
        let mut adj: BTreeMap<usize, BTreeSet<usize>> = BTreeMap::new();
        let connect = |a: usize, b: usize, adj: &mut BTreeMap<usize, BTreeSet<usize>>| {
            adj.entry(a).or_default().insert(b);
            adj.entry(b).or_default().insert(a);
        };
        for &n in &anc {
            adj.entry(n).or_default();
            // directed edges
            for &p in &self.parents[n] {
                if anc.contains(&p) {
                    connect(p, n, &mut adj);
                }
            }
            // marry parents
            let ps: Vec<usize> =
                self.parents[n].iter().cloned().filter(|p| anc.contains(p)).collect();
            for i in 0..ps.len() {
                for j in (i + 1)..ps.len() {
                    connect(ps[i], ps[j], &mut adj);
                }
            }
        }

        // BFS from X avoiding Z; separated iff no Y reached.
        let mut visited: BTreeSet<usize> = BTreeSet::new();
        let mut queue: VecDeque<usize> = x.iter().cloned().filter(|n| !z.contains(n)).collect();
        visited.extend(queue.iter());
        while let Some(n) = queue.pop_front() {
            if y.contains(&n) {
                return false;
            }
            if let Some(nbrs) = adj.get(&n) {
                for &m in nbrs {
                    if !z.contains(&m) && visited.insert(m) {
                        queue.push_back(m);
                    }
                }
            }
        }
        true
    }

    /// Named-node convenience wrapper around [`Self::d_separated`].
    pub fn d_separated_names(&self, xs: &[&str], ys: &[&str], zs: &[&str]) -> Result<bool> {
        let r = |names: &[&str]| -> Result<Vec<usize>> {
            names.iter().map(|n| self.lookup(n)).collect()
        };
        Ok(self.d_separated(&r(xs)?, &r(ys)?, &r(zs)?))
    }

    /// Greedy minimization: given a valid separating set `zs` (u ⟂ rest |
    /// zs must already hold), repeatedly drop variables whose removal keeps
    /// `xs ⟂ ys | zs'`. Returns the reduced set (a minimal — not
    /// necessarily minimum — d-set, as in Acid & De Campos 1996).
    pub fn minimize_dset(&self, xs: &[usize], ys: &[usize], zs: &[usize]) -> Result<Vec<usize>> {
        if !self.d_separated(xs, ys, zs) {
            bail!("initial set is not d-separating");
        }
        let mut current: Vec<usize> = zs.to_vec();
        loop {
            let mut removed = false;
            for i in 0..current.len() {
                let mut candidate = current.clone();
                candidate.remove(i);
                if self.d_separated(xs, ys, &candidate) {
                    current = candidate;
                    removed = true;
                    break;
                }
            }
            if !removed {
                return Ok(current);
            }
        }
    }
}

/// Build the local-POMDP prototype DBN of Figure 1, unrolled `t_max`
/// timesteps: local vars `x1,x2`, influence sources `u`, non-local vars
/// `y`, actions `a`. Used by tests and by the domain modules' d-set
/// verification helpers.
pub fn figure1_prototype(t_max: usize) -> Dag {
    let mut g = Dag::new();
    for t in 0..t_max {
        let x1 = format!("x1_{t}");
        let x2 = format!("x2_{t}");
        let u = format!("u_{t}");
        let y = format!("y_{t}");
        let a = format!("a_{t}");
        g.node(&x1);
        g.node(&x2);
        g.node(&u);
        g.node(&y);
        g.node(&a);
        if t + 1 < t_max {
            let n = |s: &str| format!("{s}_{}", t + 1);
            // Local transition: x' depends on (x, u, a).
            g.edge(&x1, &n("x1"));
            g.edge(&x2, &n("x1"));
            g.edge(&x1, &n("x2"));
            g.edge(&x2, &n("x2"));
            g.edge(&u, &n("x1")); // influence enters the local region
            g.edge(&a, &n("x1"));
            g.edge(&a, &n("x2"));
            // Non-local dynamics: y' depends on y; u' depends on y (and u).
            g.edge(&y, &n("y"));
            g.edge(&y, &n("u"));
            g.edge(&u, &n("u"));
            // The local region feeds back into the global system
            // (e.g. cars leaving the intersection): x -> y'.
            g.edge(&x2, &n("y"));
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> Dag {
        // a -> b -> c
        let mut g = Dag::new();
        g.edge("a", "b");
        g.edge("b", "c");
        g
    }

    #[test]
    fn chain_separation() {
        let g = chain();
        // a ⟂ c | b, but not marginally.
        assert!(g.d_separated_names(&["a"], &["c"], &["b"]).unwrap());
        assert!(!g.d_separated_names(&["a"], &["c"], &[]).unwrap());
    }

    #[test]
    fn fork_separation() {
        // a <- b -> c : a ⟂ c | b only.
        let mut g = Dag::new();
        g.edge("b", "a");
        g.edge("b", "c");
        assert!(g.d_separated_names(&["a"], &["c"], &["b"]).unwrap());
        assert!(!g.d_separated_names(&["a"], &["c"], &[]).unwrap());
    }

    #[test]
    fn collider_separation() {
        // a -> b <- c : a ⟂ c marginally, but NOT given the collider b.
        let mut g = Dag::new();
        g.edge("a", "b");
        g.edge("c", "b");
        assert!(g.d_separated_names(&["a"], &["c"], &[]).unwrap());
        assert!(!g.d_separated_names(&["a"], &["c"], &["b"]).unwrap());
    }

    #[test]
    fn collider_descendant_opens_path() {
        // a -> b <- c, b -> d: conditioning on the descendant d also opens.
        let mut g = Dag::new();
        g.edge("a", "b");
        g.edge("c", "b");
        g.edge("b", "d");
        assert!(!g.d_separated_names(&["a"], &["c"], &["d"]).unwrap());
    }

    #[test]
    fn acyclicity() {
        assert!(chain().is_acyclic());
        let mut g = chain();
        g.edge("c", "a");
        assert!(!g.is_acyclic());
    }

    #[test]
    fn figure1_u_separates_x_from_y() {
        // The defining property of influence sources (paper §3.2): given
        // u_t (and the local state/action), x_{t+1} ⟂ y_t.
        let g = figure1_prototype(3);
        // u_1 <- y_0 carries the global influence; conditioning on u_1 (and
        // the local state/action) blocks it: x1_2 ⟂ y_0 | {u_1, x_1, a_1}.
        assert!(g
            .d_separated_names(
                &["x1_2"],
                &["y_0"],
                &["u_1", "x1_1", "x2_1", "a_1"],
            )
            .unwrap());
        // Dropping u_1 opens the chain y_0 -> u_1 -> x1_2.
        assert!(!g.d_separated_names(&["x1_2"], &["y_0"], &["x1_1", "x2_1", "a_1"]).unwrap());
    }

    #[test]
    fn figure1_dset_minimization() {
        let g = figure1_prototype(3);
        // Predicting u_2 given the whole t<=1 ALSH: actions should be
        // removable (they only touch u via x -> y', a long path through y
        // that the x's block... in this prototype a_t -> x_{t+1} -> y_{t+2}
        // which is downstream of u_2's parents only through y).
        let u2 = g.lookup("u_2").unwrap();
        let alsh: Vec<usize> = ["x1_0", "x2_0", "a_0", "x1_1", "x2_1", "a_1"]
            .iter()
            .map(|n| g.lookup(n).unwrap())
            .collect();
        let rest: Vec<usize> = ["y_0"].iter().map(|n| g.lookup(n).unwrap()).collect();
        // ALSH + history must separate u_2 from y_0? u_2 <- y_1 <- y_0:
        // conditioning on x's doesn't block that, so full separation needs
        // y — this asserts the *failure* case is detected too.
        assert!(!g.d_separated(&[u2], &rest, &alsh));
    }

    #[test]
    fn minimize_dset_strips_redundant_vars() {
        // x -> m -> y, plus irrelevant r. {m, r} separates x from y; the
        // minimal set is {m}.
        let mut g = Dag::new();
        g.edge("x", "m");
        g.edge("m", "y");
        g.node("r");
        let (x, m, y, r) = (
            g.lookup("x").unwrap(),
            g.lookup("m").unwrap(),
            g.lookup("y").unwrap(),
            g.lookup("r").unwrap(),
        );
        let min = g.minimize_dset(&[x], &[y], &[m, r]).unwrap();
        assert_eq!(min, vec![m]);
    }

    #[test]
    fn minimize_rejects_nonseparating_input() {
        let g = chain();
        let (a, c) = (g.lookup("a").unwrap(), g.lookup("c").unwrap());
        assert!(g.minimize_dset(&[a], &[c], &[]).is_err());
    }

    #[test]
    fn unknown_name_is_error() {
        let g = chain();
        assert!(g.d_separated_names(&["nope"], &["c"], &[]).is_err());
    }
}
