//! `repro` — the leader binary: CLI entrypoint for reproducing every
//! figure of the IALS paper. See `repro --help` / [`ials::cli::USAGE`].

use anyhow::Result;
use ials::cli::{Args, USAGE};
use ials::collect::{collect_dataset, FeatureKind};
use ials::config::{DomainKind, ExperimentConfig};
use ials::coordinator::{
    run_condition, run_distributed, run_figure, run_multi_condition_resumable, run_worker,
    DistributedOptions, FIGURES, WorkerArgs,
};
use ials::metrics::write_curve;
use ials::runtime::Runtime;
use ials::serve::ServeOptions;
use ials::sim::traffic::TrafficGlobalEnv;
use ials::sim::warehouse::WarehouseGlobalEnv;
use ials::testkit::fault::abort_after_from_env;
use std::rc::Rc;

fn main() {
    ials::util::logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.iter().any(|a| a == "--help" || a == "-h") || argv.is_empty() {
        println!("{USAGE}");
        return;
    }
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> Result<ExperimentConfig> {
    match args.get("config") {
        Some(path) => ExperimentConfig::load(path),
        None => Ok(ExperimentConfig::default()),
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.subcommand.as_str() {
        "figure" => {
            let name = args.require("name")?.to_string();
            let cfg = load_config(&args)?;
            // Figures are the paper's single-learner reproductions; fail
            // loudly rather than silently ignoring a multi-learner config.
            anyhow::ensure!(
                cfg.num_learners == 1,
                "figure runs are single-learner (num_learners = {}); use `repro train \
                 --learners {}` for a multi-learner run",
                cfg.num_learners,
                cfg.num_learners
            );
            let rt = Rc::new(Runtime::from_config(&cfg)?);
            run_figure(&rt, &name, &cfg)?;
        }
        "train" => {
            let mut cfg = load_config(&args)?;
            if args.get("config").is_none() {
                anyhow::bail!("train requires --config");
            }
            let seed = args.get_u64("seed", cfg.seeds[0])?;
            if args.get("steps").is_some() {
                cfg.ppo.total_steps = args.get_usize("steps", 0)?;
            }
            if args.get("learners").is_some() {
                cfg.num_learners = args.get_usize("learners", 1)?;
                cfg.validate()?;
            }
            if args.get("checkpoint-every").is_some() {
                cfg.checkpoint_every = args.get_usize("checkpoint-every", 0)?;
            }
            if let Some(dir) = args.get("checkpoint-dir") {
                cfg.checkpoint_dir = dir.to_string();
            }
            let resume = args.get_bool("resume");
            if args.get_bool("no-health") {
                cfg.health.enabled = false;
            }
            if args.get("distributed").is_some() {
                // Cross-process runtime: the coordinator never builds an
                // engine runtime itself — workers do — so this path stays
                // before the Runtime construction below.
                anyhow::ensure!(
                    !resume,
                    "--resume is meaningless with --distributed: workers always auto-resume \
                     from their shard's newest valid checkpoint"
                );
                cfg.distributed.workers = args.get_usize("distributed", cfg.distributed.workers)?;
                cfg.validate()?;
                let workers = cfg.distributed.workers;
                let out = run_distributed(&cfg, seed, workers, &DistributedOptions::default())?;
                let single = out.learners.len() == 1;
                for (l, lr) in out.learners.iter().enumerate() {
                    let Some(lr) = lr else { continue };
                    let r = &lr.result;
                    let path = if single {
                        format!("{}/{}_seed{}.csv", cfg.results_dir, r.condition, seed)
                    } else {
                        format!("{}/{}_seed{}_learner{}.csv", cfg.results_dir, r.condition, seed, l)
                    };
                    write_curve(&path, &r.curve)?;
                    println!(
                        "learner {l} (seed {seed}): prep {:.2}s train {:.2}s aip_ce {:.4} \
                         final {:.4} -> {}",
                        r.prep_secs, r.train_secs, r.aip_ce, r.final_eval, path
                    );
                }
                print!("{}", out.report());
                // Machine-readable mirror of the report, next to the CSVs.
                let report_path = format!(
                    "{}/{}-{}_seed{}_report.json",
                    cfg.results_dir,
                    cfg.simulator.name(),
                    cfg.name,
                    seed
                );
                ials::util::state::atomic_write(&report_path, out.report_json().as_bytes())?;
                println!("health report -> {report_path}");
                anyhow::ensure!(
                    out.healthy(),
                    "distributed run degraded: {} of {} shard(s) failed, {} learner(s) \
                     quarantined after exhausting [health] max_rollbacks (see {})",
                    out.shards.iter().filter(|s| !s.ok).count(),
                    out.shards.len(),
                    out.shards
                        .iter()
                        .flat_map(|s| &s.health)
                        .filter(|h| h.quarantined)
                        .count(),
                    report_path
                );
                return Ok(());
            }
            let rt = Rc::new(Runtime::from_config(&cfg)?);
            if cfg.num_learners > 1 || resume || cfg.checkpoint_every > 0 {
                // Resumable driver: K curves (one per learner), periodic
                // crash-safe checkpoints, optional injected abort (CI's
                // kill-and-resume smoke). A num_learners = 1 run through
                // this path is bitwise identical to `run_condition` and
                // keeps the single-learner CSV name.
                let abort_after = abort_after_from_env()?;
                let out = run_multi_condition_resumable(&rt, &cfg, seed, resume, abort_after)?;
                let single = out.results.len() == 1;
                for (l, r) in out.results.iter().enumerate() {
                    let path = if single {
                        format!("{}/{}_seed{}.csv", cfg.results_dir, r.condition, seed)
                    } else {
                        format!("{}/{}_seed{}_learner{}.csv", cfg.results_dir, r.condition, seed, l)
                    };
                    write_curve(&path, &r.curve)?;
                    println!(
                        "learner {l} (seed {seed}): prep {:.2}s train {:.2}s aip_ce {:.4} \
                         final {:.4} -> {}",
                        r.prep_secs, r.train_secs, r.aip_ce, r.final_eval, path
                    );
                }
                for (l, h) in out.health.iter().enumerate() {
                    if h.quarantined || h.rollbacks > 0 {
                        println!(
                            "learner {l} (seed {seed}): health {} ({} rollback(s))",
                            if h.quarantined { "QUARANTINED" } else { "recovered" },
                            h.rollbacks
                        );
                    }
                }
                anyhow::ensure!(
                    !out.any_quarantined(),
                    "training degraded: {} learner(s) quarantined after exhausting [health] \
                     max_rollbacks = {}; healthy learners finished and their curves were written",
                    out.health.iter().filter(|h| h.quarantined).count(),
                    cfg.health.max_rollbacks
                );
            } else {
                let r = run_condition(&rt, &cfg, seed)?;
                let out = format!("{}/{}_seed{}.csv", cfg.results_dir, r.condition, seed);
                write_curve(&out, &r.curve)?;
                println!(
                    "condition {} seed {}: prep {:.2}s train {:.2}s aip_ce {:.4} final {:.4} -> {}",
                    r.condition, seed, r.prep_secs, r.train_secs, r.aip_ce, r.final_eval, out
                );
            }
        }
        "worker" => {
            // Internal: one learner shard of a `train --distributed` run.
            // Spawned (and restarted) by the coordinator; not meant to be
            // invoked by hand.
            let cfg = load_config(&args)?;
            if args.get("config").is_none() {
                anyhow::bail!("worker requires --config");
            }
            let wa = WorkerArgs {
                dist_dir: args.require("dist-dir")?.into(),
                index: args.require_usize("index")?,
                first_learner: args.require_usize("first-learner")?,
                count: args.require_usize("count")?,
                seed: args.require_u64("seed")?,
            };
            run_worker(&cfg, &wa)?;
        }
        "collect" => {
            let domain = DomainKind::parse(args.require("domain")?)?;
            let steps = args.get_usize("steps", 10_000)?;
            let seed = args.get_u64("seed", 1)?;
            let cfg = load_config(&args)?;
            let data = match domain {
                DomainKind::Traffic => {
                    let mut env = TrafficGlobalEnv::new(&cfg.traffic);
                    collect_dataset(&mut env, steps, seed, FeatureKind::Dset)
                }
                DomainKind::Warehouse => {
                    let mut env = WarehouseGlobalEnv::new(&cfg.warehouse);
                    collect_dataset(&mut env, steps, seed, FeatureKind::Dset)
                }
            };
            println!(
                "collected {} steps / {} episodes; u marginals: {:?}",
                data.total_steps(),
                data.episodes.len(),
                data.u_marginals()
            );
        }
        "serve" => {
            // Policy-inference front tier over trained checkpoint run
            // directories (the `<checkpoint_dir>/<sim>-<config>_seed<S>`
            // paths a `train --checkpoint-dir` run writes). Each
            // --checkpoint-dir becomes one hosted run; with no flags the
            // `[serve] runs` config list is used.
            let cfg = load_config(&args)?;
            let mut dirs: Vec<std::path::PathBuf> =
                args.get_all("checkpoint-dir").iter().map(std::path::PathBuf::from).collect();
            if dirs.is_empty() {
                dirs = cfg.serve.runs.iter().map(std::path::PathBuf::from).collect();
            }
            anyhow::ensure!(
                !dirs.is_empty(),
                "serve needs at least one run: pass --checkpoint-dir (repeatable) or set \
                 [serve] runs in the config"
            );
            let mut opts = ServeOptions::from_config(&cfg.serve)?;
            if args.get("port").is_some() {
                let port = args.get_usize("port", cfg.serve.port)?;
                anyhow::ensure!(port <= u16::MAX as usize, "--port {port} is out of range");
                opts.port = port as u16;
            }
            ials::serve::run(&dirs, opts)?;
        }
        "inspect" => {
            // Read-only checkpoint-directory report: one line per file
            // with header metadata, geometry and CRC validity — one
            // verdict block per directory when several are passed.
            let dirs = args.get_all("checkpoint-dir");
            anyhow::ensure!(!dirs.is_empty(), "missing required flag --checkpoint-dir");
            let many = dirs.len() > 1;
            for (i, dir) in dirs.iter().enumerate() {
                if many {
                    if i > 0 {
                        println!();
                    }
                    println!("{dir}:");
                }
                for line in ials::serve::snapshot::inspect_dir(std::path::Path::new(dir))? {
                    println!("{line}");
                }
            }
        }
        "list" => {
            println!("figures: {FIGURES:?}");
            let cfg = load_config(&args)?;
            match Runtime::from_config(&cfg) {
                Ok(rt) => {
                    println!(
                        "backend: {} (config: {}) / artifacts ({}):",
                        rt.backend_kind(),
                        cfg.runtime.backend.name(),
                        rt.manifest.artifacts.len()
                    );
                    for name in rt.manifest.artifacts.keys() {
                        println!("  {name}");
                    }
                }
                Err(e) => println!("runtime unavailable: {e:#}"),
            }
        }
        other => anyhow::bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
    Ok(())
}
