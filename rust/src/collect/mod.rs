//! Algorithm 1: collect a `(d_t, u_t)` dataset from the global simulator
//! under an exploratory policy π₀ (uniform random — which satisfies the
//! support condition `π₀(a|l) > 0` of §4.2).

use crate::core::GlobalEnv;
use crate::influence::InfluenceDataset;
use crate::util::Pcg32;

/// Which per-step features to record as the AIP input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeatureKind {
    /// The hand-specified d-separating set (the paper's choice).
    Dset,
    /// The full ALSH features including the confounder-prone variables
    /// (lights / agent location) — the Appendix-B ablation.
    Alsh,
}

/// Number of independent collection chunks Algorithm-1 sharding splits the
/// step budget into. Fixed (not derived from the worker count) so the
/// collected dataset depends only on `(seed, steps)` — the same bits on a
/// laptop and a 64-core server, for any `num_workers`.
pub const COLLECT_CHUNKS: usize = 16;

/// Sharded Algorithm 1: the `steps` budget is split into [`COLLECT_CHUNKS`]
/// logical chunks, each collected from its own GS instance under a
/// per-chunk seed stream; `num_workers` scoped threads execute the chunks
/// and the results merge in chunk order. Because the chunking is fixed, the
/// output is **bitwise identical for every worker count** — `num_workers`
/// only changes wall-clock. (It therefore differs from the single-
/// trajectory [`collect_dataset`], which remains available for callers that
/// want one continuous rollout.)
///
/// One-shot work uses scoped threads here rather than the persistent
/// per-step pool of `core::shard` — collection happens once per condition,
/// not once per env step.
pub fn collect_dataset_sharded<G, F>(
    make_env: F,
    steps: usize,
    seed: u64,
    feature: FeatureKind,
    num_workers: usize,
) -> InfluenceDataset
where
    G: GlobalEnv,
    F: Fn() -> G + Sync,
{
    let chunks = COLLECT_CHUNKS.min(steps.max(1));
    let collect_chunk = |c: usize| {
        // First `steps % chunks` chunks take one extra step (same balancing
        // rule as `core::shard::shard_ranges`).
        let share = steps / chunks + usize::from(c < steps % chunks);
        let chunk_seed = seed.wrapping_add((c as u64 + 1).wrapping_mul(0xA24BAED4963EE407));
        let mut env = make_env();
        collect_dataset(&mut env, share, chunk_seed, feature)
    };

    let mut parts: Vec<Option<InfluenceDataset>> = (0..chunks).map(|_| None).collect();
    let w = num_workers.max(1).min(chunks);
    if w == 1 {
        for (c, slot) in parts.iter_mut().enumerate() {
            *slot = Some(collect_chunk(c));
        }
    } else {
        // Round-robin the fixed chunk list over `w` workers; the chunk ->
        // dataset mapping (and the merge order below) never depends on `w`.
        let mut assignments: Vec<Vec<(usize, &mut Option<InfluenceDataset>)>> =
            (0..w).map(|_| Vec::new()).collect();
        for (c, slot) in parts.iter_mut().enumerate() {
            assignments[c % w].push((c, slot));
        }
        std::thread::scope(|scope| {
            for worker_chunks in assignments {
                let collect_chunk = &collect_chunk;
                scope.spawn(move || {
                    for (c, slot) in worker_chunks {
                        *slot = Some(collect_chunk(c));
                    }
                });
            }
        });
    }

    let mut merged = parts[0].take().expect("chunk 0 collected");
    for part in parts.iter().skip(1) {
        merged.extend_from(part.as_ref().expect("chunk collected"));
    }
    merged
}

/// Collect `steps` transitions (Algorithm 1) under the uniform-random
/// exploratory policy π₀. `d_t` is recorded *before* stepping; `u_t` is the
/// influence realization of that step's transition.
pub fn collect_dataset<G: GlobalEnv>(
    env: &mut G,
    steps: usize,
    seed: u64,
    feature: FeatureKind,
) -> InfluenceDataset {
    collect_dataset_with_policy(env, steps, seed, feature, |_env, rng, n_actions| {
        rng.below(n_actions)
    })
}

/// Generalized collector: `policy(env, rng, n_actions)` chooses the action
/// (used by the Appendix-B off-policy ablation, which evaluates the AIP on
/// data gathered under a *different* policy than π₀).
pub fn collect_dataset_with_policy<G: GlobalEnv>(
    env: &mut G,
    steps: usize,
    seed: u64,
    feature: FeatureKind,
    mut policy: impl FnMut(&G, &mut Pcg32, usize) -> usize,
) -> InfluenceDataset {
    let mut rng = Pcg32::new(seed, 77);
    let dim = match feature {
        FeatureKind::Dset => env.dset_dim(),
        FeatureKind::Alsh => env.alsh_dim(),
    };
    let mut data = InfluenceDataset::new(dim, env.num_influence_sources());
    let mut d = vec![0.0f32; dim];
    let mut u = vec![0.0f32; env.num_influence_sources()];
    let mut episode = 0u64;
    env.reset(seed.wrapping_add(episode));
    data.begin_episode();
    let n_actions = env.num_actions();
    for _ in 0..steps {
        match feature {
            FeatureKind::Dset => env.dset(&mut d),
            FeatureKind::Alsh => env.alsh(&mut d),
        }
        let action = policy(env, &mut rng, n_actions);
        let step = env.step(action);
        env.influence_sources(&mut u);
        data.push(&d, &u);
        if step.done {
            episode += 1;
            env.reset(seed.wrapping_add(episode).wrapping_mul(0x9E3779B97F4A7C15));
            data.begin_episode();
        }
    }
    data
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{TrafficConfig, WarehouseConfig};
    use crate::sim::traffic::TrafficGlobalEnv;
    use crate::sim::warehouse::WarehouseGlobalEnv;

    #[test]
    fn collects_requested_steps_with_episode_structure() {
        let mut env = TrafficGlobalEnv::new(&TrafficConfig::default());
        let data = collect_dataset(&mut env, 450, 1, FeatureKind::Dset);
        assert_eq!(data.total_steps(), 450);
        assert_eq!(data.dset_dim, 40);
        assert_eq!(data.u_dim, 4);
        // 450 steps at 200-step episodes → 3 episodes (last partial).
        assert_eq!(data.episodes.len(), 3);
        // Traffic actually arrives at the center intersection.
        let marg = data.u_marginals();
        assert!(marg.iter().sum::<f32>() > 0.0, "u never fired: {marg:?}");
    }

    #[test]
    fn alsh_features_are_wider() {
        let mut env = TrafficGlobalEnv::new(&TrafficConfig::default());
        let data = collect_dataset(&mut env, 100, 2, FeatureKind::Alsh);
        assert_eq!(data.dset_dim, 43);
    }

    #[test]
    fn warehouse_collection_sees_neighbors() {
        let mut env = WarehouseGlobalEnv::new(&WarehouseConfig::default());
        let data = collect_dataset(&mut env, 1000, 3, FeatureKind::Dset);
        assert_eq!(data.dset_dim, 24);
        assert_eq!(data.u_dim, 12);
        let total: f32 = data.u_marginals().iter().sum();
        assert!(total > 0.0, "neighbor presence should register");
    }

    #[test]
    fn sharded_collection_is_worker_count_invariant() {
        let make = || TrafficGlobalEnv::new(&TrafficConfig::default());
        // The chunking is fixed, so the dataset is bitwise identical for
        // every worker count (only wall-clock changes) and the full step
        // budget is preserved.
        let reference = collect_dataset_sharded(make, 450, 5, FeatureKind::Dset, 1);
        assert_eq!(reference.total_steps(), 450);
        for w in [2usize, 3, 8, 64] {
            let other = collect_dataset_sharded(make, 450, 5, FeatureKind::Dset, w);
            assert_eq!(other.total_steps(), 450, "w={w}");
            assert_eq!(other.episodes.len(), reference.episodes.len(), "w={w}");
            for (a, b) in reference.episodes.iter().zip(&other.episodes) {
                assert_eq!(a.steps, b.steps);
                for t in 0..a.steps {
                    assert_eq!(a.d_row(&reference, t), b.d_row(&other, t), "w={w}");
                    assert_eq!(a.u_row(&reference, t), b.u_row(&other, t), "w={w}");
                }
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut env = TrafficGlobalEnv::new(&TrafficConfig::default());
            let data = collect_dataset(&mut env, 200, seed, FeatureKind::Dset);
            data.u_marginals()
        };
        assert_eq!(run(9), run(9));
    }
}
