//! Hand-rolled benchmark harness (criterion is not in the offline crate
//! set — DESIGN.md §6). `cargo bench` binaries use [`Bench`] to report
//! mean/p50/p95 wall-clock per iteration, plus free-form result tables for
//! the paper-figure benches (learning curves, runtime bars, CE losses).

use crate::util::stats::Summary;
use std::time::Instant;

/// Simple timing benchmark: warmup then `reps` timed runs of a closure.
pub struct Bench {
    name: String,
    warmup: usize,
    reps: usize,
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per iteration.
    pub summary: Summary,
    /// Iterations of work done per rep (for throughput reporting).
    pub items_per_rep: f64,
}

impl BenchResult {
    pub fn throughput(&self) -> f64 {
        self.items_per_rep / self.summary.mean
    }
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        Bench { name: name.to_string(), warmup: 3, reps: 10 }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    pub fn reps(mut self, n: usize) -> Self {
        self.reps = n;
        self
    }

    /// Run the closure; `items_per_rep` is the number of logical items each
    /// rep processes (e.g. simulator steps) for steps/sec reporting.
    pub fn run(&self, items_per_rep: f64, mut f: impl FnMut()) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        let summary = Summary::of(&times);
        let res = BenchResult { name: self.name.clone(), summary, items_per_rep };
        print_result(&res);
        res
    }
}

fn print_result(r: &BenchResult) {
    let s = &r.summary;
    println!(
        "bench {:<44} mean {:>10.4} ms  p50 {:>10.4} ms  p95 {:>10.4} ms  ({:.0} items/s)",
        r.name,
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p95 * 1e3,
        r.throughput()
    );
}

/// A labelled results table printed in a uniform format so each paper-figure
/// bench emits "the same rows the paper reports".
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "table arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) {
        let cells: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&cells);
    }

    /// Pretty-print with column alignment.
    pub fn print(&self) {
        println!("\n=== {} ===", self.title);
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut line = String::new();
            for c in 0..ncol {
                line.push_str(&format!("{:<w$}  ", cells[c], w = widths[c]));
            }
            println!("{}", line.trim_end());
        };
        fmt_row(&self.header);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
        for row in &self.rows {
            fmt_row(row);
        }
        println!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = Bench::new("noop").warmup(1).reps(5).run(100.0, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(r.summary.n, 5);
        assert!(r.summary.mean >= 0.0);
        assert!(r.throughput() > 0.0);
    }

    #[test]
    fn table_accepts_matching_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print();
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into()]);
    }
}
