//! Benchmark domains (paper §5): a microscopic traffic-control simulator
//! and a multi-robot warehouse-commissioning simulator, each with a global
//! simulator (GS) and the matching local simulator (LS).
//!
//! Both domains share a crucial design property: **the LS runs the exact
//! same local-dynamics code as the GS** — the GS is "LS + the rest of the
//! networked system". This guarantees the paper's premise that the local
//! simulator reproduces the local transition function exactly, so the only
//! source of sim-to-real (sim-to-GS) gap is the influence distribution.

pub mod traffic;
pub mod warehouse;
