//! Traffic-light state and the actuated (gap-out) baseline controller.
//!
//! The paper's non-agent intersections run "fixed actuators that use
//! sensors to adapt to the traffic" (policies extensively optimized by Wu
//! et al. 2017). Our equivalent is the classic gap-out actuated controller:
//! hold green while vehicles keep arriving near the stop line, switch when
//! a gap appears (after a minimum green) or a maximum green elapses while
//! the cross street has demand.

use super::network::{Network, DIRS};
use crate::util::{StateReader, StateWriter};

/// Two-phase light: which axis currently has green.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LightPhase {
    /// North/South approaches green.
    Vertical,
    /// East/West approaches green.
    Horizontal,
}

impl LightPhase {
    pub fn is_vertical(self) -> bool {
        matches!(self, LightPhase::Vertical)
    }

    pub fn flipped(self) -> LightPhase {
        match self {
            LightPhase::Vertical => LightPhase::Horizontal,
            LightPhase::Horizontal => LightPhase::Vertical,
        }
    }
}

/// Per-intersection light state.
#[derive(Debug, Clone, Copy)]
pub struct LightState {
    pub phase: LightPhase,
    /// Ticks spent in the current phase.
    pub elapsed: usize,
}

impl LightState {
    pub fn new(phase: LightPhase) -> LightState {
        LightState { phase, elapsed: 0 }
    }

    /// Serialize the light for checkpointing.
    pub fn save_state(&self, out: &mut StateWriter) {
        out.u8(match self.phase {
            LightPhase::Vertical => 0,
            LightPhase::Horizontal => 1,
        });
        out.usize(self.elapsed);
    }

    /// Restore state written by [`LightState::save_state`].
    pub fn load_state(&mut self, r: &mut StateReader) -> crate::Result<()> {
        self.phase = match r.u8()? {
            0 => LightPhase::Vertical,
            1 => LightPhase::Horizontal,
            other => anyhow::bail!("corrupt state: light phase byte {other}"),
        };
        self.elapsed = r.usize()?;
        Ok(())
    }

    /// Apply a keep(0)/switch(1) action, honoring the minimum green time.
    /// Returns true if the phase actually switched.
    pub fn apply_action(&mut self, action: usize, min_green: usize) -> bool {
        if action == 1 && self.elapsed >= min_green {
            self.phase = self.phase.flipped();
            self.elapsed = 0;
            true
        } else {
            self.elapsed += 1;
            false
        }
    }
}

/// Gap-out actuated controller for one intersection.
#[derive(Debug, Clone)]
pub struct ActuatedController {
    pub min_green: usize,
    pub max_green: usize,
    /// How many cells upstream of the stop line count as "an approaching
    /// vehicle" for gap detection.
    pub detector_cells: usize,
}

impl ActuatedController {
    pub fn new(min_green: usize, max_green: usize) -> ActuatedController {
        ActuatedController { min_green, max_green, detector_cells: 3 }
    }

    /// Demand on the approaches of `node` served by `vertical` phase:
    /// vehicles within `detector_cells` of the stop line.
    fn demand(&self, net: &Network, node: usize, vertical: bool) -> bool {
        for d in DIRS {
            if d.is_vertical() != vertical {
                continue;
            }
            if let Some(link) = net.nodes[node].incoming[d.index()] {
                let cells = &net.links[link].cells;
                let len = cells.len();
                let lo = len.saturating_sub(self.detector_cells);
                if cells[lo..].iter().any(|c| c.is_some()) {
                    return true;
                }
            }
        }
        false
    }

    /// Decide keep(0)/switch(1) for `node` given the current light state.
    pub fn decide(&self, net: &Network, node: usize, light: &LightState) -> usize {
        if light.elapsed < self.min_green {
            return 0;
        }
        let green_demand = self.demand(net, node, light.phase.is_vertical());
        let red_demand = self.demand(net, node, !light.phase.is_vertical());
        if !red_demand {
            return 0; // nothing to serve on the cross street
        }
        if !green_demand {
            return 1; // gap-out: green direction has cleared
        }
        if light.elapsed >= self.max_green {
            return 1; // max-out: force the switch
        }
        0
    }
}

#[cfg(test)]
mod tests {
    use super::super::network::single_intersection;
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn apply_action_honors_min_green() {
        let mut l = LightState::new(LightPhase::Vertical);
        assert!(!l.apply_action(1, 3), "switch before min green must be ignored");
        l.elapsed = 3;
        assert!(l.apply_action(1, 3));
        assert_eq!(l.phase, LightPhase::Horizontal);
        assert_eq!(l.elapsed, 0);
    }

    #[test]
    fn keep_increments_elapsed() {
        let mut l = LightState::new(LightPhase::Vertical);
        l.apply_action(0, 3);
        l.apply_action(0, 3);
        assert_eq!(l.elapsed, 2);
        assert_eq!(l.phase, LightPhase::Vertical);
    }

    #[test]
    fn gap_out_switches_when_cross_demand_only() {
        let (mut net, inc, _) = single_intersection(6, 1.0);
        let mut rng = Pcg32::seeded(1);
        // Put a car on the E (horizontal) approach at the stop line.
        net.spawn(inc[1], &mut rng);
        for _ in 0..6 {
            net.tick(&[true], &mut rng); // vertical green: E car queues up
        }
        let ctrl = ActuatedController::new(2, 10);
        let light = LightState { phase: LightPhase::Vertical, elapsed: 5 };
        assert_eq!(ctrl.decide(&net, 0, &light), 1, "no vertical demand, horizontal queued");
    }

    #[test]
    fn holds_green_when_serving_traffic_and_under_max() {
        let (mut net, inc, _) = single_intersection(6, 1.0);
        let mut rng = Pcg32::seeded(2);
        // Demand on both axes near the stop line: advance both cars into
        // detector range without letting either cross (4 < stopline index 5).
        net.spawn(inc[0], &mut rng);
        net.spawn(inc[1], &mut rng);
        for _ in 0..4 {
            net.tick(&[true], &mut rng);
        }
        let ctrl = ActuatedController::new(2, 10);
        let light = LightState { phase: LightPhase::Vertical, elapsed: 5 };
        assert_eq!(ctrl.decide(&net, 0, &light), 0, "green still serving, not maxed");
        let maxed = LightState { phase: LightPhase::Vertical, elapsed: 10 };
        assert_eq!(ctrl.decide(&net, 0, &maxed), 1, "max-out with cross demand");
    }

    #[test]
    fn no_cross_demand_never_switches() {
        let (net, _, _) = single_intersection(6, 1.0);
        let ctrl = ActuatedController::new(2, 10);
        let light = LightState { phase: LightPhase::Vertical, elapsed: 100 };
        assert_eq!(ctrl.decide(&net, 0, &light), 0);
    }
}
