//! Traffic-control domain (paper §5.2).
//!
//! A grid of signalized intersections connected by one-way cell lanes
//! (cellular-automaton car dynamics, v_max = 1 cell/step). This replaces
//! the paper's SUMO + Flow stack — see DESIGN.md §6 for why the
//! substitution preserves the behaviour the experiments measure.
//!
//! * [`global::TrafficGlobalEnv`] — the GS: the full `grid × grid` network.
//!   Non-agent intersections run the actuated (gap-out) controller; the
//!   agent controls one intersection's lights.
//! * [`local::TrafficLocalEnv`] — the LS: the agent's intersection only,
//!   with its four incoming lanes fed by influence-source samples.
//!
//! Influence sources `u_t ∈ {0,1}^4`: whether a car enters each of the four
//! incoming lanes of the agent's intersection during step `t`. The d-set
//! `d_t` is the binary occupancy of the four incoming lanes — traffic-light
//! state is deliberately **excluded** to avoid the Appendix-B spurious
//! correlation (conditioning the AIP on the agent's own lights).

pub mod global;
pub mod lights;
pub mod local;
pub mod network;

pub use global::TrafficGlobalEnv;
pub use lights::{ActuatedController, LightPhase};
pub use local::TrafficLocalEnv;
pub use network::{Car, Dir, Link, Network, Turn};

use crate::dbn::Dag;

/// Number of influence sources (one per incoming lane of the agent
/// intersection).
pub const NUM_INFLUENCE: usize = 4;

/// Build the (coarse, per-lane) DBN of the traffic local-POMDP and verify
/// that lane occupancy d-separates the influence sources from the rest of
/// the ALSH — mirroring the paper's hand-designed d-set. Nodes per step:
/// `lane{i}_t` (occupancy of incoming lane i), `light_t`, `a_t`,
/// `u{i}_t` (arrival on lane i), `up{i}_t` (upstream neighborhood state).
pub fn traffic_dbn(t_max: usize) -> Dag {
    let mut g = Dag::new();
    for t in 0..t_max {
        for i in 0..4 {
            g.node(&format!("lane{i}_{t}"));
            g.node(&format!("u{i}_{t}"));
            g.node(&format!("up{i}_{t}"));
        }
        g.node(&format!("light_{t}"));
        g.node(&format!("a_{t}"));
        if t + 1 < t_max {
            let t1 = t + 1;
            for i in 0..4 {
                // Lane occupancy evolves from itself, the light and arrivals.
                g.edge(&format!("lane{i}_{t}"), &format!("lane{i}_{t1}"));
                g.edge(&format!("light_{t}"), &format!("lane{i}_{t1}"));
                g.edge(&format!("u{i}_{t}"), &format!("lane{i}_{t1}"));
                // Arrivals are produced by the upstream network state.
                g.edge(&format!("up{i}_{t}"), &format!("u{i}_{t1}"));
                g.edge(&format!("up{i}_{t}"), &format!("up{i}_{t1}"));
                // Cars the agent releases eventually reach upstream queues
                // of *other* intersections; within the 2-slice horizon this
                // feedback goes lane -> upstream-next.
                g.edge(&format!("lane{i}_{t}"), &format!("up{i}_{t1}"));
            }
            // Light follows the agent's action.
            g.edge(&format!("a_{t}"), &format!("light_{t1}"));
            g.edge(&format!("light_{t}"), &format!("light_{t1}"));
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The hand-specified d-set (lane occupancies) must d-separate u_{t+1}
    /// from the agent's past actions/lights given the DBN above.
    #[test]
    fn lane_occupancy_is_a_dset() {
        let g = traffic_dbn(3);
        // Predict u0_2. Conditioning on lane histories (t=0,1):
        let dset: Vec<&str> = Box::leak(Box::new([
            "lane0_0", "lane1_0", "lane2_0", "lane3_0", "lane0_1", "lane1_1", "lane2_1",
            "lane3_1",
        ]))
        .to_vec();
        // ALSH remainder: actions + lights.
        let rest = ["a_0", "light_0", "light_1"];
        let sep = g.d_separated_names(&["u0_2"], &rest, &dset).unwrap();
        assert!(sep, "lane occupancy history should d-separate u from actions/lights");
    }

    /// Conditioning on the *lights* instead of lane occupancy does NOT
    /// separate — the Appendix-B confounding scenario.
    #[test]
    fn lights_alone_are_not_a_dset() {
        let g = traffic_dbn(3);
        let sep = g
            .d_separated_names(&["u0_2"], &["lane0_0"], &["light_0", "light_1", "a_0"])
            .unwrap();
        assert!(!sep);
    }
}
