//! The traffic **global simulator** (GS): the full grid network. Slow by
//! construction (cost scales with the whole city), exact by construction.

use super::lights::{ActuatedController, LightPhase, LightState};
use super::network::{grid_network, source_links, Network, DIRS};
use super::NUM_INFLUENCE;
use crate::config::TrafficConfig;
use crate::core::{Environment, GlobalEnv, Step};
use crate::util::{Pcg32, StateReader, StateWriter};

/// Grid coordinates of the agent's intersection for the paper's two
/// highlighted intersections (Fig 2): 1 = the central intersection,
/// 2 = an off-center one (different coupling with the boundary).
pub fn agent_node_coords(which: usize, grid: usize) -> (usize, usize) {
    match which {
        1 => (grid / 2, grid / 2),
        2 => (1, 1),
        _ => panic!("agent_intersection must be 1 or 2"),
    }
}

pub struct TrafficGlobalEnv {
    cfg: TrafficConfig,
    net: Network,
    lights: Vec<LightState>,
    actuated: ActuatedController,
    sources: Vec<usize>,
    agent_node: usize,
    /// Agent's incoming links in `DIRS` order — the local region.
    agent_incoming: [usize; 4],
    rng: Pcg32,
    t: usize,
    /// Influence-source realizations of the last step.
    last_u: [bool; NUM_INFLUENCE],
    /// Action applied at the last step (part of the full ALSH features).
    last_action: usize,
}

impl TrafficGlobalEnv {
    pub fn new(cfg: &TrafficConfig) -> TrafficGlobalEnv {
        let net = grid_network(cfg.grid, cfg.lane_len, cfg.p_straight);
        let sources = source_links(&net);
        let (r, c) = agent_node_coords(cfg.agent_intersection, cfg.grid);
        let agent_node = r * cfg.grid + c;
        let mut agent_incoming = [0usize; 4];
        for d in DIRS {
            agent_incoming[d.index()] =
                net.nodes[agent_node].incoming[d.index()].expect("agent node incoming");
        }
        let lights = vec![LightState::new(LightPhase::Vertical); cfg.grid * cfg.grid];
        TrafficGlobalEnv {
            cfg: cfg.clone(),
            net,
            lights,
            actuated: ActuatedController::new(cfg.min_green, cfg.actuated_max_green),
            sources,
            agent_node,
            agent_incoming,
            rng: Pcg32::seeded(0),
            t: 0,
            last_u: [false; NUM_INFLUENCE],
            last_action: 0,
        }
    }

    pub fn agent_node(&self) -> usize {
        self.agent_node
    }

    pub fn agent_incoming(&self) -> &[usize; 4] {
        &self.agent_incoming
    }

    pub fn network(&self) -> &Network {
        &self.net
    }

    /// What the actuated baseline controller would do at the agent's
    /// intersection right now (the paper's black-line baseline in Fig 3).
    pub fn actuated_action(&self) -> usize {
        self.actuated.decide(&self.net, self.agent_node, &self.lights[self.agent_node])
    }

}

impl Environment for TrafficGlobalEnv {
    fn obs_dim(&self) -> usize {
        4 * self.cfg.lane_len + 2
    }

    fn num_actions(&self) -> usize {
        2 // keep / switch
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Pcg32::seeded(seed);
        self.net.clear();
        for l in &mut self.lights {
            *l = LightState::new(LightPhase::Vertical);
        }
        self.t = 0;
        self.last_u = [false; NUM_INFLUENCE];
        self.last_action = 0;
    }

    fn observe(&self, out: &mut [f32]) {
        let d = 4 * self.cfg.lane_len;
        self.net.occupancy_into(&self.agent_incoming, &mut out[..d]);
        let phase = self.lights[self.agent_node].phase;
        out[d] = if phase.is_vertical() { 1.0 } else { 0.0 };
        out[d + 1] = if phase.is_vertical() { 0.0 } else { 1.0 };
    }

    fn step(&mut self, action: usize) -> Step {
        // 1. Lights: agent action at the agent node, actuated elsewhere.
        for n in 0..self.lights.len() {
            let a = if n == self.agent_node {
                action
            } else {
                self.actuated.decide(&self.net, n, &self.lights[n])
            };
            self.lights[n].apply_action(a, self.cfg.min_green);
        }
        self.last_action = action;

        // 2. Car dynamics: `substeps` microscopic ticks per control step
        //    (SUMO-style). Influence sources accumulate across ticks; the
        //    reward averages the moving fraction over the control interval.
        let green: Vec<bool> = self.lights.iter().map(|l| l.phase.is_vertical()).collect();
        self.last_u = [false; NUM_INFLUENCE];
        let (mut moved, mut total) = (0usize, 0usize);
        for _ in 0..self.cfg.substeps.max(1) {
            self.net.tick(&green, &mut self.rng);
            // Boundary inflow happens at the microscopic timescale.
            for i in 0..self.sources.len() {
                let s = self.sources[i];
                if self.rng.bernoulli(self.cfg.inflow_prob) {
                    self.net.spawn(s, &mut self.rng);
                }
            }
            // Arrivals at the agent's incoming lanes during this tick.
            for d in DIRS {
                self.last_u[d.index()] |= self.net.entered[self.agent_incoming[d.index()]];
            }
            let s = self.net.stats_over(&self.agent_incoming);
            moved += s.moved;
            total += s.total;
        }

        self.t += 1;
        let reward = if total == 0 { 1.0 } else { moved as f32 / total as f32 };
        Step { reward, done: self.t >= self.cfg.episode_len }
    }

    fn save_state(&self, out: &mut StateWriter) -> crate::Result<()> {
        self.net.save_state(out);
        out.usize(self.lights.len());
        for light in &self.lights {
            light.save_state(out);
        }
        let (s, inc) = self.rng.state();
        out.u64(s);
        out.u64(inc);
        out.usize(self.t);
        out.bools(&self.last_u);
        out.usize(self.last_action);
        Ok(())
    }

    fn load_state(&mut self, r: &mut StateReader) -> crate::Result<()> {
        self.net.load_state(r)?;
        let n = r.usize()?;
        anyhow::ensure!(
            n == self.lights.len(),
            "snapshot has {n} lights, env has {}",
            self.lights.len()
        );
        for light in &mut self.lights {
            light.load_state(r)?;
        }
        let (s, inc) = (r.u64()?, r.u64()?);
        self.rng = Pcg32::from_state(s, inc);
        self.t = r.usize()?;
        r.bools_into(&mut self.last_u)?;
        self.last_action = r.usize()?;
        Ok(())
    }
}

impl GlobalEnv for TrafficGlobalEnv {
    fn num_influence_sources(&self) -> usize {
        NUM_INFLUENCE
    }

    fn dset_dim(&self) -> usize {
        4 * self.cfg.lane_len
    }

    fn influence_sources(&self, out: &mut [f32]) {
        for (o, &u) in out.iter_mut().zip(&self.last_u) {
            *o = if u { 1.0 } else { 0.0 };
        }
    }

    fn dset(&self, out: &mut [f32]) {
        self.net.occupancy_into(&self.agent_incoming, out);
    }

    fn alsh_dim(&self) -> usize {
        // d-set + light phase one-hot + last action: the confounder-prone
        // extras of the full ALSH (Appendix B ablation).
        self.dset_dim() + 3
    }

    fn alsh(&self, out: &mut [f32]) {
        let d = self.dset_dim();
        self.dset(&mut out[..d]);
        let phase = self.lights[self.agent_node].phase;
        out[d] = if phase.is_vertical() { 1.0 } else { 0.0 };
        out[d + 1] = if phase.is_vertical() { 0.0 } else { 1.0 };
        out[d + 2] = self.last_action as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TrafficConfig {
        TrafficConfig::default()
    }

    #[test]
    fn dims_consistent() {
        let env = TrafficGlobalEnv::new(&cfg());
        assert_eq!(env.obs_dim(), 42);
        assert_eq!(env.dset_dim(), 40);
        assert_eq!(env.alsh_dim(), 43);
        assert_eq!(env.num_actions(), 2);
        assert_eq!(env.num_influence_sources(), 4);
    }

    #[test]
    fn episode_terminates_at_horizon() {
        let mut env = TrafficGlobalEnv::new(&cfg());
        env.reset(1);
        let mut done = false;
        let mut steps = 0;
        while !done {
            done = env.step(0).done;
            steps += 1;
            assert!(steps <= 200);
        }
        assert_eq!(steps, 200);
    }

    #[test]
    fn traffic_reaches_the_center() {
        let mut env = TrafficGlobalEnv::new(&cfg());
        env.reset(2);
        let mut any_u = false;
        let mut u = [0.0f32; 4];
        for _ in 0..150 {
            env.step(env.actuated_action());
            env.influence_sources(&mut u);
            if u.iter().any(|&x| x > 0.0) {
                any_u = true;
            }
        }
        assert!(any_u, "cars should eventually arrive at the center intersection");
        let mut dset = vec![0.0; env.dset_dim()];
        env.dset(&mut dset);
        assert!(dset.iter().sum::<f32>() > 0.0, "local box should contain cars");
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed: u64| {
            let mut env = TrafficGlobalEnv::new(&cfg());
            env.reset(seed);
            let mut rewards = Vec::new();
            for t in 0..100 {
                rewards.push(env.step((t / 11) % 2).reward);
            }
            rewards
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn observation_encodes_phase() {
        let mut env = TrafficGlobalEnv::new(&cfg());
        env.reset(3);
        let mut obs = vec![0.0; env.obs_dim()];
        env.observe(&mut obs);
        assert_eq!(&obs[40..], &[1.0, 0.0], "starts vertical");
        // Switch (min_green=3 → wait, then switch).
        for _ in 0..4 {
            env.step(0);
        }
        env.step(1);
        env.observe(&mut obs);
        assert_eq!(&obs[40..], &[0.0, 1.0]);
    }

    #[test]
    fn intersection_two_differs_from_one() {
        let mut c1 = cfg();
        c1.agent_intersection = 1;
        let mut c2 = cfg();
        c2.agent_intersection = 2;
        let e1 = TrafficGlobalEnv::new(&c1);
        let e2 = TrafficGlobalEnv::new(&c2);
        assert_ne!(e1.agent_node(), e2.agent_node());
    }

    #[test]
    fn rewards_bounded() {
        let mut env = TrafficGlobalEnv::new(&cfg());
        env.reset(4);
        for t in 0..200 {
            let s = env.step(t % 2);
            assert!((0.0..=1.0).contains(&s.reward), "reward={}", s.reward);
        }
    }
}
