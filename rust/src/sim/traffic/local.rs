//! The traffic **local simulator** (LS): the agent's intersection only.
//! Runs the identical `Network::tick` machinery as the GS over an 8-link
//! network; arrivals on the four incoming lanes are *injected* from an
//! influence-source realization (Algorithm 2) instead of simulated.

use super::lights::{LightPhase, LightState};
use super::network::{single_intersection, Network, DIRS};
use super::NUM_INFLUENCE;
use crate::config::TrafficConfig;
use crate::core::{LocalEnv, Step};
use crate::util::{Pcg32, StateReader, StateWriter};

pub struct TrafficLocalEnv {
    cfg: TrafficConfig,
    net: Network,
    incoming: [usize; 4],
    light: LightState,
    rng: Pcg32,
    t: usize,
}

impl TrafficLocalEnv {
    pub fn new(cfg: &TrafficConfig) -> TrafficLocalEnv {
        let (net, incoming, _outgoing) = single_intersection(cfg.lane_len, cfg.p_straight);
        TrafficLocalEnv {
            cfg: cfg.clone(),
            net,
            incoming,
            light: LightState::new(LightPhase::Vertical),
            rng: Pcg32::seeded(0),
            t: 0,
        }
    }

    pub fn network(&self) -> &Network {
        &self.net
    }
}

impl LocalEnv for TrafficLocalEnv {
    fn obs_dim(&self) -> usize {
        4 * self.cfg.lane_len + 2
    }

    fn num_actions(&self) -> usize {
        2
    }

    fn num_influence_sources(&self) -> usize {
        NUM_INFLUENCE
    }

    fn dset_dim(&self) -> usize {
        4 * self.cfg.lane_len
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Pcg32::seeded(seed);
        self.net.clear();
        self.light = LightState::new(LightPhase::Vertical);
        self.t = 0;
    }

    fn observe(&self, out: &mut [f32]) {
        let d = 4 * self.cfg.lane_len;
        self.net.occupancy_into(&self.incoming, &mut out[..d]);
        out[d] = if self.light.phase.is_vertical() { 1.0 } else { 0.0 };
        out[d + 1] = if self.light.phase.is_vertical() { 0.0 } else { 1.0 };
    }

    fn dset(&self, out: &mut [f32]) {
        self.net.occupancy_into(&self.incoming, out);
    }

    fn step_with_influence(&mut self, action: usize, influence: &[bool]) -> Step {
        debug_assert_eq!(influence.len(), NUM_INFLUENCE);
        self.light.apply_action(action, self.cfg.min_green);
        let green = [self.light.phase.is_vertical()];
        // Same microscopic substep count as the GS; the sampled arrivals
        // are injected at the end of the control interval (entry timing
        // within the interval is part of the IALS approximation).
        let (mut moved, mut total) = (0usize, 0usize);
        for _ in 0..self.cfg.substeps.max(1) {
            self.net.tick(&green, &mut self.rng);
            let s = self.net.stats_over(&self.incoming);
            moved += s.moved;
            total += s.total;
        }
        // Inject arrivals per the influence realization (Algorithm 2 l.7-9).
        for d in DIRS {
            if influence[d.index()] {
                self.net.spawn(self.incoming[d.index()], &mut self.rng);
            }
        }
        self.t += 1;
        let reward = if total == 0 { 1.0 } else { moved as f32 / total as f32 };
        Step { reward, done: self.t >= self.cfg.episode_len }
    }

    fn save_state(&self, out: &mut StateWriter) -> crate::Result<()> {
        self.net.save_state(out);
        self.light.save_state(out);
        let (s, inc) = self.rng.state();
        out.u64(s);
        out.u64(inc);
        out.usize(self.t);
        Ok(())
    }

    fn load_state(&mut self, r: &mut StateReader) -> crate::Result<()> {
        self.net.load_state(r)?;
        self.light.load_state(r)?;
        let (s, inc) = (r.u64()?, r.u64()?);
        self.rng = Pcg32::from_state(s, inc);
        self.t = r.usize()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::global::TrafficGlobalEnv;
    use super::*;
    use crate::core::{Environment, GlobalEnv};

    fn cfg() -> TrafficConfig {
        TrafficConfig::default()
    }

    #[test]
    fn dims_match_global() {
        let ls = TrafficLocalEnv::new(&cfg());
        let gs = TrafficGlobalEnv::new(&cfg());
        assert_eq!(ls.obs_dim(), gs.obs_dim());
        assert_eq!(ls.dset_dim(), gs.dset_dim());
        assert_eq!(ls.num_actions(), gs.num_actions());
        assert_eq!(ls.num_influence_sources(), gs.num_influence_sources());
    }

    #[test]
    fn influence_injects_cars() {
        let mut ls = TrafficLocalEnv::new(&cfg());
        ls.reset(1);
        ls.step_with_influence(0, &[true, true, false, false]);
        let mut d = vec![0.0; ls.dset_dim()];
        ls.dset(&mut d);
        assert_eq!(d.iter().sum::<f32>(), 2.0);
    }

    #[test]
    fn no_influence_no_cars() {
        let mut ls = TrafficLocalEnv::new(&cfg());
        ls.reset(2);
        for _ in 0..50 {
            ls.step_with_influence(0, &[false; 4]);
        }
        let mut d = vec![0.0; ls.dset_dim()];
        ls.dset(&mut d);
        assert_eq!(d.iter().sum::<f32>(), 0.0);
    }

    #[test]
    fn episode_length_respected() {
        let mut ls = TrafficLocalEnv::new(&cfg());
        ls.reset(3);
        for t in 1..=200 {
            let s = ls.step_with_influence(0, &[false; 4]);
            assert_eq!(s.done, t == 200);
        }
    }

    /// Key fidelity test (the paper's premise): replaying the GS's realized
    /// influence sequence and actions through the LS reproduces the GS's
    /// local region. Turns are made deterministic (p_straight = 1) so the
    /// only coupling left is the influence itself.
    #[test]
    fn ls_replays_gs_local_region() {
        let mut c = cfg();
        c.p_straight = 1.0;
        c.substeps = 1; // exact-fidelity regime (entry timing is exact)
        let mut gs = TrafficGlobalEnv::new(&c);
        let mut ls = TrafficLocalEnv::new(&c);
        gs.reset(11);
        ls.reset(99); // different seed: LS randomness must not matter here

        let horizon = 120;
        let mut u = [0.0f32; 4];
        let mut gs_d = vec![0.0; gs.dset_dim()];
        let mut ls_d = vec![0.0; gs.dset_dim()];
        let mut agree = 0usize;
        let mut total = 0usize;
        for t in 0..horizon {
            let action = (t / 9) % 2; // arbitrary fixed policy
            gs.step(action);
            gs.influence_sources(&mut u);
            let ub: Vec<bool> = u.iter().map(|&x| x > 0.5).collect();
            ls.step_with_influence(action, &ub);

            gs.dset(&mut gs_d);
            ls.dset(&mut ls_d);
            for (a, b) in gs_d.iter().zip(&ls_d) {
                total += 1;
                if a == b {
                    agree += 1;
                }
            }
        }
        let frac = agree as f64 / total as f64;
        assert!(
            frac > 0.995,
            "LS should track the GS local region almost exactly (agreement {frac:.4})"
        );
    }

    #[test]
    fn reward_bounded_and_flows() {
        let mut ls = TrafficLocalEnv::new(&cfg());
        ls.reset(5);
        let mut rng = crate::util::Pcg32::seeded(17);
        let mut total = 0.0;
        for t in 0..200 {
            let u = [
                rng.bernoulli(0.3),
                rng.bernoulli(0.3),
                rng.bernoulli(0.3),
                rng.bernoulli(0.3),
            ];
            let s = ls.step_with_influence((t / 8) % 2, &u);
            assert!((0.0..=1.0).contains(&s.reward));
            total += s.reward;
        }
        assert!(total > 0.0);
    }
}
