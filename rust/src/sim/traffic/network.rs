//! Core traffic machinery shared verbatim by the GS and the LS: lane
//! links, cellular-automaton car movement, intersection crossing.
//!
//! Dynamics (one tick):
//! 1. **Crossing** — a car at a stop line crosses into its target link's
//!    entry cell if its approach has green and the entry cell is free and
//!    unclaimed this tick.
//! 2. **Advance** — within each link, cars move one cell forward into free
//!    cells (processed downstream-first so platoons compress).
//! 3. **Inflow** — source links spawn a car at their entry cell with the
//!    configured probability (GS boundary) or per the supplied influence
//!    realization (LS).

use crate::util::{Pcg32, StateReader, StateWriter};

/// Compass direction. For an incoming link this is the **approach side**:
/// the side of the intersection the link arrives at (a link whose cars
/// travel southward arrives at the north side → `Dir::N`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Dir {
    N = 0,
    E = 1,
    S = 2,
    W = 3,
}

pub const DIRS: [Dir; 4] = [Dir::N, Dir::E, Dir::S, Dir::W];

impl Dir {
    pub fn opposite(self) -> Dir {
        match self {
            Dir::N => Dir::S,
            Dir::E => Dir::W,
            Dir::S => Dir::N,
            Dir::W => Dir::E,
        }
    }

    /// Is this approach served by the vertical (N/S) phase?
    pub fn is_vertical(self) -> bool {
        matches!(self, Dir::N | Dir::S)
    }

    pub fn index(self) -> usize {
        self as usize
    }
}

/// Turn decision a car makes at the next intersection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Turn {
    Straight,
    Left,
    Right,
}

/// Departure side for a car arriving at `approach` and taking `turn`.
/// (Arriving at the N side means heading south; a left turn then heads
/// east, i.e. exits through the E side.)
pub fn departure_side(approach: Dir, turn: Turn) -> Dir {
    match turn {
        Turn::Straight => approach.opposite(),
        Turn::Left => match approach {
            Dir::N => Dir::E,
            Dir::E => Dir::S,
            Dir::S => Dir::W,
            Dir::W => Dir::N,
        },
        Turn::Right => match approach {
            Dir::N => Dir::W,
            Dir::E => Dir::N,
            Dir::S => Dir::E,
            Dir::W => Dir::S,
        },
    }
}

/// A car occupying one lane cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Car {
    /// Turn it will take at the downstream intersection of its current link.
    pub turn: Turn,
    /// Did it advance during the last tick (speed 1) — drives the reward.
    pub moved: bool,
}

/// Where a link comes from / leads to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Endpoint {
    /// An intersection in this network.
    Node(usize),
    /// The world outside the modelled region (boundary inflow / sink).
    Boundary,
}

/// A one-way lane of `len` cells. Cell `0` is the upstream entry, cell
/// `len-1` is the stop line at the downstream endpoint.
#[derive(Debug, Clone)]
pub struct Link {
    pub cells: Vec<Option<Car>>,
    pub from: Endpoint,
    pub to: Endpoint,
    /// Approach side at the downstream intersection (meaningful when
    /// `to == Node(_)`), and departure side at the upstream one.
    pub approach: Dir,
}

impl Link {
    pub fn new(len: usize, from: Endpoint, to: Endpoint, approach: Dir) -> Link {
        Link { cells: vec![None; len], from, to, approach }
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn count(&self) -> usize {
        self.cells.iter().filter(|c| c.is_some()).count()
    }

    pub fn entry_free(&self) -> bool {
        self.cells[0].is_none()
    }

    pub fn stopline(&self) -> Option<&Car> {
        self.cells[self.cells.len() - 1].as_ref()
    }
}

/// An intersection: its incoming/outgoing link ids per side.
#[derive(Debug, Clone)]
pub struct NodeLinks {
    /// `incoming[d]` = link arriving at side `d` (approach d).
    pub incoming: [Option<usize>; 4],
    /// `outgoing[d]` = link departing through side `d`.
    pub outgoing: [Option<usize>; 4],
}

impl NodeLinks {
    pub fn empty() -> NodeLinks {
        NodeLinks { incoming: [None; 4], outgoing: [None; 4] }
    }
}

/// Result of one network tick, per intersection of interest.
#[derive(Debug, Clone, Default)]
pub struct TickStats {
    /// Cars that moved this tick / total cars, over the watched links.
    pub moved: usize,
    pub total: usize,
    /// Cars that crossed the watched intersection this tick.
    pub crossed: usize,
}

/// A lane network plus turn-probability parameters. Both the GS (grid) and
/// the LS (single intersection) are instances of this struct and share
/// [`Network::tick`].
#[derive(Debug, Clone)]
pub struct Network {
    pub links: Vec<Link>,
    pub nodes: Vec<NodeLinks>,
    pub p_straight: f32,
    /// Per-tick scratch: entry-cell claims to prevent two crossings into
    /// the same cell (index = link id).
    claims: Vec<bool>,
    /// Entries written during the last tick: `entered[l]` is true if a car
    /// appeared at link `l`'s entry cell (crossing or inflow). Used to
    /// extract influence-source realizations in the GS.
    pub entered: Vec<bool>,
}

impl Network {
    pub fn new(links: Vec<Link>, nodes: Vec<NodeLinks>, p_straight: f32) -> Network {
        let n = links.len();
        Network { links, nodes, p_straight, claims: vec![false; n], entered: vec![false; n] }
    }

    pub fn sample_turn(p_straight: f32, rng: &mut Pcg32) -> Turn {
        let x = rng.f32();
        if x < p_straight {
            Turn::Straight
        } else if x < p_straight + (1.0 - p_straight) * 0.5 {
            Turn::Left
        } else {
            Turn::Right
        }
    }

    pub fn total_cars(&self) -> usize {
        self.links.iter().map(|l| l.count()).sum()
    }

    pub fn clear(&mut self) {
        for link in &mut self.links {
            link.cells.fill(None);
        }
        self.entered.fill(false);
    }

    /// Advance the network one tick.
    ///
    /// * `green_vertical[node]` — true if node `node` currently gives green
    ///   to its vertical (N/S) approaches.
    /// * `rng` — drives turn decisions for crossing cars.
    ///
    /// Returns the number of cars that exited through boundary sinks.
    pub fn tick(&mut self, green_vertical: &[bool], rng: &mut Pcg32) -> usize {
        debug_assert_eq!(green_vertical.len(), self.nodes.len());
        self.claims.fill(false);
        self.entered.fill(false);
        for link in &mut self.links {
            for cell in link.cells.iter_mut().flatten() {
                cell.moved = false;
            }
        }
        let mut exited = 0usize;

        // Phase 1: crossings, fixed approach order N,E,S,W per node.
        for node in 0..self.nodes.len() {
            for d in DIRS {
                let Some(in_id) = self.nodes[node].incoming[d.index()] else { continue };
                let green = green_vertical[node] == d.is_vertical();
                if !green {
                    continue;
                }
                let last = self.links[in_id].len() - 1;
                let Some(car) = self.links[in_id].cells[last] else { continue };
                let out_side = departure_side(d, car.turn);
                match self.nodes[node].outgoing[out_side.index()] {
                    Some(out_id) => {
                        if self.links[out_id].entry_free() && !self.claims[out_id] {
                            self.claims[out_id] = true;
                            self.links[in_id].cells[last] = None;
                            // New link → new turn decision for the next node.
                            let turn = Self::sample_turn(self.p_straight, rng);
                            self.links[out_id].cells[0] = Some(Car { turn, moved: true });
                            self.entered[out_id] = true;
                        }
                    }
                    None => {
                        // Departure side leads off the modelled region.
                        self.links[in_id].cells[last] = None;
                        exited += 1;
                    }
                }
            }
        }

        // Phase 2: within-link advance, downstream-first. Cars that already
        // crossed in phase 1 (moved == true) stay put — v_max is 1 cell/tick.
        for link in &mut self.links {
            let len = link.cells.len();
            for i in (0..len - 1).rev() {
                let can_move = matches!(link.cells[i], Some(c) if !c.moved);
                if can_move && link.cells[i + 1].is_none() {
                    let mut car = link.cells[i].take().unwrap();
                    car.moved = true;
                    link.cells[i + 1] = Some(car);
                }
            }
            // A car that reaches the end of a sink link (to == Boundary)
            // leaves the world.
            if matches!(link.to, Endpoint::Boundary) {
                if link.cells[len - 1].take().is_some() {
                    exited += 1;
                }
            }
        }
        exited
    }

    /// Spawn a car at the entry of `link` (inflow / influence realization).
    /// Returns false if the entry cell is occupied (arrival is lost — the
    /// queue spills outside the modelled region, same as SUMO's insertion
    /// backlog behaviour on saturated boundaries).
    pub fn spawn(&mut self, link: usize, rng: &mut Pcg32) -> bool {
        if self.links[link].entry_free() && !self.entered[link] {
            let turn = Self::sample_turn(self.p_straight, rng);
            self.links[link].cells[0] = Some(Car { turn, moved: true });
            self.entered[link] = true;
            true
        } else {
            false
        }
    }

    /// Movement stats over a set of links (the agent's local box).
    pub fn stats_over(&self, link_ids: &[usize]) -> TickStats {
        let mut s = TickStats::default();
        for &id in link_ids {
            for cell in self.links[id].cells.iter().flatten() {
                s.total += 1;
                if cell.moved {
                    s.moved += 1;
                }
            }
        }
        s
    }

    /// Serialize the dynamic state (cell occupancy + `entered` flags) for
    /// checkpointing. Topology and parameters are rebuilt from config, and
    /// `claims` is per-tick scratch cleared at the top of [`Network::tick`],
    /// so neither is stored. Each cell packs into one byte: 0 = empty, else
    /// bit 0 set, bits 1–2 = turn, bit 3 = moved.
    pub fn save_state(&self, out: &mut StateWriter) {
        out.usize(self.links.len());
        for link in &self.links {
            out.usize(link.cells.len());
            for cell in &link.cells {
                out.u8(match cell {
                    None => 0,
                    Some(car) => {
                        let turn = match car.turn {
                            Turn::Straight => 0u8,
                            Turn::Left => 1,
                            Turn::Right => 2,
                        };
                        1 | (turn << 1) | ((car.moved as u8) << 3)
                    }
                });
            }
        }
        out.bools(&self.entered);
    }

    /// Restore state written by [`Network::save_state`] into a network with
    /// identical topology.
    pub fn load_state(&mut self, r: &mut StateReader) -> crate::Result<()> {
        let n = r.usize()?;
        anyhow::ensure!(
            n == self.links.len(),
            "snapshot has {n} links, network has {}",
            self.links.len()
        );
        for link in &mut self.links {
            let len = r.usize()?;
            anyhow::ensure!(
                len == link.len(),
                "snapshot link len {len}, network link len {}",
                link.len()
            );
            for cell in &mut link.cells {
                let b = r.u8()?;
                *cell = if b == 0 {
                    None
                } else {
                    anyhow::ensure!(b & 1 == 1 && b < 16, "corrupt state: car byte {b}");
                    let turn = match (b >> 1) & 3 {
                        0 => Turn::Straight,
                        1 => Turn::Left,
                        2 => Turn::Right,
                        _ => anyhow::bail!("corrupt state: turn bits in car byte {b}"),
                    };
                    Some(Car { turn, moved: (b >> 3) & 1 == 1 })
                };
            }
        }
        r.bools_into(&mut self.entered)?;
        Ok(())
    }

    /// Write binary occupancy of `link_ids` (concatenated, entry→stopline)
    /// into `out`.
    pub fn occupancy_into(&self, link_ids: &[usize], out: &mut [f32]) {
        let mut k = 0;
        for &id in link_ids {
            for cell in &self.links[id].cells {
                out[k] = if cell.is_some() { 1.0 } else { 0.0 };
                k += 1;
            }
        }
        debug_assert_eq!(k, out.len());
    }
}

/// Build the single-intersection network used by the LS, and (as the local
/// region) embedded in the GS layout: four incoming source links and four
/// outgoing sink links of `lane_len` cells.
///
/// Returns `(network, incoming_ids, outgoing_ids)`, both indexed by `Dir`.
pub fn single_intersection(lane_len: usize, p_straight: f32) -> (Network, [usize; 4], [usize; 4]) {
    let mut links = Vec::new();
    let mut node = NodeLinks::empty();
    let mut incoming = [0usize; 4];
    let mut outgoing = [0usize; 4];
    for d in DIRS {
        let id = links.len();
        links.push(Link::new(lane_len, Endpoint::Boundary, Endpoint::Node(0), d));
        node.incoming[d.index()] = Some(id);
        incoming[d.index()] = id;
    }
    for d in DIRS {
        let id = links.len();
        links.push(Link::new(lane_len, Endpoint::Node(0), Endpoint::Boundary, d));
        node.outgoing[d.index()] = Some(id);
        outgoing[d.index()] = id;
    }
    (Network::new(links, vec![node], p_straight), incoming, outgoing)
}

/// Build a `grid × grid` lattice of intersections. Adjacent intersections
/// are connected by one link per direction; boundary sides get a source
/// (inflow) link and departures through boundary sides despawn via sink
/// links. Returns the network plus, for every node, nothing extra — use
/// [`Network::nodes`] to navigate.
pub fn grid_network(grid: usize, lane_len: usize, p_straight: f32) -> Network {
    assert!(grid >= 2);
    let node_id = |r: usize, c: usize| r * grid + c;
    let mut links: Vec<Link> = Vec::new();
    let mut nodes = vec![NodeLinks::empty(); grid * grid];

    // Internal links: for each ordered pair of adjacent nodes.
    for r in 0..grid {
        for c in 0..grid {
            let to = node_id(r, c);
            // For each side of (r,c), create the incoming link that arrives
            // at that side (so every incoming direction is covered once).
            for d in DIRS {
                let from_rc: Option<(usize, usize)> = match d {
                    Dir::N => r.checked_sub(1).map(|rr| (rr, c)),
                    Dir::S => (r + 1 < grid).then_some((r + 1, c)),
                    Dir::W => c.checked_sub(1).map(|cc| (r, cc)),
                    Dir::E => (c + 1 < grid).then_some((r, c + 1)),
                };
                let id = links.len();
                match from_rc {
                    Some((fr, fc)) => {
                        let from = node_id(fr, fc);
                        links.push(Link::new(
                            lane_len,
                            Endpoint::Node(from),
                            Endpoint::Node(to),
                            d,
                        ));
                        nodes[to].incoming[d.index()] = Some(id);
                        // This link departs `from` through the side facing
                        // `to`, which is the opposite of the approach side.
                        nodes[from].outgoing[d.opposite().index()] = Some(id);
                    }
                    None => {
                        // Boundary source feeding this side.
                        links.push(Link::new(lane_len, Endpoint::Boundary, Endpoint::Node(to), d));
                        nodes[to].incoming[d.index()] = Some(id);
                    }
                }
            }
        }
    }
    // Boundary sinks: any side with no outgoing link gets a sink so cars
    // can leave the grid (departures onto it despawn after traversing).
    for r in 0..grid {
        for c in 0..grid {
            let n = node_id(r, c);
            for d in DIRS {
                if nodes[n].outgoing[d.index()].is_none() {
                    let id = links.len();
                    links.push(Link::new(lane_len, Endpoint::Node(n), Endpoint::Boundary, d));
                    nodes[n].outgoing[d.index()] = Some(id);
                }
            }
        }
    }
    Network::new(links, nodes, p_straight)
}

/// Ids of the boundary *source* links of a grid network (for inflow).
pub fn source_links(net: &Network) -> Vec<usize> {
    net.links
        .iter()
        .enumerate()
        .filter(|(_, l)| matches!(l.from, Endpoint::Boundary) && matches!(l.to, Endpoint::Node(_)))
        .map(|(i, _)| i)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn departure_sides_are_consistent() {
        // Heading south (approach N): straight exits S, left exits E.
        assert_eq!(departure_side(Dir::N, Turn::Straight), Dir::S);
        assert_eq!(departure_side(Dir::N, Turn::Left), Dir::E);
        assert_eq!(departure_side(Dir::N, Turn::Right), Dir::W);
        // Every (approach, turn) pair exits through a side != approach.
        for d in DIRS {
            for t in [Turn::Straight, Turn::Left, Turn::Right] {
                assert_ne!(departure_side(d, t), d);
            }
        }
    }

    #[test]
    fn single_intersection_geometry() {
        let (net, inc, out) = single_intersection(10, 0.7);
        assert_eq!(net.links.len(), 8);
        assert_eq!(net.nodes.len(), 1);
        for d in DIRS {
            assert_eq!(net.links[inc[d.index()]].approach, d);
            assert!(matches!(net.links[inc[d.index()]].to, Endpoint::Node(0)));
            assert!(matches!(net.links[out[d.index()]].from, Endpoint::Node(0)));
        }
    }

    #[test]
    fn grid_geometry() {
        let g = 3;
        let net = grid_network(g, 5, 0.7);
        // Every node has 4 incoming and 4 outgoing links.
        for n in &net.nodes {
            assert!(n.incoming.iter().all(|l| l.is_some()));
            assert!(n.outgoing.iter().all(|l| l.is_some()));
        }
        // Interior link shared: node (0,0) outgoing east == node (0,1)
        // incoming west.
        let a = net.nodes[0].outgoing[Dir::E.opposite().opposite().index()];
        // (explicit) outgoing through the E side of (0,0):
        let out_e = net.nodes[0].outgoing[Dir::E.index()].unwrap();
        let in_w = net.nodes[1].incoming[Dir::W.index()].unwrap();
        assert_eq!(out_e, in_w);
        let _ = a;
        // Sources = 4 sides * grid boundary lanes = 4*g.
        assert_eq!(source_links(&net).len(), 4 * g);
    }

    #[test]
    fn cars_advance_and_compress() {
        let (mut net, inc, _) = single_intersection(5, 1.0);
        let lane = inc[Dir::N.index()];
        let mut rng = Pcg32::seeded(1);
        net.spawn(lane, &mut rng);
        // Red for vertical: car advances to the stop line then waits.
        for _ in 0..10 {
            net.tick(&[false], &mut rng);
        }
        assert!(net.links[lane].stopline().is_some());
        assert_eq!(net.links[lane].count(), 1);
    }

    #[test]
    fn green_lets_cars_cross_and_exit() {
        let (mut net, inc, _) = single_intersection(4, 1.0); // always straight
        let lane = inc[Dir::N.index()];
        let mut rng = Pcg32::seeded(2);
        net.spawn(lane, &mut rng);
        let mut exited = 0;
        for _ in 0..12 {
            exited += net.tick(&[true], &mut rng);
        }
        assert_eq!(exited, 1, "car should cross and leave via the S sink");
        assert_eq!(net.total_cars(), 0);
    }

    #[test]
    fn no_two_cars_share_a_cell_under_load() {
        let (mut net, inc, _) = single_intersection(6, 0.7);
        let mut rng = Pcg32::seeded(3);
        for t in 0..300 {
            let green_v = (t / 7) % 2 == 0;
            net.tick(&[green_v], &mut rng);
            for d in DIRS {
                if rng.bernoulli(0.5) {
                    net.spawn(inc[d.index()], &mut rng);
                }
            }
            // Invariant: each cell holds at most one car by construction of
            // Option — instead check conservation: count equals spawned - exited
            // implicitly via no panic + occupancy bounded by capacity.
            assert!(net.total_cars() <= 8 * 6);
        }
    }

    #[test]
    fn red_blocks_crossing() {
        let (mut net, inc, _) = single_intersection(3, 1.0);
        let lane = inc[Dir::E.index()]; // horizontal approach
        let mut rng = Pcg32::seeded(4);
        net.spawn(lane, &mut rng);
        for _ in 0..10 {
            net.tick(&[true], &mut rng); // vertical green → E is red
        }
        assert_eq!(net.links[lane].count(), 1, "car must still be waiting");
        assert!(net.links[lane].stopline().is_some());
    }

    #[test]
    fn entered_flags_record_arrivals() {
        let (mut net, inc, _) = single_intersection(4, 1.0);
        let mut rng = Pcg32::seeded(5);
        net.tick(&[false], &mut rng);
        assert!(!net.entered[inc[0]]);
        net.spawn(inc[0], &mut rng);
        assert!(net.entered[inc[0]]);
    }

    #[test]
    fn spawn_blocked_when_entry_occupied() {
        let (mut net, inc, _) = single_intersection(4, 1.0);
        let mut rng = Pcg32::seeded(6);
        assert!(net.spawn(inc[0], &mut rng));
        assert!(!net.spawn(inc[0], &mut rng), "same tick, cell now occupied");
    }

    #[test]
    fn grid_conservation() {
        let mut net = grid_network(3, 5, 0.7);
        let sources = source_links(&net);
        let mut rng = Pcg32::seeded(7);
        let mut spawned = 0usize;
        let mut exited = 0usize;
        for t in 0..400 {
            let phases: Vec<bool> = (0..net.nodes.len()).map(|n| (t + n) % 8 < 4).collect();
            exited += net.tick(&phases, &mut rng);
            for &s in &sources {
                if rng.bernoulli(0.1) && net.spawn(s, &mut rng) {
                    spawned += 1;
                }
            }
        }
        assert_eq!(spawned, exited + net.total_cars(), "car conservation");
        assert!(spawned > 50, "sanity: traffic actually flowed (spawned={spawned})");
    }
}
