//! Warehouse floor geometry (paper §5.3, Fig 4).
//!
//! `R × R` robots, each owning a `5 × 5` region; regions overlap at their
//! edges (stride 4), so the floor is `(4R+1) × (4R+1)` cells. The *item
//! cells* of a region are the 12 interior edge cells (3 per side, corners
//! excluded); each side's item shelf is shared with the adjacent region.

/// Global cell coordinate (row, col).
pub type Cell = (usize, usize);

/// Region side length — fixed at 5 by the paper's layout.
pub const REGION: usize = 5;
/// Region stride (regions overlap by one shared edge line).
pub const STRIDE: usize = REGION - 1;
/// Item cells per region: 3 per side.
pub const ITEMS_PER_REGION: usize = 12;

/// Geometry of the floor.
#[derive(Debug, Clone)]
pub struct Floor {
    /// Robots per side.
    pub robots: usize,
    /// Floor side length in cells.
    pub side: usize,
}

impl Floor {
    pub fn new(robots_per_side: usize) -> Floor {
        Floor { robots: robots_per_side, side: STRIDE * robots_per_side + 1 }
    }

    /// Top-left corner of region `(ri, rj)`.
    pub fn region_origin(&self, ri: usize, rj: usize) -> Cell {
        debug_assert!(ri < self.robots && rj < self.robots);
        (ri * STRIDE, rj * STRIDE)
    }

    /// The 12 item cells of region `(ri, rj)` in canonical order:
    /// top (3, left→right), right (3, top→bottom), bottom (3, left→right),
    /// left (3, top→bottom).
    pub fn item_cells(&self, ri: usize, rj: usize) -> [Cell; ITEMS_PER_REGION] {
        let (r0, c0) = self.region_origin(ri, rj);
        let mut out = [(0usize, 0usize); ITEMS_PER_REGION];
        let mut k = 0;
        for dc in 1..=3 {
            out[k] = (r0, c0 + dc); // top
            k += 1;
        }
        for dr in 1..=3 {
            out[k] = (r0 + dr, c0 + REGION - 1); // right
            k += 1;
        }
        for dc in 1..=3 {
            out[k] = (r0 + REGION - 1, c0 + dc); // bottom
            k += 1;
        }
        for dr in 1..=3 {
            out[k] = (r0 + dr, c0); // left
            k += 1;
        }
        out
    }

    /// All shelf cells on the floor (union of all regions' item cells),
    /// deduplicated, as a boolean mask indexed by `cell_id`.
    pub fn shelf_mask(&self) -> Vec<bool> {
        let mut mask = vec![false; self.side * self.side];
        for ri in 0..self.robots {
            for rj in 0..self.robots {
                for cell in self.item_cells(ri, rj) {
                    mask[self.cell_id(cell)] = true;
                }
            }
        }
        mask
    }

    #[inline]
    pub fn cell_id(&self, (r, c): Cell) -> usize {
        debug_assert!(r < self.side && c < self.side);
        r * self.side + c
    }

    /// Is `cell` inside region `(ri, rj)`?
    pub fn in_region(&self, ri: usize, rj: usize, (r, c): Cell) -> bool {
        let (r0, c0) = self.region_origin(ri, rj);
        (r0..r0 + REGION).contains(&r) && (c0..c0 + REGION).contains(&c)
    }

    /// Clamp a proposed move to the robot's region.
    pub fn step_in_region(&self, ri: usize, rj: usize, (r, c): Cell, action: Action) -> Cell {
        let (r0, c0) = self.region_origin(ri, rj);
        let (mut nr, mut nc) = (r as isize, c as isize);
        match action {
            Action::Up => nr -= 1,
            Action::Down => nr += 1,
            Action::Left => nc -= 1,
            Action::Right => nc += 1,
            Action::Stay => {}
        }
        let nr = nr.clamp(r0 as isize, (r0 + REGION - 1) as isize) as usize;
        let nc = nc.clamp(c0 as isize, (c0 + REGION - 1) as isize) as usize;
        (nr, nc)
    }
}

/// Robot movement actions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Action {
    Up = 0,
    Down = 1,
    Left = 2,
    Right = 3,
    Stay = 4,
}

pub const NUM_ACTIONS: usize = 5;

impl Action {
    pub fn from_index(i: usize) -> Action {
        match i {
            0 => Action::Up,
            1 => Action::Down,
            2 => Action::Left,
            3 => Action::Right,
            4 => Action::Stay,
            _ => panic!("bad action {i}"),
        }
    }
}

/// BFS path planning within a region: shortest path from `pos` to `target`
/// avoiding `obstacles` (other robots currently inside the region — the
/// online planning the paper's pre-programmed robots perform, after Claes
/// et al. 2017). Returns the first action of the path, `Stay` if already
/// there or unreachable. Deterministic: neighbors expanded in action order.
pub fn plan_step_bfs(
    floor: &Floor,
    ri: usize,
    rj: usize,
    pos: Cell,
    target: Cell,
    obstacles: &[Cell],
) -> Action {
    if pos == target {
        return Action::Stay;
    }
    let (r0, c0) = floor.region_origin(ri, rj);
    let local = |(r, c): Cell| (r - r0) * REGION + (c - c0);
    let mut parent_action = [None::<Action>; REGION * REGION];
    let mut blocked = [false; REGION * REGION];
    for &o in obstacles {
        if floor.in_region(ri, rj, o) && o != target {
            blocked[local(o)] = true;
        }
    }
    let mut queue = std::collections::VecDeque::new();
    let mut visited = [false; REGION * REGION];
    visited[local(pos)] = true;
    queue.push_back(pos);
    while let Some(cur) = queue.pop_front() {
        for a in [Action::Up, Action::Down, Action::Left, Action::Right] {
            let nxt = floor.step_in_region(ri, rj, cur, a);
            if nxt == cur {
                continue;
            }
            let li = local(nxt);
            if visited[li] || blocked[li] {
                continue;
            }
            visited[li] = true;
            // Record the FIRST action of the path: inherit from cur, or
            // start a new path if cur is the source.
            parent_action[li] =
                if cur == pos { Some(a) } else { parent_action[local(cur)] };
            if nxt == target {
                return parent_action[li].unwrap_or(Action::Stay);
            }
            queue.push_back(nxt);
        }
    }
    Action::Stay // target unreachable (boxed in)
}

/// Greedy scripted policy: one Manhattan step toward `target` (rows first,
/// then columns — deterministic, as the paper's pre-programmed robots).
pub fn greedy_step_toward(pos: Cell, target: Cell) -> Action {
    if pos.0 < target.0 {
        Action::Down
    } else if pos.0 > target.0 {
        Action::Up
    } else if pos.1 < target.1 {
        Action::Right
    } else if pos.1 > target.1 {
        Action::Left
    } else {
        Action::Stay
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floor_dimensions() {
        let f = Floor::new(6);
        assert_eq!(f.side, 25);
        assert_eq!(f.region_origin(5, 5), (20, 20));
    }

    #[test]
    fn item_cells_are_edges_no_corners() {
        let f = Floor::new(6);
        let cells = f.item_cells(0, 0);
        assert_eq!(cells.len(), 12);
        for (r, c) in cells {
            let on_edge = r == 0 || r == 4 || c == 0 || c == 4;
            let corner = (r == 0 || r == 4) && (c == 0 || c == 4);
            assert!(on_edge && !corner, "({r},{c})");
        }
    }

    #[test]
    fn adjacent_regions_share_their_edge_shelf() {
        let f = Floor::new(6);
        let right_of_00: Vec<Cell> = f.item_cells(0, 0)[3..6].to_vec(); // right side
        let left_of_01: Vec<Cell> = f.item_cells(0, 1)[9..12].to_vec(); // left side
        assert_eq!(right_of_00, left_of_01, "shared shelf between (0,0) and (0,1)");
    }

    #[test]
    fn shelf_mask_counts_unique_cells() {
        let f = Floor::new(2); // 9x9 floor, 4 regions
        let mask = f.shelf_mask();
        let count = mask.iter().filter(|&&b| b).count();
        // 4 regions * 12 = 48 slots, interior edges shared pairwise:
        // 4 shared shelves of 3 cells → 48 - 12 = 36 unique.
        assert_eq!(count, 36);
    }

    #[test]
    fn movement_clamped_to_region() {
        let f = Floor::new(6);
        let origin = f.region_origin(1, 1); // (4,4)
        assert_eq!(f.step_in_region(1, 1, origin, Action::Up), origin);
        assert_eq!(f.step_in_region(1, 1, origin, Action::Left), origin);
        assert_eq!(f.step_in_region(1, 1, origin, Action::Down), (5, 4));
        assert_eq!(f.step_in_region(1, 1, (8, 8), Action::Down), (8, 8));
    }

    #[test]
    fn greedy_reaches_target() {
        let mut pos = (0, 0);
        let target = (3, 2);
        let f = Floor::new(6);
        for _ in 0..10 {
            let a = greedy_step_toward(pos, target);
            pos = f.step_in_region(0, 0, pos, a);
        }
        assert_eq!(pos, target);
    }

    #[test]
    fn greedy_stays_at_target() {
        assert_eq!(greedy_step_toward((2, 2), (2, 2)), Action::Stay);
    }

    #[test]
    fn bfs_reaches_target_in_manhattan_steps() {
        let f = Floor::new(6);
        let mut pos = (0, 0);
        let target = (4, 3);
        let mut steps = 0;
        while pos != target {
            let a = plan_step_bfs(&f, 0, 0, pos, target, &[]);
            assert_ne!(a, Action::Stay, "must make progress");
            pos = f.step_in_region(0, 0, pos, a);
            steps += 1;
            assert!(steps <= 7);
        }
        assert_eq!(steps, 7); // manhattan distance
    }

    #[test]
    fn bfs_routes_around_obstacles() {
        let f = Floor::new(6);
        // Wall of obstacles between (2,0) and (2,4), gap at (0,2) row 0.
        let obstacles = [(1, 0), (1, 1), (1, 2), (1, 3)];
        let mut pos = (2, 0);
        let target = (0, 0);
        let mut steps = 0;
        while pos != target && steps < 20 {
            let a = plan_step_bfs(&f, 0, 0, pos, target, &obstacles);
            if a == Action::Stay {
                break;
            }
            pos = f.step_in_region(0, 0, pos, a);
            steps += 1;
        }
        assert_eq!(pos, target, "should detour via column 4");
        assert!(steps > 2, "detour is longer than the direct path");
    }

    #[test]
    fn bfs_boxed_in_stays() {
        let f = Floor::new(6);
        let obstacles = [(0, 1), (1, 0), (1, 1)];
        let a = plan_step_bfs(&f, 0, 0, (0, 0), (4, 4), &obstacles);
        assert_eq!(a, Action::Stay);
    }

    #[test]
    fn bfs_at_target_stays() {
        let f = Floor::new(6);
        assert_eq!(plan_step_bfs(&f, 0, 0, (2, 2), (2, 2), &[]), Action::Stay);
    }
}
