//! Warehouse-commissioning domain (paper §5.3).
//!
//! 36 robots on a 25×25 floor; each owns a 5×5 region overlapping its
//! neighbors at shared item shelves. The agent (the paper's purple robot)
//! is RL-controlled; the others are scripted greedily. The agent cannot
//! see the other robots — they affect it only by taking shared items,
//! which is exactly the influence channel the IALS models.
//!
//! Influence sources `u_t ∈ {0,1}^12`: standard mode — neighbor-robot
//! presence at each of the agent region's 12 item cells; memory mode
//! (§5.4, `fixed_item_lifetime > 0`) — per-cell item-expiry events.
//!
//! The d-set `d_t` (24 bits/step): the 12 item-active bits plus 12 bits
//! flagging whether the *agent itself* is at each item cell (so the AIP can
//! tell "agent collected it" apart from "neighbor took it" — paper §5.3.1).
//! The agent's own location bitmap is excluded (confounder-prone).

pub mod geometry;
pub mod global;
pub mod items;
pub mod local;

pub use geometry::{Action, Floor, ITEMS_PER_REGION, NUM_ACTIONS, REGION};
pub use global::{WarehouseGlobalEnv, ALSH_DIM, DSET_DIM, OBS_DIM};
pub use items::ItemSet;
pub use local::WarehouseLocalEnv;

use crate::dbn::Dag;

/// A coarse per-cell DBN of the warehouse local-POMDP, used to verify the
/// paper's d-set choice. Nodes per step: `item_t` (an item bit), `atcell_t`
/// (agent at that cell), `pos_t` (agent position), `nbr_t` (neighbor robot
/// state), `u_t` (neighbor presence at the cell), `a_t` (action).
pub fn warehouse_dbn(t_max: usize) -> Dag {
    let mut g = Dag::new();
    for t in 0..t_max {
        for n in ["item", "atcell", "pos", "nbr", "u", "a"] {
            g.node(&format!("{n}_{t}"));
        }
        if t + 1 < t_max {
            let t1 = t + 1;
            // Item persists unless the agent (atcell) or a neighbor (u)
            // collects it; new items spawn exogenously.
            g.edge(&format!("item_{t}"), &format!("item_{t1}"));
            g.edge(&format!("atcell_{t}"), &format!("item_{t1}"));
            g.edge(&format!("u_{t}"), &format!("item_{t1}"));
            // Agent motion.
            g.edge(&format!("pos_{t}"), &format!("pos_{t1}"));
            g.edge(&format!("a_{t}"), &format!("pos_{t1}"));
            g.edge(&format!("pos_{t1}"), &format!("atcell_{t1}"));
            // Neighbor robots react to the *shared* item state and their own
            // internal state; they cannot see the agent.
            g.edge(&format!("nbr_{t}"), &format!("nbr_{t1}"));
            g.edge(&format!("item_{t}"), &format!("nbr_{t1}"));
            g.edge(&format!("nbr_{t1}"), &format!("u_{t1}"));
        }
    }
    // atcell_0 also derives from pos_0.
    if t_max > 0 {
        g.edge("pos_0", "atcell_0");
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The item + at-cell history d-separates u from the agent's position
    /// history (the confounder the paper removes).
    #[test]
    fn item_and_atcell_history_is_a_dset() {
        let g = warehouse_dbn(3);
        let dset = ["item_0", "atcell_0", "item_1", "atcell_1"];
        let sep = g.d_separated_names(&["u_2"], &["pos_0", "a_0"], &dset).unwrap();
        assert!(sep, "d-set must screen off the agent's location history");
    }

    /// Dropping the item bits breaks the separation (neighbors react to
    /// shared items, which the agent's collections have altered).
    #[test]
    fn atcell_alone_is_not_a_dset() {
        let g = warehouse_dbn(3);
        let sep = g.d_separated_names(&["u_2"], &["item_0"], &["atcell_1"]).unwrap();
        assert!(!sep);
    }
}
