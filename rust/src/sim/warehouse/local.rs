//! The warehouse **local simulator** (LS): the agent's 5×5 region only.
//! Neighbor robots (standard mode) or the expiry timer (memory mode) are
//! replaced by influence-source realizations.

use super::geometry::{Action, Floor, ITEMS_PER_REGION, NUM_ACTIONS, REGION};
use super::global::{ALSH_DIM, DSET_DIM, OBS_DIM};
use super::items::ItemSet;
use crate::config::WarehouseConfig;
use crate::core::{LocalEnv, Step};
use crate::util::{Pcg32, StateReader, StateWriter};

pub struct WarehouseLocalEnv {
    cfg: WarehouseConfig,
    /// Local 12-slot item set (slot k == canonical item cell k).
    items: ItemSet,
    /// Agent position in local coordinates (0..REGION, 0..REGION).
    pos: (usize, usize),
    /// Local coordinates of the 12 item cells.
    item_cells: [(usize, usize); ITEMS_PER_REGION],
    memory_mode: bool,
    floor: Floor,
    rng: Pcg32,
    t: usize,
    /// Ages of items removed by influence samples (external disappearance)
    /// — drives the Fig 6 item-lifetime histogram. Only filled when
    /// recording is enabled ([`WarehouseLocalEnv::record_removed_ages`]):
    /// training steps would otherwise grow this diagnostic buffer without
    /// bound and allocate on the fused-step hot path
    /// (`rust/tests/native_alloc.rs` pins the step at zero allocations).
    pub removed_ages: Vec<u32>,
    record_ages: bool,
}

impl WarehouseLocalEnv {
    pub fn new(cfg: &WarehouseConfig) -> WarehouseLocalEnv {
        let memory_mode = cfg.fixed_item_lifetime > 0;
        // In memory mode, expiry is driven by the influence samples (that's
        // the thing being predicted), so the local item set does not expire
        // by itself.
        let items = ItemSet::new(ITEMS_PER_REGION, cfg.item_prob, 0);
        // A single-region floor gives the local item-cell geometry.
        let floor = Floor::new(1);
        let cells = floor.item_cells(0, 0);
        let mut item_cells = [(0usize, 0usize); ITEMS_PER_REGION];
        item_cells.copy_from_slice(&cells);
        WarehouseLocalEnv {
            cfg: cfg.clone(),
            items,
            pos: (REGION / 2, REGION / 2),
            item_cells,
            memory_mode,
            floor,
            rng: Pcg32::seeded(0),
            t: 0,
            removed_ages: Vec::new(),
            record_ages: false,
        }
    }

    pub fn memory_mode(&self) -> bool {
        self.memory_mode
    }

    /// Enable (or disable) recording of externally-removed item ages into
    /// [`WarehouseLocalEnv::removed_ages`]. Off by default — see the field
    /// docs; the Fig 6 histogram harness switches it on explicitly.
    pub fn record_removed_ages(&mut self, on: bool) {
        self.record_ages = on;
    }

    /// Ages of the 12 local items (diagnostics: Fig 6 bottom histogram).
    pub fn item_ages(&self) -> [u32; ITEMS_PER_REGION] {
        let mut out = [0u32; ITEMS_PER_REGION];
        for (k, s) in self.items.slots.iter().enumerate() {
            out[k] = s.age;
        }
        out
    }

    pub fn item_active(&self, k: usize) -> bool {
        self.items.active(k)
    }

    #[cfg(test)]
    pub(crate) fn items_mut(&mut self) -> &mut ItemSet {
        &mut self.items
    }
}

impl LocalEnv for WarehouseLocalEnv {
    fn obs_dim(&self) -> usize {
        OBS_DIM
    }

    fn num_actions(&self) -> usize {
        NUM_ACTIONS
    }

    fn num_influence_sources(&self) -> usize {
        ITEMS_PER_REGION
    }

    fn dset_dim(&self) -> usize {
        DSET_DIM
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Pcg32::seeded(seed);
        self.items.reset();
        self.pos = (REGION / 2, REGION / 2);
        self.t = 0;
        // Same warm-up as the GS so initial item distributions match
        // (skipped in the memory variant, mirroring the GS).
        if !self.memory_mode {
            for _ in 0..25 {
                self.items.tick(&mut self.rng);
            }
        }
    }

    fn observe(&self, out: &mut [f32]) {
        out[..REGION * REGION].fill(0.0);
        out[self.pos.0 * REGION + self.pos.1] = 1.0;
        self.items.write_bits(&mut out[REGION * REGION..OBS_DIM]);
    }

    fn dset(&self, out: &mut [f32]) {
        self.items.write_bits(&mut out[..ITEMS_PER_REGION]);
        for (k, &cell) in self.item_cells.iter().enumerate() {
            out[ITEMS_PER_REGION + k] = if cell == self.pos { 1.0 } else { 0.0 };
        }
    }

    fn step_with_influence(&mut self, action: usize, influence: &[bool]) -> Step {
        debug_assert_eq!(influence.len(), ITEMS_PER_REGION);
        // 1. Agent moves.
        self.pos = self.floor.step_in_region(0, 0, self.pos, Action::from_index(action));

        let mut reward = 0.0;
        if self.memory_mode {
            // Memory mode: agent collects first, then the influence samples
            // realize expiry (mirrors GS ordering: collect happens before
            // the lifecycle tick that expires items).
            if let Some(k) = self.item_cells.iter().position(|&c| c == self.pos) {
                if self.items.collect(k) {
                    reward = 1.0;
                }
            }
            for (k, &gone) in influence.iter().enumerate() {
                if gone {
                    let age = self.items.slots[k].age;
                    if self.items.collect(k) && self.record_ages {
                        self.removed_ages.push(age);
                    }
                }
            }
        } else {
            // Standard mode: neighbors (the influence) act first — a
            // neighbor standing on a shared active item takes it before the
            // agent can (paper §5.3.1), then the agent collects.
            for (k, &present) in influence.iter().enumerate() {
                if present {
                    let age = self.items.slots[k].age;
                    if self.items.collect(k) && self.record_ages {
                        self.removed_ages.push(age);
                    }
                }
            }
            if let Some(k) = self.item_cells.iter().position(|&c| c == self.pos) {
                if self.items.collect(k) {
                    reward = 1.0;
                }
            }
        }

        // 2. Item lifecycle (spawn only — local set never self-expires).
        self.items.tick(&mut self.rng);

        self.t += 1;
        Step { reward, done: self.t >= self.cfg.episode_len }
    }

    fn save_state(&self, out: &mut StateWriter) -> crate::Result<()> {
        // `removed_ages` / `record_ages` are diagnostics (Fig 6 harness
        // only) and never enabled inside checkpointed training — excluded.
        self.items.save_state(out);
        out.usize(self.pos.0);
        out.usize(self.pos.1);
        let (s, inc) = self.rng.state();
        out.u64(s);
        out.u64(inc);
        out.usize(self.t);
        Ok(())
    }

    fn load_state(&mut self, r: &mut StateReader) -> crate::Result<()> {
        self.items.load_state(r)?;
        self.pos = (r.usize()?, r.usize()?);
        let (s, inc) = (r.u64()?, r.u64()?);
        self.rng = Pcg32::from_state(s, inc);
        self.t = r.usize()?;
        Ok(())
    }
}

/// Local ALSH feature writer (for the Appendix-B ablation parity with the
/// GS): d-set + agent position bitmap.
pub fn alsh_of(env: &WarehouseLocalEnv, out: &mut [f32]) {
    debug_assert_eq!(out.len(), ALSH_DIM);
    env.dset(&mut out[..DSET_DIM]);
    out[DSET_DIM..].fill(0.0);
    out[DSET_DIM + env.pos.0 * REGION + env.pos.1] = 1.0;
}

#[cfg(test)]
mod tests {
    use super::super::global::WarehouseGlobalEnv;
    use super::*;
    use crate::core::{Environment, GlobalEnv};

    fn cfg() -> WarehouseConfig {
        WarehouseConfig::default()
    }

    #[test]
    fn dims_match_global() {
        let ls = WarehouseLocalEnv::new(&cfg());
        let gs = WarehouseGlobalEnv::new(&cfg());
        assert_eq!(ls.obs_dim(), gs.obs_dim());
        assert_eq!(ls.dset_dim(), gs.dset_dim());
        assert_eq!(ls.num_actions(), gs.num_actions());
        assert_eq!(ls.num_influence_sources(), gs.num_influence_sources());
    }

    #[test]
    fn influence_removes_items_before_agent() {
        let mut c = cfg();
        c.item_prob = 0.0;
        let mut ls = WarehouseLocalEnv::new(&c);
        ls.reset(1);
        // Plant an item at cell 0 = local (0,1); walk the agent onto it
        // while a neighbor "arrives" at the same time — the neighbor wins.
        ls.items_mut().slots[0].active = true;
        ls.step_with_influence(0, &[false; 12]); // up → (1,2)
        ls.step_with_influence(0, &[false; 12]); // up → (0,2)
        let mut u = [false; 12];
        u[0] = true;
        let s = ls.step_with_influence(2, &u); // left → (0,1): contested
        assert_eq!(s.reward, 0.0, "neighbor collects shared items first");
        assert!(!ls.item_active(0));
    }

    #[test]
    fn agent_collects_when_uncontested() {
        let mut c = cfg();
        c.item_prob = 0.0;
        let mut ls = WarehouseLocalEnv::new(&c);
        ls.reset(2);
        ls.items_mut().slots[0].active = true;
        ls.step_with_influence(0, &[false; 12]);
        ls.step_with_influence(0, &[false; 12]);
        let s = ls.step_with_influence(2, &[false; 12]);
        assert_eq!(s.reward, 1.0);
    }

    #[test]
    fn memory_mode_agent_beats_expiry_same_step() {
        let mut c = cfg();
        c.item_prob = 0.0;
        c.fixed_item_lifetime = 8;
        let mut ls = WarehouseLocalEnv::new(&c);
        ls.reset(3);
        ls.items_mut().slots[0].active = true;
        ls.step_with_influence(0, &[false; 12]);
        ls.step_with_influence(0, &[false; 12]);
        let mut u = [false; 12];
        u[0] = true; // expiry fires the very step the agent arrives
        let s = ls.step_with_influence(2, &u);
        assert_eq!(s.reward, 1.0, "in memory mode the agent collects before expiry");
    }

    #[test]
    fn items_do_not_self_expire_locally() {
        let mut c = cfg();
        c.item_prob = 0.0;
        c.fixed_item_lifetime = 8; // memory mode, but expiry comes via u
        let mut ls = WarehouseLocalEnv::new(&c);
        ls.reset(4);
        ls.items_mut().slots[5].active = true;
        for _ in 0..30 {
            ls.step_with_influence(4, &[false; 12]);
        }
        assert!(ls.item_active(5), "without influence, local items persist");
        assert!(ls.item_ages()[5] >= 30);
    }

    /// Mechanism fidelity: replaying the GS's realized influence sequence
    /// through the LS must *reduce* local item occupancy (the neighbor
    /// channel works), while replaying all-zeros must saturate the shelf.
    /// (Exact distribution match is not expected from open-loop replay —
    /// the AIP closes the loop on the LS's own d-set at simulation time.)
    #[test]
    fn ls_replays_gs_item_dynamics() {
        let c = cfg();
        let mut gs = WarehouseGlobalEnv::new(&c);
        let mut ls_replay = WarehouseLocalEnv::new(&c);
        let mut ls_zero = WarehouseLocalEnv::new(&c);
        gs.reset(7);
        ls_replay.reset(7);
        ls_zero.reset(7);
        let mut u = [0.0f32; 12];
        let (mut gs_bits, mut rep_bits, mut zero_bits) = (0.0f64, 0.0f64, 0.0f64);
        let mut d = [0.0f32; 24];
        let steps = 3000;
        for t in 0..steps {
            if gs.step(4).done {
                let s = 100 + t as u64;
                gs.reset(s);
                ls_replay.reset(s);
                ls_zero.reset(s);
            }
            gs.influence_sources(&mut u);
            let ub: Vec<bool> = u.iter().map(|&x| x > 0.5).collect();
            ls_replay.step_with_influence(4, &ub);
            ls_zero.step_with_influence(4, &[false; 12]);
            gs.dset(&mut d);
            gs_bits += d[..12].iter().sum::<f32>() as f64;
            let mut ld = [0.0f32; 24];
            ls_replay.dset(&mut ld);
            rep_bits += ld[..12].iter().sum::<f32>() as f64;
            ls_zero.dset(&mut ld);
            zero_bits += ld[..12].iter().sum::<f32>() as f64;
        }
        let gs_rate = gs_bits / steps as f64 / 12.0;
        let rep_rate = rep_bits / steps as f64 / 12.0;
        let zero_rate = zero_bits / steps as f64 / 12.0;
        assert!(
            rep_rate < zero_rate - 0.1,
            "u replay must remove items: replay={rep_rate:.3} zero={zero_rate:.3}"
        );
        assert!(
            rep_rate > gs_rate - 0.02,
            "LS cannot have *fewer* items than the GS (fewer collectors): \
             replay={rep_rate:.3} gs={gs_rate:.3}"
        );
        assert!(gs_rate > 0.01 && gs_rate < 0.5, "gs occupancy sane: {gs_rate:.3}");
    }
}
