//! The warehouse **global simulator** (GS): the full floor, all robots.
//!
//! Two modes, selected by `fixed_item_lifetime`:
//!
//! * **Standard** (lifetime = 0, §5.3): 36 scripted robots chase the oldest
//!   item in their region; the influence sources are *neighbor-robot
//!   presence* at each of the agent region's 12 item cells (a neighbor on
//!   an active shared item collects it — the item is gone for the agent).
//! * **Memory variant** (lifetime = k, §5.4): items vanish after exactly
//!   `k` steps; the influence sources are the per-cell *expiry events*.
//!   Scripted robots are absent (disappearance is fully driven by the
//!   deterministic timer), which is what makes a k-step memory AIP exact.

use super::geometry::{plan_step_bfs, Action, Cell, Floor, ITEMS_PER_REGION, NUM_ACTIONS, REGION};
use super::items::ItemSet;
use crate::config::WarehouseConfig;
use crate::core::{Environment, GlobalEnv, Step};
use crate::util::{Pcg32, StateReader, StateWriter};

/// Observation layout: 25-cell position bitmap + 12 item bits.
pub const OBS_DIM: usize = REGION * REGION + ITEMS_PER_REGION;
/// d-set per step: 12 item bits + 12 agent-at-item-cell bits (paper §5.3.1).
pub const DSET_DIM: usize = 2 * ITEMS_PER_REGION;
/// Full-ALSH features: d-set + the agent's 25-cell position bitmap (the
/// confounder-prone extra the paper excludes).
pub const ALSH_DIM: usize = DSET_DIM + REGION * REGION;

struct ScriptedRobot {
    ri: usize,
    rj: usize,
    pos: Cell,
    /// Slot indices (into the global [`ItemSet`]) of this robot's 12 item
    /// cells, canonical order.
    item_slots: [usize; ITEMS_PER_REGION],
    /// The corresponding cells.
    item_cells: [Cell; ITEMS_PER_REGION],
}

pub struct WarehouseGlobalEnv {
    cfg: WarehouseConfig,
    floor: Floor,
    items: ItemSet,
    /// cell_id → slot index in `items` (usize::MAX if not a shelf cell).
    slot_of_cell: Vec<usize>,
    robots: Vec<ScriptedRobot>,
    /// Index of the agent's region.
    agent_region: (usize, usize),
    agent_pos: Cell,
    /// The agent's 12 item cells + their global slots.
    agent_item_cells: [Cell; ITEMS_PER_REGION],
    agent_item_slots: [usize; ITEMS_PER_REGION],
    /// Robot indices of the 4 orthogonal neighbors.
    neighbor_robots: Vec<usize>,
    rng: Pcg32,
    t: usize,
    last_u: [bool; ITEMS_PER_REGION],
}

impl WarehouseGlobalEnv {
    pub fn new(cfg: &WarehouseConfig) -> WarehouseGlobalEnv {
        let floor = Floor::new(cfg.robots_per_side);
        let mask = floor.shelf_mask();
        let mut slot_of_cell = vec![usize::MAX; mask.len()];
        let mut n_slots = 0usize;
        for (cell_id, &is_shelf) in mask.iter().enumerate() {
            if is_shelf {
                slot_of_cell[cell_id] = n_slots;
                n_slots += 1;
            }
        }
        let items = ItemSet::new(n_slots, cfg.item_prob, cfg.fixed_item_lifetime);

        let memory_mode = cfg.fixed_item_lifetime > 0;
        let agent_region = (cfg.robots_per_side / 2, cfg.robots_per_side / 2);

        let mut robots = Vec::new();
        if !memory_mode {
            for ri in 0..cfg.robots_per_side {
                for rj in 0..cfg.robots_per_side {
                    if (ri, rj) == agent_region {
                        continue;
                    }
                    let cells = floor.item_cells(ri, rj);
                    let mut slots = [0usize; ITEMS_PER_REGION];
                    for (k, &c) in cells.iter().enumerate() {
                        slots[k] = slot_of_cell[floor.cell_id(c)];
                    }
                    let (r0, c0) = floor.region_origin(ri, rj);
                    robots.push(ScriptedRobot {
                        ri,
                        rj,
                        pos: (r0 + REGION / 2, c0 + REGION / 2),
                        item_slots: slots,
                        item_cells: cells,
                    });
                }
            }
        }

        let agent_item_cells = floor.item_cells(agent_region.0, agent_region.1);
        let mut agent_item_slots = [0usize; ITEMS_PER_REGION];
        for (k, &c) in agent_item_cells.iter().enumerate() {
            agent_item_slots[k] = slot_of_cell[floor.cell_id(c)];
        }

        // Orthogonal neighbor robots (share one shelf each with the agent).
        let mut neighbor_robots = Vec::new();
        let (ar, ac) = agent_region;
        for (i, r) in robots.iter().enumerate() {
            let d = (r.ri as isize - ar as isize).abs() + (r.rj as isize - ac as isize).abs();
            if d == 1 {
                neighbor_robots.push(i);
            }
        }

        let (r0, c0) = floor.region_origin(agent_region.0, agent_region.1);
        WarehouseGlobalEnv {
            cfg: cfg.clone(),
            floor,
            items,
            slot_of_cell,
            robots,
            agent_region,
            agent_pos: (r0 + REGION / 2, c0 + REGION / 2),
            agent_item_cells,
            agent_item_slots,
            neighbor_robots,
            rng: Pcg32::seeded(0),
            t: 0,
            last_u: [false; ITEMS_PER_REGION],
        }
    }

    pub fn memory_mode(&self) -> bool {
        self.cfg.fixed_item_lifetime > 0
    }

    pub fn num_robots(&self) -> usize {
        self.robots.len() + 1
    }

    pub fn agent_pos(&self) -> Cell {
        self.agent_pos
    }

    fn agent_local(&self) -> (usize, usize) {
        let (r0, c0) = self.floor.region_origin(self.agent_region.0, self.agent_region.1);
        (self.agent_pos.0 - r0, self.agent_pos.1 - c0)
    }

    /// Ages of the agent-region item slots (test/diagnostic access).
    pub fn agent_item_ages(&self) -> [u32; ITEMS_PER_REGION] {
        let mut out = [0u32; ITEMS_PER_REGION];
        for (k, &s) in self.agent_item_slots.iter().enumerate() {
            out[k] = self.items.slots[s].age;
        }
        out
    }

    #[cfg(test)]
    pub(crate) fn items_mut(&mut self) -> &mut ItemSet {
        &mut self.items
    }

    #[cfg(test)]
    pub(crate) fn agent_slots(&self) -> &[usize; ITEMS_PER_REGION] {
        &self.agent_item_slots
    }

    #[cfg(test)]
    pub(crate) fn slot_at(&self, cell: Cell) -> usize {
        self.slot_of_cell[self.floor.cell_id(cell)]
    }
}

impl Environment for WarehouseGlobalEnv {
    fn obs_dim(&self) -> usize {
        OBS_DIM
    }

    fn num_actions(&self) -> usize {
        NUM_ACTIONS
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Pcg32::seeded(seed);
        self.items.reset();
        let (ar, ac) = self.agent_region;
        let (r0, c0) = self.floor.region_origin(ar, ac);
        self.agent_pos = (r0 + REGION / 2, c0 + REGION / 2);
        for robot in &mut self.robots {
            let (rr, rc) = self.floor.region_origin(robot.ri, robot.rj);
            robot.pos = (rr + REGION / 2, rc + REGION / 2);
        }
        self.t = 0;
        self.last_u = [false; ITEMS_PER_REGION];
        // Warm-up the item process so episodes don't start on an empty
        // floor (steady-state warehouse). Skipped in the §5.4 memory
        // variant: there, item *ages* must be observable from the episode
        // start or the 8-step expiry is irreducibly ambiguous for any AIP.
        if !self.memory_mode() {
            for _ in 0..25 {
                self.items.tick(&mut self.rng);
            }
        }
    }

    fn observe(&self, out: &mut [f32]) {
        out[..REGION * REGION].fill(0.0);
        let (lr, lc) = self.agent_local();
        out[lr * REGION + lc] = 1.0;
        for (k, &slot) in self.agent_item_slots.iter().enumerate() {
            out[REGION * REGION + k] = if self.items.active(slot) { 1.0 } else { 0.0 };
        }
    }

    fn step(&mut self, action: usize) -> Step {
        // 1. Scripted robots plan (BFS, avoiding robots currently inside
        //    their region — the online planning of Claes et al. 2017) one
        //    step toward the oldest item in their region.
        let mut all_pos: Vec<Cell> = self.robots.iter().map(|r| r.pos).collect();
        all_pos.push(self.agent_pos);
        for idx in 0..self.robots.len() {
            let robot = &self.robots[idx];
            let target = robot
                .item_slots
                .iter()
                .enumerate()
                .filter(|(_, &s)| self.items.active(s))
                .max_by_key(|(k, &s)| (self.items.slots[s].age, usize::MAX - k))
                .map(|(k, _)| robot.item_cells[k]);
            if let Some(t) = target {
                let obstacles: Vec<Cell> = all_pos
                    .iter()
                    .enumerate()
                    .filter(|&(j, _)| j != idx)
                    .map(|(_, &p)| p)
                    .collect();
                let a = plan_step_bfs(&self.floor, robot.ri, robot.rj, robot.pos, t, &obstacles);
                let new_pos = self.floor.step_in_region(robot.ri, robot.rj, robot.pos, a);
                all_pos[idx] = new_pos;
                self.robots[idx].pos = new_pos;
            }
        }
        // 2. Agent moves.
        let (ar, ac) = self.agent_region;
        self.agent_pos =
            self.floor.step_in_region(ar, ac, self.agent_pos, Action::from_index(action));

        // 3. Scripted collection (neighbor priority at shared cells).
        for robot in &self.robots {
            let cid = self.floor.cell_id(robot.pos);
            let slot = self.slot_of_cell[cid];
            if slot != usize::MAX && robot.item_cells.contains(&robot.pos) {
                self.items.collect(slot);
            }
        }

        // 4. Agent collection.
        let mut reward = 0.0;
        let apos = self.agent_pos;
        if let Some(k) = self.agent_item_cells.iter().position(|&c| c == apos) {
            if self.items.collect(self.agent_item_slots[k]) {
                reward = 1.0;
            }
        }

        // 5. Item lifecycle (expiry + spawn).
        self.items.tick(&mut self.rng);

        // 6. Influence sources.
        if self.memory_mode() {
            // Expiry events at the agent's item cells.
            for (k, &slot) in self.agent_item_slots.iter().enumerate() {
                self.last_u[k] = self.items.last_expired[slot];
            }
        } else {
            // Neighbor-robot presence at the agent's item cells.
            for (k, &cell) in self.agent_item_cells.iter().enumerate() {
                self.last_u[k] = self.neighbor_robots.iter().any(|&i| self.robots[i].pos == cell);
            }
        }

        self.t += 1;
        Step { reward, done: self.t >= self.cfg.episode_len }
    }

    fn save_state(&self, out: &mut StateWriter) -> crate::Result<()> {
        self.items.save_state(out);
        out.usize(self.robots.len());
        for robot in &self.robots {
            out.usize(robot.pos.0);
            out.usize(robot.pos.1);
        }
        out.usize(self.agent_pos.0);
        out.usize(self.agent_pos.1);
        let (s, inc) = self.rng.state();
        out.u64(s);
        out.u64(inc);
        out.usize(self.t);
        out.bools(&self.last_u);
        Ok(())
    }

    fn load_state(&mut self, r: &mut StateReader) -> crate::Result<()> {
        self.items.load_state(r)?;
        let n = r.usize()?;
        anyhow::ensure!(
            n == self.robots.len(),
            "snapshot has {n} robots, env has {}",
            self.robots.len()
        );
        for robot in &mut self.robots {
            robot.pos = (r.usize()?, r.usize()?);
        }
        self.agent_pos = (r.usize()?, r.usize()?);
        let (s, inc) = (r.u64()?, r.u64()?);
        self.rng = Pcg32::from_state(s, inc);
        self.t = r.usize()?;
        r.bools_into(&mut self.last_u)?;
        Ok(())
    }
}

impl GlobalEnv for WarehouseGlobalEnv {
    fn num_influence_sources(&self) -> usize {
        ITEMS_PER_REGION
    }

    fn dset_dim(&self) -> usize {
        DSET_DIM
    }

    fn influence_sources(&self, out: &mut [f32]) {
        for (o, &u) in out.iter_mut().zip(&self.last_u) {
            *o = if u { 1.0 } else { 0.0 };
        }
    }

    fn dset(&self, out: &mut [f32]) {
        for (k, &slot) in self.agent_item_slots.iter().enumerate() {
            out[k] = if self.items.active(slot) { 1.0 } else { 0.0 };
        }
        let apos = self.agent_pos;
        for (k, &cell) in self.agent_item_cells.iter().enumerate() {
            out[ITEMS_PER_REGION + k] = if cell == apos { 1.0 } else { 0.0 };
        }
    }

    fn alsh_dim(&self) -> usize {
        ALSH_DIM
    }

    fn alsh(&self, out: &mut [f32]) {
        self.dset(&mut out[..DSET_DIM]);
        out[DSET_DIM..].fill(0.0);
        let (lr, lc) = self.agent_local();
        out[DSET_DIM + lr * REGION + lc] = 1.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> WarehouseConfig {
        WarehouseConfig::default()
    }

    #[test]
    fn dims() {
        let env = WarehouseGlobalEnv::new(&cfg());
        assert_eq!(env.obs_dim(), 37);
        assert_eq!(env.dset_dim(), 24);
        assert_eq!(env.alsh_dim(), 49);
        assert_eq!(env.num_actions(), 5);
        assert_eq!(env.num_influence_sources(), 12);
        assert_eq!(env.num_robots(), 36);
    }

    #[test]
    fn episode_horizon() {
        let mut env = WarehouseGlobalEnv::new(&cfg());
        env.reset(1);
        let mut steps = 0;
        loop {
            steps += 1;
            if env.step(4).done {
                break;
            }
        }
        assert_eq!(steps, 200);
    }

    #[test]
    fn items_spawn_and_neighbors_visit() {
        let mut env = WarehouseGlobalEnv::new(&cfg());
        env.reset(2);
        let mut saw_item = false;
        let mut saw_u = false;
        let mut u = [0.0f32; 12];
        let mut d = [0.0f32; 24];
        for _ in 0..400 {
            if env.step(4).done {
                env.reset(3);
            }
            env.dset(&mut d);
            if d[..12].iter().sum::<f32>() > 0.0 {
                saw_item = true;
            }
            env.influence_sources(&mut u);
            if u.iter().sum::<f32>() > 0.0 {
                saw_u = true;
            }
        }
        assert!(saw_item, "items should appear in the agent's region");
        assert!(saw_u, "neighbor robots should visit shared shelves");
    }

    #[test]
    fn agent_collects_and_gets_reward() {
        let mut c = cfg();
        c.item_prob = 0.0; // no stray spawns; only the planted item exists
        let mut env = WarehouseGlobalEnv::new(&c);
        env.reset(4);
        // Plant an item on the agent's top shelf — shared with the region
        // above, whose robot would race us to it (and win ties). Distract
        // that neighbor with a much older decoy on its own far shelf.
        // Agent region = (3,3), origin (12,12); its top shelf cell 0 is
        // (12,13). Neighbor (2,3) origin (8,12): far/top shelf cell (8,13).
        let slot = env.agent_slots()[0];
        env.items_mut().slots[slot].active = true;
        let decoy = env.slot_at((8, 13));
        env.items_mut().slots[decoy].active = true;
        env.items_mut().slots[decoy].age = 200;
        // Agent starts at region center (2,2) local; cell 0 is (0,1)
        // locally: two ups and one left.
        let mut reward = 0.0;
        for a in [0usize, 0, 2] {
            reward += env.step(a).reward;
        }
        assert_eq!(reward, 1.0, "agent should collect the planted item");
    }

    #[test]
    fn memory_mode_u_is_expiry() {
        let mut c = cfg();
        c.fixed_item_lifetime = 8;
        let mut env = WarehouseGlobalEnv::new(&c);
        assert!(env.memory_mode());
        assert_eq!(env.num_robots(), 1, "no scripted robots in memory mode");
        env.reset(5);
        // Track: whenever u fires for a cell, the item there must have just
        // disappeared with age ~ 8.
        let mut ages_before = env.agent_item_ages();
        let mut u = [0.0f32; 12];
        let mut fired = 0;
        for _ in 0..200 {
            env.step(4);
            env.influence_sources(&mut u);
            for k in 0..12 {
                if u[k] > 0.5 {
                    fired += 1;
                    assert_eq!(ages_before[k], 7, "expiry exactly at lifetime 8");
                }
            }
            ages_before = env.agent_item_ages();
        }
        assert!(fired > 0, "some items should expire in 200 steps");
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed: u64| {
            let mut env = WarehouseGlobalEnv::new(&cfg());
            env.reset(seed);
            let mut obs = vec![0.0; env.obs_dim()];
            let mut trace = Vec::new();
            for t in 0..100 {
                env.step(t % 5);
                env.observe(&mut obs);
                trace.extend_from_slice(&obs);
            }
            trace
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10));
    }

    #[test]
    fn observation_position_onehot() {
        let mut env = WarehouseGlobalEnv::new(&cfg());
        env.reset(6);
        let mut obs = vec![0.0; env.obs_dim()];
        env.observe(&mut obs);
        assert_eq!(obs[..25].iter().sum::<f32>(), 1.0);
        assert_eq!(obs[2 * 5 + 2], 1.0, "starts at region center");
    }
}
