//! Item lifecycle shared by the warehouse GS and LS: stochastic spawning
//! on shelf cells, aging, optional fixed-lifetime expiry (§5.4 variant),
//! and collection.

use crate::util::{Pcg32, StateReader, StateWriter};

/// State of one shelf cell.
#[derive(Debug, Clone, Copy, Default)]
pub struct Slot {
    pub active: bool,
    /// Steps since the item appeared (0 = appeared this step).
    pub age: u32,
}

/// A set of shelf slots addressed by dense local index. The GS instantiates
/// one over every shelf cell of the floor; the LS over the agent region's
/// 12 cells — **the same lifecycle code**, per the LS-fidelity design rule.
#[derive(Debug, Clone)]
pub struct ItemSet {
    pub slots: Vec<Slot>,
    /// Spawn probability per inactive slot per step.
    pub spawn_prob: f32,
    /// If > 0, items vanish after exactly this many steps (paper §5.4).
    pub fixed_lifetime: usize,
    /// Per-slot flag: did the item expire during the last `tick`? (The
    /// §5.4 influence sources are these expiry events.)
    pub last_expired: Vec<bool>,
}

impl ItemSet {
    pub fn new(n: usize, spawn_prob: f32, fixed_lifetime: usize) -> ItemSet {
        ItemSet {
            slots: vec![Slot::default(); n],
            spawn_prob,
            fixed_lifetime,
            last_expired: vec![false; n],
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn reset(&mut self) {
        self.slots.fill(Slot::default());
        self.last_expired.fill(false);
    }

    pub fn active(&self, i: usize) -> bool {
        self.slots[i].active
    }

    /// Collect the item at slot `i` if active. Returns true on success.
    pub fn collect(&mut self, i: usize) -> bool {
        if self.slots[i].active {
            self.slots[i] = Slot::default();
            true
        } else {
            false
        }
    }

    /// Advance the lifecycle one step: age active items, expire those at
    /// the fixed lifetime, then spawn new items on inactive slots.
    /// Returns the number of items that expired (vanished uncollected).
    ///
    /// IMPORTANT for GS/LS fidelity: expiry happens when `age` *reaches*
    /// `fixed_lifetime`, so an item is observable for exactly
    /// `fixed_lifetime` steps.
    pub fn tick(&mut self, rng: &mut Pcg32) -> usize {
        let mut expired = 0;
        self.last_expired.fill(false);
        for (i, slot) in self.slots.iter_mut().enumerate() {
            if slot.active {
                slot.age += 1;
                if self.fixed_lifetime > 0 && slot.age as usize >= self.fixed_lifetime {
                    *slot = Slot::default();
                    self.last_expired[i] = true;
                    expired += 1;
                }
            }
        }
        for slot in &mut self.slots {
            if !slot.active && rng.bernoulli(self.spawn_prob) {
                *slot = Slot { active: true, age: 0 };
            }
        }
        expired
    }

    /// Serialize the dynamic state (slot activity/ages + expiry flags) for
    /// checkpointing; `spawn_prob` / `fixed_lifetime` come from config.
    pub fn save_state(&self, out: &mut StateWriter) {
        out.usize(self.slots.len());
        for slot in &self.slots {
            out.bool(slot.active);
            out.u32(slot.age);
        }
        out.bools(&self.last_expired);
    }

    /// Restore state written by [`ItemSet::save_state`].
    pub fn load_state(&mut self, r: &mut StateReader) -> crate::Result<()> {
        let n = r.usize()?;
        anyhow::ensure!(
            n == self.slots.len(),
            "snapshot has {n} item slots, set has {}",
            self.slots.len()
        );
        for slot in &mut self.slots {
            slot.active = r.bool()?;
            slot.age = r.u32()?;
        }
        r.bools_into(&mut self.last_expired)?;
        Ok(())
    }

    /// Index of the oldest active slot (ties by lowest index), if any.
    pub fn oldest_active(&self) -> Option<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.active)
            .max_by_key(|(i, s)| (s.age, usize::MAX - i))
            .map(|(i, _)| i)
    }

    pub fn count_active(&self) -> usize {
        self.slots.iter().filter(|s| s.active).count()
    }

    pub fn write_bits(&self, out: &mut [f32]) {
        for (o, s) in out.iter_mut().zip(&self.slots) {
            *o = if s.active { 1.0 } else { 0.0 };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawn_rate_approximates_probability() {
        // With no removal the set saturates: after 200 steps at p=0.02 per
        // slot, essentially every slot should have filled exactly once.
        let mut set = ItemSet::new(100, 0.02, 0);
        let mut rng = Pcg32::seeded(1);
        let mut spawned = 0usize;
        for _ in 0..200 {
            let before = set.count_active();
            set.tick(&mut rng);
            spawned += set.count_active() - before;
        }
        assert!((90..=100).contains(&spawned), "spawned={spawned}");
        // And the single-step spawn count matches p within noise: fresh set,
        // one tick over many slots.
        let mut big = ItemSet::new(20_000, 0.02, 0);
        big.tick(&mut rng);
        let rate = big.count_active() as f64 / 20_000.0;
        assert!((rate - 0.02).abs() < 0.005, "rate={rate}");
    }

    #[test]
    fn fixed_lifetime_expires_exactly() {
        let mut set = ItemSet::new(1, 0.0, 8);
        set.slots[0] = Slot { active: true, age: 0 };
        let mut rng = Pcg32::seeded(2);
        let mut alive_steps = 0;
        for _ in 0..20 {
            if set.active(0) {
                alive_steps += 1;
            }
            set.tick(&mut rng);
        }
        assert_eq!(alive_steps, 8);
    }

    #[test]
    fn collect_deactivates() {
        let mut set = ItemSet::new(3, 0.0, 0);
        set.slots[1] = Slot { active: true, age: 5 };
        assert!(set.collect(1));
        assert!(!set.collect(1), "double collection must fail");
        assert_eq!(set.count_active(), 0);
    }

    #[test]
    fn oldest_active_prefers_age_then_index() {
        let mut set = ItemSet::new(4, 0.0, 0);
        set.slots[1] = Slot { active: true, age: 3 };
        set.slots[2] = Slot { active: true, age: 7 };
        set.slots[3] = Slot { active: true, age: 7 };
        assert_eq!(set.oldest_active(), Some(2), "oldest; lowest index on tie");
        assert_eq!(ItemSet::new(2, 0.0, 0).oldest_active(), None);
    }

    #[test]
    fn write_bits_roundtrip() {
        let mut set = ItemSet::new(3, 0.0, 0);
        set.slots[0].active = true;
        set.slots[2].active = true;
        let mut out = [0.0f32; 3];
        set.write_bits(&mut out);
        assert_eq!(out, [1.0, 0.0, 1.0]);
    }
}
