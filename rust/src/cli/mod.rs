//! Hand-rolled CLI (clap is not in the offline crate set — DESIGN.md §6).
//!
//! ```text
//! repro figure --name fig3 [--config configs/base.toml]
//! repro train  --config configs/fig3_ials.toml [--seed 1] [--learners 4]
//! repro collect --domain traffic --steps 50000 --out results/data.csv
//! repro list
//! ```

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

/// Flags that take no value (`--resume` alone means `resume = true`).
/// Everything else must be followed by a value; unknown bare flags still
/// error out, so typos never parse as booleans.
const BOOL_FLAGS: &[&str] = &["resume", "no-health"];

/// Flags that may be passed more than once (each occurrence appends a
/// value — `serve --checkpoint-dir A --checkpoint-dir B` hosts two
/// runs). Every other repeated flag is still a hard error: a silently
/// last-wins duplicate is almost always a typo.
const REPEATABLE_FLAGS: &[&str] = &["checkpoint-dir"];

/// Parsed command line: a subcommand plus `--key value` flags (each key
/// holding every value it was passed, in order).
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter();
        args.subcommand = it
            .next()
            .cloned()
            .ok_or_else(|| anyhow!("missing subcommand\n{}", USAGE))?;
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got '{a}'\n{}", USAGE))?;
            let value = if BOOL_FLAGS.contains(&key) {
                "true".to_string()
            } else {
                it.next().ok_or_else(|| anyhow!("flag --{key} needs a value"))?.clone()
            };
            let values = args.flags.entry(key.to_string()).or_default();
            if !values.is_empty() && !REPEATABLE_FLAGS.contains(&key) {
                bail!("duplicate flag --{key}");
            }
            values.push(value);
        }
        Ok(args)
    }

    /// The flag's (first) value. For repeatable flags, [`Args::get_all`]
    /// returns every occurrence.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|vs| vs.first()).map(|s| s.as_str())
    }

    /// Every value a repeatable flag was passed, in command-line order
    /// (empty if the flag is absent).
    pub fn get_all(&self, key: &str) -> &[String] {
        self.flags.get(key).map(|vs| vs.as_slice()).unwrap_or(&[])
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required flag --{key}"))
    }

    /// Whether a boolean flag (see [`BOOL_FLAGS`]) was passed.
    pub fn get_bool(&self, key: &str) -> bool {
        self.get(key) == Some("true")
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("invalid value '{v}' for --{key}")),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("invalid value '{v}' for --{key}")),
            None => Ok(default),
        }
    }

    /// [`Args::require`] + parse, for flags with no sensible default.
    pub fn require_usize(&self, key: &str) -> Result<usize> {
        let v = self.require(key)?;
        v.parse().with_context(|| format!("invalid value '{v}' for --{key}"))
    }

    /// [`Args::require`] + parse, for flags with no sensible default.
    pub fn require_u64(&self, key: &str) -> Result<u64> {
        let v = self.require(key)?;
        v.parse().with_context(|| format!("invalid value '{v}' for --{key}"))
    }
}

pub const USAGE: &str = "\
repro — Influence-Augmented Local Simulators (ICML 2022) reproduction

USAGE:
  repro figure --name <fig3|fig5|fig6|fig8|fig10|fig11|fig12> [--config <toml>]
  repro train  --config <toml> [--seed <n>] [--learners <k>]
               [--checkpoint-every <steps>] [--checkpoint-dir <dir>] [--resume]
               [--distributed <n>] [--no-health]
  repro collect --domain <traffic|warehouse> [--steps <n>] [--seed <n>]
  repro serve  --checkpoint-dir <run-dir> [--checkpoint-dir <run-dir> ...]
               [--config <toml>] [--port <p>]
  repro inspect --checkpoint-dir <run-dir> [--checkpoint-dir <run-dir> ...]
  repro bench-throughput            # GS vs LS vs IALS steps/sec table
  repro list                        # list figures and artifacts

Flags default from the config file; configs/ has one per figure.
Backend: [runtime] backend = auto|native|pjrt — `auto` (default) runs the
native CPU engine when artifacts/ is absent, so no `make artifacts` step
is needed to train end-to-end.
Multi-learner: [experiment] num_learners = K (or train --learners K) runs
K independent learners round-robin over one shared AIP dataset and one
compute pool — one curve CSV per learner.
Checkpointing: --checkpoint-every N (or [experiment] checkpoint_every)
writes a crash-safe checkpoint every N env steps per learner into
<checkpoint-dir>/<condition>_seed<seed>/; `train --resume` restarts a
killed run from the newest valid checkpoint and reproduces the
uninterrupted run bit for bit (wall-clock columns excepted).
Distributed: `train --distributed N` (or [distributed] workers) splits the
K learners across N supervised `repro worker` processes — heartbeats,
crashed/hung workers restarted from their newest checkpoint with bounded
backoff ([distributed] heartbeat_timeout_secs / max_restarts / backoff_ms),
failed shards reported per shard with a nonzero exit. Curves and final
params are bitwise identical to the in-process run at the same seed, and
the per-shard health/failure report is also written as machine-readable
<results_dir>/<condition>_seed<seed>_report.json next to the curve CSVs.
(`repro worker` is internal — the coordinator spawns it.)
Health guard: after every PPO update each learner's loss, grad norm and
param norm are checked ([health] enabled/window/spike_factor/
max_anomalies/max_rollbacks; see PERF.md). A diverged learner rolls back
to its newest valid checkpoint; after max_rollbacks it is quarantined —
the run finishes the healthy learners and exits nonzero. Checks are
read-only: a guard-on clean run is bitwise identical to --no-health
(which disables the guard, like [health] enabled = false).
Serving: `repro serve --checkpoint-dir A [--checkpoint-dir B ...]` (or
[serve] runs = [\"A\", \"B\"]) hosts each training-run directory (the
<checkpoint-dir>/<condition>_seed<seed>/ path) as a named run — the
directory basename — behind one HTTP front tier on loopback:
POST /v1/runs/<run>/learners/<j>/act with {\"obs\": [...]} returns
action, value and logits; POST /v1/runs/<run>/admin/reload atomically
hot-swaps that run (only that run) to its newest checkpoint after full
off-to-the-side validation (a corrupt or geometry-changing candidate is
a 409 and the old params keep serving); GET /healthz, /readyz and
/v1/meta (api_version 2, one entry per hosted run) report liveness,
drain state and the serving geometry. The PR-9 single-run routes
POST /v1/learners/<j>/act and POST /admin/reload are DEPRECATED aliases
onto run 0: they keep working, answered with a `Deprecation: true`
header and a `Link: ...; rel=\"successor-version\"` pointer to the
/v1/runs/ route. Connections are HTTP/1.1 keep-alive (per-connection
request cap [serve] max_requests_per_conn, idle close after [serve]
idle_timeout_ms; Connection: close is honored per request). Every
4xx/5xx body is the envelope {\"error\": {\"code\", \"message\",
\"retry_after_ms\"?}} with a stable machine-readable code. Concurrent
requests are coalesced into one batched forward per learner per run
([serve] batch_window_ms / max_batch — the window adapts to queue depth
and batching is bitwise-neutral); each run's bounded queue sheds
overload with 503 + Retry-After ([serve] queue_capacity), slow clients
time out ([serve] read/write_timeout_ms), per-request deadlines return
504 ([serve] request_timeout_ms), and SIGINT/SIGTERM drain in-flight
requests before exiting 0.
`repro inspect --checkpoint-dir <run-dir> [--checkpoint-dir ...]` prints
one verdict block per run: one line per checkpoint file with iteration,
header version, learner count and geometry, and whether the file fully
validates (CRC + payload parse) or is CORRUPT.";

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(&v(&["figure", "--name", "fig3", "--seed", "2"])).unwrap();
        assert_eq!(a.subcommand, "figure");
        assert_eq!(a.get("name"), Some("fig3"));
        assert_eq!(a.get_u64("seed", 0).unwrap(), 2);
        assert_eq!(a.get_usize("steps", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Args::parse(&v(&[])).is_err());
        assert!(Args::parse(&v(&["x", "notflag"])).is_err());
        assert!(Args::parse(&v(&["x", "--k"])).is_err());
        assert!(Args::parse(&v(&["x", "--k", "1", "--k", "2"])).is_err());
    }

    #[test]
    fn repeatable_flags_accumulate_in_order() {
        let a = Args::parse(&v(&["serve", "--checkpoint-dir", "a", "--checkpoint-dir", "b"]))
            .unwrap();
        assert_eq!(a.get_all("checkpoint-dir"), &["a".to_string(), "b".to_string()]);
        // `get` still sees the first occurrence, and absent flags are empty.
        assert_eq!(a.get("checkpoint-dir"), Some("a"));
        assert!(a.get_all("port").is_empty());
        // Non-repeatable flags still reject duplicates (see rejects_malformed).
        assert!(Args::parse(&v(&["serve", "--port", "1", "--port", "2"])).is_err());
    }

    #[test]
    fn require_reports_missing() {
        let a = Args::parse(&v(&["train"])).unwrap();
        assert!(a.require("config").is_err());
    }

    #[test]
    fn bool_flag_takes_no_value() {
        let a = Args::parse(&v(&["train", "--resume", "--seed", "3"])).unwrap();
        assert!(a.get_bool("resume"));
        assert_eq!(a.get_u64("seed", 0).unwrap(), 3);
        let b = Args::parse(&v(&["train", "--seed", "3"])).unwrap();
        assert!(!b.get_bool("resume"));
        // Trailing bool flag parses too (nothing left to consume).
        assert!(Args::parse(&v(&["train", "--resume"])).unwrap().get_bool("resume"));
    }

    #[test]
    fn require_parse_helpers() {
        let a = Args::parse(&v(&["worker", "--index", "2", "--seed", "z"])).unwrap();
        assert_eq!(a.require_usize("index").unwrap(), 2);
        assert_eq!(a.require_u64("index").unwrap(), 2);
        assert!(a.require_usize("count").is_err(), "missing flag must error");
        let err = format!("{:#}", a.require_u64("seed").unwrap_err());
        assert!(err.contains("--seed") && err.contains("'z'"), "{err}");
    }

    #[test]
    fn parse_errors_name_the_flag() {
        let a = Args::parse(&v(&["train", "--seed", "x", "--steps", "1e4"])).unwrap();
        let err = format!("{:#}", a.get_u64("seed", 0).unwrap_err());
        assert!(err.contains("--seed"), "error must name the flag: {err}");
        assert!(err.contains("'x'"), "error must quote the value: {err}");
        let err = format!("{:#}", a.get_usize("steps", 0).unwrap_err());
        assert!(err.contains("--steps"), "error must name the flag: {err}");
    }
}
