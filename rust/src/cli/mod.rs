//! Hand-rolled CLI (clap is not in the offline crate set — DESIGN.md §6).
//!
//! ```text
//! repro figure --name fig3 [--config configs/base.toml]
//! repro train  --config configs/fig3_ials.toml [--seed 1] [--learners 4]
//! repro collect --domain traffic --steps 50000 --out results/data.csv
//! repro list
//! ```

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: a subcommand plus `--key value` flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter();
        args.subcommand = it
            .next()
            .cloned()
            .ok_or_else(|| anyhow!("missing subcommand\n{}", USAGE))?;
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got '{a}'\n{}", USAGE))?;
            let value = it.next().ok_or_else(|| anyhow!("flag --{key} needs a value"))?.clone();
            if args.flags.insert(key.to_string(), value).is_some() {
                bail!("duplicate flag --{key}");
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required flag --{key}"))
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }
}

pub const USAGE: &str = "\
repro — Influence-Augmented Local Simulators (ICML 2022) reproduction

USAGE:
  repro figure --name <fig3|fig5|fig6|fig8|fig10|fig11|fig12> [--config <toml>]
  repro train  --config <toml> [--seed <n>] [--learners <k>]
  repro collect --domain <traffic|warehouse> [--steps <n>] [--seed <n>]
  repro bench-throughput            # GS vs LS vs IALS steps/sec table
  repro list                        # list figures and artifacts

Flags default from the config file; configs/ has one per figure.
Backend: [runtime] backend = auto|native|pjrt — `auto` (default) runs the
native CPU engine when artifacts/ is absent, so no `make artifacts` step
is needed to train end-to-end.
Multi-learner: [experiment] num_learners = K (or train --learners K) runs
K independent learners round-robin over one shared AIP dataset and one
compute pool — one curve CSV per learner.";

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(&v(&["figure", "--name", "fig3", "--seed", "2"])).unwrap();
        assert_eq!(a.subcommand, "figure");
        assert_eq!(a.get("name"), Some("fig3"));
        assert_eq!(a.get_u64("seed", 0).unwrap(), 2);
        assert_eq!(a.get_usize("steps", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Args::parse(&v(&[])).is_err());
        assert!(Args::parse(&v(&["x", "notflag"])).is_err());
        assert!(Args::parse(&v(&["x", "--k"])).is_err());
        assert!(Args::parse(&v(&["x", "--k", "1", "--k", "2"])).is_err());
    }

    #[test]
    fn require_reports_missing() {
        let a = Args::parse(&v(&["train"])).unwrap();
        assert!(a.require("config").is_err());
    }
}
