//! Tiny CSV writer/reader for learning curves and benchmark tables.
//! (No serde in the offline crate set; the format we need is trivial.)

use anyhow::{Context, Result};
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Append-style CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    columns: usize,
}

impl CsvWriter {
    /// Create (truncate) `path` and write the header row.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> Result<CsvWriter> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let file = File::create(path.as_ref())
            .with_context(|| format!("creating {}", path.as_ref().display()))?;
        let mut out = BufWriter::new(file);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, columns: header.len() })
    }

    /// Write one row of f64 values (formatted with enough precision).
    pub fn row(&mut self, values: &[f64]) -> Result<()> {
        anyhow::ensure!(
            values.len() == self.columns,
            "row has {} values, header has {}",
            values.len(),
            self.columns
        );
        let mut line = String::with_capacity(values.len() * 12);
        for (i, v) in values.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            line.push_str(&format!("{v:.6}"));
        }
        writeln!(self.out, "{line}")?;
        Ok(())
    }

    /// Write one row of raw string cells.
    pub fn row_str(&mut self, values: &[String]) -> Result<()> {
        anyhow::ensure!(values.len() == self.columns, "column count mismatch");
        writeln!(self.out, "{}", values.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Read a CSV written by [`CsvWriter`]: returns (header, rows-of-f64).
pub fn read_numeric(path: impl AsRef<Path>) -> Result<(Vec<String>, Vec<Vec<f64>>)> {
    let file = File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut lines = BufReader::new(file).lines();
    let header = lines
        .next()
        .context("empty csv")??
        .split(',')
        .map(|s| s.to_string())
        .collect();
    let mut rows = Vec::new();
    for line in lines {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let row: Result<Vec<f64>, _> = line.split(',').map(|s| s.trim().parse::<f64>()).collect();
        rows.push(row.context("non-numeric cell")?);
    }
    Ok((header, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("ials_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&[1.0, 2.5]).unwrap();
            w.row(&[3.0, -4.25]).unwrap();
            w.flush().unwrap();
        }
        let (header, rows) = read_numeric(&path).unwrap();
        assert_eq!(header, vec!["a", "b"]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1][1], -4.25);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn wrong_arity_rejected() {
        let dir = std::env::temp_dir().join("ials_csv_test2");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        assert!(w.row(&[1.0]).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
