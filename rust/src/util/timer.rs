//! Wall-clock timing helpers. Learning curves in the paper are plotted
//! against *wall-clock time* (Figures 3/5/6/10–12), so timing is a
//! first-class measurement, not just profiling.

use std::time::{Duration, Instant};

/// A stopwatch that can be paused (e.g. to exclude evaluation time from the
/// training clock, matching the paper's protocol of interleaved evals).
#[derive(Debug)]
pub struct Stopwatch {
    accumulated: Duration,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { accumulated: Duration::ZERO, started: None }
    }

    pub fn start() -> Self {
        let mut s = Self::new();
        s.resume();
        s
    }

    pub fn resume(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    pub fn pause(&mut self) {
        if let Some(t0) = self.started.take() {
            self.accumulated += t0.elapsed();
        }
    }

    pub fn is_running(&self) -> bool {
        self.started.is_some()
    }

    pub fn elapsed(&self) -> Duration {
        match self.started {
            Some(t0) => self.accumulated + t0.elapsed(),
            None => self.accumulated,
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn reset(&mut self) {
        self.accumulated = Duration::ZERO;
        self.started = None;
    }

    /// Restore a paused stopwatch to a previously observed elapsed time
    /// (checkpoint resume: the training clock continues from the saved
    /// wall-clock total instead of restarting at zero).
    pub fn set_elapsed(&mut self, secs: f64) {
        self.accumulated = Duration::from_secs_f64(secs.max(0.0));
        self.started = None;
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pause_excludes_time() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(10));
        sw.pause();
        let at_pause = sw.elapsed();
        std::thread::sleep(Duration::from_millis(20));
        // No time accrued while paused.
        assert_eq!(sw.elapsed(), at_pause);
        sw.resume();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.elapsed() > at_pause);
    }

    #[test]
    fn timed_returns_result() {
        let (v, secs) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
