//! Binary state (de)serialization for checkpoints: a little-endian,
//! length-checked writer/reader pair, an IEEE CRC-32, and the crash-safe
//! atomic file writer every durable artifact of the crate routes through
//! (temp file in the target directory → fsync → atomic rename → best-effort
//! directory fsync).

use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

/// Append-only little-endian byte buffer for snapshot payloads.
#[derive(Debug, Default)]
pub struct StateWriter {
    buf: Vec<u8>,
}

impl StateWriter {
    pub fn new() -> StateWriter {
        StateWriter { buf: Vec::new() }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }

    pub fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn usize(&mut self, x: usize) {
        self.u64(x as u64);
    }

    pub fn f32(&mut self, x: f32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn f64(&mut self, x: f64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn bool(&mut self, x: bool) {
        self.u8(x as u8);
    }

    /// Length-prefixed raw bytes.
    pub fn bytes(&mut self, xs: &[u8]) {
        self.usize(xs.len());
        self.buf.extend_from_slice(xs);
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.bytes(s.as_bytes());
    }

    /// Length-prefixed f32 slice.
    pub fn f32s(&mut self, xs: &[f32]) {
        self.usize(xs.len());
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed bool slice (one byte per flag).
    pub fn bools(&mut self, xs: &[bool]) {
        self.usize(xs.len());
        for &x in xs {
            self.buf.push(x as u8);
        }
    }

    /// Length-prefixed u64 slice.
    pub fn u64s(&mut self, xs: &[u64]) {
        self.usize(xs.len());
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Cursor over a snapshot payload; every read is bounds-checked so a
/// truncated or corrupt blob surfaces as a structured error, never a panic.
#[derive(Debug)]
pub struct StateReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> StateReader<'a> {
    pub fn new(buf: &'a [u8]) -> StateReader<'a> {
        StateReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "truncated state: need {n} bytes at offset {}, {} remain",
                self.pos,
                self.remaining()
            );
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn usize(&mut self) -> Result<usize> {
        let x = self.u64()?;
        usize::try_from(x).map_err(|_| anyhow::anyhow!("state value {x} overflows usize"))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => bail!("corrupt state: bool byte {other}"),
        }
    }

    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.usize()?;
        self.take(n)
    }

    pub fn str(&mut self) -> Result<&'a str> {
        std::str::from_utf8(self.bytes()?).context("corrupt state: non-UTF-8 string")
    }

    /// Read a length-prefixed f32 slice into `out` (must match the stored
    /// length — snapshot geometry is fixed by construction).
    pub fn f32s_into(&mut self, out: &mut [f32]) -> Result<()> {
        let n = self.usize()?;
        anyhow::ensure!(n == out.len(), "state f32 slice len {n}, expected {}", out.len());
        let src = self.take(n * 4)?;
        for (x, chunk) in out.iter_mut().zip(src.chunks_exact(4)) {
            *x = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        Ok(())
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.usize()?;
        let src = self.take(n * 4)?;
        Ok(src.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn bools_into(&mut self, out: &mut [bool]) -> Result<()> {
        let n = self.usize()?;
        anyhow::ensure!(n == out.len(), "state bool slice len {n}, expected {}", out.len());
        let src = self.take(n)?;
        for (x, &b) in out.iter_mut().zip(src) {
            *x = match b {
                0 => false,
                1 => true,
                other => bail!("corrupt state: bool byte {other}"),
            };
        }
        Ok(())
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.usize()?;
        let src = self.take(n * 8)?;
        Ok(src.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Assert the payload is fully consumed (catches writer/reader skew).
    pub fn expect_end(&self) -> Result<()> {
        anyhow::ensure!(
            self.remaining() == 0,
            "state has {} trailing bytes (format skew?)",
            self.remaining()
        );
        Ok(())
    }
}

/// IEEE CRC-32 (the zlib polynomial), table-driven.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

/// Crash-safe file write: the bytes land in a temp file in the target
/// directory, are fsynced, then atomically renamed over `path` — a crash
/// at any point leaves either the old file or the new one, never a torn
/// mix. The directory fsync after the rename is best-effort (not every
/// filesystem supports it) and only affects when the rename becomes
/// durable, not its atomicity.
pub fn atomic_write(path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
    let path = path.as_ref();
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => {
            std::fs::create_dir_all(d)
                .with_context(|| format!("creating directory {}", d.display()))?;
            Some(d)
        }
        _ => None,
    };
    let file_name = path
        .file_name()
        .and_then(|n| n.to_str())
        .with_context(|| format!("atomic_write: bad path {}", path.display()))?;
    let tmp = path.with_file_name(format!(".{file_name}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes)?;
        f.sync_all().with_context(|| format!("fsync {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    if let Some(d) = dir {
        if let Ok(df) = std::fs::File::open(d) {
            let _ = df.sync_all();
        }
    }
    Ok(())
}

/// Size of the self-validating blob header written by [`headered_bytes`]:
/// magic (8) + format version (LE u32) + payload length (LE u64) + payload
/// CRC-32 (LE u32). Shared by every durable artifact of the crate
/// (checkpoints, the distributed runtime's AIP dataset and shard results).
pub const HEADER_LEN: usize = 8 + 4 + 8 + 4;

/// Frame `payload` behind the standard self-validating header.
pub fn headered_bytes(magic: &[u8; 8], version: u32, payload: &[u8]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(HEADER_LEN + payload.len());
    bytes.extend_from_slice(magic);
    bytes.extend_from_slice(&version.to_le_bytes());
    bytes.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    bytes.extend_from_slice(&crc32(payload).to_le_bytes());
    bytes.extend_from_slice(payload);
    bytes
}

/// Validate the fixed-size header prefix (`bytes.len() == HEADER_LEN`) and
/// return the payload length and CRC it declares. Shared by the in-memory
/// [`parse_headered`] and the file-backed [`read_headered`], so both reject
/// a corrupt header with the same structured errors.
fn check_header(magic: &[u8; 8], version: u32, header: &[u8; HEADER_LEN]) -> Result<(u64, u32)> {
    anyhow::ensure!(&header[..8] == magic, "bad magic (not a {} file)", magic.escape_ascii());
    let stored_version = u32::from_le_bytes(header[8..12].try_into().unwrap());
    anyhow::ensure!(
        stored_version == version,
        "format version {stored_version}, this build reads {version}"
    );
    let payload_len = u64::from_le_bytes(header[12..20].try_into().unwrap());
    let stored_crc = u32::from_le_bytes(header[20..24].try_into().unwrap());
    Ok((payload_len, stored_crc))
}

/// Validate a [`headered_bytes`] frame and return its payload slice. Errors
/// name the failure (truncation, foreign magic, version skew, CRC mismatch)
/// so callers can log *why* a file was rejected before falling back.
pub fn parse_headered<'a>(magic: &[u8; 8], version: u32, bytes: &'a [u8]) -> Result<&'a [u8]> {
    anyhow::ensure!(!bytes.is_empty(), "empty file");
    anyhow::ensure!(
        bytes.len() >= HEADER_LEN,
        "{} bytes — shorter than the {HEADER_LEN}-byte header (truncated)",
        bytes.len()
    );
    let header: &[u8; HEADER_LEN] = bytes[..HEADER_LEN].try_into().unwrap();
    let (payload_len, stored_crc) = check_header(magic, version, header)?;
    let payload = &bytes[HEADER_LEN..];
    anyhow::ensure!(
        payload.len() as u64 == payload_len,
        "header says {payload_len} payload bytes, file has {} (truncated)",
        payload.len()
    );
    anyhow::ensure!(
        crc32(payload) == stored_crc,
        "CRC mismatch — corrupt (bit flip or torn write)"
    );
    Ok(payload)
}

/// [`atomic_write`] of a [`headered_bytes`] frame.
pub fn write_headered(
    path: impl AsRef<Path>,
    magic: &[u8; 8],
    version: u32,
    payload: &[u8],
) -> Result<()> {
    atomic_write(path, &headered_bytes(magic, version, payload))
}

/// Read and validate a [`write_headered`] file, returning its payload.
///
/// Defensive against a corrupt length field: the header's `payload_len` is
/// bounded against the file's actual on-disk size *before* any
/// payload-sized allocation, so a bit flip that turns the length into
/// terabytes is a structured error naming both numbers — not an attempted
/// huge allocation (`rust/tests/state_properties.rs`).
pub fn read_headered(path: impl AsRef<Path>, magic: &[u8; 8], version: u32) -> Result<Vec<u8>> {
    let path = path.as_ref();
    let inner = || -> Result<Vec<u8>> {
        let mut f =
            std::fs::File::open(path).with_context(|| format!("reading {}", path.display()))?;
        let file_len = f.metadata().with_context(|| format!("stat {}", path.display()))?.len();
        anyhow::ensure!(file_len > 0, "empty file");
        anyhow::ensure!(
            file_len >= HEADER_LEN as u64,
            "{file_len} bytes — shorter than the {HEADER_LEN}-byte header (truncated)"
        );
        let mut header = [0u8; HEADER_LEN];
        std::io::Read::read_exact(&mut f, &mut header)?;
        let (payload_len, stored_crc) = check_header(magic, version, &header)?;
        let actual = file_len - HEADER_LEN as u64;
        anyhow::ensure!(
            payload_len == actual,
            "header says {payload_len} payload bytes, file has {actual} ({})",
            if payload_len > actual {
                "truncated, or a corrupt length field — not allocating"
            } else {
                "trailing bytes — truncated header or foreign file"
            }
        );
        let mut payload = vec![0u8; actual as usize];
        std::io::Read::read_exact(&mut f, &mut payload)
            .context("file shrank while reading the payload")?;
        anyhow::ensure!(
            crc32(&payload) == stored_crc,
            "CRC mismatch — corrupt (bit flip or torn write)"
        );
        Ok(payload)
    };
    inner().with_context(|| format!("validating {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_reader_roundtrip() {
        let mut w = StateWriter::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 1);
        w.usize(42);
        w.f32(1.5);
        w.f64(-2.25);
        w.bool(true);
        w.bytes(b"abc");
        w.str("hello");
        w.f32s(&[1.0, 2.0, 3.0]);
        w.bools(&[true, false, true]);
        w.u64s(&[9, 8]);
        let bytes = w.into_bytes();
        let mut r = StateReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.usize().unwrap(), 42);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.f64().unwrap(), -2.25);
        assert!(r.bool().unwrap());
        assert_eq!(r.bytes().unwrap(), b"abc");
        assert_eq!(r.str().unwrap(), "hello");
        let mut xs = [0.0f32; 3];
        r.f32s_into(&mut xs).unwrap();
        assert_eq!(xs, [1.0, 2.0, 3.0]);
        let mut bs = [false; 3];
        r.bools_into(&mut bs).unwrap();
        assert_eq!(bs, [true, false, true]);
        assert_eq!(r.u64s().unwrap(), vec![9, 8]);
        r.expect_end().unwrap();
    }

    #[test]
    fn truncated_reads_error_not_panic() {
        let mut w = StateWriter::new();
        w.u64(1);
        let mut bytes = w.into_bytes();
        bytes.truncate(5);
        let mut r = StateReader::new(&bytes);
        let err = r.u64().unwrap_err().to_string();
        assert!(err.contains("truncated state"), "{err}");
        // A length prefix pointing past the end is also caught.
        let mut w = StateWriter::new();
        w.usize(1_000_000);
        let bytes = w.into_bytes();
        assert!(StateReader::new(&bytes).bytes().is_err());
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut w = StateWriter::new();
        w.u32(1);
        w.u32(2);
        let bytes = w.into_bytes();
        let mut r = StateReader::new(&bytes);
        r.u32().unwrap();
        assert!(r.expect_end().is_err());
    }

    #[test]
    fn crc32_known_vector() {
        // The classic check value for the IEEE polynomial.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"abc"), crc32(b"abd"));
    }

    #[test]
    fn atomic_write_replaces_and_cleans_tmp() {
        let dir = std::env::temp_dir().join("ials_state_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("blob.bin");
        atomic_write(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        assert!(!dir.join(".blob.bin.tmp").exists());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn crash_mid_write_never_tears_the_destination() {
        use crate::testkit::fault::partial_atomic_write;
        let dir = std::env::temp_dir().join("ials_state_torn_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("blob.bin");
        atomic_write(&path, b"committed").unwrap();
        // Die partway through writing a replacement: the temp file holds a
        // truncated prefix and the rename never happens — the committed
        // contents must be byte-for-byte intact, not torn.
        let tmp = partial_atomic_write(&path, b"replacement-that-died", 7).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"committed");
        // Recovery after the "crash": the next full atomic_write reclaims
        // the stale temp name and lands atomically.
        atomic_write(&path, b"recovered").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"recovered");
        assert!(!tmp.exists(), "recovery consumed the stale temp file");
        // Same holds when the destination never existed: a torn first write
        // leaves no destination at all (absent, not half-written).
        let fresh = dir.join("fresh.bin");
        partial_atomic_write(&fresh, b"never-landed", 4).unwrap();
        assert!(!fresh.exists());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn headered_blob_roundtrip_and_rejection() {
        const MAGIC: &[u8; 8] = b"IALSTEST";
        let framed = headered_bytes(MAGIC, 3, b"payload");
        assert_eq!(framed.len(), HEADER_LEN + 7);
        assert_eq!(parse_headered(MAGIC, 3, &framed).unwrap(), b"payload");
        let msg = |r: Result<&[u8]>| r.unwrap_err().to_string();
        assert!(msg(parse_headered(b"IALSELSE", 3, &framed)).contains("magic"));
        assert!(msg(parse_headered(MAGIC, 4, &framed)).contains("version"));
        let mut flipped = framed.clone();
        let n = flipped.len();
        flipped[n - 1] ^= 1;
        assert!(msg(parse_headered(MAGIC, 3, &flipped)).contains("CRC"));
        assert!(msg(parse_headered(MAGIC, 3, &framed[..n - 2])).contains("truncated"));
        assert!(msg(parse_headered(MAGIC, 3, &framed[..10])).contains("truncated"));
        assert!(parse_headered(MAGIC, 3, &[]).is_err());
        // The empty payload is legal (all validation is in the header).
        let empty = headered_bytes(MAGIC, 3, &[]);
        assert_eq!(parse_headered(MAGIC, 3, &empty).unwrap(), b"");
    }

    #[test]
    fn write_read_headered_roundtrip() {
        const MAGIC: &[u8; 8] = b"IALSTEST";
        let dir = std::env::temp_dir().join("ials_state_headered_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("blob.bin");
        write_headered(&path, MAGIC, 1, b"data").unwrap();
        assert_eq!(read_headered(&path, MAGIC, 1).unwrap(), b"data");
        // The error context names the offending file.
        let err = read_headered(&path, MAGIC, 2).unwrap_err();
        assert!(format!("{err:#}").contains("blob.bin"), "{err:#}");
        std::fs::remove_dir_all(dir).ok();
    }
}
