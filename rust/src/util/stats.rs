//! Online statistics (Welford) and simple sample summaries used by metrics,
//! evaluation and the bench harness.

/// Numerically-stable running mean/variance (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.m2 / self.n as f64 }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Summary (mean/std/percentiles) of a finite sample.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let pct = |q: f64| -> f64 {
            let idx = ((n - 1) as f64 * q).round() as usize;
            sorted[idx]
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: pct(0.5),
            p95: pct(0.95),
            max: sorted[n - 1],
        }
    }
}

/// Mean of an f32 slice (0.0 on empty).
pub fn mean_f32(xs: &[f32]) -> f32 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f32>() / xs.len() as f32 }
}

/// Softmax over logits (stable), written into `out`.
pub fn softmax_into(logits: &[f32], out: &mut [f32]) {
    debug_assert_eq!(logits.len(), out.len());
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for (o, &l) in out.iter_mut().zip(logits) {
        let e = (l - max).exp();
        *o = e;
        sum += e;
    }
    for o in out.iter_mut() {
        *o /= sum;
    }
}

/// log softmax(logits)[idx] — the log-probability of one category.
pub fn log_prob_from_logits(logits: &[f32], idx: usize) -> f32 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = logits.iter().map(|&l| (l - max).exp()).sum::<f32>().ln() + max;
    logits[idx] - lse
}

/// Binary cross-entropy -[y ln p + (1-y) ln (1-p)] with clamping, averaged
/// over the slice pair. Used to score AIP predictions (paper Fig 3/5 bottom).
pub fn binary_cross_entropy(probs: &[f32], targets: &[f32]) -> f32 {
    assert_eq!(probs.len(), targets.len());
    let eps = 1e-7f32;
    let mut total = 0.0f32;
    for (&p, &y) in probs.iter().zip(targets) {
        let p = p.clamp(eps, 1.0 - eps);
        total -= y * p.ln() + (1.0 - y) * (1.0 - p).ln();
    }
    total / probs.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut st = OnlineStats::new();
        for &x in &xs {
            st.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((st.mean() - mean).abs() < 1e-12);
        assert!((st.variance() - var).abs() < 1e-12);
        assert_eq!(st.min(), 1.0);
        assert_eq!(st.max(), 10.0);
    }

    #[test]
    fn merge_equals_single_stream() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 5.0).collect();
        let mut whole = OnlineStats::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.variance() - whole.variance()).abs() < 1e-10);
    }

    #[test]
    fn summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.0).abs() <= 1.0);
        assert!((s.p95 - 95.0).abs() <= 1.0);
    }

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let logits = [1.0f32, 2.0, 3.0];
        let mut out = [0.0f32; 3];
        softmax_into(&logits, &mut out);
        let sum: f32 = out.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(out[2] > out[1] && out[1] > out[0]);
    }

    #[test]
    fn log_prob_consistent_with_softmax() {
        let logits = [0.3f32, -1.2, 2.0, 0.0];
        let mut probs = [0.0f32; 4];
        softmax_into(&logits, &mut probs);
        for i in 0..4 {
            assert!((log_prob_from_logits(&logits, i) - probs[i].ln()).abs() < 1e-5);
        }
    }

    #[test]
    fn bce_perfect_prediction_is_small() {
        let p = [0.999f32, 0.001];
        let y = [1.0f32, 0.0];
        assert!(binary_cross_entropy(&p, &y) < 0.01);
        // Wrong prediction is large.
        let y2 = [0.0f32, 1.0];
        assert!(binary_cross_entropy(&p, &y2) > 3.0);
    }
}
