//! PCG32 pseudo-random number generator (O'Neill 2014).
//!
//! The offline crate set has no `rand`; simulations, exploration policies
//! and influence sampling all draw from this deterministic, seedable
//! generator so every experiment is exactly reproducible from its seed.

/// PCG-XSH-RR 64/32. Small state, good statistical quality, fully
/// deterministic across platforms.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id. Different streams
    /// with the same seed are independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        // 24 mantissa-ish bits -> uniform in [0,1)
        (self.next_u32() >> 8) as f32 * (1.0 / 16_777_216.0)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / 9_007_199_254_740_992.0)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Uniform integer in `[0, n)` (n > 0) via Lemire-style rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u32;
        // Rejection sampling to remove modulo bias.
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return (r % n) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut x = self.f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample an action index from a categorical distribution given logits
    /// (Gumbel-max trick — numerically stable, no normalization needed).
    pub fn categorical_from_logits(&mut self, logits: &[f32]) -> usize {
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &l) in logits.iter().enumerate() {
            // Gumbel(0,1) = -ln(-ln(U))
            let u = self.f32().max(1e-9);
            let g = -(-(u.ln())).ln();
            let v = l + g;
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-9);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive an independent child generator (for per-env streams).
    pub fn split(&mut self, stream: u64) -> Pcg32 {
        Pcg32::new(self.next_u64(), stream)
    }

    /// Export the full generator state `(state, inc)` for checkpointing.
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from an exported [`Pcg32::state`] pair — the
    /// restored stream continues bit for bit where the saved one stopped.
    pub fn from_state(state: u64, inc: u64) -> Pcg32 {
        Pcg32 { state, inc }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut rng = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let x = rng.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Pcg32::seeded(3);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[rng.below(5)] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.2).abs() < 0.02, "frac={frac}");
        }
    }

    #[test]
    fn bernoulli_mean_matches_p() {
        let mut rng = Pcg32::seeded(11);
        let hits = (0..100_000).filter(|_| rng.bernoulli(0.1)).count();
        let mean = hits as f64 / 100_000.0;
        assert!((mean - 0.1).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn categorical_prefers_high_logit() {
        let mut rng = Pcg32::seeded(5);
        let logits = [0.0f32, 3.0, 0.0];
        let hits = (0..2000).filter(|_| rng.categorical_from_logits(&logits) == 1).count();
        // softmax([0,3,0])[1] ~ 0.9
        assert!(hits > 1600, "hits={hits}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(9);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Pcg32::new(99, 5);
        for _ in 0..37 {
            a.next_u32();
        }
        let (s, inc) = a.state();
        let mut b = Pcg32::from_state(s, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn normal_has_unit_moments() {
        let mut rng = Pcg32::seeded(13);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
