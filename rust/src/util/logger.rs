//! Minimal leveled logger (the offline crate set has no `log`/`env_logger`).
//!
//! Controlled by `IALS_LOG` (error|warn|info|debug|trace, default info).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(2);
static INITIALIZED: std::sync::Once = std::sync::Once::new();

/// Initialize log level from the `IALS_LOG` environment variable.
pub fn init() {
    INITIALIZED.call_once(|| {
        let lvl = match std::env::var("IALS_LOG").unwrap_or_default().to_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        };
        MAX_LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
}

pub fn set_level(lvl: Level) {
    MAX_LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Test-only capture sink: while some test holds it open, every emitted
/// line is *also* appended here (emission to stderr is unchanged). Global
/// rather than thread-local because the code under test may log from pool
/// threads; tests filter captured lines by their own paths/tags, so
/// concurrent tests don't confuse each other.
static CAPTURE: Mutex<Option<Vec<String>>> = Mutex::new(None);

/// Serializes tests that use the capture sink — it is process-global, so
/// two tests capturing at once would drain each other's lines. Lock via
/// [`capture_test_guard`] for the whole capture..drain span.
static CAPTURE_TEST_LOCK: Mutex<()> = Mutex::new(());

/// Take the capture-sink test lock (poison-tolerant: a previous test's
/// panic must not cascade).
pub fn capture_test_guard() -> std::sync::MutexGuard<'static, ()> {
    CAPTURE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Start capturing log lines (see [`drain_captured`]). Idempotent: a second
/// call while a capture is active keeps the already-captured lines.
pub fn capture_for_test() {
    let mut sink = CAPTURE.lock().unwrap();
    if sink.is_none() {
        *sink = Some(Vec::new());
    }
}

/// Stop capturing and return every line logged since [`capture_for_test`],
/// formatted as `[TAG ] message`.
pub fn drain_captured() -> Vec<String> {
    CAPTURE.lock().unwrap().take().unwrap_or_default()
}

pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(lvl) {
        return;
    }
    let tag = match lvl {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let line = format!("[{tag}] {args}");
    if let Some(sink) = CAPTURE.lock().unwrap().as_mut() {
        sink.push(line.clone());
    }
    let stderr = std::io::stderr();
    let mut h = stderr.lock();
    let _ = writeln!(h, "{line}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Error, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn capture_sees_emitted_lines() {
        let _guard = capture_test_guard();
        capture_for_test();
        crate::log_warn!("capture-test sentinel {}", 42);
        let mine: Vec<String> = drain_captured()
            .into_iter()
            .filter(|l| l.contains("capture-test sentinel"))
            .collect();
        assert_eq!(mine, vec!["[WARN ] capture-test sentinel 42"]);
        // Draining closes the sink; later lines are not captured.
        crate::log_warn!("capture-test sentinel after drain");
        assert!(drain_captured().is_empty());
    }
}
