//! Minimal leveled logger (the offline crate set has no `log`/`env_logger`).
//!
//! Controlled by `IALS_LOG` (error|warn|info|debug|trace, default info).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(2);
static INITIALIZED: std::sync::Once = std::sync::Once::new();

/// Initialize log level from the `IALS_LOG` environment variable.
pub fn init() {
    INITIALIZED.call_once(|| {
        let lvl = match std::env::var("IALS_LOG").unwrap_or_default().to_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        };
        MAX_LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
}

pub fn set_level(lvl: Level) {
    MAX_LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(lvl) {
        return;
    }
    let tag = match lvl {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let stderr = std::io::stderr();
    let mut h = stderr.lock();
    let _ = writeln!(h, "[{tag}] {args}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Error, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
