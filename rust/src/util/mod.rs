//! Small shared utilities: deterministic PRNG, running statistics, CSV
//! output, logging and wall-clock timing.

pub mod csv;
pub mod logger;
pub mod rng;
pub mod state;
pub mod stats;
pub mod timer;

pub use rng::Pcg32;
pub use state::{StateReader, StateWriter};
pub use stats::{OnlineStats, Summary};
pub use timer::Stopwatch;
