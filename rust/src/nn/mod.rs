//! Neural-network parameter store: the host-side home of every model's
//! weights and Adam state. Parameters are loaded once from the AOT
//! emitter's `<model>.params.bin` (PJRT backend) or synthesized in memory
//! (native backend), handed to the execution backend as leading arguments
//! on every call, and written back by training artifacts.
//!
//! [`kernels`] holds the hand-rolled CPU math the native backend executes.

pub mod kernels;

use crate::runtime::manifest::ModelSpec;
use crate::util::state::{atomic_write, crc32};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

/// Magic + format version of the headered `save_bin` layout. The legacy
/// layout (the AOT emitter's raw little-endian f32 blob) has no header and
/// is still accepted by [`ParamStore::load_bin`] when the file length
/// matches the spec exactly.
const PARAMS_MAGIC: &[u8; 8] = b"IALSPRMS";
const PARAMS_VERSION: u32 = 1;
/// magic + version + payload_len + crc32.
const PARAMS_HEADER_LEN: usize = 8 + 4 + 8 + 4;

/// Unique id per store instance (keys the runtime's device-buffer cache).
static STORE_IDS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);

/// Flat f32 tensors for one model, ordered as in the manifest.
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub model: String,
    names: Vec<String>,
    tensors: Vec<Vec<f32>>,
    index: BTreeMap<String, usize>,
    /// Identity + mutation counter: the runtime caches device-resident
    /// copies of the parameters and invalidates on (id, version) change.
    id: u64,
    version: u64,
}

impl ParamStore {
    /// Build a zero-initialized store for a model spec.
    pub fn zeros(spec: &ModelSpec) -> ParamStore {
        let names: Vec<String> = spec.params.iter().map(|p| p.name.clone()).collect();
        let tensors: Vec<Vec<f32>> = spec.params.iter().map(|p| vec![0.0; p.numel()]).collect();
        let index = names.iter().enumerate().map(|(i, n)| (n.clone(), i)).collect();
        ParamStore {
            model: spec.name.clone(),
            names,
            tensors,
            index,
            id: STORE_IDS.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            version: 0,
        }
    }

    /// Deterministic Glorot-style initialization (the native backend's
    /// replacement for `<model>.params.bin` when no artifacts directory
    /// exists): zero biases/Adam slots, seeded normal weights.
    pub fn glorot(spec: &ModelSpec, seed: u64) -> ParamStore {
        let mut st = Self::zeros(spec);
        st.reinit(spec, seed);
        st
    }

    /// (identity, mutation counter) for device-buffer cache keys.
    pub fn cache_key(&self) -> (u64, u64) {
        (self.id, self.version)
    }

    /// Simultaneous mutable access to a base tensor and its Adam slots
    /// `(name, m.name, v.name)` — one borrow-checked split, no copies.
    /// Used by the native backend's in-place Adam step. Bumps the version.
    pub fn adam_slots_mut(&mut self, name: &str) -> Result<(&mut [f32], &mut [f32], &mut [f32])> {
        let idx = self.adam_indices(name)?;
        self.adam_slots_at(idx)
    }

    /// Resolve `(name, m.name, v.name)` to tensor indices once, so hot
    /// training loops can use [`ParamStore::adam_slots_at`] without the
    /// per-call name formatting (which allocates). Indices stay valid for
    /// the life of the store (the tensor list never changes shape).
    pub fn adam_indices(&self, name: &str) -> Result<[usize; 3]> {
        let ip = *self
            .index
            .get(name)
            .ok_or_else(|| anyhow!("model {}: no tensor '{name}'", self.model))?;
        let im = *self
            .index
            .get(format!("m.{name}").as_str())
            .ok_or_else(|| anyhow!("model {}: no Adam slot 'm.{name}'", self.model))?;
        let iv = *self
            .index
            .get(format!("v.{name}").as_str())
            .ok_or_else(|| anyhow!("model {}: no Adam slot 'v.{name}'", self.model))?;
        anyhow::ensure!(ip != im && ip != iv && im != iv, "duplicate tensor indices");
        Ok([ip, im, iv])
    }

    /// Index-based variant of [`ParamStore::adam_slots_mut`] — the
    /// allocation-free training path (`runtime::native` caches the indices
    /// per op at first call). Bumps the version.
    pub fn adam_slots_at(
        &mut self,
        [ip, im, iv]: [usize; 3],
    ) -> Result<(&mut [f32], &mut [f32], &mut [f32])> {
        anyhow::ensure!(
            ip != im && ip != iv && im != iv && ip.max(im).max(iv) < self.tensors.len(),
            "bad adam slot indices"
        );
        self.version += 1;
        let (p, m, v) = disjoint3_mut(&mut self.tensors, ip, im, iv);
        Ok((p.as_mut_slice(), m.as_mut_slice(), v.as_mut_slice()))
    }

    /// Mutable access to a tensor (bumps the version — device caches of
    /// this store are invalidated).
    pub fn tensor_mut(&mut self, name: &str) -> Result<&mut [f32]> {
        let &i = self
            .index
            .get(name)
            .ok_or_else(|| anyhow!("model {}: no tensor '{name}'", self.model))?;
        self.version += 1;
        Ok(&mut self.tensors[i])
    }

    /// Load `<model>.params.bin`. Two accepted layouts:
    ///
    /// * **Headered** (written by [`ParamStore::save_bin`]): magic +
    ///   version + payload length + CRC-32, then the spec-ordered raw
    ///   little-endian f32 payload. Zero-length, truncated and bit-flipped
    ///   files all surface as structured errors, never a panic.
    /// * **Legacy** (the AOT emitter's headerless raw blob): accepted only
    ///   when the file length equals the spec's total byte size exactly —
    ///   the pre-existing artifact flow keeps working unchanged.
    pub fn load_bin(spec: &ModelSpec, path: impl AsRef<Path>) -> Result<ParamStore> {
        let path = path.as_ref();
        let mut file =
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;
        let expected = spec.total_numel() * 4;
        anyhow::ensure!(!bytes.is_empty(), "param blob {}: empty file", path.display());
        let payload: &[u8] = if bytes.len() == expected {
            // Legacy raw blob: the length is the only (exact) check it has.
            &bytes
        } else {
            anyhow::ensure!(
                bytes.len() >= PARAMS_HEADER_LEN,
                "param blob {}: {} bytes — too short for a header and not a \
                 legacy raw blob of {expected} bytes (truncated?)",
                path.display(),
                bytes.len()
            );
            anyhow::ensure!(
                &bytes[..8] == PARAMS_MAGIC,
                "param blob {}: bad magic (not a param store file, or a \
                 corrupt/truncated legacy blob of the wrong size)",
                path.display()
            );
            let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
            anyhow::ensure!(
                version == PARAMS_VERSION,
                "param blob {}: format version {version}, this build reads {PARAMS_VERSION}",
                path.display()
            );
            let payload_len = u64::from_le_bytes(bytes[12..20].try_into().unwrap()) as usize;
            let stored_crc = u32::from_le_bytes(bytes[20..24].try_into().unwrap());
            let payload = &bytes[PARAMS_HEADER_LEN..];
            anyhow::ensure!(
                payload.len() == payload_len,
                "param blob {}: header says {payload_len} payload bytes, file has {} (truncated?)",
                path.display(),
                payload.len()
            );
            anyhow::ensure!(
                crc32(payload) == stored_crc,
                "param blob {}: CRC mismatch — file is corrupt (bit flip or torn write)",
                path.display()
            );
            payload
        };
        anyhow::ensure!(
            payload.len() == expected,
            "param blob {}: {} payload bytes, spec {} expects {expected}",
            path.display(),
            payload.len(),
            spec.name
        );
        let mut store = Self::zeros(spec);
        // Bulk chunked conversion: one pass of 4-byte chunks per tensor
        // (auto-vectorizes) instead of a per-element indexed byte loop.
        let mut off = 0usize;
        for t in &mut store.tensors {
            let n_bytes = t.len() * 4;
            let src = &payload[off..off + n_bytes];
            for (x, chunk) in t.iter_mut().zip(src.chunks_exact(4)) {
                *x = f32::from_le_bytes(chunk.try_into().unwrap());
            }
            off += n_bytes;
        }
        Ok(store)
    }

    /// Save the current state in the headered layout (see
    /// [`ParamStore::load_bin`]), written crash-safely: temp file → fsync →
    /// atomic rename, so a kill mid-save leaves the previous file intact.
    pub fn save_bin(&self, path: impl AsRef<Path>) -> Result<()> {
        let total_bytes: usize = self.tensors.iter().map(|t| t.len() * 4).sum();
        let mut buf: Vec<u8> = Vec::with_capacity(PARAMS_HEADER_LEN + total_bytes);
        buf.extend_from_slice(PARAMS_MAGIC);
        buf.extend_from_slice(&PARAMS_VERSION.to_le_bytes());
        buf.extend_from_slice(&(total_bytes as u64).to_le_bytes());
        buf.extend_from_slice(&[0u8; 4]); // CRC placeholder
        for t in &self.tensors {
            for x in t {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        debug_assert_eq!(buf.len(), PARAMS_HEADER_LEN + total_bytes);
        let crc = crc32(&buf[PARAMS_HEADER_LEN..]);
        buf[20..24].copy_from_slice(&crc.to_le_bytes());
        atomic_write(path, &buf)
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn get(&self, name: &str) -> Result<&[f32]> {
        let &i = self
            .index
            .get(name)
            .ok_or_else(|| anyhow!("model {}: no tensor '{name}'", self.model))?;
        Ok(&self.tensors[i])
    }

    pub fn set(&mut self, name: &str, values: &[f32]) -> Result<()> {
        let &i = self
            .index
            .get(name)
            .ok_or_else(|| anyhow!("model {}: no tensor '{name}'", self.model))?;
        anyhow::ensure!(
            self.tensors[i].len() == values.len(),
            "tensor '{name}': size {} != {}",
            values.len(),
            self.tensors[i].len()
        );
        self.tensors[i].copy_from_slice(values);
        self.version += 1;
        Ok(())
    }

    /// Reset the Adam slots (m.*, v.*, adam_t) to zero — used when reusing
    /// a network for a fresh training run.
    pub fn reset_adam(&mut self) {
        for (i, n) in self.names.iter().enumerate() {
            if n.starts_with("m.") || n.starts_with("v.") || n == "adam_t" {
                self.tensors[i].fill(0.0);
            }
        }
        self.version += 1;
    }

    /// Re-randomize base parameters with a seeded generator (fresh init for
    /// per-seed experiment repetitions; matches the emitter's Glorot scheme
    /// in distribution, not bit-for-bit).
    pub fn reinit(&mut self, spec: &ModelSpec, seed: u64) {
        use crate::util::Pcg32;
        self.version += 1;
        let mut rng = Pcg32::seeded(seed);
        for (i, p) in spec.params.iter().enumerate() {
            if p.name.starts_with("m.") || p.name.starts_with("v.") || p.name == "adam_t" {
                self.tensors[i].fill(0.0);
                continue;
            }
            if p.shape.len() == 1 {
                self.tensors[i].fill(0.0);
            } else {
                let (fi, fo) = (p.shape[0] as f32, p.shape[1] as f32);
                let mut scale = (2.0 / (fi + fo)).sqrt();
                if p.name == "w_pi" || p.name == "w_v" {
                    scale *= 0.1;
                }
                for x in self.tensors[i].iter_mut() {
                    *x = rng.normal() * scale;
                }
            }
        }
    }

    /// L2 norm of the base (non-Adam) parameters — a cheap training probe.
    pub fn param_norm(&self) -> f64 {
        let mut acc = 0.0f64;
        for (n, t) in self.names.iter().zip(&self.tensors) {
            if n.starts_with("m.") || n.starts_with("v.") || n == "adam_t" {
                continue;
            }
            for &x in t {
                acc += (x as f64) * (x as f64);
            }
        }
        acc.sqrt()
    }
}

/// Split three distinct indices of a slice into simultaneous `&mut`
/// references (sort, split twice, map back to the requested order).
/// `<[T]>::get_disjoint_mut` would do the same but was only stabilized in
/// Rust 1.86; this keeps the crate buildable on older toolchains.
fn disjoint3_mut<T>(xs: &mut [T], i: usize, j: usize, k: usize) -> (&mut T, &mut T, &mut T) {
    assert!(i != j && j != k && i != k, "indices must be distinct");
    let mut ord = [i, j, k];
    ord.sort_unstable();
    let (lo, rest) = xs.split_at_mut(ord[1]);
    let (mid, hi) = rest.split_at_mut(ord[2] - ord[1]);
    let mut refs = [Some(&mut lo[ord[0]]), Some(&mut mid[0]), Some(&mut hi[0])];
    let pos = |want: usize| ord.iter().position(|&o| o == want).unwrap();
    let (pi, pj, pk) = (pos(i), pos(j), pos(k));
    let a = refs[pi].take().unwrap();
    let b = refs[pj].take().unwrap();
    let c = refs[pk].take().unwrap();
    (a, b, c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{DType, TensorSpec};

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "tiny".into(),
            params: vec![
                TensorSpec { name: "w".into(), dtype: DType::F32, shape: vec![2, 3] },
                TensorSpec { name: "b".into(), dtype: DType::F32, shape: vec![3] },
                TensorSpec { name: "m.w".into(), dtype: DType::F32, shape: vec![2, 3] },
                TensorSpec { name: "adam_t".into(), dtype: DType::F32, shape: vec![1] },
            ],
        }
    }

    #[test]
    fn zeros_get_set() {
        let mut st = ParamStore::zeros(&spec());
        assert_eq!(st.get("w").unwrap().len(), 6);
        st.set("b", &[1.0, 2.0, 3.0]).unwrap();
        assert_eq!(st.get("b").unwrap(), &[1.0, 2.0, 3.0]);
        assert!(st.set("b", &[1.0]).is_err());
        assert!(st.get("nope").is_err());
    }

    #[test]
    fn bin_roundtrip() {
        let dir = std::env::temp_dir().join("ials_nn_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.params.bin");
        let mut st = ParamStore::zeros(&spec());
        st.set("w", &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        st.set("adam_t", &[7.0]).unwrap();
        st.save_bin(&path).unwrap();
        let st2 = ParamStore::load_bin(&spec(), &path).unwrap();
        assert_eq!(st2.get("w").unwrap(), st.get("w").unwrap());
        assert_eq!(st2.get("adam_t").unwrap(), &[7.0]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn wrong_blob_size_rejected() {
        let dir = std::env::temp_dir().join("ials_nn_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, [0u8; 12]).unwrap();
        assert!(ParamStore::load_bin(&spec(), &path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn legacy_raw_blob_still_loads() {
        // The AOT emitter writes headerless spec-ordered f32s; a file of
        // exactly the spec's byte size must keep loading.
        let dir = std::env::temp_dir().join("ials_nn_legacy");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("legacy.bin");
        let total = spec().total_numel();
        let mut raw = Vec::with_capacity(total * 4);
        for i in 0..total {
            raw.extend_from_slice(&(i as f32).to_le_bytes());
        }
        std::fs::write(&path, &raw).unwrap();
        let st = ParamStore::load_bin(&spec(), &path).unwrap();
        assert_eq!(st.get("w").unwrap(), &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(st.get("adam_t").unwrap(), &[15.0]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn zero_length_blob_rejected_with_context() {
        let dir = std::env::temp_dir().join("ials_nn_zero");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("empty.bin");
        std::fs::write(&path, []).unwrap();
        let err = ParamStore::load_bin(&spec(), &path).unwrap_err();
        assert!(format!("{err:#}").contains("empty file"), "got: {err:#}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn truncated_blob_rejected_with_context() {
        let dir = std::env::temp_dir().join("ials_nn_trunc");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.params.bin");
        let st = ParamStore::zeros(&spec());
        st.save_bin(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Cut mid-payload: header intact, payload short.
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let err = ParamStore::load_bin(&spec(), &path).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "got: {err:#}");
        // Cut mid-header.
        std::fs::write(&path, &bytes[..10]).unwrap();
        let err = ParamStore::load_bin(&spec(), &path).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "got: {err:#}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn bit_flipped_blob_rejected_with_context() {
        let dir = std::env::temp_dir().join("ials_nn_flip");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.params.bin");
        let mut st = ParamStore::zeros(&spec());
        st.set("w", &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        st.save_bin(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let flip_at = PARAMS_HEADER_LEN + 3;
        bytes[flip_at] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = ParamStore::load_bin(&spec(), &path).unwrap_err();
        assert!(format!("{err:#}").contains("CRC mismatch"), "got: {err:#}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn save_bin_leaves_no_temp_file() {
        let dir = std::env::temp_dir().join("ials_nn_atomic");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("deep").join("t.params.bin");
        ParamStore::zeros(&spec()).save_bin(&path).unwrap();
        let entries: Vec<_> = std::fs::read_dir(path.parent().unwrap())
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        assert_eq!(entries, vec!["t.params.bin".to_string()]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn reset_adam_clears_only_adam() {
        let mut st = ParamStore::zeros(&spec());
        st.set("w", &[1.0; 6]).unwrap();
        st.set("m.w", &[2.0; 6]).unwrap();
        st.set("adam_t", &[3.0]).unwrap();
        st.reset_adam();
        assert_eq!(st.get("w").unwrap(), &[1.0; 6]);
        assert_eq!(st.get("m.w").unwrap(), &[0.0; 6]);
        assert_eq!(st.get("adam_t").unwrap(), &[0.0]);
    }

    #[test]
    fn adam_slots_mut_yields_disjoint_triple() {
        let spec = ModelSpec {
            name: "t".into(),
            params: vec![
                TensorSpec { name: "w".into(), dtype: DType::F32, shape: vec![2] },
                TensorSpec { name: "m.w".into(), dtype: DType::F32, shape: vec![2] },
                TensorSpec { name: "v.w".into(), dtype: DType::F32, shape: vec![2] },
                TensorSpec { name: "adam_t".into(), dtype: DType::F32, shape: vec![1] },
            ],
        };
        let mut st = ParamStore::zeros(&spec);
        {
            let (p, m, v) = st.adam_slots_mut("w").unwrap();
            p[0] = 1.0;
            m[1] = 2.0;
            v[0] = 3.0;
        }
        assert_eq!(st.get("w").unwrap(), &[1.0, 0.0]);
        assert_eq!(st.get("m.w").unwrap(), &[0.0, 2.0]);
        assert_eq!(st.get("v.w").unwrap(), &[3.0, 0.0]);
        assert!(st.adam_slots_mut("adam_t").is_err(), "no m./v. slots for adam_t");
    }

    #[test]
    fn glorot_is_seeded_and_nonzero() {
        let a = ParamStore::glorot(&spec(), 9);
        let b = ParamStore::glorot(&spec(), 9);
        let c = ParamStore::glorot(&spec(), 10);
        assert_eq!(a.get("w").unwrap(), b.get("w").unwrap());
        assert_ne!(a.get("w").unwrap(), c.get("w").unwrap());
        assert!(a.get("w").unwrap().iter().any(|&x| x != 0.0));
        assert_eq!(a.get("m.w").unwrap(), &[0.0; 6]);
    }

    #[test]
    fn reinit_randomizes_weights_only() {
        let mut st = ParamStore::zeros(&spec());
        st.set("m.w", &[5.0; 6]).unwrap();
        st.reinit(&spec(), 42);
        assert!(st.get("w").unwrap().iter().any(|&x| x != 0.0));
        assert_eq!(st.get("m.w").unwrap(), &[0.0; 6]);
        assert!(st.param_norm() > 0.0);
        // deterministic
        let mut st2 = ParamStore::zeros(&spec());
        st2.reinit(&spec(), 42);
        assert_eq!(st.get("w").unwrap(), st2.get("w").unwrap());
    }
}
