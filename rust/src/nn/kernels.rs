//! Native CPU NN kernels: the hand-rolled math the native execution
//! backend (`runtime::native`) runs instead of compiled XLA artifacts.
//!
//! Everything operates on plain row-major `&[f32]` slices so the kernels
//! bind directly to [`super::ParamStore`] tensors and caller scratch — no
//! tensor type, no allocation. The GEMM uses the classic i-k-j loop order
//! (row-major panels: the inner loop streams one weight row against one
//! output row) with an 8-wide unrolled AXPY/dot so the compiler keeps the
//! accumulators in vector registers. At the model sizes in this repo
//! (hidden 64, batch ≤ 1024) every panel fits in L1/L2, which is exactly
//! the regime where this beats a runtime round-trip of literal packing and
//! buffer copies (see PERF.md §Execution backends).
//!
//! Correctness is pinned by scalar-reference parity tests here and in
//! `rust/tests/native_parity.rs` (tolerance 1e-5, mirroring the Python
//! kernel-vs-ref suite).

#![allow(clippy::too_many_arguments)]

/// Fused activation applied by [`linear_into`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    None,
    Tanh,
    Sigmoid,
}

/// Adam hyperparameters (must match `python/compile/model.py`).
pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;

#[inline(always)]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// `y += a * x` with an 8-lane unrolled body (auto-vectorizes).
#[inline(always)]
pub fn axpy(y: &mut [f32], x: &[f32], a: f32) {
    debug_assert_eq!(y.len(), x.len());
    let n8 = y.len() & !7;
    for (y8, x8) in y[..n8].chunks_exact_mut(8).zip(x[..n8].chunks_exact(8)) {
        for (yy, &xx) in y8.iter_mut().zip(x8) {
            *yy += a * xx;
        }
    }
    for (yy, &xx) in y[n8..].iter_mut().zip(&x[n8..]) {
        *yy += a * xx;
    }
}

/// `y += x` elementwise, 8-lane unrolled — the ordered gradient-slice
/// reduction primitive of the data-parallel trainers (`runtime::native`
/// reduces per-slice gradient scratch sequentially in slice order, never
/// with atomics, so results are independent of the worker count).
#[inline(always)]
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let n8 = y.len() & !7;
    for (y8, x8) in y[..n8].chunks_exact_mut(8).zip(x[..n8].chunks_exact(8)) {
        for (yy, &xx) in y8.iter_mut().zip(x8) {
            *yy += xx;
        }
    }
    for (yy, &xx) in y[n8..].iter_mut().zip(&x[n8..]) {
        *yy += xx;
    }
}

/// Dot product with 8 independent accumulators (breaks the FP dependency
/// chain so the loop vectorizes).
#[inline(always)]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n8 = a.len() & !7;
    let mut lanes = [0.0f32; 8];
    for (a8, b8) in a[..n8].chunks_exact(8).zip(b[..n8].chunks_exact(8)) {
        for ((l, &x), &y) in lanes.iter_mut().zip(a8).zip(b8) {
            *l += x * y;
        }
    }
    let mut s: f32 = lanes.iter().sum();
    for (&x, &y) in a[n8..].iter().zip(&b[n8..]) {
        s += x * y;
    }
    s
}

/// Apply `act` elementwise in place.
pub fn apply_act(xs: &mut [f32], act: Act) {
    match act {
        Act::None => {}
        Act::Tanh => {
            for x in xs.iter_mut() {
                *x = x.tanh();
            }
        }
        Act::Sigmoid => {
            for x in xs.iter_mut() {
                *x = sigmoid(*x);
            }
        }
    }
}

/// `out[M,N] = act(x[M,K] @ w[K,N] + b[N])`.
///
/// i-k-j order: each output row is initialized from the bias, then
/// accumulated one weight row at a time ([`axpy`], 8-wide). Zero input
/// activations (sparse bitmap observations) skip their weight row entirely.
pub fn linear_into(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    act: Act,
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for (xrow, row) in x.chunks_exact(k).zip(out.chunks_exact_mut(n)) {
        match bias {
            Some(b) => row.copy_from_slice(b),
            None => row.fill(0.0),
        }
        for (&a, wrow) in xrow.iter().zip(w.chunks_exact(n)) {
            if a != 0.0 {
                axpy(row, wrow, a);
            }
        }
        apply_act(row, act);
    }
}

/// `c[K,N] += a[M,K]^T @ g[M,N]` — the weight-gradient GEMM.
pub fn matmul_at_b_acc(a: &[f32], g: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    for (arow, grow) in a.chunks_exact(k).zip(g.chunks_exact(n)) {
        for (&av, crow) in arow.iter().zip(c.chunks_exact_mut(n)) {
            if av != 0.0 {
                axpy(crow, grow, av);
            }
        }
    }
}

/// `out[M,K] = g[M,N] @ w[K,N]^T` — backprop through a linear layer
/// (`w` stays in its row-major forward layout; each output element is a
/// row-row dot product).
pub fn matmul_bt_into(g: &[f32], w: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * k);
    for (grow, orow) in g.chunks_exact(n).zip(out.chunks_exact_mut(k)) {
        for (o, wrow) in orow.iter_mut().zip(w.chunks_exact(n)) {
            *o = dot(grow, wrow);
        }
    }
}

/// `out[M,K] += g[M,N] @ w[K,N]^T` (accumulating variant).
pub fn matmul_bt_acc(g: &[f32], w: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * k);
    for (grow, orow) in g.chunks_exact(n).zip(out.chunks_exact_mut(k)) {
        for (o, wrow) in orow.iter_mut().zip(w.chunks_exact(n)) {
            *o += dot(grow, wrow);
        }
    }
}

/// `out[N] += column sums of g[M,N]` — bias gradients.
pub fn colsum_acc(g: &[f32], out: &mut [f32], n: usize) {
    debug_assert_eq!(g.len() % n, 0);
    debug_assert_eq!(out.len(), n);
    for grow in g.chunks_exact(n) {
        for (o, &v) in out.iter_mut().zip(grow) {
            *o += v;
        }
    }
}

/// Numerically-stable `out = log_softmax(logits)` for one row.
pub fn log_softmax_row(logits: &[f32], out: &mut [f32]) {
    debug_assert_eq!(logits.len(), out.len());
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for &l in logits {
        sum += (l - max).exp();
    }
    let lse = sum.ln() + max;
    for (o, &l) in out.iter_mut().zip(logits) {
        *o = l - lse;
    }
}

/// One element of the numerically-stable binary cross-entropy with logits:
/// `max(l, 0) - l*y + ln(1 + e^{-|l|})` (matches `bce_with_logits` in
/// `python/compile/model.py`). Its gradient w.r.t. `l` is `sigmoid(l) - y`.
#[inline(always)]
pub fn bce_with_logits_elem(l: f32, y: f32) -> f32 {
    l.max(0.0) - l * y + (-l.abs()).exp().ln_1p()
}

/// One GRU step with fused gate weights (layout `z | r | n`, matching
/// `gru_cell_ref` in `python/compile/kernels/ref.py`).
///
/// `x` is `[B,D]`, `h` is `[B,H]`, `w_x` is `[D,3H]`, `w_h` is `[H,3H]`,
/// `b` is `[3H]`. Writes `h'` into `h_new` (must not alias `h`); `gx`/`gh`
/// are caller scratch `[B,3H]`.
pub fn gru_cell_into(
    x: &[f32],
    h: &[f32],
    w_x: &[f32],
    w_h: &[f32],
    b: &[f32],
    h_new: &mut [f32],
    gx: &mut [f32],
    gh: &mut [f32],
    bsz: usize,
    d: usize,
    hid: usize,
) {
    debug_assert_eq!(h.len(), bsz * hid);
    debug_assert_eq!(h_new.len(), bsz * hid);
    linear_into(x, w_x, Some(b), gx, bsz, d, 3 * hid, Act::None);
    linear_into(h, w_h, None, gh, bsz, hid, 3 * hid, Act::None);
    for bi in 0..bsz {
        let gxr = &gx[bi * 3 * hid..(bi + 1) * 3 * hid];
        let ghr = &gh[bi * 3 * hid..(bi + 1) * 3 * hid];
        let hr = &h[bi * hid..(bi + 1) * hid];
        let hn = &mut h_new[bi * hid..(bi + 1) * hid];
        for j in 0..hid {
            let z = sigmoid(gxr[j] + ghr[j]);
            let r = sigmoid(gxr[hid + j] + ghr[hid + j]);
            let n = (gxr[2 * hid + j] + r * ghr[2 * hid + j]).tanh();
            hn[j] = (1.0 - z) * n + z * hr[j];
        }
    }
}

/// Global L2 norm over a set of gradient tensors (with the same `1e-12`
/// epsilon as `clip_global_norm` in `python/compile/model.py`).
pub fn global_norm(grads: &[&[f32]]) -> f32 {
    let mut acc = 0.0f64;
    for g in grads {
        for &x in *g {
            acc += (x as f64) * (x as f64);
        }
    }
    ((acc + 1e-12) as f32).sqrt()
}

/// One Adam step for a single tensor. `bc1`/`bc2` are the bias corrections
/// `1 - beta^t` for the *incremented* step counter.
pub fn adam_tensor(
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    lr: f32,
    bc1: f32,
    bc2: f32,
) {
    debug_assert_eq!(p.len(), g.len());
    debug_assert_eq!(m.len(), g.len());
    debug_assert_eq!(v.len(), g.len());
    for (((pp, mm), vv), &gg) in p.iter_mut().zip(m.iter_mut()).zip(v.iter_mut()).zip(g) {
        *mm = ADAM_B1 * *mm + (1.0 - ADAM_B1) * gg;
        *vv = ADAM_B2 * *vv + (1.0 - ADAM_B2) * gg * gg;
        let mhat = *mm / bc1;
        let vhat = *vv / bc2;
        *pp -= lr * mhat / (vhat.sqrt() + ADAM_EPS);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn randv(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect()
    }

    /// Naive scalar GEMM oracle.
    fn linear_ref(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = b[j];
                for kk in 0..k {
                    acc += x[i * k + kk] * w[kk * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    #[test]
    fn linear_matches_scalar_reference() {
        let mut rng = Pcg32::seeded(1);
        for &(m, k, n) in &[(1usize, 7usize, 5usize), (4, 42, 64), (16, 64, 64), (3, 9, 1)] {
            let x = randv(&mut rng, m * k);
            let w = randv(&mut rng, k * n);
            let b = randv(&mut rng, n);
            let want = linear_ref(&x, &w, &b, m, k, n);
            let mut got = vec![0.0f32; m * n];
            linear_into(&x, &w, Some(&b), &mut got, m, k, n, Act::None);
            for (g, w_) in got.iter().zip(&want) {
                assert!((g - w_).abs() <= 1e-5, "{g} vs {w_}");
            }
        }
    }

    #[test]
    fn linear_activations_and_sparse_rows() {
        let mut rng = Pcg32::seeded(2);
        let (m, k, n) = (5usize, 12usize, 9usize);
        let mut x = randv(&mut rng, m * k);
        // Inject zeros to exercise the sparse skip path.
        for v in x.iter_mut().step_by(3) {
            *v = 0.0;
        }
        let w = randv(&mut rng, k * n);
        let b = randv(&mut rng, n);
        let lin = linear_ref(&x, &w, &b, m, k, n);
        let mut got = vec![0.0f32; m * n];
        linear_into(&x, &w, Some(&b), &mut got, m, k, n, Act::Tanh);
        for (g, l) in got.iter().zip(&lin) {
            assert!((g - l.tanh()).abs() <= 1e-5);
        }
        linear_into(&x, &w, Some(&b), &mut got, m, k, n, Act::Sigmoid);
        for (g, l) in got.iter().zip(&lin) {
            assert!((g - 1.0 / (1.0 + (-l).exp())).abs() <= 1e-5);
        }
    }

    #[test]
    fn transposed_matmuls_match_reference() {
        let mut rng = Pcg32::seeded(3);
        let (m, k, n) = (6usize, 11usize, 13usize);
        let a = randv(&mut rng, m * k);
        let g = randv(&mut rng, m * n);
        let w = randv(&mut rng, k * n);

        // c[K,N] = a^T g
        let mut c = vec![0.0f32; k * n];
        matmul_at_b_acc(&a, &g, &mut c, m, k, n);
        for kk in 0..k {
            for j in 0..n {
                let mut want = 0.0f32;
                for i in 0..m {
                    want += a[i * k + kk] * g[i * n + j];
                }
                assert!((c[kk * n + j] - want).abs() <= 1e-5);
            }
        }

        // out[M,K] = g w^T
        let mut out = vec![0.0f32; m * k];
        matmul_bt_into(&g, &w, &mut out, m, n, k);
        let mut out2 = out.clone();
        matmul_bt_acc(&g, &w, &mut out2, m, n, k);
        for i in 0..m {
            for kk in 0..k {
                let mut want = 0.0f32;
                for j in 0..n {
                    want += g[i * n + j] * w[kk * n + j];
                }
                assert!((out[i * k + kk] - want).abs() <= 1e-5);
                assert!((out2[i * k + kk] - 2.0 * want).abs() <= 2e-5);
            }
        }
    }

    #[test]
    fn add_assign_matches_elementwise() {
        let mut y: Vec<f32> = (0..19).map(|i| i as f32).collect();
        let x: Vec<f32> = (0..19).map(|i| 0.5 * i as f32).collect();
        add_assign(&mut y, &x);
        for (i, &v) in y.iter().enumerate() {
            assert_eq!(v, 1.5 * i as f32);
        }
    }

    #[test]
    fn colsum_and_dot() {
        let g = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut c = [0.0f32; 3];
        colsum_acc(&g, &mut c, 3);
        assert_eq!(c, [5.0, 7.0, 9.0]);
        let a: Vec<f32> = (0..19).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..19).map(|i| 1.0 - i as f32 * 0.1).collect();
        let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - want).abs() <= 1e-4);
    }

    #[test]
    fn log_softmax_is_normalized() {
        let logits = [0.3f32, -1.2, 2.0, 0.0];
        let mut lp = [0.0f32; 4];
        log_softmax_row(&logits, &mut lp);
        let sum: f32 = lp.iter().map(|l| l.exp()).sum();
        assert!((sum - 1.0).abs() < 1e-6);
        // Shift invariance.
        let shifted: Vec<f32> = logits.iter().map(|l| l + 100.0).collect();
        let mut lp2 = [0.0f32; 4];
        log_softmax_row(&shifted, &mut lp2);
        for (a, b) in lp.iter().zip(&lp2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn gru_cell_matches_scalar_reference() {
        let mut rng = Pcg32::seeded(4);
        let (bsz, d, hid) = (3usize, 5usize, 4usize);
        let x = randv(&mut rng, bsz * d);
        let h = randv(&mut rng, bsz * hid);
        let w_x = randv(&mut rng, d * 3 * hid);
        let w_h = randv(&mut rng, hid * 3 * hid);
        let b = randv(&mut rng, 3 * hid);
        let mut h_new = vec![0.0f32; bsz * hid];
        let mut gx = vec![0.0f32; bsz * 3 * hid];
        let mut gh = vec![0.0f32; bsz * 3 * hid];
        gru_cell_into(&x, &h, &w_x, &w_h, &b, &mut h_new, &mut gx, &mut gh, bsz, d, hid);
        for bi in 0..bsz {
            for j in 0..hid {
                let gate = |col: usize| -> f32 {
                    let mut acc = b[col];
                    for kk in 0..d {
                        acc += x[bi * d + kk] * w_x[kk * 3 * hid + col];
                    }
                    acc
                };
                let gate_h = |col: usize| -> f32 {
                    let mut acc = 0.0f32;
                    for kk in 0..hid {
                        acc += h[bi * hid + kk] * w_h[kk * 3 * hid + col];
                    }
                    acc
                };
                let z = 1.0 / (1.0 + (-(gate(j) + gate_h(j))).exp());
                let r = 1.0 / (1.0 + (-(gate(hid + j) + gate_h(hid + j))).exp());
                let n = (gate(2 * hid + j) + r * gate_h(2 * hid + j)).tanh();
                let want = (1.0 - z) * n + z * h[bi * hid + j];
                let got = h_new[bi * hid + j];
                assert!((got - want).abs() <= 1e-5, "({bi},{j}): {got} vs {want}");
            }
        }
    }

    #[test]
    fn bce_elem_matches_naive_and_is_stable() {
        for &(l, y) in &[(0.0f32, 0.0f32), (2.5, 1.0), (-3.0, 0.0), (40.0, 0.0), (-40.0, 1.0)] {
            let p = sigmoid(l).clamp(1e-7, 1.0 - 1e-7);
            let naive = -(y * p.ln() + (1.0 - y) * (1.0 - p).ln());
            let stable = bce_with_logits_elem(l, y);
            assert!(stable.is_finite());
            assert!((stable - naive).abs() < 1e-4, "l={l} y={y}: {stable} vs {naive}");
        }
    }

    #[test]
    fn adam_first_step_is_lr_times_sign() {
        // With zero m/v, one Adam step moves each weight by ~lr * sign(g).
        let mut p = [1.0f32, -1.0];
        let mut m = [0.0f32; 2];
        let mut v = [0.0f32; 2];
        let g = [0.5f32, -0.25];
        let t = 1.0f32;
        let bc1 = 1.0 - ADAM_B1.powf(t);
        let bc2 = 1.0 - ADAM_B2.powf(t);
        adam_tensor(&mut p, &mut m, &mut v, &g, 0.01, bc1, bc2);
        assert!((p[0] - (1.0 - 0.01)).abs() < 1e-4, "{}", p[0]);
        assert!((p[1] - (-1.0 + 0.01)).abs() < 1e-4, "{}", p[1]);
    }

    #[test]
    fn global_norm_matches_direct() {
        let a = [3.0f32, 0.0];
        let b = [4.0f32];
        assert!((global_norm(&[&a, &b]) - 5.0).abs() < 1e-5);
    }
}
