//! The `(d_t, u_t)` dataset of Algorithm 1: episode-structured so that
//! recurrent AIPs can be trained on contiguous windows (BPTT) and
//! evaluated on whole trajectories.

use crate::util::{Pcg32, StateReader, StateWriter};
use anyhow::Result;

/// Index range of one episode within the flat step storage.
#[derive(Debug, Clone, Copy)]
pub struct Episode {
    pub start: usize,
    pub steps: usize,
}

impl Episode {
    pub fn len(&self, _data: &InfluenceDataset) -> usize {
        self.steps
    }

    pub fn d_row<'a>(&self, data: &'a InfluenceDataset, t: usize) -> &'a [f32] {
        debug_assert!(t < self.steps);
        let d = data.dset_dim;
        let off = (self.start + t) * d;
        &data.dsets[off..off + d]
    }

    pub fn u_row<'a>(&self, data: &'a InfluenceDataset, t: usize) -> &'a [f32] {
        debug_assert!(t < self.steps);
        let u = data.u_dim;
        let off = (self.start + t) * u;
        &data.us[off..off + u]
    }
}

/// Flat, episode-indexed storage of d-set features and influence-source
/// realizations.
#[derive(Debug, Clone)]
pub struct InfluenceDataset {
    pub dset_dim: usize,
    pub u_dim: usize,
    dsets: Vec<f32>,
    us: Vec<f32>,
    pub episodes: Vec<Episode>,
    open: bool,
}

impl InfluenceDataset {
    pub fn new(dset_dim: usize, u_dim: usize) -> InfluenceDataset {
        InfluenceDataset {
            dset_dim,
            u_dim,
            dsets: Vec::new(),
            us: Vec::new(),
            episodes: Vec::new(),
            open: false,
        }
    }

    pub fn begin_episode(&mut self) {
        self.episodes.push(Episode { start: self.total_steps(), steps: 0 });
        self.open = true;
    }

    pub fn push(&mut self, d: &[f32], u: &[f32]) {
        assert!(self.open, "push before begin_episode");
        assert_eq!(d.len(), self.dset_dim);
        assert_eq!(u.len(), self.u_dim);
        self.dsets.extend_from_slice(d);
        self.us.extend_from_slice(u);
        self.episodes.last_mut().unwrap().steps += 1;
    }

    pub fn total_steps(&self) -> usize {
        self.dsets.len() / self.dset_dim.max(1)
    }

    /// Flat step access (for feedforward training).
    pub fn d_at(&self, step: usize) -> &[f32] {
        &self.dsets[step * self.dset_dim..(step + 1) * self.dset_dim]
    }

    pub fn u_at(&self, step: usize) -> &[f32] {
        &self.us[step * self.u_dim..(step + 1) * self.u_dim]
    }

    /// Mean of each influence source across the dataset.
    pub fn u_marginals(&self) -> Vec<f32> {
        let n = self.total_steps().max(1);
        let mut out = vec![0.0f32; self.u_dim];
        for s in 0..self.total_steps() {
            for (o, &x) in out.iter_mut().zip(self.u_at(s)) {
                *o += x;
            }
        }
        for o in &mut out {
            *o /= n as f32;
        }
        out
    }

    /// Append every episode of `other` (used to merge per-worker datasets
    /// from sharded collection in a deterministic worker order).
    pub fn extend_from(&mut self, other: &InfluenceDataset) {
        assert_eq!(self.dset_dim, other.dset_dim, "d-set dims must agree");
        assert_eq!(self.u_dim, other.u_dim, "influence dims must agree");
        for ep in &other.episodes {
            self.begin_episode();
            for t in 0..ep.steps {
                self.push(ep.d_row(other, t), ep.u_row(other, t));
            }
        }
        self.open = false;
    }

    /// Serialize the dataset exactly (f32 values byte for byte, episode
    /// structure included) — the distributed runtime ships the shared
    /// Algorithm-1 dataset to worker processes through this.
    pub fn write_state(&self, w: &mut StateWriter) {
        w.usize(self.dset_dim);
        w.usize(self.u_dim);
        w.f32s(&self.dsets);
        w.f32s(&self.us);
        w.usize(self.episodes.len());
        for ep in &self.episodes {
            w.usize(ep.start);
            w.usize(ep.steps);
        }
    }

    /// Inverse of [`InfluenceDataset::write_state`]. The episode index is
    /// re-validated against the step storage, so a corrupted-but-CRC-valid
    /// payload still cannot produce out-of-bounds row reads.
    pub fn read_state(r: &mut StateReader<'_>) -> Result<InfluenceDataset> {
        let dset_dim = r.usize()?;
        let u_dim = r.usize()?;
        let dsets = r.f32s()?;
        let us = r.f32s()?;
        let n_eps = r.usize()?;
        let steps = if dset_dim > 0 { dsets.len() / dset_dim } else { 0 };
        anyhow::ensure!(
            dsets.len() == steps * dset_dim && us.len() == steps * u_dim,
            "dataset storage is ragged: {} d-floats / {} u-floats for dims {dset_dim}/{u_dim}",
            dsets.len(),
            us.len()
        );
        let mut episodes = Vec::with_capacity(n_eps.min(steps + 1));
        let mut expect_start = 0usize;
        for i in 0..n_eps {
            let start = r.usize()?;
            let ep_steps = r.usize()?;
            anyhow::ensure!(
                start == expect_start && start + ep_steps <= steps,
                "episode {i} spans [{start}, {start}+{ep_steps}) of {steps} steps"
            );
            expect_start = start + ep_steps;
            episodes.push(Episode { start, steps: ep_steps });
        }
        anyhow::ensure!(
            expect_start == steps,
            "episodes cover {expect_start} of {steps} stored steps"
        );
        Ok(InfluenceDataset { dset_dim, u_dim, dsets, us, episodes, open: false })
    }

    /// Split episodes into (train, heldout) with the given train fraction.
    pub fn split(&self, train_frac: f64, rng: &mut Pcg32) -> (InfluenceDataset, InfluenceDataset) {
        let mut idx: Vec<usize> = (0..self.episodes.len()).collect();
        rng.shuffle(&mut idx);
        let n_train = ((idx.len() as f64) * train_frac).round() as usize;
        let mut train = InfluenceDataset::new(self.dset_dim, self.u_dim);
        let mut held = InfluenceDataset::new(self.dset_dim, self.u_dim);
        for (k, &ep_i) in idx.iter().enumerate() {
            let target = if k < n_train { &mut train } else { &mut held };
            let ep = self.episodes[ep_i];
            target.begin_episode();
            for t in 0..ep.steps {
                target.push(ep.d_row(self, t), ep.u_row(self, t));
            }
        }
        (train, held)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> InfluenceDataset {
        let mut d = InfluenceDataset::new(2, 1);
        for ep in 0..4 {
            d.begin_episode();
            for t in 0..10 {
                d.push(&[ep as f32, t as f32], &[(t % 2) as f32]);
            }
        }
        d
    }

    #[test]
    fn episode_indexing() {
        let d = sample();
        assert_eq!(d.total_steps(), 40);
        assert_eq!(d.episodes.len(), 4);
        let ep2 = d.episodes[2];
        assert_eq!(ep2.d_row(&d, 3), &[2.0, 3.0]);
        assert_eq!(ep2.u_row(&d, 3), &[1.0]);
    }

    #[test]
    fn marginals() {
        let d = sample();
        assert!((d.u_marginals()[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn split_preserves_everything() {
        let d = sample();
        let mut rng = Pcg32::seeded(1);
        let (tr, he) = d.split(0.75, &mut rng);
        assert_eq!(tr.episodes.len(), 3);
        assert_eq!(he.episodes.len(), 1);
        assert_eq!(tr.total_steps() + he.total_steps(), 40);
    }

    #[test]
    fn state_roundtrip_is_exact() {
        let d = sample();
        let mut w = StateWriter::new();
        d.write_state(&mut w);
        let bytes = w.into_bytes();
        let mut r = StateReader::new(&bytes);
        let back = InfluenceDataset::read_state(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(back.dset_dim, d.dset_dim);
        assert_eq!(back.u_dim, d.u_dim);
        assert_eq!(back.dsets, d.dsets);
        assert_eq!(back.us, d.us);
        assert_eq!(back.episodes.len(), d.episodes.len());
        for (a, b) in back.episodes.iter().zip(&d.episodes) {
            assert_eq!((a.start, a.steps), (b.start, b.steps));
        }
        // A payload whose episode index lies about the storage is rejected
        // even though it deserializes cleanly.
        let mut w = StateWriter::new();
        w.usize(2);
        w.usize(1);
        w.f32s(&[0.0; 4]); // 2 steps of d
        w.f32s(&[0.0; 2]); // 2 steps of u
        w.usize(1);
        w.usize(0);
        w.usize(5); // episode claims 5 steps, storage has 2
        let bytes = w.into_bytes();
        assert!(InfluenceDataset::read_state(&mut StateReader::new(&bytes)).is_err());
    }

    #[test]
    #[should_panic(expected = "begin_episode")]
    fn push_without_episode_panics() {
        let mut d = InfluenceDataset::new(1, 1);
        d.push(&[0.0], &[0.0]);
    }
}
