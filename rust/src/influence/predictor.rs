//! Neural influence predictors backed by the runtime's `*_fwd_*` /
//! `*_step_*` artifacts: an FNN (traffic / memoryless warehouse) or a GRU
//! with recurrent state per environment (warehouse). On the PJRT backend
//! the Pallas fused-GRU kernel runs inside the compiled artifact; on the
//! native backend `nn::kernels::gru_cell_into` plays the same role.

use super::{InfluencePredictor, ShardPredict};
use crate::nn::ParamStore;
use crate::runtime::native::{FnnView, GruView};
use crate::runtime::{DataArg, MultiStore, Runtime};
use crate::Result;
use anyhow::Context;
use std::rc::Rc;

/// Architecture, derived from the model's parameter names in the manifest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AipArch {
    Fnn,
    Gru { hidden: usize },
}

/// Seed mix for the untrained-IALS fresh init — shared by
/// [`NeuralAip::untrained`] and the multi-learner preparation path
/// (`coordinator::experiment::build_learner_predictor`), so the two can
/// never drift apart and break the condition's reproducibility.
pub const UNTRAINED_INIT_MIX: u64 = 0xBADC0FFEE;

pub struct NeuralAip {
    rt: Rc<Runtime>,
    pub store: ParamStore,
    pub model: String,
    artifact: String,
    arch: AipArch,
    batch: usize,
    dset_dim: usize,
    u_dim: usize,
    /// Hidden width (FNN `b1` / GRU gate block) — sizes the per-shard
    /// scratch of the fused step path.
    hidden: usize,
    /// Recurrent state `[batch * hidden]` (GRU only).
    h: Vec<f32>,
    /// Scratch for the updated recurrent state — the step artifact writes
    /// into this buffer, then it is swapped with `h` (no allocation on the
    /// predict path).
    h_next: Vec<f32>,
}

impl NeuralAip {
    /// Build from the manifest for a given model + batch width, loading the
    /// emitted initial parameters (call [`train::train_fnn`] /
    /// [`train::train_gru`] afterwards for the trained-IALS condition).
    pub fn new(rt: Rc<Runtime>, model: &str, batch: usize) -> Result<NeuralAip> {
        let store = rt.load_store(model)?;
        Self::with_store(rt, model, batch, store)
    }

    /// The untrained-IALS condition: a randomly re-initialized predictor.
    pub fn untrained(rt: Rc<Runtime>, model: &str, batch: usize, seed: u64) -> Result<NeuralAip> {
        let mut aip = Self::new(rt.clone(), model, batch)?;
        let spec = rt.manifest.model(model)?.clone();
        aip.store.reinit(&spec, seed ^ UNTRAINED_INIT_MIX);
        Ok(aip)
    }

    /// Learner-indexed predictor for multi-learner runs: takes learner
    /// `learner`'s (already seeded) store for `model` out of a
    /// [`MultiStore`] — the predictor owns it from here on, because its
    /// recurrent state (`h`/`h_next` for GRU architectures) is as
    /// per-learner as the parameters. K predictors built this way share
    /// the engine (one op cache, one pool) but nothing learner-specific.
    pub fn from_multi_store(
        rt: Rc<Runtime>,
        stores: &mut MultiStore,
        learner: usize,
        model: &str,
        batch: usize,
    ) -> Result<NeuralAip> {
        let store = stores.take(learner, model)?;
        Self::with_store(rt, model, batch, store)
    }

    pub fn with_store(
        rt: Rc<Runtime>,
        model: &str,
        batch: usize,
        store: ParamStore,
    ) -> Result<NeuralAip> {
        let spec = rt.manifest.model(model)?;
        let arch = if spec.params.iter().any(|p| p.name == "w_x") {
            let hidden = spec.param("w_h")?.shape[0];
            AipArch::Gru { hidden }
        } else {
            AipArch::Fnn
        };
        let artifact = match arch {
            AipArch::Fnn => format!("{model}_fwd_b{batch}"),
            AipArch::Gru { .. } => format!("{model}_step_b{batch}"),
        };
        let art = rt
            .manifest
            .artifact(&artifact)
            .with_context(|| format!("no artifact for model {model} at batch {batch}"))?;
        // Derive dims from the artifact's data bindings.
        let d_in = art
            .data_inputs()
            .find(|t| t.name == "d")
            .context("artifact missing d input")?;
        let dset_dim = *d_in.shape.last().unwrap();
        let probs = art
            .data_outputs()
            .find(|t| t.name == "probs")
            .context("artifact missing probs output")?;
        let u_dim = *probs.shape.last().unwrap();
        let hidden = match arch {
            AipArch::Gru { hidden } => hidden,
            AipArch::Fnn => spec.param("b1")?.shape[0],
        };
        let h = match arch {
            AipArch::Gru { hidden } => vec![0.0; batch * hidden],
            AipArch::Fnn => Vec::new(),
        };
        let h_next = h.clone();
        Ok(NeuralAip {
            rt,
            store,
            model: model.to_string(),
            artifact,
            arch,
            batch,
            dset_dim,
            u_dim,
            hidden,
            h,
            h_next,
        })
    }

    pub fn arch(&self) -> AipArch {
        self.arch
    }
}

impl InfluencePredictor for NeuralAip {
    fn num_sources(&self) -> usize {
        self.u_dim
    }

    fn dset_dim(&self) -> usize {
        self.dset_dim
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn reset_state(&mut self, env_idx: usize) {
        if let AipArch::Gru { hidden } = self.arch {
            self.h[env_idx * hidden..(env_idx + 1) * hidden].fill(0.0);
        }
    }

    fn reset_all(&mut self) {
        self.h.fill(0.0);
    }

    fn predict(&mut self, dsets: &[f32], probs: &mut [f32]) -> Result<()> {
        debug_assert_eq!(dsets.len(), self.batch * self.dset_dim);
        debug_assert_eq!(probs.len(), self.batch * self.u_dim);
        // Allocation-free forwards: outputs land straight in the caller's
        // `probs` (and the reusable `h_next`) via `Runtime::call_into`.
        match self.arch {
            AipArch::Fnn => {
                self.rt.call_into(
                    &self.artifact,
                    &mut self.store,
                    &[DataArg::F32(dsets)],
                    &mut [probs],
                )?;
            }
            AipArch::Gru { .. } => {
                self.rt.call_into(
                    &self.artifact,
                    &mut self.store,
                    &[DataArg::F32(&self.h), DataArg::F32(dsets)],
                    &mut [probs, &mut self.h_next],
                )?;
                std::mem::swap(&mut self.h, &mut self.h_next);
            }
        }
        Ok(())
    }

    // ---- Fused step path (native backend only) ----------------------------
    //
    // The forward math is row-independent, so each sim shard can run its
    // own band through a `Sync` view of this predictor's parameters —
    // bitwise identical to the batched `predict` above. The PJRT backend
    // owns thread-bound state and falls back to the sandwich.

    fn supports_shard_exec(&self) -> bool {
        self.rt.backend_kind() == "native"
    }

    fn begin_step(&mut self) -> Option<ShardPredict<'_>> {
        if self.rt.backend_kind() != "native" {
            return None;
        }
        let NeuralAip { store, h, h_next, arch, .. } = self;
        match arch {
            AipArch::Fnn => match FnnView::resolve(store) {
                Ok(view) => Some(ShardPredict::Fnn(view)),
                Err(_) => None,
            },
            AipArch::Gru { .. } => match GruView::resolve(store) {
                Ok(view) => Some(ShardPredict::Gru {
                    view,
                    h: h.as_slice(),
                    h_next: h_next.as_mut_slice(),
                }),
                Err(_) => None,
            },
        }
    }

    fn end_step(&mut self) {
        if let AipArch::Gru { .. } = self.arch {
            std::mem::swap(&mut self.h, &mut self.h_next);
        }
    }

    fn shard_scratch_rows(&self) -> (usize, usize) {
        match self.arch {
            AipArch::Fnn => (self.hidden, 0),
            AipArch::Gru { .. } => (3 * self.hidden, 3 * self.hidden),
        }
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        // Only the recurrent hidden state is step-mutable; weights are
        // rebuilt by the deterministic prep replay on resume. FNN
        // predictors are stateless and write nothing.
        for &x in &self.h {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn load_state(&mut self, bytes: &[u8]) -> Result<()> {
        anyhow::ensure!(
            bytes.len() == self.h.len() * 4,
            "predictor snapshot has {} bytes, expected {} ({} hidden f32s)",
            bytes.len(),
            self.h.len() * 4,
            self.h.len()
        );
        for (x, chunk) in self.h.iter_mut().zip(bytes.chunks_exact(4)) {
            *x = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        Ok(())
    }
}
