//! Offline AIP training (Eq. 3: expected cross-entropy on `(d_t, u_t)`
//! pairs) and trajectory-level CE evaluation.
//!
//! Training drives the `*_update` artifacts through the runtime backend
//! (XLA on PJRT, or the native CPU kernels): the gradient / Adam math runs
//! inside the backend; this module only assembles minibatches, reusing one
//! set of gather buffers and a scalar loss output across every call. On
//! the native backend with `[runtime] nn_workers > 1` each update call is
//! data-parallel inside the backend (per-slice gradients, ordered
//! reduction), so `train_fnn` / `train_gru` stay single-call-per-minibatch
//! here yet scale with cores — and produce bitwise-identical parameters
//! for every worker count at a fixed seed.

use super::{InfluenceDataset, InfluencePredictor};
use crate::nn::ParamStore;
use crate::runtime::{DataArg, Runtime};
use crate::util::Pcg32;
use crate::Result;

/// Train an FNN AIP. Returns the mean loss per epoch.
pub fn train_fnn(
    rt: &Runtime,
    store: &mut ParamStore,
    update_artifact: &str,
    data: &InfluenceDataset,
    epochs: usize,
    minibatch: usize,
    lr: f32,
    seed: u64,
) -> Result<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    let n = data.total_steps();
    anyhow::ensure!(n >= minibatch, "dataset ({n}) smaller than one minibatch ({minibatch})");
    let mut order: Vec<usize> = (0..n).collect();
    let lr_arr = [lr];
    let (dd, ud) = (data.dset_dim, data.u_dim);
    let mut d_buf = vec![0.0f32; minibatch * dd];
    let mut u_buf = vec![0.0f32; minibatch * ud];
    let mut loss_out = [0.0f32; 1];
    let mut epoch_losses = Vec::with_capacity(epochs);
    for _ in 0..epochs {
        rng.shuffle(&mut order);
        let mut total = 0.0f64;
        let mut batches = 0usize;
        for chunk in order.chunks_exact(minibatch) {
            for (row, &step) in chunk.iter().enumerate() {
                d_buf[row * dd..(row + 1) * dd].copy_from_slice(data.d_at(step));
                u_buf[row * ud..(row + 1) * ud].copy_from_slice(data.u_at(step));
            }
            rt.call_into(
                update_artifact,
                store,
                &[DataArg::F32(&lr_arr), DataArg::F32(&d_buf), DataArg::F32(&u_buf)],
                &mut [loss_out.as_mut_slice()],
            )?;
            total += loss_out[0] as f64;
            batches += 1;
        }
        epoch_losses.push((total / batches.max(1) as f64) as f32);
    }
    // A non-finite AIP loss poisons everything downstream (the IALS
    // trusts this predictor); fail fast with a structured error.
    crate::runtime::guard::check_losses_finite("fnn AIP training", &epoch_losses)?;
    Ok(epoch_losses)
}

/// Train a GRU AIP on random contiguous windows (BPTT length = the
/// artifact's compiled `T`). Returns the mean loss per epoch.
#[allow(clippy::too_many_arguments)]
pub fn train_gru(
    rt: &Runtime,
    store: &mut ParamStore,
    update_artifact: &str,
    data: &InfluenceDataset,
    epochs: usize,
    seq_b: usize,
    seq_t: usize,
    lr: f32,
    seed: u64,
) -> Result<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    let eligible: Vec<usize> = (0..data.episodes.len())
        .filter(|&i| data.episodes[i].steps >= seq_t)
        .collect();
    anyhow::ensure!(!eligible.is_empty(), "no episode long enough for BPTT window {seq_t}");
    let lr_arr = [lr];
    let (dd, ud) = (data.dset_dim, data.u_dim);
    let mut seqs = vec![0.0f32; seq_b * seq_t * dd];
    let mut targets = vec![0.0f32; seq_b * seq_t * ud];
    let mut loss_out = [0.0f32; 1];
    let iters_per_epoch = (data.total_steps() / (seq_b * seq_t)).max(1);
    let mut epoch_losses = Vec::with_capacity(epochs);
    for _ in 0..epochs {
        let mut total = 0.0f64;
        for _ in 0..iters_per_epoch {
            for b in 0..seq_b {
                let ep = data.episodes[*rng.choose_ref(&eligible)];
                let start = rng.below(ep.steps - seq_t + 1);
                for t in 0..seq_t {
                    let off_d = (b * seq_t + t) * dd;
                    let off_u = (b * seq_t + t) * ud;
                    seqs[off_d..off_d + dd].copy_from_slice(ep.d_row(data, start + t));
                    targets[off_u..off_u + ud].copy_from_slice(ep.u_row(data, start + t));
                }
            }
            rt.call_into(
                update_artifact,
                store,
                &[DataArg::F32(&lr_arr), DataArg::F32(&seqs), DataArg::F32(&targets)],
                &mut [loss_out.as_mut_slice()],
            )?;
            total += loss_out[0] as f64;
        }
        epoch_losses.push((total / iters_per_epoch as f64) as f32);
    }
    crate::runtime::guard::check_losses_finite("gru AIP training", &epoch_losses)?;
    Ok(epoch_losses)
}

/// Trajectory-level mean cross-entropy of any predictor on a dataset —
/// the number reported in the paper's bottom bar charts (Figs 3/5/10–12).
/// Episodes are processed in chunks of `predictor.batch()`, stepping the
/// (possibly recurrent) predictor through time.
pub fn evaluate_ce(
    predictor: &mut dyn InfluencePredictor,
    data: &InfluenceDataset,
) -> Result<f32> {
    let b = predictor.batch();
    let dd = data.dset_dim;
    let ud = predictor.num_sources();
    anyhow::ensure!(dd == predictor.dset_dim(), "d-set dim mismatch");
    anyhow::ensure!(ud == data.u_dim, "influence dim mismatch");
    let eps = 1e-7f32;
    let mut total = 0.0f64;
    let mut count = 0usize;
    let mut dsets = vec![0.0f32; b * dd];
    let mut probs = vec![0.0f32; b * ud];
    for chunk in data.episodes.chunks(b) {
        predictor.reset_all();
        let max_len = chunk.iter().map(|e| e.steps).max().unwrap_or(0);
        for t in 0..max_len {
            dsets.fill(0.0);
            for (row, ep) in chunk.iter().enumerate() {
                if t < ep.steps {
                    dsets[row * dd..(row + 1) * dd].copy_from_slice(ep.d_row(data, t));
                }
            }
            predictor.predict(&dsets, &mut probs)?;
            for (row, ep) in chunk.iter().enumerate() {
                if t < ep.steps {
                    let u = ep.u_row(data, t);
                    for (k, &y) in u.iter().enumerate() {
                        let p = probs[row * ud + k].clamp(eps, 1.0 - eps);
                        total -= (y * p.ln() + (1.0 - y) * (1.0 - p).ln()) as f64;
                        count += 1;
                    }
                }
            }
        }
    }
    Ok(if count == 0 { 0.0 } else { (total / count as f64) as f32 })
}

trait ChooseRef {
    fn choose_ref<'a, T>(&mut self, xs: &'a [T]) -> &'a T;
}

impl ChooseRef for Pcg32 {
    fn choose_ref<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::influence::FixedMarginalAip;

    fn dataset_with_marginal(p: f32, steps: usize) -> InfluenceDataset {
        let mut d = InfluenceDataset::new(2, 1);
        let mut rng = Pcg32::seeded(5);
        d.begin_episode();
        for _ in 0..steps {
            let u = if rng.bernoulli(p) { 1.0 } else { 0.0 };
            d.push(&[0.0, 1.0], &[u]);
        }
        d
    }

    #[test]
    fn ce_of_true_marginal_is_entropy() {
        let p = 0.3f64;
        let data = dataset_with_marginal(p as f32, 20000);
        let mut aip = FixedMarginalAip::constant(4, 2, 1, p as f32);
        let ce = evaluate_ce(&mut aip, &data).unwrap() as f64;
        let entropy = -(p * p.ln() + (1.0 - p) * (1.0 - p).ln());
        assert!((ce - entropy).abs() < 0.02, "ce={ce:.4} H={entropy:.4}");
    }

    #[test]
    fn ce_of_wrong_marginal_is_higher() {
        let data = dataset_with_marginal(0.1, 10000);
        let mut right = FixedMarginalAip::constant(4, 2, 1, 0.1);
        let mut wrong = FixedMarginalAip::constant(4, 2, 1, 0.5);
        let ce_r = evaluate_ce(&mut right, &data).unwrap();
        let ce_w = evaluate_ce(&mut wrong, &data).unwrap();
        assert!(ce_w > ce_r + 0.2, "mis-specified marginal must score worse: {ce_r} vs {ce_w}");
    }
}
