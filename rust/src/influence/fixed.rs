//! F-IALS predictor (Appendix E): influence sources modelled by a fixed
//! marginal `P(u)` independent of the ALSH — either a hand-set constant
//! (traffic: 0.1 / 0.5) or a marginal estimated from GS samples
//! (warehouse).

use super::{InfluenceDataset, InfluencePredictor, ShardPredict};
use crate::Result;

pub struct FixedMarginalAip {
    batch: usize,
    dset_dim: usize,
    /// Per-source marginal probability.
    p: Vec<f32>,
}

impl FixedMarginalAip {
    /// Same probability for every source (traffic F-IALS 0.1 / 0.5).
    pub fn constant(batch: usize, dset_dim: usize, num_sources: usize, p: f32) -> Self {
        assert!((0.0..=1.0).contains(&p));
        FixedMarginalAip { batch, dset_dim, p: vec![p; num_sources] }
    }

    /// Per-source marginal estimated from a dataset collected under π₀
    /// (warehouse F-IALS: P̂(u) from 10K GS samples).
    pub fn from_data(batch: usize, data: &InfluenceDataset) -> Self {
        let u = data.u_dim;
        let mut counts = vec![0.0f64; u];
        let mut n = 0usize;
        for ep in &data.episodes {
            let steps = ep.len(data);
            for t in 0..steps {
                let row = ep.u_row(data, t);
                for (c, &x) in counts.iter_mut().zip(row) {
                    *c += x as f64;
                }
            }
            n += steps;
        }
        let p: Vec<f32> =
            counts.iter().map(|&c| if n > 0 { (c / n as f64) as f32 } else { 0.0 }).collect();
        FixedMarginalAip { batch, dset_dim: data.dset_dim, p }
    }

    pub fn marginals(&self) -> &[f32] {
        &self.p
    }
}

impl InfluencePredictor for FixedMarginalAip {
    fn num_sources(&self) -> usize {
        self.p.len()
    }
    fn dset_dim(&self) -> usize {
        self.dset_dim
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn reset_state(&mut self, _env_idx: usize) {}
    fn reset_all(&mut self) {}
    fn predict(&mut self, _dsets: &[f32], probs: &mut [f32]) -> Result<()> {
        let u = self.p.len();
        debug_assert_eq!(probs.len(), self.batch * u);
        for b in 0..self.batch {
            probs[b * u..(b + 1) * u].copy_from_slice(&self.p);
        }
        Ok(())
    }

    // The marginals are d-set-independent, so any shard can broadcast them
    // to its own prob rows inside a fused step dispatch.
    fn supports_shard_exec(&self) -> bool {
        true
    }

    fn begin_step(&mut self) -> Option<ShardPredict<'_>> {
        Some(ShardPredict::Marginals(&self.p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_broadcasts() {
        let mut aip = FixedMarginalAip::constant(3, 5, 2, 0.1);
        let d = vec![0.0; 15];
        let mut probs = vec![0.0; 6];
        aip.predict(&d, &mut probs).unwrap();
        assert!(probs.iter().all(|&x| x == 0.1));
    }

    #[test]
    fn from_data_estimates_marginals() {
        let mut data = InfluenceDataset::new(3, 2);
        // Episode: u0 fires half the time, u1 never.
        data.begin_episode();
        for t in 0..100 {
            let d = [0.0f32; 3];
            let u = [if t % 2 == 0 { 1.0 } else { 0.0 }, 0.0];
            data.push(&d, &u);
        }
        let aip = FixedMarginalAip::from_data(4, &data);
        assert!((aip.marginals()[0] - 0.5).abs() < 1e-6);
        assert_eq!(aip.marginals()[1], 0.0);
    }
}
