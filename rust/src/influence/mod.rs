//! The influence layer (paper §4): approximate influence predictors (AIPs)
//! and their offline training/evaluation.
//!
//! An AIP estimates `Î_θ(u_t | d_t, history)` — the conditional probability
//! of each binary influence source given the d-set features — and is
//! sampled once per IALS step (Algorithm 2). Four implementations:
//!
//! | impl | paper condition |
//! |------|-----------------|
//! | [`NeuralAip`] (trained) | IALS |
//! | [`NeuralAip`] (random init via [`NeuralAip::untrained`]) | untrained-IALS |
//! | [`FixedMarginalAip`] | F-IALS (Appendix E) |
//! | [`ReplayPredictor`] (test/bench oracle) | — |

pub mod dataset;
pub mod fixed;
pub mod predictor;
pub mod train;

pub use dataset::InfluenceDataset;
pub use fixed::FixedMarginalAip;
pub use predictor::{AipArch, NeuralAip};
pub use train::{evaluate_ce, train_fnn, train_gru};

use crate::Result;

/// A batched influence predictor. `batch` is fixed at construction (it must
/// match the AOT-compiled artifact's leading dimension).
pub trait InfluencePredictor {
    /// Number of binary influence sources per environment.
    fn num_sources(&self) -> usize;
    /// d-set feature dimension (one timestep's slice).
    fn dset_dim(&self) -> usize;
    /// Batch width this predictor was built for.
    fn batch(&self) -> usize;
    /// Clear any recurrent state for environment row `i` (episode reset).
    fn reset_state(&mut self, env_idx: usize);
    /// Clear all recurrent state.
    fn reset_all(&mut self);
    /// Predict `P(u_t = 1)` for all envs: `dsets` is `[batch * dset_dim]`
    /// env-major, `probs` is `[batch * num_sources]` env-major. Stateful
    /// implementations advance their recurrent state.
    fn predict(&mut self, dsets: &[f32], probs: &mut [f32]) -> Result<()>;
}

/// Test/diagnostic predictor that replays a fixed probability table row by
/// row (cycling). Lives here rather than in tests because benches use it
/// to isolate LS cost from AIP cost.
pub struct ReplayPredictor {
    pub batch: usize,
    pub dset_dim: usize,
    pub rows: Vec<Vec<f32>>,
    pub cursor: usize,
}

impl InfluencePredictor for ReplayPredictor {
    fn num_sources(&self) -> usize {
        self.rows.first().map(|r| r.len()).unwrap_or(0)
    }
    fn dset_dim(&self) -> usize {
        self.dset_dim
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn reset_state(&mut self, _env_idx: usize) {}
    fn reset_all(&mut self) {
        self.cursor = 0;
    }
    fn predict(&mut self, _dsets: &[f32], probs: &mut [f32]) -> Result<()> {
        let u = self.num_sources();
        for b in 0..self.batch {
            let row = &self.rows[self.cursor % self.rows.len()];
            probs[b * u..(b + 1) * u].copy_from_slice(row);
        }
        self.cursor += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replay_cycles_rows() {
        let mut p = ReplayPredictor {
            batch: 2,
            dset_dim: 3,
            rows: vec![vec![0.1, 0.9], vec![0.5, 0.5]],
            cursor: 0,
        };
        let d = vec![0.0; 6];
        let mut probs = vec![0.0; 4];
        p.predict(&d, &mut probs).unwrap();
        assert_eq!(probs, vec![0.1, 0.9, 0.1, 0.9]);
        p.predict(&d, &mut probs).unwrap();
        assert_eq!(probs, vec![0.5, 0.5, 0.5, 0.5]);
        p.reset_all();
        p.predict(&d, &mut probs).unwrap();
        assert_eq!(probs, vec![0.1, 0.9, 0.1, 0.9]);
    }
}
