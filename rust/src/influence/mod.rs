//! The influence layer (paper §4): approximate influence predictors (AIPs)
//! and their offline training/evaluation.
//!
//! An AIP estimates `Î_θ(u_t | d_t, history)` — the conditional probability
//! of each binary influence source given the d-set features — and is
//! sampled once per IALS step (Algorithm 2). Four implementations:
//!
//! | impl | paper condition |
//! |------|-----------------|
//! | [`NeuralAip`] (trained) | IALS |
//! | [`NeuralAip`] (random init via [`NeuralAip::untrained`]) | untrained-IALS |
//! | [`FixedMarginalAip`] | F-IALS (Appendix E) |
//! | [`ReplayPredictor`] (test/bench oracle) | — |

pub mod dataset;
pub mod fixed;
pub mod predictor;
pub mod train;

pub use dataset::InfluenceDataset;
pub use fixed::FixedMarginalAip;
pub use predictor::{AipArch, NeuralAip, UNTRAINED_INIT_MIX};
pub use train::{evaluate_ce, train_fnn, train_gru};

use crate::runtime::native::{FnnView, GruView};
use crate::Result;

/// Thread-shareable execution plan for one **fused** IALS step: everything
/// a shard worker needs to run the predictor over its own contiguous row
/// band, inside the same pool dispatch that gathers d-sets and steps the
/// local simulators (`ials::IalsVecEnv`). Borrowed from the predictor
/// between [`InfluencePredictor::begin_step`] and
/// [`InfluencePredictor::end_step`].
pub enum ShardPredict<'a> {
    /// d-set-independent per-source marginals, broadcast to every env row
    /// (the F-IALS predictor).
    Marginals(&'a [f32]),
    /// One FNN forward over the band's d-set rows.
    Fnn(FnnView<'a>),
    /// One GRU step over the band's rows: reads the `h` band, writes the
    /// disjoint `h_next` band; the caller's [`InfluencePredictor::end_step`]
    /// swaps the double-buffer after the dispatch completes.
    Gru {
        view: GruView<'a>,
        h: &'a [f32],
        h_next: &'a mut [f32],
    },
}

/// A batched influence predictor. `batch` is fixed at construction (it must
/// match the AOT-compiled artifact's leading dimension).
pub trait InfluencePredictor {
    /// Number of binary influence sources per environment.
    fn num_sources(&self) -> usize;
    /// d-set feature dimension (one timestep's slice).
    fn dset_dim(&self) -> usize;
    /// Batch width this predictor was built for.
    fn batch(&self) -> usize;
    /// Clear any recurrent state for environment row `i` (episode reset).
    fn reset_state(&mut self, env_idx: usize);
    /// Clear all recurrent state.
    fn reset_all(&mut self);
    /// Predict `P(u_t = 1)` for all envs: `dsets` is `[batch * dset_dim]`
    /// env-major, `probs` is `[batch * num_sources]` env-major. Stateful
    /// implementations advance their recurrent state. This is the batched
    /// (coordinator-issued) path; the fused step path uses
    /// [`InfluencePredictor::begin_step`] instead.
    fn predict(&mut self, dsets: &[f32], probs: &mut [f32]) -> Result<()>;

    /// Whether this predictor can execute shard-locally inside a fused
    /// step dispatch (`false` keeps the coordinator-batched sandwich —
    /// e.g. PJRT-backed predictors, whose runtime cannot cross threads).
    fn supports_shard_exec(&self) -> bool {
        false
    }

    /// Begin one fused step: a `Sync` execution plan shard workers run on
    /// their own row bands. Callers must invoke
    /// [`InfluencePredictor::end_step`] exactly once after the dispatch
    /// completes. `None` (the default) means "use [`predict`] instead".
    ///
    /// [`predict`]: InfluencePredictor::predict
    fn begin_step(&mut self) -> Option<ShardPredict<'_>> {
        None
    }

    /// Commit a fused step started with [`InfluencePredictor::begin_step`]
    /// (e.g. swap the recurrent-state double buffer).
    fn end_step(&mut self) {}

    /// Per-row f32 scratch sizes `(a, b)` a shard needs to execute this
    /// predictor's [`ShardPredict`] plan (`(0, 0)` when none is needed).
    fn shard_scratch_rows(&self) -> (usize, usize) {
        (0, 0)
    }

    /// Serialize the predictor's *mutable* step state (recurrent hidden
    /// state, replay cursors — not weights, which the checkpoint layer
    /// rebuilds deterministically from config + seed). Stateless
    /// predictors write nothing.
    fn save_state(&self, _out: &mut Vec<u8>) {}

    /// Restore state written by [`InfluencePredictor::save_state`]. The
    /// default (for stateless predictors) accepts only an empty blob.
    fn load_state(&mut self, bytes: &[u8]) -> Result<()> {
        anyhow::ensure!(
            bytes.is_empty(),
            "stateless predictor given {} bytes of snapshot state",
            bytes.len()
        );
        Ok(())
    }
}

/// Test/diagnostic predictor that replays a fixed probability table row by
/// row (cycling). Lives here rather than in tests because benches use it
/// to isolate LS cost from AIP cost.
pub struct ReplayPredictor {
    pub batch: usize,
    pub dset_dim: usize,
    pub rows: Vec<Vec<f32>>,
    pub cursor: usize,
}

impl InfluencePredictor for ReplayPredictor {
    fn num_sources(&self) -> usize {
        self.rows.first().map(|r| r.len()).unwrap_or(0)
    }
    fn dset_dim(&self) -> usize {
        self.dset_dim
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn reset_state(&mut self, _env_idx: usize) {}
    fn reset_all(&mut self) {
        self.cursor = 0;
    }
    fn predict(&mut self, _dsets: &[f32], probs: &mut [f32]) -> Result<()> {
        let u = self.num_sources();
        for b in 0..self.batch {
            let row = &self.rows[self.cursor % self.rows.len()];
            probs[b * u..(b + 1) * u].copy_from_slice(row);
        }
        self.cursor += 1;
        Ok(())
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.cursor as u64).to_le_bytes());
    }

    fn load_state(&mut self, bytes: &[u8]) -> Result<()> {
        let arr: [u8; 8] = bytes.try_into().map_err(|_| {
            anyhow::anyhow!("replay predictor snapshot must be 8 bytes, got {}", bytes.len())
        })?;
        self.cursor = u64::from_le_bytes(arr) as usize;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replay_cycles_rows() {
        let mut p = ReplayPredictor {
            batch: 2,
            dset_dim: 3,
            rows: vec![vec![0.1, 0.9], vec![0.5, 0.5]],
            cursor: 0,
        };
        let d = vec![0.0; 6];
        let mut probs = vec![0.0; 4];
        p.predict(&d, &mut probs).unwrap();
        assert_eq!(probs, vec![0.1, 0.9, 0.1, 0.9]);
        p.predict(&d, &mut probs).unwrap();
        assert_eq!(probs, vec![0.5, 0.5, 0.5, 0.5]);
        p.reset_all();
        p.predict(&d, &mut probs).unwrap();
        assert_eq!(probs, vec![0.1, 0.9, 0.1, 0.9]);
    }
}
