//! Seeded property-testing mini-framework (proptest is not in the offline
//! vendored crate set — see DESIGN.md §6).
//!
//! Provides `forall`-style runners over seeded generators: each case is a
//! pure function of `(base_seed, case_index)` so every failure message
//! pinpoints a reproducible case. No shrinking — cases are kept small by
//! construction instead.
//!
//! ```no_run
//! // (no_run: rustdoc test binaries don't inherit the xla rpath flags)
//! use ials::testkit::{forall, Gen};
//! forall("sum is commutative", 200, |g: &mut Gen| {
//!     let a = g.i64_in(-1000, 1000);
//!     let b = g.i64_in(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

pub mod fault;

use crate::util::Pcg32;

/// Per-case generator handed to property bodies.
pub struct Gen {
    rng: Pcg32,
    pub case: usize,
}

impl Gen {
    pub fn new(seed: u64, case: usize) -> Gen {
        Gen { rng: Pcg32::new(seed ^ 0x9e3779b97f4a7c15, case as u64 + 1), case }
    }

    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo)
    }

    pub fn usize_in(&mut self, lo: usize, hi_incl: usize) -> usize {
        self.rng.range(lo, hi_incl + 1)
    }

    pub fn i64_in(&mut self, lo: i64, hi_incl: i64) -> i64 {
        lo + self.rng.below((hi_incl - lo + 1) as usize) as i64
    }

    /// Vector of f32s with the given length range and value range.
    pub fn vec_f32(&mut self, len_lo: usize, len_hi: usize, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(len_lo, len_hi);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_bool(&mut self, len: usize) -> Vec<bool> {
        (0..len).map(|_| self.bool()).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Base seed for a property: stable per-property (hash of name) unless
/// `IALS_TEST_SEED` overrides it.
fn base_seed(name: &str) -> u64 {
    if let Ok(s) = std::env::var("IALS_TEST_SEED") {
        if let Ok(v) = s.parse::<u64>() {
            return v;
        }
    }
    // FNV-1a over the property name.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Run `cases` independent cases of a property. Panics (with the case
/// index + seed) on the first failing case.
pub fn forall(name: &str, cases: usize, mut body: impl FnMut(&mut Gen)) {
    let seed = base_seed(name);
    for case in 0..cases {
        let mut g = Gen::new(seed, case);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed}): {msg}\n\
                 reproduce with IALS_TEST_SEED={seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("reverse twice is identity", 100, |g| {
            let xs = g.vec_f32(0, 20, -5.0, 5.0);
            let mut ys = xs.clone();
            ys.reverse();
            ys.reverse();
            assert_eq!(xs, ys);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn forall_reports_failure() {
        forall("always fails", 10, |_g| {
            panic!("boom");
        });
    }

    #[test]
    fn gen_is_deterministic_per_case() {
        let mut a = Gen::new(base_seed("x"), 3);
        let mut b = Gen::new(base_seed("x"), 3);
        assert_eq!(a.vec_f32(5, 5, 0.0, 1.0), b.vec_f32(5, 5, 0.0, 1.0));
    }

    #[test]
    fn ranges_respected() {
        forall("ranges respected", 500, |g| {
            let x = g.usize_in(2, 7);
            assert!((2..=7).contains(&x));
            let y = g.i64_in(-3, 3);
            assert!((-3..=3).contains(&y));
            let z = g.f32_in(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&z));
        });
    }
}
