//! Fault-injection helpers for crash-safety testing: a process-level
//! abort hook (kill training after iteration M, driven by an environment
//! variable so CI can inject it into the real binary) and on-disk
//! corruption injectors (truncate / bit-flip / zero a file) used by the
//! checkpoint and param-store robustness tests.

use anyhow::{Context, Result};
use std::path::Path;

/// Environment variable the abort hook reads: `IALS_ABORT_AT_ITER=M`
/// makes a resumable training run fail right after iteration `M` (and
/// after any checkpoint save scheduled for it), emulating a mid-run
/// crash without needing process signals in CI shells.
pub const ABORT_ENV: &str = "IALS_ABORT_AT_ITER";

/// The `abort_after` argument for
/// `coordinator::run_multi_condition_resumable`, from [`ABORT_ENV`].
/// Unset or empty means no injected fault; a malformed value errors
/// rather than silently training to completion.
pub fn abort_after_from_env() -> Result<Option<usize>> {
    match std::env::var(ABORT_ENV) {
        Err(_) => Ok(None),
        Ok(v) if v.is_empty() => Ok(None),
        Ok(v) => {
            let m: usize = v
                .parse()
                .with_context(|| format!("invalid {ABORT_ENV}='{v}': want an iteration number"))?;
            Ok(Some(m))
        }
    }
}

/// Truncate `path` to `len` bytes (a torn write / partial copy).
pub fn truncate_file(path: impl AsRef<Path>, len: usize) -> Result<()> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let keep = len.min(bytes.len());
    std::fs::write(path, &bytes[..keep]).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// XOR one bit of `path` at byte `offset` (silent media corruption).
/// Offsets past the end wrap, so callers can corrupt "somewhere in the
/// payload" without knowing the exact file size.
pub fn flip_bit(path: impl AsRef<Path>, offset: usize, bit: u8) -> Result<()> {
    let path = path.as_ref();
    let mut bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    anyhow::ensure!(!bytes.is_empty(), "cannot flip a bit of empty {}", path.display());
    let i = offset % bytes.len();
    bytes[i] ^= 1u8 << (bit % 8);
    std::fs::write(path, &bytes).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Replace `path` with a zero-length file (a crash between `creat` and
/// the first write of a non-atomic writer).
pub fn zero_file(path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    std::fs::write(path, []).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ials_fault_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(tag)
    }

    #[test]
    fn injectors_corrupt_as_described() {
        let p = tmp("blob.bin");
        std::fs::write(&p, [1u8, 2, 3, 4, 5]).unwrap();
        truncate_file(&p, 2).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), vec![1, 2]);
        flip_bit(&p, 1, 0).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), vec![1, 3]);
        // Offset wraps instead of erroring.
        flip_bit(&p, 3, 0).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), vec![1, 2]);
        zero_file(&p).unwrap();
        assert_eq!(std::fs::read(&p).unwrap().len(), 0);
        assert!(flip_bit(&p, 0, 0).is_err(), "no bits to flip in an empty file");
    }
}
