//! Fault-injection helpers for crash-safety testing: a process-level
//! abort hook (kill training after iteration M, driven by an environment
//! variable so CI can inject it into the real binary) and on-disk
//! corruption injectors (truncate / bit-flip / zero a file) used by the
//! checkpoint and param-store robustness tests.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Environment variable the abort hook reads: `IALS_ABORT_AT_ITER=M`
/// makes a resumable training run fail right after iteration `M` (and
/// after any checkpoint save scheduled for it), emulating a mid-run
/// crash without needing process signals in CI shells.
pub const ABORT_ENV: &str = "IALS_ABORT_AT_ITER";

/// The `abort_after` argument for
/// `coordinator::run_multi_condition_resumable`, from [`ABORT_ENV`].
/// Unset or empty means no injected fault; a malformed value errors
/// rather than silently training to completion.
pub fn abort_after_from_env() -> Result<Option<usize>> {
    match std::env::var(ABORT_ENV) {
        Err(_) => Ok(None),
        Ok(v) if v.is_empty() => Ok(None),
        Ok(v) => {
            let m: usize = v
                .parse()
                .with_context(|| format!("invalid {ABORT_ENV}='{v}': want an iteration number"))?;
            Ok(Some(m))
        }
    }
}

/// Environment variable the distributed worker's kill hook reads:
/// `IALS_WORKER_KILL=<worker>:<iter>[:every]` makes worker `<worker>` abort
/// the process (no cleanup, no result file) right after training iteration
/// `<iter>`. Without `:every` the fault fires once per worker directory
/// ([`fire_once`]), so the supervisor's restarted incarnation survives; with
/// `:every` each incarnation dies again — the way CI and `tests/distributed`
/// exhaust `max_restarts`.
pub const KILL_ENV: &str = "IALS_WORKER_KILL";

/// Like [`KILL_ENV`] but the worker hangs (sleeps forever, heartbeat
/// frozen) instead of dying — exercises the supervisor's hung-worker
/// detection path, which only a stalled-but-alive process can.
pub const HANG_ENV: &str = "IALS_WORKER_HANG";

/// What a matched [`KILL_ENV`] / [`HANG_ENV`] spec tells a worker to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerFaultKind {
    /// `std::process::abort()` — simulates a crash (OOM-kill, segfault).
    Kill,
    /// Sleep forever — simulates a livelock or stuck I/O.
    Hang,
}

/// A parsed worker fault: fire `kind` right after iteration `iter`, either
/// once per worker directory or on every incarnation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerFault {
    pub kind: WorkerFaultKind,
    pub iter: usize,
    pub every_restart: bool,
}

fn parse_worker_fault(env: &str, spec: &str, worker: usize) -> Result<Option<(usize, bool)>> {
    let parts: Vec<&str> = spec.split(':').collect();
    let (w, i, every) = match parts.as_slice() {
        [w, i] => (w, i, false),
        [w, i, "every"] => (w, i, true),
        _ => anyhow::bail!("invalid {env}='{spec}': want <worker>:<iter>[:every]"),
    };
    let w: usize = w.parse().with_context(|| format!("invalid {env}='{spec}': bad worker"))?;
    let i: usize = i.parse().with_context(|| format!("invalid {env}='{spec}': bad iteration"))?;
    Ok(if w == worker { Some((i, every)) } else { None })
}

/// The injected fault for distributed worker `worker`, from [`KILL_ENV`] /
/// [`HANG_ENV`] (kill wins when both name the same worker). Unset or empty
/// means no fault; a malformed spec errors rather than silently running
/// clean.
pub fn worker_fault_from_env(worker: usize) -> Result<Option<WorkerFault>> {
    for (env, kind) in [(KILL_ENV, WorkerFaultKind::Kill), (HANG_ENV, WorkerFaultKind::Hang)] {
        match std::env::var(env) {
            Err(_) => {}
            Ok(v) if v.is_empty() => {}
            Ok(v) => {
                if let Some((iter, every_restart)) = parse_worker_fault(env, &v, worker)? {
                    return Ok(Some(WorkerFault { kind, iter, every_restart }));
                }
            }
        }
    }
    Ok(None)
}

/// Environment variable the health guard's NaN-injection hook reads:
/// `IALS_NAN_AT=<learner>:<iter>[:every]` poisons learner `<learner>`'s
/// policy parameters with NaN right after training iteration `<iter>`,
/// emulating a numerically diverged update. The guard detects it via the
/// parameter-norm check and rolls the learner back, so (unlike
/// [`KILL_ENV`]) the faulted run is expected to *succeed* — recovered
/// bitwise onto the clean trajectory. Without `:every` the fault fires
/// once per process (in-memory latch — the post-rollback replay must run
/// clean); with `:every` each replay re-diverges, exhausting
/// `[health] max_rollbacks` and driving the quarantine path.
pub const NAN_ENV: &str = "IALS_NAN_AT";

/// Like [`NAN_ENV`] but perturbs only the *observed* gradient-norm metric
/// (multiplies it by 1000; parameters untouched), exercising the guard's
/// rolling-window spike detector instead of the non-finite check.
pub const SPIKE_ENV: &str = "IALS_GRAD_SPIKE_AT";

/// What a matched [`NAN_ENV`] / [`SPIKE_ENV`] spec does to a learner.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LearnerFaultKind {
    /// Overwrite the learner's policy parameters with NaN.
    NanParams,
    /// Scale the reported grad norm by 1000 (metrics only).
    GradSpike,
}

/// A parsed per-learner fault: fire `kind` right after the learner
/// completes iteration `iter`. The latch is in-memory (not a file like
/// [`fire_once`]) because the replay that must survive happens in the
/// *same* process, right after the rollback.
#[derive(Debug, Clone)]
pub struct LearnerFault {
    pub kind: LearnerFaultKind,
    pub iter: usize,
    pub every: bool,
    fired: bool,
}

impl LearnerFault {
    /// Whether the fault fires for a just-completed iteration `iter`
    /// (0-based, the learner's own counter). Latches after the first hit
    /// unless the spec said `:every`.
    pub fn should_fire(&mut self, iter: usize) -> bool {
        if iter != self.iter || (self.fired && !self.every) {
            return false;
        }
        self.fired = true;
        true
    }
}

/// The injected fault for (global) learner `learner`, from [`NAN_ENV`] /
/// [`SPIKE_ENV`] (NaN wins when both name the same learner). Unset or
/// empty means no fault; a malformed spec errors rather than silently
/// running clean.
pub fn learner_fault_from_env(learner: usize) -> Result<Option<LearnerFault>> {
    for (env, kind) in [
        (NAN_ENV, LearnerFaultKind::NanParams),
        (SPIKE_ENV, LearnerFaultKind::GradSpike),
    ] {
        match std::env::var(env) {
            Err(_) => {}
            Ok(v) if v.is_empty() => {}
            Ok(v) => {
                if let Some((iter, every)) = parse_worker_fault(env, &v, learner)? {
                    return Ok(Some(LearnerFault { kind, iter, every, fired: false }));
                }
            }
        }
    }
    Ok(None)
}

/// First-incarnation latch for injected faults: returns `true` exactly once
/// per `marker` path (the create beats any later attempt), so a restarted
/// worker reruns the same code without re-dying. The marker lives in the
/// worker's directory, which survives the restart.
pub fn fire_once(marker: impl AsRef<Path>) -> bool {
    std::fs::OpenOptions::new().write(true).create_new(true).open(marker.as_ref()).is_ok()
}

/// A crash in the middle of `util::state::atomic_write`: performs the same
/// steps up to the crash point — temp file `.{name}.tmp` in the target
/// directory, only the first `written` bytes of `bytes` flushed — and then
/// "dies" before the atomic rename. The destination at `path` is never
/// touched. Returns the temp path so tests can assert on (and clean up) the
/// debris a real crash would leave.
pub fn partial_atomic_write(
    path: impl AsRef<Path>,
    bytes: &[u8],
    written: usize,
) -> Result<PathBuf> {
    let path = path.as_ref();
    if let Some(d) = path.parent() {
        if !d.as_os_str().is_empty() {
            std::fs::create_dir_all(d)
                .with_context(|| format!("creating directory {}", d.display()))?;
        }
    }
    let file_name = path
        .file_name()
        .and_then(|n| n.to_str())
        .with_context(|| format!("partial_atomic_write: bad path {}", path.display()))?;
    let tmp = path.with_file_name(format!(".{file_name}.tmp"));
    let keep = written.min(bytes.len());
    std::fs::write(&tmp, &bytes[..keep]).with_context(|| format!("writing {}", tmp.display()))?;
    Ok(tmp)
}

/// Environment variable the serving engine's stall hook reads:
/// `IALS_SERVE_STALL_MS=<ms>` makes the micro-batcher engine sleep once at
/// startup, before consuming any request — a deterministic way to fill the
/// bounded request queue (load-shedding tests) and to park a request
/// in-flight across a SIGINT (drain tests) without racing the engine.
pub const SERVE_STALL_ENV: &str = "IALS_SERVE_STALL_MS";

/// The injected engine stall in milliseconds, from [`SERVE_STALL_ENV`].
/// Unset or empty means no stall; a malformed value errors rather than
/// silently serving at full speed.
pub fn serve_stall_from_env() -> Result<Option<u64>> {
    match std::env::var(SERVE_STALL_ENV) {
        Err(_) => Ok(None),
        Ok(v) if v.is_empty() => Ok(None),
        Ok(v) => {
            let ms: u64 = v
                .parse()
                .with_context(|| format!("invalid {SERVE_STALL_ENV}='{v}': want milliseconds"))?;
            Ok(Some(ms))
        }
    }
}

// ---------------------------------------------------------------------------
// Client-side HTTP injectors (the serving runtime's corruption matrix)
// ---------------------------------------------------------------------------

/// Write `bytes` to `addr`, half-close the write side, and collect whatever
/// the server answers (possibly nothing — a clean close is a valid defense).
/// The read is bounded by `timeout` so a wedged server fails the test
/// instead of hanging it.
fn send_and_collect(
    addr: std::net::SocketAddr,
    bytes: &[u8],
    timeout: std::time::Duration,
) -> Result<Vec<u8>> {
    use std::io::{Read, Write};
    let mut stream = std::net::TcpStream::connect(addr)
        .with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(timeout)).ok();
    stream.write_all(bytes).ok(); // the server may close on us mid-write
    stream.shutdown(std::net::Shutdown::Write).ok();
    let mut out = Vec::new();
    let _ = stream.read_to_end(&mut out); // timeout/reset both mean "done"
    Ok(out)
}

/// A client that dies mid-request: send only the first `keep` bytes of
/// `request`, close, and return the server's response bytes (a structured
/// 4xx, or empty if the server just closed — never a hang).
pub fn send_truncated_request(
    addr: std::net::SocketAddr,
    request: &[u8],
    keep: usize,
) -> Result<Vec<u8>> {
    send_and_collect(addr, &request[..keep.min(request.len())], REPLY_TIMEOUT)
}

/// A client that sends `len` bytes of seeded garbage (not HTTP at all) and
/// returns whatever comes back.
pub fn send_garbage(addr: std::net::SocketAddr, len: usize, seed: u64) -> Result<Vec<u8>> {
    let mut rng = crate::util::Pcg32::seeded(seed);
    let bytes: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
    send_and_collect(addr, &bytes, REPLY_TIMEOUT)
}

/// A client whose headers claim (and whose body delivers) `body_len` bytes
/// to `path` — the oversized-body probe. Returns the response bytes; the
/// server must answer from the Content-Length alone, before reading (or
/// allocating for) the body.
pub fn send_oversized_body(
    addr: std::net::SocketAddr,
    path: &str,
    body_len: usize,
) -> Result<Vec<u8>> {
    let head = format!("POST {path} HTTP/1.1\r\nContent-Length: {body_len}\r\n\r\n");
    let mut bytes = head.into_bytes();
    bytes.resize(bytes.len() + body_len, b'x');
    send_and_collect(addr, &bytes, REPLY_TIMEOUT)
}

/// A slow-loris client: send `prefix` (an incomplete request head), then
/// stall for `hold` while keeping the connection open. Returns the server's
/// response — a well-defended server answers `408` (read timeout) instead
/// of letting the connection pin a worker forever.
pub fn slow_loris_request(
    addr: std::net::SocketAddr,
    prefix: &[u8],
    hold: std::time::Duration,
) -> Result<Vec<u8>> {
    use std::io::{Read, Write};
    let mut stream = std::net::TcpStream::connect(addr)
        .with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(hold + REPLY_TIMEOUT)).ok();
    stream.write_all(prefix).ok();
    // Keep the write side open — the whole point is an unfinished request.
    let mut out = Vec::new();
    let _ = stream.read_to_end(&mut out);
    Ok(out)
}

/// How long the injectors wait for a reply before declaring the exchange
/// over. Generous against CI jitter, small enough that a matrix of probes
/// stays fast.
const REPLY_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(5);

/// Read exactly one HTTP response off a keep-alive connection: the head
/// (status line + headers, returned verbatim) and exactly
/// `Content-Length` body bytes. Unlike `read_to_end`, this does not need
/// the server to close the connection — it is how tests and the serve
/// bench drive many requests down one socket. Fails loudly on a closed
/// or truncated response rather than returning a partial one.
pub fn read_one_response(reader: &mut impl std::io::BufRead) -> Result<(String, Vec<u8>)> {
    use std::io::Read;
    let mut head = String::new();
    loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).context("reading a response head line")?;
        anyhow::ensure!(n > 0, "connection closed mid-response-head (head so far: {head:?})");
        head.push_str(&line);
        if line == "\r\n" {
            break;
        }
    }
    let mut content_length = None;
    for line in head.lines() {
        if let Some((k, v)) = line.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                let len: usize = v.trim().parse().with_context(|| {
                    format!("bad content-length {:?} in response head", v.trim())
                })?;
                content_length = Some(len);
            }
        }
    }
    let len = content_length
        .with_context(|| format!("response head has no content-length: {head:?}"))?;
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).context("reading the response body")?;
    Ok((head, body))
}

/// Truncate `path` to `len` bytes (a torn write / partial copy).
pub fn truncate_file(path: impl AsRef<Path>, len: usize) -> Result<()> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let keep = len.min(bytes.len());
    std::fs::write(path, &bytes[..keep]).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// XOR one bit of `path` at byte `offset` (silent media corruption).
/// Offsets past the end wrap, so callers can corrupt "somewhere in the
/// payload" without knowing the exact file size.
pub fn flip_bit(path: impl AsRef<Path>, offset: usize, bit: u8) -> Result<()> {
    let path = path.as_ref();
    let mut bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    anyhow::ensure!(!bytes.is_empty(), "cannot flip a bit of empty {}", path.display());
    let i = offset % bytes.len();
    bytes[i] ^= 1u8 << (bit % 8);
    std::fs::write(path, &bytes).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Replace `path` with a zero-length file (a crash between `creat` and
/// the first write of a non-atomic writer).
pub fn zero_file(path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    std::fs::write(path, []).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ials_fault_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(tag)
    }

    #[test]
    fn worker_fault_spec_parses_and_filters_by_worker() {
        assert_eq!(parse_worker_fault("E", "1:2", 1).unwrap(), Some((2, false)));
        assert_eq!(parse_worker_fault("E", "1:2", 0).unwrap(), None, "other worker untouched");
        assert_eq!(parse_worker_fault("E", "0:3:every", 0).unwrap(), Some((3, true)));
        assert!(parse_worker_fault("E", "1", 1).is_err());
        assert!(parse_worker_fault("E", "1:2:always", 1).is_err());
        assert!(parse_worker_fault("E", "one:2", 1).is_err());
        assert!(parse_worker_fault("E", "1:2:every:x", 1).is_err());
    }

    #[test]
    fn learner_fault_latch_and_every() {
        let mut f = LearnerFault { kind: LearnerFaultKind::NanParams, iter: 2, every: false, fired: false };
        assert!(!f.should_fire(1));
        assert!(f.should_fire(2), "first pass over iter 2 fires");
        assert!(!f.should_fire(2), "post-rollback replay runs clean");
        let mut f = LearnerFault { kind: LearnerFaultKind::GradSpike, iter: 2, every: true, fired: false };
        assert!(f.should_fire(2));
        assert!(f.should_fire(2), ":every re-fires on replay");
    }

    #[test]
    fn fire_once_latches_on_first_call() {
        let marker = tmp("fire_once.marker");
        std::fs::remove_file(&marker).ok();
        assert!(fire_once(&marker), "first call wins");
        assert!(!fire_once(&marker), "second call sees the latch");
        assert!(!fire_once(&marker));
        std::fs::remove_file(&marker).ok();
    }

    #[test]
    fn partial_write_leaves_destination_untouched() {
        let dest = tmp("partial_dest.bin");
        std::fs::remove_file(&dest).ok();
        let tmp_path = partial_atomic_write(&dest, b"abcdef", 3).unwrap();
        assert!(!dest.exists(), "crash before rename must not create the destination");
        assert_eq!(std::fs::read(&tmp_path).unwrap(), b"abc", "temp holds the torn prefix");
        std::fs::remove_file(tmp_path).ok();
    }

    #[test]
    fn read_one_response_frames_by_content_length() {
        // Two pipelined responses on one "connection": each read takes
        // exactly one, leaving the next untouched.
        let wire = b"HTTP/1.1 200 OK\r\ncontent-length: 2\r\n\r\nok\
                     HTTP/1.1 503 Service Unavailable\r\nContent-Length: 4\r\n\r\nbusy";
        let mut reader = std::io::BufReader::new(&wire[..]);
        let (head, body) = read_one_response(&mut reader).unwrap();
        assert!(head.starts_with("HTTP/1.1 200 OK\r\n"), "{head}");
        assert_eq!(body, b"ok");
        let (head, body) = read_one_response(&mut reader).unwrap();
        assert!(head.starts_with("HTTP/1.1 503"), "{head}");
        assert_eq!(body, b"busy");
        // A third read fails loudly: the stream is exhausted.
        assert!(read_one_response(&mut reader).is_err());
        // Truncated bodies fail instead of returning partial bytes.
        let wire = b"HTTP/1.1 200 OK\r\ncontent-length: 10\r\n\r\nshort";
        assert!(read_one_response(&mut std::io::BufReader::new(&wire[..])).is_err());
        // A head with no content-length is unframeable — loud error.
        let wire = b"HTTP/1.1 200 OK\r\n\r\n";
        assert!(read_one_response(&mut std::io::BufReader::new(&wire[..])).is_err());
    }

    #[test]
    fn injectors_corrupt_as_described() {
        let p = tmp("blob.bin");
        std::fs::write(&p, [1u8, 2, 3, 4, 5]).unwrap();
        truncate_file(&p, 2).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), vec![1, 2]);
        flip_bit(&p, 1, 0).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), vec![1, 3]);
        // Offset wraps instead of erroring.
        flip_bit(&p, 3, 0).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), vec![1, 2]);
        zero_file(&p).unwrap();
        assert_eq!(std::fs::read(&p).unwrap().len(), 0);
        assert!(flip_bit(&p, 0, 0).is_err(), "no bits to flip in an empty file");
    }
}
