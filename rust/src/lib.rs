//! # ials — Influence-Augmented Local Simulators for fast deep RL
//!
//! Reproduction of *"Influence-Augmented Local Simulators: a Scalable
//! Solution for Fast Deep RL in Large Networked Systems"* (Suau, He, Spaan,
//! Oliehoek — ICML 2022) as a three-layer Rust + JAX + Pallas system.
//!
//! The crate is **Layer 3**: it owns the simulators, the influence layer,
//! the PPO training loop and all orchestration. Neural computation (policy
//! forward, PPO update, influence-predictor forward/training) is executed
//! through AOT-compiled XLA artifacts (`artifacts/*.hlo.txt`, produced once
//! by `python/compile/aot.py` from JAX/Pallas sources) via the PJRT C API —
//! Python never runs on the request path.
//!
//! ## Module map
//!
//! | Module | Role |
//! |--------|------|
//! | [`core`] | `Environment` / `VecEnv` traits, history buffers, wrappers |
//! | [`sim`] | the two benchmark domains: traffic grid + warehouse (GS & LS) |
//! | [`influence`] | AIP implementations (neural / untrained / fixed / replay) |
//! | [`ials`] | Algorithm 2: local simulator + AIP = drop-in environment |
//! | [`collect`] | Algorithm 1: (d-set, influence-source) dataset collection |
//! | [`runtime`] | PJRT client, artifact manifest, compiled-executable cache |
//! | [`nn`] | flat parameter store + Adam state + checkpoints |
//! | [`rl`] | GAE, rollout buffer, PPO driver |
//! | [`coordinator`] | trainers, evaluators, experiment harnesses per figure |
//! | [`dbn`] | dynamic-Bayesian-network d-separation / minimal d-set search |
//! | [`serve`] | batched policy-inference server over trained checkpoints |
//! | [`config`] | TOML-subset parser + typed experiment schema |
//! | [`metrics`] | CSV learning curves, run summaries |
//! | [`util`] | PRNG, stats, logging, timing |
//! | [`testkit`] | seeded property-testing mini-framework |
//! | [`bench_harness`] | warmup/repeat/percentile benchmark runner |

pub mod bench_harness;
pub mod cli;
pub mod collect;
pub mod config;
pub mod coordinator;
pub mod core;
pub mod dbn;
pub mod ials;
pub mod influence;
pub mod metrics;
pub mod nn;
pub mod rl;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod testkit;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
