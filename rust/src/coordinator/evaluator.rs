//! Policy evaluation on the global simulator (paper §5.1: "training is
//! interleaved with periodic evaluations on the GS").

use crate::core::VecEnv;
use crate::rl::Policy;
use crate::util::Pcg32;
use crate::Result;

#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub mean: f64,
    pub std: f64,
    pub episodes: usize,
}

/// Run `episodes` full episodes on a batch-1 eval environment, sampling
/// actions from the policy (the same stochastic policy PPO optimizes).
/// Returns mean/std of *mean per-step episodic reward* (the paper's metric
/// for traffic is mean speed; warehouse is items collected — both are
/// reported per episode).
pub fn evaluate(
    env: &mut dyn VecEnv,
    policy: &mut Policy,
    episodes: usize,
    seed: u64,
) -> Result<EvalResult> {
    assert_eq!(env.num_envs(), 1, "evaluation uses a batch-1 environment");
    assert_eq!(env.obs_dim(), policy.obs_dim);
    let mut rng = Pcg32::new(seed, 999);
    env.reset_all(seed);
    let mut obs = vec![0.0f32; env.obs_dim()];
    let mut rewards = [0.0f32; 1];
    let mut dones = [false; 1];
    let mut episode_returns = Vec::with_capacity(episodes);
    let mut acc = 0.0f64;
    let mut steps = 0usize;
    while episode_returns.len() < episodes {
        env.observe_all(&mut obs);
        // Batch-1 forward into the policy's reusable eval scratch — the
        // evaluation loop allocates nothing per step, same as training.
        let (logits, _v) = policy.forward1(&obs)?;
        let action = rng.categorical_from_logits(logits);
        env.step_all(&[action], &mut rewards, &mut dones);
        acc += rewards[0] as f64;
        steps += 1;
        if dones[0] {
            episode_returns.push(acc / steps.max(1) as f64);
            acc = 0.0;
            steps = 0;
        }
    }
    let n = episode_returns.len() as f64;
    let mean = episode_returns.iter().sum::<f64>() / n;
    let var = episode_returns.iter().map(|r| (r - mean).powi(2)).sum::<f64>() / n;
    Ok(EvalResult { mean, std: var.sqrt(), episodes })
}

#[cfg(test)]
mod tests {
    // evaluate() is exercised end-to-end in rust/tests/integration_training.rs.
    // rust/tests/eval_parity.rs pins that this batch-1 serial path (env
    // stream and forward1 logits) is bitwise identical to env 0 of the
    // fused training pipeline at the same seed, so eval metrics can never
    // drift from what training actually optimizes.
}
