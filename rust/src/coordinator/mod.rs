//! The coordinator: evaluation on the GS, the wall-clock-aware training
//! loop, the per-figure experiment harnesses, and the multi-learner
//! (distributed-IALS) round-robin driver.

pub mod evaluator;
pub mod experiment;
pub mod multi;
pub mod trainer;

pub use evaluator::{evaluate, EvalResult};
pub use experiment::{run_condition, run_figure, FIGURES};
pub use multi::{
    checkpoint_run_dir, run_multi_condition, run_multi_condition_resumable, MultiLearnerOutcome,
    MultiLearnerRun,
};
pub use trainer::{train_with_eval, LearnerLoop};
