//! The coordinator: evaluation on the GS, the wall-clock-aware training
//! loop, and the per-figure experiment harnesses.

pub mod evaluator;
pub mod experiment;
pub mod trainer;

pub use evaluator::{evaluate, EvalResult};
pub use experiment::{run_condition, run_figure, FIGURES};
pub use trainer::train_with_eval;
