//! The coordinator: evaluation on the GS, the wall-clock-aware training
//! loop, the per-figure experiment harnesses, the multi-learner
//! (distributed-IALS) round-robin driver, and the fault-tolerant
//! cross-process runtime that supervises it over N worker processes.

pub mod distributed;
pub mod evaluator;
pub mod experiment;
pub mod multi;
pub mod trainer;

pub use distributed::{
    distributed_run_dir, run_distributed, run_worker, DistributedOptions, DistributedOutcome,
    ShardReport, WorkerArgs,
};
pub use evaluator::{evaluate, EvalResult};
pub use experiment::{run_condition, run_figure, FIGURES};
pub use multi::{
    checkpoint_run_dir, run_multi_condition, run_multi_condition_resumable, MultiLearnerOutcome,
    MultiLearnerRun,
};
pub use trainer::{train_with_eval, LearnerLoop};
