//! The wall-clock-aware training loop: PPO iterations on the training
//! simulator, periodically paused for GS evaluations (eval time excluded
//! from the training clock, exactly as the paper's x-axes are drawn).
//!
//! The loop body lives in [`LearnerLoop`], a step-wise driver holding one
//! learner's training state (PPO trainer, curve, clock, eval schedule).
//! [`train_with_eval`] runs one learner start-to-finish — the historical
//! single-learner API — while `coordinator::multi` interleaves K
//! [`LearnerLoop`]s round-robin over one compute pool. Both paths execute
//! the exact same per-iteration code, which is what makes a
//! `num_learners = 1` multi-learner run bitwise identical to this one
//! (`rust/tests/multi_learner.rs`).

use super::evaluator::evaluate;
use crate::config::ExperimentConfig;
use crate::core::VecEnv;
use crate::log_info;
use crate::metrics::{read_curve_state, write_curve_state, CurvePoint};
use crate::rl::{Policy, PpoStats, PpoTrainer};
use crate::util::{StateReader, StateWriter, Stopwatch};
use crate::Result;

pub struct TrainOutcome {
    pub curve: Vec<CurvePoint>,
    /// PPO training seconds (excluding evaluations).
    pub train_secs: f64,
}

/// One learner's stepwise training loop: owns the PPO trainer, the
/// learning curve, the training stopwatch and the evaluation schedule.
/// Call [`LearnerLoop::start`] once, then [`LearnerLoop::advance`] for
/// `iterations()` iterations, then [`LearnerLoop::finish`]. The
/// environments and the policy are passed per call so a multi-learner
/// driver can hand the same engine-side `Policy` (with swapped-in
/// per-learner parameters) to several loops.
pub struct LearnerLoop {
    trainer: PpoTrainer,
    curve: Vec<CurvePoint>,
    sw: Stopwatch,
    per_iter: usize,
    iterations: usize,
    /// Iterations completed so far — owned here so drivers cannot desync
    /// the final-evaluation trigger with an external counter.
    iter: usize,
    next_eval: usize,
    steps_done: usize,
    seed: u64,
    clock_offset: f64,
}

impl LearnerLoop {
    /// Build the loop for one learner. `clock_offset` shifts the curve
    /// right by the AIP preparation time (the short horizontal segment at
    /// the start of the paper's IALS curves).
    pub fn new(
        cfg: &ExperimentConfig,
        obs_dim: usize,
        seed: u64,
        clock_offset: f64,
    ) -> LearnerLoop {
        let trainer = PpoTrainer::new(&cfg.ppo, obs_dim, seed);
        let per_iter = trainer.steps_per_iteration();
        let iterations = cfg.ppo.total_steps.div_ceil(per_iter);
        LearnerLoop {
            trainer,
            curve: Vec::new(),
            sw: Stopwatch::new(),
            per_iter,
            iterations,
            iter: 0,
            next_eval: cfg.eval_every,
            steps_done: 0,
            seed,
            clock_offset,
        }
    }

    /// PPO iterations this loop will run.
    pub fn iterations(&self) -> usize {
        self.iterations
    }

    /// Reset the training env and record the t=0 curve point.
    pub fn start(
        &mut self,
        cfg: &ExperimentConfig,
        train_env: &mut dyn VecEnv,
        eval_env: &mut dyn VecEnv,
        policy: &mut Policy,
    ) -> Result<()> {
        train_env.reset_all(self.seed);
        let ev = evaluate(eval_env, policy, cfg.eval_episodes, self.seed ^ 0x5EED)?;
        self.curve.push(CurvePoint {
            wall_clock_s: self.clock_offset,
            env_steps: 0,
            eval_mean: ev.mean,
            eval_std: ev.std,
            stats: PpoStats::default(),
        });
        Ok(())
    }

    /// One PPO iteration (training-clocked), plus a GS evaluation when the
    /// schedule (or the final iteration) demands one. Returns the
    /// iteration's training stats so the driver's health guard can
    /// inspect them (`runtime/guard.rs`) — the loop itself never judges.
    pub fn advance(
        &mut self,
        cfg: &ExperimentConfig,
        train_env: &mut dyn VecEnv,
        eval_env: &mut dyn VecEnv,
        policy: &mut Policy,
    ) -> Result<PpoStats> {
        let iter = self.iter;
        self.iter += 1;
        self.sw.resume();
        let last_stats = self.trainer.train_iteration(train_env, policy)?;
        self.sw.pause();
        self.steps_done += self.per_iter;

        if self.steps_done >= self.next_eval || iter + 1 == self.iterations {
            let ev = evaluate(eval_env, policy, cfg.eval_episodes, self.seed ^ (iter as u64 + 1))?;
            self.curve.push(CurvePoint {
                wall_clock_s: self.clock_offset + self.sw.elapsed_secs(),
                env_steps: self.steps_done,
                eval_mean: ev.mean,
                eval_std: ev.std,
                stats: last_stats,
            });
            log_info!(
                "[{}] seed {} steps {}/{} clock {:.1}s eval {:.4} (ent {:.3}, kl {:.4})",
                cfg.name,
                self.seed,
                self.steps_done,
                cfg.ppo.total_steps,
                self.clock_offset + self.sw.elapsed_secs(),
                ev.mean,
                last_stats.entropy,
                last_stats.approx_kl
            );
            while self.next_eval <= self.steps_done {
                self.next_eval += cfg.eval_every;
            }
        }
        Ok(last_stats)
    }

    /// Iterations completed so far.
    pub fn iter(&self) -> usize {
        self.iter
    }

    /// Serialize the loop's full mutable state for checkpointing: trainer
    /// RNG/permutation, the learning curve so far, the iteration/eval
    /// schedule and the training clock. `per_iter`/`iterations` are
    /// derived from config and validated on restore via the seed.
    pub fn write_state(&self, out: &mut StateWriter) {
        self.trainer.save_state(out);
        write_curve_state(&self.curve, out);
        out.usize(self.iter);
        out.usize(self.next_eval);
        out.usize(self.steps_done);
        out.u64(self.seed);
        out.f64(self.clock_offset);
        out.f64(self.sw.elapsed_secs());
    }

    /// Restore state written by [`LearnerLoop::write_state`] into a loop
    /// freshly built with the same config and seed. Do **not** call
    /// [`LearnerLoop::start`] afterwards — the restored curve already
    /// holds the t=0 point and the envs are restored separately.
    pub fn read_state(&mut self, r: &mut StateReader) -> Result<()> {
        self.trainer.load_state(r)?;
        self.curve = read_curve_state(r)?;
        self.iter = r.usize()?;
        anyhow::ensure!(
            self.iter <= self.iterations,
            "checkpoint iteration {} exceeds the configured {} iterations",
            self.iter,
            self.iterations
        );
        self.next_eval = r.usize()?;
        self.steps_done = r.usize()?;
        let seed = r.u64()?;
        anyhow::ensure!(
            seed == self.seed,
            "checkpoint was written with seed {seed}, loop is seeded {}",
            self.seed
        );
        self.clock_offset = r.f64()?;
        self.sw.set_elapsed(r.f64()?);
        Ok(())
    }

    /// The finished curve + training clock.
    pub fn finish(self) -> TrainOutcome {
        TrainOutcome { curve: self.curve, train_secs: self.sw.elapsed_secs() }
    }
}

/// Train `policy` on `train_env` for `cfg.ppo.total_steps` env steps,
/// evaluating on `eval_env` (batch-1, always the GS) every
/// `cfg.eval_every` steps. `clock_offset` shifts the curve right by the
/// AIP preparation time.
pub fn train_with_eval(
    cfg: &ExperimentConfig,
    train_env: &mut dyn VecEnv,
    eval_env: &mut dyn VecEnv,
    policy: &mut Policy,
    seed: u64,
    clock_offset: f64,
) -> Result<TrainOutcome> {
    let mut learner = LearnerLoop::new(cfg, train_env.obs_dim(), seed, clock_offset);
    let plan = super::experiment::worker_plan(cfg);
    let workers = plan.sim.min(cfg.ppo.num_envs);
    if workers > 1 || plan.nn > 1 {
        log_info!(
            "[{}] parallel plan: {} envs over {workers} sim workers, NN slices over {} \
             workers (one shared pool; bitwise identical to serial at this seed)",
            cfg.name,
            cfg.ppo.num_envs,
            plan.nn
        );
    }
    learner.start(cfg, train_env, eval_env, policy)?;
    for _ in 0..learner.iterations() {
        learner.advance(cfg, train_env, eval_env, policy)?;
    }
    Ok(learner.finish())
}
