//! The wall-clock-aware training loop: PPO iterations on the training
//! simulator, periodically paused for GS evaluations (eval time excluded
//! from the training clock, exactly as the paper's x-axes are drawn).

use super::evaluator::evaluate;
use crate::config::ExperimentConfig;
use crate::core::VecEnv;
use crate::log_info;
use crate::metrics::CurvePoint;
use crate::rl::{Policy, PpoStats, PpoTrainer};
use crate::util::Stopwatch;
use crate::Result;

pub struct TrainOutcome {
    pub curve: Vec<CurvePoint>,
    /// PPO training seconds (excluding evaluations).
    pub train_secs: f64,
}

/// Train `policy` on `train_env` for `cfg.ppo.total_steps` env steps,
/// evaluating on `eval_env` (batch-1, always the GS) every
/// `cfg.eval_every` steps. `clock_offset` shifts the curve right by the
/// AIP preparation time (the short horizontal segment at the start of the
/// paper's IALS curves).
pub fn train_with_eval(
    cfg: &ExperimentConfig,
    train_env: &mut dyn VecEnv,
    eval_env: &mut dyn VecEnv,
    policy: &mut Policy,
    seed: u64,
    clock_offset: f64,
) -> Result<TrainOutcome> {
    let mut trainer = PpoTrainer::new(&cfg.ppo, train_env.obs_dim(), seed);
    let plan = super::experiment::worker_plan(cfg);
    let workers = plan.sim.min(cfg.ppo.num_envs);
    if workers > 1 || plan.nn > 1 {
        log_info!(
            "[{}] parallel plan: {} envs over {workers} sim workers, NN slices over {} \
             workers (one shared pool; bitwise identical to serial at this seed)",
            cfg.name,
            cfg.ppo.num_envs,
            plan.nn
        );
    }
    let per_iter = trainer.steps_per_iteration();
    let iterations = cfg.ppo.total_steps.div_ceil(per_iter);
    let mut curve = Vec::new();
    let mut sw = Stopwatch::new();

    train_env.reset_all(seed);

    // Initial evaluation (t=0 point of the curve).
    let ev = evaluate(eval_env, policy, cfg.eval_episodes, seed ^ 0x5EED)?;
    curve.push(CurvePoint {
        wall_clock_s: clock_offset,
        env_steps: 0,
        eval_mean: ev.mean,
        eval_std: ev.std,
        stats: PpoStats::default(),
    });

    let mut next_eval = cfg.eval_every;
    let mut steps_done = 0usize;
    let mut last_stats = PpoStats::default();
    for iter in 0..iterations {
        sw.resume();
        last_stats = trainer.train_iteration(train_env, policy)?;
        sw.pause();
        steps_done += per_iter;

        if steps_done >= next_eval || iter + 1 == iterations {
            let ev = evaluate(eval_env, policy, cfg.eval_episodes, seed ^ (iter as u64 + 1))?;
            curve.push(CurvePoint {
                wall_clock_s: clock_offset + sw.elapsed_secs(),
                env_steps: steps_done,
                eval_mean: ev.mean,
                eval_std: ev.std,
                stats: last_stats,
            });
            log_info!(
                "[{}] seed {seed} steps {steps_done}/{} clock {:.1}s eval {:.4} (ent {:.3}, kl {:.4})",
                cfg.name,
                cfg.ppo.total_steps,
                clock_offset + sw.elapsed_secs(),
                ev.mean,
                last_stats.entropy,
                last_stats.approx_kl
            );
            while next_eval <= steps_done {
                next_eval += cfg.eval_every;
            }
        }
    }
    let _ = last_stats;
    Ok(TrainOutcome { curve, train_secs: sw.elapsed_secs() })
}
