//! Fault-tolerant cross-process distributed training: `repro train
//! --distributed N` splits the K learners of a run across N supervised
//! `repro worker` OS processes.
//!
//! ## Topology
//!
//! The **coordinator** (this module, in the `repro train` process) runs the
//! shared Algorithm-1 collection phase once and serializes it for the
//! workers, then supervises; the **workers** (`repro worker`, spawned by
//! the coordinator) each build and train one contiguous learner shard via
//! the in-process machinery ([`MultiLearnerRun::build_shard`]). Everything
//! crosses the process boundary through files in one run directory
//! ([`distributed_run_dir`]), every durable one framed by
//! `util::state::write_headered` (magic + version + length + CRC-32,
//! written via `atomic_write`):
//!
//! ```text
//! <checkpoint_dir>/<condition>_seed<S>_dist/
//!   config.toml        effective config (coordinator → workers, exact
//!                      TOML round trip: ExperimentConfig::to_toml_string)
//!   aip_data.bin       shared AIP dataset, f32s byte for byte (IALSAIPD)
//!   worker_<i>/
//!     heartbeat        progress note, atomically rewritten per phase/round
//!     ckpt/            the shard's own CheckpointManager directory
//!     result.bin       shard results + final policy params (IALSDRES)
//! ```
//!
//! ## Supervision
//!
//! Liveness is **progress-based**: a worker rewrites its heartbeat file at
//! every phase boundary and every training round, and the coordinator
//! tracks *content changes* — a worker whose heartbeat content has not
//! changed for `[distributed] heartbeat_timeout_secs` is declared hung and
//! killed. Crashed (nonzero/signalled exit) and hung workers are restarted
//! with bounded exponential backoff (`backoff_ms * 2^restarts`, capped by
//! `max_restarts`); a restarted worker auto-resumes from its shard's
//! newest valid checkpoint and replays to completion. When a worker
//! exhausts its restart budget the shard is marked failed, the remaining
//! shards still finish, and [`run_distributed`] returns a structured
//! per-shard report ([`ShardReport`]) — graceful degradation, never a hang
//! and never lost completed shards.
//!
//! ## Bitwise identity
//!
//! The N-process run reproduces the in-process `num_learners = K` run bit
//! for bit (curves, AIP CE, final params) because no bit-affecting state
//! crosses shards: learner `j` is seeded by `learner_seed(seed, j)` from
//! its **global** index wherever it runs, learners share no mutable state,
//! and the one shared input — the AIP dataset — ships as exact f32 bytes.
//! Worker crashes don't perturb bits either: resume replays from a
//! checkpoint through the same deterministic path the crash interrupted
//! (`rust/tests/checkpoint_resume.rs`), so a kill-and-restart run equals
//! the clean run. Locked in by `rust/tests/distributed.rs`.

use super::experiment::{collect_shared_aip_data, SharedAipData};
use super::multi::{MultiLearnerOutcome, MultiLearnerRun};
use crate::config::ExperimentConfig;
use crate::runtime::guard::LearnerHealth;
use crate::core::shard_ranges;
use crate::metrics::{read_curve_state, write_curve_state, ConditionResult};
use crate::runtime::checkpoint::CheckpointManager;
use crate::runtime::Runtime;
use crate::testkit::fault::{fire_once, worker_fault_from_env, WorkerFaultKind};
use crate::util::state::{atomic_write, read_headered, write_headered};
use crate::util::{StateReader, StateWriter};
use crate::{log_info, log_warn, Result};
use anyhow::Context;
use std::path::{Path, PathBuf};
use std::process::{Child, ExitStatus};
use std::rc::Rc;
use std::time::{Duration, Instant};

const AIP_DATA_MAGIC: &[u8; 8] = b"IALSAIPD";
const AIP_DATA_VERSION: u32 = 1;
const RESULT_MAGIC: &[u8; 8] = b"IALSDRES";
// v2: per-learner health record (quarantined flag + rollback count)
// appended to each learner section — the channel that carries the health
// guard's verdicts from workers to the coordinator.
const RESULT_VERSION: u32 = 2;

/// Supervisor poll cadence. Only affects detection latency, never bits.
const POLL: Duration = Duration::from_millis(25);

/// The distributed run directory for one (condition, seed): sibling of the
/// in-process [`super::checkpoint_run_dir`], suffixed so the two runtimes
/// never share files.
pub fn distributed_run_dir(cfg: &ExperimentConfig, seed: u64) -> PathBuf {
    Path::new(&cfg.checkpoint_dir)
        .join(format!("{}-{}_seed{}_dist", cfg.simulator.name(), cfg.name, seed))
}

/// Worker `index`'s private subdirectory (heartbeat, checkpoints, result).
pub fn worker_dir(dist_dir: &Path, index: usize) -> PathBuf {
    dist_dir.join(format!("worker_{index}"))
}

/// Coordinator-side knobs that are not experiment config: where the worker
/// binary lives and what extra environment the workers get. Tests use
/// `worker_env` to scope fault-injection variables to spawned children
/// only, and `worker_exe` because the test binary is not `repro`.
#[derive(Debug, Clone, Default)]
pub struct DistributedOptions {
    /// Worker executable; `None` = this process's own binary.
    pub worker_exe: Option<PathBuf>,
    /// Extra `(key, value)` environment entries for every spawned worker.
    pub worker_env: Vec<(String, String)>,
}

/// What happened to one worker's learner shard.
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub worker: usize,
    /// Global index of the shard's first learner.
    pub first_learner: usize,
    /// Learners in the shard.
    pub count: usize,
    /// Restarts the supervisor granted this worker.
    pub restarts: usize,
    pub ok: bool,
    /// Terminal failure reason (`ok = false` only).
    pub error: Option<String>,
    /// Per-learner health records in shard-local order (empty for failed
    /// shards — a shard that never finished ships no result file). A
    /// quarantined learner does **not** make the shard `ok = false`: the
    /// worker completed its healthy learners and exited cleanly; callers
    /// degrade the process exit code from these records instead.
    pub health: Vec<LearnerHealth>,
}

/// One learner's shipped-back result: the usual per-learner numbers plus
/// the final policy parameters as raw named tensors (the coordinator keeps
/// no engine runtime, so no `ParamStore` is materialized here).
#[derive(Debug, Clone)]
pub struct LearnerResult {
    pub result: ConditionResult,
    pub policy_params: Vec<(String, Vec<f32>)>,
    /// The health guard's final record for this learner (v2 result files).
    pub health: LearnerHealth,
}

/// Outcome of a distributed run: per-learner results in global learner
/// order (`None` where the owning shard failed) plus the per-shard report.
#[derive(Debug, Clone)]
pub struct DistributedOutcome {
    pub learners: Vec<Option<LearnerResult>>,
    pub shards: Vec<ShardReport>,
}

impl DistributedOutcome {
    pub fn all_ok(&self) -> bool {
        self.shards.iter().all(|s| s.ok)
    }

    /// Whether any completed shard reported a quarantined learner.
    pub fn any_quarantined(&self) -> bool {
        self.shards.iter().any(|s| s.health.iter().any(|h| h.quarantined))
    }

    /// Fully healthy: every shard finished and no learner was quarantined.
    /// The condition for a zero exit code.
    pub fn healthy(&self) -> bool {
        self.all_ok() && !self.any_quarantined()
    }

    /// Human-readable per-shard report (printed on degraded exits).
    pub fn report(&self) -> String {
        let mut out = String::from("shard report:\n");
        for s in &self.shards {
            let state = if s.ok {
                "ok".to_string()
            } else {
                format!("FAILED: {}", s.error.as_deref().unwrap_or("?"))
            };
            out.push_str(&format!(
                "  worker {} (learners {}..{}, {} restart(s)): {state}\n",
                s.worker,
                s.first_learner,
                s.first_learner + s.count,
                s.restarts
            ));
            for (off, h) in s.health.iter().enumerate() {
                if h.quarantined || h.rollbacks > 0 {
                    out.push_str(&format!(
                        "    learner {}: {} ({} rollback(s))\n",
                        s.first_learner + off,
                        if h.quarantined { "QUARANTINED" } else { "recovered" },
                        h.rollbacks
                    ));
                }
            }
        }
        out
    }

    /// The same report as machine-readable JSON (for `report.json` next
    /// to the curve CSVs — CI and sweeps assert on outcomes without
    /// scraping logs). Hand-rolled: the offline crate set has no serde.
    pub fn report_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"ok\": {},\n", self.all_ok()));
        out.push_str(&format!("  \"quarantined\": {},\n", self.any_quarantined()));
        out.push_str("  \"shards\": [\n");
        for (i, s) in self.shards.iter().enumerate() {
            let error = match &s.error {
                None => "null".to_string(),
                Some(e) => format!("\"{}\"", json_escape(e)),
            };
            let learners: Vec<String> = s
                .health
                .iter()
                .enumerate()
                .map(|(off, h)| {
                    format!(
                        "{{\"learner\": {}, \"quarantined\": {}, \"rollbacks\": {}}}",
                        s.first_learner + off,
                        h.quarantined,
                        h.rollbacks
                    )
                })
                .collect();
            out.push_str(&format!(
                "    {{\"worker\": {}, \"first_learner\": {}, \"count\": {}, \"restarts\": {}, \
                 \"ok\": {}, \"error\": {error}, \"learners\": [{}]}}{}\n",
                s.worker,
                s.first_learner,
                s.count,
                s.restarts,
                s.ok,
                learners.join(", "),
                if i + 1 < self.shards.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars) —
/// error strings routinely quote paths and status text.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Coordinator
// ---------------------------------------------------------------------------

enum SlotState {
    Running(Child),
    Backoff(Instant),
    Done(Vec<LearnerResult>),
    Failed(String),
}

struct Slot {
    worker: usize,
    first: usize,
    count: usize,
    state: SlotState,
    restarts: usize,
    /// Last observed heartbeat content + when it last *changed*.
    hb: Vec<u8>,
    hb_at: Instant,
}

/// Train `cfg.num_learners` learners across `workers` supervised worker
/// processes (clamped to the learner count; see the module docs for the
/// protocol). Returns `Ok` with a per-shard report even when shards fail —
/// callers decide the exit code from [`DistributedOutcome::all_ok`]. `Err`
/// is reserved for coordinator-level failures (cannot write the run
/// directory, cannot spawn the worker binary at all).
pub fn run_distributed(
    cfg: &ExperimentConfig,
    seed: u64,
    workers: usize,
    opts: &DistributedOptions,
) -> Result<DistributedOutcome> {
    cfg.validate()?;
    cfg.validate_distributed(workers)?;
    let k = cfg.num_learners;
    let ranges = shard_ranges(k, workers);
    let dist_dir = distributed_run_dir(cfg, seed);
    std::fs::create_dir_all(&dist_dir)
        .with_context(|| format!("creating {}", dist_dir.display()))?;
    log_info!(
        "=== distributed {} / {} / seed {seed}: {k} learner(s) over {} worker(s) in {} ===",
        cfg.name,
        cfg.simulator.name(),
        ranges.len(),
        dist_dir.display()
    );

    // Ship the effective config — the worker re-parses exactly this, so
    // coordinator and workers agree on every knob bit for bit.
    let config_path = dist_dir.join("config.toml");
    atomic_write(&config_path, cfg.to_toml_string().as_bytes())?;

    // One shared Algorithm-1 collection phase, serialized exactly.
    let shared = collect_shared_aip_data(cfg, seed);
    let mut w = StateWriter::new();
    w.bool(shared.is_some());
    if let Some(sh) = &shared {
        sh.write_state(&mut w);
    }
    let aip_path = dist_dir.join("aip_data.bin");
    write_headered(&aip_path, AIP_DATA_MAGIC, AIP_DATA_VERSION, &w.into_bytes())?;
    drop(shared);

    let exe = match &opts.worker_exe {
        Some(p) => p.clone(),
        None => std::env::current_exe().context("resolving the worker executable")?,
    };

    // Slots start in `Backoff(now)` so the supervisor performs the first
    // spawn too: a spawn failure then takes the one cleanup path that
    // kills whatever was already started, instead of orphaning it.
    let mut slots = Vec::with_capacity(ranges.len());
    for (i, (s, e)) in ranges.iter().enumerate() {
        // A stale result from an earlier session must not masquerade as
        // this run's; checkpoints stay (they are the resume payload).
        std::fs::remove_file(worker_dir(&dist_dir, i).join("result.bin")).ok();
        slots.push(Slot {
            worker: i,
            first: *s,
            count: e - s,
            state: SlotState::Backoff(Instant::now()),
            restarts: 0,
            hb: Vec::new(),
            hb_at: Instant::now(),
        });
    }

    let r = supervise(&mut slots, cfg, &exe, &config_path, &dist_dir, seed, opts);
    if r.is_err() {
        // Coordinator-level failure: never leave orphan workers behind.
        for slot in &mut slots {
            if let SlotState::Running(child) = &mut slot.state {
                child.kill().ok();
                child.wait().ok();
            }
        }
    }
    r?;

    let mut learners: Vec<Option<LearnerResult>> = vec![None; k];
    let mut shards = Vec::with_capacity(slots.len());
    for slot in slots {
        let (ok, error, results) = match slot.state {
            SlotState::Done(rs) => (true, None, Some(rs)),
            SlotState::Failed(e) => (false, Some(e), None),
            _ => unreachable!("supervise returns only terminal slots"),
        };
        let mut health = Vec::new();
        if let Some(rs) = results {
            for (off, lr) in rs.into_iter().enumerate() {
                health.push(lr.health);
                learners[slot.first + off] = Some(lr);
            }
        }
        shards.push(ShardReport {
            worker: slot.worker,
            first_learner: slot.first,
            count: slot.count,
            restarts: slot.restarts,
            ok,
            error,
            health,
        });
    }
    Ok(DistributedOutcome { learners, shards })
}

fn spawn_worker(
    slot: &mut Slot,
    exe: &Path,
    config_path: &Path,
    dist_dir: &Path,
    seed: u64,
    opts: &DistributedOptions,
) -> Result<()> {
    let mut cmd = std::process::Command::new(exe);
    cmd.arg("worker")
        .arg("--config")
        .arg(config_path)
        .arg("--dist-dir")
        .arg(dist_dir)
        .arg("--index")
        .arg(slot.worker.to_string())
        .arg("--first-learner")
        .arg(slot.first.to_string())
        .arg("--count")
        .arg(slot.count.to_string())
        .arg("--seed")
        .arg(seed.to_string());
    for (key, val) in &opts.worker_env {
        cmd.env(key, val);
    }
    let child = cmd
        .spawn()
        .with_context(|| format!("spawning worker {} ({})", slot.worker, exe.display()))?;
    // A fresh incarnation gets a fresh liveness window.
    slot.hb_at = Instant::now();
    slot.state = SlotState::Running(child);
    Ok(())
}

/// Crash/hang handling policy: grant a backoff-delayed restart while the
/// budget lasts, mark the shard failed once it is spent.
fn fail_or_restart(slot: &mut Slot, cfg: &ExperimentConfig, reason: String) {
    let d = &cfg.distributed;
    if slot.restarts >= d.max_restarts {
        log_warn!(
            "worker {} (learners {}..{}): {reason}; max_restarts = {} exhausted — shard failed",
            slot.worker,
            slot.first,
            slot.first + slot.count,
            d.max_restarts
        );
        slot.state = SlotState::Failed(reason);
        return;
    }
    slot.restarts += 1;
    // Bounded exponential backoff; the shift is clamped so a huge
    // max_restarts cannot overflow the multiplier.
    let delay = d.backoff_ms.saturating_mul(1u64 << (slot.restarts - 1).min(20));
    log_warn!(
        "worker {} (learners {}..{}): {reason}; restart {}/{} in {delay} ms",
        slot.worker,
        slot.first,
        slot.first + slot.count,
        slot.restarts,
        d.max_restarts
    );
    slot.state = SlotState::Backoff(Instant::now() + Duration::from_millis(delay));
}

fn supervise(
    slots: &mut [Slot],
    cfg: &ExperimentConfig,
    exe: &Path,
    config_path: &Path,
    dist_dir: &Path,
    seed: u64,
    opts: &DistributedOptions,
) -> Result<()> {
    let timeout = Duration::from_secs_f64(cfg.distributed.heartbeat_timeout_secs);
    loop {
        let mut pending = false;
        for slot in slots.iter_mut() {
            match &mut slot.state {
                SlotState::Done(_) | SlotState::Failed(_) => {}
                SlotState::Backoff(due) => {
                    pending = true;
                    if Instant::now() >= *due {
                        spawn_worker(slot, exe, config_path, dist_dir, seed, opts)?;
                    }
                }
                SlotState::Running(child) => {
                    pending = true;
                    if let Some(status) = child.try_wait().context("polling a worker")? {
                        on_exit(slot, cfg, dist_dir, status);
                        continue;
                    }
                    // Liveness: has the heartbeat content changed?
                    let hb = std::fs::read(worker_dir(dist_dir, slot.worker).join("heartbeat"))
                        .unwrap_or_default();
                    if hb != slot.hb {
                        slot.hb = hb;
                        slot.hb_at = Instant::now();
                    } else if slot.hb_at.elapsed() > timeout {
                        child.kill().ok();
                        child.wait().ok();
                        let reason = format!(
                            "no heartbeat progress for {:.1}s (heartbeat_timeout_secs = {}) — \
                             killed as hung",
                            slot.hb_at.elapsed().as_secs_f64(),
                            cfg.distributed.heartbeat_timeout_secs
                        );
                        fail_or_restart(slot, cfg, reason);
                    }
                }
            }
        }
        if !pending {
            return Ok(());
        }
        std::thread::sleep(POLL);
    }
}

/// A worker exited: a zero status with a valid result file completes the
/// shard; anything else is a crash (which includes "exited 0 but the
/// result is missing or corrupt" — the restarted worker resumes from its
/// checkpoint and rewrites it).
fn on_exit(slot: &mut Slot, cfg: &ExperimentConfig, dist_dir: &Path, status: ExitStatus) {
    if status.success() {
        let path = worker_dir(dist_dir, slot.worker).join("result.bin");
        match read_result(&path, slot.first, slot.count) {
            Ok(results) => {
                log_info!(
                    "worker {} done: learners {}..{} ({} restart(s))",
                    slot.worker,
                    slot.first,
                    slot.first + slot.count,
                    slot.restarts
                );
                slot.state = SlotState::Done(results);
            }
            Err(e) => {
                fail_or_restart(slot, cfg, format!("exited 0 but shard result is unusable: {e:#}"))
            }
        }
    } else {
        fail_or_restart(slot, cfg, format!("worker exited abnormally ({status})"));
    }
}

// ---------------------------------------------------------------------------
// Worker
// ---------------------------------------------------------------------------

/// `repro worker` flags (all coordinator-supplied — this subcommand is not
/// meant to be invoked by hand).
#[derive(Debug, Clone)]
pub struct WorkerArgs {
    pub dist_dir: PathBuf,
    pub index: usize,
    pub first_learner: usize,
    pub count: usize,
    pub seed: u64,
}

/// The worker process body: deserialize the shared AIP data, build the
/// learner shard (global seeds, shard-local slots), auto-resume from the
/// shard's newest valid checkpoint if one exists, train to completion with
/// per-round heartbeats and checkpoints, and ship the results back via
/// `result.bin`. Exit code is the `Result`: `Ok` ⇒ 0.
pub fn run_worker(cfg: &ExperimentConfig, wa: &WorkerArgs) -> Result<()> {
    let wdir = worker_dir(&wa.dist_dir, wa.index);
    std::fs::create_dir_all(&wdir).with_context(|| format!("creating {}", wdir.display()))?;
    let hb_path = wdir.join("heartbeat");
    let heartbeat = |msg: &str| {
        // Heartbeats are liveness, not state: a failed write must not kill
        // the worker (the supervisor would then also see no progress and
        // restart it, which is the right outcome anyway).
        atomic_write(&hb_path, msg.as_bytes()).ok();
    };
    heartbeat("phase:load-aip-data");
    let bytes = read_headered(wa.dist_dir.join("aip_data.bin"), AIP_DATA_MAGIC, AIP_DATA_VERSION)?;
    let mut r = StateReader::new(&bytes);
    let shared = if r.bool()? { Some(SharedAipData::read_state(&mut r)?) } else { None };
    r.expect_end()?;

    heartbeat("phase:build");
    let rt = Rc::new(Runtime::from_config(cfg)?);
    let fault = worker_fault_from_env(wa.index)?;
    let mut run = MultiLearnerRun::build_shard(
        &rt,
        cfg,
        wa.seed,
        wa.first_learner,
        wa.count,
        shared.as_ref(),
    )?;

    // The shard's own checkpoint stream. Workers always checkpoint — the
    // restart protocol depends on it — so an unset [experiment]
    // checkpoint_every falls back to once per iteration.
    let per_iter = cfg.ppo.num_envs * cfg.ppo.rollout_len;
    let every = if cfg.checkpoint_every > 0 { cfg.checkpoint_every } else { per_iter };
    let mgr = CheckpointManager::new(wdir.join("ckpt"), cfg.checkpoint_retain);
    let start_round = match mgr.load_latest() {
        Some((iter, payload)) => {
            let rounds = run
                .restore(&payload)
                .with_context(|| format!("restoring shard checkpoint at iteration {iter}"))?;
            log_info!(
                "worker {}: resumed learners {}..{} at iteration {rounds}/{}",
                wa.index,
                wa.first_learner,
                wa.first_learner + wa.count,
                run.iterations()
            );
            rounds
        }
        None => {
            run.start()?;
            0
        }
    };
    heartbeat(&format!("round:{start_round}"));

    // Absolute-boundary save cadence (same alignment as the in-process
    // resumable driver, so restarted and clean workers save at the same
    // iterations).
    let mut next_ckpt = {
        let mut n = every;
        while n <= start_round * per_iter {
            n += every;
        }
        n
    };
    for round in start_round..run.iterations() {
        run.advance_round_guarded(round + 1, Some(&mgr))?;
        let steps = (round + 1) * per_iter;
        if steps >= next_ckpt {
            while next_ckpt <= steps {
                next_ckpt += every;
            }
            let payload = run.write_checkpoint(round + 1)?;
            mgr.save(round + 1, &payload)?;
        }
        heartbeat(&format!("round:{}", round + 1));
        if let Some(f) = fault {
            if f.iter == round + 1 && (f.every_restart || fire_once(wdir.join("fault_fired"))) {
                match f.kind {
                    WorkerFaultKind::Kill => {
                        log_warn!("worker {}: injected kill after iteration {}", wa.index, f.iter);
                        std::process::abort();
                    }
                    WorkerFaultKind::Hang => {
                        log_warn!("worker {}: injected hang after iteration {}", wa.index, f.iter);
                        loop {
                            std::thread::sleep(Duration::from_millis(250));
                        }
                    }
                }
            }
        }
    }

    heartbeat("phase:finish");
    let outcome = run.finish()?;
    write_result(&wdir.join("result.bin"), wa.first_learner, &outcome)?;
    heartbeat("phase:done");
    Ok(())
}

// ---------------------------------------------------------------------------
// Shard result file (IALSDRES)
// ---------------------------------------------------------------------------

fn write_result(path: &Path, first_learner: usize, outcome: &MultiLearnerOutcome) -> Result<()> {
    let mut w = StateWriter::new();
    w.usize(first_learner);
    w.usize(outcome.results.len());
    for ((res, store), health) in
        outcome.results.iter().zip(&outcome.policy_stores).zip(&outcome.health)
    {
        w.str(&res.condition);
        w.u64(res.seed);
        write_curve_state(&res.curve, &mut w);
        w.f64(res.prep_secs);
        w.f64(res.train_secs);
        w.f64(res.aip_ce);
        w.f64(res.final_eval);
        // v2: the health guard's record for this learner.
        w.bool(health.quarantined);
        w.usize(health.rollbacks);
        w.usize(store.names().len());
        for name in store.names() {
            w.str(name);
            w.f32s(store.get(name)?);
        }
    }
    write_headered(path, RESULT_MAGIC, RESULT_VERSION, &w.into_bytes())
}

fn read_result(path: &Path, first_learner: usize, count: usize) -> Result<Vec<LearnerResult>> {
    let bytes = read_headered(path, RESULT_MAGIC, RESULT_VERSION)?;
    let mut r = StateReader::new(&bytes);
    let stored_first = r.usize()?;
    let stored_count = r.usize()?;
    anyhow::ensure!(
        (stored_first, stored_count) == (first_learner, count),
        "shard result covers learners {stored_first}..{} but the shard is {first_learner}..{}",
        stored_first + stored_count,
        first_learner + count
    );
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let condition = r.str()?.to_string();
        let seed = r.u64()?;
        let curve = read_curve_state(&mut r)?;
        let prep_secs = r.f64()?;
        let train_secs = r.f64()?;
        let aip_ce = r.f64()?;
        let final_eval = r.f64()?;
        let health = LearnerHealth { quarantined: r.bool()?, rollbacks: r.usize()? };
        let nt = r.usize()?;
        let mut policy_params = Vec::with_capacity(nt);
        for _ in 0..nt {
            let name = r.str()?.to_string();
            policy_params.push((name, r.f32s()?));
        }
        out.push(LearnerResult {
            result: ConditionResult {
                condition,
                seed,
                curve,
                prep_secs,
                train_secs,
                aip_ce,
                final_eval,
            },
            policy_params,
            health,
        });
    }
    r.expect_end()?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_dir_is_disjoint_from_in_process() {
        let cfg = ExperimentConfig::default();
        let dist = distributed_run_dir(&cfg, 7);
        let local = super::super::checkpoint_run_dir(&cfg, 7);
        assert_ne!(dist, local);
        assert!(dist.to_string_lossy().ends_with("_dist"));
        assert_eq!(worker_dir(&dist, 3), dist.join("worker_3"));
    }

    #[test]
    fn result_file_roundtrip_and_shard_mismatch() {
        use crate::metrics::CurvePoint;
        use crate::rl::PpoStats;
        let dir = std::env::temp_dir().join("ials_dres_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("result.bin");
        // Hand-build a 1-learner outcome-shaped payload via the writer's
        // own building blocks (a real MultiLearnerOutcome needs an engine).
        let mut w = StateWriter::new();
        w.usize(2); // first_learner
        w.usize(1); // count
        w.str("ials-t");
        w.u64(99);
        let curve = vec![CurvePoint {
            wall_clock_s: 0.5,
            env_steps: 128,
            eval_mean: 1.25,
            eval_std: 0.25,
            stats: PpoStats::default(),
        }];
        write_curve_state(&curve, &mut w);
        w.f64(1.0);
        w.f64(2.0);
        w.f64(0.5);
        w.f64(1.25);
        w.bool(true); // v2 health: quarantined
        w.usize(2); // v2 health: rollbacks
        w.usize(1);
        w.str("dense.w");
        w.f32s(&[1.0, -2.0]);
        write_headered(&path, RESULT_MAGIC, RESULT_VERSION, &w.into_bytes()).unwrap();
        let rs = read_result(&path, 2, 1).unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].result.condition, "ials-t");
        assert_eq!(rs[0].result.seed, 99);
        assert_eq!(rs[0].result.curve.len(), 1);
        assert_eq!(rs[0].result.curve[0].env_steps, 128);
        assert_eq!(rs[0].policy_params, vec![("dense.w".to_string(), vec![1.0, -2.0])]);
        assert_eq!(rs[0].health, LearnerHealth { quarantined: true, rollbacks: 2 });
        // A result for the wrong shard is rejected, not silently placed.
        let err = read_result(&path, 0, 1).unwrap_err().to_string();
        assert!(err.contains("covers learners 2..3"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    fn degraded_outcome() -> DistributedOutcome {
        DistributedOutcome {
            learners: vec![None, None],
            shards: vec![
                ShardReport {
                    worker: 0,
                    first_learner: 0,
                    count: 1,
                    restarts: 1,
                    ok: true,
                    error: None,
                    health: vec![LearnerHealth { quarantined: true, rollbacks: 2 }],
                },
                ShardReport {
                    worker: 1,
                    first_learner: 1,
                    count: 1,
                    restarts: 2,
                    ok: false,
                    error: Some("worker exited abnormally (signal: 6)".into()),
                    health: vec![],
                },
            ],
        }
    }

    #[test]
    fn report_names_failed_shards_and_quarantines() {
        let out = degraded_outcome();
        assert!(!out.all_ok());
        assert!(out.any_quarantined());
        assert!(!out.healthy());
        let rep = out.report();
        assert!(rep.contains("worker 0 (learners 0..1, 1 restart(s)): ok"), "{rep}");
        assert!(rep.contains("worker 1 (learners 1..2, 2 restart(s)): FAILED"), "{rep}");
        assert!(rep.contains("signal: 6"), "{rep}");
        assert!(rep.contains("learner 0: QUARANTINED (2 rollback(s))"), "{rep}");
    }

    #[test]
    fn report_json_is_machine_readable_and_escaped() {
        let mut out = degraded_outcome();
        out.shards[1].error = Some("bad \"path\"\\tmp\nline".into());
        let json = out.report_json();
        assert!(json.contains("\"ok\": false"), "{json}");
        assert!(json.contains("\"quarantined\": true"), "{json}");
        assert!(
            json.contains("{\"learner\": 0, \"quarantined\": true, \"rollbacks\": 2}"),
            "{json}"
        );
        assert!(json.contains(r#""error": "bad \"path\"\\tmp\nline""#), "{json}");
        // No raw control characters survive escaping.
        assert!(json.chars().all(|c| c == '\n' || (c as u32) >= 0x20), "{json}");
        // Balanced braces/brackets — cheap structural sanity without a
        // JSON parser in the offline crate set.
        let opens = json.matches('{').count() + json.matches('[').count();
        let closes = json.matches('}').count() + json.matches(']').count();
        assert_eq!(opens, closes, "{json}");
    }

    #[test]
    fn healthy_outcome_is_healthy() {
        let out = DistributedOutcome {
            learners: vec![],
            shards: vec![ShardReport {
                worker: 0,
                first_learner: 0,
                count: 1,
                restarts: 0,
                ok: true,
                error: None,
                health: vec![LearnerHealth::default()],
            }],
        };
        assert!(out.healthy());
        assert!(out.report_json().contains("\"quarantined\": false"));
    }
}
