//! Per-figure experiment harnesses: everything needed to regenerate each
//! table/figure of the paper's evaluation (DESIGN.md §3 index).
//!
//! `run_condition` trains one (domain, simulator, seed) cell; `run_figure`
//! fans out over the paper's conditions, writes learning-curve CSVs and a
//! summary into `results/<figure>/`, and prints the figure's rows.

use crate::bench_harness::Table;
use crate::collect::{
    collect_dataset, collect_dataset_sharded, collect_dataset_with_policy, FeatureKind,
};
use crate::config::{DomainKind, ExperimentConfig, SimulatorKind};
use crate::core::{
    shard_ranges, Environment, FrameStackVec, GsVecEnv, ShardedVecEnv, VecEnv, WorkerPlan,
};
use crate::ials::IalsVecEnv;
use crate::influence::{
    evaluate_ce, train_fnn, train_gru, FixedMarginalAip, InfluenceDataset, InfluencePredictor,
    NeuralAip, UNTRAINED_INIT_MIX,
};
use crate::log_info;
use crate::metrics::{write_curve, ConditionResult, SummaryWriter};
use crate::rl::Policy;
use crate::runtime::{learner_seed, MultiStore, Runtime};
use crate::sim::traffic::{TrafficGlobalEnv, TrafficLocalEnv};
use crate::sim::warehouse::{WarehouseGlobalEnv, WarehouseLocalEnv};
use crate::util::Pcg32;
use crate::Result;
use anyhow::Context;
use std::path::Path;
use std::rc::Rc;

pub const FIGURES: &[&str] =
    &["fig3", "fig5", "fig6", "fig8", "fig10", "fig11", "fig12"];

/// The run's resolved worker counts — the single source of truth for both
/// worker knobs (`[ppo] num_workers` sim sharding + dataset collection,
/// `[runtime] nn_workers` native NN slices). Everything below routes
/// through this helper so `0` means the same core count everywhere and the
/// shared compute pool is sized once for both halves.
pub fn worker_plan(cfg: &ExperimentConfig) -> WorkerPlan {
    WorkerPlan::resolve(cfg.ppo.num_workers, cfg.runtime.nn_workers)
}

/// Policy model name for a config (must exist in the manifest).
pub fn policy_model_name(cfg: &ExperimentConfig) -> &'static str {
    match cfg.domain {
        DomainKind::Traffic => "policy_traffic",
        DomainKind::Warehouse => {
            if cfg.warehouse.frame_stack > 1 {
                "policy_warehouse"
            } else {
                "policy_warehouse_nm"
            }
        }
    }
}

/// AIP model name + whether it is recurrent + which features it consumes.
pub fn aip_model_name(cfg: &ExperimentConfig) -> (&'static str, bool, FeatureKind) {
    match cfg.domain {
        DomainKind::Traffic => {
            if cfg.aip.use_full_alsh {
                ("aip_traffic_full", false, FeatureKind::Alsh)
            } else {
                ("aip_traffic", false, FeatureKind::Dset)
            }
        }
        DomainKind::Warehouse => {
            // aip.seq_len selects the paper's M (GRU) vs NM (FNN) predictor.
            if cfg.aip.seq_len > 1 {
                ("aip_warehouse", true, FeatureKind::Dset)
            } else {
                ("aip_warehouse_nm", false, FeatureKind::Dset)
            }
        }
    }
}

/// Outcome of the AIP preparation stage.
pub struct Prep {
    pub predictor: Option<Box<dyn InfluencePredictor>>,
    /// Dataset collection + offline training seconds (counted on the
    /// training clock, per the paper's protocol).
    pub prep_secs: f64,
    /// Held-out cross-entropy (NaN when not applicable).
    pub aip_ce: f64,
}

/// Algorithm-1 GS data shared by every learner of a run: collected
/// **once**, consumed by each learner's own predictor build — the
/// distributed-IALS layout (K AIPs trained on one dataset).
pub struct SharedAipData {
    /// Held-out evaluation data (never timed — reporting only).
    pub eval_data: InfluenceDataset,
    /// Training data for simulator kinds that learn from GS samples
    /// (IALS: `aip.dataset_size` steps; data-estimated F-IALS: 10K steps;
    /// `None` otherwise).
    pub train_data: Option<InfluenceDataset>,
    /// Seconds spent collecting `train_data` (on the training clock).
    pub collect_secs: f64,
}

impl SharedAipData {
    /// Serialize for shipping to distributed worker processes. Exact: the
    /// f32 payloads go byte for byte, so a worker's AIP training consumes
    /// the same bits the in-process run would. `collect_secs` rides along
    /// so workers report the same prep-time accounting.
    pub fn write_state(&self, w: &mut crate::util::StateWriter) {
        self.eval_data.write_state(w);
        w.bool(self.train_data.is_some());
        if let Some(td) = &self.train_data {
            td.write_state(w);
        }
        w.f64(self.collect_secs);
    }

    /// Inverse of [`SharedAipData::write_state`].
    pub fn read_state(r: &mut crate::util::StateReader<'_>) -> Result<SharedAipData> {
        let eval_data = InfluenceDataset::read_state(r)?;
        let train_data = if r.bool()? { Some(InfluenceDataset::read_state(r)?) } else { None };
        let collect_secs = r.f64()?;
        Ok(SharedAipData { eval_data, train_data, collect_secs })
    }
}

/// Run the shared Algorithm-1 collection phase for `cfg.simulator`
/// (`None` for the GS condition, which needs no influence data). Seeds
/// are the run's base seed, so a `num_learners = 1` run collects exactly
/// the bits the single-learner path always has.
pub fn collect_shared_aip_data(cfg: &ExperimentConfig, seed: u64) -> Option<SharedAipData> {
    if cfg.simulator == SimulatorKind::Gs {
        return None;
    }
    let (_, _, feature) = aip_model_name(cfg);
    let eval_data = collect_from_gs(cfg, cfg.aip.eval_size, seed ^ 0xE7A1, feature);
    let (train_data, collect_secs) = match cfg.simulator {
        SimulatorKind::Ials => {
            let t0 = std::time::Instant::now();
            let data = collect_from_gs(cfg, cfg.aip.dataset_size, seed, feature);
            (Some(data), t0.elapsed().as_secs_f64())
        }
        // Estimate the marginal from 10K GS samples (App E).
        SimulatorKind::FixedIals if cfg.aip.fixed_p < 0.0 => {
            let t0 = std::time::Instant::now();
            let data = collect_from_gs(cfg, 10_000, seed, feature);
            (Some(data), t0.elapsed().as_secs_f64())
        }
        _ => (None, 0.0),
    };
    Some(SharedAipData { eval_data, train_data, collect_secs })
}

/// Build learner `learner`'s influence predictor over the shared dataset:
/// a per-learner parameter store seeded from [`learner_seed`] (hosted in
/// slot `slot` of `stores`, then owned by the predictor), trained on
/// `shared.train_data` where the condition demands it. Learner 0 at the
/// base seed reproduces the single-learner preparation bit for bit.
///
/// `slot` and `learner` split on purpose: a distributed worker hosts a
/// *shard* of the learners, so its store slots are shard-local while every
/// bit-affecting seed still derives from the global learner index.
#[allow(clippy::too_many_arguments)]
pub fn build_learner_predictor(
    rt: &Rc<Runtime>,
    cfg: &ExperimentConfig,
    shared: &SharedAipData,
    stores: &mut MultiStore,
    slot: usize,
    learner: usize,
    seed: u64,
    batch: usize,
) -> Result<Prep> {
    let (model, is_gru, _) = aip_model_name(cfg);
    let lseed = learner_seed(seed, learner);
    let (mut predictor, prep_secs): (Box<dyn InfluencePredictor>, f64) = match cfg.simulator {
        SimulatorKind::Gs => unreachable!("GS condition has no influence predictor"),
        SimulatorKind::UntrainedIals => {
            // Random-initialized network; no data, no training time (same
            // seed mix as `NeuralAip::untrained`, by shared constant).
            stores.init_model(rt, slot, model, lseed ^ UNTRAINED_INIT_MIX)?;
            let aip = NeuralAip::from_multi_store(rt.clone(), stores, slot, model, batch)?;
            (Box::new(aip), 0.0)
        }
        SimulatorKind::Ials => {
            let data = shared
                .train_data
                .as_ref()
                .context("IALS condition needs a shared training dataset")?;
            let t0 = std::time::Instant::now();
            // Fresh per-(seed, learner) init so learners (and seeds) are
            // independent repetitions.
            stores.init_model(rt, slot, model, lseed ^ 0xA1B2)?;
            let mut aip = NeuralAip::from_multi_store(rt.clone(), stores, slot, model, batch)?;
            let update = format!("{model}_update");
            let losses = if is_gru {
                let b = rt.geom("gru_seq_b")?;
                let t = rt.geom("gru_seq_t")?;
                train_gru(
                    rt,
                    &mut aip.store,
                    &update,
                    data,
                    cfg.aip.train_epochs,
                    b,
                    t,
                    cfg.aip.lr,
                    lseed,
                )?
            } else {
                train_fnn(
                    rt,
                    &mut aip.store,
                    &update,
                    data,
                    cfg.aip.train_epochs,
                    rt.geom("aip_batch")?,
                    cfg.aip.lr,
                    lseed,
                )?
            };
            log_info!(
                "[{}] learner {learner} AIP {model} trained: loss {:.4} -> {:.4}",
                cfg.name,
                losses.first().copied().unwrap_or(f32::NAN),
                losses.last().copied().unwrap_or(f32::NAN)
            );
            (Box::new(aip), shared.collect_secs + t0.elapsed().as_secs_f64())
        }
        SimulatorKind::FixedIals => {
            if cfg.aip.fixed_p >= 0.0 {
                let u = shared.eval_data.u_dim;
                let d = shared.eval_data.dset_dim;
                let aip = FixedMarginalAip::constant(batch, d, u, cfg.aip.fixed_p);
                (Box::new(aip), 0.0)
            } else {
                let data = shared
                    .train_data
                    .as_ref()
                    .context("data-estimated F-IALS needs the shared 10K dataset")?;
                let aip = FixedMarginalAip::from_data(batch, data);
                (Box::new(aip), shared.collect_secs)
            }
        }
    };

    let aip_ce = evaluate_ce(predictor.as_mut(), &shared.eval_data)? as f64;
    Ok(Prep { predictor: Some(predictor), prep_secs, aip_ce })
}

/// Build (and train, for the IALS condition) the influence predictor
/// demanded by `cfg.simulator`, timing the parts the paper counts — the
/// single-learner path: one shared collection feeding one learner.
pub fn prepare_predictor(
    rt: &Rc<Runtime>,
    cfg: &ExperimentConfig,
    seed: u64,
    batch: usize,
) -> Result<Prep> {
    match collect_shared_aip_data(cfg, seed) {
        None => Ok(Prep { predictor: None, prep_secs: 0.0, aip_ce: f64::NAN }),
        Some(shared) => {
            let mut stores = MultiStore::new(1);
            build_learner_predictor(rt, cfg, &shared, &mut stores, 0, 0, seed, batch)
        }
    }
}

fn collect_from_gs(
    cfg: &ExperimentConfig,
    steps: usize,
    seed: u64,
    feature: FeatureKind,
) -> InfluenceDataset {
    // Algorithm 1 fans out over scoped workers (num_workers = 1 is exactly
    // the serial collector; see `collect_dataset_sharded`).
    let w = worker_plan(cfg).sim;
    match cfg.domain {
        DomainKind::Traffic => collect_dataset_sharded(
            || TrafficGlobalEnv::new(&cfg.traffic),
            steps,
            seed,
            feature,
            w,
        ),
        DomainKind::Warehouse => collect_dataset_sharded(
            || WarehouseGlobalEnv::new(&cfg.warehouse),
            steps,
            seed,
            feature,
            w,
        ),
    }
}

/// Build a GS vec-env, sharded over `w` persistent workers when `w > 1`.
/// Each shard seeds its envs by global index, so any `w` produces bitwise
/// identical rollouts at a fixed seed.
fn make_gs_env<E: Environment + Send + 'static>(
    make: impl Fn() -> E,
    b: usize,
    w: usize,
) -> Box<dyn VecEnv> {
    if w <= 1 {
        return Box::new(GsVecEnv::new((0..b).map(|_| make()).collect()));
    }
    let shards: Vec<GsVecEnv<E>> = shard_ranges(b, w)
        .into_iter()
        .map(|(s, e)| GsVecEnv::with_index_offset((s..e).map(|_| make()).collect(), s))
        .collect();
    Box::new(ShardedVecEnv::from_shards(shards))
}

/// Build the training simulator (the paper's GS vs IALS conditions),
/// sharded over `cfg.ppo.num_workers` persistent worker threads. On the
/// native backend an IALS env steps through the **fused pipeline** (d-set
/// gather, AIP forward, influence sampling and LS stepping in one pool
/// dispatch — `ials::IalsVecEnv`); the policy forward stays one batched
/// pooled call per step on the coordinator (see `core::shard`).
pub fn make_train_env(
    cfg: &ExperimentConfig,
    predictor: Option<Box<dyn InfluencePredictor>>,
) -> Box<dyn VecEnv> {
    let b = cfg.ppo.num_envs;
    let w = worker_plan(cfg).sim.min(b);
    let stack = match cfg.domain {
        DomainKind::Traffic => 1,
        DomainKind::Warehouse => cfg.warehouse.frame_stack,
    };
    let base: Box<dyn VecEnv> = match (cfg.domain, predictor) {
        (DomainKind::Traffic, None) => {
            make_gs_env(|| TrafficGlobalEnv::new(&cfg.traffic), b, w)
        }
        (DomainKind::Traffic, Some(p)) => Box::new(IalsVecEnv::with_workers(
            (0..b).map(|_| TrafficLocalEnv::new(&cfg.traffic)).collect(),
            p,
            w,
        )),
        (DomainKind::Warehouse, None) => {
            make_gs_env(|| WarehouseGlobalEnv::new(&cfg.warehouse), b, w)
        }
        (DomainKind::Warehouse, Some(p)) => Box::new(IalsVecEnv::with_workers(
            (0..b).map(|_| WarehouseLocalEnv::new(&cfg.warehouse)).collect(),
            p,
            w,
        )),
    };
    if stack > 1 {
        Box::new(FrameStackVec::new(base, stack))
    } else {
        base
    }
}

/// Build the batch-1 GS evaluation environment (frame-stacked to match the
/// policy input).
pub fn make_eval_env(cfg: &ExperimentConfig) -> Box<dyn VecEnv> {
    let base: Box<dyn VecEnv> = match cfg.domain {
        DomainKind::Traffic => {
            Box::new(GsVecEnv::new(vec![TrafficGlobalEnv::new(&cfg.traffic)]))
        }
        DomainKind::Warehouse => {
            Box::new(GsVecEnv::new(vec![WarehouseGlobalEnv::new(&cfg.warehouse)]))
        }
    };
    let stack = match cfg.domain {
        DomainKind::Traffic => 1,
        DomainKind::Warehouse => cfg.warehouse.frame_stack,
    };
    if stack > 1 {
        Box::new(FrameStackVec::new(base, stack))
    } else {
        base
    }
}

/// Train one condition with one seed; returns the curve + summary numbers.
pub fn run_condition(
    rt: &Rc<Runtime>,
    cfg: &ExperimentConfig,
    seed: u64,
) -> Result<ConditionResult> {
    log_info!(
        "=== condition {} / {} / seed {seed} (backend: {}) ===",
        cfg.name,
        cfg.simulator.name(),
        rt.backend_kind()
    );
    let prep = prepare_predictor(rt, cfg, seed, cfg.ppo.num_envs)?;
    let prep_secs = prep.prep_secs;
    let aip_ce = prep.aip_ce;
    let mut train_env = make_train_env(cfg, prep.predictor);
    let mut eval_env = make_eval_env(cfg);
    let mut policy = Policy::new(rt.clone(), policy_model_name(cfg), cfg.ppo.num_envs)?;
    policy.reinit(seed)?;
    let out = super::trainer::train_with_eval(
        cfg,
        train_env.as_mut(),
        eval_env.as_mut(),
        &mut policy,
        seed,
        prep_secs,
    )?;
    let final_eval = out.curve.last().map(|p| p.eval_mean).unwrap_or(f64::NAN);
    Ok(ConditionResult {
        condition: format!("{}-{}", cfg.simulator.name(), cfg.name),
        seed,
        curve: out.curve,
        prep_secs,
        train_secs: out.train_secs,
        aip_ce,
        final_eval,
    })
}

/// Mean per-step reward of the actuated baseline controller on the traffic
/// GS (the black horizontal line of Figs 3/10).
pub fn evaluate_actuated(cfg: &ExperimentConfig, episodes: usize, seed: u64) -> f64 {
    let mut env = TrafficGlobalEnv::new(&cfg.traffic);
    let mut returns = Vec::new();
    for ep in 0..episodes {
        env.reset(seed + ep as u64);
        let mut acc = 0.0f64;
        let mut steps = 0usize;
        loop {
            let a = env.actuated_action();
            let s = env.step(a);
            acc += s.reward as f64;
            steps += 1;
            if s.done {
                break;
            }
        }
        returns.push(acc / steps as f64);
    }
    returns.iter().sum::<f64>() / returns.len() as f64
}

/// Item-lifetime histogram under an IALS (Fig 6 bottom): run the IALS with
/// a random policy and log the age at which items disappear externally.
pub fn item_lifetime_histogram(
    rt: &Rc<Runtime>,
    cfg: &ExperimentConfig,
    seed: u64,
    steps: usize,
) -> Result<Vec<u32>> {
    let prep = prepare_predictor(rt, cfg, seed, cfg.ppo.num_envs)?;
    let predictor = prep.predictor.expect("histogram needs an IALS condition");
    let b = cfg.ppo.num_envs;
    let mut env = IalsVecEnv::new(
        (0..b).map(|_| WarehouseLocalEnv::new(&cfg.warehouse)).collect(),
        predictor,
    );
    // Age recording is off by default (training would grow the diagnostic
    // buffer without bound); this harness is its one consumer.
    for e in env.envs_mut() {
        e.record_removed_ages(true);
    }
    env.reset_all(seed);
    let mut rng = Pcg32::new(seed, 31337);
    let mut rewards = vec![0.0f32; b];
    let mut dones = vec![false; b];
    let mut actions = vec![0usize; b];
    for _ in 0..steps {
        for a in actions.iter_mut() {
            *a = rng.below(5);
        }
        env.step_all(&actions, &mut rewards, &mut dones);
    }
    let mut ages = Vec::new();
    for e in env.envs_mut() {
        ages.append(&mut e.removed_ages);
    }
    Ok(ages)
}

// ---------------------------------------------------------------------------
// Figure harnesses
// ---------------------------------------------------------------------------

fn cond(base: &ExperimentConfig, f: impl FnOnce(&mut ExperimentConfig)) -> ExperimentConfig {
    let mut c = base.clone();
    f(&mut c);
    c.validate().expect("derived condition config invalid");
    c
}

/// Run one of the paper's figures end to end. `base` carries the scale
/// knobs (steps, seeds); each figure derives its conditions from it.
pub fn run_figure(rt: &Rc<Runtime>, name: &str, base: &ExperimentConfig) -> Result<()> {
    let dir = Path::new(&base.results_dir).join(name);
    std::fs::create_dir_all(&dir)?;
    let mut summary = SummaryWriter::create(dir.join("summary.csv"))?;
    let mut table = Table::new(
        &format!("{name}: paper-figure reproduction"),
        &["condition", "seed", "prep_s", "train_s", "total_s", "aip_ce", "final_eval"],
    );

    let mut base = base.clone();
    base.name = name.to_string();
    let conditions: Vec<ExperimentConfig> = match name {
        "fig3" | "fig10" => {
            let int = if name == "fig3" { 1 } else { 2 };
            let d = cond(&base, |c| {
                c.domain = DomainKind::Traffic;
                c.traffic.agent_intersection = int;
            });
            vec![
                cond(&d, |c| c.simulator = SimulatorKind::Gs),
                cond(&d, |c| c.simulator = SimulatorKind::Ials),
                cond(&d, |c| c.simulator = SimulatorKind::UntrainedIals),
            ]
        }
        "fig11" => {
            let d = cond(&base, |c| c.domain = DomainKind::Traffic);
            vec![
                cond(&d, |c| c.simulator = SimulatorKind::Gs),
                cond(&d, |c| c.simulator = SimulatorKind::Ials),
                cond(&d, |c| {
                    c.simulator = SimulatorKind::FixedIals;
                    c.aip.fixed_p = 0.1;
                    c.name = format!("{name}-p0.1");
                }),
                cond(&d, |c| {
                    c.simulator = SimulatorKind::FixedIals;
                    c.aip.fixed_p = 0.5;
                    c.name = format!("{name}-p0.5");
                }),
            ]
        }
        "fig5" => {
            let d = cond(&base, |c| {
                c.domain = DomainKind::Warehouse;
                c.warehouse.frame_stack = 8;
            });
            vec![
                cond(&d, |c| c.simulator = SimulatorKind::Gs),
                cond(&d, |c| c.simulator = SimulatorKind::Ials),
                cond(&d, |c| c.simulator = SimulatorKind::UntrainedIals),
            ]
        }
        "fig12" => {
            let d = cond(&base, |c| {
                c.domain = DomainKind::Warehouse;
                c.warehouse.frame_stack = 8;
            });
            vec![
                cond(&d, |c| c.simulator = SimulatorKind::Gs),
                cond(&d, |c| c.simulator = SimulatorKind::Ials),
                cond(&d, |c| {
                    c.simulator = SimulatorKind::FixedIals;
                    c.aip.fixed_p = -1.0; // estimate marginal from GS data
                }),
            ]
        }
        "fig6" => {
            let d = cond(&base, |c| {
                c.domain = DomainKind::Warehouse;
                c.warehouse.fixed_item_lifetime = 8;
                c.simulator = SimulatorKind::Ials;
            });
            let named = |c: &mut ExperimentConfig, n: &str| c.name = format!("{name}-{n}");
            let out = vec![
                cond(&d, |c| {
                    c.warehouse.frame_stack = 8;
                    c.aip.seq_len = 8;
                    named(c, "Magent-Maip");
                }),
                cond(&d, |c| {
                    c.warehouse.frame_stack = 8;
                    c.aip.seq_len = 1;
                    named(c, "Magent-NMaip");
                }),
                cond(&d, |c| {
                    c.warehouse.frame_stack = 1;
                    c.aip.seq_len = 8;
                    named(c, "NMagent-Maip");
                }),
                cond(&d, |c| {
                    c.warehouse.frame_stack = 1;
                    c.aip.seq_len = 1;
                    named(c, "NMagent-NMaip");
                }),
            ];
            // Fig 6 bottom: lifetime histograms under M-IALS and NM-IALS.
            for (label, seq) in [("m", 8usize), ("nm", 1usize)] {
                let hc = cond(&d, |c| {
                    c.aip.seq_len = seq;
                    c.name = format!("{name}-hist-{label}");
                });
                let ages = item_lifetime_histogram(rt, &hc, base.seeds[0], 4000)?;
                let mut w = crate::util::csv::CsvWriter::create(
                    dir.join(format!("histogram_{label}.csv")),
                    &["age"],
                )?;
                for a in &ages {
                    w.row(&[*a as f64])?;
                }
                w.flush()?;
                log_info!("{name}: {label}-IALS histogram, {} removals", ages.len());
            }
            out
        }
        "fig8" => {
            // Confounding ablation — handled separately (CE table only).
            return run_fig8(rt, &base, &dir);
        }
        other => anyhow::bail!("unknown figure '{other}' (known: {FIGURES:?})"),
    };

    for c in &conditions {
        for &seed in &c.seeds {
            let r = run_condition(rt, c, seed)?;
            write_curve(
                dir.join(format!("{}_seed{}.csv", r.condition.replace('/', "-"), seed)),
                &r.curve,
            )?;
            table.row(&[
                r.condition.clone(),
                seed.to_string(),
                format!("{:.2}", r.prep_secs),
                format!("{:.2}", r.train_secs),
                format!("{:.2}", r.total_secs()),
                format!("{:.4}", r.aip_ce),
                format!("{:.4}", r.final_eval),
            ]);
            summary.add(&r)?;
        }
    }

    if name == "fig3" || name == "fig10" {
        let baseline = evaluate_actuated(&conditions[0], base.eval_episodes.max(3), 12345);
        table.row(&[
            "actuated-baseline".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            format!("{baseline:.4}"),
        ]);
        let mut w =
            crate::util::csv::CsvWriter::create(dir.join("actuated_baseline.csv"), &["reward"])?;
        w.row(&[baseline])?;
        w.flush()?;
    }

    table.print();
    Ok(())
}

/// Appendix-B ablation: train the AIP on π₀ data with (a) the d-set and
/// (b) the full ALSH (lights included), then compare held-out CE under π₀
/// vs under a different (actuated) policy. The ALSH predictor picks up the
/// lights→arrival shortcut and degrades off-policy.
fn run_fig8(rt: &Rc<Runtime>, base: &ExperimentConfig, dir: &Path) -> Result<()> {
    let cfg = cond(base, |c| {
        c.domain = DomainKind::Traffic;
        c.simulator = SimulatorKind::Ials;
    });
    let seed = cfg.seeds[0];
    let mut table = Table::new(
        "fig8: spurious-correlation ablation (held-out CE)",
        &["features", "CE under pi0 (random)", "CE under actuated policy", "degradation"],
    );
    let mut rows_csv = crate::util::csv::CsvWriter::create(
        dir.join("ce_table.csv"),
        &["use_alsh", "ce_on_policy", "ce_off_policy", "degradation"],
    )?;

    for use_alsh in [false, true] {
        let feature = if use_alsh { FeatureKind::Alsh } else { FeatureKind::Dset };
        let model = if use_alsh { "aip_traffic_full" } else { "aip_traffic" };
        // Train on random-policy data.
        let mut gs = TrafficGlobalEnv::new(&cfg.traffic);
        let train = collect_dataset(&mut gs, cfg.aip.dataset_size, seed, feature);
        let mut aip = NeuralAip::new(rt.clone(), model, cfg.ppo.num_envs)?;
        let spec = rt.manifest.model(model)?.clone();
        aip.store.reinit(&spec, seed ^ 0xF168);
        train_fnn(
            rt,
            &mut aip.store,
            &format!("{model}_update"),
            &train,
            cfg.aip.train_epochs,
            rt.geom("aip_batch")?,
            cfg.aip.lr,
            seed,
        )?;
        // Evaluate on-policy (fresh random-policy data) and off-policy
        // (data under the actuated controller).
        let mut gs2 = TrafficGlobalEnv::new(&cfg.traffic);
        let on_data = collect_dataset(&mut gs2, 4000, seed ^ 0x0A, feature);
        let mut gs3 = TrafficGlobalEnv::new(&cfg.traffic);
        let off_data = collect_dataset_with_policy(
            &mut gs3,
            4000,
            seed ^ 0x0FF,
            feature,
            |env, _rng, _n| env.actuated_action(),
        );
        let ce_on = evaluate_ce(&mut aip, &on_data)? as f64;
        let ce_off = evaluate_ce(&mut aip, &off_data)? as f64;
        let label = if use_alsh { "full ALSH (confounded)" } else { "d-set" };
        table.row(&[
            label.into(),
            format!("{ce_on:.4}"),
            format!("{ce_off:.4}"),
            format!("{:+.4}", ce_off - ce_on),
        ]);
        rows_csv.row(&[if use_alsh { 1.0 } else { 0.0 }, ce_on, ce_off, ce_off - ce_on])?;
    }
    rows_csv.flush()?;
    table.print();
    Ok(())
}
