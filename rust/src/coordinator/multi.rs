//! The multi-learner IALS runtime ("Distributed IALS", Suau et al.,
//! arXiv:2207.00288): K independent learners trained concurrently in one
//! process, against **shared** influence data, over the **one**
//! process-shared compute pool.
//!
//! ## Layout
//!
//! * One Algorithm-1 GS collection phase feeds one AIP dataset
//!   ([`collect_shared_aip_data`]); every learner trains its own
//!   predictor on it ([`build_learner_predictor`]).
//! * Parameters live in a [`MultiStore`]: per-learner AIP stores are
//!   taken out into per-learner predictors (their recurrent state is
//!   per-learner anyway); per-learner **policy** stores stay hosted, and
//!   one engine-side [`Policy`] (one scratch set, one op cache) has the
//!   active learner's parameters swapped in for its turn and swapped
//!   back out afterwards.
//! * Each learner owns its fused [`IalsVecEnv`]-based training env, its
//!   GS eval env and its [`LearnerLoop`]; rollouts and PPO updates are
//!   scheduled **round-robin in fixed learner order** (learner 0 first,
//!   every round), all over the same shared pool — K learners never
//!   oversubscribe cores, they interleave.
//!
//! ## Determinism
//!
//! Learner `j` is seeded by [`learner_seed`]`(seed, j)` everywhere (init,
//! PPO RNG, env streams, evaluation), learner 0 by the base seed itself.
//! Round-robin order is fixed and learners share no mutable state except
//! the pool (whose scheduling never affects bits), so:
//!
//! * `num_learners = 1` is **bitwise identical** to the single-learner
//!   experiment ([`super::run_condition`]) at the same seed, and
//! * any `num_learners × num_workers × nn_workers` run is bitwise
//!   reproducible across worker counts.
//!
//! Both are locked in by `rust/tests/multi_learner.rs`.
//!
//! [`IalsVecEnv`]: crate::ials::IalsVecEnv

use super::experiment::{
    build_learner_predictor, collect_shared_aip_data, make_eval_env, make_train_env,
    policy_model_name, Prep, SharedAipData,
};
use super::trainer::LearnerLoop;
use crate::config::ExperimentConfig;
use crate::core::VecEnv;
use crate::metrics::ConditionResult;
use crate::nn::ParamStore;
use crate::rl::{Policy, PpoStats};
use crate::runtime::checkpoint::CheckpointManager;
use crate::runtime::guard::{self, HealthGuard, HealthStatus, LearnerHealth, UpdateMetrics};
use crate::runtime::{learner_seed, MultiStore, Runtime};
use crate::testkit::fault::{learner_fault_from_env, LearnerFault, LearnerFaultKind};
use crate::util::{StateReader, StateWriter};
use crate::Result;
use crate::{log_info, log_warn};
use anyhow::{bail, Context};
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// One learner's run-long state: its envs, its stepwise training loop,
/// its health bookkeeping and its reporting numbers. The policy
/// parameters live in the shared [`MultiStore`], not here. The guard and
/// any injected fault are per process incarnation by design — rollback
/// must never restore the rollback budget it just spent, so neither is
/// part of [`MultiLearnerRun::write_checkpoint`].
struct Learner {
    train_env: Box<dyn VecEnv>,
    eval_env: Box<dyn VecEnv>,
    lp: LearnerLoop,
    seed: u64,
    prep_secs: f64,
    aip_ce: f64,
    guard: HealthGuard,
    fault: Option<LearnerFault>,
}

/// Everything one learner produces, in the single-learner result shape
/// (curves are directly comparable with [`super::run_condition`] output).
pub struct MultiLearnerOutcome {
    /// Per-learner condition results, in learner order.
    pub results: Vec<ConditionResult>,
    /// Final per-learner policy parameter stores, in learner order
    /// (bitwise comparisons, checkpointing).
    pub policy_stores: Vec<ParamStore>,
    /// Per-learner health records, in learner order. A quarantined entry
    /// means the learner's curve stops at its last rollback point and the
    /// caller must report the run degraded (nonzero exit).
    pub health: Vec<LearnerHealth>,
}

impl MultiLearnerOutcome {
    /// Whether any learner ended the run quarantined.
    pub fn any_quarantined(&self) -> bool {
        self.health.iter().any(|h| h.quarantined)
    }
}

/// K learners interleaved round-robin over one pool: build with
/// [`MultiLearnerRun::build`], then `start`, `advance_round` for
/// [`MultiLearnerRun::iterations`] rounds, and `finish`. The driver for
/// both [`run_multi_condition`] and `bench_multi_learner`.
pub struct MultiLearnerRun {
    rt: Rc<Runtime>,
    cfg: ExperimentConfig,
    policy: Policy,
    policy_model: &'static str,
    stores: MultiStore,
    learners: Vec<Learner>,
    /// Global index of slot 0 (0 for in-process runs; the shard base for
    /// distributed workers) — fault specs and health logs use global
    /// learner indices.
    first_learner: usize,
}

impl MultiLearnerRun {
    /// Shared collection + per-learner preparation: one Algorithm-1 phase,
    /// then per learner an AIP (trained on the shared dataset), a fused
    /// IALS training env, a GS eval env and a seeded policy store.
    pub fn build(rt: &Rc<Runtime>, cfg: &ExperimentConfig, seed: u64) -> Result<MultiLearnerRun> {
        let k = cfg.num_learners;
        anyhow::ensure!(k >= 1, "num_learners must be >= 1");
        log_info!(
            "=== multi-learner {} / {} / seed {seed}: {k} learner(s) (backend: {}) ===",
            cfg.name,
            cfg.simulator.name(),
            rt.backend_kind()
        );
        let shared = collect_shared_aip_data(cfg, seed);
        Self::build_shard(rt, cfg, seed, 0, k, shared.as_ref())
    }

    /// Build the shard of learners `[first_learner, first_learner + count)`
    /// against already-collected shared AIP data (`None` for the GS
    /// condition). Store slots are shard-local (`0..count`) but every
    /// bit-affecting seed derives from the **global** learner index, so a
    /// learner's bits are identical whether it runs in the full in-process
    /// run or in some worker process's shard — the distributed runtime's
    /// bitwise-identity foundation ([`super::distributed`]).
    pub fn build_shard(
        rt: &Rc<Runtime>,
        cfg: &ExperimentConfig,
        seed: u64,
        first_learner: usize,
        count: usize,
        shared: Option<&SharedAipData>,
    ) -> Result<MultiLearnerRun> {
        anyhow::ensure!(count >= 1, "a learner shard cannot be empty");
        anyhow::ensure!(
            first_learner + count <= cfg.num_learners,
            "shard [{first_learner}, {}) out of range for num_learners = {}",
            first_learner + count,
            cfg.num_learners
        );
        let policy_model = policy_model_name(cfg);
        let mut stores = MultiStore::new(count);
        let mut learners = Vec::with_capacity(count);
        for slot in 0..count {
            let l = first_learner + slot;
            let lseed = learner_seed(seed, l);
            let prep = match shared {
                None => Prep { predictor: None, prep_secs: 0.0, aip_ce: f64::NAN },
                Some(sh) => build_learner_predictor(
                    rt,
                    cfg,
                    sh,
                    &mut stores,
                    slot,
                    l,
                    seed,
                    cfg.ppo.num_envs,
                )?,
            };
            let prep_secs = prep.prep_secs;
            let aip_ce = prep.aip_ce;
            let train_env = make_train_env(cfg, prep.predictor);
            let eval_env = make_eval_env(cfg);
            stores.init_model(rt, slot, policy_model, lseed)?;
            let lp = LearnerLoop::new(cfg, train_env.obs_dim(), lseed, prep_secs);
            learners.push(Learner {
                train_env,
                eval_env,
                lp,
                seed: lseed,
                prep_secs,
                aip_ce,
                guard: HealthGuard::new(cfg.health.clone()),
                // Injected test fault, keyed by *global* learner index
                // (unset env means None — the production path).
                fault: learner_fault_from_env(l)?,
            });
        }
        // One engine-side policy (scratch + artifacts shared across
        // learners); its initially-loaded store is a placeholder that the
        // per-turn swap parks in the MultiStore slot.
        let policy = Policy::new(rt.clone(), policy_model, cfg.ppo.num_envs)?;
        Ok(MultiLearnerRun {
            rt: rt.clone(),
            cfg: cfg.clone(),
            policy,
            policy_model,
            stores,
            learners,
            first_learner,
        })
    }

    pub fn num_learners(&self) -> usize {
        self.learners.len()
    }

    /// PPO iterations per learner (identical for all — one config).
    pub fn iterations(&self) -> usize {
        self.learners[0].lp.iterations()
    }

    /// Env steps one round consumes across all learners.
    pub fn steps_per_round(&self) -> usize {
        self.learners.len() * self.cfg.ppo.num_envs * self.cfg.ppo.rollout_len
    }

    /// Swap learner `l`'s parameters into the shared engine-side policy,
    /// run `f`, and swap them back out — also when `f` errors. The one
    /// place the checkout invariant lives.
    fn with_learner<T>(
        &mut self,
        l: usize,
        f: impl FnOnce(&ExperimentConfig, &mut Learner, &mut Policy) -> Result<T>,
    ) -> Result<T> {
        let MultiLearnerRun { cfg, policy, policy_model, stores, learners, .. } = self;
        let learner = &mut learners[l];
        stores.swap(l, policy_model, &mut policy.store)?;
        let r = f(cfg, learner, policy);
        stores.swap(l, policy_model, &mut policy.store)?;
        r
    }

    /// Reset every learner's env and record its t=0 curve point, in fixed
    /// learner order.
    pub fn start(&mut self) -> Result<()> {
        for l in 0..self.learners.len() {
            self.with_learner(l, |cfg, ln, policy| {
                ln.lp.start(cfg, ln.train_env.as_mut(), ln.eval_env.as_mut(), policy)
            })?;
        }
        Ok(())
    }

    /// One round-robin pass: the next PPO iteration for every learner, in
    /// fixed learner order, each with its own parameters swapped into the
    /// shared engine-side policy for the duration of its turn.
    pub fn advance_round(&mut self) -> Result<()> {
        for l in 0..self.learners.len() {
            self.with_learner(l, |cfg, ln, policy| {
                ln.lp.advance(cfg, ln.train_env.as_mut(), ln.eval_env.as_mut(), policy)
            })?;
        }
        Ok(())
    }

    /// One *guarded* round-robin pass: like [`MultiLearnerRun::advance_round`]
    /// but each learner's update is followed by the health checks of
    /// `runtime/guard.rs`, with automatic rollback to the newest valid
    /// checkpoint on divergence and quarantine once `[health]
    /// max_rollbacks` is exhausted (or no valid checkpoint exists).
    ///
    /// `target` is the iteration count every non-quarantined learner must
    /// reach by the end of the pass (the driver's `round + 1`): a learner
    /// that just rolled back — or resumed behind the round, e.g. it was
    /// quarantined in a previous incarnation — replays forward to it
    /// *within its own turn*, so the fixed round-robin order (and with it
    /// every other learner's bit stream) is untouched.
    pub fn advance_round_guarded(
        &mut self,
        target: usize,
        mgr: Option<&CheckpointManager>,
    ) -> Result<()> {
        for l in 0..self.learners.len() {
            while !self.learners[l].guard.quarantined() && self.learners[l].lp.iter() < target {
                let stats = self.with_learner(l, |cfg, ln, policy| {
                    ln.lp.advance(cfg, ln.train_env.as_mut(), ln.eval_env.as_mut(), policy)
                })?;
                self.check_learner(l, stats, mgr)?;
            }
        }
        Ok(())
    }

    /// Post-update health pass for learner `l`: apply any injected test
    /// fault, feed the observed metrics to the guard, and on divergence
    /// roll back or quarantine. Read-only on the training state unless a
    /// fault is injected or a rollback fires.
    fn check_learner(
        &mut self,
        l: usize,
        stats: PpoStats,
        mgr: Option<&CheckpointManager>,
    ) -> Result<()> {
        let gl = self.first_learner + l;
        let completed = self.learners[l].lp.iter();
        let mut grad_norm = stats.grad_norm as f64;
        if let Some(f) = self.learners[l].fault.as_mut() {
            if f.should_fire(completed) {
                match f.kind {
                    LearnerFaultKind::NanParams => {
                        poison_store(self.stores.store_mut(l, self.policy_model)?)?;
                        log_warn!(
                            "[fault] learner {gl}: policy params poisoned with NaN after \
                             iteration {completed} ({})",
                            crate::testkit::fault::NAN_ENV
                        );
                    }
                    LearnerFaultKind::GradSpike => {
                        grad_norm *= 1000.0;
                        log_warn!(
                            "[fault] learner {gl}: grad-norm metric spiked x1000 after \
                             iteration {completed} ({})",
                            crate::testkit::fault::SPIKE_ENV
                        );
                    }
                }
            }
        }
        if !self.learners[l].guard.enabled() {
            return Ok(());
        }
        let metrics = UpdateMetrics {
            total_loss: stats.total_loss as f64,
            grad_norm,
            param_norm: guard::param_norm(self.stores.store(l, self.policy_model)?)?,
        };
        let (status, verdict) = self.learners[l].guard.observe(&metrics);
        match status {
            HealthStatus::Healthy => {}
            HealthStatus::Anomalous => log_warn!(
                "[health] learner {gl}: anomalous update at iteration {completed}: {verdict:?}"
            ),
            HealthStatus::Diverged => {
                log_warn!(
                    "[health] learner {gl}: diverged at iteration {completed}: {verdict:?}"
                );
                self.rollback_or_quarantine(l, mgr)?;
            }
        }
        Ok(())
    }

    /// Recovery for a diverged learner: roll back to the newest valid
    /// checkpoint while the `[health] max_rollbacks` budget lasts and a
    /// valid checkpoint exists; quarantine otherwise. Only learner `l`'s
    /// state is touched either way.
    fn rollback_or_quarantine(&mut self, l: usize, mgr: Option<&CheckpointManager>) -> Result<()> {
        let gl = self.first_learner + l;
        let Some((iter, payload)) = mgr.and_then(|m| m.load_latest()) else {
            log_warn!("[health] learner {gl}: no valid checkpoint to roll back to — quarantined");
            self.learners[l].guard.quarantine();
            return Ok(());
        };
        if !self.learners[l].guard.try_rollback() {
            log_warn!(
                "[health] learner {gl}: rollback budget exhausted ({} used) — quarantined",
                self.learners[l].guard.rollbacks_used()
            );
            self.learners[l].guard.quarantine();
            return Ok(());
        }
        self.restore_inner(&payload, Some(l))
            .with_context(|| format!("rolling learner {gl} back to iteration {iter}"))?;
        log_warn!(
            "[health] learner {gl}: rolled back to checkpoint at iteration {iter} ({}/{} \
             rollbacks used)",
            self.learners[l].guard.rollbacks_used(),
            self.learners[l].guard.max_rollbacks()
        );
        Ok(())
    }

    /// Serialize the run's full mutable training state after `rounds_done`
    /// completed rounds: the config geometry (validated on restore), then
    /// per learner its hosted policy store (base params *and* Adam `m.*` /
    /// `v.*` / `adam_t` slots — ordinary store tensors), its
    /// [`LearnerLoop`] state (trainer RNG + shuffle permutation, curve,
    /// schedule, training clock) and its training-env snapshot (sim state,
    /// per-env RNG streams, AIP recurrent state). AIP *parameters* are
    /// deliberately absent: preparation is a deterministic function of
    /// (config, seed) and is replayed bit-for-bit by
    /// [`MultiLearnerRun::build`] on resume. Eval envs are fully re-seeded
    /// per evaluation and carry no cross-eval state.
    pub fn write_checkpoint(&self, rounds_done: usize) -> Result<Vec<u8>> {
        let cfg = &self.cfg;
        let mut w = StateWriter::new();
        w.str(cfg.domain.name());
        w.str(cfg.simulator.name());
        w.str(self.policy_model);
        w.usize(self.learners.len());
        w.usize(cfg.ppo.num_envs);
        w.usize(cfg.ppo.rollout_len);
        w.usize(cfg.ppo.total_steps);
        w.usize(cfg.eval_every);
        w.usize(rounds_done);
        for (l, ln) in self.learners.iter().enumerate() {
            w.u64(ln.seed);
            let store = self.stores.store(l, self.policy_model)?;
            w.usize(store.names().len());
            for name in store.names() {
                w.str(name);
                w.f32s(store.get(name)?);
            }
            let mut lw = StateWriter::new();
            ln.lp.write_state(&mut lw);
            w.bytes(&lw.into_bytes());
            let mut ew = StateWriter::new();
            ln.train_env.save_state(&mut ew)?;
            w.bytes(&ew.into_bytes());
        }
        Ok(w.into_bytes())
    }

    /// Restore state written by [`MultiLearnerRun::write_checkpoint`] into
    /// a run freshly built with the same config and seed; returns the
    /// number of completed rounds. Do **not** call
    /// [`MultiLearnerRun::start`] afterwards — the restored curves already
    /// hold their t=0 points. Every geometry mismatch (different learner
    /// count, batch shape, worker-dependent env sharding, seeds) surfaces
    /// as a structured error, never a silently-diverging run.
    pub fn restore(&mut self, payload: &[u8]) -> Result<usize> {
        self.restore_inner(payload, None)
    }

    /// Shared body of [`MultiLearnerRun::restore`] (apply every learner)
    /// and the health guard's rollback (`only = Some(l)`: parse the whole
    /// sequential payload, validate every header and seed, but apply only
    /// learner `l`'s store / loop / env sections). A learner may land
    /// *behind* the checkpoint's round count (it was quarantined, or is
    /// the one being rolled back while the others run ahead) — the
    /// guarded driver replays it forward — but never ahead of it.
    fn restore_inner(&mut self, payload: &[u8], only: Option<usize>) -> Result<usize> {
        let rt = self.rt.clone();
        let rt: &Runtime = &rt;
        let mut r = StateReader::new(payload);
        let domain = r.str()?;
        anyhow::ensure!(
            domain == self.cfg.domain.name(),
            "checkpoint domain '{domain}', run is configured for '{}'",
            self.cfg.domain.name()
        );
        let simulator = r.str()?;
        anyhow::ensure!(
            simulator == self.cfg.simulator.name(),
            "checkpoint simulator '{simulator}', run is configured for '{}'",
            self.cfg.simulator.name()
        );
        let model = r.str()?;
        anyhow::ensure!(
            model == self.policy_model,
            "checkpoint policy model '{model}', run uses '{}'",
            self.policy_model
        );
        let k = r.usize()?;
        anyhow::ensure!(
            k == self.learners.len(),
            "checkpoint has {k} learner(s), run is configured for {}",
            self.learners.len()
        );
        for (what, want) in [
            ("num_envs", self.cfg.ppo.num_envs),
            ("rollout_len", self.cfg.ppo.rollout_len),
            ("total_steps", self.cfg.ppo.total_steps),
            ("eval_every", self.cfg.eval_every),
        ] {
            let got = r.usize()?;
            anyhow::ensure!(
                got == want,
                "checkpoint {what} is {got}, run is configured for {want}"
            );
        }
        let rounds_done = r.usize()?;
        anyhow::ensure!(
            rounds_done <= self.iterations(),
            "checkpoint is at iteration {rounds_done}, run only has {}",
            self.iterations()
        );
        let spec = rt.manifest.model(self.policy_model)?.clone();
        for l in 0..k {
            let lseed = r.u64()?;
            anyhow::ensure!(
                lseed == self.learners[l].seed,
                "checkpoint learner {l} has seed {lseed}, run derives {}",
                self.learners[l].seed
            );
            let nt = r.usize()?;
            anyhow::ensure!(
                nt == spec.params.len(),
                "checkpoint learner {l} store has {nt} tensors, model {} has {}",
                self.policy_model,
                spec.params.len()
            );
            let apply = only.is_none_or(|o| o == l);
            // A fresh store gets a fresh (id, version) cache key, so no
            // backend-side device copy of the pre-restore parameters can
            // survive the resume.
            let mut store = ParamStore::zeros(&spec);
            for _ in 0..nt {
                let name = r.str()?.to_string();
                let vals = r.f32s()?;
                if apply {
                    store.set(&name, &vals).with_context(|| format!("learner {l} store"))?;
                }
            }
            let blob = r.bytes()?;
            if apply {
                self.stores.insert(l, store)?;
                let mut lr = StateReader::new(blob);
                self.learners[l]
                    .lp
                    .read_state(&mut lr)
                    .and_then(|()| lr.expect_end())
                    .with_context(|| format!("learner {l} loop state"))?;
                // `<=`, not `==`: a checkpoint written after a quarantine
                // legitimately holds that learner behind the round count.
                anyhow::ensure!(
                    self.learners[l].lp.iter() <= rounds_done,
                    "learner {l} loop is at iteration {}, checkpoint header says {rounds_done}",
                    self.learners[l].lp.iter()
                );
            }
            let blob = r.bytes()?;
            if apply {
                let mut er = StateReader::new(blob);
                self.learners[l]
                    .train_env
                    .load_state(&mut er)
                    .and_then(|()| er.expect_end())
                    .with_context(|| format!("learner {l} training-env state"))?;
            }
        }
        r.expect_end()?;
        Ok(rounds_done)
    }

    /// Per-learner results + final policy stores + health records, in
    /// learner order.
    pub fn finish(self) -> Result<MultiLearnerOutcome> {
        let MultiLearnerRun { cfg, policy_model, mut stores, learners, .. } = self;
        let mut results = Vec::with_capacity(learners.len());
        let mut policy_stores = Vec::with_capacity(learners.len());
        let mut health = Vec::with_capacity(learners.len());
        for (l, learner) in learners.into_iter().enumerate() {
            health.push(learner.guard.health());
            let out = learner.lp.finish();
            let final_eval = out.curve.last().map(|p| p.eval_mean).unwrap_or(f64::NAN);
            results.push(ConditionResult {
                condition: format!("{}-{}", cfg.simulator.name(), cfg.name),
                seed: learner.seed,
                curve: out.curve,
                prep_secs: learner.prep_secs,
                train_secs: out.train_secs,
                aip_ce: learner.aip_ce,
                final_eval,
            });
            policy_stores.push(stores.take(l, policy_model)?);
        }
        Ok(MultiLearnerOutcome { results, policy_stores, health })
    }
}

/// Overwrite every tensor of a policy store with NaN — the
/// [`LearnerFaultKind::NanParams`] injector. Test-only in spirit, but it
/// lives here (not behind `cfg(test)`) so the release binary that CI's
/// NaN-recovery smoke drives can fire it via the env hook, exactly like
/// `IALS_ABORT_AT_ITER`.
fn poison_store(store: &mut ParamStore) -> Result<()> {
    for name in store.names().to_vec() {
        let n = store.get(&name)?.len();
        store.set(&name, &vec![f32::NAN; n])?;
    }
    Ok(())
}

/// Train `cfg.num_learners` learners end to end (the multi-learner
/// counterpart of [`super::run_condition`]): shared collection,
/// per-learner AIP training, then round-robin PPO with interleaved GS
/// evaluations. Writes checkpoints when `[experiment] checkpoint_every >
/// 0`; see [`run_multi_condition_resumable`] for resuming one.
pub fn run_multi_condition(
    rt: &Rc<Runtime>,
    cfg: &ExperimentConfig,
    seed: u64,
) -> Result<MultiLearnerOutcome> {
    run_multi_condition_resumable(rt, cfg, seed, false, None)
}

/// Per-run checkpoint directory: one subdirectory per (condition, seed),
/// so concurrent conditions and seeds never share checkpoint files.
pub fn checkpoint_run_dir(cfg: &ExperimentConfig, seed: u64) -> PathBuf {
    Path::new(&cfg.checkpoint_dir)
        .join(format!("{}-{}_seed{}", cfg.simulator.name(), cfg.name, seed))
}

/// The crash-safe training driver. With `resume = false` this is
/// [`run_multi_condition`] plus periodic checkpoint saves every
/// `cfg.checkpoint_every` per-learner env steps (rounded up to iteration
/// boundaries; `0` disables saves). With `resume = true` the run is
/// rebuilt from `(cfg, seed)` — replaying the deterministic AIP
/// preparation bit for bit — then fast-forwarded from the newest *valid*
/// checkpoint in [`checkpoint_run_dir`] and trained to completion; the
/// result is bitwise identical (modulo wall-clock columns) to the
/// uninterrupted run at the same seed, for any `num_learners ×
/// num_workers × nn_workers` (`rust/tests/checkpoint_resume.rs`).
///
/// `abort_after` is the fault-injection hook: `Some(m)` kills the run
/// with an error right after iteration `m` completes (and after any
/// checkpoint save scheduled for it), emulating a mid-training crash.
pub fn run_multi_condition_resumable(
    rt: &Rc<Runtime>,
    cfg: &ExperimentConfig,
    seed: u64,
    resume: bool,
    abort_after: Option<usize>,
) -> Result<MultiLearnerOutcome> {
    let mut run = MultiLearnerRun::build(rt, cfg, seed)?;
    let mgr = (cfg.checkpoint_every > 0 || resume)
        .then(|| CheckpointManager::new(checkpoint_run_dir(cfg, seed), cfg.checkpoint_retain));
    let start_round = if resume {
        let mgr = mgr.as_ref().expect("resume implies a checkpoint manager");
        let (iter, payload) = mgr.load_latest().with_context(|| {
            format!(
                "--resume: no valid checkpoint in {} (start without --resume, with \
                 checkpoint_every > 0, to write checkpoints first)",
                mgr.dir().display()
            )
        })?;
        let rounds = run
            .restore(&payload)
            .with_context(|| format!("restoring checkpoint at iteration {iter}"))?;
        log_info!(
            "[{}] seed {seed}: resumed at iteration {rounds}/{}",
            cfg.name,
            run.iterations()
        );
        rounds
    } else {
        run.start()?;
        0
    };
    let per_iter = cfg.ppo.num_envs * cfg.ppo.rollout_len;
    let every = cfg.checkpoint_every;
    // Next per-learner env-step count that triggers a save — aligned to
    // absolute step boundaries so a resumed run saves at the same
    // iterations the uninterrupted run would.
    let mut next_ckpt = if every > 0 {
        let mut n = every;
        while n <= start_round * per_iter {
            n += every;
        }
        n
    } else {
        usize::MAX
    };
    for round in start_round..run.iterations() {
        run.advance_round_guarded(round + 1, mgr.as_ref())?;
        let steps = (round + 1) * per_iter;
        if steps >= next_ckpt {
            while next_ckpt <= steps {
                next_ckpt += every;
            }
            let payload = run.write_checkpoint(round + 1)?;
            mgr.as_ref().expect("save cadence implies a manager").save(round + 1, &payload)?;
        }
        if abort_after == Some(round + 1) {
            bail!("injected abort after iteration {} (fault-injection hook)", round + 1);
        }
    }
    let out = run.finish()?;
    for (l, r) in out.results.iter().enumerate() {
        log_info!(
            "[{}] learner {l} (seed {seed}): prep {:.2}s train {:.2}s aip_ce {:.4} final {:.4}",
            cfg.name,
            r.prep_secs,
            r.train_secs,
            r.aip_ce,
            r.final_eval
        );
    }
    for (l, h) in out.health.iter().enumerate() {
        if h.quarantined || h.rollbacks > 0 {
            log_warn!(
                "[{}] learner {l} (seed {seed}): health {} ({} rollback(s))",
                cfg.name,
                if h.quarantined { "QUARANTINED" } else { "recovered" },
                h.rollbacks
            );
        }
    }
    Ok(out)
}
