//! The multi-learner IALS runtime ("Distributed IALS", Suau et al.,
//! arXiv:2207.00288): K independent learners trained concurrently in one
//! process, against **shared** influence data, over the **one**
//! process-shared compute pool.
//!
//! ## Layout
//!
//! * One Algorithm-1 GS collection phase feeds one AIP dataset
//!   ([`collect_shared_aip_data`]); every learner trains its own
//!   predictor on it ([`build_learner_predictor`]).
//! * Parameters live in a [`MultiStore`]: per-learner AIP stores are
//!   taken out into per-learner predictors (their recurrent state is
//!   per-learner anyway); per-learner **policy** stores stay hosted, and
//!   one engine-side [`Policy`] (one scratch set, one op cache) has the
//!   active learner's parameters swapped in for its turn and swapped
//!   back out afterwards.
//! * Each learner owns its fused [`IalsVecEnv`]-based training env, its
//!   GS eval env and its [`LearnerLoop`]; rollouts and PPO updates are
//!   scheduled **round-robin in fixed learner order** (learner 0 first,
//!   every round), all over the same shared pool — K learners never
//!   oversubscribe cores, they interleave.
//!
//! ## Determinism
//!
//! Learner `j` is seeded by [`learner_seed`]`(seed, j)` everywhere (init,
//! PPO RNG, env streams, evaluation), learner 0 by the base seed itself.
//! Round-robin order is fixed and learners share no mutable state except
//! the pool (whose scheduling never affects bits), so:
//!
//! * `num_learners = 1` is **bitwise identical** to the single-learner
//!   experiment ([`super::run_condition`]) at the same seed, and
//! * any `num_learners × num_workers × nn_workers` run is bitwise
//!   reproducible across worker counts.
//!
//! Both are locked in by `rust/tests/multi_learner.rs`.
//!
//! [`IalsVecEnv`]: crate::ials::IalsVecEnv

use super::experiment::{
    build_learner_predictor, collect_shared_aip_data, make_eval_env, make_train_env,
    policy_model_name, Prep,
};
use super::trainer::LearnerLoop;
use crate::config::ExperimentConfig;
use crate::core::VecEnv;
use crate::log_info;
use crate::metrics::ConditionResult;
use crate::nn::ParamStore;
use crate::rl::Policy;
use crate::runtime::{learner_seed, MultiStore, Runtime};
use crate::Result;
use std::rc::Rc;

/// One learner's run-long state: its envs, its stepwise training loop and
/// its reporting numbers. The policy parameters live in the shared
/// [`MultiStore`], not here.
struct Learner {
    train_env: Box<dyn VecEnv>,
    eval_env: Box<dyn VecEnv>,
    lp: LearnerLoop,
    seed: u64,
    prep_secs: f64,
    aip_ce: f64,
}

/// Everything one learner produces, in the single-learner result shape
/// (curves are directly comparable with [`super::run_condition`] output).
pub struct MultiLearnerOutcome {
    /// Per-learner condition results, in learner order.
    pub results: Vec<ConditionResult>,
    /// Final per-learner policy parameter stores, in learner order
    /// (bitwise comparisons, checkpointing).
    pub policy_stores: Vec<ParamStore>,
}

/// K learners interleaved round-robin over one pool: build with
/// [`MultiLearnerRun::build`], then `start`, `advance_round` for
/// [`MultiLearnerRun::iterations`] rounds, and `finish`. The driver for
/// both [`run_multi_condition`] and `bench_multi_learner`.
pub struct MultiLearnerRun {
    cfg: ExperimentConfig,
    policy: Policy,
    policy_model: &'static str,
    stores: MultiStore,
    learners: Vec<Learner>,
}

impl MultiLearnerRun {
    /// Shared collection + per-learner preparation: one Algorithm-1 phase,
    /// then per learner an AIP (trained on the shared dataset), a fused
    /// IALS training env, a GS eval env and a seeded policy store.
    pub fn build(rt: &Rc<Runtime>, cfg: &ExperimentConfig, seed: u64) -> Result<MultiLearnerRun> {
        let k = cfg.num_learners;
        anyhow::ensure!(k >= 1, "num_learners must be >= 1");
        log_info!(
            "=== multi-learner {} / {} / seed {seed}: {k} learner(s) (backend: {}) ===",
            cfg.name,
            cfg.simulator.name(),
            rt.backend_kind()
        );
        let shared = collect_shared_aip_data(cfg, seed);
        let policy_model = policy_model_name(cfg);
        let mut stores = MultiStore::new(k);
        let mut learners = Vec::with_capacity(k);
        for l in 0..k {
            let lseed = learner_seed(seed, l);
            let prep = match &shared {
                None => Prep { predictor: None, prep_secs: 0.0, aip_ce: f64::NAN },
                Some(sh) => {
                    build_learner_predictor(rt, cfg, sh, &mut stores, l, seed, cfg.ppo.num_envs)?
                }
            };
            let prep_secs = prep.prep_secs;
            let aip_ce = prep.aip_ce;
            let train_env = make_train_env(cfg, prep.predictor);
            let eval_env = make_eval_env(cfg);
            stores.init_model(rt, l, policy_model, lseed)?;
            let lp = LearnerLoop::new(cfg, train_env.obs_dim(), lseed, prep_secs);
            learners.push(Learner { train_env, eval_env, lp, seed: lseed, prep_secs, aip_ce });
        }
        // One engine-side policy (scratch + artifacts shared across
        // learners); its initially-loaded store is a placeholder that the
        // per-turn swap parks in the MultiStore slot.
        let policy = Policy::new(rt.clone(), policy_model, cfg.ppo.num_envs)?;
        Ok(MultiLearnerRun { cfg: cfg.clone(), policy, policy_model, stores, learners })
    }

    pub fn num_learners(&self) -> usize {
        self.learners.len()
    }

    /// PPO iterations per learner (identical for all — one config).
    pub fn iterations(&self) -> usize {
        self.learners[0].lp.iterations()
    }

    /// Env steps one round consumes across all learners.
    pub fn steps_per_round(&self) -> usize {
        self.learners.len() * self.cfg.ppo.num_envs * self.cfg.ppo.rollout_len
    }

    /// Swap learner `l`'s parameters into the shared engine-side policy,
    /// run `f`, and swap them back out — also when `f` errors. The one
    /// place the checkout invariant lives.
    fn with_learner(
        &mut self,
        l: usize,
        f: impl FnOnce(&ExperimentConfig, &mut Learner, &mut Policy) -> Result<()>,
    ) -> Result<()> {
        let MultiLearnerRun { cfg, policy, policy_model, stores, learners } = self;
        let learner = &mut learners[l];
        stores.swap(l, policy_model, &mut policy.store)?;
        let r = f(cfg, learner, policy);
        stores.swap(l, policy_model, &mut policy.store)?;
        r
    }

    /// Reset every learner's env and record its t=0 curve point, in fixed
    /// learner order.
    pub fn start(&mut self) -> Result<()> {
        for l in 0..self.learners.len() {
            self.with_learner(l, |cfg, ln, policy| {
                ln.lp.start(cfg, ln.train_env.as_mut(), ln.eval_env.as_mut(), policy)
            })?;
        }
        Ok(())
    }

    /// One round-robin pass: the next PPO iteration for every learner, in
    /// fixed learner order, each with its own parameters swapped into the
    /// shared engine-side policy for the duration of its turn.
    pub fn advance_round(&mut self) -> Result<()> {
        for l in 0..self.learners.len() {
            self.with_learner(l, |cfg, ln, policy| {
                ln.lp.advance(cfg, ln.train_env.as_mut(), ln.eval_env.as_mut(), policy)
            })?;
        }
        Ok(())
    }

    /// Per-learner results + final policy stores, in learner order.
    pub fn finish(self) -> Result<MultiLearnerOutcome> {
        let MultiLearnerRun { cfg, policy_model, mut stores, learners, .. } = self;
        let mut results = Vec::with_capacity(learners.len());
        let mut policy_stores = Vec::with_capacity(learners.len());
        for (l, learner) in learners.into_iter().enumerate() {
            let out = learner.lp.finish();
            let final_eval = out.curve.last().map(|p| p.eval_mean).unwrap_or(f64::NAN);
            results.push(ConditionResult {
                condition: format!("{}-{}", cfg.simulator.name(), cfg.name),
                seed: learner.seed,
                curve: out.curve,
                prep_secs: learner.prep_secs,
                train_secs: out.train_secs,
                aip_ce: learner.aip_ce,
                final_eval,
            });
            policy_stores.push(stores.take(l, policy_model)?);
        }
        Ok(MultiLearnerOutcome { results, policy_stores })
    }
}

/// Train `cfg.num_learners` learners end to end (the multi-learner
/// counterpart of [`super::run_condition`]): shared collection,
/// per-learner AIP training, then round-robin PPO with interleaved GS
/// evaluations.
pub fn run_multi_condition(
    rt: &Rc<Runtime>,
    cfg: &ExperimentConfig,
    seed: u64,
) -> Result<MultiLearnerOutcome> {
    let mut run = MultiLearnerRun::build(rt, cfg, seed)?;
    run.start()?;
    for _ in 0..run.iterations() {
        run.advance_round()?;
    }
    let out = run.finish()?;
    for (l, r) in out.results.iter().enumerate() {
        log_info!(
            "[{}] learner {l} (seed {seed}): prep {:.2}s train {:.2}s aip_ce {:.4} final {:.4}",
            cfg.name,
            r.prep_secs,
            r.train_secs,
            r.aip_ce,
            r.final_eval
        );
    }
    Ok(out)
}
