//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`), compiles
//! them once on the CPU PJRT client, and executes them with model
//! parameters + caller data as positional literals.
//!
//! This module is the **only** place the `xla` crate is touched; everything
//! above it works with plain `&[f32]` slices. Python never runs here —
//! artifacts were lowered once at build time (`make artifacts`).

pub mod manifest;

pub use manifest::{ArtifactSpec, Binding, DType, Manifest, ModelSpec, TensorSpec};

use crate::nn::ParamStore;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A caller-supplied data argument.
#[derive(Debug, Clone, Copy)]
pub enum DataArg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

struct CompiledArtifact {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    /// Does the artifact write any parameters back (training artifact)?
    mutates_params: bool,
    /// Device-resident parameter buffers for forward-only artifacts,
    /// keyed by the owning store's (id, version). Uploading the weights
    /// once per version (instead of per call) is the main L3 perf lever —
    /// see EXPERIMENTS.md §Perf.
    param_cache: RefCell<Option<((u64, u64), Vec<xla::PjRtBuffer>)>>,
}

/// The runtime: one PJRT CPU client + a lazily-compiled artifact cache.
pub struct Runtime {
    pub manifest: Manifest,
    dir: PathBuf,
    client: xla::PjRtClient,
    compiled: RefCell<HashMap<String, Rc<CompiledArtifact>>>,
    /// Executions performed (diagnostics / perf accounting).
    calls: RefCell<u64>,
}

impl Runtime {
    /// Load the manifest from `dir` and connect the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let manifest = Manifest::load(dir.as_ref())?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            manifest,
            dir: dir.as_ref().to_path_buf(),
            client,
            compiled: RefCell::new(HashMap::new()),
            calls: RefCell::new(0),
        })
    }

    pub fn geom(&self, key: &str) -> Result<usize> {
        Ok(self.manifest.geom(key)? as usize)
    }

    pub fn call_count(&self) -> u64 {
        *self.calls.borrow()
    }

    /// Load a model's initial parameters (`<model>.params.bin`).
    pub fn load_store(&self, model: &str) -> Result<ParamStore> {
        let spec = self.manifest.model(model)?;
        ParamStore::load_bin(spec, self.dir.join(format!("{model}.params.bin")))
    }

    fn compile(&self, name: &str) -> Result<Rc<CompiledArtifact>> {
        if let Some(c) = self.compiled.borrow().get(name) {
            return Ok(c.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&spec.hlo_file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let mutates_params =
            spec.outputs.iter().any(|b| matches!(b, Binding::Param(_)));
        let c = Rc::new(CompiledArtifact {
            spec,
            exe,
            mutates_params,
            param_cache: RefCell::new(None),
        });
        self.compiled.borrow_mut().insert(name.to_string(), c.clone());
        Ok(c)
    }

    /// Pre-compile a set of artifacts (so first-step latency is paid at
    /// startup, not on the training hot path).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.compile(n)?;
        }
        Ok(())
    }

    /// Execute `name`. Parameter bindings are read from (and, for training
    /// artifacts, written back to) `store`; `data` supplies the data inputs
    /// in manifest order. Returns the data outputs in manifest order.
    ///
    /// Allocates one `Vec` per data output; hot paths (policy forward, AIP
    /// predict) use [`Runtime::call_into`] with reusable scratch instead.
    pub fn call(
        &self,
        name: &str,
        store: &mut ParamStore,
        data: &[DataArg<'_>],
    ) -> Result<Vec<Vec<f32>>> {
        let art = self.compile(name)?;
        let mut outs: Vec<Vec<f32>> =
            art.spec.data_outputs().map(|t| vec![0.0; t.numel()]).collect();
        {
            let mut refs: Vec<&mut [f32]> =
                outs.iter_mut().map(|v| v.as_mut_slice()).collect();
            self.call_into(name, store, data, &mut refs)?;
        }
        Ok(outs)
    }

    /// Execute `name`, writing each data output directly into the
    /// caller-provided scratch: `outs[k]` receives the k-th data output (in
    /// manifest order) and must be exactly its `numel()` long. This is the
    /// allocation-free variant of [`Runtime::call`] used on the per-step hot
    /// path — parameters stay device-resident, inputs are borrowed, and
    /// outputs land in reusable buffers.
    pub fn call_into(
        &self,
        name: &str,
        store: &mut ParamStore,
        data: &[DataArg<'_>],
        outs: &mut [&mut [f32]],
    ) -> Result<()> {
        let art = self.compile(name)?;
        anyhow::ensure!(
            store.model == art.spec.model,
            "artifact {name} expects model {}, got store for {}",
            art.spec.model,
            store.model
        );
        let model = self.manifest.model(&art.spec.model)?;

        let n_data_inputs = art.spec.data_inputs().count();
        anyhow::ensure!(
            data.len() == n_data_inputs,
            "artifact {name}: {} data args given, {} expected",
            data.len(),
            n_data_inputs
        );

        // Forward-only artifacts run on the buffer path: parameters stay
        // resident on the device and are re-uploaded only when the store
        // mutates. Training artifacts (param write-back) use the literal
        // path (the output tuple must come back to the host anyway).
        let result = if !art.mutates_params {
            // Refresh the resident parameter buffers if stale.
            {
                let mut cache = art.param_cache.borrow_mut();
                let key = store.cache_key();
                let stale = !matches!(&*cache, Some((k, _)) if *k == key);
                if stale {
                    let mut bufs = Vec::new();
                    for binding in &art.spec.inputs {
                        if let Binding::Param(pname) = binding {
                            let tspec = model.param(pname)?;
                            let values = store.get(pname)?;
                            bufs.push(self.client.buffer_from_host_buffer(
                                values,
                                &tspec.shape,
                                None,
                            )?);
                        }
                    }
                    *cache = Some((key, bufs));
                }
            }
            let cache = art.param_cache.borrow();
            let (_, param_bufs) = cache.as_ref().unwrap();
            // Upload data inputs and assemble positional args.
            let mut data_bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(data.len());
            let mut data_it = data.iter();
            for binding in &art.spec.inputs {
                if let Binding::Data(tspec) = binding {
                    let arg = data_it.next().unwrap();
                    data_bufs.push(buf_from_arg(&self.client, arg, tspec, name)?);
                }
            }
            let mut args: Vec<&xla::PjRtBuffer> =
                Vec::with_capacity(art.spec.inputs.len());
            let (mut pi, mut di) = (0usize, 0usize);
            for binding in &art.spec.inputs {
                match binding {
                    Binding::Param(_) => {
                        args.push(&param_bufs[pi]);
                        pi += 1;
                    }
                    Binding::Data(_) => {
                        args.push(&data_bufs[di]);
                        di += 1;
                    }
                }
            }
            art.exe.execute_b(&args).with_context(|| format!("executing {name}"))?
        } else {
            let mut literals: Vec<xla::Literal> = Vec::with_capacity(art.spec.inputs.len());
            let mut data_it = data.iter();
            for binding in &art.spec.inputs {
                match binding {
                    Binding::Param(pname) => {
                        let tspec = model.param(pname)?;
                        let values = store.get(pname)?;
                        literals.push(lit_f32(values, tspec)?);
                    }
                    Binding::Data(tspec) => {
                        let arg = data_it.next().unwrap();
                        literals.push(lit_from_arg(arg, tspec, name)?);
                    }
                }
            }
            art.exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing {name}"))?
        };
        *self.calls.borrow_mut() += 1;

        // Unpack the output tuple.
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {name}"))?;
        let parts = tuple.to_tuple().with_context(|| format!("untupling result of {name}"))?;
        anyhow::ensure!(
            parts.len() == art.spec.outputs.len(),
            "artifact {name}: {} outputs, manifest says {}",
            parts.len(),
            art.spec.outputs.len()
        );

        let n_data_outputs = art.spec.data_outputs().count();
        anyhow::ensure!(
            outs.len() == n_data_outputs,
            "artifact {name}: {} output buffers given, {} expected",
            outs.len(),
            n_data_outputs
        );
        let mut out_it = outs.iter_mut();
        for (part, binding) in parts.into_iter().zip(&art.spec.outputs) {
            match binding {
                Binding::Param(pname) => {
                    // Write back directly into the store tensor (single copy).
                    let dst = store.tensor_mut(pname)?;
                    anyhow::ensure!(
                        part.element_count() == dst.len(),
                        "{name}: writeback of {pname} has {} elements, expected {}",
                        part.element_count(),
                        dst.len()
                    );
                    part.copy_raw_to(dst)
                        .with_context(|| format!("{name}: writeback of {pname}"))?;
                }
                Binding::Data(tspec) => {
                    if tspec.dtype != DType::F32 {
                        bail!("artifact {name}: non-f32 data outputs unsupported");
                    }
                    let dst: &mut [f32] = out_it.next().unwrap();
                    anyhow::ensure!(
                        part.element_count() == tspec.numel() && dst.len() == tspec.numel(),
                        "{name}: output {} has {} elements, buffer {}, expected {}",
                        tspec.name,
                        part.element_count(),
                        dst.len(),
                        tspec.numel()
                    );
                    // Single copy straight into the caller's scratch.
                    part.copy_raw_to(dst)
                        .with_context(|| format!("{name}: output {}", tspec.name))?;
                }
            }
        }
        Ok(())
    }
}

fn lit_f32(values: &[f32], spec: &TensorSpec) -> Result<xla::Literal> {
    anyhow::ensure!(
        values.len() == spec.numel(),
        "tensor {}: {} values, expected {} {:?}",
        spec.name,
        values.len(),
        spec.numel(),
        spec.shape
    );
    // Single-copy literal creation (vec1 + reshape would copy twice).
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(values.as_ptr() as *const u8, values.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &spec.shape,
        bytes,
    )?)
}

fn lit_from_arg(arg: &DataArg<'_>, spec: &TensorSpec, artifact: &str) -> Result<xla::Literal> {
    match (arg, spec.dtype) {
        (DataArg::F32(v), DType::F32) => lit_f32(v, spec),
        (DataArg::I32(v), DType::I32) => {
            anyhow::ensure!(
                v.len() == spec.numel(),
                "tensor {}: {} values, expected {}",
                spec.name,
                v.len(),
                spec.numel()
            );
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            };
            Ok(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S32,
                &spec.shape,
                bytes,
            )?)
        }
        _ => bail!("artifact {artifact}: dtype mismatch for data input {}", spec.name),
    }
}

fn buf_from_arg(
    client: &xla::PjRtClient,
    arg: &DataArg<'_>,
    spec: &TensorSpec,
    artifact: &str,
) -> Result<xla::PjRtBuffer> {
    match (arg, spec.dtype) {
        (DataArg::F32(v), DType::F32) => {
            anyhow::ensure!(v.len() == spec.numel(), "tensor {}: wrong size", spec.name);
            Ok(client.buffer_from_host_buffer(v, &spec.shape, None)?)
        }
        (DataArg::I32(v), DType::I32) => {
            anyhow::ensure!(v.len() == spec.numel(), "tensor {}: wrong size", spec.name);
            Ok(client.buffer_from_host_buffer(v, &spec.shape, None)?)
        }
        _ => bail!("artifact {artifact}: dtype mismatch for data input {}", spec.name),
    }
}
