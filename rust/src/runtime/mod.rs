//! The execution runtime: a manifest (artifact call ABI) plus a pluggable
//! [`Backend`] that actually runs artifacts.
//!
//! Two backends exist:
//!
//! * [`pjrt`] — loads AOT artifacts (`artifacts/*.hlo.txt`), compiles them
//!   once on the PJRT CPU client, and executes them with model parameters +
//!   caller data as positional literals. Requires `make artifacts` and a
//!   real `xla` binding (the vendored crate is a host-side stub).
//! * [`native`] — a hand-rolled CPU engine that executes the same artifact
//!   set directly on [`ParamStore`] slices (`nn/kernels.rs`), against a
//!   [`Manifest`] synthesized in memory from config geometry. No artifacts
//!   directory, no Python, no copies: the whole training loop runs on any
//!   CPU. Its forward path is additionally exposed as `Sync` views
//!   (`native::PolicyView` / `FnnView` / `GruView` + per-worker
//!   `native::EngineScratch`), which is what lets the IALS fuse the AIP
//!   forward into the sim shards' own dispatch (`ials::IalsVecEnv`).
//!
//! Selection is per config: `[runtime] backend = "auto" | "native" |
//! "pjrt"`, where `auto` (the default) uses PJRT when the artifacts
//! directory exists and the native engine otherwise. Everything above this
//! module works with plain `&[f32]` slices and is backend-agnostic.
//!
//! For multi-learner runs, [`multistore`] hosts K independent per-learner
//! [`ParamStore`]s behind the same `Backend` API — one engine, K parameter
//! sets (the distributed-IALS runtime; see `coordinator::multi`).

pub mod checkpoint;
pub mod guard;
pub mod manifest;
pub mod multistore;
pub mod native;
mod pjrt;

pub use manifest::{
    ArtifactSpec, Binding, DType, Manifest, ModelSpec, SynthGeometry, TensorSpec,
};
pub use multistore::{learner_seed, MultiStore};

use crate::config::{BackendKind, ExperimentConfig};
use crate::core::shard::{effective_workers, ComputePool, WorkerPlan};
use crate::nn::ParamStore;
use anyhow::{bail, Result};
use std::cell::RefCell;
use std::path::{Path, PathBuf};

/// A caller-supplied data argument.
#[derive(Debug, Clone, Copy)]
pub enum DataArg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

/// An execution engine for manifest artifacts. Inputs/outputs are already
/// shape- and dtype-validated by [`Runtime::call_into`]; implementations
/// read parameters from (and, for training artifacts, write them back to)
/// the store and fill `outs` with the data outputs in manifest order.
pub trait Backend {
    /// Short name for logs/diagnostics ("pjrt" / "native").
    fn kind(&self) -> &'static str;

    /// Run one artifact.
    fn execute(
        &self,
        art: &ArtifactSpec,
        manifest: &Manifest,
        store: &mut ParamStore,
        data: &[DataArg<'_>],
        outs: &mut [&mut [f32]],
    ) -> Result<()>;

    /// Prepare an artifact ahead of the hot path (compile / allocate
    /// scratch) so first-call latency is paid at startup.
    fn prepare(&self, art: &ArtifactSpec, manifest: &Manifest) -> Result<()> {
        let _ = (art, manifest);
        Ok(())
    }
}

impl SynthGeometry {
    /// Derive the synthesized-manifest geometry from an experiment config
    /// (native mode compiles nothing, so batch shapes can follow the
    /// config instead of the config having to match `make artifacts`).
    pub fn from_config(cfg: &ExperimentConfig) -> SynthGeometry {
        SynthGeometry {
            rollout_b: cfg.ppo.num_envs,
            rollout_t: cfg.ppo.rollout_len,
            ppo_epochs: cfg.ppo.epochs,
            ppo_minibatch: cfg.ppo.minibatch,
            aip_batch: cfg.aip.batch,
            ..SynthGeometry::default()
        }
    }
}

/// The runtime: one manifest + one execution backend.
pub struct Runtime {
    pub manifest: Manifest,
    /// Artifact directory (PJRT mode); `None` for the in-memory native mode.
    dir: Option<PathBuf>,
    backend: Box<dyn Backend>,
    /// Executions performed (diagnostics / perf accounting).
    calls: RefCell<u64>,
}

impl Runtime {
    /// Load the manifest from `dir` and connect the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let manifest = Manifest::load(dir.as_ref())?;
        let backend = pjrt::PjrtBackend::new(dir.as_ref())?;
        Ok(Runtime {
            manifest,
            dir: Some(dir.as_ref().to_path_buf()),
            backend: Box::new(backend),
            calls: RefCell::new(0),
        })
    }

    /// Build a native-CPU runtime with a manifest synthesized from `geom`
    /// — no artifacts directory required. Serial NN execution
    /// (`nn_workers = 1`); see [`Runtime::native_parallel`].
    pub fn native(geom: &SynthGeometry) -> Runtime {
        Runtime {
            manifest: Manifest::synthesize(geom),
            dir: None,
            backend: Box::new(native::NativeBackend::new()),
            calls: RefCell::new(0),
        }
    }

    /// Native runtime whose engine fans batched forwards and training
    /// updates out over the process-shared compute pool (`nn_workers`
    /// worker threads; `0` = one per core, `1` = serial). At a fixed seed
    /// every `nn_workers` produces bitwise-identical results — the knob
    /// only changes wall-clock (see `runtime::native` docs).
    pub fn native_parallel(geom: &SynthGeometry, nn_workers: usize) -> Runtime {
        let nn = effective_workers(nn_workers);
        let pool = if nn > 1 { Some(ComputePool::shared(nn)) } else { None };
        Runtime {
            manifest: Manifest::synthesize(geom),
            dir: None,
            backend: Box::new(native::NativeBackend::with_pool(pool, nn)),
            calls: RefCell::new(0),
        }
    }

    /// Native runtime at the emitter's default geometry (exactly the
    /// artifact set `make artifacts` would produce).
    pub fn native_default() -> Runtime {
        Self::native(&SynthGeometry::default())
    }

    /// PJRT when `dir` holds a manifest, native otherwise — the `auto`
    /// backend policy (also used by tests, benches and examples so they
    /// run with or without compiled artifacts).
    pub fn load_or_native(dir: impl AsRef<Path>) -> Result<Runtime> {
        if dir.as_ref().join("manifest.txt").exists() {
            Self::load(dir)
        } else {
            Ok(Self::native_default())
        }
    }

    /// Select a backend per `[runtime] backend` and build the runtime with
    /// config-derived geometry. In native mode this also sizes the run's
    /// shared compute pool once, for the larger of `[ppo] num_workers` and
    /// `[runtime] nn_workers` (both resolved through [`WorkerPlan`]), so
    /// the sim and NN halves share one pool and never oversubscribe.
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Runtime> {
        match cfg.runtime.backend {
            BackendKind::Pjrt => Self::load(&cfg.artifacts_dir),
            BackendKind::Native => Ok(Self::native_from_config(cfg)),
            BackendKind::Auto => {
                if Path::new(&cfg.artifacts_dir).join("manifest.txt").exists() {
                    Self::load(&cfg.artifacts_dir)
                } else {
                    Ok(Self::native_from_config(cfg))
                }
            }
        }
    }

    fn native_from_config(cfg: &ExperimentConfig) -> Runtime {
        let plan = WorkerPlan::resolve(cfg.ppo.num_workers, cfg.runtime.nn_workers);
        // Create (or grow) the shared pool at the size both halves need,
        // even when the NN half stays serial — env construction then reuses
        // the same pool instead of making a second one.
        let pool = plan.shared_pool();
        let backend_pool = if plan.nn > 1 { pool } else { None };
        Runtime {
            manifest: Manifest::synthesize(&SynthGeometry::from_config(cfg)),
            dir: None,
            backend: Box::new(native::NativeBackend::with_pool(backend_pool, plan.nn)),
            calls: RefCell::new(0),
        }
    }

    /// Which engine is executing ("pjrt" / "native").
    pub fn backend_kind(&self) -> &'static str {
        self.backend.kind()
    }

    pub fn geom(&self, key: &str) -> Result<usize> {
        Ok(self.manifest.geom(key)? as usize)
    }

    pub fn call_count(&self) -> u64 {
        *self.calls.borrow()
    }

    /// Load a model's initial parameters: `<model>.params.bin` in PJRT
    /// mode, a deterministic in-memory Glorot init in native mode.
    pub fn load_store(&self, model: &str) -> Result<ParamStore> {
        let spec = self.manifest.model(model)?;
        match &self.dir {
            Some(dir) => ParamStore::load_bin(spec, dir.join(format!("{model}.params.bin"))),
            None => Ok(ParamStore::glorot(spec, native::init_seed(model))),
        }
    }

    /// Pre-compile / pre-allocate a set of artifacts (so first-step latency
    /// is paid at startup, not on the training hot path).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            let art = self.manifest.artifact(n)?;
            self.backend.prepare(art, &self.manifest)?;
        }
        Ok(())
    }

    /// Execute `name`. Parameter bindings are read from (and, for training
    /// artifacts, written back to) `store`; `data` supplies the data inputs
    /// in manifest order. Returns the data outputs in manifest order.
    ///
    /// Allocates one `Vec` per data output; hot paths (policy forward, AIP
    /// predict) use [`Runtime::call_into`] with reusable scratch instead.
    pub fn call(
        &self,
        name: &str,
        store: &mut ParamStore,
        data: &[DataArg<'_>],
    ) -> Result<Vec<Vec<f32>>> {
        let art = self.manifest.artifact(name)?;
        let mut outs: Vec<Vec<f32>> = art.data_outputs().map(|t| vec![0.0; t.numel()]).collect();
        {
            let mut refs: Vec<&mut [f32]> = outs.iter_mut().map(|v| v.as_mut_slice()).collect();
            self.call_into(name, store, data, &mut refs)?;
        }
        Ok(outs)
    }

    /// Execute `name`, writing each data output directly into the
    /// caller-provided scratch: `outs[k]` receives the k-th data output (in
    /// manifest order) and must be exactly its `numel()` long. This is the
    /// allocation-free variant of [`Runtime::call`] used on the per-step
    /// hot path — inputs are borrowed, outputs land in reusable buffers,
    /// and every shape/dtype is validated against the manifest before the
    /// backend runs.
    pub fn call_into(
        &self,
        name: &str,
        store: &mut ParamStore,
        data: &[DataArg<'_>],
        outs: &mut [&mut [f32]],
    ) -> Result<()> {
        let art = self.manifest.artifact(name)?;
        anyhow::ensure!(
            store.model == art.model,
            "artifact {name} expects model {}, got store for {}",
            art.model,
            store.model
        );

        let n_data_inputs = art.data_inputs().count();
        anyhow::ensure!(
            data.len() == n_data_inputs,
            "artifact {name}: {} data args given, {} expected",
            data.len(),
            n_data_inputs
        );
        for (arg, spec) in data.iter().zip(art.data_inputs()) {
            let given = match (arg, spec.dtype) {
                (DataArg::F32(v), DType::F32) => v.len(),
                (DataArg::I32(v), DType::I32) => v.len(),
                _ => bail!("artifact {name}: dtype mismatch for data input {}", spec.name),
            };
            anyhow::ensure!(
                given == spec.numel(),
                "artifact {name}: input {} has {} values, expected {} {:?}",
                spec.name,
                given,
                spec.numel(),
                spec.shape
            );
        }

        let n_data_outputs = art.data_outputs().count();
        anyhow::ensure!(
            outs.len() == n_data_outputs,
            "artifact {name}: {} output buffers given, {} expected",
            outs.len(),
            n_data_outputs
        );
        for (out, spec) in outs.iter().zip(art.data_outputs()) {
            if spec.dtype != DType::F32 {
                bail!("artifact {name}: non-f32 data outputs unsupported");
            }
            anyhow::ensure!(
                out.len() == spec.numel(),
                "artifact {name}: output {} buffer has {} values, expected {}",
                spec.name,
                out.len(),
                spec.numel()
            );
        }

        self.backend.execute(art, &self.manifest, store, data, outs)?;
        *self.calls.borrow_mut() += 1;
        Ok(())
    }
}
