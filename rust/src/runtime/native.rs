//! Native CPU execution backend: runs every artifact of the synthesized
//! manifest directly on [`ParamStore`] slices with the hand-rolled kernels
//! in [`crate::nn::kernels`] — no HLO, no PJRT, no `artifacts/` directory.
//!
//! Each artifact is classified once (from its model's parameter names and
//! its data bindings) into an op with preallocated scratch; after that
//! first call, the forward ops (`*_fwd_*`, `*_step_*`) perform **zero heap
//! allocations and zero redundant copies** — inputs are borrowed from the
//! caller, intermediates live in reusable scratch, and outputs are written
//! straight into the caller's buffers (`rust/tests/native_alloc.rs` pins
//! this with a counting allocator). Training ops reuse their scratch too
//! and mutate the store through in-place Adam updates
//! ([`ParamStore::adam_slots_mut`]).
//!
//! The math mirrors `python/compile/model.py` exactly (same losses, same
//! clipping, same Adam) so learning-dynamics tests hold on either backend.

#![allow(clippy::too_many_arguments)]

use super::manifest::{ArtifactSpec, Binding, Manifest, ModelSpec};
use super::{Backend, DataArg};
use crate::nn::kernels::{self, Act};
use crate::nn::ParamStore;
use crate::Result;
use anyhow::{bail, Context};
use std::cell::RefCell;
use std::collections::HashMap;

/// Deterministic per-model seed for in-memory parameter initialization
/// (FNV-1a over the model name; the native stand-in for `params.bin`).
pub fn init_seed(model: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in model.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The native CPU engine: one classified-op cache, scratch reused across
/// calls.
pub struct NativeBackend {
    ops: RefCell<HashMap<String, Op>>,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend { ops: RefCell::new(HashMap::new()) }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn prepare(&self, art: &ArtifactSpec, manifest: &Manifest) -> Result<()> {
        let mut ops = self.ops.borrow_mut();
        if !ops.contains_key(&art.name) {
            let op = Op::build(art, manifest)
                .with_context(|| format!("classifying artifact {}", art.name))?;
            ops.insert(art.name.clone(), op);
        }
        Ok(())
    }

    fn execute(
        &self,
        art: &ArtifactSpec,
        manifest: &Manifest,
        store: &mut ParamStore,
        data: &[DataArg<'_>],
        outs: &mut [&mut [f32]],
    ) -> Result<()> {
        self.prepare(art, manifest)?;
        let mut ops = self.ops.borrow_mut();
        let op = ops.get_mut(&art.name).unwrap();
        op.run(store, data, outs)
            .with_context(|| format!("native execution of {}", art.name))
    }
}

// ---------------------------------------------------------------------------
// Argument helpers
// ---------------------------------------------------------------------------

fn f32_arg<'a>(data: &[DataArg<'a>], idx: usize, what: &str) -> Result<&'a [f32]> {
    match data.get(idx) {
        Some(&DataArg::F32(v)) => Ok(v),
        _ => bail!("data arg {idx} ({what}) must be f32"),
    }
}

fn i32_arg<'a>(data: &[DataArg<'a>], idx: usize, what: &str) -> Result<&'a [i32]> {
    match data.get(idx) {
        Some(&DataArg::I32(v)) => Ok(v),
        _ => bail!("data arg {idx} ({what}) must be i32"),
    }
}

fn scalar(data: &[DataArg<'_>], idx: usize, what: &str) -> Result<f32> {
    Ok(f32_arg(data, idx, what)?[0])
}

fn data_shape<'m>(art: &'m ArtifactSpec, name: &str) -> Result<&'m [usize]> {
    art.data_inputs()
        .find(|t| t.name == name)
        .map(|t| t.shape.as_slice())
        .with_context(|| format!("artifact {} has no data input '{name}'", art.name))
}

/// In-place Adam over `(param, grad)` pairs: bumps `adam_t`, then updates
/// `m.*` / `v.*` / the parameter in one pass each (matching `adam_step` in
/// `python/compile/model.py`).
fn adam_apply(store: &mut ParamStore, lr: f32, pairs: &[(&str, &[f32])]) -> Result<()> {
    let t_new = {
        let t = store.tensor_mut("adam_t")?;
        t[0] += 1.0;
        t[0]
    };
    let bc1 = 1.0 - kernels::ADAM_B1.powf(t_new);
    let bc2 = 1.0 - kernels::ADAM_B2.powf(t_new);
    for (name, g) in pairs {
        let (p, m, v) = store.adam_slots_mut(name)?;
        kernels::adam_tensor(p, m, v, g, lr, bc1, bc2);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Op classification
// ---------------------------------------------------------------------------

enum Op {
    PolicyFwd(PolicyFwd),
    PolicyUpdate(PolicyUpdate),
    PolicyUpdateFused(PolicyUpdateFused),
    FnnFwd(FnnFwd),
    FnnUpdate(FnnUpdate),
    GruStep(GruStep),
    GruUpdate(GruUpdate),
}

impl Op {
    fn build(art: &ArtifactSpec, manifest: &Manifest) -> Result<Op> {
        let model = manifest.model(&art.model)?;
        let trains = art.outputs.iter().any(|b| matches!(b, Binding::Param(_)));
        let is_policy = model.params.iter().any(|p| p.name == "w_pi");
        let is_gru = model.params.iter().any(|p| p.name == "w_x");
        Ok(if is_policy {
            if !trains {
                Op::PolicyFwd(PolicyFwd::new(art, model)?)
            } else if art.data_inputs().any(|t| t.name == "perm") {
                Op::PolicyUpdateFused(PolicyUpdateFused::new(art, model, manifest)?)
            } else {
                Op::PolicyUpdate(PolicyUpdate::new(art, model)?)
            }
        } else if is_gru {
            if trains {
                Op::GruUpdate(GruUpdate::new(art, model)?)
            } else {
                Op::GruStep(GruStep::new(art, model)?)
            }
        } else if trains {
            Op::FnnUpdate(FnnUpdate::new(art, model)?)
        } else {
            Op::FnnFwd(FnnFwd::new(art, model)?)
        })
    }

    fn run(
        &mut self,
        store: &mut ParamStore,
        data: &[DataArg<'_>],
        outs: &mut [&mut [f32]],
    ) -> Result<()> {
        match self {
            Op::PolicyFwd(o) => {
                let obs = f32_arg(data, 0, "obs")?;
                let (lo, rest) = outs.split_at_mut(1);
                o.run(store, obs, &mut *lo[0], &mut *rest[0])
            }
            Op::PolicyUpdate(o) => {
                let hp = Hyper::parse(data)?;
                let obs = f32_arg(data, 5, "obs")?;
                let actions = i32_arg(data, 6, "actions")?;
                let adv = f32_arg(data, 7, "advantages")?;
                let ret = f32_arg(data, 8, "returns")?;
                let lp = f32_arg(data, 9, "old_logp")?;
                let stats = o.run_minibatch(store, &hp, obs, actions, adv, ret, lp)?;
                outs[0].copy_from_slice(&stats);
                Ok(())
            }
            Op::PolicyUpdateFused(o) => {
                let stats = o.run(store, data)?;
                outs[0].copy_from_slice(&stats);
                Ok(())
            }
            Op::FnnFwd(o) => {
                let d = f32_arg(data, 0, "d")?;
                o.run(store, d, &mut *outs[0])
            }
            Op::FnnUpdate(o) => {
                let lr = scalar(data, 0, "lr")?;
                let d = f32_arg(data, 1, "d")?;
                let targets = f32_arg(data, 2, "targets")?;
                let loss = o.run(store, lr, d, targets)?;
                outs[0][0] = loss;
                Ok(())
            }
            Op::GruStep(o) => {
                let h = f32_arg(data, 0, "h")?;
                let d = f32_arg(data, 1, "d")?;
                let (probs, rest) = outs.split_at_mut(1);
                o.run(store, h, d, &mut *probs[0], &mut *rest[0])
            }
            Op::GruUpdate(o) => {
                let lr = scalar(data, 0, "lr")?;
                let seqs = f32_arg(data, 1, "seqs")?;
                let targets = f32_arg(data, 2, "targets")?;
                let loss = o.run(store, lr, seqs, targets)?;
                outs[0][0] = loss;
                Ok(())
            }
        }
    }
}

/// PPO hyperparameters handed over as shape-(1,) scalars.
struct Hyper {
    lr: f32,
    clip: f32,
    vf: f32,
    ent: f32,
    mgn: f32,
}

impl Hyper {
    fn parse(data: &[DataArg<'_>]) -> Result<Hyper> {
        Ok(Hyper {
            lr: scalar(data, 0, "lr")?,
            clip: scalar(data, 1, "clip")?,
            vf: scalar(data, 2, "vf_coef")?,
            ent: scalar(data, 3, "ent_coef")?,
            mgn: scalar(data, 4, "max_grad_norm")?,
        })
    }
}

// ---------------------------------------------------------------------------
// Policy MLP (tanh-tanh trunk, logits + value heads)
// ---------------------------------------------------------------------------

fn policy_dims(model: &ModelSpec) -> Result<(usize, usize, usize)> {
    let w1 = model.param("w1")?;
    let act = model.param("w_pi")?.shape[1];
    Ok((w1.shape[0], w1.shape[1], act))
}

struct PolicyFwd {
    b: usize,
    obs_dim: usize,
    hid: usize,
    act_dim: usize,
    h1: Vec<f32>,
    h2: Vec<f32>,
}

impl PolicyFwd {
    fn new(art: &ArtifactSpec, model: &ModelSpec) -> Result<PolicyFwd> {
        let (obs_dim, hid, act_dim) = policy_dims(model)?;
        let b = data_shape(art, "obs")?[0];
        Ok(PolicyFwd {
            b,
            obs_dim,
            hid,
            act_dim,
            h1: vec![0.0; b * hid],
            h2: vec![0.0; b * hid],
        })
    }

    fn run(
        &mut self,
        store: &ParamStore,
        obs: &[f32],
        logits: &mut [f32],
        value: &mut [f32],
    ) -> Result<()> {
        let (b, od, h, a) = (self.b, self.obs_dim, self.hid, self.act_dim);
        let w1 = store.get("w1")?;
        let b1 = store.get("b1")?;
        let w2 = store.get("w2")?;
        let b2 = store.get("b2")?;
        let w_pi = store.get("w_pi")?;
        let b_pi = store.get("b_pi")?;
        let w_v = store.get("w_v")?;
        let b_v = store.get("b_v")?;
        kernels::linear_into(obs, w1, Some(b1), &mut self.h1, b, od, h, Act::Tanh);
        kernels::linear_into(&self.h1, w2, Some(b2), &mut self.h2, b, h, h, Act::Tanh);
        kernels::linear_into(&self.h2, w_pi, Some(b_pi), logits, b, h, a, Act::None);
        kernels::linear_into(&self.h2, w_v, Some(b_v), value, b, h, 1, Act::None);
        Ok(())
    }
}

/// Per-tensor policy gradients (same order as the model spec).
struct PolicyGrads {
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
    w_pi: Vec<f32>,
    b_pi: Vec<f32>,
    w_v: Vec<f32>,
    b_v: Vec<f32>,
}

impl PolicyGrads {
    fn new(obs_dim: usize, hid: usize, act_dim: usize) -> PolicyGrads {
        PolicyGrads {
            w1: vec![0.0; obs_dim * hid],
            b1: vec![0.0; hid],
            w2: vec![0.0; hid * hid],
            b2: vec![0.0; hid],
            w_pi: vec![0.0; hid * act_dim],
            b_pi: vec![0.0; act_dim],
            w_v: vec![0.0; hid],
            b_v: vec![0.0; 1],
        }
    }

    fn zero(&mut self) {
        for g in [
            &mut self.w1,
            &mut self.b1,
            &mut self.w2,
            &mut self.b2,
            &mut self.w_pi,
            &mut self.b_pi,
            &mut self.w_v,
            &mut self.b_v,
        ] {
            g.fill(0.0);
        }
    }

    fn scale(&mut self, s: f32) {
        for g in [
            &mut self.w1,
            &mut self.b1,
            &mut self.w2,
            &mut self.b2,
            &mut self.w_pi,
            &mut self.b_pi,
            &mut self.w_v,
            &mut self.b_v,
        ] {
            for x in g.iter_mut() {
                *x *= s;
            }
        }
    }

    fn norm(&self) -> f32 {
        kernels::global_norm(&[
            &self.w1[..],
            &self.b1[..],
            &self.w2[..],
            &self.b2[..],
            &self.w_pi[..],
            &self.b_pi[..],
            &self.w_v[..],
            &self.b_v[..],
        ])
    }
}

struct PolicyUpdate {
    mb: usize,
    obs_dim: usize,
    hid: usize,
    act_dim: usize,
    h1: Vec<f32>,
    h2: Vec<f32>,
    logits: Vec<f32>,
    logp: Vec<f32>,
    value: Vec<f32>,
    g_logits: Vec<f32>,
    g_value: Vec<f32>,
    g_ha: Vec<f32>,
    g_hb: Vec<f32>,
    grads: PolicyGrads,
}

impl PolicyUpdate {
    fn new(art: &ArtifactSpec, model: &ModelSpec) -> Result<PolicyUpdate> {
        let (obs_dim, hid, act_dim) = policy_dims(model)?;
        let mb = data_shape(art, "obs")?[0];
        Ok(Self::with_minibatch(mb, obs_dim, hid, act_dim))
    }

    fn with_minibatch(mb: usize, obs_dim: usize, hid: usize, act_dim: usize) -> PolicyUpdate {
        PolicyUpdate {
            mb,
            obs_dim,
            hid,
            act_dim,
            h1: vec![0.0; mb * hid],
            h2: vec![0.0; mb * hid],
            logits: vec![0.0; mb * act_dim],
            logp: vec![0.0; mb * act_dim],
            value: vec![0.0; mb],
            g_logits: vec![0.0; mb * act_dim],
            g_value: vec![0.0; mb],
            g_ha: vec![0.0; mb * hid],
            g_hb: vec![0.0; mb * hid],
            grads: PolicyGrads::new(obs_dim, hid, act_dim),
        }
    }

    /// One clipped-surrogate PPO minibatch step — forward, loss, backward,
    /// grad-norm clip, Adam (`ppo_update` in `model.py`). Returns
    /// `[total, pg_loss, v_loss, entropy, approx_kl]`.
    fn run_minibatch(
        &mut self,
        store: &mut ParamStore,
        hp: &Hyper,
        obs: &[f32],
        actions: &[i32],
        adv: &[f32],
        ret: &[f32],
        old_logp: &[f32],
    ) -> Result<[f32; 5]> {
        let (mb, od, h, a) = (self.mb, self.obs_dim, self.hid, self.act_dim);
        let inv_mb = 1.0 / mb as f32;
        let stats;
        {
            let w1 = store.get("w1")?;
            let b1 = store.get("b1")?;
            let w2 = store.get("w2")?;
            let b2 = store.get("b2")?;
            let w_pi = store.get("w_pi")?;
            let b_pi = store.get("b_pi")?;
            let w_v = store.get("w_v")?;
            let b_v = store.get("b_v")?;

            kernels::linear_into(obs, w1, Some(b1), &mut self.h1, mb, od, h, Act::Tanh);
            kernels::linear_into(&self.h1, w2, Some(b2), &mut self.h2, mb, h, h, Act::Tanh);
            kernels::linear_into(&self.h2, w_pi, Some(b_pi), &mut self.logits, mb, h, a, Act::None);
            kernels::linear_into(&self.h2, w_v, Some(b_v), &mut self.value, mb, h, 1, Act::None);

            // Loss terms + dL/dlogits, dL/dvalue per row.
            let mut pg_sum = 0.0f64;
            let mut v_sum = 0.0f64;
            let mut ent_sum = 0.0f64;
            let mut kl_sum = 0.0f64;
            for r in 0..mb {
                let lrow = &self.logits[r * a..(r + 1) * a];
                let lprow = &mut self.logp[r * a..(r + 1) * a];
                kernels::log_softmax_row(lrow, lprow);
                let act_i = actions[r] as usize;
                anyhow::ensure!(act_i < a, "action {act_i} out of range (act_dim {a})");
                let lpa = lprow[act_i];
                let ratio = (lpa - old_logp[r]).exp();
                let s1 = ratio * adv[r];
                let s2 = ratio.clamp(1.0 - hp.clip, 1.0 + hp.clip) * adv[r];
                // Gradient flows through the unclipped surrogate iff it is
                // the active min (jnp.minimum semantics; the clipped branch
                // is constant in logp).
                let (min_s, gpg) =
                    if s1 <= s2 { (s1, -adv[r] * ratio * inv_mb) } else { (s2, 0.0) };
                pg_sum += min_s as f64;
                let mut h_row = 0.0f32;
                for &lp in lprow.iter() {
                    h_row -= lp.exp() * lp;
                }
                ent_sum += h_row as f64;
                kl_sum += (old_logp[r] - lpa) as f64;
                let grow = &mut self.g_logits[r * a..(r + 1) * a];
                for (j, (gj, &lp)) in grow.iter_mut().zip(lprow.iter()).enumerate() {
                    let p = lp.exp();
                    let onehot = if j == act_i { 1.0 } else { 0.0 };
                    // d(-ent_coef * H)/dlogit = ent_coef * p * (logp + H)
                    *gj = gpg * (onehot - p) + hp.ent * inv_mb * p * (lp + h_row);
                }
                let vdiff = self.value[r] - ret[r];
                v_sum += (vdiff as f64) * (vdiff as f64);
                self.g_value[r] = hp.vf * 2.0 * vdiff * inv_mb;
            }
            let pg_loss = -(pg_sum as f32) * inv_mb;
            let v_loss = (v_sum as f32) * inv_mb;
            let entropy = (ent_sum as f32) * inv_mb;
            let approx_kl = (kl_sum as f32) * inv_mb;
            let total = pg_loss + hp.vf * v_loss - hp.ent * entropy;
            stats = [total, pg_loss, v_loss, entropy, approx_kl];

            // Backward.
            let g = &mut self.grads;
            g.zero();
            kernels::matmul_at_b_acc(&self.h2, &self.g_logits, &mut g.w_pi, mb, h, a);
            kernels::colsum_acc(&self.g_logits, &mut g.b_pi, a);
            kernels::matmul_at_b_acc(&self.h2, &self.g_value, &mut g.w_v, mb, h, 1);
            g.b_v[0] = self.g_value.iter().sum();
            kernels::matmul_bt_into(&self.g_logits, w_pi, &mut self.g_ha, mb, a, h);
            for (r, &gv) in self.g_value.iter().enumerate() {
                kernels::axpy(&mut self.g_ha[r * h..(r + 1) * h], w_v, gv);
            }
            for (gz, &hv) in self.g_ha.iter_mut().zip(&self.h2) {
                *gz *= 1.0 - hv * hv;
            }
            kernels::matmul_at_b_acc(&self.h1, &self.g_ha, &mut g.w2, mb, h, h);
            kernels::colsum_acc(&self.g_ha, &mut g.b2, h);
            kernels::matmul_bt_into(&self.g_ha, w2, &mut self.g_hb, mb, h, h);
            for (gz, &hv) in self.g_hb.iter_mut().zip(&self.h1) {
                *gz *= 1.0 - hv * hv;
            }
            kernels::matmul_at_b_acc(obs, &self.g_hb, &mut g.w1, mb, od, h);
            kernels::colsum_acc(&self.g_hb, &mut g.b1, h);
        }

        // Global grad-norm clip, then Adam (clip_global_norm + adam_step).
        let gn = self.grads.norm();
        self.grads.scale((hp.mgn / (gn + 1e-8)).min(1.0));
        let g = &self.grads;
        adam_apply(
            store,
            hp.lr,
            &[
                ("w1", g.w1.as_slice()),
                ("b1", g.b1.as_slice()),
                ("w2", g.w2.as_slice()),
                ("b2", g.b2.as_slice()),
                ("w_pi", g.w_pi.as_slice()),
                ("b_pi", g.b_pi.as_slice()),
                ("w_v", g.w_v.as_slice()),
                ("b_v", g.b_v.as_slice()),
            ],
        )?;
        Ok(stats)
    }
}

/// The whole-phase PPO update (`ppo_update_fused`): all epochs and
/// minibatches of one iteration in a single call, gathering rows by the
/// caller-supplied per-epoch permutation.
struct PolicyUpdateFused {
    epochs: usize,
    n: usize,
    core: PolicyUpdate,
    mb_obs: Vec<f32>,
    mb_act: Vec<i32>,
    mb_adv: Vec<f32>,
    mb_ret: Vec<f32>,
    mb_lp: Vec<f32>,
}

impl PolicyUpdateFused {
    fn new(art: &ArtifactSpec, model: &ModelSpec, manifest: &Manifest) -> Result<PolicyUpdateFused> {
        let (obs_dim, hid, act_dim) = policy_dims(model)?;
        let perm = data_shape(art, "perm")?;
        let (epochs, n) = (perm[0], perm[1]);
        // Minibatch width comes from the manifest geometry (the fused op
        // scans the same chunks the per-minibatch artifact would see).
        let mut mb = manifest.geom("ppo_minibatch").unwrap_or(n as i64) as usize;
        if mb == 0 || n % mb != 0 {
            mb = n;
        }
        Ok(PolicyUpdateFused {
            epochs,
            n,
            core: PolicyUpdate::with_minibatch(mb, obs_dim, hid, act_dim),
            mb_obs: vec![0.0; mb * obs_dim],
            mb_act: vec![0; mb],
            mb_adv: vec![0.0; mb],
            mb_ret: vec![0.0; mb],
            mb_lp: vec![0.0; mb],
        })
    }

    fn run(&mut self, store: &mut ParamStore, data: &[DataArg<'_>]) -> Result<[f32; 5]> {
        let hp = Hyper::parse(data)?;
        let perm = i32_arg(data, 5, "perm")?;
        let obs = f32_arg(data, 6, "obs")?;
        let actions = i32_arg(data, 7, "actions")?;
        let adv = f32_arg(data, 8, "advantages")?;
        let ret = f32_arg(data, 9, "returns")?;
        let old_logp = f32_arg(data, 10, "old_logp")?;
        let (n, mb, od) = (self.n, self.core.mb, self.core.obs_dim);
        let mut agg = [0.0f64; 5];
        let mut updates = 0usize;
        for e in 0..self.epochs {
            let perm_e = &perm[e * n..(e + 1) * n];
            for chunk in perm_e.chunks_exact(mb) {
                for (row, &src) in chunk.iter().enumerate() {
                    let s = src as usize;
                    anyhow::ensure!(s < n, "perm index {s} out of range (n {n})");
                    self.mb_obs[row * od..(row + 1) * od]
                        .copy_from_slice(&obs[s * od..(s + 1) * od]);
                    self.mb_act[row] = actions[s];
                    self.mb_adv[row] = adv[s];
                    self.mb_ret[row] = ret[s];
                    self.mb_lp[row] = old_logp[s];
                }
                let stats = self.core.run_minibatch(
                    store,
                    &hp,
                    &self.mb_obs,
                    &self.mb_act,
                    &self.mb_adv,
                    &self.mb_ret,
                    &self.mb_lp,
                )?;
                for (acc, s) in agg.iter_mut().zip(stats) {
                    *acc += s as f64;
                }
                updates += 1;
            }
        }
        let d = updates.max(1) as f64;
        Ok([
            (agg[0] / d) as f32,
            (agg[1] / d) as f32,
            (agg[2] / d) as f32,
            (agg[3] / d) as f32,
            (agg[4] / d) as f32,
        ])
    }
}

// ---------------------------------------------------------------------------
// FNN influence predictor (tanh hidden, sigmoid head)
// ---------------------------------------------------------------------------

fn fnn_dims(model: &ModelSpec) -> Result<(usize, usize, usize)> {
    let w1 = model.param("w1")?;
    let u = model.param("w2")?.shape[1];
    Ok((w1.shape[0], w1.shape[1], u))
}

struct FnnFwd {
    b: usize,
    d_dim: usize,
    hid: usize,
    u_dim: usize,
    h1: Vec<f32>,
}

impl FnnFwd {
    fn new(art: &ArtifactSpec, model: &ModelSpec) -> Result<FnnFwd> {
        let (d_dim, hid, u_dim) = fnn_dims(model)?;
        let b = data_shape(art, "d")?[0];
        Ok(FnnFwd { b, d_dim, hid, u_dim, h1: vec![0.0; b * hid] })
    }

    fn run(&mut self, store: &ParamStore, d: &[f32], probs: &mut [f32]) -> Result<()> {
        let (b, dd, h, u) = (self.b, self.d_dim, self.hid, self.u_dim);
        let w1 = store.get("w1")?;
        let b1 = store.get("b1")?;
        let w2 = store.get("w2")?;
        let b2 = store.get("b2")?;
        kernels::linear_into(d, w1, Some(b1), &mut self.h1, b, dd, h, Act::Tanh);
        kernels::linear_into(&self.h1, w2, Some(b2), probs, b, h, u, Act::Sigmoid);
        Ok(())
    }
}

struct FnnUpdate {
    mb: usize,
    d_dim: usize,
    hid: usize,
    u_dim: usize,
    h1: Vec<f32>,
    logits: Vec<f32>,
    g_l: Vec<f32>,
    g_h: Vec<f32>,
    gw1: Vec<f32>,
    gb1: Vec<f32>,
    gw2: Vec<f32>,
    gb2: Vec<f32>,
}

impl FnnUpdate {
    fn new(art: &ArtifactSpec, model: &ModelSpec) -> Result<FnnUpdate> {
        let (d_dim, hid, u_dim) = fnn_dims(model)?;
        let mb = data_shape(art, "d")?[0];
        Ok(FnnUpdate {
            mb,
            d_dim,
            hid,
            u_dim,
            h1: vec![0.0; mb * hid],
            logits: vec![0.0; mb * u_dim],
            g_l: vec![0.0; mb * u_dim],
            g_h: vec![0.0; mb * hid],
            gw1: vec![0.0; d_dim * hid],
            gb1: vec![0.0; hid],
            gw2: vec![0.0; hid * u_dim],
            gb2: vec![0.0; u_dim],
        })
    }

    /// One Adam step of stable BCE-with-logits (`aip_fnn_update`).
    fn run(&mut self, store: &mut ParamStore, lr: f32, d: &[f32], targets: &[f32]) -> Result<f32> {
        let (mb, dd, h, u) = (self.mb, self.d_dim, self.hid, self.u_dim);
        let inv = 1.0 / (mb * u) as f32;
        let loss;
        {
            let w1 = store.get("w1")?;
            let b1 = store.get("b1")?;
            let w2 = store.get("w2")?;
            let b2 = store.get("b2")?;
            kernels::linear_into(d, w1, Some(b1), &mut self.h1, mb, dd, h, Act::Tanh);
            kernels::linear_into(&self.h1, w2, Some(b2), &mut self.logits, mb, h, u, Act::None);
            let mut loss_sum = 0.0f64;
            for ((gl, &l), &y) in self.g_l.iter_mut().zip(&self.logits).zip(targets) {
                loss_sum += kernels::bce_with_logits_elem(l, y) as f64;
                *gl = (kernels::sigmoid(l) - y) * inv;
            }
            loss = (loss_sum as f32) * inv;
            self.gw1.fill(0.0);
            self.gb1.fill(0.0);
            self.gw2.fill(0.0);
            self.gb2.fill(0.0);
            kernels::matmul_at_b_acc(&self.h1, &self.g_l, &mut self.gw2, mb, h, u);
            kernels::colsum_acc(&self.g_l, &mut self.gb2, u);
            kernels::matmul_bt_into(&self.g_l, w2, &mut self.g_h, mb, u, h);
            for (gz, &hv) in self.g_h.iter_mut().zip(&self.h1) {
                *gz *= 1.0 - hv * hv;
            }
            kernels::matmul_at_b_acc(d, &self.g_h, &mut self.gw1, mb, dd, h);
            kernels::colsum_acc(&self.g_h, &mut self.gb1, h);
        }
        adam_apply(
            store,
            lr,
            &[
                ("w1", self.gw1.as_slice()),
                ("b1", self.gb1.as_slice()),
                ("w2", self.gw2.as_slice()),
                ("b2", self.gb2.as_slice()),
            ],
        )?;
        Ok(loss)
    }
}

// ---------------------------------------------------------------------------
// GRU influence predictor (fused z|r|n gates, sigmoid head)
// ---------------------------------------------------------------------------

fn gru_dims(model: &ModelSpec) -> Result<(usize, usize, usize)> {
    let w_x = model.param("w_x")?;
    let hid = model.param("w_h")?.shape[0];
    let u = model.param("w_o")?.shape[1];
    Ok((w_x.shape[0], hid, u))
}

struct GruStep {
    b: usize,
    d_dim: usize,
    hid: usize,
    u_dim: usize,
    gx: Vec<f32>,
    gh: Vec<f32>,
}

impl GruStep {
    fn new(art: &ArtifactSpec, model: &ModelSpec) -> Result<GruStep> {
        let (d_dim, hid, u_dim) = gru_dims(model)?;
        let b = data_shape(art, "d")?[0];
        Ok(GruStep {
            b,
            d_dim,
            hid,
            u_dim,
            gx: vec![0.0; b * 3 * hid],
            gh: vec![0.0; b * 3 * hid],
        })
    }

    fn run(
        &mut self,
        store: &ParamStore,
        h: &[f32],
        d: &[f32],
        probs: &mut [f32],
        h_new: &mut [f32],
    ) -> Result<()> {
        let (b, dd, hid, u) = (self.b, self.d_dim, self.hid, self.u_dim);
        let w_x = store.get("w_x")?;
        let w_h = store.get("w_h")?;
        let b_g = store.get("b_g")?;
        let w_o = store.get("w_o")?;
        let b_o = store.get("b_o")?;
        kernels::gru_cell_into(d, h, w_x, w_h, b_g, h_new, &mut self.gx, &mut self.gh, b, dd, hid);
        kernels::linear_into(h_new, w_o, Some(b_o), probs, b, hid, u, Act::Sigmoid);
        Ok(())
    }
}

struct GruUpdate {
    b: usize,
    t: usize,
    d_dim: usize,
    hid: usize,
    u_dim: usize,
    /// Hidden states `[T+1, B, H]` (slot 0 = zeros).
    h: Vec<f32>,
    /// Per-step gate activations `[T, B, H]` each.
    z: Vec<f32>,
    r: Vec<f32>,
    n_: Vec<f32>,
    /// Recurrent candidate pre-activation `(h_t @ w_h)` n-block `[T, B, H]`.
    ghn: Vec<f32>,
    /// Output-head logits `[T, B, U]`.
    logits: Vec<f32>,
    /// Time-major gather of the `[B, T, D]` input window.
    xt: Vec<f32>,
    gx: Vec<f32>,
    gh: Vec<f32>,
    g_l: Vec<f32>,
    dh: Vec<f32>,
    carry: Vec<f32>,
    gw_x: Vec<f32>,
    gw_h: Vec<f32>,
    gb_g: Vec<f32>,
    gw_o: Vec<f32>,
    gb_o: Vec<f32>,
}

impl GruUpdate {
    fn new(art: &ArtifactSpec, model: &ModelSpec) -> Result<GruUpdate> {
        let (d_dim, hid, u_dim) = gru_dims(model)?;
        let seqs = data_shape(art, "seqs")?;
        let (b, t) = (seqs[0], seqs[1]);
        Ok(GruUpdate {
            b,
            t,
            d_dim,
            hid,
            u_dim,
            h: vec![0.0; (t + 1) * b * hid],
            z: vec![0.0; t * b * hid],
            r: vec![0.0; t * b * hid],
            n_: vec![0.0; t * b * hid],
            ghn: vec![0.0; t * b * hid],
            logits: vec![0.0; t * b * u_dim],
            xt: vec![0.0; b * d_dim],
            gx: vec![0.0; b * 3 * hid],
            gh: vec![0.0; b * 3 * hid],
            g_l: vec![0.0; b * u_dim],
            dh: vec![0.0; b * hid],
            carry: vec![0.0; b * hid],
            gw_x: vec![0.0; d_dim * 3 * hid],
            gw_h: vec![0.0; hid * 3 * hid],
            gb_g: vec![0.0; 3 * hid],
            gw_o: vec![0.0; hid * u_dim],
            gb_o: vec![0.0; u_dim],
        })
    }

    /// One Adam step of truncated BPTT over the `[B, T, D]` windows
    /// (`aip_gru_update`: BCE-with-logits on every step's head output).
    fn run(
        &mut self,
        store: &mut ParamStore,
        lr: f32,
        seqs: &[f32],
        targets: &[f32],
    ) -> Result<f32> {
        let (b, t, dd, hid, u) = (self.b, self.t, self.d_dim, self.hid, self.u_dim);
        let (bh, bu) = (b * hid, b * u);
        let inv = 1.0 / (b * t * u) as f32;
        let loss;
        {
            let w_x = store.get("w_x")?;
            let w_h = store.get("w_h")?;
            let b_g = store.get("b_g")?;
            let w_o = store.get("w_o")?;
            let b_o = store.get("b_o")?;

            // Forward scan, recording gates and hidden states.
            self.h[..bh].fill(0.0);
            let mut loss_sum = 0.0f64;
            for step in 0..t {
                for bi in 0..b {
                    let src = (bi * t + step) * dd;
                    self.xt[bi * dd..(bi + 1) * dd].copy_from_slice(&seqs[src..src + dd]);
                }
                kernels::linear_into(&self.xt, w_x, Some(b_g), &mut self.gx, b, dd, 3 * hid, Act::None);
                let (lo, hi) = self.h.split_at_mut((step + 1) * bh);
                let h_t = &lo[step * bh..];
                let h_next = &mut hi[..bh];
                kernels::linear_into(h_t, w_h, None, &mut self.gh, b, hid, 3 * hid, Act::None);
                for bi in 0..b {
                    for j in 0..hid {
                        let g3 = bi * 3 * hid;
                        let zv = kernels::sigmoid(self.gx[g3 + j] + self.gh[g3 + j]);
                        let rv = kernels::sigmoid(self.gx[g3 + hid + j] + self.gh[g3 + hid + j]);
                        let ghn_v = self.gh[g3 + 2 * hid + j];
                        let nv = (self.gx[g3 + 2 * hid + j] + rv * ghn_v).tanh();
                        let idx = step * bh + bi * hid + j;
                        self.z[idx] = zv;
                        self.r[idx] = rv;
                        self.n_[idx] = nv;
                        self.ghn[idx] = ghn_v;
                        h_next[bi * hid + j] = (1.0 - zv) * nv + zv * h_t[bi * hid + j];
                    }
                }
                let lrows = &mut self.logits[step * bu..(step + 1) * bu];
                kernels::linear_into(h_next, w_o, Some(b_o), lrows, b, hid, u, Act::None);
                for bi in 0..b {
                    let lrow = &lrows[bi * u..(bi + 1) * u];
                    let yrow = &targets[(bi * t + step) * u..(bi * t + step + 1) * u];
                    for (&l, &y) in lrow.iter().zip(yrow) {
                        loss_sum += kernels::bce_with_logits_elem(l, y) as f64;
                    }
                }
            }
            loss = (loss_sum as f32) * inv;

            // Backward through time.
            self.gw_x.fill(0.0);
            self.gw_h.fill(0.0);
            self.gb_g.fill(0.0);
            self.gw_o.fill(0.0);
            self.gb_o.fill(0.0);
            self.carry.fill(0.0);
            for step in (0..t).rev() {
                for bi in 0..b {
                    let lrow = &self.logits[step * bu + bi * u..step * bu + (bi + 1) * u];
                    let yrow = &targets[(bi * t + step) * u..(bi * t + step + 1) * u];
                    let glrow = &mut self.g_l[bi * u..(bi + 1) * u];
                    for ((gl, &l), &y) in glrow.iter_mut().zip(lrow).zip(yrow) {
                        *gl = (kernels::sigmoid(l) - y) * inv;
                    }
                }
                let h_next = &self.h[(step + 1) * bh..(step + 2) * bh];
                let h_t = &self.h[step * bh..(step + 1) * bh];
                kernels::matmul_at_b_acc(h_next, &self.g_l, &mut self.gw_o, b, hid, u);
                kernels::colsum_acc(&self.g_l, &mut self.gb_o, u);
                kernels::matmul_bt_into(&self.g_l, w_o, &mut self.dh, b, u, hid);
                for (d_, &c) in self.dh.iter_mut().zip(&self.carry) {
                    *d_ += c;
                }
                for bi in 0..b {
                    for j in 0..hid {
                        let idx = step * bh + bi * hid + j;
                        let (zv, rv, nv, ghn_v) =
                            (self.z[idx], self.r[idx], self.n_[idx], self.ghn[idx]);
                        let dh_v = self.dh[bi * hid + j];
                        let h_prev = h_t[bi * hid + j];
                        let dz = dh_v * (h_prev - nv);
                        let dn = dh_v * (1.0 - zv);
                        let dan = dn * (1.0 - nv * nv);
                        let dr = dan * ghn_v;
                        let daz = dz * zv * (1.0 - zv);
                        let dar = dr * rv * (1.0 - rv);
                        let g3 = bi * 3 * hid;
                        self.gx[g3 + j] = daz;
                        self.gh[g3 + j] = daz;
                        self.gx[g3 + hid + j] = dar;
                        self.gh[g3 + hid + j] = dar;
                        self.gx[g3 + 2 * hid + j] = dan;
                        self.gh[g3 + 2 * hid + j] = dan * rv;
                        self.carry[bi * hid + j] = dh_v * zv;
                    }
                }
                for bi in 0..b {
                    let src = (bi * t + step) * dd;
                    self.xt[bi * dd..(bi + 1) * dd].copy_from_slice(&seqs[src..src + dd]);
                }
                kernels::matmul_at_b_acc(&self.xt, &self.gx, &mut self.gw_x, b, dd, 3 * hid);
                kernels::colsum_acc(&self.gx, &mut self.gb_g, 3 * hid);
                kernels::matmul_at_b_acc(h_t, &self.gh, &mut self.gw_h, b, hid, 3 * hid);
                kernels::matmul_bt_acc(&self.gh, w_h, &mut self.carry, b, 3 * hid, hid);
            }
        }
        adam_apply(
            store,
            lr,
            &[
                ("w_x", self.gw_x.as_slice()),
                ("w_h", self.gw_h.as_slice()),
                ("b_g", self.gb_g.as_slice()),
                ("w_o", self.gw_o.as_slice()),
                ("b_o", self.gb_o.as_slice()),
            ],
        )?;
        Ok(loss)
    }
}
