//! Native CPU execution backend: runs every artifact of the synthesized
//! manifest directly on [`ParamStore`] slices with the hand-rolled kernels
//! in [`crate::nn::kernels`] — no HLO, no PJRT, no `artifacts/` directory.
//!
//! Each artifact is classified once (from its model's parameter names and
//! its data bindings) into an op with preallocated scratch; after that
//! first call, the forward *and* training ops perform **zero steady-state
//! heap allocations and zero redundant copies** — inputs are borrowed from
//! the caller, intermediates live in reusable scratch (including per-slice
//! gradient scratch and the cached Adam slot indices), and outputs are
//! written straight into the caller's buffers (`rust/tests/native_alloc.rs`
//! pins both paths with a counting allocator). Training ops mutate the
//! store through in-place Adam updates ([`ParamStore::adam_slots_at`]).
//!
//! ## Data parallelism
//!
//! With `[runtime] nn_workers > 1` the engine fans batch rows out over the
//! run's shared [`ComputePool`]: forwards partition rows into disjoint
//! output bands, and the trainers (PPO minibatch + fused whole-phase, FNN
//! BCE, GRU BPTT) compute per-slice gradients into preallocated per-slice
//! scratch, reduced **sequentially in fixed slice order** (never atomics)
//! before the global grad-norm clip and the in-place Adam step. The slice
//! grid ([`NN_SLICES`]) never depends on the worker count, so `nn_workers =
//! k` is bitwise identical to `nn_workers = 1` for every `k`
//! (`rust/tests/native_parallel.rs` locks this in end to end).
//!
//! ## Sync forward views (the fused step path)
//!
//! The forward ops are split into **shared immutable execution state** —
//! [`PolicyView`] / [`FnnView`] / [`GruView`], `Copy + Sync` bundles of
//! dimensions plus read-only parameter slices — and per-worker
//! [`EngineScratch`]. A forward is then a `&view + &mut scratch` call that
//! *any* pool worker can run over its own contiguous row band: the batched
//! ops above execute their slice grid through the same views, and the
//! fused IALS step (`ials::IalsVecEnv`) hands each sim shard a view so the
//! AIP forward happens inside the shard's own dispatch — no coordinator
//! round-trip. Rows are arithmetically independent in every forward
//! kernel, so any banding produces bitwise-identical outputs
//! (`rust/tests/integration_parallel.rs` pins fused == sandwich end to
//! end). Training ops mutate parameters and stay coordinator-driven.
//!
//! The math mirrors `python/compile/model.py` exactly (same losses, same
//! clipping, same Adam) so learning-dynamics tests hold on either backend.

#![allow(clippy::too_many_arguments)]

use super::manifest::{ArtifactSpec, Binding, Manifest, ModelSpec};
use super::{Backend, DataArg};
use crate::core::shard::{shard_ranges, ComputePool, SendSliceMut};
use crate::nn::kernels::{self, Act};
use crate::nn::ParamStore;
use crate::Result;
use anyhow::{bail, Context};
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;

/// Fixed partition grid for data-parallel NN work: batch rows split into at
/// most this many contiguous slices, **independent of the worker count**.
/// Workers claim slices round-robin and per-slice partials (gradients, loss
/// sums) are reduced sequentially in slice order on the coordinator — so
/// `nn_workers = k` is bitwise identical to `nn_workers = 1` for every `k`
/// by construction (the floating-point summation tree never changes; only
/// which thread computes each slice does).
pub const NN_SLICES: usize = 16;

/// Forwards smaller than this many rows stay inline — dispatch latency
/// would dominate. (Engagement only changes wall-clock, never bits: the
/// slice grid and reduction order are identical either way.)
const PAR_MIN_FWD_ROWS: usize = 32;

/// The slice grid for a row count: `shard_ranges` over at most
/// [`NN_SLICES`] slices.
fn nn_slices(rows: usize) -> Vec<(usize, usize)> {
    shard_ranges(rows, NN_SLICES.min(rows.max(1)))
}

/// Parallel execution context for native ops: the run's shared
/// [`ComputePool`] (if any) plus the `nn_workers` concurrency cap.
#[derive(Clone)]
pub struct Par {
    pool: Option<Arc<ComputePool>>,
    limit: usize,
}

impl Par {
    /// Serial execution (the default; also `nn_workers = 1`).
    pub fn serial() -> Par {
        Par { pool: None, limit: 1 }
    }

    /// Fan slices out over `pool`, at most `nn_workers` at a time.
    pub fn with_pool(pool: Option<Arc<ComputePool>>, nn_workers: usize) -> Par {
        if nn_workers > 1 && pool.is_some() {
            Par { pool, limit: nn_workers }
        } else {
            Par::serial()
        }
    }

    pub fn is_parallel(&self) -> bool {
        self.pool.is_some()
    }

    /// Run `f(0), …, f(n_slices - 1)`: on the pool when parallel and
    /// `engage`, else inline in slice order. Every slice writes disjoint
    /// output and partials are reduced in slice order afterwards, so both
    /// paths produce bitwise-identical results.
    fn run(&self, n_slices: usize, engage: bool, f: &(dyn Fn(usize) + Sync)) {
        match &self.pool {
            Some(pool) if engage && n_slices > 1 => pool.run_tasks(n_slices, self.limit, f),
            _ => {
                for i in 0..n_slices {
                    f(i);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Sync forward views + per-worker scratch (the fused step path)
// ---------------------------------------------------------------------------

/// Per-worker forward scratch: two reusable buffers, sized once for the
/// largest row band their owner can be handed (e.g. one IALS shard's env
/// count). The `&view + &mut EngineScratch` calling convention is what
/// makes the forward path executable from any pool worker with zero
/// steady-state heap allocations.
pub struct EngineScratch {
    a: Vec<f32>,
    b: Vec<f32>,
}

impl EngineScratch {
    /// Preallocate `a_len + b_len` f32 of scratch (per-row sizes come from
    /// the predictor/view that will run on it).
    pub fn new(a_len: usize, b_len: usize) -> EngineScratch {
        EngineScratch { a: vec![0.0; a_len], b: vec![0.0; b_len] }
    }

    /// Mutable prefixes of both buffers, growing them first if a larger
    /// band than planned arrives (never on the steady-state path — owners
    /// preallocate for their maximum band at construction).
    fn bands(&mut self, a_len: usize, b_len: usize) -> (&mut [f32], &mut [f32]) {
        if self.a.len() < a_len {
            self.a.resize(a_len, 0.0);
        }
        if self.b.len() < b_len {
            self.b.resize(b_len, 0.0);
        }
        (&mut self.a[..a_len], &mut self.b[..b_len])
    }
}

/// Shared immutable execution state of the policy-MLP forward: dimensions
/// plus parameter slices borrowed read-only from the store. The view is
/// `Copy + Sync`, so any pool worker can run it over its own contiguous
/// row band with per-worker scratch. Every forward kernel computes rows
/// independently ([`kernels::linear_into`] is i-k-j per output row), so
/// banding rows by shard instead of by NN slice is bitwise identical to
/// the batched op — the fused-pipeline determinism guarantee.
#[derive(Clone, Copy)]
pub struct PolicyView<'a> {
    pub obs_dim: usize,
    pub hid: usize,
    pub act_dim: usize,
    w1: &'a [f32],
    b1: &'a [f32],
    w2: &'a [f32],
    b2: &'a [f32],
    w_pi: &'a [f32],
    b_pi: &'a [f32],
    w_v: &'a [f32],
    b_v: &'a [f32],
}

impl<'a> PolicyView<'a> {
    /// Resolve the view from a store (dimension-checked; no allocation).
    pub fn resolve(store: &'a ParamStore) -> Result<PolicyView<'a>> {
        let w1 = store.get("w1")?;
        let b1 = store.get("b1")?;
        let w2 = store.get("w2")?;
        let b2 = store.get("b2")?;
        let w_pi = store.get("w_pi")?;
        let b_pi = store.get("b_pi")?;
        let w_v = store.get("w_v")?;
        let b_v = store.get("b_v")?;
        let hid = b1.len();
        let act_dim = b_pi.len();
        anyhow::ensure!(hid > 0 && act_dim > 0, "empty policy dims");
        let obs_dim = w1.len() / hid;
        anyhow::ensure!(
            w1.len() == obs_dim * hid
                && b2.len() == hid
                && w2.len() == hid * hid
                && w_pi.len() == hid * act_dim
                && w_v.len() == hid
                && b_v.len() == 1,
            "policy parameter shapes inconsistent"
        );
        Ok(PolicyView { obs_dim, hid, act_dim, w1, b1, w2, b2, w_pi, b_pi, w_v, b_v })
    }

    /// Row-band forward with explicit scratch slices (`h1`/`h2` hold
    /// `m * hid` each).
    fn forward_band(
        &self,
        m: usize,
        obs: &[f32],
        h1: &mut [f32],
        h2: &mut [f32],
        logits: &mut [f32],
        values: &mut [f32],
    ) {
        kernels::linear_into(obs, self.w1, Some(self.b1), h1, m, self.obs_dim, self.hid, Act::Tanh);
        kernels::linear_into(h1, self.w2, Some(self.b2), h2, m, self.hid, self.hid, Act::Tanh);
        kernels::linear_into(
            h2,
            self.w_pi,
            Some(self.b_pi),
            logits,
            m,
            self.hid,
            self.act_dim,
            Act::None,
        );
        kernels::linear_into(h2, self.w_v, Some(self.b_v), values, m, self.hid, 1, Act::None);
    }

    /// `&self + &mut scratch` forward over `m` rows: `logits` holds
    /// `m * act_dim`, `values` holds `m`. Rows are independent (every
    /// kernel is i-k-j per output row), so a batch of `m` rows is bitwise
    /// identical to `m` single-row forwards — the guarantee the serving
    /// runtime's micro-batcher is built on. The *training* path stays
    /// coordinator-batched on purpose (action sampling consumes one RNG
    /// stream in env order) and never calls this.
    pub fn forward_rows(
        &self,
        m: usize,
        obs: &[f32],
        logits: &mut [f32],
        values: &mut [f32],
        scratch: &mut EngineScratch,
    ) {
        debug_assert_eq!(obs.len(), m * self.obs_dim);
        debug_assert_eq!(logits.len(), m * self.act_dim);
        debug_assert_eq!(values.len(), m);
        let (h1, h2) = scratch.bands(m * self.hid, m * self.hid);
        self.forward_band(m, obs, h1, h2, logits, values);
    }
}

/// Shared immutable execution state of the FNN-AIP forward (tanh hidden,
/// sigmoid head). `Copy + Sync`; see [`PolicyView`] for the row-banding
/// determinism argument.
#[derive(Clone, Copy)]
pub struct FnnView<'a> {
    pub d_dim: usize,
    pub hid: usize,
    pub u_dim: usize,
    w1: &'a [f32],
    b1: &'a [f32],
    w2: &'a [f32],
    b2: &'a [f32],
}

impl<'a> FnnView<'a> {
    /// Resolve the view from a store (dimension-checked; no allocation).
    pub fn resolve(store: &'a ParamStore) -> Result<FnnView<'a>> {
        let w1 = store.get("w1")?;
        let b1 = store.get("b1")?;
        let w2 = store.get("w2")?;
        let b2 = store.get("b2")?;
        let hid = b1.len();
        let u_dim = b2.len();
        anyhow::ensure!(hid > 0 && u_dim > 0, "empty FNN dims");
        let d_dim = w1.len() / hid;
        anyhow::ensure!(
            w1.len() == d_dim * hid && w2.len() == hid * u_dim,
            "FNN parameter shapes inconsistent"
        );
        Ok(FnnView { d_dim, hid, u_dim, w1, b1, w2, b2 })
    }

    /// Row-band forward with explicit scratch (`h1` holds `m * hid`).
    fn forward_band(&self, m: usize, d: &[f32], h1: &mut [f32], probs: &mut [f32]) {
        kernels::linear_into(d, self.w1, Some(self.b1), h1, m, self.d_dim, self.hid, Act::Tanh);
        kernels::linear_into(
            h1,
            self.w2,
            Some(self.b2),
            probs,
            m,
            self.hid,
            self.u_dim,
            Act::Sigmoid,
        );
    }

    /// `&self + &mut scratch` forward over `m` rows.
    pub fn forward_rows(
        &self,
        m: usize,
        d: &[f32],
        probs: &mut [f32],
        scratch: &mut EngineScratch,
    ) {
        let (h1, _) = scratch.bands(m * self.hid, 0);
        self.forward_band(m, d, h1, probs);
    }
}

/// Shared immutable execution state of one GRU-AIP step (fused z|r|n
/// gates, sigmoid head). `Copy + Sync`; rows are independent through the
/// cell, so shard workers can advance their own disjoint bands of the
/// recurrent state.
#[derive(Clone, Copy)]
pub struct GruView<'a> {
    pub d_dim: usize,
    pub hid: usize,
    pub u_dim: usize,
    w_x: &'a [f32],
    w_h: &'a [f32],
    b_g: &'a [f32],
    w_o: &'a [f32],
    b_o: &'a [f32],
}

impl<'a> GruView<'a> {
    /// Resolve the view from a store (dimension-checked; no allocation).
    pub fn resolve(store: &'a ParamStore) -> Result<GruView<'a>> {
        let w_x = store.get("w_x")?;
        let w_h = store.get("w_h")?;
        let b_g = store.get("b_g")?;
        let w_o = store.get("w_o")?;
        let b_o = store.get("b_o")?;
        anyhow::ensure!(b_g.len() % 3 == 0 && !b_g.is_empty(), "bad GRU gate dims");
        let hid = b_g.len() / 3;
        let u_dim = b_o.len();
        anyhow::ensure!(u_dim > 0, "empty GRU head");
        let d_dim = w_x.len() / (3 * hid);
        anyhow::ensure!(
            w_x.len() == d_dim * 3 * hid
                && w_h.len() == hid * 3 * hid
                && w_o.len() == hid * u_dim,
            "GRU parameter shapes inconsistent"
        );
        Ok(GruView { d_dim, hid, u_dim, w_x, w_h, b_g, w_o, b_o })
    }

    /// Row-band step with explicit scratch (`gx`/`gh` hold `m * 3 * hid`
    /// each). `h_new` must not alias `h`.
    fn step_band(
        &self,
        m: usize,
        h: &[f32],
        d: &[f32],
        probs: &mut [f32],
        h_new: &mut [f32],
        gx: &mut [f32],
        gh: &mut [f32],
    ) {
        kernels::gru_cell_into(
            d,
            h,
            self.w_x,
            self.w_h,
            self.b_g,
            h_new,
            gx,
            gh,
            m,
            self.d_dim,
            self.hid,
        );
        kernels::linear_into(
            h_new,
            self.w_o,
            Some(self.b_o),
            probs,
            m,
            self.hid,
            self.u_dim,
            Act::Sigmoid,
        );
    }

    /// `&self + &mut scratch` step over `m` rows.
    pub fn step_rows(
        &self,
        m: usize,
        h: &[f32],
        d: &[f32],
        probs: &mut [f32],
        h_new: &mut [f32],
        scratch: &mut EngineScratch,
    ) {
        let (gx, gh) = scratch.bands(m * 3 * self.hid, m * 3 * self.hid);
        self.step_band(m, h, d, probs, h_new, gx, gh);
    }
}

/// Deterministic per-model seed for in-memory parameter initialization
/// (FNV-1a over the model name; the native stand-in for `params.bin`).
pub fn init_seed(model: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in model.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The native CPU engine: one classified-op cache, scratch reused across
/// calls, optionally data-parallel over the run's shared compute pool.
pub struct NativeBackend {
    ops: RefCell<HashMap<String, Op>>,
    par: Par,
}

impl NativeBackend {
    /// Serial engine (the historical behaviour; `nn_workers = 1`).
    pub fn new() -> NativeBackend {
        Self::with_par(Par::serial())
    }

    /// Data-parallel engine: batched forwards and training updates fan row
    /// slices out over `pool`, capped at `nn_workers` concurrent workers.
    pub fn with_pool(pool: Option<Arc<ComputePool>>, nn_workers: usize) -> NativeBackend {
        Self::with_par(Par::with_pool(pool, nn_workers))
    }

    fn with_par(par: Par) -> NativeBackend {
        NativeBackend { ops: RefCell::new(HashMap::new()), par }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn prepare(&self, art: &ArtifactSpec, manifest: &Manifest) -> Result<()> {
        let mut ops = self.ops.borrow_mut();
        if !ops.contains_key(&art.name) {
            let op = Op::build(art, manifest, &self.par)
                .with_context(|| format!("classifying artifact {}", art.name))?;
            ops.insert(art.name.clone(), op);
        }
        Ok(())
    }

    fn execute(
        &self,
        art: &ArtifactSpec,
        manifest: &Manifest,
        store: &mut ParamStore,
        data: &[DataArg<'_>],
        outs: &mut [&mut [f32]],
    ) -> Result<()> {
        self.prepare(art, manifest)?;
        let mut ops = self.ops.borrow_mut();
        let op = ops.get_mut(&art.name).unwrap();
        op.run(store, data, outs)
            .with_context(|| format!("native execution of {}", art.name))
    }
}

// ---------------------------------------------------------------------------
// Argument helpers
// ---------------------------------------------------------------------------

fn f32_arg<'a>(data: &[DataArg<'a>], idx: usize, what: &str) -> Result<&'a [f32]> {
    match data.get(idx) {
        Some(&DataArg::F32(v)) => Ok(v),
        _ => bail!("data arg {idx} ({what}) must be f32"),
    }
}

fn i32_arg<'a>(data: &[DataArg<'a>], idx: usize, what: &str) -> Result<&'a [i32]> {
    match data.get(idx) {
        Some(&DataArg::I32(v)) => Ok(v),
        _ => bail!("data arg {idx} ({what}) must be i32"),
    }
}

fn scalar(data: &[DataArg<'_>], idx: usize, what: &str) -> Result<f32> {
    Ok(f32_arg(data, idx, what)?[0])
}

fn data_shape<'m>(art: &'m ArtifactSpec, name: &str) -> Result<&'m [usize]> {
    art.data_inputs()
        .find(|t| t.name == name)
        .map(|t| t.shape.as_slice())
        .with_context(|| format!("artifact {} has no data input '{name}'", art.name))
}

/// In-place Adam over named tensors: bumps `adam_t`, then updates `m.*` /
/// `v.*` / the parameter in one pass each (matching `adam_step` in
/// `python/compile/model.py`). `idx_cache` memoizes the name → tensor-index
/// resolution (which formats slot names and therefore allocates) so the
/// steady-state training path performs zero heap allocations — the cache
/// fills on the first (warmup) call and is reused afterwards.
fn adam_apply(
    store: &mut ParamStore,
    lr: f32,
    names: &[&str],
    grads: &[&[f32]],
    idx_cache: &mut Vec<[usize; 3]>,
) -> Result<()> {
    debug_assert_eq!(names.len(), grads.len());
    if idx_cache.len() != names.len() {
        // Resolve into a fresh list and install only on full success, so a
        // mid-loop error can never leave a partial cache behind (which a
        // later call would silently zip against only a prefix of `grads`).
        let mut resolved = Vec::with_capacity(names.len());
        for name in names {
            resolved.push(store.adam_indices(name)?);
        }
        *idx_cache = resolved;
    }
    let t_new = {
        let t = store.tensor_mut("adam_t")?;
        t[0] += 1.0;
        t[0]
    };
    let bc1 = 1.0 - kernels::ADAM_B1.powf(t_new);
    let bc2 = 1.0 - kernels::ADAM_B2.powf(t_new);
    for (idx, g) in idx_cache.iter().zip(grads) {
        let (p, m, v) = store.adam_slots_at(*idx)?;
        kernels::adam_tensor(p, m, v, g, lr, bc1, bc2);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Op classification
// ---------------------------------------------------------------------------

enum Op {
    PolicyFwd(PolicyFwd),
    PolicyUpdate(PolicyUpdate),
    PolicyUpdateFused(PolicyUpdateFused),
    FnnFwd(FnnFwd),
    FnnUpdate(FnnUpdate),
    GruStep(GruStep),
    GruUpdate(GruUpdate),
}

impl Op {
    fn build(art: &ArtifactSpec, manifest: &Manifest, par: &Par) -> Result<Op> {
        let model = manifest.model(&art.model)?;
        let trains = art.outputs.iter().any(|b| matches!(b, Binding::Param(_)));
        let is_policy = model.params.iter().any(|p| p.name == "w_pi");
        let is_gru = model.params.iter().any(|p| p.name == "w_x");
        Ok(if is_policy {
            if !trains {
                Op::PolicyFwd(PolicyFwd::new(art, model, par)?)
            } else if art.data_inputs().any(|t| t.name == "perm") {
                Op::PolicyUpdateFused(PolicyUpdateFused::new(art, model, manifest, par)?)
            } else {
                Op::PolicyUpdate(PolicyUpdate::new(art, model, par)?)
            }
        } else if is_gru {
            if trains {
                Op::GruUpdate(GruUpdate::new(art, model, par)?)
            } else {
                Op::GruStep(GruStep::new(art, model, par)?)
            }
        } else if trains {
            Op::FnnUpdate(FnnUpdate::new(art, model, par)?)
        } else {
            Op::FnnFwd(FnnFwd::new(art, model, par)?)
        })
    }

    fn run(
        &mut self,
        store: &mut ParamStore,
        data: &[DataArg<'_>],
        outs: &mut [&mut [f32]],
    ) -> Result<()> {
        match self {
            Op::PolicyFwd(o) => {
                let obs = f32_arg(data, 0, "obs")?;
                let (lo, rest) = outs.split_at_mut(1);
                o.run(store, obs, &mut *lo[0], &mut *rest[0])
            }
            Op::PolicyUpdate(o) => {
                let hp = Hyper::parse(data)?;
                let obs = f32_arg(data, 5, "obs")?;
                let actions = i32_arg(data, 6, "actions")?;
                let adv = f32_arg(data, 7, "advantages")?;
                let ret = f32_arg(data, 8, "returns")?;
                let lp = f32_arg(data, 9, "old_logp")?;
                let stats = o.run_minibatch(store, &hp, obs, actions, adv, ret, lp)?;
                outs[0].copy_from_slice(&stats);
                Ok(())
            }
            Op::PolicyUpdateFused(o) => {
                let stats = o.run(store, data)?;
                outs[0].copy_from_slice(&stats);
                Ok(())
            }
            Op::FnnFwd(o) => {
                let d = f32_arg(data, 0, "d")?;
                o.run(store, d, &mut *outs[0])
            }
            Op::FnnUpdate(o) => {
                let lr = scalar(data, 0, "lr")?;
                let d = f32_arg(data, 1, "d")?;
                let targets = f32_arg(data, 2, "targets")?;
                let loss = o.run(store, lr, d, targets)?;
                outs[0][0] = loss;
                Ok(())
            }
            Op::GruStep(o) => {
                let h = f32_arg(data, 0, "h")?;
                let d = f32_arg(data, 1, "d")?;
                let (probs, rest) = outs.split_at_mut(1);
                o.run(store, h, d, &mut *probs[0], &mut *rest[0])
            }
            Op::GruUpdate(o) => {
                let lr = scalar(data, 0, "lr")?;
                let seqs = f32_arg(data, 1, "seqs")?;
                let targets = f32_arg(data, 2, "targets")?;
                let loss = o.run(store, lr, seqs, targets)?;
                outs[0][0] = loss;
                Ok(())
            }
        }
    }
}

/// PPO hyperparameters handed over as shape-(1,) scalars.
struct Hyper {
    lr: f32,
    clip: f32,
    vf: f32,
    ent: f32,
    mgn: f32,
}

impl Hyper {
    fn parse(data: &[DataArg<'_>]) -> Result<Hyper> {
        Ok(Hyper {
            lr: scalar(data, 0, "lr")?,
            clip: scalar(data, 1, "clip")?,
            vf: scalar(data, 2, "vf_coef")?,
            ent: scalar(data, 3, "ent_coef")?,
            mgn: scalar(data, 4, "max_grad_norm")?,
        })
    }
}

// ---------------------------------------------------------------------------
// Policy MLP (tanh-tanh trunk, logits + value heads)
// ---------------------------------------------------------------------------

fn policy_dims(model: &ModelSpec) -> Result<(usize, usize, usize)> {
    let w1 = model.param("w1")?;
    let act = model.param("w_pi")?.shape[1];
    Ok((w1.shape[0], w1.shape[1], act))
}

struct PolicyFwd {
    b: usize,
    obs_dim: usize,
    hid: usize,
    act_dim: usize,
    h1: Vec<f32>,
    h2: Vec<f32>,
    slices: Vec<(usize, usize)>,
    par: Par,
}

impl PolicyFwd {
    fn new(art: &ArtifactSpec, model: &ModelSpec, par: &Par) -> Result<PolicyFwd> {
        let (obs_dim, hid, act_dim) = policy_dims(model)?;
        let b = data_shape(art, "obs")?[0];
        Ok(PolicyFwd {
            b,
            obs_dim,
            hid,
            act_dim,
            h1: vec![0.0; b * hid],
            h2: vec![0.0; b * hid],
            slices: nn_slices(b),
            par: par.clone(),
        })
    }

    fn run(
        &mut self,
        store: &ParamStore,
        obs: &[f32],
        logits: &mut [f32],
        value: &mut [f32],
    ) -> Result<()> {
        let (od, h, a) = (self.obs_dim, self.hid, self.act_dim);
        // Same shared-state/scratch split as the fused step path: the view
        // carries the immutable execution state, the op only owns scratch.
        let view = PolicyView::resolve(store)?;
        debug_assert_eq!((view.obs_dim, view.hid, view.act_dim), (od, h, a));
        let slices = &self.slices;
        let h1 = SendSliceMut::new(&mut self.h1);
        let h2 = SendSliceMut::new(&mut self.h2);
        let lg = SendSliceMut::new(logits);
        let vl = SendSliceMut::new(value);
        let task = |si: usize| {
            let (r0, r1) = slices[si];
            let m = r1 - r0;
            // SAFETY: slices are disjoint row bands tiling [0, b); Par::run
            // blocks until every slice has completed.
            let (h1s, h2s, ls, vs) = unsafe {
                (
                    h1.range(r0 * h, m * h),
                    h2.range(r0 * h, m * h),
                    lg.range(r0 * a, m * a),
                    vl.range(r0, m),
                )
            };
            view.forward_band(m, &obs[r0 * od..r1 * od], h1s, h2s, ls, vs);
        };
        self.par.run(slices.len(), self.b >= PAR_MIN_FWD_ROWS, &task);
        Ok(())
    }
}

/// Per-tensor policy gradients (same order as the model spec).
struct PolicyGrads {
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
    w_pi: Vec<f32>,
    b_pi: Vec<f32>,
    w_v: Vec<f32>,
    b_v: Vec<f32>,
}

impl PolicyGrads {
    fn new(obs_dim: usize, hid: usize, act_dim: usize) -> PolicyGrads {
        PolicyGrads {
            w1: vec![0.0; obs_dim * hid],
            b1: vec![0.0; hid],
            w2: vec![0.0; hid * hid],
            b2: vec![0.0; hid],
            w_pi: vec![0.0; hid * act_dim],
            b_pi: vec![0.0; act_dim],
            w_v: vec![0.0; hid],
            b_v: vec![0.0; 1],
        }
    }

    fn zero(&mut self) {
        for g in [
            &mut self.w1,
            &mut self.b1,
            &mut self.w2,
            &mut self.b2,
            &mut self.w_pi,
            &mut self.b_pi,
            &mut self.w_v,
            &mut self.b_v,
        ] {
            g.fill(0.0);
        }
    }

    fn scale(&mut self, s: f32) {
        for g in [
            &mut self.w1,
            &mut self.b1,
            &mut self.w2,
            &mut self.b2,
            &mut self.w_pi,
            &mut self.b_pi,
            &mut self.w_v,
            &mut self.b_v,
        ] {
            for x in g.iter_mut() {
                *x *= s;
            }
        }
    }

    fn norm(&self) -> f32 {
        kernels::global_norm(&[
            &self.w1[..],
            &self.b1[..],
            &self.w2[..],
            &self.b2[..],
            &self.w_pi[..],
            &self.b_pi[..],
            &self.w_v[..],
            &self.b_v[..],
        ])
    }

    /// `self += other` — one step of the ordered per-slice reduction.
    fn add_from(&mut self, o: &PolicyGrads) {
        kernels::add_assign(&mut self.w1, &o.w1);
        kernels::add_assign(&mut self.b1, &o.b1);
        kernels::add_assign(&mut self.w2, &o.w2);
        kernels::add_assign(&mut self.b2, &o.b2);
        kernels::add_assign(&mut self.w_pi, &o.w_pi);
        kernels::add_assign(&mut self.b_pi, &o.b_pi);
        kernels::add_assign(&mut self.w_v, &o.w_v);
        kernels::add_assign(&mut self.b_v, &o.b_v);
    }
}

/// Parameter-name order shared by the policy backward + Adam step.
const POLICY_PARAMS: [&str; 8] = ["w1", "b1", "w2", "b2", "w_pi", "b_pi", "w_v", "b_v"];

struct PolicyUpdate {
    mb: usize,
    obs_dim: usize,
    hid: usize,
    act_dim: usize,
    h1: Vec<f32>,
    h2: Vec<f32>,
    logits: Vec<f32>,
    logp: Vec<f32>,
    value: Vec<f32>,
    g_logits: Vec<f32>,
    g_value: Vec<f32>,
    g_ha: Vec<f32>,
    g_hb: Vec<f32>,
    /// Reduced (total) gradients — also the serial accumulator target.
    grads: PolicyGrads,
    /// Fixed slice grid over minibatch rows (see [`NN_SLICES`]).
    slices: Vec<(usize, usize)>,
    /// Per-slice gradient scratch, preallocated at op build.
    part_grads: Vec<PolicyGrads>,
    /// Per-slice loss partials `[pg, v, ent, kl]`.
    part_sums: Vec<[f64; 4]>,
    adam_idx: Vec<[usize; 3]>,
    par: Par,
}

impl PolicyUpdate {
    fn new(art: &ArtifactSpec, model: &ModelSpec, par: &Par) -> Result<PolicyUpdate> {
        let (obs_dim, hid, act_dim) = policy_dims(model)?;
        let mb = data_shape(art, "obs")?[0];
        Ok(Self::with_minibatch(mb, obs_dim, hid, act_dim, par))
    }

    fn with_minibatch(
        mb: usize,
        obs_dim: usize,
        hid: usize,
        act_dim: usize,
        par: &Par,
    ) -> PolicyUpdate {
        let slices = nn_slices(mb);
        let part_grads =
            slices.iter().map(|_| PolicyGrads::new(obs_dim, hid, act_dim)).collect::<Vec<_>>();
        let part_sums = vec![[0.0f64; 4]; slices.len()];
        PolicyUpdate {
            mb,
            obs_dim,
            hid,
            act_dim,
            h1: vec![0.0; mb * hid],
            h2: vec![0.0; mb * hid],
            logits: vec![0.0; mb * act_dim],
            logp: vec![0.0; mb * act_dim],
            value: vec![0.0; mb],
            g_logits: vec![0.0; mb * act_dim],
            g_value: vec![0.0; mb],
            g_ha: vec![0.0; mb * hid],
            g_hb: vec![0.0; mb * hid],
            grads: PolicyGrads::new(obs_dim, hid, act_dim),
            slices,
            part_grads,
            part_sums,
            adam_idx: Vec::with_capacity(POLICY_PARAMS.len()),
            par: par.clone(),
        }
    }

    /// One clipped-surrogate PPO minibatch step — forward, loss, backward,
    /// grad-norm clip, Adam (`ppo_update` in `model.py`). Returns
    /// `[total, pg_loss, v_loss, entropy, approx_kl, grad_norm]`, where
    /// `grad_norm` is the pre-clip global gradient norm (the health
    /// guard's spike-detector input).
    ///
    /// Data-parallel over the fixed row-slice grid: each slice runs its own
    /// forward + loss + backward into per-slice gradient scratch; slice
    /// partials (gradients and f64 loss sums) are then reduced sequentially
    /// in slice order before the *global* grad-norm clip and the in-place
    /// Adam step. The grid never depends on the worker count, so results
    /// are bitwise identical for every `nn_workers`.
    fn run_minibatch(
        &mut self,
        store: &mut ParamStore,
        hp: &Hyper,
        obs: &[f32],
        actions: &[i32],
        adv: &[f32],
        ret: &[f32],
        old_logp: &[f32],
    ) -> Result<[f32; 6]> {
        let (mb, od, h, a) = (self.mb, self.obs_dim, self.hid, self.act_dim);
        let inv_mb = 1.0 / mb as f32;
        // Slice tasks cannot surface errors — validate inputs up front.
        for &act in actions {
            anyhow::ensure!(
                act >= 0 && (act as usize) < a,
                "action {act} out of range (act_dim {a})"
            );
        }
        {
            let w1 = store.get("w1")?;
            let b1 = store.get("b1")?;
            let w2 = store.get("w2")?;
            let b2 = store.get("b2")?;
            let w_pi = store.get("w_pi")?;
            let b_pi = store.get("b_pi")?;
            let w_v = store.get("w_v")?;
            let b_v = store.get("b_v")?;
            let slices = &self.slices;
            let h1 = SendSliceMut::new(&mut self.h1);
            let h2 = SendSliceMut::new(&mut self.h2);
            let lg = SendSliceMut::new(&mut self.logits);
            let lp_ = SendSliceMut::new(&mut self.logp);
            let vl = SendSliceMut::new(&mut self.value);
            let gl = SendSliceMut::new(&mut self.g_logits);
            let gv = SendSliceMut::new(&mut self.g_value);
            let gha = SendSliceMut::new(&mut self.g_ha);
            let ghb = SendSliceMut::new(&mut self.g_hb);
            let pg = SendSliceMut::new(&mut self.part_grads);
            let ps = SendSliceMut::new(&mut self.part_sums);
            let task = |si: usize| {
                let (r0, r1) = slices[si];
                let m = r1 - r0;
                // SAFETY: disjoint row bands / per-slice cells; Par::run
                // blocks until every slice has completed.
                let (h1s, h2s, ls, lps, vs) = unsafe {
                    (
                        h1.range(r0 * h, m * h),
                        h2.range(r0 * h, m * h),
                        lg.range(r0 * a, m * a),
                        lp_.range(r0 * a, m * a),
                        vl.range(r0, m),
                    )
                };
                let (gls, gvs, ghas, ghbs) = unsafe {
                    (
                        gl.range(r0 * a, m * a),
                        gv.range(r0, m),
                        gha.range(r0 * h, m * h),
                        ghb.range(r0 * h, m * h),
                    )
                };
                let g = unsafe { &mut pg.range(si, 1)[0] };
                let sums = unsafe { &mut ps.range(si, 1)[0] };
                let xb = &obs[r0 * od..r1 * od];

                // Forward for this slice's rows.
                kernels::linear_into(xb, w1, Some(b1), h1s, m, od, h, Act::Tanh);
                kernels::linear_into(h1s, w2, Some(b2), h2s, m, h, h, Act::Tanh);
                kernels::linear_into(h2s, w_pi, Some(b_pi), ls, m, h, a, Act::None);
                kernels::linear_into(h2s, w_v, Some(b_v), vs, m, h, 1, Act::None);

                // Loss terms + dL/dlogits, dL/dvalue per row.
                let mut pg_sum = 0.0f64;
                let mut v_sum = 0.0f64;
                let mut ent_sum = 0.0f64;
                let mut kl_sum = 0.0f64;
                for li in 0..m {
                    let r = r0 + li;
                    let lrow = &ls[li * a..(li + 1) * a];
                    let lprow = &mut lps[li * a..(li + 1) * a];
                    kernels::log_softmax_row(lrow, lprow);
                    let act_i = actions[r] as usize;
                    let lpa = lprow[act_i];
                    let ratio = (lpa - old_logp[r]).exp();
                    let s1 = ratio * adv[r];
                    let s2 = ratio.clamp(1.0 - hp.clip, 1.0 + hp.clip) * adv[r];
                    // Gradient flows through the unclipped surrogate iff it
                    // is the active min (jnp.minimum semantics; the clipped
                    // branch is constant in logp).
                    let (min_s, gpg) =
                        if s1 <= s2 { (s1, -adv[r] * ratio * inv_mb) } else { (s2, 0.0) };
                    pg_sum += min_s as f64;
                    let mut h_row = 0.0f32;
                    for &lp in lprow.iter() {
                        h_row -= lp.exp() * lp;
                    }
                    ent_sum += h_row as f64;
                    kl_sum += (old_logp[r] - lpa) as f64;
                    let grow = &mut gls[li * a..(li + 1) * a];
                    for (j, (gj, &lp)) in grow.iter_mut().zip(lprow.iter()).enumerate() {
                        let p = lp.exp();
                        let onehot = if j == act_i { 1.0 } else { 0.0 };
                        // d(-ent_coef * H)/dlogit = ent_coef * p * (logp + H)
                        *gj = gpg * (onehot - p) + hp.ent * inv_mb * p * (lp + h_row);
                    }
                    let vdiff = vs[li] - ret[r];
                    v_sum += (vdiff as f64) * (vdiff as f64);
                    gvs[li] = hp.vf * 2.0 * vdiff * inv_mb;
                }
                *sums = [pg_sum, v_sum, ent_sum, kl_sum];

                // Backward for this slice into its own gradient scratch.
                g.zero();
                kernels::matmul_at_b_acc(h2s, gls, &mut g.w_pi, m, h, a);
                kernels::colsum_acc(gls, &mut g.b_pi, a);
                kernels::matmul_at_b_acc(h2s, gvs, &mut g.w_v, m, h, 1);
                g.b_v[0] = gvs.iter().sum::<f32>();
                kernels::matmul_bt_into(gls, w_pi, ghas, m, a, h);
                for (li, &gvr) in gvs.iter().enumerate() {
                    kernels::axpy(&mut ghas[li * h..(li + 1) * h], w_v, gvr);
                }
                for (gz, &hv) in ghas.iter_mut().zip(h2s.iter()) {
                    *gz *= 1.0 - hv * hv;
                }
                kernels::matmul_at_b_acc(h1s, ghas, &mut g.w2, m, h, h);
                kernels::colsum_acc(ghas, &mut g.b2, h);
                kernels::matmul_bt_into(ghas, w2, ghbs, m, h, h);
                for (gz, &hv) in ghbs.iter_mut().zip(h1s.iter()) {
                    *gz *= 1.0 - hv * hv;
                }
                kernels::matmul_at_b_acc(xb, ghbs, &mut g.w1, m, od, h);
                kernels::colsum_acc(ghbs, &mut g.b1, h);
            };
            self.par.run(slices.len(), true, &task);
        }

        // Ordered reduction in fixed slice order (sequential, never
        // atomics): the summation tree is the same for every worker count.
        let mut agg = [0.0f64; 4];
        for part in &self.part_sums {
            for (acc, &s) in agg.iter_mut().zip(part) {
                *acc += s;
            }
        }
        let pg_loss = -(agg[0] as f32) * inv_mb;
        let v_loss = (agg[1] as f32) * inv_mb;
        let entropy = (agg[2] as f32) * inv_mb;
        let approx_kl = (agg[3] as f32) * inv_mb;
        let total = pg_loss + hp.vf * v_loss - hp.ent * entropy;

        let PolicyUpdate { grads, part_grads, adam_idx, .. } = self;
        grads.zero();
        for part in part_grads.iter() {
            grads.add_from(part);
        }

        // Global grad-norm clip, then Adam (clip_global_norm + adam_step).
        let gn = grads.norm();
        let stats = [total, pg_loss, v_loss, entropy, approx_kl, gn];
        grads.scale((hp.mgn / (gn + 1e-8)).min(1.0));
        adam_apply(
            store,
            hp.lr,
            &POLICY_PARAMS,
            &[
                grads.w1.as_slice(),
                grads.b1.as_slice(),
                grads.w2.as_slice(),
                grads.b2.as_slice(),
                grads.w_pi.as_slice(),
                grads.b_pi.as_slice(),
                grads.w_v.as_slice(),
                grads.b_v.as_slice(),
            ],
            adam_idx,
        )?;
        Ok(stats)
    }
}

/// The whole-phase PPO update (`ppo_update_fused`): all epochs and
/// minibatches of one iteration in a single call, gathering rows by the
/// caller-supplied per-epoch permutation.
struct PolicyUpdateFused {
    epochs: usize,
    n: usize,
    core: PolicyUpdate,
    mb_obs: Vec<f32>,
    mb_act: Vec<i32>,
    mb_adv: Vec<f32>,
    mb_ret: Vec<f32>,
    mb_lp: Vec<f32>,
}

impl PolicyUpdateFused {
    fn new(
        art: &ArtifactSpec,
        model: &ModelSpec,
        manifest: &Manifest,
        par: &Par,
    ) -> Result<PolicyUpdateFused> {
        let (obs_dim, hid, act_dim) = policy_dims(model)?;
        let perm = data_shape(art, "perm")?;
        let (epochs, n) = (perm[0], perm[1]);
        // Minibatch width comes from the manifest geometry (the fused op
        // scans the same chunks the per-minibatch artifact would see).
        let mut mb = manifest.geom("ppo_minibatch").unwrap_or(n as i64) as usize;
        if mb == 0 || n % mb != 0 {
            mb = n;
        }
        Ok(PolicyUpdateFused {
            epochs,
            n,
            core: PolicyUpdate::with_minibatch(mb, obs_dim, hid, act_dim, par),
            mb_obs: vec![0.0; mb * obs_dim],
            mb_act: vec![0; mb],
            mb_adv: vec![0.0; mb],
            mb_ret: vec![0.0; mb],
            mb_lp: vec![0.0; mb],
        })
    }

    fn run(&mut self, store: &mut ParamStore, data: &[DataArg<'_>]) -> Result<[f32; 6]> {
        let hp = Hyper::parse(data)?;
        let perm = i32_arg(data, 5, "perm")?;
        let obs = f32_arg(data, 6, "obs")?;
        let actions = i32_arg(data, 7, "actions")?;
        let adv = f32_arg(data, 8, "advantages")?;
        let ret = f32_arg(data, 9, "returns")?;
        let old_logp = f32_arg(data, 10, "old_logp")?;
        let (n, mb, od) = (self.n, self.core.mb, self.core.obs_dim);
        let mut agg = [0.0f64; 6];
        let mut updates = 0usize;
        for e in 0..self.epochs {
            let perm_e = &perm[e * n..(e + 1) * n];
            for chunk in perm_e.chunks_exact(mb) {
                for (row, &src) in chunk.iter().enumerate() {
                    let s = src as usize;
                    anyhow::ensure!(s < n, "perm index {s} out of range (n {n})");
                    self.mb_obs[row * od..(row + 1) * od]
                        .copy_from_slice(&obs[s * od..(s + 1) * od]);
                    self.mb_act[row] = actions[s];
                    self.mb_adv[row] = adv[s];
                    self.mb_ret[row] = ret[s];
                    self.mb_lp[row] = old_logp[s];
                }
                let stats = self.core.run_minibatch(
                    store,
                    &hp,
                    &self.mb_obs,
                    &self.mb_act,
                    &self.mb_adv,
                    &self.mb_ret,
                    &self.mb_lp,
                )?;
                for (acc, s) in agg.iter_mut().zip(stats) {
                    *acc += s as f64;
                }
                updates += 1;
            }
        }
        let d = updates.max(1) as f64;
        Ok([
            (agg[0] / d) as f32,
            (agg[1] / d) as f32,
            (agg[2] / d) as f32,
            (agg[3] / d) as f32,
            (agg[4] / d) as f32,
            (agg[5] / d) as f32,
        ])
    }
}

// ---------------------------------------------------------------------------
// FNN influence predictor (tanh hidden, sigmoid head)
// ---------------------------------------------------------------------------

fn fnn_dims(model: &ModelSpec) -> Result<(usize, usize, usize)> {
    let w1 = model.param("w1")?;
    let u = model.param("w2")?.shape[1];
    Ok((w1.shape[0], w1.shape[1], u))
}

struct FnnFwd {
    b: usize,
    d_dim: usize,
    hid: usize,
    u_dim: usize,
    h1: Vec<f32>,
    slices: Vec<(usize, usize)>,
    par: Par,
}

impl FnnFwd {
    fn new(art: &ArtifactSpec, model: &ModelSpec, par: &Par) -> Result<FnnFwd> {
        let (d_dim, hid, u_dim) = fnn_dims(model)?;
        let b = data_shape(art, "d")?[0];
        Ok(FnnFwd {
            b,
            d_dim,
            hid,
            u_dim,
            h1: vec![0.0; b * hid],
            slices: nn_slices(b),
            par: par.clone(),
        })
    }

    fn run(&mut self, store: &ParamStore, d: &[f32], probs: &mut [f32]) -> Result<()> {
        let (dd, h, u) = (self.d_dim, self.hid, self.u_dim);
        let view = FnnView::resolve(store)?;
        debug_assert_eq!((view.d_dim, view.hid, view.u_dim), (dd, h, u));
        let slices = &self.slices;
        let h1 = SendSliceMut::new(&mut self.h1);
        let pr = SendSliceMut::new(probs);
        let task = |si: usize| {
            let (r0, r1) = slices[si];
            let m = r1 - r0;
            // SAFETY: disjoint row bands; Par::run blocks until done.
            let (h1s, ps) = unsafe { (h1.range(r0 * h, m * h), pr.range(r0 * u, m * u)) };
            view.forward_band(m, &d[r0 * dd..r1 * dd], h1s, ps);
        };
        self.par.run(slices.len(), self.b >= PAR_MIN_FWD_ROWS, &task);
        Ok(())
    }
}

/// Per-slice FNN gradient scratch (preallocated at op build).
struct FnnGrads {
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
}

impl FnnGrads {
    fn new(d_dim: usize, hid: usize, u_dim: usize) -> FnnGrads {
        FnnGrads {
            w1: vec![0.0; d_dim * hid],
            b1: vec![0.0; hid],
            w2: vec![0.0; hid * u_dim],
            b2: vec![0.0; u_dim],
        }
    }

    fn zero(&mut self) {
        for g in [&mut self.w1, &mut self.b1, &mut self.w2, &mut self.b2] {
            g.fill(0.0);
        }
    }
}

struct FnnUpdate {
    mb: usize,
    d_dim: usize,
    hid: usize,
    u_dim: usize,
    h1: Vec<f32>,
    logits: Vec<f32>,
    g_l: Vec<f32>,
    g_h: Vec<f32>,
    /// Reduced (total) gradients.
    gw1: Vec<f32>,
    gb1: Vec<f32>,
    gw2: Vec<f32>,
    gb2: Vec<f32>,
    slices: Vec<(usize, usize)>,
    part: Vec<FnnGrads>,
    part_loss: Vec<f64>,
    adam_idx: Vec<[usize; 3]>,
    par: Par,
}

impl FnnUpdate {
    fn new(art: &ArtifactSpec, model: &ModelSpec, par: &Par) -> Result<FnnUpdate> {
        let (d_dim, hid, u_dim) = fnn_dims(model)?;
        let mb = data_shape(art, "d")?[0];
        let slices = nn_slices(mb);
        let part = slices.iter().map(|_| FnnGrads::new(d_dim, hid, u_dim)).collect::<Vec<_>>();
        let part_loss = vec![0.0f64; slices.len()];
        Ok(FnnUpdate {
            mb,
            d_dim,
            hid,
            u_dim,
            h1: vec![0.0; mb * hid],
            logits: vec![0.0; mb * u_dim],
            g_l: vec![0.0; mb * u_dim],
            g_h: vec![0.0; mb * hid],
            gw1: vec![0.0; d_dim * hid],
            gb1: vec![0.0; hid],
            gw2: vec![0.0; hid * u_dim],
            gb2: vec![0.0; u_dim],
            slices,
            part,
            part_loss,
            adam_idx: Vec::with_capacity(4),
            par: par.clone(),
        })
    }

    /// One Adam step of stable BCE-with-logits (`aip_fnn_update`),
    /// data-parallel over the fixed row-slice grid with ordered per-slice
    /// gradient/loss reduction (bitwise identical for every `nn_workers`).
    fn run(&mut self, store: &mut ParamStore, lr: f32, d: &[f32], targets: &[f32]) -> Result<f32> {
        let (mb, dd, h, u) = (self.mb, self.d_dim, self.hid, self.u_dim);
        let inv = 1.0 / (mb * u) as f32;
        {
            let w1 = store.get("w1")?;
            let b1 = store.get("b1")?;
            let w2 = store.get("w2")?;
            let b2 = store.get("b2")?;
            let slices = &self.slices;
            let h1 = SendSliceMut::new(&mut self.h1);
            let lg = SendSliceMut::new(&mut self.logits);
            let gl = SendSliceMut::new(&mut self.g_l);
            let gh = SendSliceMut::new(&mut self.g_h);
            let pg = SendSliceMut::new(&mut self.part);
            let pl = SendSliceMut::new(&mut self.part_loss);
            let task = |si: usize| {
                let (r0, r1) = slices[si];
                let m = r1 - r0;
                // SAFETY: disjoint row bands / per-slice cells; Par::run
                // blocks until every slice has completed.
                let (h1s, ls, gls, ghs) = unsafe {
                    (
                        h1.range(r0 * h, m * h),
                        lg.range(r0 * u, m * u),
                        gl.range(r0 * u, m * u),
                        gh.range(r0 * h, m * h),
                    )
                };
                let g = unsafe { &mut pg.range(si, 1)[0] };
                let loss_slot = unsafe { &mut pl.range(si, 1)[0] };
                let db = &d[r0 * dd..r1 * dd];
                let yb = &targets[r0 * u..r1 * u];
                kernels::linear_into(db, w1, Some(b1), h1s, m, dd, h, Act::Tanh);
                kernels::linear_into(h1s, w2, Some(b2), ls, m, h, u, Act::None);
                let mut loss_sum = 0.0f64;
                for ((glv, &l), &y) in gls.iter_mut().zip(ls.iter()).zip(yb) {
                    loss_sum += kernels::bce_with_logits_elem(l, y) as f64;
                    *glv = (kernels::sigmoid(l) - y) * inv;
                }
                *loss_slot = loss_sum;
                g.zero();
                kernels::matmul_at_b_acc(h1s, gls, &mut g.w2, m, h, u);
                kernels::colsum_acc(gls, &mut g.b2, u);
                kernels::matmul_bt_into(gls, w2, ghs, m, u, h);
                for (gz, &hv) in ghs.iter_mut().zip(h1s.iter()) {
                    *gz *= 1.0 - hv * hv;
                }
                kernels::matmul_at_b_acc(db, ghs, &mut g.w1, m, dd, h);
                kernels::colsum_acc(ghs, &mut g.b1, h);
            };
            self.par.run(slices.len(), true, &task);
        }
        // Ordered reduction in fixed slice order.
        let loss = (self.part_loss.iter().sum::<f64>() as f32) * inv;
        self.gw1.fill(0.0);
        self.gb1.fill(0.0);
        self.gw2.fill(0.0);
        self.gb2.fill(0.0);
        for part in &self.part {
            kernels::add_assign(&mut self.gw1, &part.w1);
            kernels::add_assign(&mut self.gb1, &part.b1);
            kernels::add_assign(&mut self.gw2, &part.w2);
            kernels::add_assign(&mut self.gb2, &part.b2);
        }
        adam_apply(
            store,
            lr,
            &["w1", "b1", "w2", "b2"],
            &[
                self.gw1.as_slice(),
                self.gb1.as_slice(),
                self.gw2.as_slice(),
                self.gb2.as_slice(),
            ],
            &mut self.adam_idx,
        )?;
        Ok(loss)
    }
}

// ---------------------------------------------------------------------------
// GRU influence predictor (fused z|r|n gates, sigmoid head)
// ---------------------------------------------------------------------------

fn gru_dims(model: &ModelSpec) -> Result<(usize, usize, usize)> {
    let w_x = model.param("w_x")?;
    let hid = model.param("w_h")?.shape[0];
    let u = model.param("w_o")?.shape[1];
    Ok((w_x.shape[0], hid, u))
}

struct GruStep {
    b: usize,
    d_dim: usize,
    hid: usize,
    u_dim: usize,
    gx: Vec<f32>,
    gh: Vec<f32>,
    slices: Vec<(usize, usize)>,
    par: Par,
}

impl GruStep {
    fn new(art: &ArtifactSpec, model: &ModelSpec, par: &Par) -> Result<GruStep> {
        let (d_dim, hid, u_dim) = gru_dims(model)?;
        let b = data_shape(art, "d")?[0];
        Ok(GruStep {
            b,
            d_dim,
            hid,
            u_dim,
            gx: vec![0.0; b * 3 * hid],
            gh: vec![0.0; b * 3 * hid],
            slices: nn_slices(b),
            par: par.clone(),
        })
    }

    fn run(
        &mut self,
        store: &ParamStore,
        h: &[f32],
        d: &[f32],
        probs: &mut [f32],
        h_new: &mut [f32],
    ) -> Result<()> {
        let (dd, hid, u) = (self.d_dim, self.hid, self.u_dim);
        let view = GruView::resolve(store)?;
        debug_assert_eq!((view.d_dim, view.hid, view.u_dim), (dd, hid, u));
        let slices = &self.slices;
        let gx = SendSliceMut::new(&mut self.gx);
        let gh = SendSliceMut::new(&mut self.gh);
        let hn = SendSliceMut::new(h_new);
        let pr = SendSliceMut::new(probs);
        let task = |si: usize| {
            let (r0, r1) = slices[si];
            let m = r1 - r0;
            // SAFETY: disjoint row bands; Par::run blocks until done.
            let (gxs, ghs, hns, ps) = unsafe {
                (
                    gx.range(r0 * 3 * hid, m * 3 * hid),
                    gh.range(r0 * 3 * hid, m * 3 * hid),
                    hn.range(r0 * hid, m * hid),
                    pr.range(r0 * u, m * u),
                )
            };
            view.step_band(m, &h[r0 * hid..r1 * hid], &d[r0 * dd..r1 * dd], ps, hns, gxs, ghs);
        };
        self.par.run(slices.len(), self.b >= PAR_MIN_FWD_ROWS, &task);
        Ok(())
    }
}

/// Per-slice GRU gradient scratch (preallocated at op build).
struct GruGrads {
    w_x: Vec<f32>,
    w_h: Vec<f32>,
    b_g: Vec<f32>,
    w_o: Vec<f32>,
    b_o: Vec<f32>,
}

impl GruGrads {
    fn new(d_dim: usize, hid: usize, u_dim: usize) -> GruGrads {
        GruGrads {
            w_x: vec![0.0; d_dim * 3 * hid],
            w_h: vec![0.0; hid * 3 * hid],
            b_g: vec![0.0; 3 * hid],
            w_o: vec![0.0; hid * u_dim],
            b_o: vec![0.0; u_dim],
        }
    }

    fn zero(&mut self) {
        for g in [&mut self.w_x, &mut self.w_h, &mut self.b_g, &mut self.w_o, &mut self.b_o] {
            g.fill(0.0);
        }
    }
}

struct GruUpdate {
    b: usize,
    t: usize,
    d_dim: usize,
    hid: usize,
    u_dim: usize,
    /// Hidden states `[T+1, B, H]` (slot 0 = zeros).
    h: Vec<f32>,
    /// Per-step gate activations `[T, B, H]` each.
    z: Vec<f32>,
    r: Vec<f32>,
    n_: Vec<f32>,
    /// Recurrent candidate pre-activation `(h_t @ w_h)` n-block `[T, B, H]`.
    ghn: Vec<f32>,
    /// Output-head logits `[T, B, U]`.
    logits: Vec<f32>,
    /// Time-major gather of the `[B, T, D]` input window.
    xt: Vec<f32>,
    gx: Vec<f32>,
    gh: Vec<f32>,
    g_l: Vec<f32>,
    dh: Vec<f32>,
    carry: Vec<f32>,
    /// Reduced (total) gradients.
    gw_x: Vec<f32>,
    gw_h: Vec<f32>,
    gb_g: Vec<f32>,
    gw_o: Vec<f32>,
    gb_o: Vec<f32>,
    /// Fixed slice grid over the `B` sequences (rows are independent
    /// through time, so each slice runs its own forward + backward scan).
    slices: Vec<(usize, usize)>,
    part: Vec<GruGrads>,
    part_loss: Vec<f64>,
    adam_idx: Vec<[usize; 3]>,
    par: Par,
}

impl GruUpdate {
    fn new(art: &ArtifactSpec, model: &ModelSpec, par: &Par) -> Result<GruUpdate> {
        let (d_dim, hid, u_dim) = gru_dims(model)?;
        let seqs = data_shape(art, "seqs")?;
        let (b, t) = (seqs[0], seqs[1]);
        let slices = nn_slices(b);
        let part = slices.iter().map(|_| GruGrads::new(d_dim, hid, u_dim)).collect::<Vec<_>>();
        let part_loss = vec![0.0f64; slices.len()];
        Ok(GruUpdate {
            b,
            t,
            d_dim,
            hid,
            u_dim,
            h: vec![0.0; (t + 1) * b * hid],
            z: vec![0.0; t * b * hid],
            r: vec![0.0; t * b * hid],
            n_: vec![0.0; t * b * hid],
            ghn: vec![0.0; t * b * hid],
            logits: vec![0.0; t * b * u_dim],
            xt: vec![0.0; b * d_dim],
            gx: vec![0.0; b * 3 * hid],
            gh: vec![0.0; b * 3 * hid],
            g_l: vec![0.0; b * u_dim],
            dh: vec![0.0; b * hid],
            carry: vec![0.0; b * hid],
            gw_x: vec![0.0; d_dim * 3 * hid],
            gw_h: vec![0.0; hid * 3 * hid],
            gb_g: vec![0.0; 3 * hid],
            gw_o: vec![0.0; hid * u_dim],
            gb_o: vec![0.0; u_dim],
            slices,
            part,
            part_loss,
            adam_idx: Vec::with_capacity(5),
            par: par.clone(),
        })
    }

    /// One Adam step of truncated BPTT over the `[B, T, D]` windows
    /// (`aip_gru_update`: BCE-with-logits on every step's head output).
    ///
    /// Sequences are independent through time, so each slice of the fixed
    /// row grid runs its *own* forward scan and backward-through-time scan
    /// over its rows; per-slice gradients and f64 loss sums are reduced in
    /// slice order afterwards (bitwise identical for every `nn_workers`).
    fn run(
        &mut self,
        store: &mut ParamStore,
        lr: f32,
        seqs: &[f32],
        targets: &[f32],
    ) -> Result<f32> {
        let (b, t_len, dd, hid, u) = (self.b, self.t, self.d_dim, self.hid, self.u_dim);
        let (bh, bu) = (b * hid, b * u);
        let inv = 1.0 / (b * t_len * u) as f32;
        {
            let w_x = store.get("w_x")?;
            let w_h = store.get("w_h")?;
            let b_g = store.get("b_g")?;
            let w_o = store.get("w_o")?;
            let b_o = store.get("b_o")?;
            let slices = &self.slices;
            let h = SendSliceMut::new(&mut self.h);
            let z = SendSliceMut::new(&mut self.z);
            let rg = SendSliceMut::new(&mut self.r);
            let ng = SendSliceMut::new(&mut self.n_);
            let ghn = SendSliceMut::new(&mut self.ghn);
            let lg = SendSliceMut::new(&mut self.logits);
            let xt = SendSliceMut::new(&mut self.xt);
            let gx = SendSliceMut::new(&mut self.gx);
            let gh = SendSliceMut::new(&mut self.gh);
            let gl = SendSliceMut::new(&mut self.g_l);
            let dh = SendSliceMut::new(&mut self.dh);
            let carry = SendSliceMut::new(&mut self.carry);
            let pg = SendSliceMut::new(&mut self.part);
            let pl = SendSliceMut::new(&mut self.part_loss);
            let task = |si: usize| {
                let (r0, r1) = slices[si];
                let m = r1 - r0;
                // SAFETY: every range below is this slice's disjoint row
                // band (per time-plane for the [T, B, ·] buffers); Par::run
                // blocks until all slices have completed.
                let (xts, gxs, ghs, gls, dhs, carrys) = unsafe {
                    (
                        xt.range(r0 * dd, m * dd),
                        gx.range(r0 * 3 * hid, m * 3 * hid),
                        gh.range(r0 * 3 * hid, m * 3 * hid),
                        gl.range(r0 * u, m * u),
                        dh.range(r0 * hid, m * hid),
                        carry.range(r0 * hid, m * hid),
                    )
                };
                let g = unsafe { &mut pg.range(si, 1)[0] };
                let loss_slot = unsafe { &mut pl.range(si, 1)[0] };
                let seqs_b = &seqs[r0 * t_len * dd..r1 * t_len * dd];
                let targ_b = &targets[r0 * t_len * u..r1 * t_len * u];

                // Forward scan, recording gates and hidden states.
                unsafe { h.range(r0 * hid, m * hid) }.fill(0.0);
                let mut loss_sum = 0.0f64;
                for step in 0..t_len {
                    for li in 0..m {
                        let src = (li * t_len + step) * dd;
                        xts[li * dd..(li + 1) * dd].copy_from_slice(&seqs_b[src..src + dd]);
                    }
                    kernels::linear_into(xts, w_x, Some(b_g), gxs, m, dd, 3 * hid, Act::None);
                    let h_t = unsafe { &*h.range(step * bh + r0 * hid, m * hid) };
                    let h_next = unsafe { h.range((step + 1) * bh + r0 * hid, m * hid) };
                    kernels::linear_into(h_t, w_h, None, ghs, m, hid, 3 * hid, Act::None);
                    let (zs, rs, ns, ghns) = unsafe {
                        (
                            z.range(step * bh + r0 * hid, m * hid),
                            rg.range(step * bh + r0 * hid, m * hid),
                            ng.range(step * bh + r0 * hid, m * hid),
                            ghn.range(step * bh + r0 * hid, m * hid),
                        )
                    };
                    for li in 0..m {
                        for j in 0..hid {
                            let g3 = li * 3 * hid;
                            let zv = kernels::sigmoid(gxs[g3 + j] + ghs[g3 + j]);
                            let rv = kernels::sigmoid(gxs[g3 + hid + j] + ghs[g3 + hid + j]);
                            let ghn_v = ghs[g3 + 2 * hid + j];
                            let nv = (gxs[g3 + 2 * hid + j] + rv * ghn_v).tanh();
                            let idx = li * hid + j;
                            zs[idx] = zv;
                            rs[idx] = rv;
                            ns[idx] = nv;
                            ghns[idx] = ghn_v;
                            h_next[idx] = (1.0 - zv) * nv + zv * h_t[idx];
                        }
                    }
                    let lrows = unsafe { lg.range(step * bu + r0 * u, m * u) };
                    kernels::linear_into(h_next, w_o, Some(b_o), lrows, m, hid, u, Act::None);
                    for li in 0..m {
                        let lrow = &lrows[li * u..(li + 1) * u];
                        let yrow = &targ_b[(li * t_len + step) * u..(li * t_len + step + 1) * u];
                        for (&l, &y) in lrow.iter().zip(yrow) {
                            loss_sum += kernels::bce_with_logits_elem(l, y) as f64;
                        }
                    }
                }
                *loss_slot = loss_sum;

                // Backward through time for this slice's rows.
                g.zero();
                carrys.fill(0.0);
                for step in (0..t_len).rev() {
                    let lrows = unsafe { &*lg.range(step * bu + r0 * u, m * u) };
                    for li in 0..m {
                        let lrow = &lrows[li * u..(li + 1) * u];
                        let yrow = &targ_b[(li * t_len + step) * u..(li * t_len + step + 1) * u];
                        let glrow = &mut gls[li * u..(li + 1) * u];
                        for ((gl_, &l), &y) in glrow.iter_mut().zip(lrow).zip(yrow) {
                            *gl_ = (kernels::sigmoid(l) - y) * inv;
                        }
                    }
                    let h_next = unsafe { &*h.range((step + 1) * bh + r0 * hid, m * hid) };
                    let h_t = unsafe { &*h.range(step * bh + r0 * hid, m * hid) };
                    kernels::matmul_at_b_acc(h_next, gls, &mut g.w_o, m, hid, u);
                    kernels::colsum_acc(gls, &mut g.b_o, u);
                    kernels::matmul_bt_into(gls, w_o, dhs, m, u, hid);
                    for (d_, &c) in dhs.iter_mut().zip(carrys.iter()) {
                        *d_ += c;
                    }
                    let (zs, rs, ns, ghns) = unsafe {
                        (
                            &*z.range(step * bh + r0 * hid, m * hid),
                            &*rg.range(step * bh + r0 * hid, m * hid),
                            &*ng.range(step * bh + r0 * hid, m * hid),
                            &*ghn.range(step * bh + r0 * hid, m * hid),
                        )
                    };
                    for li in 0..m {
                        for j in 0..hid {
                            let idx = li * hid + j;
                            let (zv, rv, nv, ghn_v) = (zs[idx], rs[idx], ns[idx], ghns[idx]);
                            let dh_v = dhs[idx];
                            let h_prev = h_t[idx];
                            let dz = dh_v * (h_prev - nv);
                            let dn = dh_v * (1.0 - zv);
                            let dan = dn * (1.0 - nv * nv);
                            let dr = dan * ghn_v;
                            let daz = dz * zv * (1.0 - zv);
                            let dar = dr * rv * (1.0 - rv);
                            let g3 = li * 3 * hid;
                            gxs[g3 + j] = daz;
                            ghs[g3 + j] = daz;
                            gxs[g3 + hid + j] = dar;
                            ghs[g3 + hid + j] = dar;
                            gxs[g3 + 2 * hid + j] = dan;
                            ghs[g3 + 2 * hid + j] = dan * rv;
                            carrys[idx] = dh_v * zv;
                        }
                    }
                    for li in 0..m {
                        let src = (li * t_len + step) * dd;
                        xts[li * dd..(li + 1) * dd].copy_from_slice(&seqs_b[src..src + dd]);
                    }
                    kernels::matmul_at_b_acc(xts, gxs, &mut g.w_x, m, dd, 3 * hid);
                    kernels::colsum_acc(gxs, &mut g.b_g, 3 * hid);
                    kernels::matmul_at_b_acc(h_t, ghs, &mut g.w_h, m, hid, 3 * hid);
                    kernels::matmul_bt_acc(ghs, w_h, carrys, m, 3 * hid, hid);
                }
            };
            self.par.run(slices.len(), true, &task);
        }
        // Ordered reduction in fixed slice order.
        let loss = (self.part_loss.iter().sum::<f64>() as f32) * inv;
        self.gw_x.fill(0.0);
        self.gw_h.fill(0.0);
        self.gb_g.fill(0.0);
        self.gw_o.fill(0.0);
        self.gb_o.fill(0.0);
        for part in &self.part {
            kernels::add_assign(&mut self.gw_x, &part.w_x);
            kernels::add_assign(&mut self.gw_h, &part.w_h);
            kernels::add_assign(&mut self.gb_g, &part.b_g);
            kernels::add_assign(&mut self.gw_o, &part.w_o);
            kernels::add_assign(&mut self.gb_o, &part.b_o);
        }
        adam_apply(
            store,
            lr,
            &["w_x", "w_h", "b_g", "w_o", "b_o"],
            &[
                self.gw_x.as_slice(),
                self.gw_h.as_slice(),
                self.gb_g.as_slice(),
                self.gw_o.as_slice(),
                self.gb_o.as_slice(),
            ],
            &mut self.adam_idx,
        )?;
        Ok(loss)
    }
}
