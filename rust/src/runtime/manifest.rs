//! Parser for `artifacts/manifest.txt`, the ABI contract between the AOT
//! emitter (`python/compile/aot.py`) and the Rust runtime.
//!
//! The manifest declares, for every compiled artifact, the exact positional
//! call convention: which model tensors are bound as leading parameters
//! (and which outputs are written back), followed by the data tensors the
//! caller supplies. Shapes are validated on every call.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Element type of a tensor (all artifacts use f32 except integer inputs
/// like PPO actions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype '{other}'"),
        }
    }
}

/// A named tensor with a static shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn dims_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|&d| d as i64).collect()
    }
}

/// One positional input/output of an artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Binding {
    /// Bound from (input) / written back to (output) the model's parameter
    /// store, by tensor name.
    Param(String),
    /// Supplied by (input) / returned to (output) the caller.
    Data(TensorSpec),
}

/// A model: the ordered parameter tensors backing `<model>.params.bin`.
#[derive(Debug, Clone, Default)]
pub struct ModelSpec {
    pub name: String,
    pub params: Vec<TensorSpec>,
}

impl ModelSpec {
    pub fn param(&self, name: &str) -> Result<&TensorSpec> {
        self.params
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| anyhow!("model {} has no param '{name}'", self.name))
    }

    pub fn total_numel(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }
}

/// A compiled artifact's call ABI.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub model: String,
    pub hlo_file: String,
    pub inputs: Vec<Binding>,
    pub outputs: Vec<Binding>,
}

impl ArtifactSpec {
    pub fn data_inputs(&self) -> impl Iterator<Item = &TensorSpec> {
        self.inputs.iter().filter_map(|b| match b {
            Binding::Data(t) => Some(t),
            _ => None,
        })
    }

    pub fn data_outputs(&self) -> impl Iterator<Item = &TensorSpec> {
        self.outputs.iter().filter_map(|b| match b {
            Binding::Data(t) => Some(t),
            _ => None,
        })
    }
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub geometry: BTreeMap<String, i64>,
    pub models: BTreeMap<String, ModelSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let path = dir.as_ref().join("manifest.txt");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first to AOT-compile the models",
                path.display()
            )
        })?;
        Self::parse(&text)
    }

    pub fn geom(&self, key: &str) -> Result<i64> {
        self.geometry
            .get(key)
            .copied()
            .ok_or_else(|| anyhow!("manifest geometry missing '{key}'"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models.get(name).ok_or_else(|| anyhow!("unknown model '{name}'"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| anyhow!("unknown artifact '{name}'"))
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        let mut lines = text.lines().map(str::trim).enumerate();

        fn tensor_from(parts: &[&str]) -> Result<TensorSpec> {
            // name dtype dims...
            anyhow::ensure!(parts.len() >= 2, "malformed tensor spec {parts:?}");
            let shape: Result<Vec<usize>, _> =
                parts[2..].iter().map(|d| d.parse::<usize>()).collect();
            Ok(TensorSpec {
                name: parts[0].to_string(),
                dtype: DType::parse(parts[1])?,
                shape: shape.context("bad dims")?,
            })
        }

        while let Some((ln, line)) = lines.next() {
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            match parts.next().unwrap() {
                "version" => {
                    let v = parts.next().unwrap_or("?");
                    anyhow::ensure!(v == "1", "unsupported manifest version {v}");
                }
                "geometry" => {
                    for (ln2, l) in lines.by_ref() {
                        if l == "endgeometry" {
                            break;
                        }
                        let mut p = l.split_whitespace();
                        let k = p.next().ok_or_else(|| anyhow!("line {}: empty", ln2 + 1))?;
                        let v: i64 = p
                            .next()
                            .ok_or_else(|| anyhow!("line {}: missing value", ln2 + 1))?
                            .parse()?;
                        m.geometry.insert(k.to_string(), v);
                    }
                }
                "model" => {
                    let name = parts.next().ok_or_else(|| anyhow!("line {ln}: model name"))?;
                    let mut spec = ModelSpec { name: name.to_string(), params: Vec::new() };
                    for (ln2, l) in lines.by_ref() {
                        if l == "endmodel" {
                            break;
                        }
                        let ps: Vec<&str> = l.split_whitespace().collect();
                        anyhow::ensure!(
                            ps.first() == Some(&"param"),
                            "line {}: expected 'param'",
                            ln2 + 1
                        );
                        spec.params.push(tensor_from(&ps[1..])?);
                    }
                    m.models.insert(name.to_string(), spec);
                }
                "artifact" => {
                    let name = parts.next().ok_or_else(|| anyhow!("line {ln}: artifact name"))?;
                    let mut art = ArtifactSpec {
                        name: name.to_string(),
                        model: String::new(),
                        hlo_file: String::new(),
                        inputs: Vec::new(),
                        outputs: Vec::new(),
                    };
                    for (ln2, l) in lines.by_ref() {
                        if l == "endartifact" {
                            break;
                        }
                        let ps: Vec<&str> = l.split_whitespace().collect();
                        match ps.as_slice() {
                            ["model", mn] => art.model = mn.to_string(),
                            ["hlo", f] => art.hlo_file = f.to_string(),
                            ["input", "param", n] => {
                                art.inputs.push(Binding::Param(n.to_string()))
                            }
                            ["output", "param", n] => {
                                art.outputs.push(Binding::Param(n.to_string()))
                            }
                            ["input", "data", rest @ ..] => {
                                art.inputs.push(Binding::Data(tensor_from(rest)?))
                            }
                            ["output", "data", rest @ ..] => {
                                art.outputs.push(Binding::Data(tensor_from(rest)?))
                            }
                            other => bail!("line {}: bad artifact line {other:?}", ln2 + 1),
                        }
                    }
                    anyhow::ensure!(!art.model.is_empty(), "artifact {name}: missing model");
                    anyhow::ensure!(!art.hlo_file.is_empty(), "artifact {name}: missing hlo");
                    m.artifacts.insert(name.to_string(), art);
                }
                other => bail!("line {}: unexpected token '{other}'", ln + 1),
            }
        }

        // Cross-validate: every param binding must exist in its model.
        for art in m.artifacts.values() {
            let model = m
                .models
                .get(&art.model)
                .ok_or_else(|| anyhow!("artifact {} references unknown model", art.name))?;
            for b in art.inputs.iter().chain(&art.outputs) {
                if let Binding::Param(n) = b {
                    model.param(n).with_context(|| format!("artifact {}", art.name))?;
                }
            }
        }
        Ok(m)
    }
}

// ---------------------------------------------------------------------------
// Synthesized manifest (native backend)
// ---------------------------------------------------------------------------

// Domain geometry shared with the Rust simulators and the Python emitter
// (`python/compile/model.py`). The synthesized manifest carries the same
// keys as the emitted one, so `Runtime::geom` works identically.
pub const TRAFFIC_OBS: usize = 42;
pub const TRAFFIC_ACT: usize = 2;
pub const TRAFFIC_DSET: usize = 40;
pub const TRAFFIC_ALSH: usize = 43;
pub const TRAFFIC_U: usize = 4;
pub const WH_OBS: usize = 37;
pub const WH_ACT: usize = 5;
pub const WH_DSET: usize = 24;
pub const WH_ALSH: usize = 49;
pub const WH_U: usize = 12;
pub const WH_STACK: usize = 8;
pub const NN_HID: usize = 64;

/// Batch geometry of a synthesized manifest — the knobs that vary per
/// experiment config (the domain dims above are fixed by the simulators).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SynthGeometry {
    /// Vectorized envs per training simulator (batched forward width).
    pub rollout_b: usize,
    /// Steps per PPO rollout.
    pub rollout_t: usize,
    pub ppo_epochs: usize,
    pub ppo_minibatch: usize,
    /// FNN AIP training minibatch.
    pub aip_batch: usize,
    /// GRU AIP BPTT batch / window length.
    pub gru_seq_b: usize,
    pub gru_seq_t: usize,
}

impl Default for SynthGeometry {
    /// Matches the AOT emitter's constants (`python/compile/model.py`), so
    /// a default-geometry native runtime exposes exactly the artifact set
    /// `make artifacts` would have produced.
    fn default() -> Self {
        SynthGeometry {
            rollout_b: 16,
            rollout_t: 128,
            ppo_epochs: 4,
            ppo_minibatch: 256,
            aip_batch: 256,
            gru_seq_b: 16,
            gru_seq_t: 32,
        }
    }
}

fn ts(name: &str, dtype: DType, shape: &[usize]) -> TensorSpec {
    TensorSpec { name: name.to_string(), dtype, shape: shape.to_vec() }
}

fn f32t(name: &str, shape: &[usize]) -> TensorSpec {
    ts(name, DType::F32, shape)
}

/// Base params + Adam slots (`m.*`, `v.*`, `adam_t`), mirroring
/// `_with_adam` in `python/compile/aot.py`.
fn model_with_adam(name: &str, base: Vec<TensorSpec>) -> ModelSpec {
    let mut params = base.clone();
    for prefix in ["m", "v"] {
        params.extend(base.iter().map(|t| TensorSpec {
            name: format!("{prefix}.{}", t.name),
            dtype: t.dtype,
            shape: t.shape.clone(),
        }));
    }
    params.push(f32t("adam_t", &[1]));
    ModelSpec { name: name.to_string(), params }
}

fn policy_base(obs: usize, act: usize) -> Vec<TensorSpec> {
    vec![
        f32t("w1", &[obs, NN_HID]),
        f32t("b1", &[NN_HID]),
        f32t("w2", &[NN_HID, NN_HID]),
        f32t("b2", &[NN_HID]),
        f32t("w_pi", &[NN_HID, act]),
        f32t("b_pi", &[act]),
        f32t("w_v", &[NN_HID, 1]),
        f32t("b_v", &[1]),
    ]
}

fn fnn_base(d: usize, u: usize) -> Vec<TensorSpec> {
    vec![
        f32t("w1", &[d, NN_HID]),
        f32t("b1", &[NN_HID]),
        f32t("w2", &[NN_HID, u]),
        f32t("b2", &[u]),
    ]
}

fn gru_base(d: usize, u: usize) -> Vec<TensorSpec> {
    vec![
        f32t("w_x", &[d, 3 * NN_HID]),
        f32t("w_h", &[NN_HID, 3 * NN_HID]),
        f32t("b_g", &[3 * NN_HID]),
        f32t("w_o", &[NN_HID, u]),
        f32t("b_o", &[u]),
    ]
}

/// Build an artifact spec against `model`. Forward artifacts bind the base
/// parameters as inputs; training artifacts bind (and write back) the full
/// parameter list including Adam state — the same ABI `aot.py` emits.
fn synth_artifact(
    name: &str,
    model: &ModelSpec,
    train: bool,
    data_in: Vec<TensorSpec>,
    data_out: Vec<TensorSpec>,
) -> ArtifactSpec {
    let base_n = (model.params.len() - 1) / 3;
    let bound: &[TensorSpec] = if train { &model.params } else { &model.params[..base_n] };
    let mut inputs: Vec<Binding> = bound.iter().map(|p| Binding::Param(p.name.clone())).collect();
    inputs.extend(data_in.into_iter().map(Binding::Data));
    let mut outputs: Vec<Binding> = if train {
        model.params.iter().map(|p| Binding::Param(p.name.clone())).collect()
    } else {
        Vec::new()
    };
    outputs.extend(data_out.into_iter().map(Binding::Data));
    ArtifactSpec {
        name: name.to_string(),
        model: model.name.clone(),
        hlo_file: format!("{name}.hlo.txt"),
        inputs,
        outputs,
    }
}

impl Manifest {
    /// Synthesize the full artifact registry in memory from config-derived
    /// geometry — no `manifest.txt`, no `make artifacts`. The native
    /// backend executes these artifacts directly on `ParamStore` slices;
    /// names, bindings and shapes match the AOT emitter so every caller
    /// (policy, AIP, trainers) is backend-agnostic.
    pub fn synthesize(g: &SynthGeometry) -> Manifest {
        let mut m = Manifest::default();
        let ppo_n = g.rollout_b * g.rollout_t;

        for (k, v) in [
            ("traffic_obs", TRAFFIC_OBS),
            ("traffic_act", TRAFFIC_ACT),
            ("traffic_dset", TRAFFIC_DSET),
            ("traffic_alsh", TRAFFIC_ALSH),
            ("traffic_u", TRAFFIC_U),
            ("wh_obs", WH_OBS),
            ("wh_act", WH_ACT),
            ("wh_dset", WH_DSET),
            ("wh_alsh", WH_ALSH),
            ("wh_u", WH_U),
            ("wh_stack", WH_STACK),
            ("rollout_b", g.rollout_b),
            ("rollout_t", g.rollout_t),
            ("ppo_rollout_n", ppo_n),
            ("ppo_epochs", g.ppo_epochs),
            ("ppo_minibatch", g.ppo_minibatch),
            ("aip_batch", g.aip_batch),
            ("gru_seq_b", g.gru_seq_b),
            ("gru_seq_t", g.gru_seq_t),
            ("gru_hid", NN_HID),
        ] {
            m.geometry.insert(k.to_string(), v as i64);
        }

        let policies = [
            ("policy_traffic", TRAFFIC_OBS, TRAFFIC_ACT),
            ("policy_warehouse", WH_OBS * WH_STACK, WH_ACT),
            ("policy_warehouse_nm", WH_OBS, WH_ACT),
        ];
        for (name, obs, act) in policies {
            let spec = model_with_adam(name, policy_base(obs, act));
            for b in [g.rollout_b, 1] {
                let art = synth_artifact(
                    &format!("{name}_fwd_b{b}"),
                    &spec,
                    false,
                    vec![f32t("obs", &[b, obs])],
                    vec![f32t("logits", &[b, act]), f32t("value", &[b])],
                );
                m.artifacts.insert(art.name.clone(), art);
            }
            let scalars = || {
                vec![
                    f32t("lr", &[1]),
                    f32t("clip", &[1]),
                    f32t("vf_coef", &[1]),
                    f32t("ent_coef", &[1]),
                    f32t("max_grad_norm", &[1]),
                ]
            };
            let mb = g.ppo_minibatch;
            let mut data_in = scalars();
            data_in.extend([
                f32t("obs", &[mb, obs]),
                ts("actions", DType::I32, &[mb]),
                f32t("advantages", &[mb]),
                f32t("returns", &[mb]),
                f32t("old_logp", &[mb]),
            ]);
            let art = synth_artifact(
                &format!("{name}_update"),
                &spec,
                true,
                data_in,
                vec![f32t("stats", &[6])],
            );
            m.artifacts.insert(art.name.clone(), art);
            let mut data_in = scalars();
            data_in.extend([
                ts("perm", DType::I32, &[g.ppo_epochs, ppo_n]),
                f32t("obs", &[ppo_n, obs]),
                ts("actions", DType::I32, &[ppo_n]),
                f32t("advantages", &[ppo_n]),
                f32t("returns", &[ppo_n]),
                f32t("old_logp", &[ppo_n]),
            ]);
            let art = synth_artifact(
                &format!("{name}_update_fused"),
                &spec,
                true,
                data_in,
                vec![f32t("stats", &[6])],
            );
            m.artifacts.insert(art.name.clone(), art);
            m.models.insert(name.to_string(), spec);
        }

        let fnns = [
            ("aip_traffic", TRAFFIC_DSET, TRAFFIC_U),
            ("aip_traffic_full", TRAFFIC_ALSH, TRAFFIC_U),
            ("aip_warehouse_nm", WH_DSET, WH_U),
        ];
        for (name, d, u) in fnns {
            let spec = model_with_adam(name, fnn_base(d, u));
            for b in [g.rollout_b, 1] {
                let art = synth_artifact(
                    &format!("{name}_fwd_b{b}"),
                    &spec,
                    false,
                    vec![f32t("d", &[b, d])],
                    vec![f32t("probs", &[b, u])],
                );
                m.artifacts.insert(art.name.clone(), art);
            }
            let mb = g.aip_batch;
            let art = synth_artifact(
                &format!("{name}_update"),
                &spec,
                true,
                vec![f32t("lr", &[1]), f32t("d", &[mb, d]), f32t("targets", &[mb, u])],
                vec![f32t("loss", &[1])],
            );
            m.artifacts.insert(art.name.clone(), art);
            m.models.insert(name.to_string(), spec);
        }

        let (name, d, u) = ("aip_warehouse", WH_DSET, WH_U);
        let spec = model_with_adam(name, gru_base(d, u));
        for b in [g.rollout_b, 1] {
            let art = synth_artifact(
                &format!("{name}_step_b{b}"),
                &spec,
                false,
                vec![f32t("h", &[b, NN_HID]), f32t("d", &[b, d])],
                vec![f32t("probs", &[b, u]), f32t("h_new", &[b, NN_HID])],
            );
            m.artifacts.insert(art.name.clone(), art);
        }
        let (sb, st) = (g.gru_seq_b, g.gru_seq_t);
        let art = synth_artifact(
            &format!("{name}_update"),
            &spec,
            true,
            vec![
                f32t("lr", &[1]),
                f32t("seqs", &[sb, st, d]),
                f32t("targets", &[sb, st, u]),
            ],
            vec![f32t("loss", &[1])],
        );
        m.artifacts.insert(art.name.clone(), art);
        m.models.insert(name.to_string(), spec);

        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
version 1

geometry
foo 42
endgeometry

model tiny
param w f32 2 3
param b f32 3
param adam_t f32 1
endmodel

artifact tiny_fwd
model tiny
hlo tiny_fwd.hlo.txt
input param w
input param b
input data x f32 4 2
output data y f32 4 3
endartifact
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.geom("foo").unwrap(), 42);
        let model = m.model("tiny").unwrap();
        assert_eq!(model.params.len(), 3);
        assert_eq!(model.param("w").unwrap().numel(), 6);
        assert_eq!(model.total_numel(), 10);
        let art = m.artifact("tiny_fwd").unwrap();
        assert_eq!(art.inputs.len(), 3);
        assert_eq!(art.data_inputs().count(), 1);
        assert_eq!(art.data_outputs().next().unwrap().shape, vec![4, 3]);
    }

    #[test]
    fn rejects_unknown_param_binding() {
        let bad = SAMPLE.replace("input param w", "input param nope");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_bad_version() {
        assert!(Manifest::parse("version 9").is_err());
    }

    #[test]
    fn missing_keys_are_errors() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.geom("nope").is_err());
        assert!(m.model("nope").is_err());
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn synthesized_manifest_mirrors_the_emitter() {
        let m = Manifest::synthesize(&SynthGeometry::default());
        assert_eq!(m.geom("traffic_obs").unwrap(), 42);
        assert_eq!(m.geom("aip_batch").unwrap(), 256);
        assert_eq!(m.geom("ppo_rollout_n").unwrap(), 16 * 128);
        // Same per-model shape as the emitted manifest: 8 base tensors,
        // Adam-doubled, plus the step counter.
        let pol = m.model("policy_traffic").unwrap();
        assert_eq!(pol.params.len(), 8 * 3 + 1);
        assert_eq!(pol.param("w1").unwrap().shape, vec![42, 64]);
        assert_eq!(m.model("aip_warehouse").unwrap().param("w_x").unwrap().shape, vec![24, 192]);
        // The full artifact registry: 4 per policy, 3 per FNN AIP, 3 GRU.
        assert_eq!(m.artifacts.len(), 3 * 4 + 3 * 3 + 3);
        let fwd = m.artifact("policy_traffic_fwd_b16").unwrap();
        assert_eq!(fwd.data_inputs().count(), 1);
        assert_eq!(fwd.data_outputs().count(), 2);
        assert_eq!(fwd.inputs.len(), 8 + 1, "forward binds base params only");
        let upd = m.artifact("policy_traffic_update").unwrap();
        assert_eq!(upd.inputs.len(), 25 + 10, "update binds full Adam state");
        assert!(upd.outputs.iter().any(|b| matches!(b, Binding::Param(_))));
    }

    #[test]
    fn synthesized_geometry_follows_config_knobs() {
        let g = SynthGeometry { rollout_b: 8, rollout_t: 32, ..SynthGeometry::default() };
        let m = Manifest::synthesize(&g);
        assert!(m.artifact("policy_traffic_fwd_b8").is_ok());
        assert!(m.artifact("aip_warehouse_step_b8").is_ok());
        let fused = m.artifact("policy_traffic_update_fused").unwrap();
        let perm = fused.data_inputs().find(|t| t.name == "perm").unwrap();
        assert_eq!(perm.shape, vec![4, 8 * 32]);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        if let Ok(m) = Manifest::load("artifacts") {
            assert!(m.artifacts.len() >= 21);
            assert_eq!(m.geom("traffic_obs").unwrap(), 42);
            let pol = m.model("policy_traffic").unwrap();
            assert_eq!(pol.params.len(), 8 * 3 + 1);
        }
    }
}
