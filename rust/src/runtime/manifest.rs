//! Parser for `artifacts/manifest.txt`, the ABI contract between the AOT
//! emitter (`python/compile/aot.py`) and the Rust runtime.
//!
//! The manifest declares, for every compiled artifact, the exact positional
//! call convention: which model tensors are bound as leading parameters
//! (and which outputs are written back), followed by the data tensors the
//! caller supplies. Shapes are validated on every call.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Element type of a tensor (all artifacts use f32 except integer inputs
/// like PPO actions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype '{other}'"),
        }
    }
}

/// A named tensor with a static shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn dims_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|&d| d as i64).collect()
    }
}

/// One positional input/output of an artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Binding {
    /// Bound from (input) / written back to (output) the model's parameter
    /// store, by tensor name.
    Param(String),
    /// Supplied by (input) / returned to (output) the caller.
    Data(TensorSpec),
}

/// A model: the ordered parameter tensors backing `<model>.params.bin`.
#[derive(Debug, Clone, Default)]
pub struct ModelSpec {
    pub name: String,
    pub params: Vec<TensorSpec>,
}

impl ModelSpec {
    pub fn param(&self, name: &str) -> Result<&TensorSpec> {
        self.params
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| anyhow!("model {} has no param '{name}'", self.name))
    }

    pub fn total_numel(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }
}

/// A compiled artifact's call ABI.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub model: String,
    pub hlo_file: String,
    pub inputs: Vec<Binding>,
    pub outputs: Vec<Binding>,
}

impl ArtifactSpec {
    pub fn data_inputs(&self) -> impl Iterator<Item = &TensorSpec> {
        self.inputs.iter().filter_map(|b| match b {
            Binding::Data(t) => Some(t),
            _ => None,
        })
    }

    pub fn data_outputs(&self) -> impl Iterator<Item = &TensorSpec> {
        self.outputs.iter().filter_map(|b| match b {
            Binding::Data(t) => Some(t),
            _ => None,
        })
    }
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub geometry: BTreeMap<String, i64>,
    pub models: BTreeMap<String, ModelSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let path = dir.as_ref().join("manifest.txt");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first to AOT-compile the models",
                path.display()
            )
        })?;
        Self::parse(&text)
    }

    pub fn geom(&self, key: &str) -> Result<i64> {
        self.geometry
            .get(key)
            .copied()
            .ok_or_else(|| anyhow!("manifest geometry missing '{key}'"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models.get(name).ok_or_else(|| anyhow!("unknown model '{name}'"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| anyhow!("unknown artifact '{name}'"))
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        let mut lines = text.lines().map(str::trim).enumerate();

        fn tensor_from(parts: &[&str]) -> Result<TensorSpec> {
            // name dtype dims...
            anyhow::ensure!(parts.len() >= 2, "malformed tensor spec {parts:?}");
            let shape: Result<Vec<usize>, _> =
                parts[2..].iter().map(|d| d.parse::<usize>()).collect();
            Ok(TensorSpec {
                name: parts[0].to_string(),
                dtype: DType::parse(parts[1])?,
                shape: shape.context("bad dims")?,
            })
        }

        while let Some((ln, line)) = lines.next() {
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            match parts.next().unwrap() {
                "version" => {
                    let v = parts.next().unwrap_or("?");
                    anyhow::ensure!(v == "1", "unsupported manifest version {v}");
                }
                "geometry" => {
                    for (ln2, l) in lines.by_ref() {
                        if l == "endgeometry" {
                            break;
                        }
                        let mut p = l.split_whitespace();
                        let k = p.next().ok_or_else(|| anyhow!("line {}: empty", ln2 + 1))?;
                        let v: i64 = p
                            .next()
                            .ok_or_else(|| anyhow!("line {}: missing value", ln2 + 1))?
                            .parse()?;
                        m.geometry.insert(k.to_string(), v);
                    }
                }
                "model" => {
                    let name = parts.next().ok_or_else(|| anyhow!("line {ln}: model name"))?;
                    let mut spec = ModelSpec { name: name.to_string(), params: Vec::new() };
                    for (ln2, l) in lines.by_ref() {
                        if l == "endmodel" {
                            break;
                        }
                        let ps: Vec<&str> = l.split_whitespace().collect();
                        anyhow::ensure!(
                            ps.first() == Some(&"param"),
                            "line {}: expected 'param'",
                            ln2 + 1
                        );
                        spec.params.push(tensor_from(&ps[1..])?);
                    }
                    m.models.insert(name.to_string(), spec);
                }
                "artifact" => {
                    let name =
                        parts.next().ok_or_else(|| anyhow!("line {ln}: artifact name"))?;
                    let mut art = ArtifactSpec {
                        name: name.to_string(),
                        model: String::new(),
                        hlo_file: String::new(),
                        inputs: Vec::new(),
                        outputs: Vec::new(),
                    };
                    for (ln2, l) in lines.by_ref() {
                        if l == "endartifact" {
                            break;
                        }
                        let ps: Vec<&str> = l.split_whitespace().collect();
                        match ps.as_slice() {
                            ["model", mn] => art.model = mn.to_string(),
                            ["hlo", f] => art.hlo_file = f.to_string(),
                            ["input", "param", n] => {
                                art.inputs.push(Binding::Param(n.to_string()))
                            }
                            ["output", "param", n] => {
                                art.outputs.push(Binding::Param(n.to_string()))
                            }
                            ["input", "data", rest @ ..] => {
                                art.inputs.push(Binding::Data(tensor_from(rest)?))
                            }
                            ["output", "data", rest @ ..] => {
                                art.outputs.push(Binding::Data(tensor_from(rest)?))
                            }
                            other => bail!("line {}: bad artifact line {other:?}", ln2 + 1),
                        }
                    }
                    anyhow::ensure!(!art.model.is_empty(), "artifact {name}: missing model");
                    anyhow::ensure!(!art.hlo_file.is_empty(), "artifact {name}: missing hlo");
                    m.artifacts.insert(name.to_string(), art);
                }
                other => bail!("line {}: unexpected token '{other}'", ln + 1),
            }
        }

        // Cross-validate: every param binding must exist in its model.
        for art in m.artifacts.values() {
            let model = m
                .models
                .get(&art.model)
                .ok_or_else(|| anyhow!("artifact {} references unknown model", art.name))?;
            for b in art.inputs.iter().chain(&art.outputs) {
                if let Binding::Param(n) = b {
                    model.param(n).with_context(|| format!("artifact {}", art.name))?;
                }
            }
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
version 1

geometry
foo 42
endgeometry

model tiny
param w f32 2 3
param b f32 3
param adam_t f32 1
endmodel

artifact tiny_fwd
model tiny
hlo tiny_fwd.hlo.txt
input param w
input param b
input data x f32 4 2
output data y f32 4 3
endartifact
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.geom("foo").unwrap(), 42);
        let model = m.model("tiny").unwrap();
        assert_eq!(model.params.len(), 3);
        assert_eq!(model.param("w").unwrap().numel(), 6);
        assert_eq!(model.total_numel(), 10);
        let art = m.artifact("tiny_fwd").unwrap();
        assert_eq!(art.inputs.len(), 3);
        assert_eq!(art.data_inputs().count(), 1);
        assert_eq!(art.data_outputs().next().unwrap().shape, vec![4, 3]);
    }

    #[test]
    fn rejects_unknown_param_binding() {
        let bad = SAMPLE.replace("input param w", "input param nope");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_bad_version() {
        assert!(Manifest::parse("version 9").is_err());
    }

    #[test]
    fn missing_keys_are_errors() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.geom("nope").is_err());
        assert!(m.model("nope").is_err());
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        if let Ok(m) = Manifest::load("artifacts") {
            assert!(m.artifacts.len() >= 21);
            assert_eq!(m.geom("traffic_obs").unwrap(), 42);
            let pol = m.model("policy_traffic").unwrap();
            assert_eq!(pol.params.len(), 8 * 3 + 1);
        }
    }
}
