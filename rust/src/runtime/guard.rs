//! Training health guard: cheap, read-only invariant checks over the
//! per-update metrics every learner already produces, classifying each
//! update as healthy, anomalous, or diverged (`[health]` in the config).
//!
//! The guard *observes*; it never mutates training state. Non-finite
//! loss / gradient norm / parameter norm is an immediate divergence. A
//! finite gradient norm that spikes past `spike_factor` times the rolling
//! window mean is an anomaly; `max_anomalies` *consecutive* anomalies
//! escalate to divergence. Recovery (rollback to the newest valid
//! checkpoint, then quarantine once `max_rollbacks` is exhausted) is
//! driven by the coordinator (`coordinator/multi.rs`) — the guard only
//! keeps the books: the rolling window, the anomaly streak, and the
//! rollback budget.
//!
//! Determinism contract: because every check is a pure read of metrics
//! the trainer computes anyway (no RNG draw, no float mutated), a
//! guard-on clean run is bitwise identical to a guard-off run. The
//! rollback budget is deliberately *not* part of any serialized state:
//! restoring a checkpoint must not also restore the budget the rollback
//! just spent, so guard state lives per process incarnation only.

use crate::config::HealthConfig;
use crate::nn::ParamStore;
use anyhow::Result;

/// Classification of one training update.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthStatus {
    /// All invariants hold.
    Healthy,
    /// Finite but suspicious (grad-norm spike vs the rolling window).
    Anomalous,
    /// Non-finite metric, or too many consecutive anomalies: the learner
    /// state can no longer be trusted and must be rolled back.
    Diverged,
}

/// Why an update was flagged — carried into logs and the health report.
#[derive(Debug, Clone, PartialEq)]
pub enum HealthVerdict {
    Ok,
    /// `(metric name, value)` — e.g. `("total_loss", NaN)`.
    NonFinite(&'static str, f64),
    /// `(observed grad norm, rolling-window mean)`.
    GradSpike(f64, f64),
    /// Anomaly streak hit `max_anomalies`.
    AnomalyStreak(usize),
}

/// Metrics observed after one PPO update, fed to [`HealthGuard::observe`].
/// All values are reads of numbers the trainer already computed.
#[derive(Debug, Clone, Copy)]
pub struct UpdateMetrics {
    pub total_loss: f64,
    /// Pre-clip global gradient norm (mean over minibatches).
    pub grad_norm: f64,
    /// Global parameter norm after the update.
    pub param_norm: f64,
}

/// Final health record for one learner, reported per run (and per shard
/// through the distributed result files).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LearnerHealth {
    pub quarantined: bool,
    /// Rollbacks performed this process incarnation.
    pub rollbacks: usize,
}

/// Per-learner health bookkeeping for one process incarnation.
#[derive(Debug, Clone)]
pub struct HealthGuard {
    cfg: HealthConfig,
    /// Rolling window of recent healthy grad norms (cleared on rollback —
    /// post-restore dynamics must not be judged against pre-fault ones).
    window: Vec<f64>,
    /// Consecutive anomalous updates.
    anomaly_streak: usize,
    rollbacks_used: usize,
    quarantined: bool,
}

impl HealthGuard {
    pub fn new(cfg: HealthConfig) -> Self {
        HealthGuard {
            cfg,
            window: Vec::new(),
            anomaly_streak: 0,
            rollbacks_used: 0,
            quarantined: false,
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    pub fn quarantined(&self) -> bool {
        self.quarantined
    }

    pub fn rollbacks_used(&self) -> usize {
        self.rollbacks_used
    }

    pub fn max_rollbacks(&self) -> usize {
        self.cfg.max_rollbacks
    }

    /// The guard's final record for reports.
    pub fn health(&self) -> LearnerHealth {
        LearnerHealth { quarantined: self.quarantined, rollbacks: self.rollbacks_used }
    }

    /// Classify one update. Pure bookkeeping — never touches training
    /// state. Returns `(status, verdict)`; the verdict names the failed
    /// invariant for logs/reports.
    pub fn observe(&mut self, m: &UpdateMetrics) -> (HealthStatus, HealthVerdict) {
        if !self.cfg.enabled || self.quarantined {
            return (HealthStatus::Healthy, HealthVerdict::Ok);
        }
        for (name, v) in [
            ("total_loss", m.total_loss),
            ("grad_norm", m.grad_norm),
            ("param_norm", m.param_norm),
        ] {
            if !v.is_finite() {
                self.anomaly_streak = 0;
                return (HealthStatus::Diverged, HealthVerdict::NonFinite(name, v));
            }
        }
        // Spike detection only once the window is full: early training
        // legitimately has wild grad-norm swings, and a part-full window
        // would make the check depend on where the run (re)started.
        if self.window.len() >= self.cfg.window {
            let mean = self.window.iter().sum::<f64>() / self.window.len() as f64;
            if mean > 0.0 && m.grad_norm > self.cfg.spike_factor * mean {
                self.anomaly_streak += 1;
                if self.anomaly_streak >= self.cfg.max_anomalies {
                    let streak = self.anomaly_streak;
                    self.anomaly_streak = 0;
                    return (HealthStatus::Diverged, HealthVerdict::AnomalyStreak(streak));
                }
                return (
                    HealthStatus::Anomalous,
                    HealthVerdict::GradSpike(m.grad_norm, mean),
                );
            }
        }
        self.anomaly_streak = 0;
        self.window.push(m.grad_norm);
        if self.window.len() > self.cfg.window {
            self.window.remove(0);
        }
        (HealthStatus::Healthy, HealthVerdict::Ok)
    }

    /// Account for one rollback. Returns `false` when the budget is
    /// exhausted — the caller must quarantine the learner instead.
    pub fn try_rollback(&mut self) -> bool {
        if self.rollbacks_used >= self.cfg.max_rollbacks {
            return false;
        }
        self.rollbacks_used += 1;
        self.window.clear();
        self.anomaly_streak = 0;
        true
    }

    /// Mark the learner quarantined: all further observations pass
    /// through unchecked and the scheduler skips it.
    pub fn quarantine(&mut self) {
        self.quarantined = true;
    }
}

/// Global parameter norm over every tensor in the store: read-only,
/// f64 accumulation so the result is independent of tensor iteration
/// granularity.
pub fn param_norm(store: &ParamStore) -> Result<f64> {
    let mut acc = 0.0f64;
    for name in store.names().to_vec() {
        for &v in store.get(&name)? {
            acc += v as f64 * v as f64;
        }
    }
    Ok(acc.sqrt())
}

/// Finite-loss check for AIP (supervised) training: a non-finite epoch
/// loss means the predictor the IALS is about to trust is garbage, so
/// this fails fast with a structured error regardless of `[health]
/// enabled` (there is no rollback path for AIP pretraining — it is cheap
/// to rerun and deterministic, so failing the run is the right answer).
pub fn check_losses_finite(what: &str, losses: &[f32]) -> Result<()> {
    for (epoch, &l) in losses.iter().enumerate() {
        anyhow::ensure!(
            l.is_finite(),
            "{what}: non-finite training loss {l} at epoch {epoch} — the predictor diverged; \
             lower [influence] lr or raise batch size"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HealthConfig {
        HealthConfig {
            enabled: true,
            window: 4,
            spike_factor: 10.0,
            max_anomalies: 2,
            max_rollbacks: 2,
        }
    }

    fn m(loss: f64, gn: f64) -> UpdateMetrics {
        UpdateMetrics {
            total_loss: loss,
            grad_norm: gn,
            param_norm: 1.0,
        }
    }

    #[test]
    fn healthy_stream_stays_healthy() {
        let mut g = HealthGuard::new(cfg());
        for i in 0..32 {
            let (s, v) = g.observe(&m(0.5, 1.0 + (i % 3) as f64 * 0.1));
            assert_eq!(s, HealthStatus::Healthy);
            assert_eq!(v, HealthVerdict::Ok);
        }
    }

    #[test]
    fn non_finite_is_immediate_divergence() {
        let mut g = HealthGuard::new(cfg());
        let (s, v) = g.observe(&m(f64::NAN, 1.0));
        assert_eq!(s, HealthStatus::Diverged);
        assert!(matches!(v, HealthVerdict::NonFinite("total_loss", _)));
        let (s, _) = g.observe(&m(0.5, f64::INFINITY));
        assert_eq!(s, HealthStatus::Diverged);
        let (s, v) = g.observe(&UpdateMetrics {
            total_loss: 0.5,
            grad_norm: 1.0,
            param_norm: f64::NAN,
        });
        assert_eq!(s, HealthStatus::Diverged);
        assert!(matches!(v, HealthVerdict::NonFinite("param_norm", _)));
    }

    #[test]
    fn spike_needs_full_window_then_escalates_on_streak() {
        let mut g = HealthGuard::new(cfg());
        // Window not yet full: a huge value is tolerated (warm-up).
        let (s, _) = g.observe(&m(0.5, 1000.0));
        assert_eq!(s, HealthStatus::Healthy);
        let mut g = HealthGuard::new(cfg());
        for _ in 0..4 {
            assert_eq!(g.observe(&m(0.5, 1.0)).0, HealthStatus::Healthy);
        }
        // First spike: anomalous, not diverged.
        let (s, v) = g.observe(&m(0.5, 100.0));
        assert_eq!(s, HealthStatus::Anomalous);
        assert!(matches!(v, HealthVerdict::GradSpike(gn, mean) if gn == 100.0 && mean == 1.0));
        // Second consecutive spike hits max_anomalies = 2: diverged.
        let (s, v) = g.observe(&m(0.5, 100.0));
        assert_eq!(s, HealthStatus::Diverged);
        assert_eq!(v, HealthVerdict::AnomalyStreak(2));
    }

    #[test]
    fn healthy_update_resets_anomaly_streak() {
        let mut g = HealthGuard::new(cfg());
        for _ in 0..4 {
            g.observe(&m(0.5, 1.0));
        }
        assert_eq!(g.observe(&m(0.5, 100.0)).0, HealthStatus::Anomalous);
        assert_eq!(g.observe(&m(0.5, 1.0)).0, HealthStatus::Healthy);
        // Streak was reset: a new spike is anomalous again, not diverged.
        assert_eq!(g.observe(&m(0.5, 100.0)).0, HealthStatus::Anomalous);
    }

    #[test]
    fn rollback_budget_and_quarantine() {
        let mut g = HealthGuard::new(cfg());
        for _ in 0..4 {
            g.observe(&m(0.5, 1.0));
        }
        assert!(g.try_rollback());
        // Rollback cleared the window: spikes are tolerated again until
        // the window refills.
        assert_eq!(g.observe(&m(0.5, 1000.0)).0, HealthStatus::Healthy);
        assert!(g.try_rollback());
        assert!(!g.try_rollback(), "budget of 2 must be exhausted");
        assert_eq!(g.rollbacks_used(), 2);
        g.quarantine();
        assert!(g.quarantined());
        // Quarantined learners are no longer judged.
        assert_eq!(g.observe(&m(f64::NAN, 1.0)).0, HealthStatus::Healthy);
    }

    #[test]
    fn disabled_guard_observes_nothing() {
        let mut g = HealthGuard::new(HealthConfig {
            enabled: false,
            ..cfg()
        });
        assert_eq!(g.observe(&m(f64::NAN, f64::NAN)).0, HealthStatus::Healthy);
    }

    #[test]
    fn aip_finite_check() {
        assert!(check_losses_finite("fnn", &[0.3, 0.2, 0.1]).is_ok());
        let err = check_losses_finite("gru", &[0.3, f32::NAN]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("gru") && msg.contains("epoch 1"), "{msg}");
    }
}
