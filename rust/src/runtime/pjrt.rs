//! PJRT execution backend: compiles AOT artifacts (`artifacts/*.hlo.txt`)
//! once on the CPU PJRT client and executes them with model parameters +
//! caller data as positional literals.
//!
//! This module is the **only** place the `xla` crate is touched; everything
//! above it works with plain `&[f32]` slices. Python never runs here —
//! artifacts were lowered once at build time (`make artifacts`).

use super::manifest::{ArtifactSpec, Binding, DType, Manifest, TensorSpec};
use super::{Backend, DataArg};
use crate::nn::ParamStore;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

struct CompiledArtifact {
    exe: xla::PjRtLoadedExecutable,
    /// Does the artifact write any parameters back (training artifact)?
    mutates_params: bool,
    /// Device-resident parameter buffers for forward-only artifacts,
    /// keyed by the owning store's (id, version). Uploading the weights
    /// once per version (instead of per call) is the main L3 perf lever —
    /// see EXPERIMENTS.md §Perf.
    param_cache: RefCell<Option<((u64, u64), Vec<xla::PjRtBuffer>)>>,
}

/// One PJRT CPU client + a lazily-compiled artifact cache.
pub struct PjrtBackend {
    dir: PathBuf,
    client: xla::PjRtClient,
    compiled: RefCell<HashMap<String, Rc<CompiledArtifact>>>,
}

impl PjrtBackend {
    pub fn new(dir: &Path) -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend {
            dir: dir.to_path_buf(),
            client,
            compiled: RefCell::new(HashMap::new()),
        })
    }

    fn compile(&self, art: &ArtifactSpec) -> Result<Rc<CompiledArtifact>> {
        if let Some(c) = self.compiled.borrow().get(&art.name) {
            return Ok(c.clone());
        }
        let path = self.dir.join(&art.hlo_file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {}", art.name))?;
        let mutates_params =
            art.outputs.iter().any(|b| matches!(b, Binding::Param(_)));
        let c = Rc::new(CompiledArtifact {
            exe,
            mutates_params,
            param_cache: RefCell::new(None),
        });
        self.compiled.borrow_mut().insert(art.name.clone(), c.clone());
        Ok(c)
    }
}

impl Backend for PjrtBackend {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn prepare(&self, art: &ArtifactSpec, _manifest: &Manifest) -> Result<()> {
        self.compile(art)?;
        Ok(())
    }

    fn execute(
        &self,
        art: &ArtifactSpec,
        manifest: &Manifest,
        store: &mut ParamStore,
        data: &[DataArg<'_>],
        outs: &mut [&mut [f32]],
    ) -> Result<()> {
        let name = art.name.as_str();
        let compiled = self.compile(art)?;
        let model = manifest.model(&art.model)?;

        // Forward-only artifacts run on the buffer path: parameters stay
        // resident on the device and are re-uploaded only when the store
        // mutates. Training artifacts (param write-back) use the literal
        // path (the output tuple must come back to the host anyway).
        let result = if !compiled.mutates_params {
            // Refresh the resident parameter buffers if stale.
            {
                let mut cache = compiled.param_cache.borrow_mut();
                let key = store.cache_key();
                let stale = !matches!(&*cache, Some((k, _)) if *k == key);
                if stale {
                    let mut bufs = Vec::new();
                    for binding in &art.inputs {
                        if let Binding::Param(pname) = binding {
                            let tspec = model.param(pname)?;
                            let values = store.get(pname)?;
                            bufs.push(self.client.buffer_from_host_buffer(
                                values,
                                &tspec.shape,
                                None,
                            )?);
                        }
                    }
                    *cache = Some((key, bufs));
                }
            }
            let cache = compiled.param_cache.borrow();
            let (_, param_bufs) = cache.as_ref().unwrap();
            // Upload data inputs and assemble positional args.
            let mut data_bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(data.len());
            let mut data_it = data.iter();
            for binding in &art.inputs {
                if let Binding::Data(tspec) = binding {
                    let arg = data_it.next().unwrap();
                    data_bufs.push(buf_from_arg(&self.client, arg, tspec, name)?);
                }
            }
            let mut args: Vec<&xla::PjRtBuffer> =
                Vec::with_capacity(art.inputs.len());
            let (mut pi, mut di) = (0usize, 0usize);
            for binding in &art.inputs {
                match binding {
                    Binding::Param(_) => {
                        args.push(&param_bufs[pi]);
                        pi += 1;
                    }
                    Binding::Data(_) => {
                        args.push(&data_bufs[di]);
                        di += 1;
                    }
                }
            }
            compiled.exe.execute_b(&args).with_context(|| format!("executing {name}"))?
        } else {
            let mut literals: Vec<xla::Literal> = Vec::with_capacity(art.inputs.len());
            let mut data_it = data.iter();
            for binding in &art.inputs {
                match binding {
                    Binding::Param(pname) => {
                        let tspec = model.param(pname)?;
                        let values = store.get(pname)?;
                        literals.push(lit_f32(values, tspec)?);
                    }
                    Binding::Data(tspec) => {
                        let arg = data_it.next().unwrap();
                        literals.push(lit_from_arg(arg, tspec, name)?);
                    }
                }
            }
            compiled
                .exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing {name}"))?
        };

        // Unpack the output tuple. `into_literal` moves the payload off
        // the (stub) buffer instead of cloning it, so each output is
        // copied exactly once: straight into the store tensor or the
        // caller's scratch.
        let buf = result
            .into_iter()
            .next()
            .and_then(|row| row.into_iter().next())
            .with_context(|| format!("{name}: empty execution result"))?;
        let tuple = buf.into_literal().with_context(|| format!("fetching result of {name}"))?;
        let parts = tuple.to_tuple().with_context(|| format!("untupling result of {name}"))?;
        anyhow::ensure!(
            parts.len() == art.outputs.len(),
            "artifact {name}: {} outputs, manifest says {}",
            parts.len(),
            art.outputs.len()
        );

        let mut out_it = outs.iter_mut();
        for (part, binding) in parts.into_iter().zip(&art.outputs) {
            match binding {
                Binding::Param(pname) => {
                    // Write back directly into the store tensor (single copy).
                    let dst = store.tensor_mut(pname)?;
                    anyhow::ensure!(
                        part.element_count() == dst.len(),
                        "{name}: writeback of {pname} has {} elements, expected {}",
                        part.element_count(),
                        dst.len()
                    );
                    part.copy_raw_to(dst)
                        .with_context(|| format!("{name}: writeback of {pname}"))?;
                }
                Binding::Data(tspec) => {
                    if tspec.dtype != DType::F32 {
                        bail!("artifact {name}: non-f32 data outputs unsupported");
                    }
                    let dst: &mut [f32] = out_it.next().unwrap();
                    anyhow::ensure!(
                        part.element_count() == tspec.numel() && dst.len() == tspec.numel(),
                        "{name}: output {} has {} elements, buffer {}, expected {}",
                        tspec.name,
                        part.element_count(),
                        dst.len(),
                        tspec.numel()
                    );
                    // Single copy straight into the caller's scratch.
                    part.copy_raw_to(dst)
                        .with_context(|| format!("{name}: output {}", tspec.name))?;
                }
            }
        }
        Ok(())
    }
}

fn lit_f32(values: &[f32], spec: &TensorSpec) -> Result<xla::Literal> {
    anyhow::ensure!(
        values.len() == spec.numel(),
        "tensor {}: {} values, expected {} {:?}",
        spec.name,
        values.len(),
        spec.numel(),
        spec.shape
    );
    // Single-copy literal creation (vec1 + reshape would copy twice).
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(values.as_ptr() as *const u8, values.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &spec.shape,
        bytes,
    )?)
}

fn lit_from_arg(arg: &DataArg<'_>, spec: &TensorSpec, artifact: &str) -> Result<xla::Literal> {
    match (arg, spec.dtype) {
        (DataArg::F32(v), DType::F32) => lit_f32(v, spec),
        (DataArg::I32(v), DType::I32) => {
            anyhow::ensure!(
                v.len() == spec.numel(),
                "tensor {}: {} values, expected {}",
                spec.name,
                v.len(),
                spec.numel()
            );
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            };
            Ok(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S32,
                &spec.shape,
                bytes,
            )?)
        }
        _ => bail!("artifact {artifact}: dtype mismatch for data input {}", spec.name),
    }
}

fn buf_from_arg(
    client: &xla::PjRtClient,
    arg: &DataArg<'_>,
    spec: &TensorSpec,
    artifact: &str,
) -> Result<xla::PjRtBuffer> {
    match (arg, spec.dtype) {
        (DataArg::F32(v), DType::F32) => {
            anyhow::ensure!(v.len() == spec.numel(), "tensor {}: wrong size", spec.name);
            Ok(client.buffer_from_host_buffer(v, &spec.shape, None)?)
        }
        (DataArg::I32(v), DType::I32) => {
            anyhow::ensure!(v.len() == spec.numel(), "tensor {}: wrong size", spec.name);
            Ok(client.buffer_from_host_buffer(v, &spec.shape, None)?)
        }
        _ => bail!("artifact {artifact}: dtype mismatch for data input {}", spec.name),
    }
}
