//! Crash-safe checkpoint files for resumable training runs.
//!
//! A checkpoint is one file per saved iteration, `ckpt_{iter:08}.bin`,
//! holding an opaque payload (assembled by `coordinator::multi`) behind a
//! small self-validating header:
//!
//! | offset | bytes | field                          |
//! |--------|-------|--------------------------------|
//! | 0      | 8     | magic `IALSCKPT`               |
//! | 8      | 4     | format version (LE u32)        |
//! | 12     | 8     | payload length (LE u64)        |
//! | 20     | 4     | CRC-32 of the payload (LE u32) |
//! | 24     | …     | payload                        |
//!
//! Writes are crash-safe: the bytes go to a temp file in the same
//! directory, are fsynced, and are atomically renamed into place
//! ([`crate::util::state::atomic_write`]) — a kill at any instant leaves
//! either the previous file set or the new one, never a half-written
//! visible checkpoint. Reads are defensive: [`CheckpointManager::load_latest`]
//! walks the directory newest-first and returns the first checkpoint whose
//! header and CRC validate, logging a warning for each invalid file it
//! skips — so a torn or bit-flipped newest checkpoint falls back to the
//! previous good one instead of aborting the resume.

use crate::util::state::{read_headered, write_headered};
use crate::{log_info, log_warn};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Magic and format version of the checkpoint header — public so the
/// read-only consumers (`repro inspect`, the serving runtime) can name
/// them in operator-facing output.
pub const CKPT_MAGIC: &[u8; 8] = b"IALSCKPT";
pub const CKPT_VERSION: u32 = 1;

/// File name of the checkpoint for iteration `iter` (`ckpt_{iter:08}.bin`).
pub fn checkpoint_file_name(iter: usize) -> String {
    format!("ckpt_{iter:08}.bin")
}

/// Checkpoint files present in `dir`, `(iteration, path)` sorted ascending.
/// Foreign files are ignored; a missing or unreadable directory is simply
/// empty. This is the directory view [`CheckpointManager`], `repro inspect`
/// and the serving runtime's loader all share.
pub fn list_checkpoints(dir: impl AsRef<Path>) -> Vec<(usize, PathBuf)> {
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir.as_ref()) else {
        return out;
    };
    for entry in entries.flatten() {
        if let Some(name) = entry.file_name().to_str() {
            if let Some(iter) = parse_checkpoint_iter(name) {
                out.push((iter, entry.path()));
            }
        }
    }
    out.sort();
    out
}

/// Parse `ckpt_{iter:08}.bin` back to its iteration number.
fn parse_checkpoint_iter(name: &str) -> Option<usize> {
    let digits = name.strip_prefix("ckpt_")?.strip_suffix(".bin")?;
    if digits.len() != 8 || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Validate one checkpoint file (magic, version, length, CRC) and return
/// its payload — `util::state::read_headered` with the checkpoint framing.
pub fn read_checkpoint_file(path: impl AsRef<Path>) -> Result<Vec<u8>> {
    read_headered(path, CKPT_MAGIC, CKPT_VERSION)
}

/// Manages the checkpoint files of one run directory: atomic saves, a
/// bounded retention window, and validated newest-first loads.
pub struct CheckpointManager {
    dir: PathBuf,
    /// How many checkpoint files to keep (older ones are pruned after a
    /// successful save). At least 1.
    retain: usize,
}

impl CheckpointManager {
    pub fn new(dir: impl Into<PathBuf>, retain: usize) -> CheckpointManager {
        CheckpointManager { dir: dir.into(), retain: retain.max(1) }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn file_name(iter: usize) -> String {
        checkpoint_file_name(iter)
    }

    /// Checkpoint files present in the directory, sorted by iteration
    /// ascending. Foreign files are ignored.
    fn list(&self) -> Vec<(usize, PathBuf)> {
        list_checkpoints(&self.dir)
    }

    /// Write `payload` as the checkpoint for `iter` (temp file + fsync +
    /// atomic rename), then prune files beyond the retention window.
    pub fn save(&self, iter: usize, payload: &[u8]) -> Result<()> {
        let path = self.dir.join(Self::file_name(iter));
        write_headered(&path, CKPT_MAGIC, CKPT_VERSION, payload)
            .with_context(|| format!("writing checkpoint {}", path.display()))?;
        let files = self.list();
        if files.len() > self.retain {
            for (_, old) in &files[..files.len() - self.retain] {
                // Pruning is best-effort: a stale file never corrupts a
                // resume, it only wastes disk.
                std::fs::remove_file(old).ok();
            }
        }
        Ok(())
    }

    /// Validate one checkpoint file and return its payload
    /// (`util::state::read_headered` with the checkpoint magic).
    fn read_validated(path: &Path) -> Result<Vec<u8>> {
        read_checkpoint_file(path)
    }

    /// The newest *valid* checkpoint, as `(iter, payload)`. Invalid files
    /// (truncated, bit-flipped, foreign format) are skipped with a warning
    /// and the scan falls back to the next-newest; `None` when no valid
    /// checkpoint exists.
    pub fn load_latest(&self) -> Option<(usize, Vec<u8>)> {
        for (iter, path) in self.list().into_iter().rev() {
            match Self::read_validated(&path) {
                Ok(payload) => {
                    log_info!("resuming from checkpoint {}", path.display());
                    return Some((iter, payload));
                }
                Err(e) => {
                    log_warn!(
                        "skipping invalid checkpoint {}: {e:#} — falling back to an older one",
                        path.display()
                    );
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ials_ckpt_{tag}"));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = tmp_dir("roundtrip");
        let mgr = CheckpointManager::new(&dir, 3);
        assert!(mgr.load_latest().is_none(), "empty dir has no checkpoint");
        mgr.save(5, b"hello").unwrap();
        mgr.save(10, b"world").unwrap();
        let (iter, payload) = mgr.load_latest().unwrap();
        assert_eq!(iter, 10);
        assert_eq!(payload, b"world");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn retention_prunes_oldest() {
        let dir = tmp_dir("retain");
        let mgr = CheckpointManager::new(&dir, 2);
        for iter in [1, 2, 3, 4] {
            mgr.save(iter, &[iter as u8]).unwrap();
        }
        let names: Vec<usize> = mgr.list().into_iter().map(|(i, _)| i).collect();
        assert_eq!(names, vec![3, 4]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn retention_window_is_configurable() {
        // retain = 1: only the newest file survives each save.
        let dir = tmp_dir("retain1");
        let mgr = CheckpointManager::new(&dir, 1);
        for iter in [1, 2, 3] {
            mgr.save(iter, &[iter as u8]).unwrap();
            let names: Vec<usize> = mgr.list().into_iter().map(|(i, _)| i).collect();
            assert_eq!(names, vec![iter]);
        }
        std::fs::remove_dir_all(dir).ok();
        // retain = 5: nothing is pruned until the sixth save.
        let dir = tmp_dir("retain5");
        let mgr = CheckpointManager::new(&dir, 5);
        for iter in 1..=7 {
            mgr.save(iter, &[iter as u8]).unwrap();
        }
        let names: Vec<usize> = mgr.list().into_iter().map(|(i, _)| i).collect();
        assert_eq!(names, vec![3, 4, 5, 6, 7]);
        // retain = 0 would delete the file just written; clamped to 1.
        let dir0 = tmp_dir("retain0");
        let mgr = CheckpointManager::new(&dir0, 0);
        mgr.save(1, b"x").unwrap();
        assert_eq!(mgr.load_latest().unwrap().0, 1);
        std::fs::remove_dir_all(dir).ok();
        std::fs::remove_dir_all(dir0).ok();
    }

    #[test]
    fn skipped_file_warns_exactly_once() {
        use crate::util::logger;
        let dir = tmp_dir("warn_once");
        let mgr = CheckpointManager::new(&dir, 3);
        mgr.save(1, b"good").unwrap();
        mgr.save(2, b"bad-to-be").unwrap();
        mgr.save(3, b"also-bad").unwrap();
        let n = |i| dir.join(CheckpointManager::file_name(i));
        crate::testkit::fault::flip_bit(n(2), 30, 0).unwrap();
        crate::testkit::fault::truncate_file(n(3), 10).unwrap();
        let _guard = logger::capture_test_guard();
        logger::capture_for_test();
        let (iter, payload) = mgr.load_latest().unwrap();
        let captured = logger::drain_captured();
        assert_eq!((iter, payload.as_slice()), (1, b"good".as_slice()));
        // One warning per skipped file — not zero (silent fallback), not
        // repeated. Filter by this test's own paths: the sink is global and
        // other tests may log concurrently.
        for i in [2usize, 3] {
            let name = CheckpointManager::file_name(i);
            let mine: Vec<&String> = captured.iter().filter(|l| l.contains(&name)).collect();
            assert_eq!(mine.len(), 1, "want exactly one warning for {name}: {captured:?}");
            assert!(mine[0].starts_with("[WARN ]"), "{}", mine[0]);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn corrupt_newest_falls_back_to_previous() {
        let dir = tmp_dir("corrupt");
        let mgr = CheckpointManager::new(&dir, 3);
        mgr.save(1, b"good").unwrap();
        mgr.save(2, b"newest").unwrap();
        // Flip a payload bit in the newest file.
        let newest = dir.join(CheckpointManager::file_name(2));
        let mut bytes = std::fs::read(&newest).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0x01;
        std::fs::write(&newest, &bytes).unwrap();
        let (iter, payload) = mgr.load_latest().unwrap();
        assert_eq!(iter, 1);
        assert_eq!(payload, b"good");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn truncated_newest_falls_back_to_previous() {
        let dir = tmp_dir("trunc");
        let mgr = CheckpointManager::new(&dir, 3);
        mgr.save(1, b"good").unwrap();
        mgr.save(2, b"newest-but-torn").unwrap();
        let newest = dir.join(CheckpointManager::file_name(2));
        let bytes = std::fs::read(&newest).unwrap();
        std::fs::write(&newest, &bytes[..bytes.len() - 3]).unwrap();
        let (iter, payload) = mgr.load_latest().unwrap();
        assert_eq!(iter, 1);
        assert_eq!(payload, b"good");
        // Zero-length newest too.
        std::fs::write(&newest, []).unwrap();
        assert_eq!(mgr.load_latest().unwrap().0, 1);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn all_invalid_yields_none() {
        let dir = tmp_dir("allbad");
        let mgr = CheckpointManager::new(&dir, 3);
        mgr.save(1, b"x").unwrap();
        let path = dir.join(CheckpointManager::file_name(1));
        std::fs::write(&path, b"garbage").unwrap();
        assert!(mgr.load_latest().is_none());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn foreign_files_ignored() {
        let dir = tmp_dir("foreign");
        let mgr = CheckpointManager::new(&dir, 3);
        mgr.save(7, b"real").unwrap();
        std::fs::write(dir.join("notes.txt"), b"not a checkpoint").unwrap();
        std::fs::write(dir.join("ckpt_junk.bin"), b"nope").unwrap();
        let (iter, payload) = mgr.load_latest().unwrap();
        assert_eq!(iter, 7);
        assert_eq!(payload, b"real");
        std::fs::remove_dir_all(dir).ok();
    }
}
