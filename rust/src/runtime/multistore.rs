//! Multi-learner parameter hosting: K independent [`ParamStore`]s per
//! model (one per learner, each with its own Adam slots and its own
//! seeded init), executed through the **existing** [`Backend`] API — the
//! backend never learns about learners, it just receives a different
//! `&mut ParamStore` per call.
//!
//! This is the store half of the distributed-IALS runtime (Suau et al.,
//! arXiv:2207.00288): several learners train concurrently against shared
//! influence data, so the run needs K parameter sets but only **one**
//! engine (one op cache, one scratch set, one process-shared compute
//! pool). The [`MultiStore`] hosts the parameter sets; the engine-side
//! objects (`rl::Policy`, `influence::NeuralAip`) either check a store
//! out permanently ([`MultiStore::take`] — predictors own per-learner
//! recurrent state anyway) or swap it in for one round-robin turn
//! ([`MultiStore::swap`] — the policy path: one `Policy`, K hosted
//! stores).
//!
//! ## Determinism
//!
//! Store creation is a pure function of `(model, learner seed)`:
//! [`MultiStore::init_model`] runs the backend's load path
//! ([`Runtime::load_store`]) followed by the same seeded
//! [`ParamStore::reinit`] the single-learner experiment performs, so
//! learner 0 at the base seed is **bitwise identical** to today's
//! single-learner init, and [`learner_seed`] gives every other learner
//! its own deterministic stream. Nothing here depends on worker counts —
//! `rust/tests/multi_learner.rs` locks the end-to-end guarantee in.
//!
//! [`Backend`]: super::Backend

use super::{DataArg, Runtime};
use crate::nn::ParamStore;
use crate::Result;
use anyhow::{anyhow, ensure};
use std::collections::BTreeMap;

/// Deterministic per-learner seed stream. Learner 0 is the base seed
/// itself — the single-learner path must stay bitwise reproducible — and
/// higher indices mix the learner index in with a golden-ratio multiply
/// (distinct per index, independent of every other seed derivation in
/// the repo).
pub fn learner_seed(base: u64, learner: usize) -> u64 {
    if learner == 0 {
        base
    } else {
        base ^ (learner as u64).wrapping_mul(0x9E3779B97F4A7C15)
    }
}

/// K independent per-learner [`ParamStore`] sets, keyed by model name.
pub struct MultiStore {
    slots: Vec<BTreeMap<String, ParamStore>>,
}

impl MultiStore {
    /// An empty store host for `num_learners` learners.
    pub fn new(num_learners: usize) -> MultiStore {
        assert!(num_learners >= 1, "need at least one learner");
        MultiStore { slots: (0..num_learners).map(|_| BTreeMap::new()).collect() }
    }

    pub fn num_learners(&self) -> usize {
        self.slots.len()
    }

    fn slot(&self, learner: usize) -> Result<&BTreeMap<String, ParamStore>> {
        let n = self.slots.len();
        self.slots
            .get(learner)
            .ok_or_else(|| anyhow!("learner {learner} out of range ({n} learners)"))
    }

    fn slot_mut(&mut self, learner: usize) -> Result<&mut BTreeMap<String, ParamStore>> {
        let n = self.slots.len();
        self.slots
            .get_mut(learner)
            .ok_or_else(|| anyhow!("learner {learner} out of range ({n} learners)"))
    }

    /// Create (or replace) learner `learner`'s store for `model`: the
    /// backend's load path plus the per-learner seeded reinit — exactly
    /// the `load_store` + `reinit` sequence of the single-learner
    /// experiment, so `init_model(rt, 0, model, seed)` is bitwise
    /// identical to today's per-seed init at `seed`.
    pub fn init_model(
        &mut self,
        rt: &Runtime,
        learner: usize,
        model: &str,
        reinit_seed: u64,
    ) -> Result<()> {
        let mut store = rt.load_store(model)?;
        let spec = rt.manifest.model(model)?.clone();
        store.reinit(&spec, reinit_seed);
        self.slot_mut(learner)?.insert(model.to_string(), store);
        Ok(())
    }

    pub fn store(&self, learner: usize, model: &str) -> Result<&ParamStore> {
        self.slot(learner)?
            .get(model)
            .ok_or_else(|| anyhow!("learner {learner} has no store for model {model}"))
    }

    pub fn store_mut(&mut self, learner: usize, model: &str) -> Result<&mut ParamStore> {
        self.slot_mut(learner)?
            .get_mut(model)
            .ok_or_else(|| anyhow!("learner {learner} has no store for model {model}"))
    }

    /// Move learner `learner`'s store for `model` out of the host — for
    /// engine-side owners that keep per-learner state of their own (e.g.
    /// a recurrent influence predictor, whose hidden state is as
    /// per-learner as its parameters). Pairs with [`MultiStore::insert`].
    pub fn take(&mut self, learner: usize, model: &str) -> Result<ParamStore> {
        self.slot_mut(learner)?
            .remove(model)
            .ok_or_else(|| anyhow!("learner {learner} has no store for model {model}"))
    }

    /// Hand a store (back) to learner `learner` under its model name.
    pub fn insert(&mut self, learner: usize, store: ParamStore) -> Result<()> {
        let key = store.model.clone();
        self.slot_mut(learner)?.insert(key, store);
        Ok(())
    }

    /// Swap the hosted store with `active` — the round-robin checkout:
    /// swap learner `k`'s parameters into the (single) engine-side owner
    /// before its turn, swap them back out afterwards. Rejects a
    /// cross-model swap, which would silently train the wrong learner.
    pub fn swap(&mut self, learner: usize, model: &str, active: &mut ParamStore) -> Result<()> {
        let hosted = self.store_mut(learner, model)?;
        ensure!(
            hosted.model == active.model,
            "store swap model mismatch: hosted {} vs active {}",
            hosted.model,
            active.model
        );
        std::mem::swap(hosted, active);
        Ok(())
    }

    /// Execute an artifact against learner `learner`'s hosted store —
    /// the existing backend API ([`Runtime::call_into`] → `Backend`),
    /// just routed at a per-learner parameter set. Shapes and dtypes are
    /// validated by the runtime as usual.
    pub fn call_into(
        &mut self,
        rt: &Runtime,
        learner: usize,
        artifact: &str,
        data: &[DataArg<'_>],
        outs: &mut [&mut [f32]],
    ) -> Result<()> {
        let model = rt.manifest.artifact(artifact)?.model.clone();
        let store = self.store_mut(learner, &model)?;
        rt.call_into(artifact, store, data, outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::SynthGeometry;

    fn rt() -> Runtime {
        Runtime::native(&SynthGeometry { rollout_b: 4, ..SynthGeometry::default() })
    }

    #[test]
    fn learner_seed_is_identity_for_learner_zero() {
        assert_eq!(learner_seed(7, 0), 7);
        assert_ne!(learner_seed(7, 1), 7);
        assert_ne!(learner_seed(7, 1), learner_seed(7, 2));
        assert_ne!(learner_seed(7, 1), learner_seed(8, 1));
    }

    #[test]
    fn init_is_per_learner_seeded_and_matches_single_store_path() {
        let rt = rt();
        let mut multi = MultiStore::new(3);
        for l in 0..3 {
            multi.init_model(&rt, l, "aip_traffic", learner_seed(9, l) ^ 0xA1B2).unwrap();
        }
        // Learner 0 is exactly the single-learner init sequence.
        let mut single = rt.load_store("aip_traffic").unwrap();
        let spec = rt.manifest.model("aip_traffic").unwrap().clone();
        single.reinit(&spec, 9 ^ 0xA1B2);
        let w0 = multi.store(0, "aip_traffic").unwrap().get("w1").unwrap();
        assert_eq!(w0, single.get("w1").unwrap());
        // Higher learners re-roll deterministically and differently.
        let w1 = multi.store(1, "aip_traffic").unwrap().get("w1").unwrap();
        let w2 = multi.store(2, "aip_traffic").unwrap().get("w1").unwrap();
        assert_ne!(w0, w1);
        assert_ne!(w1, w2);
    }

    #[test]
    fn swap_checks_model_and_roundtrips() {
        let rt = rt();
        let mut multi = MultiStore::new(2);
        multi.init_model(&rt, 0, "policy_traffic", 1).unwrap();
        multi.init_model(&rt, 1, "policy_traffic", 2).unwrap();
        let hosted0 = multi.store(0, "policy_traffic").unwrap().get("w1").unwrap().to_vec();
        let mut active = rt.load_store("policy_traffic").unwrap();
        let placeholder = active.get("w1").unwrap().to_vec();
        multi.swap(0, "policy_traffic", &mut active).unwrap();
        assert_eq!(active.get("w1").unwrap(), hosted0.as_slice());
        multi.swap(0, "policy_traffic", &mut active).unwrap();
        assert_eq!(active.get("w1").unwrap(), placeholder.as_slice());
        assert_eq!(multi.store(0, "policy_traffic").unwrap().get("w1").unwrap(), hosted0);
        // A store for a different model cannot be swapped in.
        let mut wrong = rt.load_store("aip_traffic").unwrap();
        assert!(multi.swap(0, "policy_traffic", &mut wrong).is_err());
        assert!(multi.swap(5, "policy_traffic", &mut active).is_err());
    }

    #[test]
    fn take_and_insert_move_ownership() {
        let rt = rt();
        let mut multi = MultiStore::new(1);
        multi.init_model(&rt, 0, "aip_traffic", 3).unwrap();
        let store = multi.take(0, "aip_traffic").unwrap();
        assert!(multi.store(0, "aip_traffic").is_err());
        assert!(multi.take(0, "aip_traffic").is_err());
        multi.insert(0, store).unwrap();
        assert!(multi.store(0, "aip_traffic").is_ok());
    }

    #[test]
    fn call_into_routes_to_the_learner_store() {
        let rt = rt();
        let mut multi = MultiStore::new(2);
        multi.init_model(&rt, 0, "aip_traffic", 10).unwrap();
        multi.init_model(&rt, 1, "aip_traffic", 11).unwrap();
        let d = vec![0.25f32; 4 * 40];
        let mut p0 = vec![0.0f32; 4 * 4];
        let mut p1 = vec![0.0f32; 4 * 4];
        multi
            .call_into(&rt, 0, "aip_traffic_fwd_b4", &[DataArg::F32(&d)], &mut [p0.as_mut_slice()])
            .unwrap();
        multi
            .call_into(&rt, 1, "aip_traffic_fwd_b4", &[DataArg::F32(&d)], &mut [p1.as_mut_slice()])
            .unwrap();
        // Different learner params, same input: different predictions.
        assert_ne!(p0, p1, "independent learner stores must differ");
        // Re-running learner 0 reproduces its bits exactly.
        let mut p0b = vec![0.0f32; 4 * 4];
        multi
            .call_into(&rt, 0, "aip_traffic_fwd_b4", &[DataArg::F32(&d)], &mut [p0b.as_mut_slice()])
            .unwrap();
        assert_eq!(p0, p0b);
        assert!(multi.call_into(&rt, 0, "nope", &[], &mut []).is_err());
    }
}
