//! Sharded multi-threaded execution of vectorized environments.
//!
//! The paper's whole value proposition is simulation speed, and the repo's
//! hot loop is `VecEnv::step_all` over `B` environments. This module makes
//! that loop scale with cores while preserving two invariants:
//!
//! 1. **One batched NN forward per step.** PJRT calls (policy + AIP) stay on
//!    the coordinator thread — `Runtime` is `Rc`/`RefCell`-based and must
//!    not cross threads. Only pure-Rust simulator stepping is parallelized.
//! 2. **Bitwise determinism.** Each shard owns a contiguous range of env
//!    indices; every env is seeded from its *global* index and owns its RNG
//!    stream, so a sharded run produces outputs identical to a serial run
//!    at the same seed, for any worker count.
//!
//! Building blocks:
//!
//! * [`ShardPool`] — a persistent worker pool (spawned once, reused across
//!   all rollout iterations; no per-step thread spawn) where each worker
//!   owns one shard's state.
//! * [`ShardExec`] — serial-or-pooled executor so callers write one code
//!   path and `num_workers = 1` stays exactly the old serial loop.
//! * [`ShardedVecEnv`] — a [`VecEnv`] adapter that partitions any batch of
//!   per-shard vec-envs and runs `step_all`/`observe_all`/`reset_all`
//!   concurrently, each shard writing directly into its disjoint slice of
//!   the shared env-major buffers (no gather copies).

use super::VecEnv;
use std::sync::mpsc;
use std::thread;

/// Resolve a configured worker count: `0` means "one per available core".
pub fn effective_workers(requested: usize) -> usize {
    if requested == 0 {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Balanced contiguous partition of `n` items over `w` shards: the first
/// `n % w` shards get one extra item. Returns `[start, end)` ranges that
/// tile `[0, n)` in order.
pub fn shard_ranges(n: usize, w: usize) -> Vec<(usize, usize)> {
    let w = w.clamp(1, n.max(1));
    let (base, extra) = (n / w, n % w);
    let mut ranges = Vec::with_capacity(w);
    let mut start = 0;
    for i in 0..w {
        let len = base + usize::from(i < extra);
        ranges.push((start, start + len));
        start += len;
    }
    debug_assert_eq!(start, n, "shard ranges must tile the batch");
    ranges
}

/// A raw handle to a mutable slice that can cross threads. Each worker gets
/// a *disjoint* sub-range, which is what makes the aliasing sound.
pub struct SendSliceMut<T> {
    ptr: *mut T,
    len: usize,
}

impl<T> Clone for SendSliceMut<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendSliceMut<T> {}
unsafe impl<T: Send> Send for SendSliceMut<T> {}
unsafe impl<T: Send> Sync for SendSliceMut<T> {}

impl<T> SendSliceMut<T> {
    pub fn new(slice: &mut [T]) -> SendSliceMut<T> {
        SendSliceMut { ptr: slice.as_mut_ptr(), len: slice.len() }
    }

    /// Reborrow `[start, start + len)` of the underlying slice.
    ///
    /// # Safety
    /// Concurrent callers must use disjoint ranges, and the slice handed to
    /// [`SendSliceMut::new`] must outlive every use (the executors below
    /// guarantee this by blocking until all workers acknowledge completion).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range(&self, start: usize, len: usize) -> &mut [T] {
        assert!(start + len <= self.len, "shard slice range out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

/// Shared-slice counterpart of [`SendSliceMut`] for read-only inputs.
pub struct SendSliceRef<T> {
    ptr: *const T,
    len: usize,
}

impl<T> Clone for SendSliceRef<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendSliceRef<T> {}
unsafe impl<T: Sync> Send for SendSliceRef<T> {}
unsafe impl<T: Sync> Sync for SendSliceRef<T> {}

impl<T> SendSliceRef<T> {
    pub fn new(slice: &[T]) -> SendSliceRef<T> {
        SendSliceRef { ptr: slice.as_ptr(), len: slice.len() }
    }

    /// Reborrow `[start, start + len)` of the underlying slice.
    ///
    /// # Safety
    /// The slice handed to [`SendSliceRef::new`] must outlive every use.
    pub unsafe fn range(&self, start: usize, len: usize) -> &[T] {
        assert!(start + len <= self.len, "shard slice range out of bounds");
        std::slice::from_raw_parts(self.ptr.add(start), len)
    }
}

type Job<S> = Box<dyn FnOnce(&mut S) + Send + 'static>;

/// Erase a job's borrow lifetime so it can cross the worker channel.
///
/// # Safety
/// The caller must not return (or otherwise invalidate the borrows captured
/// by `job`) until the job has finished running — [`ShardPool::run_all`]
/// guarantees this by blocking on per-worker acknowledgements.
unsafe fn erase_job_lifetime<'a, S>(
    job: Box<dyn FnOnce(&mut S) + Send + 'a>,
) -> Box<dyn FnOnce(&mut S) + Send + 'static> {
    std::mem::transmute(job)
}

/// A persistent pool of worker threads, each owning one shard state `S`.
/// Spawned once; every [`ShardPool::run_all`] broadcasts a job and blocks
/// until all workers acknowledge, so borrowed captures stay valid.
pub struct ShardPool<S: Send + 'static> {
    txs: Vec<mpsc::Sender<Job<S>>>,
    done_rx: mpsc::Receiver<bool>,
    handles: Vec<thread::JoinHandle<()>>,
}

fn worker_loop<S>(mut state: S, rx: mpsc::Receiver<Job<S>>, done: mpsc::Sender<bool>) {
    while let Ok(job) = rx.recv() {
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(&mut state)))
            .is_ok();
        let still_listening = done.send(ok).is_ok();
        if !ok || !still_listening {
            break;
        }
    }
}

impl<S: Send + 'static> ShardPool<S> {
    pub fn new(states: Vec<S>) -> ShardPool<S> {
        assert!(!states.is_empty(), "shard pool needs at least one shard");
        let (done_tx, done_rx) = mpsc::channel();
        let mut txs = Vec::with_capacity(states.len());
        let mut handles = Vec::with_capacity(states.len());
        for (i, state) in states.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<Job<S>>();
            let done = done_tx.clone();
            let handle = thread::Builder::new()
                .name(format!("vecenv-shard-{i}"))
                .spawn(move || worker_loop(state, rx, done))
                .expect("spawning shard worker thread");
            txs.push(tx);
            handles.push(handle);
        }
        ShardPool { txs, done_rx, handles }
    }

    pub fn num_shards(&self) -> usize {
        self.txs.len()
    }

    /// Run `f(shard_index, &mut shard_state)` on every worker concurrently
    /// and block until all have finished. Panics if any worker's job
    /// panicked or any worker is gone — but only after draining every
    /// in-flight acknowledgement, so no worker is still touching
    /// caller-borrowed data when this unwinds.
    pub fn run_all(&self, f: &(dyn Fn(usize, &mut S) + Send + Sync)) {
        // Dispatch without panicking mid-loop: a send to a dead worker (one
        // that exited after an earlier panic) just drops the job — it never
        // runs — and is recorded as a failure for after the drain.
        let mut dispatched = 0usize;
        let mut all_sent = true;
        for (i, tx) in self.txs.iter().enumerate() {
            let job: Box<dyn FnOnce(&mut S) + Send + '_> = Box::new(move |s: &mut S| f(i, s));
            // SAFETY: lifetime erasure only — both types are the same fat
            // `Box<dyn ...>` apart from the lifetime bound (the classic
            // scoped-pool trick). This call does not return until every
            // dispatched job has been acknowledged below (or its worker has
            // provably exited), so the borrow of `f` (and anything it
            // captures) strictly outlives all use.
            let job: Job<S> = unsafe { erase_job_lifetime(job) };
            if tx.send(job).is_ok() {
                dispatched += 1;
            } else {
                all_sent = false;
            }
        }
        let mut ok = all_sent;
        for _ in 0..dispatched {
            match self.done_rx.recv() {
                Ok(job_ok) => ok &= job_ok,
                // All ack senders dropped: every worker has exited its loop,
                // so nothing is still running — safe to stop draining.
                Err(_) => {
                    ok = false;
                    break;
                }
            }
        }
        assert!(ok, "a shard worker panicked or is gone");
    }
}

impl<S: Send + 'static> Drop for ShardPool<S> {
    fn drop(&mut self) {
        // Closing the job channels ends each worker loop.
        self.txs.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

/// Serial-or-pooled shard executor: one code path for callers, with
/// `Serial` behaving exactly like the pre-sharding loop (same order, same
/// thread) so `num_workers = 1` is the old semantics by construction.
pub enum ShardExec<S: Send + 'static> {
    Serial(Vec<S>),
    Pool(ShardPool<S>),
}

impl<S: Send + 'static> ShardExec<S> {
    /// `parallel = false` (or a single shard) keeps everything inline.
    pub fn new(shards: Vec<S>, parallel: bool) -> ShardExec<S> {
        assert!(!shards.is_empty(), "need at least one shard");
        if parallel && shards.len() > 1 {
            ShardExec::Pool(ShardPool::new(shards))
        } else {
            ShardExec::Serial(shards)
        }
    }

    pub fn num_shards(&self) -> usize {
        match self {
            ShardExec::Serial(shards) => shards.len(),
            ShardExec::Pool(pool) => pool.num_shards(),
        }
    }

    pub fn is_parallel(&self) -> bool {
        matches!(self, ShardExec::Pool(_))
    }

    /// Run a mutating pass over every shard (parallel when pooled).
    pub fn run_mut(&mut self, f: impl Fn(usize, &mut S) + Send + Sync) {
        match self {
            ShardExec::Serial(shards) => {
                for (i, s) in shards.iter_mut().enumerate() {
                    f(i, s);
                }
            }
            ShardExec::Pool(pool) => pool.run_all(&f),
        }
    }

    /// Run a read-only pass over every shard (parallel when pooled).
    pub fn run_ref(&self, f: impl Fn(usize, &S) + Send + Sync) {
        match self {
            ShardExec::Serial(shards) => {
                for (i, s) in shards.iter().enumerate() {
                    f(i, s);
                }
            }
            ShardExec::Pool(pool) => {
                let g = move |i: usize, s: &mut S| f(i, &*s);
                pool.run_all(&g);
            }
        }
    }

    /// Direct access to shard states — only possible in serial mode (pooled
    /// states live on their worker threads).
    pub fn serial_shards_mut(&mut self) -> Option<&mut [S]> {
        match self {
            ShardExec::Serial(shards) => Some(shards),
            ShardExec::Pool(_) => None,
        }
    }
}

/// One shard of a [`ShardedVecEnv`]: a smaller vec-env covering the global
/// env indices `[start, start + env.num_envs())`.
pub struct Shard<V> {
    pub env: V,
    pub start: usize,
}

/// Parallel adapter over per-shard [`VecEnv`]s. Construct the shards so
/// that shard `i` covers the `i`-th range of [`shard_ranges`] *and* seeds
/// its envs by global index (e.g. [`super::GsVecEnv::with_index_offset`]);
/// then sharded output is bitwise identical to the equivalent serial env.
pub struct ShardedVecEnv<V: VecEnv + Send + 'static> {
    exec: ShardExec<Shard<V>>,
    num_envs: usize,
    obs_dim: usize,
    num_actions: usize,
}

impl<V: VecEnv + Send + 'static> ShardedVecEnv<V> {
    /// Parallel executor: one worker thread per shard.
    pub fn from_shards(shards: Vec<V>) -> ShardedVecEnv<V> {
        Self::build(shards, true)
    }

    /// Same sharding, executed inline on the caller thread (testing and the
    /// `num_workers = 1` path).
    pub fn serial_from_shards(shards: Vec<V>) -> ShardedVecEnv<V> {
        Self::build(shards, false)
    }

    fn build(shards: Vec<V>, parallel: bool) -> ShardedVecEnv<V> {
        assert!(!shards.is_empty(), "need at least one shard");
        let obs_dim = shards[0].obs_dim();
        let num_actions = shards[0].num_actions();
        let mut wrapped = Vec::with_capacity(shards.len());
        let mut start = 0usize;
        for env in shards {
            assert_eq!(env.obs_dim(), obs_dim, "shards must agree on obs_dim");
            assert_eq!(env.num_actions(), num_actions, "shards must agree on num_actions");
            assert!(env.num_envs() > 0, "empty shard");
            let n = env.num_envs();
            wrapped.push(Shard { env, start });
            start += n;
        }
        ShardedVecEnv {
            exec: ShardExec::new(wrapped, parallel),
            num_envs: start,
            obs_dim,
            num_actions,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.exec.num_shards()
    }

    pub fn is_parallel(&self) -> bool {
        self.exec.is_parallel()
    }
}

impl<V: VecEnv + Send + 'static> VecEnv for ShardedVecEnv<V> {
    fn num_envs(&self) -> usize {
        self.num_envs
    }

    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn num_actions(&self) -> usize {
        self.num_actions
    }

    fn reset_all(&mut self, seed: u64) {
        self.exec.run_mut(move |_, shard| shard.env.reset_all(seed));
    }

    fn observe_all(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.num_envs * self.obs_dim);
        let d = self.obs_dim;
        let out = SendSliceMut::new(out);
        self.exec.run_ref(move |_, shard| {
            let n = shard.env.num_envs();
            // SAFETY: shard ranges are disjoint and tile [0, B); run_ref
            // blocks until every shard is done writing.
            let dst = unsafe { out.range(shard.start * d, n * d) };
            shard.env.observe_all(dst);
        });
    }

    fn step_all(&mut self, actions: &[usize], rewards: &mut [f32], dones: &mut [bool]) {
        debug_assert_eq!(actions.len(), self.num_envs);
        debug_assert_eq!(rewards.len(), self.num_envs);
        debug_assert_eq!(dones.len(), self.num_envs);
        let actions = SendSliceRef::new(actions);
        let rewards = SendSliceMut::new(rewards);
        let dones = SendSliceMut::new(dones);
        self.exec.run_mut(move |_, shard| {
            let (s, n) = (shard.start, shard.env.num_envs());
            // SAFETY: disjoint per-shard ranges; run_mut blocks until done.
            let (a, r, dn) = unsafe { (actions.range(s, n), rewards.range(s, n), dones.range(s, n)) };
            shard.env.step_all(a, r, dn);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::test_envs::Corridor;
    use crate::core::GsVecEnv;

    #[test]
    fn shard_ranges_tile_and_balance() {
        assert_eq!(shard_ranges(10, 4), vec![(0, 3), (3, 6), (6, 8), (8, 10)]);
        assert_eq!(shard_ranges(3, 1), vec![(0, 3)]);
        assert_eq!(shard_ranges(2, 8), vec![(0, 1), (1, 2)]);
        let r = shard_ranges(1024, 8);
        assert_eq!(r.len(), 8);
        assert!(r.iter().all(|&(s, e)| e - s == 128));
    }

    #[test]
    fn pool_runs_jobs_with_borrowed_state() {
        let pool = ShardPool::new(vec![0u64, 10, 20, 30]);
        let mut out = vec![0u64; 4];
        let out_ptr = SendSliceMut::new(&mut out);
        for round in 1..=3u64 {
            pool.run_all(&move |i, s: &mut u64| {
                *s += round;
                let dst = unsafe { out_ptr.range(i, 1) };
                dst[0] = *s;
            });
        }
        assert_eq!(out, vec![6, 16, 26, 36]);
    }

    fn make_sharded(b: usize, w: usize, parallel: bool) -> ShardedVecEnv<GsVecEnv<Corridor>> {
        let shards: Vec<GsVecEnv<Corridor>> = shard_ranges(b, w)
            .into_iter()
            .map(|(s, e)| {
                GsVecEnv::with_index_offset((s..e).map(|_| Corridor::new(3, 5)).collect(), s)
            })
            .collect();
        if parallel {
            ShardedVecEnv::from_shards(shards)
        } else {
            ShardedVecEnv::serial_from_shards(shards)
        }
    }

    #[test]
    fn sharded_matches_serial_bitwise() {
        let b = 10;
        let mut serial = GsVecEnv::new((0..b).map(|_| Corridor::new(3, 5)).collect());
        let mut sharded = make_sharded(b, 4, true);
        serial.reset_all(42);
        sharded.reset_all(42);
        let mut obs_a = vec![0.0f32; b * 3];
        let mut obs_b = vec![0.0f32; b * 3];
        let (mut ra, mut rb) = (vec![0.0f32; b], vec![0.0f32; b]);
        let (mut da, mut db) = (vec![false; b], vec![false; b]);
        for t in 0..20 {
            let actions: Vec<usize> = (0..b).map(|i| (t + i) % 2).collect();
            serial.step_all(&actions, &mut ra, &mut da);
            sharded.step_all(&actions, &mut rb, &mut db);
            assert_eq!(ra, rb, "rewards diverged at step {t}");
            assert_eq!(da, db, "dones diverged at step {t}");
            serial.observe_all(&mut obs_a);
            sharded.observe_all(&mut obs_b);
            assert_eq!(obs_a, obs_b, "observations diverged at step {t}");
        }
    }

    #[test]
    fn parallel_matches_inline_sharding() {
        let b = 7;
        let mut inline = make_sharded(b, 3, false);
        let mut pooled = make_sharded(b, 3, true);
        inline.reset_all(9);
        pooled.reset_all(9);
        assert_eq!(pooled.num_shards(), 3);
        assert!(pooled.is_parallel());
        let actions = vec![1usize; b];
        let (mut ra, mut rb) = (vec![0.0f32; b], vec![0.0f32; b]);
        let (mut da, mut db) = (vec![false; b], vec![false; b]);
        for _ in 0..12 {
            inline.step_all(&actions, &mut ra, &mut da);
            pooled.step_all(&actions, &mut rb, &mut db);
            assert_eq!(ra, rb);
            assert_eq!(da, db);
        }
    }

    #[test]
    fn effective_workers_resolves_auto() {
        assert_eq!(effective_workers(3), 3);
        assert!(effective_workers(0) >= 1);
    }
}
