//! Sharded multi-threaded execution: a reusable compute pool shared by
//! vectorized-environment stepping (the sim half) and the native NN
//! engine's data-parallel forwards/updates (the NN half).
//!
//! The paper's whole value proposition is throughput, and the repo's hot
//! loop alternates two kinds of work: `VecEnv::step_all` over `B`
//! environments and batched NN calls (policy/AIP forwards, PPO/AIP
//! training). Both halves scale with cores while preserving two invariants:
//!
//! 1. **Minimal dispatches per step.** Training-phase NN work (which
//!    mutates parameters) is dispatched by the coordinator thread —
//!    `Runtime` is `Rc`/`RefCell`-based and its *ops* fan row-slices out
//!    over the pool. Forward-path NN work is `Sync` (`runtime::native`'s
//!    views), so the fused IALS step runs gather → AIP forward →
//!    influence sampling → LS step in **one** dispatch per step
//!    (`ials::IalsVecEnv`); the policy forward stays one batched pooled
//!    call per step (action sampling consumes a single RNG stream on the
//!    coordinator).
//! 2. **Bitwise determinism.** Each shard owns a contiguous range of env
//!    indices (seeded from *global* indices), and NN work partitions over
//!    row bands whose per-row arithmetic is independent, so any
//!    `num_workers` / `nn_workers` / pipeline (fused or sandwich)
//!    produces outputs identical to serial.
//!
//! Building blocks:
//!
//! * [`ComputePool`] — a persistent worker pool (spawned once, reused for
//!   every dispatch; no per-step thread spawn and **no per-dispatch heap
//!   allocation**: jobs are broadcast through a generation counter +
//!   condvars, not boxed closures on a channel). One pool serves the whole
//!   training run — sim shards and NN slices share it, so the process never
//!   oversubscribes cores ([`ComputePool::shared`]).
//! * [`ShardPool`] — per-shard owned state (`S` = a vec-env shard) executed
//!   over a [`ComputePool`].
//! * [`ShardExec`] — serial-or-pooled executor so callers write one code
//!   path and `num_workers = 1` stays exactly the old serial loop.
//! * [`ShardedVecEnv`] — a [`VecEnv`] adapter that partitions any batch of
//!   per-shard vec-envs and runs `step_all`/`observe_all`/`reset_all`
//!   concurrently, each shard writing directly into its disjoint slice of
//!   the shared env-major buffers (no gather copies).
//! * [`WorkerPlan`] — the single resolution point for the `[ppo]
//!   num_workers` and `[runtime] nn_workers` knobs (`0` = one per core for
//!   both, via [`effective_workers`]), so the two halves always agree on
//!   the core count and the shared pool size.

use super::VecEnv;
use crate::util::{StateReader, StateWriter};
use std::cell::UnsafeCell;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// Resolve a configured worker count: `0` means "one per available core".
pub fn effective_workers(requested: usize) -> usize {
    if requested == 0 {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Resolved worker counts for one training run. Both knobs (`[ppo]
/// num_workers` for the sim half, `[runtime] nn_workers` for the NN half)
/// funnel through here so `0` means the same core count everywhere and the
/// shared pool is sized once for the larger of the two (one pool per run —
/// the halves never run concurrently, so this never oversubscribes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerPlan {
    /// Sharded env stepping + dataset collection workers.
    pub sim: usize,
    /// NN row-slice workers (native backend forwards + training).
    pub nn: usize,
}

impl WorkerPlan {
    pub fn resolve(sim_requested: usize, nn_requested: usize) -> WorkerPlan {
        WorkerPlan {
            sim: effective_workers(sim_requested),
            nn: effective_workers(nn_requested),
        }
    }

    /// Threads the shared pool needs to serve both halves.
    pub fn pool_size(&self) -> usize {
        self.sim.max(self.nn)
    }

    /// The run's shared pool, sized for both halves (`None` when everything
    /// is serial).
    pub fn shared_pool(&self) -> Option<Arc<ComputePool>> {
        if self.pool_size() > 1 {
            Some(ComputePool::shared(self.pool_size()))
        } else {
            None
        }
    }
}

/// Balanced contiguous partition of `n` items over `w` shards: the first
/// `n % w` shards get one extra item. Returns `[start, end)` ranges that
/// tile `[0, n)` in order.
pub fn shard_ranges(n: usize, w: usize) -> Vec<(usize, usize)> {
    let w = w.clamp(1, n.max(1));
    let (base, extra) = (n / w, n % w);
    let mut ranges = Vec::with_capacity(w);
    let mut start = 0;
    for i in 0..w {
        let len = base + usize::from(i < extra);
        ranges.push((start, start + len));
        start += len;
    }
    debug_assert_eq!(start, n, "shard ranges must tile the batch");
    ranges
}

/// A raw handle to a mutable slice that can cross threads. Each worker gets
/// a *disjoint* sub-range, which is what makes the aliasing sound.
pub struct SendSliceMut<T> {
    ptr: *mut T,
    len: usize,
}

impl<T> Clone for SendSliceMut<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendSliceMut<T> {}
unsafe impl<T: Send> Send for SendSliceMut<T> {}
unsafe impl<T: Send> Sync for SendSliceMut<T> {}

impl<T> SendSliceMut<T> {
    pub fn new(slice: &mut [T]) -> SendSliceMut<T> {
        SendSliceMut { ptr: slice.as_mut_ptr(), len: slice.len() }
    }

    /// Reborrow `[start, start + len)` of the underlying slice.
    ///
    /// # Safety
    /// Concurrent callers must use disjoint ranges, and the slice handed to
    /// [`SendSliceMut::new`] must outlive every use (the executors below
    /// guarantee this by blocking until all workers acknowledge completion).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range(&self, start: usize, len: usize) -> &mut [T] {
        assert!(start + len <= self.len, "shard slice range out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

/// Shared-slice counterpart of [`SendSliceMut`] for read-only inputs.
pub struct SendSliceRef<T> {
    ptr: *const T,
    len: usize,
}

impl<T> Clone for SendSliceRef<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendSliceRef<T> {}
unsafe impl<T: Sync> Send for SendSliceRef<T> {}
unsafe impl<T: Sync> Sync for SendSliceRef<T> {}

impl<T> SendSliceRef<T> {
    pub fn new(slice: &[T]) -> SendSliceRef<T> {
        SendSliceRef { ptr: slice.as_ptr(), len: slice.len() }
    }

    /// Reborrow `[start, start + len)` of the underlying slice.
    ///
    /// # Safety
    /// The slice handed to [`SendSliceRef::new`] must outlive every use.
    pub unsafe fn range(&self, start: usize, len: usize) -> &[T] {
        assert!(start + len <= self.len, "shard slice range out of bounds");
        std::slice::from_raw_parts(self.ptr.add(start), len)
    }
}

// ---------------------------------------------------------------------------
// ComputePool: allocation-free broadcast worker pool
// ---------------------------------------------------------------------------

/// Type-erased pointer to the caller's task function. Only alive for the
/// duration of one [`ComputePool::run_tasks`] call, which blocks until all
/// workers acknowledge — the classic scoped-pool lifetime argument, but
/// through a shared slot instead of boxed channel messages so a dispatch
/// performs **zero heap allocations**.
#[derive(Clone, Copy)]
struct TaskRef(*const (dyn Fn(usize) + Sync + 'static));

// SAFETY: the pointee is `Sync` (callable through `&` from any thread) and
// `run_tasks` keeps it alive until every worker has acknowledged.
unsafe impl Send for TaskRef {}

impl TaskRef {
    /// Erase the borrow lifetime so the pointer can sit in the shared slot.
    ///
    /// # Safety
    /// The caller must not return (or invalidate borrows captured by `f`)
    /// until every worker has acknowledged the dispatch.
    unsafe fn erase<'a>(f: &'a (dyn Fn(usize) + Sync + 'a)) -> TaskRef {
        let ptr: *const (dyn Fn(usize) + Sync + 'a) = f;
        TaskRef(std::mem::transmute::<
            *const (dyn Fn(usize) + Sync + 'a),
            *const (dyn Fn(usize) + Sync + 'static),
        >(ptr))
    }
}

struct PoolCtl {
    /// Bumped per dispatch; workers run each generation exactly once.
    generation: u64,
    job: Option<TaskRef>,
    n_tasks: usize,
    /// Workers `w < stride` participate; task `i` runs on worker
    /// `i % stride` (a static assignment — no work stealing, no atomics).
    stride: usize,
    /// Workers that have not yet acknowledged the current generation.
    remaining: usize,
    failed: bool,
    shutdown: bool,
}

struct PoolShared {
    ctl: Mutex<PoolCtl>,
    /// Workers wait here for a new generation.
    work_cv: Condvar,
    /// The dispatching thread waits here for all acknowledgements.
    done_cv: Condvar,
    workers: usize,
}

/// A persistent pool of worker threads executing broadcast task sets.
///
/// `run_tasks(n, limit, f)` runs `f(0), …, f(n-1)` across the workers and
/// blocks until all are done. Properties the rest of the repo leans on:
///
/// * **No per-dispatch allocation** — the job crosses threads as a borrowed
///   pointer through a mutex-guarded slot (generation counter + condvars),
///   never as a boxed closure on a channel. The training-path allocation
///   audit (`rust/tests/native_alloc.rs`) depends on this.
/// * **Deterministic work product** — the task → worker assignment is
///   irrelevant to callers: every task writes disjoint output, so results
///   are identical for any pool size or `limit`.
/// * **Reentrancy** — concurrent `run_tasks` calls from different threads
///   serialize on an internal dispatch lock. Calling `run_tasks` from
///   *inside* a task would deadlock; the repo's phases (sim stepping vs NN
///   slices) never nest.
pub struct ComputePool {
    shared: Arc<PoolShared>,
    /// Serializes concurrent dispatchers (the pool is process-shared).
    dispatch: Mutex<()>,
    handles: Vec<thread::JoinHandle<()>>,
}

/// The process-wide shared pool (one pool per training run — sim shards and
/// NN slices never run concurrently, so sharing keeps active threads ≤ the
/// pool size). If a *bigger* pool is later requested, the registry swaps to
/// it; holders of the old pool keep it alive until they drop, but its
/// threads sit parked in `Condvar::wait` — idle threads, not running ones —
/// so size the pool once per run (`WorkerPlan::shared_pool`) to avoid even
/// that.
static SHARED_POOL: Mutex<Option<Arc<ComputePool>>> = Mutex::new(None);

fn pool_worker(shared: Arc<PoolShared>, w: usize) {
    let mut seen = 0u64;
    loop {
        let (job, n_tasks, stride, generation) = {
            let mut ctl = shared.ctl.lock().unwrap();
            loop {
                if ctl.shutdown {
                    return;
                }
                if ctl.generation != seen && ctl.job.is_some() {
                    break;
                }
                ctl = shared.work_cv.wait(ctl).unwrap();
            }
            (ctl.job.unwrap(), ctl.n_tasks, ctl.stride, ctl.generation)
        };
        seen = generation;
        if w >= stride {
            // Not part of this dispatch: it was not counted in `remaining`,
            // so skip without acknowledging (the coordinator only waits on
            // the `stride` participating workers).
            continue;
        }
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // SAFETY: `run_tasks` keeps the pointee alive until every
            // participating worker (including this one) acknowledges below.
            let f = unsafe { &*job.0 };
            let mut i = w;
            while i < n_tasks {
                f(i);
                i += stride;
            }
        }))
        .is_ok();
        let mut ctl = shared.ctl.lock().unwrap();
        if !ok {
            ctl.failed = true;
        }
        ctl.remaining -= 1;
        if ctl.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

impl ComputePool {
    pub fn new(workers: usize) -> ComputePool {
        assert!(workers >= 1, "compute pool needs at least one worker");
        let shared = Arc::new(PoolShared {
            ctl: Mutex::new(PoolCtl {
                generation: 0,
                job: None,
                n_tasks: 0,
                stride: 1,
                remaining: 0,
                failed: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            workers,
        });
        let handles = (0..workers)
            .map(|w| {
                let shared = shared.clone();
                thread::Builder::new()
                    .name(format!("compute-pool-{w}"))
                    .spawn(move || pool_worker(shared, w))
                    .expect("spawning compute-pool worker thread")
            })
            .collect();
        ComputePool { shared, dispatch: Mutex::new(()), handles }
    }

    /// The process-shared pool with at least `workers` threads. Reuses the
    /// existing pool when it is big enough; otherwise replaces it (current
    /// holders keep their `Arc` until they drop). Size the pool once per
    /// run via [`WorkerPlan::shared_pool`] so both halves get one pool.
    pub fn shared(workers: usize) -> Arc<ComputePool> {
        let mut slot = SHARED_POOL.lock().unwrap();
        if let Some(p) = slot.as_ref() {
            if p.workers() >= workers {
                return p.clone();
            }
        }
        let p = Arc::new(ComputePool::new(workers));
        *slot = Some(p.clone());
        p
    }

    pub fn workers(&self) -> usize {
        self.shared.workers
    }

    /// Run `f(0), …, f(n_tasks - 1)` across at most `max_workers` workers
    /// and block until all tasks complete. Task `i` runs on worker
    /// `i % stride` (`stride = min(workers, n_tasks, max_workers)`), tasks
    /// on one worker in increasing order; only the `stride` participating
    /// workers are waited on. Panics (after every participant has
    /// acknowledged, so no task still touches caller borrows) if any task
    /// panicked.
    pub fn run_tasks(&self, n_tasks: usize, max_workers: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        let stride = self.workers().min(n_tasks).min(max_workers.max(1));
        let failed = {
            let _serialize = self.dispatch.lock().unwrap();
            // SAFETY: this scope blocks until `remaining == 0`, i.e. every
            // participating worker has finished with the pointer; `f` and
            // its captures outlive that.
            let job = unsafe { TaskRef::erase(f) };
            let mut ctl = self.shared.ctl.lock().unwrap();
            ctl.generation = ctl.generation.wrapping_add(1);
            ctl.job = Some(job);
            ctl.n_tasks = n_tasks;
            ctl.stride = stride;
            ctl.remaining = stride;
            ctl.failed = false;
            self.shared.work_cv.notify_all();
            while ctl.remaining > 0 {
                ctl = self.shared.done_cv.wait(ctl).unwrap();
            }
            ctl.job = None;
            ctl.failed
            // Both guards drop *before* the panic below, so a panicking
            // task never poisons the process-shared dispatch/ctl mutexes.
        };
        assert!(!failed, "a compute-pool worker panicked");
    }
}

impl Drop for ComputePool {
    fn drop(&mut self) {
        {
            let mut ctl = self.shared.ctl.lock().unwrap();
            ctl.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

// ---------------------------------------------------------------------------
// ShardPool: per-shard owned state over a ComputePool
// ---------------------------------------------------------------------------

/// Interior-mutable shard slot. Exclusive access per index is guaranteed by
/// the pool's dispatch protocol (each task index runs exactly once per
/// dispatch and `run_tasks` blocks until all complete).
struct ShardCell<S>(UnsafeCell<S>);

// SAFETY: only one worker touches a given cell per dispatch (task i ↔ cell
// i), and dispatches are serialized + barriered by the pool.
unsafe impl<S: Send> Sync for ShardCell<S> {}

/// Shard states executed over a (usually process-shared) [`ComputePool`].
/// Replaces the old channel-based pool: states now live with the pool
/// handle on the coordinator, workers borrow them per dispatch.
pub struct ShardPool<S: Send + 'static> {
    states: Vec<ShardCell<S>>,
    pool: Arc<ComputePool>,
}

impl<S: Send + 'static> ShardPool<S> {
    /// Build over the process-shared pool, growing it to at least one
    /// worker per shard.
    pub fn new(states: Vec<S>) -> ShardPool<S> {
        assert!(!states.is_empty(), "shard pool needs at least one shard");
        let pool = ComputePool::shared(states.len());
        Self::with_pool(states, pool)
    }

    /// Build over an explicit pool (may be smaller or larger than the shard
    /// count; tasks round-robin).
    pub fn with_pool(states: Vec<S>, pool: Arc<ComputePool>) -> ShardPool<S> {
        assert!(!states.is_empty(), "shard pool needs at least one shard");
        ShardPool {
            states: states.into_iter().map(|s| ShardCell(UnsafeCell::new(s))).collect(),
            pool,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.states.len()
    }

    /// Run `f(shard_index, &mut shard_state)` for every shard concurrently
    /// and block until all have finished.
    pub fn run_all(&self, f: &(dyn Fn(usize, &mut S) + Send + Sync)) {
        let states = &self.states;
        let task = move |i: usize| {
            // SAFETY: task i is dispatched exactly once and run_tasks blocks
            // until completion, so this &mut is exclusive for the call.
            let s = unsafe { &mut *states[i].0.get() };
            f(i, s);
        };
        self.pool.run_tasks(states.len(), usize::MAX, &task);
    }
}

/// Serial-or-pooled shard executor: one code path for callers, with
/// `Serial` behaving exactly like the pre-sharding loop (same order, same
/// thread) so `num_workers = 1` is the old semantics by construction.
pub enum ShardExec<S: Send + 'static> {
    Serial(Vec<S>),
    Pool(ShardPool<S>),
}

impl<S: Send + 'static> ShardExec<S> {
    /// `parallel = false` (or a single shard) keeps everything inline.
    pub fn new(shards: Vec<S>, parallel: bool) -> ShardExec<S> {
        assert!(!shards.is_empty(), "need at least one shard");
        if parallel && shards.len() > 1 {
            ShardExec::Pool(ShardPool::new(shards))
        } else {
            ShardExec::Serial(shards)
        }
    }

    pub fn num_shards(&self) -> usize {
        match self {
            ShardExec::Serial(shards) => shards.len(),
            ShardExec::Pool(pool) => pool.num_shards(),
        }
    }

    pub fn is_parallel(&self) -> bool {
        matches!(self, ShardExec::Pool(_))
    }

    /// Run a mutating pass over every shard (parallel when pooled).
    pub fn run_mut(&mut self, f: impl Fn(usize, &mut S) + Send + Sync) {
        match self {
            ShardExec::Serial(shards) => {
                for (i, s) in shards.iter_mut().enumerate() {
                    f(i, s);
                }
            }
            ShardExec::Pool(pool) => pool.run_all(&f),
        }
    }

    /// Run a read-only pass over every shard (parallel when pooled).
    pub fn run_ref(&self, f: impl Fn(usize, &S) + Send + Sync) {
        match self {
            ShardExec::Serial(shards) => {
                for (i, s) in shards.iter().enumerate() {
                    f(i, s);
                }
            }
            ShardExec::Pool(pool) => {
                let g = move |i: usize, s: &mut S| f(i, &*s);
                pool.run_all(&g);
            }
        }
    }

    /// Direct access to shard states — only possible in serial mode (the
    /// pooled variant hands states out per dispatch).
    pub fn serial_shards_mut(&mut self) -> Option<&mut [S]> {
        match self {
            ShardExec::Serial(shards) => Some(shards),
            ShardExec::Pool(_) => None,
        }
    }
}

/// One shard of a [`ShardedVecEnv`]: a smaller vec-env covering the global
/// env indices `[start, start + env.num_envs())`.
pub struct Shard<V> {
    pub env: V,
    pub start: usize,
}

/// Parallel adapter over per-shard [`VecEnv`]s. Construct the shards so
/// that shard `i` covers the `i`-th range of [`shard_ranges`] *and* seeds
/// its envs by global index (e.g. [`super::GsVecEnv::with_index_offset`]);
/// then sharded output is bitwise identical to the equivalent serial env.
pub struct ShardedVecEnv<V: VecEnv + Send + 'static> {
    exec: ShardExec<Shard<V>>,
    num_envs: usize,
    obs_dim: usize,
    num_actions: usize,
}

impl<V: VecEnv + Send + 'static> ShardedVecEnv<V> {
    /// Parallel executor over the shared compute pool.
    pub fn from_shards(shards: Vec<V>) -> ShardedVecEnv<V> {
        Self::build(shards, true)
    }

    /// Same sharding, executed inline on the caller thread (testing and the
    /// `num_workers = 1` path).
    pub fn serial_from_shards(shards: Vec<V>) -> ShardedVecEnv<V> {
        Self::build(shards, false)
    }

    fn build(shards: Vec<V>, parallel: bool) -> ShardedVecEnv<V> {
        assert!(!shards.is_empty(), "need at least one shard");
        let obs_dim = shards[0].obs_dim();
        let num_actions = shards[0].num_actions();
        let mut wrapped = Vec::with_capacity(shards.len());
        let mut start = 0usize;
        for env in shards {
            assert_eq!(env.obs_dim(), obs_dim, "shards must agree on obs_dim");
            assert_eq!(env.num_actions(), num_actions, "shards must agree on num_actions");
            assert!(env.num_envs() > 0, "empty shard");
            let n = env.num_envs();
            wrapped.push(Shard { env, start });
            start += n;
        }
        ShardedVecEnv {
            exec: ShardExec::new(wrapped, parallel),
            num_envs: start,
            obs_dim,
            num_actions,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.exec.num_shards()
    }

    pub fn is_parallel(&self) -> bool {
        self.exec.is_parallel()
    }
}

impl<V: VecEnv + Send + 'static> VecEnv for ShardedVecEnv<V> {
    fn num_envs(&self) -> usize {
        self.num_envs
    }

    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn num_actions(&self) -> usize {
        self.num_actions
    }

    fn reset_all(&mut self, seed: u64) {
        self.exec.run_mut(move |_, shard| shard.env.reset_all(seed));
    }

    fn observe_all(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.num_envs * self.obs_dim);
        let d = self.obs_dim;
        let out = SendSliceMut::new(out);
        self.exec.run_ref(move |_, shard| {
            let n = shard.env.num_envs();
            // SAFETY: shard ranges are disjoint and tile [0, B); run_ref
            // blocks until every shard is done writing.
            let dst = unsafe { out.range(shard.start * d, n * d) };
            shard.env.observe_all(dst);
        });
    }

    fn step_all(&mut self, actions: &[usize], rewards: &mut [f32], dones: &mut [bool]) {
        debug_assert_eq!(actions.len(), self.num_envs);
        debug_assert_eq!(rewards.len(), self.num_envs);
        debug_assert_eq!(dones.len(), self.num_envs);
        let actions = SendSliceRef::new(actions);
        let rewards = SendSliceMut::new(rewards);
        let dones = SendSliceMut::new(dones);
        self.exec.run_mut(move |_, shard| {
            let (s, n) = (shard.start, shard.env.num_envs());
            // SAFETY: disjoint per-shard ranges; run_mut blocks until done.
            let (a, r, dn) =
                unsafe { (actions.range(s, n), rewards.range(s, n), dones.range(s, n)) };
            shard.env.step_all(a, r, dn);
        });
    }

    fn save_state(&self, out: &mut StateWriter) -> crate::Result<()> {
        // Each shard serializes into its own byte slot (in parallel when
        // pooled), then the slots are concatenated length-prefixed in shard
        // order — so the on-disk layout is independent of the worker count.
        let mut slots: Vec<crate::Result<Vec<u8>>> =
            (0..self.exec.num_shards()).map(|_| Ok(Vec::new())).collect();
        let slots_ptr = SendSliceMut::new(&mut slots);
        self.exec.run_ref(move |i, shard| {
            // SAFETY: slot i is written only by task i; run_ref barriers.
            let slot = unsafe { slots_ptr.range(i, 1) };
            let mut w = StateWriter::new();
            slot[0] = shard.env.save_state(&mut w).map(|()| w.into_bytes());
        });
        out.usize(slots.len());
        for slot in slots {
            out.bytes(&slot?);
        }
        Ok(())
    }

    fn load_state(&mut self, r: &mut StateReader) -> crate::Result<()> {
        let n = r.usize()?;
        anyhow::ensure!(
            n == self.exec.num_shards(),
            "sharded-env snapshot has {n} shards, executor has {}",
            self.exec.num_shards()
        );
        let blobs: Vec<&[u8]> =
            (0..n).map(|_| r.bytes()).collect::<crate::Result<Vec<_>>>()?;
        let mut results: Vec<crate::Result<()>> = (0..n).map(|_| Ok(())).collect();
        let blobs_ptr = SendSliceRef::new(&blobs);
        let results_ptr = SendSliceMut::new(&mut results);
        self.exec.run_mut(move |i, shard| {
            // SAFETY: disjoint per-task slots; run_mut barriers.
            let (blob, slot) = unsafe { (&blobs_ptr.range(i, 1)[0], results_ptr.range(i, 1)) };
            let mut sr = StateReader::new(blob);
            slot[0] = shard.env.load_state(&mut sr).and_then(|()| sr.expect_end());
        });
        results.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::test_envs::Corridor;
    use crate::core::GsVecEnv;

    #[test]
    fn shard_ranges_tile_and_balance() {
        assert_eq!(shard_ranges(10, 4), vec![(0, 3), (3, 6), (6, 8), (8, 10)]);
        assert_eq!(shard_ranges(3, 1), vec![(0, 3)]);
        assert_eq!(shard_ranges(2, 8), vec![(0, 1), (1, 2)]);
        let r = shard_ranges(1024, 8);
        assert_eq!(r.len(), 8);
        assert!(r.iter().all(|&(s, e)| e - s == 128));
    }

    #[test]
    fn compute_pool_runs_all_tasks_with_borrows() {
        let pool = ComputePool::new(3);
        let xs: Vec<u64> = (0..10).collect();
        let mut out = vec![0u64; 10];
        let out_ptr = SendSliceMut::new(&mut out);
        let task = |i: usize| {
            let dst = unsafe { out_ptr.range(i, 1) };
            dst[0] = xs[i] * 2;
        };
        pool.run_tasks(10, usize::MAX, &task);
        assert_eq!(out, (0..10).map(|i| i * 2).collect::<Vec<u64>>());
        // A worker limit below the pool size still runs every task.
        out.fill(0);
        pool.run_tasks(10, 2, &task);
        assert_eq!(out, (0..10).map(|i| i * 2).collect::<Vec<u64>>());
        // More tasks than workers round-robin.
        let mut hits = vec![0u32; 100];
        let hits_ptr = SendSliceMut::new(&mut hits);
        pool.run_tasks(100, usize::MAX, &|i| {
            let dst = unsafe { hits_ptr.range(i, 1) };
            dst[0] += 1;
        });
        assert!(hits.iter().all(|&h| h == 1));
    }

    #[test]
    fn shared_pool_is_reused_and_grows() {
        // Request a size no other test in this binary exceeds, so concurrent
        // tests can only reuse (never replace) the registry pool while we
        // compare identities.
        let a = ComputePool::shared(32);
        assert!(a.workers() >= 32);
        let b = ComputePool::shared(2);
        assert!(Arc::ptr_eq(&a, &b), "smaller request reuses the pool");
        let c = ComputePool::shared(a.workers());
        assert!(Arc::ptr_eq(&a, &c), "equal request reuses the pool");
        // A private pool is independent of the registry.
        let own = ComputePool::new(2);
        assert_eq!(own.workers(), 2);
    }

    #[test]
    fn pool_runs_jobs_with_borrowed_state() {
        let pool = ShardPool::new(vec![0u64, 10, 20, 30]);
        let mut out = vec![0u64; 4];
        let out_ptr = SendSliceMut::new(&mut out);
        for round in 1..=3u64 {
            pool.run_all(&move |i, s: &mut u64| {
                *s += round;
                let dst = unsafe { out_ptr.range(i, 1) };
                dst[0] = *s;
            });
        }
        assert_eq!(out, vec![6, 16, 26, 36]);
    }

    #[test]
    fn concurrent_dispatchers_serialize() {
        let pool = ComputePool::shared(2);
        std::thread::scope(|scope| {
            for t in 0..4 {
                let pool = pool.clone();
                scope.spawn(move || {
                    let mut out = vec![0usize; 16];
                    let out_ptr = SendSliceMut::new(&mut out);
                    for _ in 0..50 {
                        pool.run_tasks(16, usize::MAX, &|i| {
                            let dst = unsafe { out_ptr.range(i, 1) };
                            dst[0] = i + t;
                        });
                    }
                    assert_eq!(out, (0..16).map(|i| i + t).collect::<Vec<usize>>());
                });
            }
        });
    }

    fn make_sharded(b: usize, w: usize, parallel: bool) -> ShardedVecEnv<GsVecEnv<Corridor>> {
        let shards: Vec<GsVecEnv<Corridor>> = shard_ranges(b, w)
            .into_iter()
            .map(|(s, e)| {
                GsVecEnv::with_index_offset((s..e).map(|_| Corridor::new(3, 5)).collect(), s)
            })
            .collect();
        if parallel {
            ShardedVecEnv::from_shards(shards)
        } else {
            ShardedVecEnv::serial_from_shards(shards)
        }
    }

    #[test]
    fn sharded_matches_serial_bitwise() {
        let b = 10;
        let mut serial = GsVecEnv::new((0..b).map(|_| Corridor::new(3, 5)).collect());
        let mut sharded = make_sharded(b, 4, true);
        serial.reset_all(42);
        sharded.reset_all(42);
        let mut obs_a = vec![0.0f32; b * 3];
        let mut obs_b = vec![0.0f32; b * 3];
        let (mut ra, mut rb) = (vec![0.0f32; b], vec![0.0f32; b]);
        let (mut da, mut db) = (vec![false; b], vec![false; b]);
        for t in 0..20 {
            let actions: Vec<usize> = (0..b).map(|i| (t + i) % 2).collect();
            serial.step_all(&actions, &mut ra, &mut da);
            sharded.step_all(&actions, &mut rb, &mut db);
            assert_eq!(ra, rb, "rewards diverged at step {t}");
            assert_eq!(da, db, "dones diverged at step {t}");
            serial.observe_all(&mut obs_a);
            sharded.observe_all(&mut obs_b);
            assert_eq!(obs_a, obs_b, "observations diverged at step {t}");
        }
    }

    #[test]
    fn parallel_matches_inline_sharding() {
        let b = 7;
        let mut inline = make_sharded(b, 3, false);
        let mut pooled = make_sharded(b, 3, true);
        inline.reset_all(9);
        pooled.reset_all(9);
        assert_eq!(pooled.num_shards(), 3);
        assert!(pooled.is_parallel());
        let actions = vec![1usize; b];
        let (mut ra, mut rb) = (vec![0.0f32; b], vec![0.0f32; b]);
        let (mut da, mut db) = (vec![false; b], vec![false; b]);
        for _ in 0..12 {
            inline.step_all(&actions, &mut ra, &mut da);
            pooled.step_all(&actions, &mut rb, &mut db);
            assert_eq!(ra, rb);
            assert_eq!(da, db);
        }
    }

    #[test]
    fn effective_workers_resolves_auto() {
        assert_eq!(effective_workers(3), 3);
        assert!(effective_workers(0) >= 1);
    }

    #[test]
    fn worker_plan_resolves_both_knobs_through_one_helper() {
        let plan = WorkerPlan::resolve(4, 2);
        assert_eq!((plan.sim, plan.nn), (4, 2));
        assert_eq!(plan.pool_size(), 4);
        // `0` means the same auto core count for both halves.
        let auto = WorkerPlan::resolve(0, 0);
        assert_eq!(auto.sim, auto.nn);
        assert_eq!(auto.sim, effective_workers(0));
        // Fully-serial plans need no pool.
        assert!(WorkerPlan::resolve(1, 1).shared_pool().is_none());
        let pooled = WorkerPlan::resolve(1, 3).shared_pool().expect("pool");
        assert!(pooled.workers() >= 3);
    }
}
