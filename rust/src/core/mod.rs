//! Core abstractions: the `Environment` family of traits and batched
//! (vectorized) environments.
//!
//! The paper's framing (Definitions 1–3) maps onto three traits:
//!
//! * [`Environment`] — a POMDP the agent can act in (the GS, or an IALS).
//! * [`GlobalEnv`] — a *global simulator*: additionally exposes the ground
//!   truth influence sources `u_t` and the d-set features `d_t` so that
//!   Algorithm 1 can collect `(d_t, u_t)` training pairs.
//! * [`LocalEnv`] — a *local simulator*: steps on `(a_t, u_t)` where `u_t`
//!   is provided externally (by an influence predictor — Algorithm 2).

pub mod history;
pub mod shard;
pub mod vecenv;

pub use history::FrameStacker;
pub use shard::{
    effective_workers, shard_ranges, ComputePool, ShardExec, ShardPool, ShardedVecEnv, WorkerPlan,
};
pub use vecenv::{FrameStackVec, GsVecEnv, VecEnv};

use crate::util::{StateReader, StateWriter};

/// Result of one environment step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Step {
    pub reward: f32,
    pub done: bool,
}

/// A POMDP the agent interacts with. Observations are dense `f32` feature
/// vectors (binary features encoded as 0.0/1.0), actions are discrete.
///
/// Environments own their RNG (seeded at `reset`) so that vectorized
/// rollouts are reproducible per-env regardless of stepping order.
pub trait Environment {
    /// Dimension of the observation vector.
    fn obs_dim(&self) -> usize;
    /// Number of discrete actions.
    fn num_actions(&self) -> usize;
    /// Reset to an initial state drawing randomness from `seed`.
    fn reset(&mut self, seed: u64);
    /// Write the current observation into `out` (len == obs_dim()).
    fn observe(&self, out: &mut [f32]);
    /// Advance one timestep under `action`.
    fn step(&mut self, action: usize) -> Step;

    /// Convenience allocating observer.
    fn observation(&self) -> Vec<f32> {
        let mut v = vec![0.0; self.obs_dim()];
        self.observe(&mut v);
        v
    }

    /// Serialize the env's full mutable state (RNG streams included) for
    /// checkpointing. Implemented by every environment that appears in a
    /// checkpointed training loop; the default refuses, so resume support
    /// is an explicit per-env contract, never a silent partial snapshot.
    fn save_state(&self, _out: &mut StateWriter) -> crate::Result<()> {
        anyhow::bail!("environment does not support state snapshots")
    }

    /// Restore state written by [`Environment::save_state`]; the restored
    /// env continues bit for bit where the saved one stopped.
    fn load_state(&mut self, _r: &mut StateReader) -> crate::Result<()> {
        anyhow::bail!("environment does not support state snapshots")
    }
}

/// A *global simulator*: models every state variable, and can therefore
/// report the true influence sources `u_t` (the variables through which the
/// rest of the system affects the local region) and the d-set `d_t`
/// (the subset of the ALSH that d-separates `u_t` from the agent's actions
/// — paper §4.2).
pub trait GlobalEnv: Environment {
    /// Number of binary influence-source variables.
    fn num_influence_sources(&self) -> usize;
    /// Dimension of the d-set feature vector (one timestep's slice).
    fn dset_dim(&self) -> usize;
    /// Ground-truth influence sources realized at the *last* step.
    fn influence_sources(&self, out: &mut [f32]);
    /// Current d-set features.
    fn dset(&self, out: &mut [f32]);
    /// Dimension of the full-ALSH feature vector (d-set plus the
    /// confounder-prone variables — used by the Appendix B ablation).
    fn alsh_dim(&self) -> usize;
    /// Current full-ALSH features.
    fn alsh(&self, out: &mut [f32]);
}

/// A *local simulator*: models only the agent's local region. Each step
/// consumes the influence-source realization `u_t` (sampled from an AIP in
/// the IALS, or replayed from data in tests).
pub trait LocalEnv {
    fn obs_dim(&self) -> usize;
    fn num_actions(&self) -> usize;
    fn num_influence_sources(&self) -> usize;
    fn dset_dim(&self) -> usize;
    fn reset(&mut self, seed: u64);
    fn observe(&self, out: &mut [f32]);
    /// Current d-set features (input to the AIP — Algorithm 2 line 7).
    fn dset(&self, out: &mut [f32]);
    /// Step under `(a_t, u_t)`: `influence[i]` is the sampled binary
    /// realization of influence source `i`.
    fn step_with_influence(&mut self, action: usize, influence: &[bool]) -> Step;

    /// Serialize the env's full mutable state for checkpointing (same
    /// contract as [`Environment::save_state`]).
    fn save_state(&self, _out: &mut StateWriter) -> crate::Result<()> {
        anyhow::bail!("local environment does not support state snapshots")
    }

    /// Restore state written by [`LocalEnv::save_state`].
    fn load_state(&mut self, _r: &mut StateReader) -> crate::Result<()> {
        anyhow::bail!("local environment does not support state snapshots")
    }
}

#[cfg(test)]
pub(crate) mod test_envs {
    //! Tiny deterministic environments used across unit tests.
    use super::*;

    /// A 1-D corridor: +1 for moving right at the end, episode of fixed
    /// length. Observation = one-hot position.
    pub struct Corridor {
        pub len: usize,
        pub pos: usize,
        pub t: usize,
        pub horizon: usize,
    }

    impl Corridor {
        pub fn new(len: usize, horizon: usize) -> Self {
            Corridor { len, pos: 0, t: 0, horizon }
        }
    }

    impl Environment for Corridor {
        fn obs_dim(&self) -> usize {
            self.len
        }
        fn num_actions(&self) -> usize {
            2
        }
        fn reset(&mut self, _seed: u64) {
            self.pos = 0;
            self.t = 0;
        }
        fn observe(&self, out: &mut [f32]) {
            out.fill(0.0);
            out[self.pos] = 1.0;
        }
        fn step(&mut self, action: usize) -> Step {
            self.t += 1;
            let mut reward = 0.0;
            if action == 1 {
                if self.pos + 1 < self.len {
                    self.pos += 1;
                } else {
                    reward = 1.0;
                }
            } else if self.pos > 0 {
                self.pos -= 1;
            }
            Step { reward, done: self.t >= self.horizon }
        }
    }

}

#[cfg(test)]
mod tests {
    use super::test_envs::Corridor;
    use super::*;

    #[test]
    fn corridor_rewards_at_goal() {
        let mut env = Corridor::new(3, 10);
        env.reset(0);
        let mut total = 0.0;
        for _ in 0..10 {
            let s = env.step(1);
            total += s.reward;
        }
        // reach end in 2 steps, then 8 rewarded steps
        assert_eq!(total, 8.0);
    }

    #[test]
    fn observation_is_one_hot() {
        let mut env = Corridor::new(4, 10);
        env.reset(0);
        env.step(1);
        let obs = env.observation();
        assert_eq!(obs, vec![0.0, 1.0, 0.0, 0.0]);
        assert_eq!(obs.iter().sum::<f32>(), 1.0);
    }
}
