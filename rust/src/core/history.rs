//! Frame stacking / history buffers.
//!
//! Finite-memory agents (paper §4.1, App F) observe a stack of the last
//! `k` observations; [`FrameStacker`] maintains that stack. The same
//! mechanism backs the d-set history fed to feedforward AIPs.

/// Fixed-capacity stack of the last `k` feature vectors, exposed as one
/// flat `[k * dim]` vector (oldest first, zero-padded after reset).
#[derive(Debug, Clone)]
pub struct FrameStacker {
    dim: usize,
    k: usize,
    /// Flat storage, oldest frame first.
    buf: Vec<f32>,
}

impl FrameStacker {
    pub fn new(dim: usize, k: usize) -> FrameStacker {
        assert!(k >= 1, "frame stack must be >= 1");
        FrameStacker { dim, k, buf: vec![0.0; dim * k] }
    }

    pub fn out_dim(&self) -> usize {
        self.dim * self.k
    }

    pub fn frame_dim(&self) -> usize {
        self.dim
    }

    pub fn depth(&self) -> usize {
        self.k
    }

    /// Clear to zeros (episode boundary).
    pub fn reset(&mut self) {
        self.buf.fill(0.0);
    }

    /// Push a new frame (shifts history left; newest frame last).
    pub fn push(&mut self, frame: &[f32]) {
        debug_assert_eq!(frame.len(), self.dim);
        if self.k > 1 {
            self.buf.copy_within(self.dim.., 0);
        }
        let start = (self.k - 1) * self.dim;
        self.buf[start..].copy_from_slice(frame);
    }

    /// The stacked observation, oldest frame first.
    pub fn stacked(&self) -> &[f32] {
        &self.buf
    }

    pub fn write_to(&self, out: &mut [f32]) {
        out.copy_from_slice(&self.buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stack_of_one_is_identity() {
        let mut st = FrameStacker::new(3, 1);
        st.push(&[1.0, 2.0, 3.0]);
        assert_eq!(st.stacked(), &[1.0, 2.0, 3.0]);
        st.push(&[4.0, 5.0, 6.0]);
        assert_eq!(st.stacked(), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn stack_shifts_oldest_out() {
        let mut st = FrameStacker::new(2, 3);
        st.push(&[1.0, 1.0]);
        st.push(&[2.0, 2.0]);
        st.push(&[3.0, 3.0]);
        assert_eq!(st.stacked(), &[1.0, 1.0, 2.0, 2.0, 3.0, 3.0]);
        st.push(&[4.0, 4.0]);
        assert_eq!(st.stacked(), &[2.0, 2.0, 3.0, 3.0, 4.0, 4.0]);
    }

    #[test]
    fn reset_zeroes() {
        let mut st = FrameStacker::new(2, 2);
        st.push(&[1.0, 1.0]);
        st.reset();
        assert_eq!(st.stacked(), &[0.0; 4]);
    }

    #[test]
    fn zero_padding_after_reset() {
        let mut st = FrameStacker::new(1, 4);
        st.push(&[9.0]);
        assert_eq!(st.stacked(), &[0.0, 0.0, 0.0, 9.0]);
    }
}
