//! Vectorized environments: the PPO trainer steps `B` environments in
//! lockstep so that policy forwards are one batched backend call per step
//! instead of `B` calls — the single most important L3 performance lever
//! (DESIGN.md §7). The IALS goes one step further on the native backend
//! and runs its AIP forward *inside* the sharded step dispatch itself
//! (`ials::IalsVecEnv`, the fused pipeline).

use super::{Environment, Step};
use crate::util::{StateReader, StateWriter};

/// A batch of `B` synchronized environments with auto-reset: when an env
/// reports `done`, it is reset immediately and the *initial* observation of
/// the next episode is what `observe_all` returns (standard vec-env
/// semantics).
pub trait VecEnv {
    fn num_envs(&self) -> usize;
    fn obs_dim(&self) -> usize;
    fn num_actions(&self) -> usize;
    /// Reset every env; env `i` is seeded from `seed` + its index.
    fn reset_all(&mut self, seed: u64);
    /// Write all observations, env-major: `out[i*obs_dim .. (i+1)*obs_dim]`.
    fn observe_all(&self, out: &mut [f32]);
    /// Step every env. `rewards[i]`/`dones[i]` describe env `i`'s transition;
    /// auto-reset happens after recording `done`.
    fn step_all(&mut self, actions: &[usize], rewards: &mut [f32], dones: &mut [bool]);

    /// Serialize the full batch state (per-env state, RNG streams, episode
    /// counters, any wrapper history) for checkpointing. The default
    /// refuses — resume support is an explicit per-impl contract.
    fn save_state(&self, _out: &mut StateWriter) -> crate::Result<()> {
        anyhow::bail!("vec env does not support state snapshots")
    }

    /// Restore state written by [`VecEnv::save_state`]; the restored batch
    /// continues bit for bit where the saved one stopped (no `reset_all`).
    fn load_state(&mut self, _r: &mut StateReader) -> crate::Result<()> {
        anyhow::bail!("vec env does not support state snapshots")
    }
}

impl<V: VecEnv + ?Sized> VecEnv for Box<V> {
    fn num_envs(&self) -> usize {
        (**self).num_envs()
    }
    fn obs_dim(&self) -> usize {
        (**self).obs_dim()
    }
    fn num_actions(&self) -> usize {
        (**self).num_actions()
    }
    fn reset_all(&mut self, seed: u64) {
        (**self).reset_all(seed)
    }
    fn observe_all(&self, out: &mut [f32]) {
        (**self).observe_all(out)
    }
    fn step_all(&mut self, actions: &[usize], rewards: &mut [f32], dones: &mut [bool]) {
        (**self).step_all(actions, rewards, dones)
    }
    fn save_state(&self, out: &mut StateWriter) -> crate::Result<()> {
        (**self).save_state(out)
    }
    fn load_state(&mut self, r: &mut StateReader) -> crate::Result<()> {
        (**self).load_state(r)
    }
}

/// Vectorization of any [`Environment`] (used for GS training and for
/// simple test envs). Each env gets an independent seed stream derived from
/// its **global** index (`index_offset + local index`), so a batch split
/// into contiguous shards (see [`super::shard::ShardedVecEnv`]) seeds every
/// env exactly as the equivalent monolithic batch would — the basis of the
/// sharded-equals-serial determinism guarantee.
pub struct GsVecEnv<E: Environment> {
    envs: Vec<E>,
    episode_counter: Vec<u64>,
    base_seed: u64,
    index_offset: usize,
}

impl<E: Environment> GsVecEnv<E> {
    pub fn new(envs: Vec<E>) -> Self {
        Self::with_index_offset(envs, 0)
    }

    /// A shard covering global env indices `[offset, offset + envs.len())`.
    pub fn with_index_offset(envs: Vec<E>, offset: usize) -> Self {
        assert!(!envs.is_empty());
        let n = envs.len();
        GsVecEnv { envs, episode_counter: vec![0; n], base_seed: 0, index_offset: offset }
    }

    pub fn envs(&self) -> &[E] {
        &self.envs
    }

    pub fn index_offset(&self) -> usize {
        self.index_offset
    }

    fn seed_for(&self, env_idx: usize) -> u64 {
        // Distinct per (base_seed, global env index, episode) without
        // collisions.
        self.base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((self.index_offset + env_idx) as u64)
            .wrapping_add(self.episode_counter[env_idx].wrapping_mul(0xD1B54A32D192ED03))
    }
}

impl<E: Environment> VecEnv for GsVecEnv<E> {
    fn num_envs(&self) -> usize {
        self.envs.len()
    }

    fn obs_dim(&self) -> usize {
        self.envs[0].obs_dim()
    }

    fn num_actions(&self) -> usize {
        self.envs[0].num_actions()
    }

    fn reset_all(&mut self, seed: u64) {
        self.base_seed = seed;
        for i in 0..self.envs.len() {
            self.episode_counter[i] = 0;
            let s = self.seed_for(i);
            self.envs[i].reset(s);
        }
    }

    fn observe_all(&self, out: &mut [f32]) {
        let d = self.obs_dim();
        for (i, env) in self.envs.iter().enumerate() {
            env.observe(&mut out[i * d..(i + 1) * d]);
        }
    }

    fn step_all(&mut self, actions: &[usize], rewards: &mut [f32], dones: &mut [bool]) {
        debug_assert_eq!(actions.len(), self.envs.len());
        for i in 0..self.envs.len() {
            let Step { reward, done } = self.envs[i].step(actions[i]);
            rewards[i] = reward;
            dones[i] = done;
            if done {
                self.episode_counter[i] += 1;
                let s = self.seed_for(i);
                self.envs[i].reset(s);
            }
        }
    }

    fn save_state(&self, out: &mut StateWriter) -> crate::Result<()> {
        out.u64(self.base_seed);
        out.u64s(&self.episode_counter);
        for env in &self.envs {
            env.save_state(out)?;
        }
        Ok(())
    }

    fn load_state(&mut self, r: &mut StateReader) -> crate::Result<()> {
        self.base_seed = r.u64()?;
        let counters = r.u64s()?;
        anyhow::ensure!(
            counters.len() == self.envs.len(),
            "vec-env snapshot has {} episode counters, batch has {} envs",
            counters.len(),
            self.envs.len()
        );
        self.episode_counter = counters;
        for env in &mut self.envs {
            env.load_state(r)?;
        }
        Ok(())
    }
}

/// Frame-stacking wrapper over any [`VecEnv`]: multiplies the observation
/// dimension by `k` (paper App F — the warehouse memory agent stacks the
/// last 8 observations).
///
/// History is kept as a ring of `k` full-batch frame slabs (each env-major
/// `[B * frame_dim]`). The inner env writes each new frame **directly into
/// the ring slab** — for a sharded inner env that write happens per-shard
/// into disjoint slices, with no intermediate full-batch scratch copy and
/// no per-step shifting of the history.
pub struct FrameStackVec<V: VecEnv> {
    inner: V,
    k: usize,
    frame_dim: usize,
    /// `k` frame slabs of `[B * frame_dim]` each; `ring[next]` holds the
    /// oldest frame (the one the next push overwrites).
    ring: Vec<f32>,
    next: usize,
}

impl<V: VecEnv> FrameStackVec<V> {
    pub fn new(inner: V, k: usize) -> Self {
        assert!(k >= 1);
        let frame_dim = inner.obs_dim();
        let b = inner.num_envs();
        FrameStackVec { inner, k, frame_dim, ring: vec![0.0; k * b * frame_dim], next: 0 }
    }

    pub fn inner(&self) -> &V {
        &self.inner
    }

    fn push_frames(&mut self, dones: Option<&[bool]>) {
        let b = self.inner.num_envs();
        let (k, d) = (self.k, self.frame_dim);
        let slab_len = b * d;
        debug_assert!(self.next < k, "ring cursor within bounds");
        debug_assert_eq!(self.ring.len(), k * slab_len, "ring covers k full-batch slabs");
        {
            // Newest frame straight from the inner env into its slab — for a
            // sharded env, each shard writes its own disjoint slice here.
            let slab = &mut self.ring[self.next * slab_len..(self.next + 1) * slab_len];
            self.inner.observe_all(slab);
        }
        if let Some(dones) = dones {
            // Episode boundary: clear the env's history in the *other*
            // slabs so the next stacked obs holds only its initial frame.
            for (i, &done) in dones.iter().enumerate().take(b) {
                if !done {
                    continue;
                }
                for j in 0..k {
                    if j != self.next {
                        self.ring[j * slab_len + i * d..j * slab_len + (i + 1) * d].fill(0.0);
                    }
                }
            }
        }
        self.next = (self.next + 1) % k;
    }
}

impl<V: VecEnv> VecEnv for FrameStackVec<V> {
    fn num_envs(&self) -> usize {
        self.inner.num_envs()
    }

    fn obs_dim(&self) -> usize {
        self.frame_dim * self.k
    }

    fn num_actions(&self) -> usize {
        self.inner.num_actions()
    }

    fn reset_all(&mut self, seed: u64) {
        self.inner.reset_all(seed);
        self.ring.fill(0.0);
        self.next = 0;
        self.push_frames(None);
    }

    fn observe_all(&self, out: &mut [f32]) {
        let b = self.inner.num_envs();
        let (k, d) = (self.k, self.frame_dim);
        let slab_len = b * d;
        debug_assert_eq!(out.len(), b * k * d);
        // Assemble per-env stacks, oldest frame first: slab `next` is the
        // oldest, `next + k - 1 (mod k)` the newest.
        for i in 0..b {
            let dst = &mut out[i * k * d..(i + 1) * k * d];
            for j in 0..k {
                let slab = (self.next + j) % k;
                let src = &self.ring[slab * slab_len + i * d..slab * slab_len + (i + 1) * d];
                dst[j * d..(j + 1) * d].copy_from_slice(src);
            }
        }
    }

    fn step_all(&mut self, actions: &[usize], rewards: &mut [f32], dones: &mut [bool]) {
        self.inner.step_all(actions, rewards, dones);
        self.push_frames(Some(dones));
    }

    fn save_state(&self, out: &mut StateWriter) -> crate::Result<()> {
        self.inner.save_state(out)?;
        out.f32s(&self.ring);
        out.usize(self.next);
        Ok(())
    }

    fn load_state(&mut self, r: &mut StateReader) -> crate::Result<()> {
        self.inner.load_state(r)?;
        r.f32s_into(&mut self.ring)?;
        let next = r.usize()?;
        anyhow::ensure!(next < self.k, "frame-stack snapshot cursor {next} out of range");
        self.next = next;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::test_envs::Corridor;

    fn make_vec(n: usize) -> GsVecEnv<Corridor> {
        GsVecEnv::new((0..n).map(|_| Corridor::new(3, 5)).collect())
    }

    #[test]
    fn vec_env_shapes() {
        let mut v = make_vec(4);
        v.reset_all(0);
        let mut obs = vec![0.0; 4 * 3];
        v.observe_all(&mut obs);
        // all at position 0
        for i in 0..4 {
            assert_eq!(obs[i * 3], 1.0);
        }
    }

    #[test]
    fn auto_reset_restarts_episode() {
        let mut v = make_vec(2);
        v.reset_all(0);
        let mut rewards = [0.0; 2];
        let mut dones = [false; 2];
        for t in 0..5 {
            v.step_all(&[1, 0], &mut rewards, &mut dones);
            assert_eq!(dones == [true, true], t == 4);
        }
        // After done, observation is the fresh initial state.
        let mut obs = vec![0.0; 6];
        v.observe_all(&mut obs);
        assert_eq!(&obs[0..3], &[1.0, 0.0, 0.0]);
    }

    #[test]
    fn frame_stack_dims_and_shift() {
        let v = make_vec(2);
        let mut fs = FrameStackVec::new(v, 3);
        fs.reset_all(0);
        assert_eq!(fs.obs_dim(), 9);
        let mut obs = vec![0.0; 2 * 9];
        fs.observe_all(&mut obs);
        // only newest frame populated after reset
        assert_eq!(&obs[0..6], &[0.0; 6]);
        assert_eq!(&obs[6..9], &[1.0, 0.0, 0.0]);

        let mut rewards = [0.0; 2];
        let mut dones = [false; 2];
        fs.step_all(&[1, 1], &mut rewards, &mut dones);
        fs.observe_all(&mut obs);
        // now frames t-1 (pos0) and t (pos1) present
        assert_eq!(&obs[3..6], &[1.0, 0.0, 0.0]);
        assert_eq!(&obs[6..9], &[0.0, 1.0, 0.0]);
    }

    #[test]
    fn frame_stack_clears_on_done() {
        let v = make_vec(1);
        let mut fs = FrameStackVec::new(v, 4);
        fs.reset_all(0);
        let mut rewards = [0.0; 1];
        let mut dones = [false; 1];
        for _ in 0..5 {
            fs.step_all(&[1], &mut rewards, &mut dones);
        }
        assert!(dones[0]);
        let mut obs = vec![0.0; 12];
        fs.observe_all(&mut obs);
        // After auto-reset the stack holds only the new episode's frame.
        assert_eq!(&obs[0..9], &[0.0; 9]);
        assert_eq!(&obs[9..12], &[1.0, 0.0, 0.0]);
    }
}
