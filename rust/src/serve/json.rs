//! Hand-rolled JSON for the serving runtime (no new dependencies —
//! consistent with the vendored-only policy; the encoding idiom matches
//! the distributed coordinator's `report.json`). Two halves:
//!
//! - encoding: string escaping and f32 rendering via Rust's
//!   shortest-roundtrip `Display`, so a value re-parsed as f32 is bitwise
//!   the one that was serialized — the hot-reload tests compare response
//!   bodies byte for byte;
//! - decoding: a strict recursive-descent parser for the *one* request
//!   shape the server accepts (`{"obs": [f32, ...]}`). Strictness is the
//!   point — every malformed body is a structured message naming the
//!   offset, which the HTTP layer turns into a 400, never a panic.

/// Minimal JSON string escaping (quotes, backslashes, control chars) —
/// error strings routinely quote paths and client input.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// One f32 as a JSON number: Rust's shortest-roundtrip `Display` for
/// finite values, `null` for NaN/infinity (which JSON cannot carry — and
/// which a healthy checkpoint never produces).
pub fn num(x: f32) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// An f32 slice as a JSON array.
pub fn nums(xs: &[f32]) -> String {
    let mut out = String::with_capacity(2 + xs.len() * 8);
    out.push('[');
    for (i, &x) in xs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&num(x));
    }
    out.push(']');
    out
}

/// Parse the act-request body `{"obs": [f32, ...]}` strictly: exactly one
/// key, a flat numeric array, nothing trailing. Every rejection names the
/// byte offset and what was expected there.
pub fn parse_obs(body: &[u8]) -> Result<Vec<f32>, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let mut p = Cursor { text, pos: 0 };
    p.skip_ws();
    p.expect(b'{')?;
    p.skip_ws();
    let key = p.string()?;
    if key != "obs" {
        return Err(format!("unknown key \"{}\": the act body is {{\"obs\": [...]}}", escape(&key)));
    }
    p.skip_ws();
    p.expect(b':')?;
    p.skip_ws();
    p.expect(b'[')?;
    let mut obs = Vec::new();
    p.skip_ws();
    if !p.eat(b']') {
        loop {
            obs.push(p.number()?);
            p.skip_ws();
            if p.eat(b']') {
                break;
            }
            p.expect(b',')?;
            p.skip_ws();
        }
    }
    p.skip_ws();
    p.expect(b'}')?;
    p.skip_ws();
    if p.pos != p.text.len() {
        return Err(format!("trailing bytes after the closing '}}' at offset {}", p.pos));
    }
    Ok(obs)
}

struct Cursor<'a> {
    text: &'a str,
    pos: usize,
}

impl Cursor<'_> {
    fn skip_ws(&mut self) {
        let rest = &self.text.as_bytes()[self.pos..];
        let n = rest.iter().take_while(|b| b" \t\r\n".contains(b)).count();
        self.pos += n;
    }

    fn peek(&self) -> Option<u8> {
        self.text.as_bytes().get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> bool {
        if self.peek() == Some(c) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.eat(c) {
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at offset {} (body is {} byte(s))",
                c as char,
                self.pos,
                self.text.len()
            ))
        }
    }

    /// A JSON string without escape sequences — the only strings the act
    /// body carries are bare keys.
    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let start = self.pos;
        let rest = &self.text.as_bytes()[start..];
        let len = rest
            .iter()
            .position(|&b| b == b'"')
            .ok_or_else(|| format!("unterminated string starting at offset {start}"))?;
        self.pos = start + len + 1;
        Ok(self.text[start..start + len].to_string())
    }

    fn number(&mut self) -> Result<f32, String> {
        let start = self.pos;
        let rest = &self.text.as_bytes()[start..];
        let len = rest
            .iter()
            .take_while(|b| b"+-.0123456789eE".contains(b))
            .count();
        if len == 0 {
            return Err(format!("expected a number at offset {start}"));
        }
        let s = &self.text[start..start + len];
        let x: f32 = s.parse().map_err(|_| format!("invalid number '{s}' at offset {start}"))?;
        self.pos = start + len;
        Ok(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escape_matches_json_rules() {
        assert_eq!(escape("plain"), "plain");
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn f32_rendering_roundtrips() {
        for x in [0.0f32, -1.5, 3.141_592_7, 1e-8, -2.5e10] {
            let back: f32 = num(x).parse().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x} must round-trip bitwise");
        }
        assert_eq!(num(f32::NAN), "null");
        assert_eq!(num(f32::INFINITY), "null");
        assert_eq!(nums(&[1.0, -2.5]), "[1,-2.5]");
        assert_eq!(nums(&[]), "[]");
    }

    #[test]
    fn parse_obs_accepts_the_canonical_shape() {
        assert_eq!(parse_obs(br#"{"obs": [1, 2.5, -3e2]}"#).unwrap(), vec![1.0, 2.5, -300.0]);
        assert_eq!(parse_obs(b"{\"obs\":[]}").unwrap(), Vec::<f32>::new());
        assert_eq!(parse_obs(b" { \"obs\" : [ 1 , 2 ] } ").unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn parse_obs_rejects_malformed_bodies_with_offsets() {
        for (body, want) in [
            (&b""[..], "expected '{'"),
            (b"{", "expected '\"'"),
            (b"{\"obs\"", "expected ':'"),
            (b"{\"obs\": [1,]}", "expected a number"),
            (b"{\"obs\": [1 2]}", "expected ','"),
            (b"{\"obs\": [1]", "expected '}'"),
            (b"{\"obs\": [1]} x", "trailing bytes"),
            (b"{\"action\": [1]}", "unknown key"),
            (b"{\"obs\": [1e]}", "invalid number"),
            (b"{\"obs", "unterminated string"),
            (b"\xff\xfe", "not UTF-8"),
        ] {
            let err = parse_obs(body).expect_err(&format!("{body:?} must be rejected"));
            assert!(err.contains(want), "{body:?}: want '{want}' in '{err}'");
        }
    }
}
