//! `repro serve` — a fault-tolerant batched policy-inference front tier
//! over one or more trained checkpoint directories.
//!
//! ```text
//!              accept            bounded conn queue
//!   clients ─▶ acceptor thread ─▶ worker pool (keep-alive HTTP parse,
//!                 │                validate, route by run)
//!                 │                    │ bounded job queue per run
//!                 │                    ▼
//!                 │              engine thread per run (adaptive
//!                 │              micro-batcher → one batched PolicyFwd
//!                 │              per learner per batch)
//! ```
//!
//! Every hosted checkpoint directory is a **run**: its own engine
//! thread, its own atomically hot-reloadable snapshot, its own bounded
//! job queue, all behind the `/v1/runs/<run>/…` namespace. Connections
//! are **persistent** (HTTP/1.1 keep-alive): a worker serves a
//! connection's whole request stream — pipelined requests are answered
//! in order — and closes on client request (`Connection: close`), idle
//! timeout, the per-connection request cap, any parse error (framing is
//! untrustworthy past one), or drain.
//!
//! The robustness contract, end to end:
//! - **overload**: every queue is bounded; a full job queue sheds the
//!   request with `503 + Retry-After` *at admission* (the cheap end),
//!   and jobs whose deadline passes while queued are shed engine-side —
//!   under overload the server does strictly less work per request;
//! - **hostile input**: the strict HTTP layer ([`http`]) and body parser
//!   ([`json`]) turn every malformed byte stream into a structured 4xx
//!   with a stable `code` in the JSON error envelope; a handler panic is
//!   confined to its connection (`catch_unwind` → 500) and the server
//!   keeps serving;
//! - **slow clients**: socket read/write timeouts (408 / disconnect)
//!   bound what a slow-loris peer can hold, per request, keep-alive or
//!   not; between requests the (shorter-spirited) idle timeout applies;
//! - **hot reload**: `POST /v1/runs/<run>/admin/reload` validates the
//!   newest checkpoint *completely off to the side* ([`snapshot`]) and
//!   swaps it in atomically under that run's snapshot lock; a corrupt
//!   candidate is a structured 409 and the old parameters keep serving,
//!   bit-for-bit, with every other run untouched throughout;
//! - **drain**: SIGINT/SIGTERM stop the acceptor, let accepted
//!   connections and queued jobs finish, then exit 0.
//!
//! Endpoints: `POST /v1/runs/<run>/learners/<j>/act`,
//! `POST /v1/runs/<run>/admin/reload`, `GET /healthz`, `GET /readyz`,
//! `GET /v1/meta` (api_version 2). The PR 9 single-run paths
//! (`POST /v1/learners/<j>/act`, `POST /admin/reload`) remain as
//! deprecated aliases onto run 0, answered with a `Deprecation` header
//! and a `Link: …; rel="successor-version"` pointer.

pub mod engine;
pub mod http;
pub mod json;
pub mod snapshot;

use crate::config::ServeConfig;
use crate::serve::engine::{ActJob, EngineConfig, EngineReply};
use crate::serve::snapshot::PolicySnapshot;
use crate::testkit::fault::serve_stall_from_env;
use crate::{log_info, log_warn};
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Resolved serving options (config `[serve]` + CLI overrides + fault
/// injection hooks).
pub struct ServeOptions {
    pub port: u16,
    pub batch_window: Duration,
    pub max_batch: usize,
    pub queue_capacity: usize,
    pub workers: usize,
    pub read_timeout: Duration,
    pub write_timeout: Duration,
    pub request_timeout: Duration,
    pub max_body_bytes: usize,
    /// Requests served on one connection before the server closes it
    /// (resource hygiene: no connection is immortal).
    pub max_requests_per_conn: usize,
    /// How long a keep-alive connection may sit idle *between* requests
    /// before the server closes it silently.
    pub idle_timeout: Duration,
    /// Fault injection: stall every engine this long at startup so tests
    /// can fill the bounded queues deterministically (env
    /// `IALS_SERVE_STALL_MS`, or set directly for in-process tests).
    pub engine_stall: Option<Duration>,
    /// Fault injection: honor the `x-inject-panic` request header by
    /// panicking in the handler (tests the per-connection isolation).
    pub inject_panic: bool,
}

impl ServeOptions {
    /// Resolve from the validated `[serve]` config table, applying the
    /// env fault-injection hook.
    pub fn from_config(cfg: &ServeConfig) -> Result<ServeOptions> {
        Ok(ServeOptions {
            port: cfg.port as u16,
            batch_window: Duration::from_millis(cfg.batch_window_ms),
            max_batch: cfg.max_batch,
            queue_capacity: cfg.queue_capacity,
            workers: cfg.workers,
            read_timeout: Duration::from_millis(cfg.read_timeout_ms),
            write_timeout: Duration::from_millis(cfg.write_timeout_ms),
            request_timeout: Duration::from_millis(cfg.request_timeout_ms),
            max_body_bytes: cfg.max_body_bytes,
            max_requests_per_conn: cfg.max_requests_per_conn,
            idle_timeout: Duration::from_millis(cfg.idle_timeout_ms),
            engine_stall: serve_stall_from_env()?.map(Duration::from_millis),
            inject_panic: false,
        })
    }
}

/// One hosted run: a checkpoint directory with its own snapshot, engine
/// job queue and reload serialization. Everything per-run lives here so
/// runs cannot interfere (a reload or full queue on one run is invisible
/// to the others).
struct RunState {
    /// Route segment (`/v1/runs/<name>/…`): the checkpoint directory's
    /// final path component, sanitized (see [`run_name_from_dir`]).
    name: String,
    checkpoint_dir: PathBuf,
    snapshot: Arc<RwLock<PolicySnapshot>>,
    jobs: SyncSender<ActJob>,
    /// Serializes this run's hot-reloads (concurrent reload POSTs).
    reload_lock: Mutex<()>,
}

/// State shared by the acceptor, workers and admin handlers.
struct Shared {
    opts: ServeOptions,
    runs: Vec<RunState>,
    /// Accepted-but-unhandled connections, bounded at `queue_capacity`.
    conns: Mutex<VecDeque<TcpStream>>,
    conns_cv: Condvar,
    draining: AtomicBool,
    acceptor_done: AtomicBool,
}

/// A running server: spawned threads plus the bound address. Tests drive
/// it in-process; the CLI wraps it in [`run`].
pub struct Server {
    addr: SocketAddr,
    shared: Arc<Shared>,
    acceptor: std::thread::JoinHandle<()>,
    workers: Vec<std::thread::JoinHandle<()>>,
    engines: Vec<std::thread::JoinHandle<()>>,
}

/// Route segment for a checkpoint directory: its final path component
/// with anything outside `[A-Za-z0-9._-]` replaced by `_` (run names
/// live inside URL paths and log lines).
fn run_name_from_dir(dir: &Path) -> String {
    let base = dir.file_name().and_then(|n| n.to_str()).unwrap_or("run");
    let name: String = base
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') { c } else { '_' })
        .collect();
    if name.is_empty() {
        "run".to_string()
    } else {
        name
    }
}

impl Server {
    /// Load the newest valid checkpoint from every directory, bind the
    /// loopback port (0 = ephemeral) and start the acceptor, worker pool
    /// and one engine thread per run. Run 0 is the first directory — the
    /// target of the deprecated single-run aliases.
    pub fn spawn(checkpoint_dirs: &[PathBuf], opts: ServeOptions) -> Result<Server> {
        anyhow::ensure!(!checkpoint_dirs.is_empty(), "serve needs at least one checkpoint dir");
        let listener = TcpListener::bind(("127.0.0.1", opts.port))
            .with_context(|| format!("binding 127.0.0.1:{}", opts.port))?;
        let addr = listener.local_addr().context("reading the bound address")?;
        let mut runs = Vec::with_capacity(checkpoint_dirs.len());
        let mut engines = Vec::with_capacity(checkpoint_dirs.len());
        for (i, dir) in checkpoint_dirs.iter().enumerate() {
            let name = run_name_from_dir(dir);
            if let Some(prev) = runs.iter().position(|r: &RunState| r.name == name) {
                anyhow::bail!(
                    "run name {name:?} is ambiguous: both {} and {} resolve to it — point \
                     --checkpoint-dir at directories with distinct basenames",
                    checkpoint_dirs[prev].display(),
                    dir.display()
                );
            }
            let snap = snapshot::load_newest_valid(dir)
                .with_context(|| format!("loading run {name:?} from {}", dir.display()))?;
            log_info!(
                "[serve] run {name:?}: loaded checkpoint iteration {} ({} learner(s), obs={}, \
                 hid={}, act={})",
                snap.iteration,
                snap.stores.len(),
                snap.obs_dim,
                snap.hid,
                snap.act_dim
            );
            let snapshot = Arc::new(RwLock::new(snap));
            let (jobs, jobs_rx) = std::sync::mpsc::sync_channel(opts.queue_capacity);
            let engine_cfg = EngineConfig {
                batch_window: opts.batch_window,
                max_batch: opts.max_batch,
                stall: opts.engine_stall,
            };
            let engine_snapshot = Arc::clone(&snapshot);
            let engine = std::thread::Builder::new()
                .name(format!("serve-engine-{i}"))
                .spawn(move || engine::run_engine(jobs_rx, engine_snapshot, engine_cfg))
                .with_context(|| format!("spawning run {name:?}'s engine thread"))?;
            engines.push(engine);
            runs.push(RunState {
                name,
                checkpoint_dir: dir.clone(),
                snapshot,
                jobs,
                reload_lock: Mutex::new(()),
            });
        }
        let n_workers = opts.workers;
        let shared = Arc::new(Shared {
            opts,
            runs,
            conns: Mutex::new(VecDeque::new()),
            conns_cv: Condvar::new(),
            draining: AtomicBool::new(false),
            acceptor_done: AtomicBool::new(false),
        });
        let acceptor_shared = Arc::clone(&shared);
        let acceptor = std::thread::Builder::new()
            .name("serve-accept".to_string())
            .spawn(move || run_acceptor(listener, acceptor_shared))
            .context("spawning the acceptor thread")?;
        let mut workers = Vec::with_capacity(n_workers);
        for i in 0..n_workers {
            let worker_shared = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("serve-worker-{i}"))
                .spawn(move || run_worker(worker_shared))
                .with_context(|| format!("spawning worker {i}"))?;
            workers.push(handle);
        }
        Ok(Server { addr, shared, acceptor, workers, engines })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The hosted run names, in route order (run 0 first).
    pub fn run_names(&self) -> Vec<String> {
        self.shared.runs.iter().map(|r| r.name.clone()).collect()
    }

    /// Start draining: stop accepting, let in-flight work finish.
    /// Idempotent; [`Server::join`] completes the drain.
    pub fn begin_shutdown(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
        self.shared.conns_cv.notify_all();
    }

    /// Complete a graceful drain: join the acceptor, then the workers
    /// (which first empty the accepted-connection queue), then drop the
    /// job-queue handles so every engine finishes queued jobs and exits.
    pub fn join(self) -> Result<()> {
        let Server { shared, acceptor, workers, engines, .. } = self;
        shared.draining.store(true, Ordering::SeqCst);
        acceptor.join().map_err(|_| anyhow::anyhow!("the acceptor thread panicked"))?;
        shared.conns_cv.notify_all();
        for (i, w) in workers.into_iter().enumerate() {
            w.join().map_err(|_| anyhow::anyhow!("worker {i} panicked"))?;
        }
        // Last submitter handles: dropping them disconnects each job
        // queue *after* its queued jobs are delivered, draining engines.
        drop(shared);
        for (i, e) in engines.into_iter().enumerate() {
            e.join().map_err(|_| anyhow::anyhow!("run {i}'s engine thread panicked"))?;
        }
        Ok(())
    }
}

/// Accept loop: hand connections to the worker pool; shed with a fast
/// 503 when the connection queue itself is full; exit when draining.
fn run_acceptor(listener: TcpListener, shared: Arc<Shared>) {
    if let Err(e) = listener.set_nonblocking(true) {
        log_warn!("[serve] cannot set the listener nonblocking ({e}); drain may lag");
    }
    loop {
        if shared.draining.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nonblocking(false);
                let mut q = shared.conns.lock().unwrap_or_else(|e| e.into_inner());
                if q.len() >= shared.opts.queue_capacity {
                    drop(q);
                    shed_connection(&shared, stream);
                } else {
                    q.push_back(stream);
                    drop(q);
                    shared.conns_cv.notify_one();
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => {
                log_warn!("[serve] accept failed: {e}");
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }
    shared.acceptor_done.store(true, Ordering::SeqCst);
    shared.conns_cv.notify_all();
}

/// Connection-level load shedding: answer 503 without parsing anything.
fn shed_connection(shared: &Shared, stream: TcpStream) {
    let _ = stream.set_write_timeout(Some(shared.opts.write_timeout));
    let message = format!(
        "connection queue is full ({} pending) — shedding load",
        shared.opts.queue_capacity
    );
    let body = http::error_body("queue_full", &message, Some(1000));
    let mut s = &stream;
    let _ = http::write_response(&mut s, 503, &[("retry-after", "1")], &body, true);
}

/// Worker loop: pop an accepted connection, serve its whole request
/// stream, repeat. Exits only when draining *and* the acceptor is done
/// *and* the queue is empty — accepted connections always complete.
fn run_worker(shared: Arc<Shared>) {
    loop {
        let stream = {
            let mut q = shared.conns.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if let Some(s) = q.pop_front() {
                    break Some(s);
                }
                let drained = shared.draining.load(Ordering::SeqCst)
                    && shared.acceptor_done.load(Ordering::SeqCst);
                if drained {
                    break None;
                }
                let (guard, _timeout) = shared
                    .conns_cv
                    .wait_timeout(q, Duration::from_millis(100))
                    .unwrap_or_else(|e| e.into_inner());
                q = guard;
            }
        };
        match stream {
            Some(s) => handle_connection(&shared, s),
            None => return,
        }
    }
}

/// Handle one connection with panic isolation: a panic anywhere in
/// parsing or routing is caught, answered with a 500 (and a close), and
/// confined to this connection — the server keeps serving.
fn handle_connection(shared: &Shared, stream: TcpStream) {
    let _ = stream.set_write_timeout(Some(shared.opts.write_timeout));
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        serve_connection(shared, &stream);
    }));
    if outcome.is_err() {
        log_warn!("[serve] a request handler panicked; the connection got a 500");
        let body =
            http::error_body("internal", "internal error: the request handler panicked", None);
        let mut s = &stream;
        let _ = http::write_response(&mut s, 500, &[], &body, true);
    }
}

/// Serve a connection's whole request stream (HTTP/1.1 keep-alive).
///
/// The `BufReader` persists across requests — pipelined bytes the client
/// sent ahead sit in its buffer and each `read_request` consumes exactly
/// one request, so pipelined responses come back in request order.
///
/// Close conditions, each applied per-request:
/// - the client asked (`Connection: close` / HTTP/1.0 default);
/// - the per-connection request cap is reached (the capped response
///   says `connection: close`);
/// - any parse error (respond, then close: framing is untrustworthy);
/// - the server is draining;
/// - idle timeout or clean EOF *between* requests (silent close — an
///   idle keep-alive client is normal, not an error).
fn serve_connection(shared: &Shared, stream: &TcpStream) {
    use std::io::BufRead as _;
    let mut reader = std::io::BufReader::new(stream);
    let mut served = 0usize;
    loop {
        // Between requests: wait up to the idle timeout for the next
        // request's first byte. EOF and timeout here are the normal ends
        // of a keep-alive connection — close silently, answer nothing.
        let _ = stream.set_read_timeout(Some(shared.opts.idle_timeout));
        match reader.fill_buf() {
            Ok([]) => return,  // clean EOF between requests
            Ok(_) => {}        // first byte(s) of a request are waiting
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                return; // idle timeout
            }
            Err(_) => return,
        }
        // A request is arriving: switch to the per-request read timeout
        // (the slow-loris bound, same as one-request-per-connection).
        let _ = stream.set_read_timeout(Some(shared.opts.read_timeout));
        match http::read_request(&mut reader, shared.opts.max_body_bytes) {
            Err(e) => {
                let body = http::error_body(e.code, &e.reason, None);
                let mut s = stream;
                let _ = http::write_response(&mut s, e.status, &[], &body, true);
                if e.drain > 0 {
                    // Drain from the reader, not the raw stream: the
                    // refused body may be partially buffered already.
                    discard(&mut reader, e.drain);
                }
                return;
            }
            Ok(req) => {
                served += 1;
                let resp = route(shared, &req);
                let close = req.wants_close()
                    || served >= shared.opts.max_requests_per_conn
                    || shared.draining.load(Ordering::SeqCst);
                let headers: Vec<(&str, &str)> =
                    resp.headers.iter().map(|(k, v)| (*k, v.as_str())).collect();
                let mut s = stream;
                if http::write_response(&mut s, resp.status, &headers, &resp.body, close).is_err() {
                    return; // peer gone or write timeout; nothing to salvage
                }
                if close {
                    return;
                }
            }
        }
    }
}

/// Read and throw away up to `limit` bytes the client is still sending
/// (bounded by the socket read timeout per chunk), so closing the socket
/// after a refusal does not RST the already-written response away.
fn discard(reader: &mut impl std::io::Read, limit: usize) {
    let mut sink = [0u8; 4096];
    let mut taken = 0usize;
    while taken < limit {
        match reader.read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(n) => taken += n,
        }
    }
}

/// A routed response: status, extra headers (retry/deprecation hints)
/// and the JSON body. The connection loop decides `connection:` itself.
struct Response {
    status: u16,
    headers: Vec<(&'static str, String)>,
    body: Vec<u8>,
}

fn ok_json(body: String) -> Response {
    Response { status: 200, headers: Vec::new(), body: body.into_bytes() }
}

fn reject(status: u16, code: &'static str, message: &str) -> Response {
    Response { status, headers: Vec::new(), body: http::error_body(code, message, None) }
}

/// A retryable 503: `Retry-After` header for generic clients plus the
/// machine-readable `retry_after_ms` inside the envelope.
fn shed(code: &'static str, message: &str) -> Response {
    Response {
        status: 503,
        headers: vec![("retry-after", "1".to_string())],
        body: http::error_body(code, message, Some(1000)),
    }
}

/// Resolve a `/v1/runs/<run>/…` segment: by name first, then (so sharp
/// tools keep working) by numeric route index.
fn lookup_run<'a>(shared: &'a Shared, segment: &str) -> Option<&'a RunState> {
    if let Some(run) = shared.runs.iter().find(|r| r.name == segment) {
        return Some(run);
    }
    segment.parse::<usize>().ok().and_then(|i| shared.runs.get(i))
}

/// Dispatch a parsed request to its handler.
fn route(shared: &Shared, req: &http::Request) -> Response {
    if shared.opts.inject_panic && req.header("x-inject-panic").is_some() {
        panic!("injected panic (x-inject-panic)");
    }
    // The resource-oriented namespace: /v1/runs/<run>/…
    if let Some(rest) = req.target.strip_prefix("/v1/runs/") {
        let Some((segment, tail)) = rest.split_once('/') else {
            return reject(
                404,
                "not_found",
                &format!("no route for {} {} (want /v1/runs/<run>/…)", req.method, req.target),
            );
        };
        let Some(run) = lookup_run(shared, segment) else {
            let hosted: Vec<&str> = shared.runs.iter().map(|r| r.name.as_str()).collect();
            return reject(
                404,
                "unknown_run",
                &format!("unknown run {segment:?}; hosted runs: {hosted:?}"),
            );
        };
        return route_run(shared, run, tail, req);
    }
    // Deprecated PR 9 single-run aliases: served (not redirected) via
    // run 0 so existing clients keep working, with a `Deprecation`
    // header and a `Link` to the successor route.
    if let Some(rest) = req.target.strip_prefix("/v1/learners/") {
        if rest.strip_suffix("/act").is_some() {
            let run = &shared.runs[0];
            let mut resp = route_run(shared, run, &format!("learners/{rest}"), req);
            deprecate(&mut resp, format!("/v1/runs/{}/learners/{rest}", run.name));
            return resp;
        }
    }
    if req.target == "/admin/reload" {
        let run = &shared.runs[0];
        let mut resp = route_run(shared, run, "admin/reload", req);
        deprecate(&mut resp, format!("/v1/runs/{}/admin/reload", run.name));
        return resp;
    }
    match (req.method.as_str(), req.target.as_str()) {
        ("GET", "/healthz") => ok_json("{\"status\":\"ok\"}".to_string()),
        ("GET", "/readyz") => {
            if shared.draining.load(Ordering::SeqCst) {
                reject(503, "draining", "draining")
            } else {
                let snap = shared.runs[0].snapshot.read().unwrap_or_else(|e| e.into_inner());
                ok_json(format!(
                    "{{\"status\":\"ready\",\"checkpoint_iteration\":{},\"runs\":{}}}",
                    snap.iteration,
                    shared.runs.len()
                ))
            }
        }
        ("GET", "/v1/meta") => handle_meta(shared),
        (method, target) => {
            reject(404, "not_found", &format!("no route for {method} {target}"))
        }
    }
}

/// Mark a response as coming from a deprecated alias route.
fn deprecate(resp: &mut Response, successor: String) {
    resp.headers.push(("deprecation", "true".to_string()));
    resp.headers.push(("link", format!("<{successor}>; rel=\"successor-version\"")));
}

/// Route within one run's namespace: `learners/<j>/act` and
/// `admin/reload` (both POST-only).
fn route_run(shared: &Shared, run: &RunState, tail: &str, req: &http::Request) -> Response {
    if let Some(idx) = tail.strip_prefix("learners/").and_then(|r| r.strip_suffix("/act")) {
        if req.method != "POST" {
            let message = format!("{} {} — act is POST-only", req.method, req.target);
            return reject(405, "method_not_allowed", &message);
        }
        return handle_act(shared, run, idx, &req.body);
    }
    if tail == "admin/reload" {
        if req.method != "POST" {
            let message = format!("{} {} — reload is POST-only", req.method, req.target);
            return reject(405, "method_not_allowed", &message);
        }
        return handle_reload(run);
    }
    reject(404, "not_found", &format!("no route for {} {}", req.method, req.target))
}

/// `GET /v1/meta` (api_version 2): enumerate every hosted run with its
/// serving geometry. The top level also mirrors run 0's fields in the
/// v1 shape, matching the deprecated single-run routes' lifecycle.
fn handle_meta(shared: &Shared) -> Response {
    let mut runs_json = Vec::with_capacity(shared.runs.len());
    for run in &shared.runs {
        let snap = run.snapshot.read().unwrap_or_else(|e| e.into_inner());
        runs_json.push(format!(
            "{{\"name\":\"{}\",\"checkpoint_iteration\":{},\"learners\":{},\"obs_dim\":{},\
             \"act_dim\":{},\"hidden\":{},\"policy_model\":\"{}\",\"domain\":\"{}\",\
             \"simulator\":\"{}\"}}",
            json::escape(&run.name),
            snap.iteration,
            snap.stores.len(),
            snap.obs_dim,
            snap.act_dim,
            snap.hid,
            json::escape(&snap.meta.policy_model),
            json::escape(&snap.meta.domain),
            json::escape(&snap.meta.simulator)
        ));
    }
    let snap0 = shared.runs[0].snapshot.read().unwrap_or_else(|e| e.into_inner());
    ok_json(format!(
        "{{\"api_version\":2,\"runs\":[{}],\"checkpoint_iteration\":{},\"learners\":{},\
         \"obs_dim\":{},\"act_dim\":{},\"hidden\":{},\"policy_model\":\"{}\",\"domain\":\"{}\",\
         \"simulator\":\"{}\"}}",
        runs_json.join(","),
        snap0.iteration,
        snap0.stores.len(),
        snap0.obs_dim,
        snap0.act_dim,
        snap0.hid,
        json::escape(&snap0.meta.policy_model),
        json::escape(&snap0.meta.domain),
        json::escape(&snap0.meta.simulator)
    ))
}

/// `POST /v1/runs/<run>/learners/<j>/act`: validate, submit to the run's
/// engine with a deadline, block for the reply. Queue-full and
/// expired-deadline paths are the 503 shed contract; an unresponsive
/// engine is a 504.
fn handle_act(shared: &Shared, run: &RunState, idx: &str, body: &[u8]) -> Response {
    let Ok(learner) = idx.parse::<usize>() else {
        return reject(
            404,
            "unknown_learner",
            &format!("learner index {:?} is not an integer", idx),
        );
    };
    let (learners, obs_dim) = {
        let snap = run.snapshot.read().unwrap_or_else(|e| e.into_inner());
        (snap.stores.len(), snap.obs_dim)
    };
    if learner >= learners {
        let message = format!(
            "learner {learner} out of range (run {:?} hosts {learners} learner(s))",
            run.name
        );
        return reject(404, "unknown_learner", &message);
    }
    let obs = match json::parse_obs(body) {
        Ok(obs) => obs,
        Err(reason) => return reject(400, "bad_request", &reason),
    };
    if obs.len() != obs_dim {
        let message = format!("obs has {} element(s), the policy wants {obs_dim}", obs.len());
        return reject(400, "bad_request", &message);
    }
    let (resp_tx, resp_rx) = std::sync::mpsc::sync_channel::<EngineReply>(1);
    let job = ActJob {
        learner,
        obs,
        deadline: Instant::now() + shared.opts.request_timeout,
        resp: resp_tx,
    };
    match run.jobs.try_send(job) {
        Ok(()) => {}
        Err(TrySendError::Full(_)) => {
            let message = format!(
                "run {:?}'s request queue is full (capacity {}) — shedding load",
                run.name, shared.opts.queue_capacity
            );
            return shed("queue_full", &message);
        }
        Err(TrySendError::Disconnected(_)) => {
            return shed("draining", "the inference engine is shutting down");
        }
    }
    // Small grace past the deadline so the engine's own shed reply (a
    // structured 503) wins over the blunt worker-side 504.
    let wait = shared.opts.request_timeout + Duration::from_millis(250);
    match resp_rx.recv_timeout(wait) {
        Ok(EngineReply::Act { action, value, logits }) => ok_json(format!(
            "{{\"learner\":{learner},\"action\":{action},\"value\":{},\"logits\":{}}}",
            json::num(value),
            json::nums(&logits)
        )),
        Ok(EngineReply::Shed { reason }) => shed("deadline_exceeded", &reason),
        Err(_) => reject(504, "engine_timeout", "timed out waiting for the inference engine"),
    }
}

/// `POST /v1/runs/<run>/admin/reload`: atomic checkpoint hot-reload for
/// one run. The newest file is validated completely off to the side;
/// only a fully valid, geometry-compatible snapshot is swapped in (under
/// the run's write lock, so every act request sees either all-old or
/// all-new parameters). Any rejection is a structured 409 and the old
/// snapshot keeps serving. Other runs are untouched either way.
fn handle_reload(run: &RunState) -> Response {
    let _serialized = run.reload_lock.lock().unwrap_or_else(|e| e.into_inner());
    let candidate = match snapshot::load_newest_strict(&run.checkpoint_dir) {
        Ok(snap) => snap,
        Err(e) => {
            log_warn!("[serve] run {:?}: reload rejected: {e:#}", run.name);
            let message =
                format!("reload rejected; still serving the old snapshot: {e:#}");
            return reject(409, "reload_conflict", &message);
        }
    };
    {
        let cur = run.snapshot.read().unwrap_or_else(|e| e.into_inner());
        let same_geometry = candidate.stores.len() == cur.stores.len()
            && candidate.obs_dim == cur.obs_dim
            && candidate.hid == cur.hid
            && candidate.act_dim == cur.act_dim
            && candidate.meta.policy_model == cur.meta.policy_model;
        if !same_geometry {
            let message = format!(
                "reload rejected; the candidate's geometry ({} learner(s), obs={}, hid={}, \
                 act={}, model={}) does not match the serving snapshot ({} learner(s), obs={}, \
                 hid={}, act={}, model={})",
                candidate.stores.len(),
                candidate.obs_dim,
                candidate.hid,
                candidate.act_dim,
                candidate.meta.policy_model,
                cur.stores.len(),
                cur.obs_dim,
                cur.hid,
                cur.act_dim,
                cur.meta.policy_model
            );
            log_warn!("[serve] run {:?}: {message}", run.name);
            return reject(409, "reload_conflict", &message);
        }
    }
    let mut cur = run.snapshot.write().unwrap_or_else(|e| e.into_inner());
    let from = cur.iteration;
    let to = candidate.iteration;
    *cur = candidate;
    drop(cur);
    log_info!("[serve] run {:?}: hot-reloaded checkpoint: iteration {from} -> {to}", run.name);
    ok_json(format!(
        "{{\"status\":\"reloaded\",\"run\":\"{}\",\"from_iteration\":{from},\
         \"to_iteration\":{to}}}",
        json::escape(&run.name)
    ))
}

/// Signal-driven shutdown flag (SIGINT/SIGTERM → drain). A bare
/// `AtomicBool` store is the whole handler — async-signal-safe.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

extern "C" fn on_shutdown_signal(_sig: i32) {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_shutdown_signal);
        signal(SIGTERM, on_shutdown_signal);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

/// CLI entry (`repro serve`): spawn the server over every checkpoint
/// directory, print the bound address, serve until SIGINT/SIGTERM, then
/// drain gracefully and return Ok — the process exits 0 on a clean
/// drain.
pub fn run(checkpoint_dirs: &[PathBuf], opts: ServeOptions) -> Result<()> {
    install_signal_handlers();
    let server = Server::spawn(checkpoint_dirs, opts)?;
    log_info!("[serve] hosting {} run(s): {:?}", checkpoint_dirs.len(), server.run_names());
    // The line tests and scripts parse to find the (possibly ephemeral)
    // port; stdout is flushed so `kill -INT` races nothing.
    println!("serving on http://{}", server.addr());
    use std::io::Write as _;
    let _ = std::io::stdout().flush();
    while !SHUTDOWN.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(50));
    }
    log_info!("[serve] shutdown signal received — draining");
    server.begin_shutdown();
    server.join()?;
    log_info!("[serve] drained cleanly");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_names_are_sanitized_path_basenames() {
        assert_eq!(run_name_from_dir(Path::new("/tmp/ckpt/ials-fig3_seed3")), "ials-fig3_seed3");
        assert_eq!(run_name_from_dir(Path::new("rel/dir.v2")), "dir.v2");
        assert_eq!(run_name_from_dir(Path::new("/x/has spaces+stuff")), "has_spaces_stuff");
        assert_eq!(run_name_from_dir(Path::new("/")), "run");
    }
}
